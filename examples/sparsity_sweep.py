"""Self-contained mini-reproduction of the paper's central claim: sparse
(GraphBLAS) forward propagation overtakes dense (BLAS) once the weight
matrix is sparse enough, and saturates at a fixed-cost floor.

A condensed version of benchmarks/fig5_sweep.py for interactive use.

Run: PYTHONPATH=src python examples/sparsity_sweep.py [--m 2048]
"""

import argparse
import time

import jax
import jax.numpy as jnp
from jax.experimental import sparse as jsparse

from repro.sparse import ops as sparse_ops
from repro.sparse.bsr import BlockSparseMatrix


def bench(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()
    m, n = args.m, args.batch
    key = jax.random.key(0)

    w = jax.random.uniform(key, (m, m), jnp.float32, -1.0, 3.0)
    y = jax.random.uniform(jax.random.fold_in(key, 1), (m, n))
    b = jnp.zeros((m,))

    dense = jax.jit(lambda w, y, b: jnp.maximum(w @ y + b[:, None], 0.0))
    t_dense = bench(dense, w, y, b)
    print(f"m={m} batch={n}")
    print(f"{'inv sparsity':>12s} {'BLAS':>10s} {'GrB-element':>12s} {'GrB-block':>10s} {'el speedup':>10s}")

    sp_el = jax.jit(
        lambda ws, y, b: jnp.maximum(
            jsparse.bcoo_dot_general(ws, y, dimension_numbers=(((1,), (0,)), ((), ())))
            + b[:, None],
            0.0,
        )
    )
    sp_bl = jax.jit(sparse_ops.bsr_matmul_fused_relu)
    import numpy as np

    for inv in (1, 4, 16, 64, 256, 1024, 4096):
        rng = np.random.default_rng(0)
        wh = np.asarray(w)
        if inv > 1:
            wh = np.where(rng.random((m, m)) < 1.0 / inv, wh, 0.0).astype("float32")
        ws = jsparse.BCOO.fromdense(jnp.asarray(wh))
        t_el = bench(sp_el, ws, y, b)
        block = 16
        bpr = max(1, round((m // block) / inv))
        wb = BlockSparseMatrix.random(key, (m, m), (block, block), bpr)
        t_bl = bench(sp_bl, wb, y, b)
        print(
            f"{inv:12d} {t_dense*1e3:9.2f}ms {t_el*1e3:11.2f}ms "
            f"{t_bl*1e3:9.2f}ms {t_dense/t_el:9.2f}x"
        )
    print("(expect: BLAS flat; GrB arms cross below 1x between inv 4–16, "
          "then saturate — paper Fig. 5)")


if __name__ == "__main__":
    main()
