"""Serve a small LLM with batched requests through the serving engine.

Uses any assigned ``--arch`` at reduced (CPU-runnable) scale — the same
Engine/prefill/decode code path the decode_32k / long_500k dry-run cells
lower at production scale. Reports prefill + per-token decode throughput
and the KV-cache footprint.

Run: PYTHONPATH=src python examples/serve_llm.py --arch llama3.2-1b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import Model
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).scaled_down(
        d_model=128, vocab_size=1024, max_seq_len=256
    )
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    n = sum(l.size for l in jax.tree.leaves(params))
    print(f"== serving {cfg.name}: {n/1e6:.2f}M params, "
          f"batch {args.batch}, {args.prompt_len}+{args.new_tokens} tokens ==")

    engine = Engine(
        model,
        params,
        batch_size=args.batch,
        cache_len=args.prompt_len + args.new_tokens,
        temperature=args.temperature,
    )
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    ).astype(jnp.int32)

    t0 = time.monotonic()
    tokens, stats = engine.generate(prompts, args.new_tokens)  # includes compile
    t_cold = time.monotonic() - t0
    t0 = time.monotonic()
    tokens, stats = engine.generate(prompts, args.new_tokens)
    t_warm = time.monotonic() - t0

    print(f"cold (with compile): {t_cold:.2f}s; warm: {t_warm:.2f}s "
          f"→ {stats['generated_tokens']/t_warm:,.0f} tok/s")
    print(f"KV/state cache: {stats['cache_bytes']/2**20:.1f} MiB")
    print("sample:", tokens[0, :16].tolist())
    assert tokens.shape == (args.batch, args.new_tokens)
    print("serve_llm OK")


if __name__ == "__main__":
    main()
