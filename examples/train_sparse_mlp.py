"""End-to-end driver: dense-train → block-prune → sparse-retrain the
paper's square ReLU MLP, with the sparse Pallas kernels (and their
custom VJPs) in the training hot path.

Phases:
  1. dense training on a fixed random teacher (regression — the panel
     convention of the paper: features down, batch across);
  2. block-magnitude pruning of every layer to the target density —
     weights become ELL-padded BSR (``--layout bcsr`` re-flattens them
     to the occupancy-exact block-CSR layout; ``--layout auto`` applies
     ``repro.core.dnn.preferred_layout`` per layer);
  3. sparse retraining through ``repro.train.sparse``: forward AND
     backward run the SpMM kernels via their ``jax.custom_vjp`` rules —
     dX = Wᵀ·dY (a Pallas kernel call on the block-CSR transpose for
     CSR layers) and weight cotangents only at stored blocks, so the
     pruned topology is frozen by construction.

``--backend kernel`` forces the Pallas path (interpret mode off-TPU:
correct but slow — shrink --m/--layers); ``--backend xla`` uses the jnp
oracle forms (identical math, fast on CPU); ``auto`` picks kernel on TPU.

Run: PYTHONPATH=src python examples/train_sparse_mlp.py --m 256 --layers 4 --steps 60
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import dnn, pruning
from repro.sparse.bcsr import BlockCSRMatrix
from repro.train.optimizer import adamw, warmup_cosine
from repro.train.sparse import (
    grad_sparsity_preserved,
    init_sparse_mlp_state,
    make_sparse_train_step,
)

Array = jax.Array


def make_batch(key, m: int, batch: int, teacher_ws, teacher_bs):
    """Teacher-generated (y0, targets) panels — a learnable mapping whose
    targets are realizable by the student architecture."""
    y0 = jax.random.uniform(key, (m, batch))
    targets = dnn.dnn_forward(teacher_ws, teacher_bs, y0, fused=True)
    return {"y0": y0, "targets": targets}


def run_phase(state, step_fn, make_batch_fn, *, steps, seed, tag):
    t0 = time.monotonic()
    first = last = None
    for i in range(steps):
        batch = make_batch_fn(jax.random.key(seed + i))
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if i % max(1, steps // 10) == 0 or i == steps - 1:
            dt = time.monotonic() - t0
            print(f"[{tag}] step {i:4d} loss={loss:.6f} ({dt:.1f}s)", flush=True)
    print(f"[{tag}] loss {first:.6f} → {last:.6f}")
    return state, last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--retrain-steps", type=int, default=None)
    ap.add_argument("--inverse-sparsity", type=int, default=4)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--layout", choices=["ell", "bcsr", "auto"], default="auto")
    ap.add_argument("--backend", choices=["auto", "kernel", "xla"], default="auto")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    m, L = args.m, args.layers
    use_kernel = (
        jax.default_backend() == "tpu"
        if args.backend == "auto"
        else args.backend == "kernel"
    )
    print(
        f"== prune→retrain driver: {L}L of {m}² "
        f"({L * m * m / 1e6:.1f}M params), target 1/{args.inverse_sparsity} "
        f"density, backend={'pallas-kernel' if use_kernel else 'xla-oracle'} =="
    )

    # teacher = a frozen BLOCK-SPARSE net at the target density, so the
    # pruned student can represent the mapping exactly (realizable task)
    ncb = m // args.block
    bpr = max(1, round(ncb / args.inverse_sparsity))
    tkeys = jax.random.split(jax.random.key(99), L)
    teacher_ws = [
        pruning.block_prune(
            jax.random.normal(k, (m, m)) * (0.7 / m**0.5),
            (args.block, args.block),
            bpr,
        )
        for k in tkeys
    ]
    teacher_bs = [jnp.zeros((m,)) for _ in range(L)]

    def batch_fn(key):
        return make_batch(key, m, args.batch, teacher_ws, teacher_bs)

    # student init: dense
    skeys = jax.random.split(jax.random.key(args.seed), L)
    weights = [jax.random.normal(k, (m, m)) / m**0.5 for k in skeys]
    biases = [jnp.zeros((m,)) for _ in range(L)]

    opt = adamw(
        warmup_cosine(3e-3, 10, args.steps * 2), weight_decay=0.0
    )

    # Phase 1: dense training (XLA matmuls — dense has no sparse kernel)
    state = init_sparse_mlp_state(weights, biases, opt)
    step_dense = jax.jit(make_sparse_train_step(opt, use_kernel=False))
    state, dense_loss = run_phase(
        state, step_dense, batch_fn, steps=args.steps, seed=args.seed, tag="dense"
    )

    # Phase 2: block-magnitude prune → BSR (optionally re-layout)
    sparse_ws = []
    for w in state.weights:
        sw = pruning.block_prune(w, (args.block, args.block), bpr)
        if args.layout == "bcsr":
            sw = BlockCSRMatrix.from_bsr(sw)
        elif args.layout == "auto":
            sw = dnn.to_preferred_layout(sw)
        sparse_ws.append(sw)
    dense_bytes = L * m * m * 4
    sparse_bytes = sum(w.nbytes for w in sparse_ws)
    layouts = [type(w).__name__ for w in sparse_ws]
    print(
        f"[prune] params {dense_bytes / 2**20:.1f} MiB → "
        f"{sparse_bytes / 2**20:.1f} MiB; layouts {sorted(set(layouts))}"
    )
    probe = batch_fn(jax.random.key(7))
    out0 = dnn.dnn_forward_trainable(
        sparse_ws, state.biases, probe["y0"], use_kernel=use_kernel
    )
    loss0 = float(0.5 * jnp.mean((out0 - probe["targets"]) ** 2))
    print(f"[prune] post-prune loss {loss0:.6f} (dense was {dense_loss:.6f})")

    # Phase 3: sparse retraining — kernels (+ custom VJPs) in the hot path
    retrain = args.retrain_steps or max(args.steps // 2, 10)
    opt2 = adamw(warmup_cosine(1e-3, 5, retrain), weight_decay=0.0)
    state2 = init_sparse_mlp_state(sparse_ws, state.biases, opt2)
    step_sparse = jax.jit(
        make_sparse_train_step(opt2, use_kernel=use_kernel)
    )
    # one-shot invariant check: the weight cotangent lives in the primal
    # sparsity pattern (the custom-VJP guarantee)
    _, (dws, _) = dnn.dnn_value_and_grad(
        state2.weights,
        state2.biases,
        probe["y0"],
        probe["targets"],
        use_kernel=use_kernel,
    )
    assert grad_sparsity_preserved(state2.weights, dws)
    print("[check] weight cotangent sparsity pattern == primal pattern")

    state2, sparse_loss = run_phase(
        state2,
        step_sparse,
        batch_fn,
        steps=retrain,
        seed=args.seed + 10_000,
        tag="sparse-retrain",
    )
    verdict = "recovered" if sparse_loss <= loss0 else "check schedule"
    print(
        f"[done] dense {dense_loss:.6f} | post-prune {loss0:.6f} | "
        f"retrained-sparse {sparse_loss:.6f} ({verdict})"
    )


if __name__ == "__main__":
    main()
