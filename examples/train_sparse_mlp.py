"""End-to-end driver: train a ~100M-parameter ReLU MLP (the paper's own
architecture family), then prune → sparse-retrain — the Deep-Compression
pipeline the paper cites as the source of sparse weight matrices.

Phases:
  1. dense training on a learnable synthetic task (fixed random teacher);
  2. block-magnitude pruning of every layer to the target density
     (weights → ELL-padded BSR, the TPU-native sparse format);
  3. sparse retraining — gradients flow through the BSR blocks, topology
     stays frozen (exactly the paper's "retrain the pruned network").

Defaults build 24 layers of 2048² ≈ 100.7M params; use --m/--layers to
shrink for a quick run.

Run: PYTHONPATH=src python examples/train_sparse_mlp.py --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import graphblas_mlp
from repro.models.model import Model
from repro.train import adamw
from repro.train.optimizer import warmup_cosine
from repro.train.trainer import init_train_state, make_train_step


def make_batch(key, m: int, batch: int, teacher):
    x = jax.random.uniform(key, (batch, m))
    labels = jnp.argmax(x @ teacher, axis=-1)  # learnable mapping
    return {"inputs": x, "labels": labels[:, None]}


def run_phase(model, state, step_fn, teacher, *, steps, seed, tag):
    m = model.cfg.d_model
    t0 = time.monotonic()
    first = last = None
    for i in range(steps):
        batch = make_batch(jax.random.key(seed + i), m, 64, teacher)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if i % max(1, steps // 10) == 0 or i == steps - 1:
            dt = time.monotonic() - t0
            print(f"[{tag}] step {i:4d} loss={loss:.4f} ({dt:.1f}s)", flush=True)
    print(f"[{tag}] loss {first:.4f} → {last:.4f}")
    return state, last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2048)
    ap.add_argument("--layers", type=int, default=24)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--retrain-steps", type=int, default=None)
    ap.add_argument("--inverse-sparsity", type=int, default=4)
    ap.add_argument("--block", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = graphblas_mlp.make_config(
        m=args.m,
        num_layers=args.layers,
        inverse_sparsity=args.inverse_sparsity,
        block=args.block,
    )
    model = Model(cfg)
    n_params = model.param_count()
    print(f"== prune→retrain driver: {args.layers}L of {args.m}² "
          f"= {n_params/1e6:.1f}M params, target 1/{args.inverse_sparsity} density ==")

    teacher = jax.random.normal(jax.random.key(99), (args.m, args.m)) / args.m**0.5
    opt = adamw(warmup_cosine(1e-3, 20, args.steps * 2), weight_decay=0.0)
    state = init_train_state(model, opt, jax.random.key(args.seed))
    step_fn = jax.jit(make_train_step(model, opt))

    # Phase 1: dense training
    state, dense_loss = run_phase(
        model, state, step_fn, teacher,
        steps=args.steps, seed=args.seed, tag="dense",
    )

    # Phase 2: block-magnitude prune → BSR
    sparse_params = model.sparsify(state.params)
    dense_bytes = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(state.params)
    )
    sparse_bytes = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(sparse_params)
    )
    print(f"[prune] params {dense_bytes/2**20:.0f} MiB → {sparse_bytes/2**20:.0f} MiB")
    loss0, _ = model.loss(
        sparse_params, make_batch(jax.random.key(7), args.m, 64, teacher)
    )
    print(f"[prune] post-prune loss {float(loss0):.4f} (dense was {dense_loss:.4f})")

    # Phase 3: sparse retraining (BSR blocks are trainable pytree leaves)
    state2 = init_train_state(model, opt, jax.random.key(args.seed))._replace(
        params=sparse_params
    )
    state2 = state2._replace(opt=opt.init(sparse_params))
    retrain = args.retrain_steps or max(args.steps // 2, 10)
    state2, sparse_loss = run_phase(
        model, state2, step_fn, teacher,
        steps=retrain, seed=args.seed + 10_000, tag="sparse-retrain",
    )
    rec = (dense_loss - sparse_loss) if sparse_loss < float(loss0) else 0.0
    print(
        f"[done] dense {dense_loss:.4f} | post-prune {float(loss0):.4f} | "
        f"retrained-sparse {sparse_loss:.4f} "
        f"({'recovered' if sparse_loss <= float(loss0) else 'check schedule'})"
    )


if __name__ == "__main__":
    main()
