"""Quickstart: the paper's Fig. 4 ReLU DNN, in this framework's API.

Builds an L-layer square-weight ReLU network, runs the forward pass four
ways and checks they agree:

  1. paper-faithful GraphBLAS sequence (mxm over S1, eWiseMult/eWiseAdd
     over the max-plus semiring S2) with DENSE weights;
  2. the same with SPARSE (ELL-padded BSR) weights;
  3. fused sparse path (bias+ReLU folded into the SpMM epilogue);
  4. the Pallas TPU kernel (interpret mode on CPU).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dnn
from repro.core.semiring import MAX_PLUS, PLUS_TIMES, get_semiring
from repro.kernels import ops as kernel_ops
from repro.sparse.bsr import BlockSparseMatrix

M, N, L = 512, 64, 4  # neurons, batch, layers
BLOCK, BLOCKS_PER_ROW = 16, 8  # 4x sparse


def main():
    key = jax.random.key(0)
    print(f"== GraphBLAS ReLU DNN: {L} layers of {M}x{M}, batch {N} ==")
    print(f"semirings: S1={PLUS_TIMES.name}, S2={MAX_PLUS.name}")
    print(f"available semirings: {sorted(s for s in __import__('repro.core.semiring', fromlist=['REGISTRY']).REGISTRY)}")

    # sparse weights (ELL-BSR, U[-1,3) values as in the paper §V-B)
    keys = jax.random.split(key, L + 1)
    sparse_ws = [
        BlockSparseMatrix.random(
            keys[i], (M, M), (BLOCK, BLOCK), BLOCKS_PER_ROW, minval=-0.1, maxval=0.1
        )
        for i in range(L)
    ]
    dense_ws = [w.to_dense() for w in sparse_ws]
    biases = [jnp.zeros((M,)) for _ in range(L)]
    y0 = jax.random.uniform(keys[L], (M, N))

    # 1. paper-faithful (Fig. 4 three-call sequence), dense weights
    out_paper = dnn.dnn_forward(dense_ws, biases, y0, fused=False)
    # 2. paper-faithful with sparse weights
    out_sparse = dnn.dnn_forward(sparse_ws, biases, y0, fused=False)
    # 3. fused sparse (beyond-paper epilogue fusion)
    out_fused = dnn.dnn_forward(sparse_ws, biases, y0, fused=True)
    # 4. Pallas kernel, layer by layer (interpret=True on CPU)
    y = y0
    for w, b in zip(sparse_ws, biases):
        y = kernel_ops.bsr_spmm(w, y, bias=b, fuse_bias_relu=True)
    out_kernel = y

    for name, out in [
        ("sparse vs dense (paper-faithful)", out_sparse),
        ("fused vs unfused", out_fused),
        ("pallas kernel vs reference", out_kernel),
    ]:
        err = float(jnp.max(jnp.abs(out - out_paper)))
        print(f"  {name:36s} max|Δ| = {err:.2e}")
        np.testing.assert_allclose(out, out_paper, rtol=1e-4, atol=1e-4)

    dense_bytes = sum(w.size * 4 for w in dense_ws)
    sparse_bytes = sum(w.nbytes for w in sparse_ws)
    print(f"storage: dense {dense_bytes/2**20:.1f} MiB → "
          f"sparse {sparse_bytes/2**20:.1f} MiB "
          f"({dense_bytes/sparse_bytes:.1f}x smaller)")

    # semiring showcase: same mxm machinery over other algebras (§II-C)
    a = jnp.array([[0.0, 3.0], [2.0, 0.0]])
    b = jnp.array([[1.0, 0.0], [0.0, 5.0]])
    for s in ("min_plus", "max_min", "lor_land"):
        sr = get_semiring(s)
        print(f"  {s:10s} A⊕.⊗B =", np.asarray(sr.matmul(a, b)).tolist())
    print("quickstart OK")


if __name__ == "__main__":
    main()
