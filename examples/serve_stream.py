"""Streaming-inference demo: continuous batching vs static batching.

Builds the paper's deep sparse ReLU MLP, replays a deterministic bursty
(Poisson-ish) request stream through it twice over the same weights —

  1. **static aligned batching** — the pre-scheduler setup: every tick's
     arrivals are served immediately as one right-padded batch at a
     fixed service width (``SparseDNNEngine.infer``);
  2. **continuous batching** — ``repro.serve.ContinuousBatcher`` packs
     pending requests into tile-aligned panels each scheduling tick
     (late arrivals join mid-stream, completed requests free their
     slots), driving the engine's ``submit``/``step``/``drain`` API —

and prints the head-to-head ServeStats: pad-slot fraction, exact kernel
grid steps per served row, and the latency distribution. The grid-step
columns are hardware-independent: the pad columns of every underfull
static batch ride through all L layers' kernel grids, which is exactly
the work the scheduler removes.

Run: PYTHONPATH=src python examples/serve_stream.py [--quick]
Docs: docs/serving.md (design), docs/benchmarks.md (serve arm fields).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dnn
from repro.serve import (
    ContinuousBatcher,
    SparseDNNEngine,
    poissonish_trace,
    serve_trace_static,
)
from repro.sparse.bsr import BlockSparseMatrix


def build_stack(m: int, layers: int, bpr: int):
    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(i), (m, m), (16, 16), blocks_per_row=bpr
        )
        for i in range(layers)
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(layers)]
    return ws, bs


def report(tag: str, s) -> None:
    print(
        f"  {tag:11s} steps={s.engine_steps:3d}  rows={s.rows_served:3d}  "
        f"padded_slots={s.padded_slots:4d}  pad_frac={s.pad_slot_fraction:.3f}  "
        f"grid_steps={s.grid_steps_total:5d} "
        f"({s.grid_steps_per_row:.2f}/row)  "
        f"latency p50/mean/max = {s.latency_p50:.0f}/{s.latency_mean:.2f}/"
        f"{s.latency_max}"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--blocks-per-row", type=int, default=2)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--tile-align", type=int, default=8)
    ap.add_argument("--lam", type=float, default=3.0)
    ap.add_argument("--min-fill", type=float, default=0.25)
    ap.add_argument("--max-wait", type=int, default=3)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument(
        "--quick", action="store_true", help="small shapes for CI (seconds)"
    )
    args = ap.parse_args()
    if args.quick:
        args.m, args.layers, args.requests = 32, 2, 30

    ws, bs = build_stack(args.m, args.layers, args.blocks_per_row)
    trace = poissonish_trace(
        args.requests,
        m=args.m,
        lam=args.lam,
        burst_every=8,
        burst_size=12,
        seed=args.seed,
    )
    counts = [len(a) for a in trace]
    print(
        f"== serving {args.requests} requests over {len(trace)} ticks "
        f"(λ≈{args.lam}, bursts of 12 every 8 ticks) through "
        f"{args.layers}L of {args.m}² sparse MLP =="
    )
    print(f"arrivals/tick: {counts}")

    static = serve_trace_static(
        SparseDNNEngine(ws, bs, batch_align=args.batch_size), trace
    )
    batcher = ContinuousBatcher(
        SparseDNNEngine(ws, bs, batch_align=args.tile_align),
        batch_size=args.batch_size,
        min_fill=args.min_fill,
        max_wait=args.max_wait,
    )
    continuous = batcher.run_trace(trace)

    print("\nhead-to-head (same weights, same trace):")
    report("static", static)
    report("continuous", continuous)
    saved = static.grid_steps_total - continuous.grid_steps_total
    print(
        f"\ncontinuous batching removed {saved} of "
        f"{static.grid_steps_total} kernel grid steps "
        f"({saved / static.grid_steps_total:.1%}) at a latency cost of "
        f"{continuous.latency_mean - static.latency_mean:.2f} ticks mean."
    )

    # spot-check: the batcher's per-request outputs are the real forward
    ref = dnn.dnn_forward(ws, bs, trace[0][0][:, None], fused=True)[:, 0]
    np.testing.assert_allclose(
        np.asarray(batcher.result(0)), np.asarray(ref), rtol=1e-5, atol=1e-5
    )
    assert continuous.requests == static.requests == args.requests
    assert continuous.pad_slot_fraction < static.pad_slot_fraction
    print("[check] request 0 output matches the reference forward; "
          "pad waste strictly improved")


if __name__ == "__main__":
    main()
