"""Streaming-inference demo: continuous batching vs static batching.

Builds the paper's deep sparse ReLU MLP, replays a deterministic bursty
(Poisson-ish) request stream through it twice over the same weights —

  1. **static aligned batching** — the pre-scheduler setup: every tick's
     arrivals are served immediately as one right-padded batch at a
     fixed service width (``SparseDNNEngine.infer``);
  2. **continuous batching** — ``repro.serve.ContinuousBatcher`` packs
     pending requests into tile-aligned panels each scheduling tick
     (late arrivals join mid-stream, completed requests free their
     slots), driving the engine's ``submit``/``step``/``drain`` API —

and prints the head-to-head ServeStats: pad-slot fraction, exact kernel
grid steps per served row, and the latency distribution. The grid-step
columns are hardware-independent: the pad columns of every underfull
static batch ride through all L layers' kernel grids, which is exactly
the work the scheduler removes.

``--tuned`` serves through the committed autotuner table
(``examples/tuning_table.json``, a ``repro.tune.TuningTable``): the
engine looks up this topology's fingerprint and re-plans every width
class with the winning config (here: bf16 activation panels — same
grid, half the resident VMEM footprint). On a fingerprint miss the
example sweeps in-process (``repro.tune.tune_stack``) and warns that
the refreshed table should be committed. The tuned plan's grid-step
bill is asserted no worse than the default plan's.

``--shards N`` serves the same trace through a mesh-sharded engine
(``SparseDNNEngine(mesh=...)``): every layer's block-CSR segment is
partitioned across N row-block shards (``repro.sparse.partition``) and
executed under shard_map with a psum between layers — outputs are
identical, and the step stats grow per-shard grid-step bills that sum
to the single-device bill. On CPU hosts the flag fakes N host devices
(it must run before the first jax import, which is why it is parsed
early below).

Run: PYTHONPATH=src python examples/serve_stream.py [--quick] [--tuned]
     [--shards N]
Docs: docs/serving.md (design), docs/architecture.md (Distribution),
docs/benchmarks.md (serve/sharded arm fields).
"""

import argparse
import os
import sys


def _early_shards() -> int:
    """Read --shards before the first jax import: fake host devices
    only materialize if XLA_FLAGS is set at process start."""
    for i, a in enumerate(sys.argv):
        if a == "--shards" and i + 1 < len(sys.argv):
            return int(sys.argv[i + 1])
        if a.startswith("--shards="):
            return int(a.split("=", 1)[1])
    return 1


_SHARDS = _early_shards()
if _SHARDS > 1:
    _flags = os.environ.get("XLA_FLAGS", "")
    # Append to (never clobber) whatever XLA_FLAGS the user already has;
    # an explicit device-count flag from the caller wins.
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (_flags + " " if _flags else "") + (
            f"--xla_force_host_platform_device_count={_SHARDS}"
        )

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dnn
from repro.launch.mesh import make_row_blocks_mesh
from repro.serve import (
    ContinuousBatcher,
    SparseDNNEngine,
    poissonish_trace,
    serve_trace_static,
)
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix


def build_stack(m: int, layers: int, bpr: int):
    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(i), (m, m), (16, 16), blocks_per_row=bpr
        )
        for i in range(layers)
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(layers)]
    return ws, bs


def report(tag: str, s) -> None:
    print(
        f"  {tag:11s} steps={s.engine_steps:3d}  rows={s.rows_served:3d}  "
        f"padded_slots={s.padded_slots:4d}  pad_frac={s.pad_slot_fraction:.3f}  "
        f"grid_steps={s.grid_steps_total:5d} "
        f"({s.grid_steps_per_row:.2f}/row)  "
        f"latency p50/mean/max = {s.latency_p50:.0f}/{s.latency_mean:.2f}/"
        f"{s.latency_max}"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--blocks-per-row", type=int, default=2)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--tile-align", type=int, default=8)
    ap.add_argument("--lam", type=float, default=3.0)
    ap.add_argument("--min-fill", type=float, default=0.25)
    ap.add_argument("--max-wait", type=int, default=3)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument(
        "--shards",
        type=int,
        default=1,
        help="serve mesh-sharded over N row-block shards (fakes N host "
        "devices on CPU; parsed before the jax import)",
    )
    ap.add_argument(
        "--tuned",
        action="store_true",
        help="serve through the committed autotuner table "
        "(examples/tuning_table.json; sweeps in-process on a miss)",
    )
    ap.add_argument(
        "--quick", action="store_true", help="small shapes for CI (seconds)"
    )
    args = ap.parse_args()
    if args.quick:
        args.m, args.layers, args.requests = 32, 2, 30

    mesh = make_row_blocks_mesh(args.shards) if args.shards > 1 else None
    ws, bs = build_stack(args.m, args.layers, args.blocks_per_row)

    table = None
    if args.tuned:
        from repro import plan as plan_mod
        from repro import tune

        table_path = os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "tuning_table.json"
        )
        table = tune.TuningTable.load(table_path)
        fp = plan_mod.topology_fingerprint(ws)
        if table.lookup(fp) is None:
            print(
                f"[tune] no entry for fingerprint {fp[:12]}… in "
                f"{table_path} — sweeping in-process (commit the "
                "refreshed table to skip this)"
            )
            _, table = tune.tune_stack(
                ws, bs, args.batch_size, table=table, time_forwards=False
            )
        print(f"[tune] serving with config: {table.lookup(fp).token()}")
    trace = poissonish_trace(
        args.requests,
        m=args.m,
        lam=args.lam,
        burst_every=8,
        burst_size=12,
        seed=args.seed,
    )
    counts = [len(a) for a in trace]
    print(
        f"== serving {args.requests} requests over {len(trace)} ticks "
        f"(λ≈{args.lam}, bursts of 12 every 8 ticks) through "
        f"{args.layers}L of {args.m}² sparse MLP =="
    )
    print(f"arrivals/tick: {counts}")

    if mesh is not None:
        print(
            f"mesh-sharded serving: {args.shards} row-block shards over "
            f"{len(jax.devices())} host devices"
        )
    static = serve_trace_static(
        SparseDNNEngine(
            ws,
            bs,
            batch_align=args.batch_size,
            mesh=mesh,
            tuning_table=table,
        ),
        trace,
    )
    engine = SparseDNNEngine(
        ws, bs, batch_align=args.tile_align, mesh=mesh, tuning_table=table
    )
    batcher = ContinuousBatcher(
        engine,
        batch_size=args.batch_size,
        min_fill=args.min_fill,
        max_wait=args.max_wait,
    )
    continuous = batcher.run_trace(trace)

    print("\nhead-to-head (same weights, same trace):")
    report("static", static)
    report("continuous", continuous)
    saved = static.grid_steps_total - continuous.grid_steps_total
    print(
        f"\ncontinuous batching removed {saved} of "
        f"{static.grid_steps_total} kernel grid steps "
        f"({saved / static.grid_steps_total:.1%}) at a latency cost of "
        f"{continuous.latency_mean - static.latency_mean:.2f} ticks mean."
    )

    if mesh is not None:
        # one probe panel to surface the per-shard grid-step accounting;
        # compare against the INDEPENDENTLY computed single-device
        # occupancy-exact bill of the (relayouted) CSR stack — when the
        # shard count divides the stored blocks the two are equal, else
        # the per-shard segment padding shows up as extra steps
        _, pstats = engine.infer(trace[0][0][:, None])
        per = pstats["plan"]["grid_steps_per_shard"]
        total = sum(per)
        csr_ws = [BlockCSRMatrix.from_bsr(w) for w in ws]
        expected = dnn.dnn_grid_steps(csr_ws, pstats["padded_batch"])
        note = (
            f"= the single-device bill {expected}"
            if total == expected
            else f"vs single-device bill {expected}: "
            f"+{total - expected} shard-padding steps"
        )
        print(
            f"\nper-shard grid-step bill for one "
            f"{pstats['padded_batch']}-wide panel: {per} (Σ = {total} "
            f"{note})"
        )
        assert total >= expected and total == pstats["grid_steps"]

    tuned_cfg = engine.tuned if args.tuned else None
    if tuned_cfg is not None:
        # the tuned plan can never bill more kernel grid steps than the
        # default plan for the same width class — the sweep's cost-model
        # scoring only displaces the default on a strict improvement
        from repro import plan as plan_mod

        p_def = plan_mod.build_plan(ws, bs, args.batch_size)
        p_tun = plan_mod.build_plan(ws, bs, args.batch_size, tuned=tuned_cfg)
        assert p_tun.grid_steps <= p_def.grid_steps, (
            p_tun.grid_steps,
            p_def.grid_steps,
        )
        print(
            f"\n[tune] {tuned_cfg.token()}: route {p_def.route}"
            f"→{p_tun.route}, grid steps {p_def.grid_steps}"
            f"→{p_tun.grid_steps} at width {args.batch_size}"
        )

    # spot-check: the batcher's per-request outputs are the real forward
    # (for --shards > 1 this also proves sharded == single-device math).
    # bf16 activation panels trade ~0.5 % per-layer rounding for half
    # the panel footprint — judge them on a matching tolerance.
    ref = dnn.dnn_forward(ws, bs, trace[0][0][:, None], fused=True)[:, 0]
    if tuned_cfg is not None and tuned_cfg.panel_dtype is not None:
        scale = max(float(np.max(np.abs(np.asarray(ref)))), 1.0)
        tol = dict(rtol=0.05, atol=0.05 * scale)
    else:
        tol = dict(rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(batcher.result(0)), np.asarray(ref), **tol
    )
    assert continuous.requests == static.requests == args.requests
    assert continuous.pad_slot_fraction < static.pad_slot_fraction
    print("[check] request 0 output matches the reference forward; "
          "pad waste strictly improved")


if __name__ == "__main__":
    main()
