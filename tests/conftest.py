import os

# Tests must see the real single-CPU device (the 512-device override is
# dryrun.py-only). Force a deterministic, quiet JAX.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_default_matmul_precision", "highest")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running tests — GraphChallenge-scale conformance "
        "configs (interpret-mode kernels on 120-layer / 16384-neuron "
        "stacks) and multi-device subprocess runs. Tier-1 CI deselects "
        "them (-m 'not slow') and a dedicated slow job runs them; the "
        "multi-device job also deselects them because it runs the same "
        "sharded checks in-process on its 8-device view",
    )

# Property tests prefer real hypothesis (requirements-dev.txt); in
# hermetic containers without it, install the deterministic fallback shim
# so the same test modules still collect and run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import sys

    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback

    _hypothesis_fallback.install()
