import os

# Tests must see the real single-CPU device (the 512-device override is
# dryrun.py-only). Force a deterministic, quiet JAX.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_default_matmul_precision", "highest")
