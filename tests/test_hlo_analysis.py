"""Unit + property tests for the loop-aware HLO accounting (the roofline
pipeline's measurement layer — correctness here is what makes §Perf
iterations trustworthy)."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.launch import hlo_analysis as H

SYNTH = """
HloModule jit_step

%body.1 (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,64]{1,0} constant({...})
  %dot.1 = f32[128,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,64]{1,0} all-reduce(%dot.1), replica_groups=[4,2]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[128,128]{1,0}) tuple(%i, %x)
}

%cond.1 (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main.1 (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  %dot.0 = f32[128,128]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[256,128]{1,0} all-gather(%dot.0), replica_groups=[2,4]<=[8], dimensions={0}
  %w2 = (s32[], f32[128,128]{1,0}) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_loop_multiplier_and_dot_flops():
    stats = H.analyze(SYNTH)
    # entry dot: 2*128*128*128 ; body dot: 2*128*64*128 * 10 trips
    expect = 2 * 128 * 128 * 128 + 10 * 2 * 128 * 64 * 128
    assert stats.flops == expect


def test_collective_accounting():
    stats = H.analyze(SYNTH)
    # all-gather result 256*128*4 bytes, g=4 → (3/4)·b ; AR in body ×10
    ag = (3 / 4) * 256 * 128 * 4
    ar = 10 * 2 * (1 / 2) * 128 * 64 * 4  # g=2 → 2·(1/2)·b
    assert stats.per_kind_bytes["all-gather"] == pytest.approx(ag)
    assert stats.per_kind_bytes["all-reduce"] == pytest.approx(ar)
    assert stats.collective_bytes == pytest.approx(ag + ar)


def test_bytes_exclude_control_flow_and_params():
    stats = H.analyze(SYNTH)
    # while/tuple/gte/parameter contribute nothing; dots+collectives do
    assert stats.bytes_accessed > 0
    # body executes 10×: its dot touches (128·128 + 128·64 + 128·64)·4
    body_dot = 10 * (128 * 128 + 128 * 64 + 128 * 64) * 4
    assert stats.bytes_accessed >= body_dot


@given(
    g=st.integers(2, 512),
    nbytes=st.integers(4, 10**9),
)
@settings(max_examples=50, deadline=None)
def test_wire_byte_formulas_properties(g, nbytes):
    ar = H._wire_bytes("all-reduce", nbytes, g)
    ag = H._wire_bytes("all-gather", nbytes, g)
    rs = H._wire_bytes("reduce-scatter", nbytes, g)
    cp = H._wire_bytes("collective-permute", nbytes, g)
    # ring AR = AG of full + RS of full (classic identity, same result size)
    assert ar == pytest.approx(2 * ag)
    assert cp == nbytes
    assert rs == (g - 1) * nbytes
    assert H._wire_bytes("all-reduce", nbytes, 1) == 0.0


@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_shape_bytes(dims):
    s = f"f32[{','.join(map(str, dims))}]{{0}}"
    n = 1
    for d in dims:
        n *= d
    assert H._shape_bytes(s) == 4 * n
    s16 = f"bf16[{','.join(map(str, dims))}]"
    assert H._shape_bytes(s16) == 2 * n


def test_roofline_terms():
    t = H.roofline_terms(
        flops_per_device=H.PEAK_FLOPS,  # exactly 1 second of compute
        bytes_per_device=H.HBM_BW / 2,  # 0.5 s
        collective_bytes_per_device=H.ICI_BW / 4,  # 0.25 s
    )
    assert t["dominant"] == "t_compute_s"
    assert t["roofline_fraction"] == pytest.approx(1.0)
    t2 = H.roofline_terms(
        flops_per_device=H.PEAK_FLOPS / 10,
        bytes_per_device=H.HBM_BW,  # memory-bound
        collective_bytes_per_device=0,
    )
    assert t2["dominant"] == "t_memory_s"
    assert t2["roofline_fraction"] == pytest.approx(0.1)


def test_logical_line_joining():
    wrapped = (
        "ENTRY %e (a: f32[4]) -> f32[4] {\n"
        "  %a = f32[4]{0} parameter(0)\n"
        "  %w = (s32[], f32[4]{0},\n"
        "    f32[8]{0}) while(%t), condition=%c,\n"
        "    body=%b, backend_config={\"known_trip_count\":{\"n\":\"3\"}}\n"
        "  ROOT %r = f32[4]{0} add(%a, %a)\n"
        "}\n"
    )
    comps = H._parse_computations(wrapped)
    instrs = comps["e"].instrs
    ops = [i.opcode for i in instrs]
    assert "while" in ops and "add" in ops
    edges = H._call_edges(comps["e"])
    assert ("b", 3, "body") in edges
