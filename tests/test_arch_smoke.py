"""Per-arch smoke tests (assignment requirement): every assigned
architecture instantiates at reduced scale and runs one forward + one
train step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import Model
from repro.train import adamw, make_train_step
from repro.train.trainer import init_train_state

ARCH_IDS = sorted(ARCHS)


def _inputs(cfg, key, b, s):
    if cfg.input_mode == "tokens":
        return jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return jax.random.normal(key, (b, s, cfg.d_model))


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch, key):
    cfg = get_config(arch).scaled_down()
    model = Model(cfg)
    params = model.init(key)
    b, s = 2, 16
    logits = model.forward(params, _inputs(cfg, key, b, s))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, key):
    cfg = get_config(arch).scaled_down()
    model = Model(cfg)
    opt = adamw(1e-3)
    state = init_train_state(model, opt, key)
    step = jax.jit(make_train_step(model, opt))
    batch = {
        "inputs": _inputs(cfg, key, 2, 16),
        "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
    }
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state2.opt.step) == 1
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(
            jax.tree.leaves(state.params), jax.tree.leaves(state2.params)
        )
        if jnp.issubdtype(a.dtype, jnp.floating)
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, key):
    """Greedy decode logits at position s must match the full forward's
    logits at position s (cache correctness across every mixer kind)."""
    cfg = get_config(arch).scaled_down()
    model = Model(cfg)
    params = model.init(key)
    b, s = 2, 12
    inp = _inputs(cfg, key, b, s + 1)
    full = model.forward(params, inp)

    cache = model.init_cache(b, 32)
    prefix = inp[:, :s] if cfg.input_mode == "tokens" else inp[:, :s, :]
    logits_pre, cache = model.prefill(params, prefix, cache)
    # last prefill logits == full forward at s-1
    assert jnp.allclose(
        logits_pre[:, -1], full[:, s - 1], rtol=2e-2, atol=2e-2
    ), f"{arch}: prefill/fwd mismatch"
    nxt = inp[:, s] if cfg.input_mode == "tokens" else inp[:, s : s + 1, :]
    logits_dec, _ = model.decode_step(
        params, nxt, cache, jnp.asarray(s, jnp.int32)
    )
    assert jnp.allclose(
        logits_dec, full[:, s], rtol=2e-2, atol=2e-2
    ), f"{arch}: decode/fwd mismatch"


def test_param_counts_match_published_scale():
    """Full configs should land near their published parameter counts."""
    # note: moonshot is excluded — the ASSIGNED dims (48L × 64e×1408)
    # arithmetically give ~28B, not the marketing 16B (27L); we implement
    # the assigned config verbatim (see DESIGN.md §Arch-applicability).
    expect = {
        "deepseek-v2-236b": (2.0e11, 2.6e11),
        "qwen2-72b": (6.5e10, 8.2e10),
        "llama3.2-1b": (0.9e9, 1.6e9),
        "jamba-v0.1-52b": (4.4e10, 6.0e10),
    }
    for arch, (lo, hi) in expect.items():
        n = Model(get_config(arch)).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"


def test_active_params_moe():
    m = Model(get_config("deepseek-v2-236b"))
    total, active = m.param_count(), m.active_param_count()
    assert active < 0.25 * total  # ~21B active of 236B
    assert active > 0.02 * total
