"""Resilient sparse training (docs/robustness.md): sparse layouts
checkpoint/restore EXACTLY, a NaN loss triggers restore-and-skip
without committing the poisoned update, killed-and-resumed runs replay
bit-identical losses, and every restore re-validates the layouts."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import BlockCSRMatrix, BlockSparseMatrix
from repro.testing import SITE_TRAIN_NAN_LOSS, FaultInjector
from repro.train import checkpoint
from repro.train.optimizer import sgd
from repro.train.resilience import (
    run_resilient_training,
    validate_sparse_state,
)
from repro.train.sparse import SparseMLPState, init_sparse_mlp_state


def _state(seed=0, m=32, block=8, bpr=2):
    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(seed), (m, m), (block, block),
            blocks_per_row=bpr, minval=-0.5, maxval=0.5,
        ),
        BlockCSRMatrix.from_bsr(
            BlockSparseMatrix.random(
                jax.random.PRNGKey(seed + 1), (m, m), (block, block),
                blocks_per_row=bpr, minval=-0.5, maxval=0.5,
            )
        ),
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in ws]
    return init_sparse_mlp_state(ws, bs, _opt()), m


def _opt():
    return sgd(0.5, momentum=0.0)


def _batch_fn(m):
    # deterministic in step — the recovery contract (DESIGN.md §6)
    def fn(step):
        k = jax.random.PRNGKey(1000 + step)
        y0 = jax.random.uniform(k, (m, 8), jnp.float32)
        return {"y0": y0, "targets": 0.3 * y0}

    return fn


def test_sparse_state_checkpoints_exactly():
    """Block-CSR / ELL layouts round-trip through a checkpoint bit for
    bit: float32 values exact, integer topology dtypes preserved."""
    state, _ = _state()
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 7, state)
        restored, manifest = checkpoint.restore(d, state)
        assert manifest["step"] == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # restored layouts still satisfy every structural invariant
        validate_sparse_state(restored)
        assert isinstance(restored.weights[1], BlockCSRMatrix)


def test_validate_sparse_state_catches_corruption():
    import dataclasses

    state, _ = _state()
    bad_w = dataclasses.replace(
        state.weights[1],
        col_idx=state.weights[1].col_idx.at[0].set(10_000),
    )
    bad = SparseMLPState(
        (state.weights[0], bad_w), state.biases, state.opt
    )
    with pytest.raises(ValueError, match="layer 1"):
        validate_sparse_state(bad)
    nan_bias = SparseMLPState(
        state.weights,
        (state.biases[0].at[0].set(float("nan")), state.biases[1]),
        state.opt,
    )
    with pytest.raises(ValueError, match="bias"):
        validate_sparse_state(nan_bias)


def test_nan_loss_restores_and_skips():
    state, m = _state(seed=2)
    inj = FaultInjector()
    inj.schedule(SITE_TRAIN_NAN_LOSS, 3)
    with tempfile.TemporaryDirectory() as d:
        final, report = run_resilient_training(
            state, _batch_fn(m), _opt(), 6, d,
            ckpt_interval=2, use_kernel=False, fault_injector=inj,
        )
        # the poisoned attempt at step 3 was discarded and replayed clean
        assert report["skipped"] == [3]
        assert len(report["restarts"]) == 1
        assert report["restarts"][0][1] == "fault: NonFiniteLossError"
        assert sorted(report["losses"]) == [0, 1, 2, 3, 4, 5]
        assert all(np.isfinite(v) for v in report["losses"].values())
        # ...and the final state matches a never-faulted run exactly
        clean, _ = run_resilient_training(
            _state(seed=2)[0], _batch_fn(m), _opt(), 6,
            os.path.join(d, "clean"), ckpt_interval=2, use_kernel=False,
        )
        for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(clean)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_kill_and_resume_replays_bit_identical_losses():
    state, m = _state(seed=3)
    with tempfile.TemporaryDirectory() as d:
        # reference: one uninterrupted run
        _, ref = run_resilient_training(
            _state(seed=3)[0], _batch_fn(m), _opt(), 8,
            os.path.join(d, "ref"), ckpt_interval=2, use_kernel=False,
        )
        # "killed" run: stop after 5 steps (last checkpoint at step 4)...
        run_a = os.path.join(d, "killed")
        _, part = run_resilient_training(
            state, _batch_fn(m), _opt(), 5, run_a,
            ckpt_interval=2, use_kernel=False,
        )
        assert checkpoint.latest_step(run_a) == 5  # final-step save
        # ...then resume from the directory with a FRESH initial state
        # (the checkpoint, not the caller's arrays, must carry the run)
        final, rest = run_resilient_training(
            _state(seed=3)[0], _batch_fn(m), _opt(), 8, run_a,
            ckpt_interval=2, use_kernel=False,
        )
        assert rest["start_step"] == 5
        merged = {**part["losses"], **rest["losses"]}
        assert merged == ref["losses"]  # float equality — bit-identical


def test_resilient_training_with_kernels_in_path():
    """The Pallas kernels (and their custom VJPs) survive the same
    restore path — smoke-sized."""
    state, m = _state(seed=4)
    inj = FaultInjector()
    inj.schedule(SITE_TRAIN_NAN_LOSS, 1)
    with tempfile.TemporaryDirectory() as d:
        _, report = run_resilient_training(
            state, _batch_fn(m), _opt(), 3, d,
            ckpt_interval=1, use_kernel=True, fault_injector=inj,
        )
        assert report["skipped"] == [1]
        assert sorted(report["losses"]) == [0, 1, 2]
        assert all(np.isfinite(v) for v in report["losses"].values())


def test_restore_validation_rejects_corrupt_checkpoint():
    state, m = _state(seed=5)
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 2, state)
        # corrupt the stored values in place: NaN into the npz payload
        path = os.path.join(d, "step_00000002", "arrays.npz")
        arrays = dict(np.load(path))
        key = "biases//0"
        arrays[key] = np.full_like(arrays[key], np.nan)
        np.savez(path, **arrays)
        with pytest.raises(ValueError, match="restored SparseMLPState"):
            run_resilient_training(
                state, _batch_fn(m), _opt(), 4, d, use_kernel=False,
            )
