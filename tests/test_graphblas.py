"""GraphBLAS primitive semantics (paper §IV usage patterns)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MAX_PLUS, PLUS_TIMES, graphblas as gb
from repro.sparse import BlockSparseMatrix


@pytest.fixture
def rng():
    return np.random.default_rng(42)


def test_mxm_dense(rng):
    a = jnp.asarray(rng.normal(size=(6, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    np.testing.assert_allclose(gb.mxm(a, b), a @ b, rtol=1e-5)


def test_mxm_sparse_dispatch(rng):
    key = jax.random.PRNGKey(0)
    a = BlockSparseMatrix.random(key, (32, 32), (8, 8), blocks_per_row=2)
    b = jnp.asarray(rng.normal(size=(32, 7)).astype(np.float32))
    np.testing.assert_allclose(
        gb.mxm(a, b), a.to_dense() @ b, rtol=1e-4, atol=1e-5
    )


def test_mxv_vxm(rng):
    a = jnp.asarray(rng.normal(size=(5, 5)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))
    np.testing.assert_allclose(gb.mxv(a, v), a @ v, rtol=1e-5)
    np.testing.assert_allclose(gb.vxm(v, a), v @ a, rtol=1e-5)


def test_mxv_is_mxm_column_bit_exact():
    """mxv(A, v) must be mxm(A, v[:, None])[:, 0] — same kernel route,
    same narrow-panel tile, bit-for-bit."""
    from repro.core import MIN_PLUS

    key = jax.random.PRNGKey(9)
    a = BlockSparseMatrix.random(key, (64, 64), (8, 8), blocks_per_row=3)
    v = jax.random.uniform(jax.random.PRNGKey(10), (64,), jnp.float32)
    for sr in (PLUS_TIMES, MIN_PLUS):
        np.testing.assert_array_equal(
            np.asarray(gb.mxv(a, v, sr)),
            np.asarray(gb.mxm(a, v[:, None], sr)[:, 0]),
        )


def test_mxv_bills_narrow_panel():
    """A width-1 plan is billed at the effective 8-wide tile — the cost
    model's shrink, not a full DEFAULT_BLOCK_N-wide tile."""
    from repro.kernels import DEFAULT_BLOCK_N
    from repro.kernels.ops import effective_block_n
    from repro.plan.cost import layer_grid_steps, mxv_grid_steps
    from repro.plan.mxm import mxm_plan, reset_mxm_cache

    key = jax.random.PRNGKey(11)
    a = BlockSparseMatrix.random(key, (64, 64), (8, 8), blocks_per_row=3)
    assert effective_block_n(1, DEFAULT_BLOCK_N) == 8
    reset_mxm_cache()
    plan = mxm_plan(a, 1)
    assert plan.width == 1
    assert plan.grid_steps == mxv_grid_steps(a) == layer_grid_steps(a, 1)
    # a panel wider than one 8-wide tile but narrower than a full block_n
    # tile pays MORE tiles than the vector panel — the shrink is real
    assert layer_grid_steps(a, 9) > mxv_grid_steps(a)
    reset_mxm_cache()


def test_ewise_ops_max_plus():
    """The paper's bias-add (eWiseMult ⊗=+) and ReLU (eWiseAdd ⊕=max)."""
    y = jnp.array([[-1.0, 2.0], [3.0, -4.0]])
    b = jnp.array([[0.5, 0.5], [1.0, 1.0]])
    biased = gb.ewise_mult(y, b, MAX_PLUS)
    np.testing.assert_array_equal(biased, y + b)
    relu = gb.ewise_add(biased, jnp.zeros_like(y), MAX_PLUS)
    np.testing.assert_array_equal(relu, np.maximum(np.asarray(y + b), 0))


def test_mask_semantics(rng):
    a = jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))
    prev = jnp.zeros((4, 4))
    mask = jnp.asarray(rng.random((4, 4)) > 0.5)
    out = gb.mxm(a, b, PLUS_TIMES, mask=mask, prev=prev)
    full = np.asarray(a @ b)
    np.testing.assert_allclose(
        out, np.where(np.asarray(mask), full, 0.0), rtol=1e-5
    )


def test_accum_semantics(rng):
    a = jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))
    prev = jnp.ones((4, 4))
    out = gb.mxm(a, b, PLUS_TIMES, accum=jnp.add, prev=prev)
    np.testing.assert_allclose(out, np.asarray(a @ b) + 1.0, rtol=1e-5)


def test_accum_requires_prev(rng):
    a = jnp.ones((2, 2))
    with pytest.raises(ValueError):
        gb.mxm(a, a, PLUS_TIMES, accum=jnp.add)


def test_reduce(rng):
    a = jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32))
    np.testing.assert_allclose(
        gb.reduce_rows(a, PLUS_TIMES), np.asarray(a).sum(-1), rtol=1e-5
    )
    np.testing.assert_allclose(
        gb.reduce_scalar(a, MAX_PLUS), np.asarray(a).max(), rtol=1e-6
    )


def test_select_extract_assign(rng):
    a = jnp.asarray(rng.normal(size=(5, 5)).astype(np.float32))
    sel = gb.select(a, lambda x: x > 0)
    np.testing.assert_array_equal(
        sel, np.where(np.asarray(a) > 0, np.asarray(a), 0.0)
    )
    rows, cols = jnp.array([0, 2]), jnp.array([1, 3])
    sub = gb.extract(a, rows, cols)
    assert sub.shape == (2, 2)
    a2 = gb.assign(a, rows, cols, jnp.zeros((2, 2)))
    assert float(a2[0, 1]) == 0.0 and float(a2[2, 3]) == 0.0


def test_transpose_sparse():
    key = jax.random.PRNGKey(1)
    a = BlockSparseMatrix.random(key, (16, 32), (8, 8), blocks_per_row=2)
    at = gb.transpose(a)
    np.testing.assert_allclose(at.to_dense(), a.to_dense().T, rtol=1e-6)
