"""Replicated fleet serving: router affinity, replica isolation,
replica-loss failover without drops, backpressure, fault-inflated
latency, and bit-determinism of the whole front-end under a virtual
clock — with a guard proving zero real sleeps."""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import (
    FleetFrontend,
    LoadProfile,
    ReplicaFleet,
    ServiceModel,
    SparseDNNEngine,
    VirtualClock,
    generate_jobs,
)
from repro.serve.fleet import REASON_AFFINITY, REASON_CLAIM, REASON_FAILOVER
from repro.sparse import BlockSparseMatrix
from repro.testing.faults import (
    SITE_REPLICA_LOSS,
    SITE_REPLICA_SLOW,
    FaultInjector,
)


@pytest.fixture(autouse=True)
def _no_real_sleep(monkeypatch):
    """The CI fleet job's contract: every serving test here runs on
    virtual time only — one real sleep is a failure."""

    def _boom(seconds):
        raise AssertionError(f"real time.sleep({seconds}) in a virtual-clock test")

    monkeypatch.setattr(time, "sleep", _boom)


M = 32
CLASSES = (8, 16)


def _stack(seed=0, L=2, m=M, bpr=2, block=16):
    ks = jax.random.split(jax.random.key(seed), L)
    ws = [
        BlockSparseMatrix.random(k, (m, m), (block, block), blocks_per_row=bpr)
        for k in ks
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    return ws, bs


def _fleet(ws, bs, n=3, **kw):
    engines = [SparseDNNEngine(ws, bs, batch_align=8) for _ in range(n)]
    return ReplicaFleet(engines, width_classes=CLASSES, **kw)


def _trace(seed=5, rate=30.0, duration=2.0, deadline_s=None):
    return generate_jobs(
        LoadProfile.constant(rate),
        duration,
        m=M,
        seed=seed,
        width_mix=((2, 0.6), (12, 0.4)),
        deadline_s=deadline_s,
    )


def _run(fleet, jobs, **kw):
    clock = VirtualClock()
    fe = FleetFrontend(
        fleet,
        clock=clock,
        service_model=ServiceModel(base_s=1e-3, per_grid_step_s=1e-4),
        **kw,
    )
    return fe, fe.run(jobs)


# ---------------------------------------------------------------------
# construction / isolation
# ---------------------------------------------------------------------


def test_replicas_must_not_share_plan_cache():
    ws, bs = _stack()
    a = SparseDNNEngine(ws, bs, batch_align=8)
    b = SparseDNNEngine(ws, bs, batch_align=8, plan_cache=a.plan_cache)
    with pytest.raises(ValueError, match="share a plan_cache"):
        ReplicaFleet([a, b], width_classes=CLASSES)


def test_replicas_must_share_one_topology():
    ws, bs = _stack(0)
    ws2, bs2 = _stack(1, L=3)
    with pytest.raises(ValueError, match="different topologies"):
        ReplicaFleet(
            [
                SparseDNNEngine(ws, bs, batch_align=8),
                SparseDNNEngine(ws2, bs2, batch_align=8),
            ],
            width_classes=CLASSES,
        )


def test_per_replica_caches_and_ladders_are_distinct():
    ws, bs = _stack()
    fleet = _fleet(ws, bs)
    caches = {id(r.engine.plan_cache) for r in fleet.replicas}
    ladders = {id(r.engine.ladder) for r in fleet.replicas}
    assert len(caches) == len(ladders) == 3


# ---------------------------------------------------------------------
# router: width-class affinity
# ---------------------------------------------------------------------


def test_affinity_one_compile_per_owned_class_and_high_hit_rate():
    """The ISSUE's headline routing property: 2 width classes across 3
    replicas — each class compiles ONCE, on its owning replica; the
    fleet-wide plan-cache hit rate stays >= 0.9."""
    ws, bs = _stack()
    fleet = _fleet(ws, bs)
    jobs = _trace(rate=40.0, duration=2.0)
    assert len(jobs) >= 30
    fe, stats = _run(fleet, jobs)
    f = stats["fleet"]
    assert stats["served_jobs"] == len(jobs)
    # Two classes -> two distinct owners, one compile each; the third
    # replica never compiles.
    owners = {int(c): i for c, i in f["owners"].items()}
    assert set(owners) == {8, 16}
    assert len(set(owners.values())) == 2
    per = {r["replica"]: r for r in f["per_replica"]}
    for cls, owner in owners.items():
        assert per[owner]["compiled_classes"] == [cls]
        assert per[owner]["compiles"] == 1
    idle = (set(per) - set(owners.values())).pop()
    assert per[idle]["compiles"] == 0
    assert f["cross_replica_compiles"] == 0
    assert f["plan_hit_rate"] >= 0.9
    reasons = {d.reason for d in fleet.decisions}
    assert REASON_CLAIM in reasons and REASON_AFFINITY in reasons


def test_fleet_outputs_match_single_engine_reference():
    ws, bs = _stack()
    fleet = _fleet(ws, bs)
    jobs = _trace(seed=9, rate=25.0, duration=1.5)
    fe, stats = _run(fleet, jobs)
    ref = SparseDNNEngine(ws, bs, batch_align=8)
    assert set(fe.results) == {j.rid for j in jobs}
    for job in jobs:
        expect, _ = ref.infer(job.features)
        got = fe.results[job.rid]
        assert got.shape == job.features.shape
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(expect), rtol=1e-5, atol=1e-5
        )


def test_router_spills_off_a_backed_up_owner():
    ws, bs = _stack()
    fleet = _fleet(ws, bs, affinity_slack=0)
    # One class, a dense arrival burst: with zero slack the router must
    # fan the backlog across replicas instead of piling on the owner.
    jobs = generate_jobs(
        LoadProfile.constant(200.0),
        0.5,
        m=M,
        seed=2,
        width_mix=((2, 1.0),),
    )
    fe, stats = _run(fleet, jobs)
    dispatched = [r["dispatches"] for r in stats["fleet"]["per_replica"]]
    assert sum(dispatched) == len(jobs)
    assert sum(1 for d in dispatched if d > 0) >= 2
    assert stats["fleet"]["routing"].get("spill", 0) > 0


# ---------------------------------------------------------------------
# replica loss: failover without drops
# ---------------------------------------------------------------------


def test_replica_loss_mid_trace_drops_nothing():
    ws, bs = _stack()
    fleet = _fleet(ws, bs)
    jobs = _trace(seed=13, rate=60.0, duration=1.5)
    inj = FaultInjector()
    # Fire while the fleet is saturated so replica 0 has queued AND
    # in-flight work to orphan.
    inj.schedule(SITE_REPLICA_LOSS, 8, replica=0)
    fe, stats = _run(fleet, jobs, fault_injector=inj)
    assert inj.pending() == 0
    f = stats["fleet"]
    assert f["alive"] == 2
    assert not fleet.replicas[0].alive
    # THE no-drop guarantee: every offered job completes successfully.
    assert stats["offered_jobs"] == len(jobs)
    assert stats["served_jobs"] == len(jobs)
    assert stats["failed_jobs"] == stats["rejected_jobs"] == 0
    assert stats["requeued_jobs"] >= 1
    [event] = f["events"]
    assert event["event"] == "replica-loss" and event["replica"] == 0
    assert event["requeued_jobs"] == stats["requeued_jobs"]
    assert any(d.reason == REASON_FAILOVER for d in fleet.decisions)
    # Survivors re-claimed replica 0's classes.
    assert set(f["owners"].values()) <= {1, 2}
    # Outputs still correct after failover.
    ref = SparseDNNEngine(ws, bs, batch_align=8)
    for job in jobs[:5]:
        expect, _ = ref.infer(job.features)
        np.testing.assert_allclose(
            np.asarray(fe.results[job.rid]),
            np.asarray(expect),
            rtol=1e-5,
            atol=1e-5,
        )


def test_slow_replica_inflates_latency_not_correctness():
    ws, bs = _stack()
    jobs = _trace(seed=4, rate=20.0, duration=1.0)
    _, base = _run(_fleet(ws, bs), jobs)
    inj = FaultInjector()
    inj.schedule(SITE_REPLICA_SLOW, 0, factor=100.0)
    _, slow = _run(_fleet(ws, bs), jobs, fault_injector=inj)
    assert inj.pending() == 0
    assert slow["served_jobs"] == base["served_jobs"] == len(jobs)
    assert slow["latency_max_s"] > 10 * base["latency_max_s"]


# ---------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------


def test_bounded_admission_rejects_overload():
    ws, bs = _stack()
    fleet = _fleet(ws, bs)
    jobs = generate_jobs(
        LoadProfile.bursty(10.0, 400.0, 1.0, 0.5),
        1.0,
        m=M,
        seed=6,
        width_mix=((12, 1.0),),
    )
    fe, stats = _run(fleet, jobs, max_pending_cols=36)
    assert stats["rejected_jobs"] > 0
    assert stats["admitted_jobs"] + stats["rejected_jobs"] == len(jobs)
    # Rejected jobs were never queued, dispatched, or completed.
    assert stats["served_jobs"] == stats["admitted_jobs"]
    assert set(fe.rejected).isdisjoint(fe.results)
    assert stats["miss_rate"] >= stats["rejected_jobs"] / len(jobs)


def test_deadline_misses_counted_against_goodput():
    ws, bs = _stack()
    fleet = _fleet(ws, bs)
    # Deadlines below the service model's floor (base_s alone): every
    # job must miss-but-serve, never fail.
    jobs = _trace(seed=8, rate=120.0, duration=0.5, deadline_s=0.0005)
    fe, stats = _run(fleet, jobs)
    assert stats["served_jobs"] == len(jobs)
    assert stats["deadline_misses"] > 0
    assert stats["miss_rate"] > 0
    assert stats["goodput_cols_per_s"] < stats["throughput_cols_per_s"]


# ---------------------------------------------------------------------
# determinism / lifecycle
# ---------------------------------------------------------------------


def test_frontend_is_bit_deterministic():
    ws, bs = _stack()
    jobs = _trace(seed=21, rate=50.0, duration=1.0, deadline_s=0.05)

    def inj():
        i = FaultInjector()
        i.schedule(SITE_REPLICA_LOSS, 5, replica=1)
        i.schedule(SITE_REPLICA_SLOW, 9, factor=7.0)
        return i

    _, a = _run(_fleet(ws, bs), jobs, fault_injector=inj())
    _, b = _run(_fleet(ws, bs), jobs, fault_injector=inj())
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_frontend_runs_once_and_handles_empty_trace():
    ws, bs = _stack()
    fe = FleetFrontend(_fleet(ws, bs), clock=VirtualClock())
    stats = fe.run([])
    assert stats["offered_jobs"] == 0
    assert stats["throughput_cols_per_s"] == 0.0
    with pytest.raises(RuntimeError, match="one trace"):
        fe.run([])
