"""Kernel autotuner (``repro.tune``): tuning-table round-trip and schema
gating, fingerprint isolation, deterministic sweep selection (block-CSR
forcing strictly beats ELL on a skewed stack; bf16 panels move the
resident boundary), PlanCache tuned/untuned non-collision, and the
engine-side table lookup."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import plan as P
from repro.serve import SparseDNNEngine
from repro.sparse import BlockCSRMatrix, BlockSparseMatrix
from repro.tune import (
    SCHEMA_VERSION,
    TunedConfig,
    TuningTable,
    TuningTableError,
    default_candidates,
    sweep_stack,
    tune_stack,
)


def _square_stack(key, L=3, m=64, bpr=2, block=16):
    ks = jax.random.split(key, L)
    ws = [
        BlockSparseMatrix.random(k, (m, m), (block, block), blocks_per_row=bpr)
        for k in ks
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    return ws, bs


def _skewed_stack():
    """Rectangular (→ layered route) skewed stack whose ELL waste stays
    UNDER the 0.25 relayout threshold — the default plan keeps ELL, yet
    forcing block-CSR strictly drops the grid-step bill."""
    specs = [((128, 256), 100), ((128, 128), 55), ((64, 128), 28)]
    ws = []
    for i, (shape, tb) in enumerate(specs):
        w = BlockCSRMatrix.random_skewed(
            i, shape, (16, 16), tb, skew=0.3
        ).to_bsr()
        nrb, mbpr = w.col_idx.shape
        assert 1 - tb / (nrb * mbpr) < P.ELL_WASTE_THRESHOLD
        assert nrb * mbpr > tb  # ELL pays pad the CSR grid skips
        ws.append(w)
    bs = [jnp.zeros((s[0],), jnp.float32) for s, _ in specs]
    return ws, bs


# ---------------------------------------------------------------- table


class TestTunedConfig:
    def test_default_token(self):
        assert TunedConfig().token() == "default"
        assert TunedConfig().is_default

    def test_token_deterministic_and_distinct(self):
        a = TunedConfig(block_n=64, panel_dtype="bfloat16")
        b = TunedConfig(block_n=64, panel_dtype="bfloat16")
        c = TunedConfig(block_n=64)
        assert a.token() == b.token()
        assert a.token() != c.token()

    def test_panel_dtype_normalized(self):
        assert TunedConfig(panel_dtype=jnp.bfloat16).token() == (
            TunedConfig(panel_dtype="bfloat16").token()
        )

    def test_bad_layout_rejected(self):
        with pytest.raises(ValueError, match="layout"):
            TunedConfig(layout="csc")

    def test_dict_round_trip(self):
        cfg = TunedConfig(block_size=32, layout="bcsr")
        assert TunedConfig.from_dict(cfg.to_dict()) == cfg

    def test_unknown_knob_rejected(self):
        with pytest.raises(TuningTableError, match="unknown"):
            TunedConfig.from_dict({"warp_size": 32})


class TestTuningTable:
    def test_round_trip(self, tmp_path):
        table = TuningTable()
        cfg = TunedConfig(panel_dtype="bfloat16", block_n=64)
        table.put("fp1", "cpu", "float32", cfg, {"grid_steps": 7})
        path = tmp_path / "table.json"
        table.save(path)
        loaded = TuningTable.load(path)
        assert loaded.lookup("fp1", backend="cpu") == cfg
        assert loaded.record("fp1", backend="cpu")["grid_steps"] == 7

    def test_fingerprint_isolation(self):
        table = TuningTable()
        table.put("fpA", "cpu", "float32", TunedConfig(block_n=64))
        assert table.lookup("fpB", backend="cpu") is None
        assert table.lookup("fpA", backend="tpu") is None
        assert table.lookup("fpA", backend="cpu", dtype="bfloat16") is None
        assert table.lookup("fpA", backend="cpu") == TunedConfig(block_n=64)

    def test_schema_version_rejected(self, tmp_path):
        path = tmp_path / "stale.json"
        path.write_text(
            json.dumps({"schema_version": SCHEMA_VERSION + 1, "entries": {}})
        )
        with pytest.raises(TuningTableError, match="schema_version"):
            TuningTable.load(path)

    def test_corrupt_rejected(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text("{not json")
        with pytest.raises(TuningTableError):
            TuningTable.load(path)
        path.write_text(json.dumps({"schema_version": SCHEMA_VERSION}))
        with pytest.raises(TuningTableError, match="entries"):
            TuningTable.load(path)
        path.write_text(
            json.dumps(
                {
                    "schema_version": SCHEMA_VERSION,
                    "entries": {"k": {"config": {"warp_size": 4}}},
                }
            )
        )
        with pytest.raises(TuningTableError, match="unknown"):
            TuningTable.load(path)


# ---------------------------------------------------------------- sweep


class TestSweep:
    def test_default_candidate_enumerated_first(self):
        cands = default_candidates()
        assert cands[0].is_default
        tokens = [c.token() for c in cands]
        assert len(tokens) == len(set(tokens))

    def test_bcsr_forcing_wins_on_skewed_stack(self):
        ws, bs = _skewed_stack()
        winner, records = sweep_stack(ws, bs, 64, time_forwards=False)
        assert winner.layout == "bcsr"
        by_token = {r["token"]: r for r in records}
        assert (
            by_token["layout=bcsr"]["grid_steps"]
            < by_token["default"]["grid_steps"]
        )
        # Selection is recorded on exactly one candidate.
        assert sum(r.get("selected", False) for r in records) == 1

    def test_sweep_is_deterministic(self):
        ws, bs = _skewed_stack()
        w1, r1 = sweep_stack(ws, bs, 64, time_forwards=False)
        w2, r2 = sweep_stack(ws, bs, 64, time_forwards=False)
        assert w1 == w2
        assert [r["token"] for r in r1] == [r["token"] for r in r2]

    def test_accuracy_gate_rejects(self):
        ws, bs = _square_stack(jax.random.PRNGKey(0))
        # A zero tolerance still passes the default config (err == 0)
        # but rejects every bf16 candidate.
        winner, records = sweep_stack(
            ws, bs, 32, time_forwards=False, accuracy_rtol=0.0
        )
        assert winner.panel_dtype is None
        bf16 = [r for r in records if "bfloat16" in r["token"]]
        assert bf16 and all(not r["ok"] for r in bf16)

    def test_tune_stack_evidence(self):
        ws, bs = _square_stack(jax.random.PRNGKey(1))
        winner, table = tune_stack(ws, bs, 32, time_forwards=False)
        fp = P.topology_fingerprint(ws)
        rec = table.record(fp)
        assert rec is not None
        assert rec["grid_steps"] <= rec["default_grid_steps"]
        assert rec["config"] == winner.to_dict()
        assert table.lookup(fp) == winner


# ----------------------------------------------------- plan integration


class TestPlanIntegration:
    def test_plan_cache_tuned_untuned_non_collision(self):
        ws, bs = _square_stack(jax.random.PRNGKey(2))
        cache = P.PlanCache()
        tuned = TunedConfig(panel_dtype="bfloat16")
        p_default = cache.get(ws, bs, 32)
        p_tuned = cache.get(ws, bs, 32, tuned=tuned)
        assert p_default is not p_tuned
        assert p_default.key != p_tuned.key
        assert p_default.key.tuned is None
        assert p_tuned.key.tuned == tuned.token()
        # Each keeps its own slot: re-lookups hit, no rebuild.
        builds = cache.stats()["builds"]
        assert cache.get(ws, bs, 32) is p_default
        assert cache.get(ws, bs, 32, tuned=tuned) is p_tuned
        assert cache.stats()["builds"] == builds

    def test_mesh_plus_tuned_rejected(self):
        ws, bs = _square_stack(jax.random.PRNGKey(3))
        cache = P.PlanCache()
        with pytest.raises(ValueError, match="single-device"):
            cache.get(
                ws, bs, 32, mesh=object(), tuned=TunedConfig(block_n=64)
            )

    def test_tuned_plan_outputs_match(self):
        ws, bs = _skewed_stack()
        x = jax.random.normal(jax.random.PRNGKey(4), (256, 32))
        p0 = P.build_plan(ws, bs, 32)
        p1 = P.build_plan(ws, bs, 32, tuned=TunedConfig(layout="bcsr"))
        assert p1.layouts == ("bcsr", "bcsr", "bcsr")
        np.testing.assert_allclose(
            np.asarray(p1.forward(x)), np.asarray(p0.forward(x)), rtol=1e-6
        )

    def test_reblocked_plan_outputs_match(self):
        ws, bs = _skewed_stack()
        x = jax.random.normal(jax.random.PRNGKey(5), (256, 32))
        p0 = P.build_plan(ws, bs, 32)
        p1 = P.build_plan(ws, bs, 32, tuned=TunedConfig(block_size=32))
        assert all(w.block_shape == (32, 32) for w in p1.weights)
        np.testing.assert_allclose(
            np.asarray(p1.forward(x)),
            np.asarray(p0.forward(x)),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_bf16_moves_resident_boundary_at_plan_layer(self):
        # fused_mlp_vmem_bytes(8192, 128, f32) = 16 MiB > the 12 MiB
        # soft limit → fused-tiled; bf16 halves it to 8 MiB → fused.
        # Probe with route logic only (no 8192-wide build): the plan
        # layer's fused_route is the decision the builder obeys.
        from repro.kernels.fused_mlp import (
            VMEM_SOFT_LIMIT_BYTES,
            fused_mlp_vmem_bytes,
        )

        m = 8192
        assert fused_mlp_vmem_bytes(m, 128) > VMEM_SOFT_LIMIT_BYTES
        assert (
            fused_mlp_vmem_bytes(m, 128, "bfloat16") <= VMEM_SOFT_LIMIT_BYTES
        )
        # Same boundary, exercised end-to-end on a small stack via a
        # tuned vmem_limit: a budget under the f32 panel but over the
        # bf16 panel flips the route exactly like bf16-at-8192 does.
        ws, bs = _square_stack(jax.random.PRNGKey(6), m=64)
        f32_bytes = fused_mlp_vmem_bytes(64, 128)
        limit = f32_bytes - 1
        p_f32 = P.build_plan(
            ws, bs, 32, tuned=TunedConfig(vmem_limit_bytes=limit)
        )
        p_bf16 = P.build_plan(
            ws,
            bs,
            32,
            tuned=TunedConfig(
                vmem_limit_bytes=limit, panel_dtype="bfloat16"
            ),
        )
        assert p_f32.route == P.ROUTE_FUSED_TILED
        assert p_bf16.route == P.ROUTE_FUSED


# --------------------------------------------------- engine integration


class TestEngineIntegration:
    def test_engine_consults_table(self):
        ws, bs = _square_stack(jax.random.PRNGKey(7))
        _, table = tune_stack(ws, bs, 64, time_forwards=False)
        eng = SparseDNNEngine(ws, bs, batch_align=32, tuning_table=table)
        assert eng.tuned == table.lookup(P.topology_fingerprint(ws))
        x = jax.random.normal(jax.random.PRNGKey(8), (64, 20))
        out, stats = eng.infer(x)
        assert stats["plan"]["tuned"] == eng.tuned.token()
        ref_eng = SparseDNNEngine(ws, bs, batch_align=32)
        ref, ref_stats = ref_eng.infer(x)
        assert ref_stats["plan"]["tuned"] is None
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(ref, np.float32),
            atol=0.02 * float(np.max(np.abs(np.asarray(ref)))) + 1e-6,
        )

    def test_engine_table_miss_serves_defaults(self):
        ws, bs = _square_stack(jax.random.PRNGKey(9))
        eng = SparseDNNEngine(
            ws, bs, batch_align=32, tuning_table=TuningTable()
        )
        assert eng.tuned is None
        _, stats = eng.infer(jnp.ones((64, 4), jnp.float32))
        assert stats["plan"]["tuned"] is None

    def test_engine_panel_dtype_override(self):
        ws, bs = _square_stack(jax.random.PRNGKey(10))
        eng = SparseDNNEngine(
            ws, bs, batch_align=32, panel_dtype="bfloat16"
        )
        assert eng.tuned.panel_dtype == "bfloat16"
        _, stats = eng.infer(jnp.ones((64, 4), jnp.float32))
        assert stats["plan"]["tuned"] == "panel_dtype=bfloat16"
