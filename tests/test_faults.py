"""Fault-injection harness + graceful degradation (docs/robustness.md):
the injector's scheduled-pop determinism, per-request NaN quarantine,
transient retry / graceful panel failure, cache-eviction storms,
plan-compile demotion down the degradation ladder, shard failure →
single-device fallback with identical results, and the batcher-level
fault accounting (backpressure, shedding, straggler ticks, goodput)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.plan import LEVEL_LAYERED, LEVEL_RESIDENT, LEVEL_SHARDED
from repro.serve import ContinuousBatcher, QueueFull, SparseDNNEngine
from repro.sparse import BlockCSRMatrix, BlockSparseMatrix
from repro.testing import (
    SITE_CACHE_EVICTION,
    SITE_PANEL_NANS,
    SITE_PLAN_COMPILE,
    SITE_SHARD_FAILURE,
    SITE_STEP_TRANSIENT,
    SITE_STRAGGLER,
    FaultInjector,
    poison_panel,
)


def _bsr_stack(seed, L, m, bpr=2, block=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), L)
    ws = [
        BlockSparseMatrix.random(k, (m, m), (block, block), blocks_per_row=bpr)
        for k in ks
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    return ws, bs


def _csr_stack(seed, L, m, bpr=2, block=16):
    ws, bs = _bsr_stack(seed, L, m, bpr=bpr, block=block)
    return [BlockCSRMatrix.from_bsr(w) for w in ws], bs


def _panel(seed, m, k):
    return jax.random.uniform(jax.random.PRNGKey(seed), (m, k), jnp.float32)


def _col(seed, m):
    return jax.random.uniform(jax.random.PRNGKey(seed), (m,), jnp.float32)


# ---------------------------------------------------------------------
# FaultInjector mechanics
# ---------------------------------------------------------------------


def test_injector_scheduled_pop_and_log():
    inj = FaultInjector(seed=3)
    inj.schedule(SITE_PANEL_NANS, 2, count=1)
    inj.schedule(SITE_PANEL_NANS, 2, count=2)  # second fault, same slot
    assert inj.pending() == 2
    assert inj.fires(SITE_PANEL_NANS, 0) is None  # wrong ordinal
    assert inj.fires(SITE_STEP_TRANSIENT, 2) is None  # wrong site
    assert inj.fires(SITE_PANEL_NANS, 2) == {"count": 1}  # schedule order
    assert inj.fires(SITE_PANEL_NANS, 2) == {"count": 2}
    assert inj.fires(SITE_PANEL_NANS, 2) is None  # consumed
    assert inj.pending() == 0
    assert [e.payload for e in inj.fired_at(SITE_PANEL_NANS)] == [
        {"count": 1},
        {"count": 2},
    ]


def test_injector_rejects_unknown_site_and_negative_when():
    inj = FaultInjector()
    with pytest.raises(ValueError, match="unknown fault site"):
        inj.schedule("not-a-site", 0)
    with pytest.raises(ValueError, match="when"):
        inj.schedule(SITE_PANEL_NANS, -1)


def test_injector_rng_is_seeded():
    a = FaultInjector(seed=7).rng.integers(0, 1 << 30, size=8)
    b = FaultInjector(seed=7).rng.integers(0, 1 << 30, size=8)
    assert np.array_equal(a, b)


def test_poison_panel_columns_and_limit():
    panel = _panel(0, 8, 6)
    poisoned, cols = poison_panel(panel, columns=[1, 4])
    assert cols == (1, 4)
    assert not bool(jnp.isfinite(poisoned[:, 1]).any())
    assert not bool(jnp.isfinite(poisoned[:, 4]).any())
    for j in (0, 2, 3, 5):  # untouched columns are bit-identical
        assert np.array_equal(poisoned[:, j], panel[:, j])
    # limit keeps random choice inside the real (non-pad) columns
    rng = np.random.default_rng(0)
    _, cols = poison_panel(panel, count=3, limit=4, rng=rng)
    assert len(cols) == 3 and all(c < 4 for c in cols)
    with pytest.raises(ValueError, match="out of range"):
        poison_panel(panel, columns=[5], limit=4)
    with pytest.raises(ValueError, match="mode"):
        poison_panel(panel, mode="zero")


# ---------------------------------------------------------------------
# engine: quarantine / retry / graceful failure / eviction
# ---------------------------------------------------------------------


def test_engine_quarantines_only_poisoned_requests():
    m, k = 32, 6
    ws, bs = _bsr_stack(1, 3, m)
    clean = SparseDNNEngine(ws, bs, batch_align=8)
    ref, _ = clean.infer(_panel(1, m, k))

    inj = FaultInjector(seed=0)
    inj.schedule(SITE_PANEL_NANS, 0, columns=[1, 4])
    eng = SparseDNNEngine(ws, bs, batch_align=8, fault_injector=inj)
    out, stats = eng.infer(_panel(1, m, k))
    assert stats["failed"] is False
    # exactly the poisoned requests fail; NaN propagates through the
    # ReLU stack column-separably, so the rest of the panel is unharmed
    assert stats["quarantined_request_ids"] == [1, 4]
    for j in (0, 2, 3, 5):
        assert np.array_equal(out[:, j], ref[:, j])
    assert not bool(jnp.isfinite(out[:, 1]).any())


def test_engine_retries_transient_then_succeeds():
    m = 32
    ws, bs = _bsr_stack(2, 2, m)
    inj = FaultInjector()
    inj.schedule(SITE_STEP_TRANSIENT, 0, failures=2)
    eng = SparseDNNEngine(
        ws, bs, batch_align=8, fault_injector=inj, max_step_retries=2
    )
    clean = SparseDNNEngine(ws, bs, batch_align=8)
    out, stats = eng.infer(_panel(3, m, 4))
    ref, _ = clean.infer(_panel(3, m, 4))
    assert stats["failed"] is False
    assert stats["retries"] == 2  # two injected failures, then success
    assert np.array_equal(out, ref)


def test_engine_fails_gracefully_after_retry_exhaustion():
    m = 32
    ws, bs = _bsr_stack(2, 2, m)
    inj = FaultInjector()
    inj.schedule(SITE_STEP_TRANSIENT, 0, failures=10)  # > retries
    eng = SparseDNNEngine(
        ws, bs, batch_align=8, fault_injector=inj, max_step_retries=2
    )
    out, stats = eng.infer(_panel(4, m, 4))  # must NOT raise
    assert out is None
    assert stats["failed"] is True
    assert stats["retries"] == 2
    assert stats["request_ids"] == [0, 1, 2, 3]  # the lost requests
    assert "TransientFault" in stats["error"]
    # the engine survives: the next panel serves normally
    out2, stats2 = eng.infer(_panel(5, m, 4))
    assert stats2["failed"] is False and bool(jnp.isfinite(out2).all())


def test_engine_cache_eviction_storm_recompiles():
    m = 32
    ws, bs = _bsr_stack(3, 2, m)
    inj = FaultInjector()
    inj.schedule(SITE_CACHE_EVICTION, 2)
    eng = SparseDNNEngine(ws, bs, batch_align=8, fault_injector=inj)
    _, s0 = eng.infer(_panel(0, m, 4))
    _, s1 = eng.infer(_panel(1, m, 4))
    assert s0["plan"]["cache_hit"] is False  # first build
    assert s1["plan"]["cache_hit"] is True  # warm
    _, s2 = eng.infer(_panel(2, m, 4))  # eviction storm fires here
    _, s3 = eng.infer(_panel(3, m, 4))
    assert s2["plan"]["cache_hit"] is False  # forced recompile
    assert s3["plan"]["cache_hit"] is True  # warm again


# ---------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------


def test_compile_failure_demotes_resident_to_layered():
    m = 32
    ws, bs = _bsr_stack(4, 2, m)
    layered = SparseDNNEngine(ws, bs, batch_align=8, use_resident=False)
    ref, _ = layered.infer(_panel(6, m, 4))

    inj = FaultInjector()
    inj.schedule(SITE_PLAN_COMPILE, 0)
    eng = SparseDNNEngine(ws, bs, batch_align=8, fault_injector=inj)
    assert eng.ladder.preferred_level == LEVEL_RESIDENT
    out, stats = eng.infer(_panel(6, m, 4))
    # the panel that hit the compile fault is still served — one level
    # down — and matches the healthy layered engine bit for bit
    assert stats["failed"] is False
    assert stats["plan"]["level"] == LEVEL_LAYERED
    assert stats["plan"]["degraded"] is True
    assert np.array_equal(out, ref)
    # demotion is sticky and logged...
    assert not eng.ladder.is_healthy(LEVEL_RESIDENT)
    ev = eng.ladder.events
    assert len(ev) == 1 and ev[0].healthy is False
    _, s2 = eng.infer(_panel(7, m, 4))
    assert s2["plan"]["level"] == LEVEL_LAYERED
    # ...until an operator restore re-admits the level
    eng.ladder.restore(LEVEL_RESIDENT)
    _, s3 = eng.infer(_panel(8, m, 4))
    assert s3["plan"]["level"] == LEVEL_RESIDENT
    assert s3["plan"]["degraded"] is False


def test_restore_reverses_demotion_and_logs_up_transition():
    """Restore semantics: after ``restore()`` the next panel re-plans at
    the restored level, the event history records the up-transition
    (healthy=True) with the operator's reason/step, and restoring an
    already-healthy level is a silent no-op."""
    m = 32
    ws, bs = _bsr_stack(9, 2, m)
    inj = FaultInjector()
    inj.schedule(SITE_PLAN_COMPILE, 0)
    eng = SparseDNNEngine(ws, bs, batch_align=8, fault_injector=inj)
    _, s0 = eng.infer(_panel(20, m, 4))
    assert s0["plan"]["level"] == LEVEL_LAYERED  # demoted by the fault

    eng.ladder.restore(LEVEL_RESIDENT, reason="node re-slotted", step=7)
    # the history records the full round trip: down, then up
    assert [(e.level, e.healthy) for e in eng.ladder.events] == [
        (LEVEL_RESIDENT, False),
        (LEVEL_RESIDENT, True),
    ]
    up = eng.ladder.events[-1]
    assert up.reason == "node re-slotted" and up.step == 7
    assert eng.ladder.is_healthy(LEVEL_RESIDENT)
    assert not eng.ladder.degraded
    # idempotent: restoring a healthy level appends NO duplicate event
    eng.ladder.restore(LEVEL_RESIDENT)
    assert len(eng.ladder.events) == 2
    # the floor has no health state to restore
    with pytest.raises(ValueError, match="health"):
        eng.ladder.restore(LEVEL_LAYERED)

    # the next panel re-plans at the restored level — and still matches
    # a never-degraded engine bit for bit
    clean = SparseDNNEngine(ws, bs, batch_align=8)
    p = _panel(21, m, 4)
    out, s1 = eng.infer(p)
    ref, _ = clean.infer(p)
    assert s1["plan"]["level"] == LEVEL_RESIDENT
    assert s1["plan"]["degraded"] is False
    assert np.array_equal(out, ref)
    # the serve-stats surface sees the same round trip
    d = eng.ladder.describe()
    assert d["current"] == d["preferred"] == LEVEL_RESIDENT
    assert [e["healthy"] for e in d["events"]] == [False, True]


def test_shard_failure_degrades_to_single_device_same_results():
    from repro.launch.mesh import make_row_blocks_mesh

    m = 32
    ws, bs = _csr_stack(5, 2, m)
    mesh = make_row_blocks_mesh(1)
    single = SparseDNNEngine(ws, bs, batch_align=8)
    inj = FaultInjector()
    inj.schedule(SITE_SHARD_FAILURE, 1, reason="node 3 lost")
    eng = SparseDNNEngine(ws, bs, batch_align=8, mesh=mesh, fault_injector=inj)

    p0, p1 = _panel(9, m, 4), _panel(10, m, 4)
    _, s0 = eng.infer(p0)
    assert s0["plan"]["level"] == LEVEL_SHARDED  # healthy mesh first
    out1, s1 = eng.infer(p1)  # shard dies at this dispatch
    ref1, sref = single.infer(p1)
    # the in-flight panel is NOT dropped: same fingerprint re-planned on
    # a single device, identical results to a healthy single-device run
    assert s1["failed"] is False
    assert s1["plan"]["level"] == sref["plan"]["level"]
    assert s1["plan"]["degraded"] is True
    assert np.array_equal(out1, ref1)
    assert eng.ladder.degraded
    assert [e.level for e in eng.ladder.events] == [LEVEL_SHARDED]


# ---------------------------------------------------------------------
# batcher: backpressure / shedding / stragglers / goodput
# ---------------------------------------------------------------------


def test_bounded_queue_backpressure():
    from repro.serve import RequestQueue

    q = RequestQueue(max_pending=2)
    q.submit(_col(0, 8), now=0)
    q.submit(_col(1, 8), now=0)
    with pytest.raises(QueueFull):
        q.submit(_col(2, 8), now=0)
    m = 32
    ws, bs = _bsr_stack(6, 2, m)
    b = ContinuousBatcher(
        SparseDNNEngine(ws, bs, batch_align=4),
        batch_size=4,
        max_pending=2,
    )
    assert b.submit(_col(0, m)) is not None
    assert b.submit(_col(1, m)) is not None
    assert b.submit(_col(2, m)) is None  # rejected, not raised
    b.drain()
    s = b.stats()
    assert s.faults.offered == 3
    assert s.faults.rejected == 1
    assert s.requests == 2
    assert s.goodput == pytest.approx(2 / 3)


def test_batcher_straggler_tick_and_failed_step_accounting():
    m = 32
    ws, bs = _bsr_stack(7, 2, m)
    inj = FaultInjector()
    inj.schedule(SITE_STRAGGLER, 0, seconds=0.0)
    inj.schedule(SITE_STEP_TRANSIENT, 0, failures=10)  # kill panel 0
    eng = SparseDNNEngine(
        ws, bs, batch_align=4, fault_injector=inj, max_step_retries=1
    )
    b = ContinuousBatcher(eng, batch_size=4, fault_injector=inj)
    r0 = b.submit(_col(0, m))
    b.step()  # straggles, then the panel dies after retries
    r1 = b.submit(_col(1, m))
    b.drain()
    s = b.stats()
    assert s.faults.straggler_ticks == 1
    assert s.faults.failed_steps == 1
    assert s.faults.failed == 1
    assert s.faults.retried_steps == 1
    assert "step failed" in b.failures[r0]
    assert r1 in s.latencies  # the stream survived the dead panel
    assert s.goodput == pytest.approx(1 / 2)


def test_injected_trace_completes_with_goodput():
    """End-to-end: a trace with NaN panels, a transient failure, an
    eviction storm, and a straggler completes without raising and the
    quarantine fails only the poisoned requests."""
    m = 32
    ws, bs = _bsr_stack(8, 3, m)
    inj = FaultInjector(seed=1)
    inj.schedule(SITE_PANEL_NANS, 1, count=1)
    inj.schedule(SITE_STEP_TRANSIENT, 2, failures=1)  # retried, no loss
    inj.schedule(SITE_CACHE_EVICTION, 3)
    inj.schedule(SITE_STRAGGLER, 2, seconds=0.0)
    eng = SparseDNNEngine(ws, bs, batch_align=4, fault_injector=inj)
    b = ContinuousBatcher(eng, batch_size=4, fault_injector=inj)
    n = 24
    for i in range(n):
        b.submit(_col(100 + i, m))
        if i % 2:
            b.step()
    b.drain()
    s = b.stats()
    assert s.faults.quarantined == 1
    assert s.faults.retried_steps == 1
    assert s.faults.straggler_ticks == 1
    assert s.faults.failed == 0
    assert s.requests == n - 1  # everything but the quarantined one
    assert s.goodput == pytest.approx((n - 1) / n)
    assert inj.pending() == 0  # every armed fault actually fired
    quarantined = [r for r, why in b.failures.items() if "quarantine" in why]
    assert len(quarantined) == 1
    for rid, lat in s.latencies.items():
        assert bool(jnp.isfinite(b.result(rid)).all())
