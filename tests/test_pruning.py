"""Sparsification pipeline (Deep Compression; paper §I)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pruning
from repro.sparse import BlockSparseMatrix


def test_magnitude_prune_density():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    pruned = pruning.magnitude_prune(w, 0.25)
    nnz = float((pruned != 0).mean())
    assert abs(nnz - 0.25) < 0.02


def test_magnitude_prune_keeps_largest():
    w = jnp.array([[1.0, -5.0], [0.1, 3.0]])
    pruned = pruning.magnitude_prune(w, 0.5)
    np.testing.assert_array_equal(pruned, [[0.0, -5.0], [0.0, 3.0]])


def test_magnitude_prune_idempotent():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    once = pruning.magnitude_prune(w, 0.3)
    twice = pruning.magnitude_prune(once, 0.3)
    np.testing.assert_array_equal(once, twice)


def test_magnitude_prune_validates():
    with pytest.raises(ValueError):
        pruning.magnitude_prune(jnp.ones((2, 2)), 0.0)


def test_block_prune_mask_row_budget():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    mask = pruning.block_prune_mask(w, (8, 8), blocks_per_row=3)
    assert mask.shape == (8, 8)
    np.testing.assert_array_equal(np.asarray(mask).sum(1), 3)


def test_block_prune_returns_ell_bsr():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    bsr = pruning.block_prune(w, (8, 8), blocks_per_row=2)
    assert isinstance(bsr, BlockSparseMatrix)
    assert bsr.max_blocks_per_row == 2
    # kept blocks are the top-2 by L1 per row
    scores = np.asarray(pruning.block_scores(w, (8, 8)))
    ci = np.asarray(bsr.col_idx)
    for i in range(8):
        top2 = set(np.argsort(-scores[i])[:2].tolist())
        assert set(ci[i].tolist()) == top2


def test_block_prune_preserves_kept_values():
    rng = np.random.default_rng(4)
    w = np.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    bsr = pruning.block_prune(jnp.asarray(w), (8, 8), blocks_per_row=4)
    np.testing.assert_allclose(bsr.to_dense(), w, rtol=1e-6)  # 4/4 = keep all


def test_apply_block_mask():
    w = jnp.ones((16, 16))
    mask = jnp.zeros((2, 2), bool).at[0, 0].set(True)
    out = pruning.apply_block_mask(w, mask, (8, 8))
    assert float(out[:8, :8].sum()) == 64.0
    assert float(out.sum()) == 64.0


def test_schedule():
    sched = pruning.PruneSchedule(steps=[10, 20], densities=[0.5, 0.25])
    assert sched.density_at(0) == 1.0
    assert sched.density_at(10) == 0.5
    assert sched.density_at(25) == 0.25
    assert sched.is_prune_step(20) and not sched.is_prune_step(15)
    with pytest.raises(ValueError):
        pruning.PruneSchedule(steps=[1, 2], densities=[0.2, 0.5])


@hypothesis.given(
    density=st.floats(0.05, 1.0), seed=st.integers(0, 2**31 - 1)
)
@hypothesis.settings(deadline=None, max_examples=25)
def test_prune_density_property(density, seed):
    """Achieved density within one element of requested; energy kept is
    maximal (no dropped element larger than a kept one)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (16, 16))
    pruned = pruning.magnitude_prune(w, density)
    nnz = int((np.asarray(pruned) != 0).sum())
    assert abs(nnz - round(256 * density)) <= 1
    kept = np.abs(np.asarray(pruned))[np.asarray(pruned) != 0]
    dropped = np.abs(np.asarray(w))[np.asarray(pruned) == 0]
    if kept.size and dropped.size:
        assert kept.min() >= dropped.max() - 1e-6
