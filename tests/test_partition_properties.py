"""Property-based invariants of ``repro.sparse.partition`` — the
balanced block-CSR shard partitioner underneath the sharded route.

Randomized topologies (seeded occupancy patterns: empty block-rows,
skewed rows, full rows) × shard counts, checking the partition contract
the sharded kernels rely on:

* conservation — per-shard nnz counts sum exactly to the matrix's nnz;
* slot coverage — every valid source slot lands in exactly one shard
  (``gather_index`` restricted to valid slots is a permutation of the
  source's valid slots);
* row partition — per-shard ``row_ptr`` local counts reassemble the
  source's per-row counts;
* bit-exact reassembly — summing the per-shard densifications
  reproduces ``to_dense()`` of the source bit for bit;
* degenerate shards — ``n_shards`` past the available blocks yields
  empty, inert sub-layouts, never an error.

Uses real ``hypothesis`` when installed, else the deterministic shim in
``tests/_hypothesis_fallback.py`` (see ``conftest.py``).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import BlockCSRMatrix, BlockSparseMatrix
from repro.sparse.partition import partition_block_csr

BLOCK = 8  # small blocks keep examples cheap; nothing depends on 16


def _random_bcsr(seed: int, nrb: int, density: float) -> BlockCSRMatrix:
    """A block-CSR matrix with a seeded random block-occupancy pattern
    (pinned to ≥ 1 stored block so the ELL lowering is well-formed)."""
    rng = np.random.default_rng(seed)
    occ = rng.random((nrb, nrb)) < density
    occ[rng.integers(nrb), rng.integers(nrb)] = True
    m = nrb * BLOCK
    dense = np.zeros((m, m), np.float32)
    for i, j in zip(*np.nonzero(occ)):
        dense[
            i * BLOCK : (i + 1) * BLOCK, j * BLOCK : (j + 1) * BLOCK
        ] = rng.standard_normal((BLOCK, BLOCK))
    w = BlockSparseMatrix.from_dense(jnp.asarray(dense), (BLOCK, BLOCK))
    return BlockCSRMatrix.from_bsr(w)


@hypothesis.given(data=st.data())
@hypothesis.settings(deadline=None, max_examples=30)
def test_partition_block_csr_invariants(data):
    seed = data.draw(st.integers(0, 2**16 - 1), label="seed")
    nrb = data.draw(st.integers(1, 6), label="row_blocks")
    density = data.draw(st.floats(0.05, 1.0), label="density")
    n_shards = data.draw(st.integers(1, 9), label="shards")
    a = _random_bcsr(seed, nrb, density)
    sharded = partition_block_csr(a, n_shards)
    valid_src = np.asarray(a.valid)
    nnz = int(valid_src.sum())
    per = sharded.nnz_per_shard()

    # conservation: per-shard nnz sums exactly to the matrix's nnz
    assert int(per.sum()) == nnz
    # equal-count split: shard sizes differ by at most one, and the
    # imbalance factor stays inside the documented 1 + S/nnz bound
    assert int(per.max() - per.min()) <= 1
    assert sharded.imbalance() <= 1.0 + n_shards / max(nnz, 1) + 1e-12

    # slot coverage: every valid source slot lands in exactly ONE shard
    mask = np.asarray(sharded.valid)
    gidx = np.asarray(sharded.gather_index)[mask]
    np.testing.assert_array_equal(np.sort(gidx), np.nonzero(valid_src)[0])

    # row partition: per-shard local row counts reassemble the source's
    # per-row counts (each shard's row_ptr is a true sub-histogram)
    local = np.diff(np.asarray(sharded.row_ptr), axis=1)
    src_rows = np.asarray(a.row_id)[valid_src]
    np.testing.assert_array_equal(
        local.sum(axis=0),
        np.bincount(src_rows, minlength=a.n_row_blocks),
    )

    # bit-exact reassembly: each stored block lands in exactly one
    # shard, so the sum of per-shard densifications is exact in float
    np.testing.assert_array_equal(
        np.asarray(sharded.to_dense()), np.asarray(a.to_dense())
    )

    # re-sharding fresh values through the frozen partition reproduces
    # the stacked values bit for bit (the training-step path)
    np.testing.assert_array_equal(
        np.asarray(sharded.rescatter_values(a.values)),
        np.asarray(sharded.values),
    )


def test_degenerate_zero_nnz_shards_validate():
    """More shards than blocks: tail shards become empty sub-layouts
    (inert: all-invalid, zero row_ptr, zero densification) — and the
    reassembly invariant still holds."""
    w = BlockSparseMatrix.random(
        jax.random.PRNGKey(0), (16, 16), (BLOCK, BLOCK), blocks_per_row=1
    )
    a = BlockCSRMatrix.from_bsr(w)
    nnz = int(np.asarray(a.valid).sum())
    sharded = partition_block_csr(a, nnz + 3)
    per = sharded.nnz_per_shard()
    assert int(per.sum()) == nnz and (per <= 1).all()
    for s in range(sharded.n_shards):
        sub = sharded.shard(s)  # every shard is a valid sub-layout
        if per[s] == 0:
            assert not bool(np.asarray(sharded.valid)[s].any())
            assert np.asarray(sharded.row_ptr)[s].sum() == 0
            assert not np.asarray(sub.to_dense()).any()
    np.testing.assert_array_equal(
        np.asarray(sharded.to_dense()), np.asarray(a.to_dense())
    )
    with pytest.raises(ValueError, match="n_shards"):
        partition_block_csr(a, 0)
