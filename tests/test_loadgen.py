"""Injectable clocks and the open-loop load generator: virtual time
semantics, profile shapes, trace determinism, and the no-real-sleep
contract for clock-routed backoff/straggler stalls."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import (
    Clock,
    ContinuousBatcher,
    LoadProfile,
    SparseDNNEngine,
    VirtualClock,
    WallClock,
    generate_jobs,
)
from repro.sparse import BlockSparseMatrix
from repro.testing.faults import (
    SITE_STEP_TRANSIENT,
    SITE_STRAGGLER,
    FaultInjector,
)


@pytest.fixture(autouse=True)
def _no_real_sleep(monkeypatch):
    """Every test in this file must finish without one real sleep —
    the same guard the CI fleet job runs the serving tests under."""

    def _boom(seconds):
        raise AssertionError(f"real time.sleep({seconds}) in a virtual-clock test")

    monkeypatch.setattr(time, "sleep", _boom)


def _bsr_stack(seed, L, m, bpr=2, block=16):
    ks = jax.random.split(jax.random.key(seed), L)
    ws = [
        BlockSparseMatrix.random(k, (m, m), (block, block), blocks_per_row=bpr)
        for k in ks
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    return ws, bs


# ---------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------


def test_virtual_clock_advances_and_records():
    c = VirtualClock(start=5.0)
    assert c.now() == 5.0
    c.sleep(1.5)
    c.sleep(0.0)
    assert c.now() == 6.5
    assert c.sleeps == [1.5, 0.0]
    assert c.slept_total == 1.5
    c.advance_to(10.0)
    assert c.now() == 10.0


def test_virtual_clock_is_monotonic():
    c = VirtualClock()
    c.advance_to(2.0)
    with pytest.raises(ValueError):
        c.advance_to(1.0)
    with pytest.raises(ValueError):
        c.sleep(-0.1)


def test_clock_protocol_covers_both_implementations():
    assert isinstance(WallClock(), Clock)
    assert isinstance(VirtualClock(), Clock)


# ---------------------------------------------------------------------
# load profiles
# ---------------------------------------------------------------------


def test_constant_profile():
    p = LoadProfile.constant(12.0)
    assert p.rate(0.0) == p.rate(99.0) == 12.0
    assert p.peak == 12.0
    with pytest.raises(ValueError):
        LoadProfile.constant(0.0)


def test_diurnal_profile_trough_and_peak():
    p = LoadProfile.diurnal(base=10.0, amplitude=20.0, period=4.0)
    assert p.peak == 30.0
    assert p.rate(1.0) == pytest.approx(30.0)  # sin peak at period/4
    assert p.rate(3.0) == pytest.approx(10.0)  # trough at 3*period/4
    assert min(p.rate(t / 10) for t in range(100)) >= 10.0 - 1e-9
    assert max(p.rate(t / 10) for t in range(100)) <= 30.0 + 1e-9


def test_bursty_profile_windows():
    p = LoadProfile.bursty(base=5.0, burst_rate=50.0, burst_every=10.0, burst_len=2.0)
    assert p.peak == 50.0
    assert p.rate(0.5) == 50.0  # inside the burst window
    assert p.rate(3.0) == 5.0  # outside
    assert p.rate(11.9) == 50.0  # next window
    with pytest.raises(ValueError):
        LoadProfile.bursty(5.0, 4.0, 10.0, 2.0)  # burst below base
    with pytest.raises(ValueError):
        LoadProfile.bursty(5.0, 50.0, 2.0, 10.0)  # len > every


def test_scaled_profile():
    p = LoadProfile.bursty(5.0, 50.0, 10.0, 2.0).scaled(2.0)
    assert p.rate(0.5) == 100.0
    assert p.rate(3.0) == 10.0
    assert p.peak == 100.0
    with pytest.raises(ValueError):
        p.scaled(0.0)


# ---------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------


def test_generate_jobs_deterministic():
    kw = dict(m=32, seed=11, width_mix=((2, 0.5), (8, 0.5)), deadline_s=0.25)
    a = generate_jobs(LoadProfile.constant(40.0), 2.0, **kw)
    b = generate_jobs(LoadProfile.constant(40.0), 2.0, **kw)
    assert [j.t for j in a] == [j.t for j in b]
    assert [j.cols for j in a] == [j.cols for j in b]
    for ja, jb in zip(a, b):
        assert np.array_equal(np.asarray(ja.features), np.asarray(jb.features))
    c = generate_jobs(LoadProfile.constant(40.0), 2.0, **{**kw, "seed": 12})
    assert [j.t for j in a] != [j.t for j in c]


def test_generate_jobs_shapes_and_deadlines():
    jobs = generate_jobs(
        LoadProfile.constant(30.0),
        3.0,
        m=16,
        seed=0,
        width_mix=((1, 0.7), (4, 0.3)),
        deadline_s=0.5,
    )
    assert jobs, "a 30 Hz trace over 3 s should produce arrivals"
    assert [j.rid for j in jobs] == list(range(len(jobs)))
    assert all(0.0 < j.t < 3.0 for j in jobs)
    assert [j.t for j in jobs] == sorted(j.t for j in jobs)
    assert {j.cols for j in jobs} <= {1, 4}
    for j in jobs:
        assert j.features.shape == (16, j.cols)
        assert j.deadline == pytest.approx(j.t + 0.5)
    nodeadline = generate_jobs(LoadProfile.constant(30.0), 1.0, m=16, seed=0)
    assert all(j.deadline is None for j in nodeadline)


def test_thinning_concentrates_arrivals_in_bursts():
    p = LoadProfile.bursty(base=2.0, burst_rate=60.0, burst_every=5.0, burst_len=1.0)
    jobs = generate_jobs(p, 20.0, m=8, seed=3)
    in_burst = sum(1 for j in jobs if (j.t % 5.0) < 1.0)
    out_burst = len(jobs) - in_burst
    # Burst windows are 1/5 of the time at 30x the rate: the bulk of
    # arrivals must land inside them.
    assert in_burst > 3 * out_burst


def test_generate_jobs_validation():
    with pytest.raises(ValueError):
        generate_jobs(LoadProfile.constant(1.0), 0.0, m=8, seed=0)
    with pytest.raises(ValueError):
        generate_jobs(
            LoadProfile.constant(1.0), 1.0, m=8, seed=0, width_mix=((0, 1.0),)
        )


# ---------------------------------------------------------------------
# clock-routed stalls: backoff and stragglers under virtual time
# ---------------------------------------------------------------------


def test_engine_retry_backoff_through_virtual_clock():
    ws, bs = _bsr_stack(0, 2, 32)
    inj = FaultInjector()
    inj.schedule(SITE_STEP_TRANSIENT, 0, failures=2)
    clock = VirtualClock()
    eng = SparseDNNEngine(
        ws,
        bs,
        batch_align=4,
        fault_injector=inj,
        max_step_retries=2,
        retry_backoff_s=0.1,
        clock=clock,
    )
    eng.submit(jax.random.uniform(jax.random.key(1), (32, 2)))
    out, stats = eng.step()
    assert out is not None and not stats["failed"]
    assert stats["retries"] == 2
    # Exponential backoff 0.1, 0.2 — recorded on the virtual clock, no
    # real stall (the autouse guard would have raised).
    assert clock.sleeps == pytest.approx([0.1, 0.2])


def test_batcher_straggler_through_virtual_clock():
    ws, bs = _bsr_stack(1, 2, 32)
    inj = FaultInjector()
    inj.schedule(SITE_STRAGGLER, 0, seconds=1.25)
    clock = VirtualClock()
    eng = SparseDNNEngine(ws, bs, batch_align=4)
    b = ContinuousBatcher(
        eng, batch_size=4, fault_injector=inj, clock=clock
    )
    b.submit(jax.random.uniform(jax.random.key(2), (32,)))
    b.drain()
    s = b.stats()
    assert s.faults.straggler_ticks == 1
    assert clock.slept_total == pytest.approx(1.25)
