"""Sharding-rule resolver properties + distributed collectives semantics.

Multi-device semantics (embed_lookup vs plain gather, compressed psum
exactness) run in a SUBPROCESS with 8 fake host devices so the main test
process keeps its single-device view (dry-run contract)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distribution.sharding import (
    ShardingRules,
    _logical_axes,
    _resolve_spec,
    param_pspecs,
)
from repro.models.model import Model


class _FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape


@given(
    dim=st.integers(1, 4096),
    axis=st.sampled_from([2, 3, 16]),
)
@settings(max_examples=40, deadline=None)
def test_resolver_divisibility_fallback(dim, axis):
    mesh = _FakeMesh({"data": axis, "model": 16})
    spec = _resolve_spec(("fsdp",), (dim,), mesh, ShardingRules())
    got = spec[0] if len(spec) else None
    if dim % axis == 0:
        assert got == "data"
    else:
        assert got is None


def test_resolver_never_reuses_axis():
    mesh = _FakeMesh({"data": 4, "model": 4})
    spec = _resolve_spec(("fsdp", "fsdp"), (16, 16), mesh, ShardingRules())
    axes = [s for s in tuple(spec) if s is not None]
    assert len(axes) <= 1  # second use of the same axis must drop


def test_param_pspecs_cover_all_leaves():
    cfg = get_config("jamba-v0.1-52b").scaled_down()
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    mesh = _FakeMesh({"data": 2, "model": 2})
    specs = param_pspecs(cfg, shapes, mesh, ShardingRules())
    s_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    p_leaves = jax.tree.leaves(shapes)
    assert len(s_leaves) == len(p_leaves)
    for spec, leaf in zip(s_leaves, p_leaves):
        assert len(tuple(spec)) <= leaf.ndim


def test_period_leading_axis_never_sharded():
    names = ["stack", "period", "0", "ffn", "w_in"]
    axes = _logical_axes(names, 3)  # stacked (L, d, ff)
    assert axes[0] is None


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distribution import sharding as sh
    from repro.distribution.collectives import compressed_psum_mean
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # --- embed_lookup == table[ids] under sharding -----------------------
    key = jax.random.key(0)
    table = jax.random.normal(key, (64, 16))
    ids = jax.random.randint(jax.random.key(1), (8, 12), 0, 64)
    with mesh, sh.activate(mesh):
        f = jax.jit(lambda t, i: sh.embed_lookup(t, i))
        out = f(
            jax.device_put(table, NamedSharding(mesh, P("data", "model"))),
            jax.device_put(ids, NamedSharding(mesh, P("data", None))),
        )
    np.testing.assert_allclose(np.asarray(out), np.asarray(table)[np.asarray(ids)], rtol=1e-6)
    print("embed_lookup OK")

    # --- compressed psum: int8 error feedback ----------------------------
    gmesh = jax.make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.key(2), (8, 256))

    def body(xl, el):
        m, e = compressed_psum_mean(xl[0], "data", el[0])
        return m[None], e[None]

    with gmesh:
        mfn = shard_map(
            body, mesh=gmesh,
            in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None)),
        )
        err = jnp.zeros_like(x)
        m1, err = mfn(x, err)
        exact = jnp.mean(x, axis=0)
        q_err1 = float(jnp.max(jnp.abs(m1[0] - exact)))
        # quantization error bounded by the int8 step size
        step = float(jnp.max(jnp.abs(x)) / 127.0)
        assert q_err1 <= step + 1e-6, (q_err1, step)
        # error feedback: running mean over repeats converges
        acc = m1[0]
        for rep in range(24):
            m, err = mfn(x, err)
            acc = acc + m[0]
        avg = acc / 25.0
        drift = float(jnp.max(jnp.abs(avg - exact)))
        assert drift < step * 0.2, (drift, step)
    print("compressed_psum OK")
    """
)


@pytest.mark.slow
def test_multidevice_semantics_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "embed_lookup OK" in r.stdout
    assert "compressed_psum OK" in r.stdout
