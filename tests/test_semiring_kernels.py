"""Semiring-generalized sparse kernels vs the algebra's own matmul.

The tentpole contract: every registry semiring × both sparse layouts
(block-CSR and ELL) through the Pallas kernels must match
``Semiring.matmul`` on the dense reconstruction — *bit-exactly* in f32
for the order-independent semirings (integer-valued inputs make
plus_times sums exact too), to 1e-5 for ``log_plus`` (the kernel chains
chunked logsumexp reductions where the reference does one). The dense
reference fills entries outside stored blocks with the semiring's ⊕
identity — NOT 0.0 — because a missing block means "no edge" in every
algebra (for ``min_plus``, 0.0 would be a free edge).

Topologies are built to exercise the two hazard cases the kernels must
get right for non-additive monoids:

* **empty rows** — a block-row with no stored blocks must come out as
  the ⊕ identity (the bcsr wrapper's fill), not garbage;
* **padded blocks** — ELL pad slots and bcsr tail padding must be
  annihilator-aware: skipped entirely, contributing exactly the ⊕
  identity to their accumulator.

Also pins the GraphBLAS façade routing: ``mxm`` on a sparse operand
launches the Pallas kernel route (pallas_call-counted), the oracle
route launches none, and plans are cached per (topology, width,
semiring).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graphblas as gb
from repro.core import semiring as core_sr
from repro.kernels import ops as kernel_ops
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

ALL_NAMES = sorted(core_sr.REGISTRY)
# log_plus: the kernel's chunked logsumexp chain vs the reference's
# single reduction — equal to f32 roundoff, not bit-equal.
TOL = {"log_plus": 1e-5}


def _assert_matches(name, out, ref):
    ref = np.asarray(ref, np.float32)
    out = np.asarray(out, np.float32)
    if name in TOL:
        np.testing.assert_allclose(out, ref, rtol=TOL[name], atol=TOL[name])
    else:
        np.testing.assert_array_equal(out, ref)


def _integer_dense(seed, shape, block_shape, zero_block_rows=()):
    """Integer-valued f32 dense with block structure and empty rows."""
    rng = np.random.default_rng(seed)
    d = rng.integers(-3, 4, size=shape).astype(np.float32)
    bs_r, _ = block_shape
    # knock out some whole blocks so the sparse forms have gaps
    nrb, ncb = shape[0] // block_shape[0], shape[1] // block_shape[1]
    keep = rng.random((nrb, ncb)) < 0.5
    keep[:, 0] = True  # every column represented somewhere
    for rb in zero_block_rows:
        keep[rb, :] = False  # an EMPTY block-row
    mask = np.kron(keep, np.ones(block_shape, bool))
    return np.where(mask, d, 0.0).astype(np.float32), keep


def _reference(sr, dense, present, b):
    """Semiring.matmul on the ⊕-identity-filled dense reconstruction."""
    a_ref = jnp.where(jnp.asarray(present), jnp.asarray(dense), sr.zero)
    ref = sr.matmul(a_ref, jnp.asarray(b))
    if ref.dtype == jnp.bool_:
        ref = ref.astype(jnp.float32)
    return ref


def _present_mask(keep, block_shape):
    return np.kron(keep, np.ones(block_shape, bool))


def _b_panel(seed, k, n, name):
    rng = np.random.default_rng(seed)
    b = rng.integers(-3, 4, size=(k, n)).astype(np.float32)
    if name in ("lor_land", "xor_and"):
        b = (b > 0).astype(np.float32)  # {0,1} encoding
    return b


def _a_values(dense, name):
    if name in ("lor_land", "xor_and"):
        return (dense > 0).astype(np.float32)
    return dense


M, K, N = 48, 32, 24
BLOCK = (8, 8)


@pytest.mark.parametrize("name", ALL_NAMES)
def test_bcsr_kernel_matches_semiring_matmul(name):
    sr = core_sr.get_semiring(name)
    dense, keep = _integer_dense(3, (M, K), BLOCK, zero_block_rows=(2,))
    dense = _a_values(dense, name)
    present = _present_mask(keep, BLOCK)
    # tail padding past the real block count = padded invalid blocks
    a = BlockCSRMatrix.from_dense(
        jnp.asarray(dense), BLOCK, pad_to=int(keep.sum()) + 5
    )
    b = _b_panel(4, K, N, name)
    out = kernel_ops.bcsr_spmm(a, jnp.asarray(b), semiring_name=name)
    _assert_matches(name, out, _reference(sr, dense, present, b))


@pytest.mark.parametrize("name", ALL_NAMES)
def test_bsr_kernel_matches_semiring_matmul(name):
    sr = core_sr.get_semiring(name)
    dense, keep = _integer_dense(5, (M, K), BLOCK, zero_block_rows=(1,))
    dense = _a_values(dense, name)
    present = _present_mask(keep, BLOCK)
    # ELL: rows with fewer blocks than the max carry masked pad slots
    a = BlockSparseMatrix.from_dense(jnp.asarray(dense), BLOCK)
    assert a.block_mask.size > int(keep.sum())  # pad slots exist
    b = _b_panel(6, K, N, name)
    out = kernel_ops.bsr_spmm(a, jnp.asarray(b), semiring_name=name)
    _assert_matches(name, out, _reference(sr, dense, present, b))


@pytest.mark.parametrize("name", ALL_NAMES)
def test_empty_rows_produce_identity(name):
    """A block-row with no stored blocks is pure ⊕-identity output."""
    sr = core_sr.get_semiring(name)
    dense, keep = _integer_dense(7, (M, K), BLOCK, zero_block_rows=(0, 4))
    dense = _a_values(dense, name)
    b = _b_panel(8, K, N, name)
    a = BlockCSRMatrix.from_dense(jnp.asarray(dense), BLOCK)
    out = np.asarray(kernel_ops.bcsr_spmm(a, jnp.asarray(b), semiring_name=name))
    bs_r = BLOCK[0]
    for rb in (0, 4):
        expect = sr.add_reduce(
            jnp.full((N, 1), sr.zero, jnp.float32), axis=-1
        )  # reduce over an all-identity set == the identity
        row = out[rb * bs_r : (rb + 1) * bs_r]
        want = np.full_like(row, float(np.asarray(expect)[0]))
        np.testing.assert_array_equal(row, want)


@pytest.mark.parametrize("name", ALL_NAMES)
def test_dense_kernel_matches_semiring_matmul(name):
    sr = core_sr.get_semiring(name)
    rng = np.random.default_rng(11)
    a = rng.integers(-3, 4, size=(40, 24)).astype(np.float32)
    a = _a_values(a, name)
    b = _b_panel(12, 24, 16, name)
    out = kernel_ops.semiring_matmul(
        jnp.asarray(a), jnp.asarray(b), semiring_name=name
    )
    ref = sr.matmul(jnp.asarray(a), jnp.asarray(b))
    if ref.dtype == jnp.bool_:
        ref = ref.astype(jnp.float32)
    _assert_matches(name, out, ref)


def test_unknown_semiring_fails_fast():
    a = BlockSparseMatrix.random(jax.random.PRNGKey(0), (16, 16), (8, 8), 1)
    b = jnp.zeros((16, 8), jnp.float32)
    with pytest.raises(KeyError):
        kernel_ops.bsr_spmm(a, b, semiring_name="no_such_algebra")


# --- graphblas façade routing -------------------------------------------


def _ell(seed=0, m=64, bpr=3):
    return BlockSparseMatrix.random(
        jax.random.PRNGKey(seed), (m, m), (8, 8), blocks_per_row=bpr
    )


def test_mxm_sparse_launches_kernel_route():
    a = _ell()
    b = jnp.ones((64, 16), jnp.float32)
    kernel_jaxpr = str(jax.make_jaxpr(lambda y: gb.mxm(a, y))(b))
    oracle_jaxpr = str(
        jax.make_jaxpr(lambda y: gb.mxm(a, y, use_kernel=False))(b)
    )
    assert kernel_jaxpr.count("pallas_call") >= 1
    assert oracle_jaxpr.count("pallas_call") == 0


@pytest.mark.parametrize("name", ["plus_times", "min_plus", "lor_land"])
def test_mxm_kernel_route_matches_oracle(name):
    sr = core_sr.get_semiring(name)
    a = _ell(seed=2)
    a = BlockSparseMatrix(
        jnp.round(a.blocks * 3), a.col_idx, a.block_mask, a.shape,
        a.block_shape,
    )
    b = jnp.round(
        jax.random.uniform(jax.random.PRNGKey(3), (64, 16), jnp.float32) * 4
    )
    if name == "lor_land":
        b = (b > 1).astype(jnp.float32)
    out_k = gb.mxm(a, b, sr)
    out_o = gb.mxm(a, b, sr, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_o))


def test_mxm_plan_cache_semiring_aware():
    from repro.plan.mxm import mxm_cache_stats, reset_mxm_cache

    a = _ell(seed=4)
    b = jnp.ones((64, 16), jnp.float32)
    reset_mxm_cache()
    gb.mxm(a, b)  # build plus_times
    gb.mxm(a, b)  # hit
    gb.mxm(a, b, core_sr.MIN_PLUS)  # distinct key: new build, no collision
    s = mxm_cache_stats()
    assert s["builds"] == 2 and s["hits"] == 1, s
    reset_mxm_cache()


def test_mxm_under_jit_falls_back_to_oracle():
    """Tracer operands can't build plans — auto-route must not crash."""
    a = _ell(seed=5)
    b = jnp.ones((64, 8), jnp.float32)

    @jax.jit
    def f(blocks, y):
        w = BlockSparseMatrix(
            blocks, a.col_idx, a.block_mask, a.shape, a.block_shape
        )
        return gb.mxm(w, y)

    np.testing.assert_allclose(
        np.asarray(f(a.blocks, b)),
        np.asarray(gb.mxm(a, b)),
        rtol=1e-6,
    )
