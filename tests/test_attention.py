"""Attention-path equivalences: the §Perf L2 streaming (flash-style)
implementation must match the dense block path in values AND gradients,
for full-causal and sliding-window masks, across chunk shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def _qkv(key, b=2, t=96, h=4, hkv=2, d=16):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, t, h, d))
    k = jax.random.normal(kk, (b, t, hkv, d))
    v = jax.random.normal(kv, (b, t, hkv, d))
    return q, k, v


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("q_chunk,k_chunk", [(32, 16), (48, 32), (96, 96)])
def test_streaming_matches_block(window, q_chunk, k_chunk):
    q, k, v = _qkv(jax.random.key(0))
    t = q.shape[1]
    ref = A._attend(q, k, v, A.causal_mask(t, window), scale=0.25, q_chunk=t)
    out = A._attend_streaming(
        q, k, v, scale=0.25, window=window, q_chunk=q_chunk, k_chunk=k_chunk
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("window", [0, 32])
def test_streaming_gradients_match(window):
    q, k, v = _qkv(jax.random.key(1))
    t = q.shape[1]

    def f_ref(q, k, v):
        return A._attend(
            q, k, v, A.causal_mask(t, window), scale=0.25, q_chunk=t
        ).sum()

    def f_str(q, k, v):
        return A._attend_streaming(
            q, k, v, scale=0.25, window=window, q_chunk=32, k_chunk=16
        ).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_str = jax.grad(f_str, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_str):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4, atol=2e-4)


def test_dispatch_threshold():
    """attend_causal uses the block path at/below q_chunk, streaming above."""
    q, k, v = _qkv(jax.random.key(2), t=64)
    out_small = A.attend_causal(q, k, v, scale=0.25, q_chunk=64)
    out_stream = A._attend_streaming(q, k, v, scale=0.25, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(
        np.asarray(out_small), np.asarray(out_stream), rtol=3e-5, atol=3e-5
    )


def test_streaming_ragged_tail():
    """t not divisible by q_chunk exercises the ragged last block."""
    q, k, v = _qkv(jax.random.key(3), t=80)
    ref = A._attend(q, k, v, A.causal_mask(80), scale=0.25, q_chunk=80)
    out = A._attend_streaming(q, k, v, scale=0.25, q_chunk=32, k_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_rope_positions_decode_vs_prefill():
    """decode at position p must use the same rotation as prefill row p."""
    d = 32
    x = jax.random.normal(jax.random.key(4), (1, 8, 2, d))
    full = A.apply_rope(x, jnp.arange(8)[None, :], 10_000.0)
    one = A.apply_rope(
        x[:, 5:6], jnp.full((1, 1), 5, jnp.int32), 10_000.0
    )
    np.testing.assert_allclose(
        np.asarray(one[0, 0]), np.asarray(full[0, 5]), rtol=1e-5, atol=1e-6
    )
