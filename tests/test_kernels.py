"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels execute in ``interpret=True`` on CPU (the target is TPU; the
interpret path runs the identical kernel body).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.sparse import BlockSparseMatrix

SHAPES_DENSE = [
    (16, 16, 16),  # single tile (after auto block shrink)
    (128, 128, 64),
    (100, 70, 33),  # ragged → padding path
    (256, 128, 96),
    (32, 256, 8),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("m,k,n", SHAPES_DENSE)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_semiring_matmul_plus_times(m, k, n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * k * n))
    a = jax.random.normal(k1, (m, k), dtype)
    b = jax.random.normal(k2, (k, n), dtype)
    out = ops.semiring_matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.semiring_matmul_ref(a, b), np.float32),
        **_tol(dtype),
    )


@pytest.mark.parametrize("m,k,n", SHAPES_DENSE[:3])
@pytest.mark.parametrize(
    "semiring", ["max_plus", "min_plus", "max_min", "min_max"]
)
def test_semiring_matmul_vpu_semirings(m, k, n, semiring):
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a = jax.random.normal(k1, (m, k))
    b = jax.random.normal(k2, (k, n))
    out = ops.semiring_matmul(a, b, semiring_name=semiring)
    expected = ref.semiring_matmul_ref(a, b, semiring_name=semiring)
    np.testing.assert_allclose(out, expected, rtol=1e-6)


@pytest.mark.parametrize("m,k,n", SHAPES_DENSE[:4])
def test_semiring_matmul_fused_epilogue(m, k, n):
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    a = jax.random.normal(keys[0], (m, k))
    b = jax.random.normal(keys[1], (k, n))
    bias = jax.random.normal(keys[2], (m,))
    out = ops.semiring_matmul(a, b, bias, fuse_bias_relu=True)
    expected = ref.semiring_matmul_ref(a, b, bias=bias, fuse_bias_relu=True)
    np.testing.assert_allclose(out, expected, rtol=2e-5, atol=2e-5)
    assert float(out.min()) >= 0.0


BSR_CASES = [
    # (m, k, n, block, bpr)
    (64, 64, 32, (8, 8), 2),
    (128, 256, 48, (16, 16), 5),
    (128, 128, 128, (32, 32), 1),
    (256, 128, 100, (8, 16), 4),  # rectangular blocks + ragged n
]


@pytest.mark.parametrize("m,k,n,block,bpr", BSR_CASES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_bsr_spmm_plus_times(m, k, n, block, bpr, dtype):
    key = jax.random.PRNGKey(m + k + n)
    a = BlockSparseMatrix.random(key, (m, k), block, blocks_per_row=bpr).astype(
        dtype
    )
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = ops.bsr_spmm(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.bsr_spmm_ref(a, b), np.float32),
        **_tol(dtype),
    )


@pytest.mark.parametrize("m,k,n,block,bpr", BSR_CASES[:2])
def test_bsr_spmm_max_plus(m, k, n, block, bpr):
    key = jax.random.PRNGKey(3)
    a = BlockSparseMatrix.random(key, (m, k), block, blocks_per_row=bpr)
    b = jax.random.normal(jax.random.PRNGKey(4), (k, n))
    out = ops.bsr_spmm(a, b, semiring_name="max_plus")
    expected = ref.bsr_spmm_ref(a, b, semiring_name="max_plus")
    np.testing.assert_allclose(out, expected, rtol=1e-6)


@pytest.mark.parametrize("m,k,n,block,bpr", BSR_CASES)
def test_bsr_spmm_fused_epilogue(m, k, n, block, bpr):
    key = jax.random.PRNGKey(5)
    a = BlockSparseMatrix.random(key, (m, k), block, blocks_per_row=bpr)
    b = jax.random.normal(jax.random.PRNGKey(6), (k, n))
    bias = jax.random.normal(jax.random.PRNGKey(7), (m,))
    out = ops.bsr_spmm(a, b, bias, fuse_bias_relu=True)
    expected = ref.bsr_spmm_ref(a, b, bias=bias, fuse_bias_relu=True)
    np.testing.assert_allclose(out, expected, rtol=2e-5, atol=2e-5)


def test_bsr_spmm_skips_padding_blocks():
    """Padded ELL slots (mask=False) must not contribute."""
    key = jax.random.PRNGKey(8)
    a = BlockSparseMatrix.random(key, (32, 32), (8, 8), blocks_per_row=2)
    # Inflate padding: widen to 4 slots, 2 marked invalid with garbage data
    blocks = jnp.concatenate(
        [a.blocks, jnp.full((4, 2, 8, 8), 1e9)], axis=1
    )
    col_idx = jnp.concatenate([a.col_idx, jnp.zeros((4, 2), jnp.int32)], axis=1)
    mask = jnp.concatenate([a.block_mask, jnp.zeros((4, 2), bool)], axis=1)
    padded = BlockSparseMatrix(blocks, col_idx, mask, a.shape, a.block_shape)
    b = jax.random.normal(jax.random.PRNGKey(9), (32, 16))
    np.testing.assert_allclose(
        ops.bsr_spmm(padded, b), ops.bsr_spmm(a, b), rtol=1e-6
    )


def test_bsr_spmm_matches_dense_kernel():
    """Cross-kernel check: BSR result == dense kernel on densified W."""
    key = jax.random.PRNGKey(10)
    a = BlockSparseMatrix.random(key, (64, 64), (8, 8), blocks_per_row=3)
    b = jax.random.normal(jax.random.PRNGKey(11), (64, 32))
    np.testing.assert_allclose(
        ops.bsr_spmm(a, b),
        ops.semiring_matmul(a.to_dense(), b),
        rtol=2e-5,
        atol=2e-5,
    )
