"""GraphChallenge conformance suite (`docs/benchmarks.md`).

Ground truth is the pure-numpy gather reference in
``repro.data.radixnet``; every engine execution path — layered Pallas
plan, VMEM-resident fused kernel, multi-panel tiled fused kernel, the
streaming challenge driver, and the 8-device sharded engine — must
produce the SAME challenge answer set (bit-level category agreement) on
fixed-seed inputs. Small configs run in tier-1; the official challenge
shapes (1024×120, 4096×120) and the 16384-neuron fused-tiled config are
``slow``-marked.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.plan as P
from repro.core import dnn
from repro.data import radixnet as rx
from repro.kernels import ops as kernel_ops
from repro.serve import run_challenge


# ---------------------------------------------------------------------
# Generator invariants
# ---------------------------------------------------------------------


@pytest.mark.parametrize("neurons", [32, 64, 256, 1024, 2048])
def test_connectivity_invariants(neurons):
    for layer in range(rx.num_phases(neurons) + 1):
        conn = rx.radixnet_connectivity(neurons, layer)
        assert conn.shape == (neurons, rx.FAN_IN)
        assert conn.dtype == np.int32
        assert conn.min() >= 0 and conn.max() < neurons
        # exact fan-in 32: no duplicate edges on any row
        sorted_cols = np.sort(conn, axis=1)
        assert (np.diff(sorted_cols, axis=1) > 0).all(), (neurons, layer)
        # regularity: fan-out is exactly 32 everywhere too
        counts = np.bincount(conn.reshape(-1), minlength=neurons)
        assert (counts == rx.FAN_IN).all(), (neurons, layer)
        # a phase cycle repeats exactly
        again = rx.radixnet_connectivity(
            neurons, layer + rx.num_phases(neurons)
        )
        np.testing.assert_array_equal(conn, again)


def test_full_mixing_across_one_phase_cycle():
    # composing num_phases consecutive layers connects neuron 0 to all
    n = 1024
    reach = np.zeros(n, bool)
    reach[0] = True
    for layer in range(rx.num_phases(n)):
        conn = rx.radixnet_connectivity(n, layer)
        reach = reach[conn].any(axis=1)
    assert reach.all()


def test_spec_constants():
    spec = rx.RadixNetSpec(1024, 120)
    assert spec.bias == rx.CHALLENGE_BIAS[1024] == -0.3
    assert spec.edges == 120 * 1024 * 32
    assert rx.RadixNetSpec(4096, 120).bias == -0.35
    assert rx.challenge_bias(2048) == -0.3  # nearest smaller size
    with pytest.raises(ValueError):
        rx.RadixNetSpec(1000, 10)  # not a power of two
    with pytest.raises(ValueError):
        rx.RadixNetSpec(16, 10)  # below fan-in


def test_conn_to_bsr_is_exact():
    for n in (64, 256):
        for layer in range(rx.num_phases(n)):
            conn = rx.radixnet_connectivity(n, layer)
            mat = rx.conn_to_bsr(conn)
            dense = np.zeros((n, n), np.float32)
            dense[
                np.repeat(np.arange(n), rx.FAN_IN), conn.reshape(-1)
            ] = rx.WEIGHT_VALUE
            np.testing.assert_array_equal(
                np.asarray(mat.to_dense()), dense
            )


def test_weights_stack_is_homogeneous_and_fused_eligible():
    ws, bs = rx.radixnet_weights(rx.RadixNetSpec(256, 5))
    assert len({w.max_blocks_per_row for w in ws}) == 1
    assert P.fused_route(ws) is not None
    assert len(bs) == 5 and bs[0].shape == (256,)


def test_input_panel_is_seeded_and_sparse():
    a = rx.radixnet_input_panel(256, 40, density=0.3, seed=7)
    b = rx.radixnet_input_panel(256, 40, density=0.3, seed=7)
    c = rx.radixnet_input_panel(256, 40, density=0.3, seed=8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert set(np.unique(a)) <= {0.0, 1.0}
    assert 0.2 < a.mean() < 0.4


# ---------------------------------------------------------------------
# Conformance: every execution path reproduces the numpy ground truth
# ---------------------------------------------------------------------


def _legs_small(spec, y0):
    """(name, final activations) for every single-device execution path."""
    ws, bs = rx.radixnet_weights(spec)
    yj = jnp.asarray(y0)
    sw = dnn.stack_bsr(ws)
    sb = jnp.stack(bs)
    layered = P.build_plan(ws, bs, y0.shape[1], use_resident=False)
    resident = P.build_plan(ws, bs, y0.shape[1], use_resident=True)
    assert layered.route == P.ROUTE_LAYERED
    assert resident.route == P.ROUTE_FUSED
    return [
        ("layered-plan", layered.forward(yj)),
        ("fused-resident", resident.forward(yj)),
        ("fused-tiled", kernel_ops.fused_mlp_tiled_forward(sw, sb, yj)),
        ("xla", dnn.dnn_forward(ws, bs, yj, fused=True)),
    ]


@pytest.mark.parametrize(
    "neurons,layers", [(64, 4), (256, 7)], ids=["64x4", "256x7"]
)
def test_conformance_small(neurons, layers):
    spec = rx.RadixNetSpec(neurons, layers)
    y0 = rx.radixnet_input_panel(neurons, 24, density=0.3, seed=11)
    ref_y, ref_cats = rx.radixnet_reference(spec, y0)
    for name, out in _legs_small(spec, y0):
        out = np.asarray(out)
        np.testing.assert_allclose(
            out, ref_y, rtol=1e-4, atol=1e-6, err_msg=name
        )
        got = rx.reference_categories(out)
        assert np.array_equal(got, ref_cats), (name, got, ref_cats)


def test_challenge_driver_small():
    spec = rx.RadixNetSpec(256, 6)
    _, ref_cats = rx.radixnet_reference(
        spec, rx.radixnet_input_panel(256, 50, density=0.3, seed=5)
    )
    res = run_challenge(
        spec, n_inputs=50, panel_width=24, batch_align=8, seed=5
    )
    assert np.array_equal(res.categories, ref_cats)
    assert res.served == 50
    assert res.steps == 3  # ceil(50 / 24) width-classed panels
    assert res.width_classes == (24,)  # one compiled class, incl. tail
    assert res.routes == ("fused",)
    assert res.levels == ("resident",)
    assert res.edges == spec.edges
    assert res.edge_inputs_per_sec > 0
    assert res.grid_steps > 0


def test_challenge_driver_layered_and_failure():
    spec = rx.RadixNetSpec(64, 3)
    _, ref_cats = rx.radixnet_reference(
        spec, rx.radixnet_input_panel(64, 20, density=0.3, seed=5)
    )
    res = run_challenge(
        spec,
        n_inputs=20,
        panel_width=16,
        batch_align=8,
        seed=5,
        use_resident=False,
    )
    assert res.routes == ("layered",)
    assert np.array_equal(res.categories, ref_cats)


# ---------------------------------------------------------------------
# The official challenge shapes (slow: interpret-mode kernels)
# ---------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize(
    "neurons,layers,density",
    [(1024, 120, 0.3), (4096, 120, 0.35)],
    ids=["1024x120", "4096x120"],
)
def test_conformance_challenge_config(neurons, layers, density):
    """Bit-level category agreement on GraphChallenge-scale stacks.

    The density per size keeps the un-clamped dynamics nondegenerate
    (see docs/benchmarks.md — this repo deliberately omits the official
    YMAX clamp): activations stay finite and the answer set is a strict,
    nonempty subset of the inputs.
    """
    spec = rx.RadixNetSpec(neurons, layers)
    y0 = rx.radixnet_input_panel(neurons, 32, density=density, seed=0)
    ref_y, ref_cats = rx.radixnet_reference(spec, y0)
    assert 0 < len(ref_cats) < 32  # nondegenerate ground truth
    assert np.isfinite(ref_y).all()

    ws, bs = rx.radixnet_weights(spec)
    yj = jnp.asarray(y0)
    tiled = np.asarray(
        kernel_ops.fused_mlp_tiled_forward(
            dnn.stack_bsr(ws), jnp.stack(bs), yj
        )
    )
    xla = np.asarray(dnn.dnn_forward(ws, bs, yj, fused=True))
    assert np.array_equal(rx.reference_categories(tiled), ref_cats)
    assert np.array_equal(rx.reference_categories(xla), ref_cats)
    # layer-1 exactness: {0,1} inputs × the dyadic 1/16 weight make the
    # first layer bit-exact in f32 under ANY summation order
    conn0 = rx.radixnet_connectivity(neurons, 0)
    l1 = rx.reference_forward([conn0], [spec.bias], y0)
    l1_x = np.asarray(
        dnn.dnn_forward(ws[:1], bs[:1], yj, fused=True)
    )
    np.testing.assert_array_equal(l1, l1_x)


@pytest.mark.slow
def test_challenge_engine_routes_fused_tiled_past_vmem_budget():
    """A 16384-neuron stack is past ``VMEM_SOFT_LIMIT_BYTES`` — the
    engine must auto-route it through the multi-panel tiled kernel and
    still reproduce the ground-truth categories."""
    spec = rx.RadixNetSpec(16384, 6)
    assert spec.bias == -0.4
    y0 = rx.radixnet_input_panel(16384, 48, density=0.4, seed=2)
    _, ref_cats = rx.radixnet_reference(spec, y0)
    assert 0 < len(ref_cats) < 48
    res = run_challenge(
        spec, n_inputs=48, panel_width=24, batch_align=8,
        density=0.4, seed=2,
    )
    assert res.routes == ("fused-tiled",)
    assert res.levels == ("resident",)
    assert np.array_equal(res.categories, ref_cats)


# ---------------------------------------------------------------------
# 8-device sharded leg
# ---------------------------------------------------------------------

_SHARDED_BODY = textwrap.dedent(
    """
    import numpy as np
    import jax
    jax.config.update("jax_default_matmul_precision", "highest")

    from repro.data import radixnet as rx
    from repro.launch.mesh import make_row_blocks_mesh
    from repro.serve import run_challenge

    assert len(jax.devices()) >= 8, jax.devices()
    spec = rx.RadixNetSpec(256, 7)
    y0 = rx.radixnet_input_panel(256, 40, density=0.3, seed=9)
    _, ref_cats = rx.radixnet_reference(spec, y0)
    assert 0 < len(ref_cats) < 40
    res = run_challenge(
        spec, n_inputs=40, panel_width=16, batch_align=8, seed=9,
        mesh=make_row_blocks_mesh(8),
    )
    assert res.routes == ("sharded",), res.routes
    assert res.levels == ("sharded",), res.levels
    assert np.array_equal(res.categories, ref_cats), (
        res.categories, ref_cats)
    print("challenge-sharded8 OK")
    """
)


@pytest.mark.slow
def test_challenge_sharded_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    body = (
        "import os\n"
        'os.environ["XLA_FLAGS"] = '
        '"--xla_force_host_platform_device_count=8"\n' + _SHARDED_BODY
    )
    r = subprocess.run(
        [sys.executable, "-c", body],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "challenge-sharded8 OK" in r.stdout, r.stdout


@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(the CI multi-device job sets it; tier-1 runs the subprocess "
    "variant instead)",
)
def test_challenge_sharded_inprocess(capsys):
    exec(compile(_SHARDED_BODY, "<challenge-sharded>", "exec"), {})
    assert "challenge-sharded8 OK" in capsys.readouterr().out
