"""BlockSparseMatrix structure, conversions, and properties."""

import hypothesis
import hypothesis.strategies as st
import jax
import numpy as np
import pytest

from repro.core.semiring import MAX_PLUS
from repro.sparse import BlockSparseMatrix, ops as sops


def test_roundtrip_from_dense():
    rng = np.random.default_rng(0)
    dense = rng.normal(size=(32, 48)).astype(np.float32)
    dense[8:16, :] = 0.0  # empty block-row must still work
    dense[:, 40:48] = 0.0
    bsr = BlockSparseMatrix.from_dense(dense, (8, 8))
    np.testing.assert_array_equal(bsr.to_dense(), dense)


def test_from_dense_rejects_indivisible():
    with pytest.raises(ValueError):
        BlockSparseMatrix.from_dense(np.ones((10, 10)), (8, 8))


def test_random_structure():
    key = jax.random.PRNGKey(0)
    bsr = BlockSparseMatrix.random(key, (64, 128), (8, 16), blocks_per_row=3)
    assert bsr.blocks.shape == (8, 3, 8, 16)
    assert int(bsr.nnz_blocks) == 8 * 3
    # indices sorted + unique per row
    ci = np.asarray(bsr.col_idx)
    for row in ci:
        assert len(set(row.tolist())) == len(row)
        assert (np.sort(row) == row).all()
    assert float(bsr.block_density) == pytest.approx(3 / 8)


def test_values_distribution_matches_paper():
    """Paper §V-B: weights ~ U[-1, 3)."""
    key = jax.random.PRNGKey(1)
    bsr = BlockSparseMatrix.random(key, (256, 256), (8, 8), blocks_per_row=16)
    vals = np.asarray(bsr.blocks).ravel()
    assert vals.min() >= -1.0 and vals.max() < 3.0
    assert abs(vals.mean() - 1.0) < 0.05


def test_nbytes_scales_with_nnz():
    key = jax.random.PRNGKey(2)
    sparse = BlockSparseMatrix.random(key, (512, 512), (8, 8), blocks_per_row=2)
    denser = BlockSparseMatrix.random(key, (512, 512), (8, 8), blocks_per_row=32)
    assert sparse.nbytes < denser.nbytes
    assert denser.nbytes < denser.dense_nbytes * 1.1  # index overhead small


def test_matmul_matches_dense():
    key = jax.random.PRNGKey(3)
    bsr = BlockSparseMatrix.random(key, (64, 96), (8, 8), blocks_per_row=4)
    y = jax.random.normal(jax.random.PRNGKey(4), (96, 10))
    np.testing.assert_allclose(
        sops.bsr_matmul(bsr, y), bsr.to_dense() @ y, rtol=1e-4, atol=1e-5
    )


def test_matmul_max_plus_masked_semantics():
    """Missing blocks are -inf (no edge), NOT zero, under max-plus."""
    key = jax.random.PRNGKey(5)
    bsr = BlockSparseMatrix.random(key, (32, 32), (8, 8), blocks_per_row=1)
    y = jax.random.normal(jax.random.PRNGKey(6), (32, 4))
    out = sops.bsr_matmul(bsr, y, MAX_PLUS)
    dense = np.asarray(bsr.to_dense())
    # build masked dense: -inf where no stored block
    mask = np.zeros((4, 4), bool)
    ci = np.asarray(bsr.col_idx)
    for i in range(4):
        mask[i, ci[i]] = True
    full_mask = np.repeat(np.repeat(mask, 8, 0), 8, 1)
    masked = np.where(full_mask, dense, -np.inf)
    ref = np.max(masked[:, :, None] + np.asarray(y)[None], axis=1)
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_map_blocks_keeps_topology():
    key = jax.random.PRNGKey(7)
    bsr = BlockSparseMatrix.random(key, (32, 32), (8, 8), blocks_per_row=2)
    doubled = bsr.map_blocks(lambda b: b * 2)
    np.testing.assert_allclose(
        doubled.to_dense(), bsr.to_dense() * 2, rtol=1e-6
    )


def test_pytree_roundtrip():
    key = jax.random.PRNGKey(8)
    bsr = BlockSparseMatrix.random(key, (16, 16), (8, 8), blocks_per_row=1)
    leaves, treedef = jax.tree_util.tree_flatten(bsr)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.shape == bsr.shape
    np.testing.assert_array_equal(rebuilt.to_dense(), bsr.to_dense())


def test_jit_through_bsr():
    key = jax.random.PRNGKey(9)
    bsr = BlockSparseMatrix.random(key, (32, 32), (8, 8), blocks_per_row=2)
    y = jax.random.normal(jax.random.PRNGKey(10), (32, 4))

    @jax.jit
    def f(a, b):
        return sops.bsr_matmul(a, b)

    np.testing.assert_allclose(f(bsr, y), sops.bsr_matmul(bsr, y), rtol=1e-6)


def test_transpose_matches_dense():
    key = jax.random.PRNGKey(20)
    bsr = BlockSparseMatrix.random(key, (64, 96), (8, 16), blocks_per_row=3)
    t = bsr.transpose()
    assert t.shape == (96, 64)
    assert t.block_shape == (16, 8)
    np.testing.assert_array_equal(
        np.asarray(t.to_dense()), np.asarray(bsr.to_dense()).T
    )


def test_transpose_skewed_and_empty_columns():
    # column-block occupancy 3/2/1/0 → transposed rows 3/2/1/0 wide
    pattern = np.array(
        [[1.0, 1, 0, 0], [1, 0, 1, 0], [1, 1, 1, 0], [0, 0, 0, 0]]
    )
    dense = np.kron(pattern, np.ones((8, 8), np.float32))
    bsr = BlockSparseMatrix.from_dense(dense, (8, 8))
    t = bsr.transpose()
    np.testing.assert_array_equal(np.asarray(t.to_dense()), dense.T)
    assert t.max_blocks_per_row == 3


def test_transpose_is_jittable_and_involutive():
    key = jax.random.PRNGKey(21)
    bsr = BlockSparseMatrix.random(key, (64, 64), (8, 8), blocks_per_row=3)

    # device-side + jittable given a static output pad width
    t = jax.jit(lambda a: a.transpose(pad_to=8))(bsr)
    np.testing.assert_array_equal(
        np.asarray(t.to_dense()), np.asarray(bsr.to_dense()).T
    )
    # transpose ∘ transpose = identity (on the dense view)
    np.testing.assert_array_equal(
        np.asarray(t.transpose().to_dense()), np.asarray(bsr.to_dense())
    )


def test_transpose_rejects_small_pad():
    key = jax.random.PRNGKey(22)
    bsr = BlockSparseMatrix.random(key, (64, 64), (8, 8), blocks_per_row=4)
    with pytest.raises(ValueError):
        bsr.transpose(pad_to=1)


@hypothesis.given(
    nrb=st.integers(1, 4),
    ncb=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
@hypothesis.settings(deadline=None, max_examples=25)
def test_roundtrip_property(nrb, ncb, seed, data):
    """from_dense(to_dense(x)) == x for any block structure."""
    bpr = data.draw(st.integers(1, ncb))
    key = jax.random.PRNGKey(seed)
    bsr = BlockSparseMatrix.random(
        key, (8 * nrb, 8 * ncb), (8, 8), blocks_per_row=bpr
    )
    dense = np.asarray(bsr.to_dense())
    rebuilt = BlockSparseMatrix.from_dense(dense, (8, 8))
    np.testing.assert_array_equal(rebuilt.to_dense(), dense)
    # storage really is ∝ stored blocks
    assert bsr.blocks.size == nrb * bpr * 64
