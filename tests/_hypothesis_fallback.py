"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite uses a small, fixed subset of the hypothesis API
(``given``/``settings``, ``strategies.integers/floats/sampled_from/
lists/data``, ``strategy.map`` and ``extra.numpy.arrays``). When the real
library is available it is always preferred (see ``conftest.py``); this
module only exists so the property tests still *run* — with seeded
pseudo-random example draws instead of hypothesis' guided search — in
environments where ``pip install hypothesis`` is not possible.

Differences from real hypothesis (intentional, documented):
  * examples are drawn from a PRNG seeded by the test name — fully
    deterministic across runs, no shrinking, no example database;
  * ``max_examples`` is honoured, every other ``settings`` knob is a
    no-op;
  * failures report the drawn arguments in the assertion chain (the
    wrapped call re-raises with the draw appended) rather than a
    minimised counterexample.
"""

from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """A draw recipe: ``sample(rng)`` produces one example."""

    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)

    def map(self, fn) -> "Strategy":
        return Strategy(lambda rng: fn(self._sample(rng)))


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float) -> Strategy:
    def _draw(rng):
        # Bias toward the endpoints — the classic property-test edge cases.
        r = rng.random()
        if r < 0.1:
            return float(min_value)
        if r < 0.2:
            return float(max_value)
        return float(rng.uniform(min_value, max_value))

    return Strategy(_draw)


def sampled_from(options) -> Strategy:
    options = list(options)
    return Strategy(lambda rng: options[int(rng.integers(len(options)))])


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def _draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(size)]

    return Strategy(_draw)


class DataObject:
    """Interactive draws inside the test body (``st.data()``)."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: Strategy, label: str | None = None):
        return strategy.sample(self._rng)


def data() -> Strategy:
    return Strategy(lambda rng: DataObject(rng))


def _np_arrays(dtype, shape, *, elements: Strategy) -> Strategy:
    """``hypothesis.extra.numpy.arrays`` subset: fixed shape + elements."""
    shape = (shape,) if isinstance(shape, int) else tuple(shape)
    size = int(np.prod(shape)) if shape else 1

    def _draw(rng):
        flat = [elements.sample(rng) for _ in range(size)]
        return np.asarray(flat, dtype=dtype).reshape(shape)

    return Strategy(_draw)


def settings(*args, max_examples: int = _DEFAULT_MAX_EXAMPLES, **kwargs):
    """Decorator recording ``max_examples``; other knobs are no-ops."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    # bare ``@settings`` (not used in this repo, but harmless)
    if args and callable(args[0]):
        return deco(args[0])
    return deco


def given(**strategies):
    """Run the test once per drawn example, deterministically seeded."""

    def deco(fn):
        max_examples = getattr(
            fn, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES
        )

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed0 = zlib.crc32(fn.__qualname__.encode())
            for i in range(max_examples):
                rng = np.random.default_rng((seed0, i))
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    shown = {
                        k: v
                        for k, v in drawn.items()
                        if not isinstance(v, DataObject)
                    }
                    raise AssertionError(
                        f"falsifying example (fallback draw {i}): {shown!r}"
                    ) from e

        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # pytest must not see the drawn parameters as fixtures: present
        # the wrapper with the original signature minus the given() names.
        sig = inspect.signature(fn)
        remaining = [
            p for name, p in sig.parameters.items() if name not in strategies
        ]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        del wrapper.__wrapped__
        return wrapper

    return deco


def install() -> None:
    """Register fake ``hypothesis`` / ``hypothesis.strategies`` /
    ``hypothesis.extra.numpy`` modules in ``sys.modules`` so the test
    modules' top-level imports resolve against this shim."""
    import sys

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__is_fallback__ = True

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "lists", "data"):
        setattr(st_mod, name, globals()[name])
    st_mod.Strategy = Strategy

    extra_mod = types.ModuleType("hypothesis.extra")
    hnp_mod = types.ModuleType("hypothesis.extra.numpy")
    hnp_mod.arrays = _np_arrays

    hyp.strategies = st_mod
    hyp.extra = extra_mod
    extra_mod.numpy = hnp_mod

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
    sys.modules["hypothesis.extra"] = extra_mod
    sys.modules["hypothesis.extra.numpy"] = hnp_mod
