"""Gradient coverage for the sparse kernel layer (custom VJPs).

Checks, per layout (ELL-BSR and block-CSR), in interpret mode:
  * jax.grad through the kernel wrappers == dense jax.grad reference;
  * finite-difference validation (jax.test_util.check_grads, rev mode);
  * the weight cotangent's sparsity pattern equals the primal's
    (padded/invalid slots exactly zero — the no-densify invariant);
  * grad through models.layers.linear matches dense to 1e-4;
  * the sparse train step decreases loss with kernels in the hot path;
  * the fused resident kernel refuses differentiation and the serve
    engine routes/rejects accordingly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.core import dnn
from repro.kernels import ops
from repro.models import layers
from repro.sparse import BlockCSRMatrix, BlockSparseMatrix
from repro.sparse import ops as sparse_ops


def _random_bsr(key, shape, block, bpr, scale=0.3):
    a = BlockSparseMatrix.random(key, shape, block, blocks_per_row=bpr)
    return a.map_blocks(lambda x: x * scale)


def _skewed_bcsr(m, k, block):
    """Block-CSR with an empty block-row AND invalid tail padding — the
    two structural edge cases of the layout."""
    nrb, ncb = m // block, k // block
    dense = np.zeros((m, k), np.float32)
    rng = np.random.default_rng(0)
    for i in range(nrb):
        if i == 1:
            continue  # empty block-row
        cols = rng.choice(ncb, size=min(2 + (i % 2), ncb), replace=False)
        for c in cols:
            dense[i * block:(i + 1) * block, c * block:(c + 1) * block] = (
                rng.uniform(-0.5, 0.5, (block, block))
            )
    c = BlockCSRMatrix.from_dense(jnp.asarray(dense), (block, block))
    return BlockCSRMatrix.from_dense(
        jnp.asarray(dense), (block, block), pad_to=c.total_blocks + 3
    )


BSR_GRAD_CASES = [
    (32, 48, (8, 8), 2),
    (32, 64, (8, 16), 3),  # rectangular blocks
]


@pytest.mark.parametrize("m,k,block,bpr", BSR_GRAD_CASES)
@pytest.mark.parametrize("fused", [False, True], ids=["plain", "fused_relu"])
def test_bsr_spmm_grad_matches_dense(m, k, block, bpr, fused):
    a = _random_bsr(jax.random.PRNGKey(m + k), (m, k), block, bpr)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, 20))
    bias = jax.random.normal(jax.random.PRNGKey(2), (m,))

    def loss_kernel(blocks, b_, bias_):
        aa = BlockSparseMatrix(blocks, a.col_idx, a.block_mask, a.shape, a.block_shape)
        out = ops.bsr_spmm(aa, b_, bias_ if fused else None, fuse_bias_relu=fused)
        return jnp.sum(jnp.sin(out))

    def loss_dense(blocks, b_, bias_):
        aa = BlockSparseMatrix(blocks, a.col_idx, a.block_mask, a.shape, a.block_shape)
        z = aa.to_dense() @ b_
        if fused:
            z = jnp.maximum(z + bias_[:, None], 0.0)
        return jnp.sum(jnp.sin(z))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(a.blocks, b, bias)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(a.blocks, b, bias)
    for got, want in zip(gk, gd):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fused", [False, True], ids=["plain", "fused_relu"])
def test_bcsr_spmm_grad_matches_dense(fused):
    c = _skewed_bcsr(48, 32, 8)
    b = jax.random.normal(jax.random.PRNGKey(3), (32, 24))
    bias = jax.random.normal(jax.random.PRNGKey(4), (48,))

    def loss_kernel(values, b_, bias_):
        cc = BlockCSRMatrix(
            values, c.row_ptr, c.row_id, c.col_idx, c.valid, c.shape, c.block_shape
        )
        out = ops.bcsr_spmm(cc, b_, bias_ if fused else None, fuse_bias_relu=fused)
        return jnp.sum(jnp.cos(out))

    def loss_dense(values, b_, bias_):
        cc = BlockCSRMatrix(
            values, c.row_ptr, c.row_id, c.col_idx, c.valid, c.shape, c.block_shape
        )
        z = cc.to_dense() @ b_
        if fused:
            z = jnp.maximum(z + bias_[:, None], 0.0)
        return jnp.sum(jnp.cos(z))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(c.values, b, bias)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(c.values, b, bias)
    for got, want in zip(gk, gd):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bsr_spmm_finite_differences():
    a = _random_bsr(jax.random.PRNGKey(5), (16, 16), (8, 8), 2)
    b = jax.random.normal(jax.random.PRNGKey(6), (16, 8))

    def f(blocks, b_):
        aa = BlockSparseMatrix(blocks, a.col_idx, a.block_mask, a.shape, a.block_shape)
        return ops.bsr_spmm(aa, b_)

    check_grads(f, (a.blocks, b), order=1, modes=["rev"], atol=1e-2, rtol=1e-2)


def test_bcsr_spmm_finite_differences():
    c = _skewed_bcsr(16, 16, 8)
    b = jax.random.normal(jax.random.PRNGKey(7), (16, 8))

    def f(values, b_):
        cc = BlockCSRMatrix(
            values, c.row_ptr, c.row_id, c.col_idx, c.valid, c.shape, c.block_shape
        )
        return ops.bcsr_spmm(cc, b_)

    check_grads(f, (c.values, b), order=1, modes=["rev"], atol=1e-2, rtol=1e-2)


def test_weight_cotangent_pattern_equals_primal():
    """Regression: the gradient lives EXACTLY in the primal's pattern."""
    # ELL with widened padding (garbage-free invalid slots)
    a = _random_bsr(jax.random.PRNGKey(8), (32, 32), (8, 8), 2)
    wide = BlockSparseMatrix.from_dense(a.to_dense(), (8, 8), pad_to=4)
    assert not bool(wide.block_mask.all())
    b = jax.random.normal(jax.random.PRNGKey(9), (32, 12))

    g = jax.grad(
        lambda aa: jnp.sum(ops.bsr_spmm(aa, b) ** 2), allow_int=True
    )(wide)
    assert isinstance(g, BlockSparseMatrix)
    off_pattern = jnp.where(wide.block_mask[:, :, None, None], 0.0, g.blocks)
    assert float(jnp.abs(off_pattern).max()) == 0.0
    on_pattern = jnp.where(wide.block_mask[:, :, None, None], g.blocks, 0.0)
    assert float(jnp.abs(on_pattern).max()) > 0.0

    # block-CSR with invalid tail slots
    c = _skewed_bcsr(32, 32, 8)
    assert not bool(c.valid.all())
    gc = jax.grad(
        lambda cc: jnp.sum(ops.bcsr_spmm(cc, b) ** 2), allow_int=True
    )(c)
    assert isinstance(gc, BlockCSRMatrix)
    assert float(jnp.abs(jnp.where(c.valid[:, None, None], 0.0, gc.values)).max()) == 0.0
    assert float(jnp.abs(gc.values).max()) > 0.0
    # integer topology leaves come back as float0 (frozen under training)
    assert gc.col_idx.dtype == jax.dtypes.float0


def test_transpose_matmul_helpers_match_dense():
    a = _random_bsr(jax.random.PRNGKey(10), (32, 48), (8, 8), 3)
    y = jax.random.normal(jax.random.PRNGKey(11), (32, 10))
    np.testing.assert_allclose(
        sparse_ops.bsr_transpose_matmul(a, y),
        a.to_dense().T @ y,
        rtol=1e-5,
        atol=1e-5,
    )
    c = _skewed_bcsr(48, 32, 8)
    yc = jax.random.normal(jax.random.PRNGKey(12), (48, 10))
    np.testing.assert_allclose(
        sparse_ops.bcsr_transpose_matmul(c, yc),
        c.to_dense().T @ yc,
        rtol=1e-5,
        atol=1e-5,
    )


@pytest.mark.parametrize("use_kernel", [True, False], ids=["pallas", "xla"])
def test_linear_grad_bcsr_matches_dense(use_kernel):
    """Acceptance: jax.grad through linear() on a BCSR weight == dense
    reference to 1e-4, with no dense weight materialized in the path."""
    c = _skewed_bcsr(32, 48, 8)  # (d_out, d_in) output-major
    x = jax.random.normal(jax.random.PRNGKey(13), (5, 48))
    bias = jax.random.normal(jax.random.PRNGKey(14), (32,))
    w_dense = c.to_dense()  # test-only reference

    def loss_sparse(values, x_, bias_):
        cc = BlockCSRMatrix(
            values, c.row_ptr, c.row_id, c.col_idx, c.valid, c.shape, c.block_shape
        )
        return jnp.sum(layers.linear(cc, x_, bias_, use_kernel=use_kernel) ** 2)

    def loss_dense(w, x_, bias_):
        return jnp.sum((x_ @ w.T + bias_) ** 2)

    gs = jax.grad(loss_sparse, argnums=(0, 1, 2))(c.values, x, bias)
    gd = jax.grad(loss_dense, argnums=(1, 2))(w_dense, x, bias)
    np.testing.assert_allclose(gs[1], gd[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gs[2], gd[1], rtol=1e-4, atol=1e-4)
    # weight cotangent: compare against dense dW sampled at stored blocks
    dw_dense = jax.grad(lambda w: jnp.sum((x @ w.T + bias) ** 2))(w_dense)
    bs = c.block_shape[0]
    tiles = dw_dense.reshape(32 // bs, bs, 48 // bs, bs).transpose(0, 2, 1, 3)
    want = jnp.where(
        c.valid[:, None, None], tiles[c.row_id, c.col_idx], 0.0
    )
    np.testing.assert_allclose(gs[0], want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("use_kernel", [True, False], ids=["pallas", "xla"])
def test_linear_grad_bsr_matches_dense(use_kernel):
    a = _random_bsr(jax.random.PRNGKey(15), (32, 48), (8, 8), 2)
    x = jax.random.normal(jax.random.PRNGKey(16), (3, 48))

    def loss_sparse(blocks, x_):
        aa = BlockSparseMatrix(blocks, a.col_idx, a.block_mask, a.shape, a.block_shape)
        return jnp.sum(layers.linear(aa, x_, use_kernel=use_kernel) ** 2)

    def loss_dense(blocks, x_):
        aa = BlockSparseMatrix(blocks, a.col_idx, a.block_mask, a.shape, a.block_shape)
        return jnp.sum((x_ @ aa.to_dense().T) ** 2)

    gs = jax.grad(loss_sparse, argnums=(0, 1))(a.blocks, x)
    gd = jax.grad(loss_dense, argnums=(0, 1))(a.blocks, x)
    np.testing.assert_allclose(gs[0], gd[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gs[1], gd[1], rtol=1e-4, atol=1e-4)


def test_sparse_train_step_decreases_loss():
    from repro.train.optimizer import sgd
    from repro.train.sparse import (
        grad_sparsity_preserved,
        init_sparse_mlp_state,
        make_sparse_train_step,
    )

    m, n = 32, 16
    ws = [
        _random_bsr(jax.random.PRNGKey(20), (m, m), (8, 8), 2),
        BlockCSRMatrix.from_bsr(_random_bsr(jax.random.PRNGKey(21), (m, m), (8, 8), 2)),
    ]
    bs = [jnp.zeros((m,)) for _ in ws]
    y0 = jax.random.uniform(jax.random.PRNGKey(22), (m, n))
    targets = jax.random.uniform(jax.random.PRNGKey(23), (m, n))
    batch = {"y0": y0, "targets": targets}

    opt = sgd(1.0, momentum=0.0)
    state = init_sparse_mlp_state(ws, bs, opt)
    step = jax.jit(make_sparse_train_step(opt, use_kernel=True))
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    # topology untouched by training
    assert isinstance(state.weights[0], BlockSparseMatrix)
    assert isinstance(state.weights[1], BlockCSRMatrix)
    np.testing.assert_array_equal(state.weights[0].col_idx, ws[0].col_idx)
    np.testing.assert_array_equal(state.weights[1].row_id, ws[1].row_id)

    # and the cotangents live in the primal pattern
    _, grads = jax.value_and_grad(
        lambda p: 0.5
        * jnp.mean(
            (dnn.dnn_forward_trainable(p[0], p[1], y0) - targets) ** 2
        ),
        allow_int=True,
    )((state.weights, state.biases))
    assert grad_sparsity_preserved(state.weights, grads[0])


def test_dnn_value_and_grad():
    m, n = 32, 8
    ws = [_random_bsr(jax.random.PRNGKey(30), (m, m), (8, 8), 2)]
    bs = [jnp.zeros((m,))]
    y0 = jax.random.uniform(jax.random.PRNGKey(31), (m, n))
    targets = jnp.zeros((m, n))
    loss, (dws, dbs) = dnn.dnn_value_and_grad(ws, bs, y0, targets)
    assert float(loss) >= 0.0
    assert isinstance(dws[0], BlockSparseMatrix)
    assert dbs[0].shape == (m,)
    # matches the XLA-oracle gradient path
    loss2, (dws2, dbs2) = dnn.dnn_value_and_grad(
        ws, bs, y0, targets, use_kernel=False
    )
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-5)
    np.testing.assert_allclose(dws[0].blocks, dws2[0].blocks, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dbs[0], dbs2[0], rtol=1e-4, atol=1e-5)


def test_fused_mlp_grad_raises():
    ws = [_random_bsr(jax.random.PRNGKey(40), (32, 32), (8, 8), 2) for _ in range(2)]
    stacked = dnn.stack_bsr(ws)
    sb = jnp.zeros((2, 32))
    y0 = jax.random.uniform(jax.random.PRNGKey(41), (32, 16))
    with pytest.raises(NotImplementedError, match="layered"):
        jax.grad(lambda y: jnp.sum(ops.fused_mlp_forward(stacked, sb, y)))(y0)


def test_serve_engine_differentiable_routing():
    from repro.serve.engine import SparseDNNEngine

    ws = [_random_bsr(jax.random.PRNGKey(50), (32, 32), (8, 8), 2) for _ in range(2)]
    bs = [jnp.zeros((32,)) for _ in ws]
    # resident-eligible stack: differentiable engine must bypass the
    # fused path...
    assert dnn.resident_eligible(ws)
    eng = SparseDNNEngine(ws, bs, batch_align=8, differentiable=True)
    out, stats = eng.infer(jax.random.uniform(jax.random.PRNGKey(51), (32, 4)))
    assert stats["resident"] is False
    assert stats["differentiable"] is True
    assert out.shape == (32, 4)
    # ...and explicit use_resident=True must be rejected.
    with pytest.raises(ValueError, match="no VJP"):
        SparseDNNEngine(ws, bs, use_resident=True, differentiable=True)


def test_serve_engine_differentiable_with_dense_layer():
    """Regression: a dense layer in a differentiable engine must route
    through the XLA fused form (the dense Pallas kernel has no VJP)."""
    from repro.serve.engine import SparseDNNEngine

    ws = [
        _random_bsr(jax.random.PRNGKey(60), (32, 32), (8, 8), 2),
        jax.random.normal(jax.random.PRNGKey(61), (32, 32)) * 0.1,
    ]
    bs = [jnp.zeros((32,)) for _ in ws]
    eng = SparseDNNEngine(ws, bs, batch_align=4, differentiable=True)
    y0 = jax.random.uniform(jax.random.PRNGKey(62), (32, 4))
    g = jax.grad(lambda y: jnp.sum(eng.infer(y)[0]))(y0)
    assert g.shape == y0.shape
    assert float(jnp.abs(g).max()) > 0.0
