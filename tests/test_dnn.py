"""The paper's ReLU DNN (§III/§IV): faithful vs fused vs sparse paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dnn
from repro.sparse import BlockSparseMatrix


def _mk_net(key, L, m, sparse=False, bpr=2):
    keys = jax.random.split(key, 2 * L)
    ws, bs = [], []
    for k in range(L):
        if sparse:
            ws.append(
                BlockSparseMatrix.random(
                    keys[2 * k], (m, m), (8, 8), blocks_per_row=bpr
                )
            )
        else:
            ws.append(
                jax.random.uniform(
                    keys[2 * k], (m, m), minval=-1.0, maxval=3.0
                )
            )
        bs.append(jax.random.uniform(keys[2 * k + 1], (m,)))
    return ws, bs


def _numpy_forward(ws, bs, y0):
    y = np.asarray(y0)
    for w, b in zip(ws, bs):
        wd = np.asarray(w.to_dense() if hasattr(w, "to_dense") else w)
        y = np.maximum(wd @ y + np.asarray(b)[:, None], 0.0)
    return y


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "bsr"])
@pytest.mark.parametrize("fused", [False, True], ids=["faithful", "fused"])
def test_forward_matches_numpy(sparse, fused):
    key = jax.random.PRNGKey(0)
    ws, bs = _mk_net(key, L=3, m=32, sparse=sparse)
    y0 = jax.random.uniform(jax.random.PRNGKey(1), (32, 8))
    out = dnn.dnn_forward(ws, bs, y0, fused=fused)
    np.testing.assert_allclose(
        out, _numpy_forward(ws, bs, y0), rtol=1e-4, atol=1e-4
    )


def test_faithful_equals_fused():
    """The fused beyond-paper path must be numerically identical."""
    key = jax.random.PRNGKey(2)
    ws, bs = _mk_net(key, L=4, m=24)
    y0 = jax.random.uniform(jax.random.PRNGKey(3), (24, 6))
    np.testing.assert_allclose(
        dnn.dnn_forward(ws, bs, y0, fused=False),
        dnn.dnn_forward(ws, bs, y0, fused=True),
        rtol=1e-5,
        atol=1e-5,
    )


def test_outputs_nonnegative():
    key = jax.random.PRNGKey(4)
    ws, bs = _mk_net(key, L=2, m=16)
    y0 = jax.random.uniform(jax.random.PRNGKey(5), (16, 4))
    out = dnn.dnn_forward(ws, bs, y0)
    assert float(out.min()) >= 0.0  # ReLU semantics via max-plus ⊕


def test_forward_all_returns_every_layer():
    key = jax.random.PRNGKey(6)
    ws, bs = _mk_net(key, L=3, m=16)
    y0 = jax.random.uniform(jax.random.PRNGKey(7), (16, 4))
    ys = dnn.dnn_forward_all(ws, bs, y0)
    assert len(ys) == 4
    np.testing.assert_array_equal(ys[0], y0)
    np.testing.assert_allclose(
        ys[-1], dnn.dnn_forward(ws, bs, y0), rtol=1e-6
    )


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "bsr"])
def test_scan_equals_loop(sparse):
    key = jax.random.PRNGKey(8)
    ws, bs = _mk_net(key, L=5, m=32, sparse=sparse)
    y0 = jax.random.uniform(jax.random.PRNGKey(9), (32, 8))
    if sparse:
        stacked_w = dnn.stack_bsr(ws)
    else:
        stacked_w = jnp.stack(ws)
    stacked_b = jnp.stack(bs)
    out_scan = dnn.dnn_forward_scan(stacked_w, stacked_b, y0)
    out_loop = dnn.dnn_forward(ws, bs, y0)
    np.testing.assert_allclose(out_scan, out_loop, rtol=1e-4, atol=1e-4)


def test_scan_jits_once_for_any_depth():
    """Scan keeps the traced graph depth-independent (dry-run requirement)."""
    key = jax.random.PRNGKey(10)
    y0 = jax.random.uniform(jax.random.PRNGKey(11), (16, 4))
    traces = []

    @jax.jit
    def fwd(ws, bs, y0):
        traces.append(1)
        return dnn.dnn_forward_scan(ws, bs, y0)

    for L in (2, 2):  # same depth → one trace
        ws, bs = _mk_net(key, L=L, m=16)
        fwd(jnp.stack(ws), jnp.stack(bs), y0)
    assert len(traces) == 1


def test_stack_bsr_rejects_heterogeneous():
    key = jax.random.PRNGKey(12)
    a = BlockSparseMatrix.random(key, (16, 16), (8, 8), blocks_per_row=1)
    b = BlockSparseMatrix.random(key, (16, 16), (8, 8), blocks_per_row=2)
    with pytest.raises(ValueError):
        dnn.stack_bsr([a, b])


def test_sparse_dense_agree_on_same_weights():
    """BSR forward == dense forward when BSR stores the same matrix."""
    key = jax.random.PRNGKey(13)
    ws_sp, bs = _mk_net(key, L=2, m=32, sparse=True, bpr=2)
    ws_dn = [w.to_dense() for w in ws_sp]
    y0 = jax.random.uniform(jax.random.PRNGKey(14), (32, 8))
    np.testing.assert_allclose(
        dnn.dnn_forward(ws_sp, bs, y0),
        dnn.dnn_forward(ws_dn, bs, y0),
        rtol=1e-4,
        atol=1e-4,
    )
