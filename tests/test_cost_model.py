"""The grid-step cost model (``repro.plan.cost``) pinned against the
grids the Pallas calls actually launch.

``layer_grid_steps`` claims to bill EXACTLY the kernel grid — these
tests intercept ``pl.pallas_call`` to capture every launched grid and
compare step products, across layouts (ELL / block-CSR / dense), the
fused whole-stack routes, non-default ``block_n``, tuner-chosen block
sizes, and the narrow-panel effective-block shrink."""

import math

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro import plan as P
from repro.kernels import DEFAULT_BLOCK_N, ops
from repro.sparse import BlockCSRMatrix, BlockSparseMatrix


@pytest.fixture
def captured_grids(monkeypatch):
    """Record the grid of every pallas_call launched inside the test.

    The public wrappers are jit'd, so a shape seen earlier in the
    process would replay from the jit cache without re-tracing (and
    without re-entering pallas_call) — clear the caches first so every
    dispatch under test traces and is captured.
    """
    grids: list[tuple[int, ...]] = []
    real = pl.pallas_call

    def spy(*args, **kwargs):
        grid = kwargs.get("grid")
        if grid is None and "grid_spec" in kwargs:
            grid = kwargs["grid_spec"].grid
        if grid is not None:
            grids.append(tuple(int(g) for g in grid))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", spy)
    jax.clear_caches()
    return grids


def _steps(grids) -> int:
    return sum(math.prod(g) for g in grids)


class TestLayerGridSteps:
    def test_ell(self, captured_grids):
        w = BlockSparseMatrix.random(
            jax.random.PRNGKey(0), (96, 64), (16, 16), blocks_per_row=3
        )
        x = jnp.ones((64, 256), jnp.float32)
        ops.bsr_spmm(w, x).block_until_ready()
        assert len(captured_grids) == 1
        assert _steps(captured_grids) == P.layer_grid_steps(w, 256)

    def test_bcsr(self, captured_grids):
        w = BlockCSRMatrix.random_skewed(3, (128, 128), (16, 16), 30, skew=0.5)
        x = jnp.ones((128, 256), jnp.float32)
        ops.bcsr_spmm(w, x).block_until_ready()
        assert len(captured_grids) == 1
        assert _steps(captured_grids) == P.layer_grid_steps(w, 256)

    def test_dense(self, captured_grids):
        w = jnp.ones((256, 256), jnp.float32)
        x = jnp.ones((256, 256), jnp.float32)
        ops.semiring_matmul(w, x).block_until_ready()
        assert len(captured_grids) == 1
        assert _steps(captured_grids) == P.layer_grid_steps(w, 256)

    def test_nondefault_block_n(self, captured_grids):
        w = BlockSparseMatrix.random(
            jax.random.PRNGKey(1), (64, 64), (16, 16), blocks_per_row=2
        )
        x = jnp.ones((64, 256), jnp.float32)
        ops.bsr_spmm(w, x, block_n=64).block_until_ready()
        assert _steps(captured_grids) == P.layer_grid_steps(
            w, 256, block_n=64
        )
        assert P.layer_grid_steps(w, 256, block_n=64) == 2 * P.layer_grid_steps(
            w, 256, block_n=DEFAULT_BLOCK_N
        )

    def test_narrow_panel_effective_shrink(self, captured_grids):
        # A 16-wide panel runs at the shrunk effective tile, not 128 —
        # the model must bill the same shrink the wrapper applies.
        w = BlockSparseMatrix.random(
            jax.random.PRNGKey(2), (64, 64), (16, 16), blocks_per_row=2
        )
        x = jnp.ones((64, 16), jnp.float32)
        ops.bsr_spmm(w, x).block_until_ready()
        assert _steps(captured_grids) == P.layer_grid_steps(w, 16)

    def test_tuner_chosen_block_size(self, captured_grids):
        # The model reads block geometry from the weight's OWN layout —
        # a 32×32 re-blocked matrix bills its own (coarser) grid.
        w16 = BlockCSRMatrix.random_skewed(
            5, (128, 128), (16, 16), 24, skew=0.2
        )
        w32 = BlockCSRMatrix.from_dense(w16.to_dense(), (32, 32))
        x = jnp.ones((128, 128), jnp.float32)
        ops.bcsr_spmm(w32, x).block_until_ready()
        assert _steps(captured_grids) == P.layer_grid_steps(w32, 128)
        assert P.layer_grid_steps(w32, 128) != P.layer_grid_steps(w16, 128)


class TestStackGridSteps:
    def test_fused_resident_stack(self, captured_grids):
        ws = [
            BlockSparseMatrix.random(
                jax.random.PRNGKey(i), (64, 64), (16, 16), blocks_per_row=2
            )
            for i in range(3)
        ]
        bs = [jnp.zeros((64,), jnp.float32)] * 3
        plan = P.build_plan(ws, bs, 128)
        assert plan.route == P.ROUTE_FUSED
        plan.forward(jnp.ones((64, 128), jnp.float32)).block_until_ready()
        assert _steps(captured_grids) == P.stack_grid_steps(ws, 128)
        assert plan.grid_steps == P.stack_grid_steps(ws, 128)

    def test_layered_stack_sums_layers(self, captured_grids):
        ws = [
            BlockSparseMatrix.random(
                jax.random.PRNGKey(7), (64, 128), (16, 16), blocks_per_row=3
            ),
            BlockCSRMatrix.random_skewed(8, (64, 64), (16, 16), 9, skew=0.6),
        ]
        bs = [jnp.zeros((64,), jnp.float32)] * 2
        plan = P.build_plan(ws, bs, 128, relayout=False)
        assert plan.route == P.ROUTE_LAYERED
        plan.forward(jnp.ones((128, 128), jnp.float32)).block_until_ready()
        assert _steps(captured_grids) == P.stack_grid_steps(ws, 128)


class TestBlockWork:
    def test_block_work_is_block_size_invariant_for_dense_pattern(self):
        # A fully-dense pattern stored at 16×16 vs 32×32 covers the same
        # nonzeros — grid steps differ 4×, block work is identical.
        dense = jnp.asarray(
            jax.random.normal(jax.random.PRNGKey(9), (128, 128))
        )
        w16 = BlockCSRMatrix.from_dense(dense, (16, 16))
        w32 = BlockCSRMatrix.from_dense(dense, (32, 32))
        assert P.layer_grid_steps(w16, 128) == 4 * P.layer_grid_steps(w32, 128)
        assert P.stack_block_work([w16], 128) == P.stack_block_work(
            [w32], 128
        )
