"""VMEM-resident multi-layer fused forward: correctness + single-call.

The acceptance contract: one ``pallas_call`` for an L-layer stack, and
the result matches the layered ``dnn_forward(..., fused=True)``
reference to ≤1e-5 (CPU interpret mode).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dnn
from repro.kernels import fused_mlp
from repro.kernels import ops, ref
from repro.serve import SparseDNNEngine
from repro.sparse import BlockSparseMatrix


def _stack(key, L, m, bpr=3, block=(8, 8), bias_scale=0.5):
    keys = jax.random.split(key, 2 * L)
    # keep magnitudes tame so L-layer products stay O(1) and the 1e-5
    # comparison is meaningful in absolute terms too
    ws = [
        BlockSparseMatrix.random(
            keys[2 * i], (m, m), block, blocks_per_row=bpr
        ).map_blocks(lambda b: b * (0.5 / bpr))
        for i in range(L)
    ]
    bs = [
        jax.random.uniform(
            keys[2 * i + 1], (m,), minval=-bias_scale, maxval=bias_scale
        )
        for i in range(L)
    ]
    return ws, bs


@pytest.mark.parametrize("L", [1, 3, 5])
def test_matches_layered_reference(L):
    ws, bs = _stack(jax.random.PRNGKey(L), L, 64)
    y0 = jax.random.uniform(jax.random.PRNGKey(100 + L), (64, 20))
    out = ops.fused_mlp_forward(dnn.stack_bsr(ws), jnp.stack(bs), y0)
    expected = dnn.dnn_forward(ws, bs, y0, fused=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5
    )


def test_matches_ref_oracle():
    ws, bs = _stack(jax.random.PRNGKey(7), 4, 64, bpr=2)
    stacked_w, stacked_b = dnn.stack_bsr(ws), jnp.stack(bs)
    y0 = jax.random.uniform(jax.random.PRNGKey(8), (64, 12))
    np.testing.assert_allclose(
        np.asarray(ops.fused_mlp_forward(stacked_w, stacked_b, y0)),
        np.asarray(ref.fused_mlp_forward_ref(stacked_w, stacked_b, y0)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_single_pallas_call():
    """An L-layer stack must lower to exactly ONE pallas_call."""
    L = 6
    ws, bs = _stack(jax.random.PRNGKey(1), L, 32)
    stacked_w, stacked_b = dnn.stack_bsr(ws), jnp.stack(bs)
    y0 = jax.random.uniform(jax.random.PRNGKey(2), (32, 8))
    jaxpr = jax.make_jaxpr(
        lambda w, b, y: ops.fused_mlp_forward(w, b, y)
    )(stacked_w, stacked_b, y0)
    assert str(jaxpr).count("pallas_call") == 1

    # while the layered kernel path pays one call PER layer
    def layered(ws_, bs_, y):
        for w, b in zip(ws_, bs_):
            y = ops.bsr_spmm(w, y, b, fuse_bias_relu=True)
        return y

    # (the jitted wrapper dedups the shared kernel jaxpr, so count the
    # per-layer call sites rather than the pallas_call primitive itself)
    jaxpr_layered = jax.make_jaxpr(layered)(ws, bs, y0)
    assert str(jaxpr_layered).count("name=bsr_spmm") == L


def test_relu_and_sparsity_semantics():
    """Outputs non-negative; empty block-rows yield max(bias, 0)."""
    m = 32
    dense = np.zeros((m, m), np.float32)
    dense[:8, :8] = 1.0  # only the first block-row stores anything
    w = BlockSparseMatrix.from_dense(dense, (8, 8))
    ws = [w, w]
    bias = jax.random.normal(jax.random.PRNGKey(3), (m,))
    bs = [bias, bias]
    y0 = jax.random.uniform(jax.random.PRNGKey(4), (m, 8))
    out = ops.fused_mlp_forward(dnn.stack_bsr(ws), jnp.stack(bs), y0)
    assert float(out.min()) >= 0.0
    expected_empty = np.maximum(np.asarray(bias)[8:, None], 0.0)
    np.testing.assert_allclose(
        np.asarray(out)[8:], np.broadcast_to(expected_empty, (m - 8, 8)),
        rtol=1e-6, atol=1e-6,
    )


def test_ragged_batch_padding():
    ws, bs = _stack(jax.random.PRNGKey(5), 3, 64)
    y0 = jax.random.uniform(jax.random.PRNGKey(6), (64, 13))  # ragged n
    out = ops.fused_mlp_forward(dnn.stack_bsr(ws), jnp.stack(bs), y0)
    assert out.shape == (64, 13)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(dnn.dnn_forward(ws, bs, y0, fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_rejects_non_square():
    w = BlockSparseMatrix.random(
        jax.random.PRNGKey(9), (32, 64), (8, 8), blocks_per_row=2
    )
    stacked = dnn.stack_bsr([w])
    y0 = jnp.ones((64, 8))
    with pytest.raises(ValueError):
        fused_mlp.fused_mlp_forward(stacked, jnp.zeros((1, 32)), y0)


def test_eligibility_gate():
    small = BlockSparseMatrix.random(
        jax.random.PRNGKey(10), (64, 64), (8, 8), blocks_per_row=2
    )
    assert fused_mlp.fused_mlp_eligible(small)
    rect = BlockSparseMatrix.random(
        jax.random.PRNGKey(11), (64, 128), (8, 8), blocks_per_row=2
    )
    assert not fused_mlp.fused_mlp_eligible(rect)
    # VMEM ceiling: a panel too tall must be rejected
    assert (
        fused_mlp.fused_mlp_vmem_bytes(64 * 1024)
        > fused_mlp.VMEM_SOFT_LIMIT_BYTES
    )


def test_dnn_forward_resident_fallback():
    """Ineligible stacks silently take the layered path, same numbers."""
    m = 48
    ws, bs = _stack(jax.random.PRNGKey(12), 2, m, bpr=2)
    # heterogeneous pad width → ineligible
    ws = [ws[0], BlockSparseMatrix.random(
        jax.random.PRNGKey(13), (m, m), (8, 8), blocks_per_row=4
    )]
    assert not dnn.resident_eligible(ws)
    y0 = jax.random.uniform(jax.random.PRNGKey(14), (m, 8))
    np.testing.assert_allclose(
        dnn.dnn_forward_resident(ws, bs, y0),
        dnn.dnn_forward(ws, bs, y0, fused=True),
        rtol=1e-6,
    )


def test_serve_engine_empty_batch_is_noop():
    ws, bs = _stack(jax.random.PRNGKey(17), 2, 32, bpr=2)
    eng = SparseDNNEngine(ws, bs, batch_align=16)
    out, stats = eng.infer(jnp.zeros((32, 0)))
    assert out.shape == (32, 0)
    assert stats["pallas_calls"] == 0
    assert stats["served_total"] == 0


def test_serve_engine_fallback_uses_layered_kernels():
    """Ineligible stack → one kernel call per layer, same numbers."""
    from repro.sparse import BlockCSRMatrix

    m = 64
    ws, bs = _stack(jax.random.PRNGKey(18), 2, m, bpr=2)
    mixed = [BlockCSRMatrix.from_bsr(ws[0]), ws[1]]  # mixed layout
    eng = SparseDNNEngine(mixed, bs, batch_align=16)
    y0 = jax.random.uniform(jax.random.PRNGKey(19), (m, 8))
    out, stats = eng.infer(y0)
    assert stats["resident"] is False
    assert stats["pallas_calls"] == 2
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(dnn.dnn_forward(mixed, bs, y0, fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_serve_engine_rejects_forced_resident_on_ineligible_stack():
    from repro.sparse import BlockCSRMatrix

    ws, bs = _stack(jax.random.PRNGKey(20), 2, 32, bpr=2)
    mixed = [BlockCSRMatrix.from_bsr(ws[0]), ws[1]]
    with pytest.raises(ValueError):
        SparseDNNEngine(mixed, bs, use_resident=True)


def test_serve_engine_resident():
    ws, bs = _stack(jax.random.PRNGKey(15), 3, 64)
    eng = SparseDNNEngine(ws, bs, batch_align=16)
    y0 = jax.random.uniform(jax.random.PRNGKey(16), (64, 10))
    out, stats = eng.infer(y0)
    assert stats["resident"] is True
    assert stats["pallas_calls"] == 1
    assert stats["padded_batch"] == 16
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(dnn.dnn_forward(ws, bs, y0, fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )
