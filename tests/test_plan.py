"""Compile-once execution plans (``repro.plan``): cache keying,
eviction, route decisions, plan-backed forward equivalence, the cached
block-CSR transpose (a multi-step train loop sorts the topology exactly
once), and the serving integration (engine plan stats, width-class
quantization, per-class recompile counts)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import plan as P
from repro.core import dnn
from repro.serve import ContinuousBatcher, SparseDNNEngine
from repro.sparse import (
    BlockCSRMatrix,
    BlockSparseMatrix,
    reset_transpose_sort_count,
    transpose_sort_count,
)


def _stack(key, L, m, bpr=2, block=16):
    ks = jax.random.split(key, L)
    ws = [
        BlockSparseMatrix.random(k, (m, m), (block, block), blocks_per_row=bpr)
        for k in ks
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    return ws, bs


def _skewed_ell(seed, m=64, block=16):
    """An ELL weight whose pad waste crosses the threshold (one heavy
    row, the rest near-empty) → preferred_layout == 'bcsr'."""
    nrb = m // block
    dense = np.zeros((m, m), np.float32)
    dense[:block, :] = 1.0  # first block-row full
    dense[block : 2 * block, :block] = 1.0  # second has one block
    return BlockSparseMatrix.from_dense(jnp.asarray(dense), (block, block))


# ---------------------------------------------------------------------
# fingerprint + width classes
# ---------------------------------------------------------------------


def test_fingerprint_is_topology_only():
    # bpr=1 over a 4x4 block grid → the stored-block pattern genuinely
    # varies with the seed (full-occupancy stacks all look alike)
    ws, _ = _stack(jax.random.PRNGKey(0), 2, 64, bpr=1)
    same_pattern = [w.map_blocks(lambda x: x * 2.0) for w in ws]
    other, _ = _stack(jax.random.PRNGKey(9), 2, 64, bpr=1)
    assert not np.array_equal(
        np.asarray(ws[0].col_idx), np.asarray(other[0].col_idx)
    )
    fp = P.topology_fingerprint(ws)
    assert P.topology_fingerprint(same_pattern) == fp  # values don't key
    assert P.topology_fingerprint(other) != fp  # pattern does
    # layout class is part of the topology
    csr = [BlockCSRMatrix.from_bsr(w) for w in ws]
    assert P.topology_fingerprint(csr) != fp


def test_quantize_width():
    classes = (8, 16, 32)
    assert P.quantize_width(1, classes) == 8
    assert P.quantize_width(8, classes) == 8
    assert P.quantize_width(9, classes) == 16
    assert P.quantize_width(32, classes) == 32
    assert P.quantize_width(33, classes) == 64  # beyond top: multiples
    assert P.quantize_width(17, None) == 17  # no classes → identity


# ---------------------------------------------------------------------
# cache keying + eviction (the satellite's contract)
# ---------------------------------------------------------------------


def test_cache_same_topology_same_width_hits():
    ws, bs = _stack(jax.random.PRNGKey(1), 2, 32)
    cache = P.PlanCache(max_size=4)
    p1 = cache.get(ws, bs, 16)
    p2 = cache.get(ws, bs, 16)
    assert p1 is p2
    assert cache.stats()["hits"] == 1 and cache.stats()["builds"] == 1


def test_cache_distinct_plans_per_key_axis():
    ws, bs = _stack(jax.random.PRNGKey(2), 2, 64, bpr=1)
    other, _ = _stack(jax.random.PRNGKey(3), 2, 64, bpr=1)
    assert P.topology_fingerprint(ws) != P.topology_fingerprint(other)
    cache = P.PlanCache(max_size=8)
    base = cache.get(ws, bs, 16)
    # changed block pattern → distinct plan
    assert cache.get(other, bs, 16) is not base
    # changed width class → distinct plan
    assert cache.get(ws, bs, 32) is not base
    # toggled differentiable → distinct plan
    assert cache.get(ws, bs, 16, differentiable=True) is not base
    assert cache.stats()["builds"] == 4
    # and each key still hits on repeat
    assert cache.get(ws, bs, 16) is base


def test_cache_eviction_respects_max_size():
    ws, bs = _stack(jax.random.PRNGKey(4), 2, 32)
    cache = P.PlanCache(max_size=2)
    p8 = cache.get(ws, bs, 8)
    cache.get(ws, bs, 16)
    cache.get(ws, bs, 32)  # evicts the LRU entry (width 8)
    assert len(cache) == 2
    assert cache.stats()["evictions"] == 1
    assert cache.get(ws, bs, 8) is not p8  # rebuilt after eviction
    assert cache.stats()["builds"] == 4


def test_cache_rejects_stale_bound_values():
    """Same topology but different value arrays must NOT reuse a plan
    whose executable binds the old values."""
    ws, bs = _stack(jax.random.PRNGKey(5), 2, 32)
    rescaled = [w.map_blocks(lambda x: x * 3.0) for w in ws]
    cache = P.PlanCache(max_size=4)
    p1 = cache.get(ws, bs, 8)
    p2 = cache.get(rescaled, bs, 8)
    assert p1 is not p2
    y0 = jax.random.uniform(jax.random.PRNGKey(6), (32, 4))
    np.testing.assert_allclose(
        np.asarray(p2.forward(y0)),
        np.asarray(dnn.dnn_forward(rescaled, bs, y0, fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )


# ---------------------------------------------------------------------
# route decisions + plan-backed forward equivalence
# ---------------------------------------------------------------------


def test_route_fused_for_homogeneous_square_stack():
    ws, bs = _stack(jax.random.PRNGKey(7), 3, 64)
    plan = P.build_plan(ws, bs, 8)
    assert plan.route == P.ROUTE_FUSED
    assert plan.pallas_calls == 1
    y0 = jax.random.uniform(jax.random.PRNGKey(8), (64, 5))
    np.testing.assert_allclose(
        np.asarray(plan.forward(y0)),
        np.asarray(dnn.dnn_forward(ws, bs, y0, fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_route_layered_for_mixed_layout_and_grid_steps():
    ws, bs = _stack(jax.random.PRNGKey(10), 2, 64)
    mixed = [BlockCSRMatrix.from_bsr(ws[0]), ws[1]]
    plan = P.build_plan(mixed, bs, 8)
    assert plan.route == P.ROUTE_LAYERED
    assert plan.layouts == ("bcsr", "ell")
    assert plan.pallas_calls == 2
    assert plan.grid_steps == dnn.dnn_grid_steps(mixed, 8)
    y0 = jax.random.uniform(jax.random.PRNGKey(11), (64, 8))
    np.testing.assert_allclose(
        np.asarray(plan.forward(y0)),
        np.asarray(dnn.dnn_forward(mixed, bs, y0, fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_route_xla_for_all_dense_differentiable():
    m = 32
    ws = [jax.random.normal(jax.random.PRNGKey(12), (m, m)) * 0.1]
    bs = [jnp.zeros((m,))]
    plan = P.build_plan(ws, bs, 8, differentiable=True)
    assert plan.route == P.ROUTE_XLA
    assert plan.pallas_calls == 0


def test_relayout_applies_waste_heuristic_to_inference_plans():
    w = _skewed_ell(0)
    assert P.preferred_layout(w) == "bcsr"
    bs = [jnp.zeros((64,), jnp.float32)]
    # the fused route would win on this square stack — force layered to
    # exercise the per-layer waste heuristic
    plan = P.build_plan([w], bs, 8, use_resident=False)
    assert plan.layers[0].source_layout == "ell"
    assert plan.layers[0].layout == "bcsr"  # the lifted heuristic fired
    y0 = jax.random.uniform(jax.random.PRNGKey(13), (64, 8))
    np.testing.assert_allclose(
        np.asarray(plan.forward(y0)),
        np.asarray(dnn.dnn_forward([w], bs, y0, fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )
    # differentiable plans must keep the caller's layout (cotangent
    # structure mirrors the primal) — relayout is refused
    dplan = P.build_plan([w], bs, 8, differentiable=True)
    assert dplan.layers[0].layout == "ell"
    with pytest.raises(ValueError, match="relayout"):
        P.build_plan([w], bs, 8, differentiable=True, relayout=True)


def test_plan_forward_pads_to_width_class_and_rejects_overflow():
    ws, bs = _stack(jax.random.PRNGKey(14), 2, 32)
    plan = P.build_plan(ws, bs, 16)
    y0 = jax.random.uniform(jax.random.PRNGKey(15), (32, 3))
    out = plan.forward(y0)  # 3 ≤ 16: padded internally, sliced back
    assert out.shape == (32, 3)
    assert plan.compile_count == 1
    plan.forward(jax.random.uniform(jax.random.PRNGKey(16), (32, 9)))
    assert plan.compile_count == 1  # same class → same executable
    with pytest.raises(ValueError, match="width"):
        plan.forward(jnp.zeros((32, 17)))


def test_vmem_boundary_tips_fused_into_tiled_exactly():
    """Regression for the route boundary: the last m whose activation
    panel exactly fills ``VMEM_SOFT_LIMIT_BYTES`` still takes the
    resident fused route; ONE block-row more must tip into fused-tiled
    (never layered). Asserted through the plan layer's decision tree,
    not the kernel."""
    from repro.kernels.fused_mlp import (
        VMEM_SOFT_LIMIT_BYTES,
        fused_mlp_vmem_bytes,
    )

    block = 16
    bytes_per_row = fused_mlp_vmem_bytes(1)
    m_res = VMEM_SOFT_LIMIT_BYTES // bytes_per_row  # last resident m
    assert fused_mlp_vmem_bytes(m_res) == VMEM_SOFT_LIMIT_BYTES
    assert m_res % block == 0

    at, bs_at = _stack(jax.random.PRNGKey(40), 2, m_res, block=block)
    over, bs_over = _stack(
        jax.random.PRNGKey(41), 2, m_res + block, block=block
    )
    # the three-way route call is exact at the boundary
    assert P.fused_route(at) == P.ROUTE_FUSED
    assert P.fused_route(over) == P.ROUTE_FUSED_TILED
    # ...and build_plan agrees: both stay single-pallas_call plans
    plan_at = P.build_plan(at, bs_at, 8)
    plan_over = P.build_plan(over, bs_over, 8)
    assert plan_at.route == P.ROUTE_FUSED
    assert plan_over.route == P.ROUTE_FUSED_TILED
    assert plan_over.route != P.ROUTE_LAYERED
    assert plan_at.pallas_calls == plan_over.pallas_calls == 1
    # the over-budget stack still honours the engine's resident knob
    # (fused family), and use_resident=False forces layered as usual
    assert (
        P.build_plan(over, bs_over, 8, use_resident=True).route
        == P.ROUTE_FUSED_TILED
    )
    assert (
        P.build_plan(over, bs_over, 8, use_resident=False).route
        == P.ROUTE_LAYERED
    )


def test_use_resident_tristate_matches_engine_contract():
    ws, bs = _stack(jax.random.PRNGKey(17), 2, 64)
    assert P.build_plan(ws, bs, 8, use_resident=True).route == P.ROUTE_FUSED
    assert P.build_plan(ws, bs, 8, use_resident=False).route == P.ROUTE_LAYERED
    with pytest.raises(ValueError, match="not eligible"):
        P.build_plan(
            [BlockCSRMatrix.from_bsr(ws[0])], bs[:1], 8, use_resident=True
        )
    with pytest.raises(ValueError, match="VJP|eligible"):
        P.build_plan(ws, bs, 8, differentiable=True, use_resident=True)


# ---------------------------------------------------------------------
# the cached transpose: one sort per topology, ever
# ---------------------------------------------------------------------


def test_train_loop_sorts_topology_exactly_once():
    """10 jitted train steps over an ELL+CSR stack: the CSR topology is
    argsorted exactly once (at plan build); the step's jaxpr contains no
    sort at all, while the legacy (plan-less) step still sorts."""
    from repro.train.optimizer import sgd
    from repro.train.sparse import (
        init_sparse_mlp_state,
        make_sparse_train_step,
    )

    m, n = 32, 8
    ws, bs = _stack(jax.random.PRNGKey(18), 2, m)
    ws = [ws[0], BlockCSRMatrix.from_bsr(ws[1])]
    y0 = jax.random.uniform(jax.random.PRNGKey(19), (m, n))
    batch = {"y0": y0, "targets": y0 * 0.5}
    opt = sgd(0.1, momentum=0.0)
    state = init_sparse_mlp_state(ws, bs, opt)

    legacy = make_sparse_train_step(opt, use_kernel=True)
    assert " sort" in str(jax.make_jaxpr(legacy)(state, batch))

    reset_transpose_sort_count()
    plan = P.build_plan(ws, bs, n, differentiable=True)
    assert transpose_sort_count() == 1  # one CSR layer → one sort
    planned = make_sparse_train_step(opt, use_kernel=True, plan=plan)
    assert " sort" not in str(jax.make_jaxpr(planned)(state, batch))

    step = jax.jit(planned)
    losses = []
    for _ in range(10):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert transpose_sort_count() == 1  # 10 steps added ZERO sorts
    assert losses[-1] < losses[0]


def test_cache_shares_topology_artifacts_across_width_classes():
    """Plans for new width classes donate from an existing plan: the
    topology is sorted once no matter how many classes serve it, and
    fused plans share one stacked weight copy."""
    ws, bs = _stack(jax.random.PRNGKey(34), 2, 32)
    mixed = [BlockCSRMatrix.from_bsr(ws[0]), ws[1]]
    cache = P.PlanCache(max_size=8)
    reset_transpose_sort_count()
    p8 = cache.get(mixed, bs, 8, differentiable=True)
    p16 = cache.get(mixed, bs, 16, differentiable=True)
    assert transpose_sort_count() == 1  # second width class: no re-sort
    assert p16.layers[0].transpose_plan is p8.layers[0].transpose_plan
    assert p16.grid_steps == dnn.dnn_grid_steps(mixed, 16)  # width-local
    f8 = cache.get(ws, bs, 8)
    f16 = cache.get(ws, bs, 16)
    assert f8.route == f16.route == P.ROUTE_FUSED
    assert f16._stacked is f8._stacked  # one device copy per topology
    y0 = jax.random.uniform(jax.random.PRNGKey(35), (32, 10))
    np.testing.assert_allclose(
        np.asarray(f16.forward(y0)),
        np.asarray(dnn.dnn_forward(ws, bs, y0, fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_dnn_forward_resident_stays_differentiable_on_fallback():
    """Regression: grad through dnn_forward_resident on an ineligible
    stack with a dense layer must keep the legacy XLA-differentiable
    fallback (the plan path would route the dense layer to the VJP-less
    Pallas kernel)."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(36), 1, m)
    mixed = [ws[0], jax.random.normal(jax.random.PRNGKey(37), (m, m)) * 0.1]
    bs = bs + [jnp.zeros((m,))]
    y0 = jax.random.uniform(jax.random.PRNGKey(38), (m, 4))
    g = jax.grad(
        lambda y: jnp.sum(dnn.dnn_forward_resident(mixed, bs, y))
    )(y0)
    assert g.shape == y0.shape
    assert float(jnp.abs(g).max()) > 0.0


def test_planned_grads_match_legacy():
    m, n = 32, 8
    ws, bs = _stack(jax.random.PRNGKey(20), 2, m)
    ws = [BlockCSRMatrix.from_bsr(ws[0]), ws[1]]
    y0 = jax.random.uniform(jax.random.PRNGKey(21), (m, n))
    targets = jax.random.uniform(jax.random.PRNGKey(22), (m, n))
    plan = P.build_plan(ws, bs, n, differentiable=True)
    l1, (dw1, db1) = dnn.dnn_value_and_grad(ws, bs, y0, targets)
    l2, (dw2, db2) = dnn.dnn_value_and_grad(ws, bs, y0, targets, plan=plan)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(dw1[0].values), np.asarray(dw2[0].values), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(dw1[1].blocks), np.asarray(dw2[1].blocks), rtol=1e-5
    )
    np.testing.assert_allclose(np.asarray(db1[0]), np.asarray(db2[0]), rtol=1e-5)


def test_forward_trainable_requires_matching_plan():
    ws, bs = _stack(jax.random.PRNGKey(23), 2, 32)
    inference_plan = P.build_plan(ws, bs, 8)
    with pytest.raises(ValueError, match="differentiable"):
        dnn.dnn_forward_trainable(
            ws, bs, jnp.zeros((32, 8)), plan=inference_plan
        )
    short = P.build_plan(ws[:1], bs[:1], 8, differentiable=True)
    with pytest.raises(ValueError, match="layers"):
        dnn.dnn_forward_trainable(ws, bs, jnp.zeros((32, 8)), plan=short)


# ---------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------


def test_engine_steps_share_one_plan_per_width_class():
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(24), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    _, s1 = eng.infer(jax.random.uniform(jax.random.PRNGKey(25), (m, 5)))
    _, s2 = eng.infer(jax.random.uniform(jax.random.PRNGKey(26), (m, 7)))
    assert s1["plan"]["width_class"] == s2["plan"]["width_class"] == 8
    assert s1["plan"]["cache_hit"] is False  # first panel built the plan
    assert s2["plan"]["cache_hit"] is True  # second reused it
    assert s2["plan"]["compiles"] == 1  # ... without recompiling
    assert eng.plan_cache.stats()["builds"] == 1
    _, s3 = eng.infer(jax.random.uniform(jax.random.PRNGKey(27), (m, 9)))
    assert s3["plan"]["width_class"] == 16  # new class → new plan
    assert eng.plan_cache.stats()["builds"] == 2


def test_engine_pad_to_quantizes_panel():
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(28), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    eng.submit(jax.random.uniform(jax.random.PRNGKey(29), (m, 3)))
    out, stats = eng.step(pad_to=24)
    assert stats["padded_batch"] == 24 and stats["pad_slots"] == 21
    assert stats["grid_steps"] == dnn.dnn_grid_steps(ws, 24)
    assert out.shape == (m, 3)
    with pytest.raises(ValueError):
        eng.step(pad_to=0)


def test_batcher_width_classes_reuse_compiled_plans():
    """The satellite knob: quantized panels land on a handful of width
    classes; the plan cache compiles once per class and ServeStats
    reports the per-class recompile counts."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(30), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    b = ContinuousBatcher(
        eng, batch_size=16, min_fill=0.0, width_classes=(8, 16)
    )
    cols = {}
    for i in range(23):  # varying occupancies across ticks
        for j in range(1 + (i * 5) % 7):
            col = jax.random.uniform(jax.random.PRNGKey(100 + 10 * i + j), (m,))
            cols[b.submit(col)] = col
        b.step(force=True)
    b.drain()
    stats = b.stats()
    assert stats.requests == len(cols)
    # every panel landed on a declared class
    assert {s.width_class for s in stats.steps} <= {8, 16}
    # one compile per class touched, everything else reused
    assert sum(stats.plan_recompiles_by_class.values()) == len(
        stats.plan_recompiles_by_class
    )
    assert eng.plan_cache.stats()["builds"] == len(
        stats.plan_recompiles_by_class
    )
    assert stats.plan_cache_hit_rate >= 0.8
    # numbers unchanged by quantization
    for rid, col in cols.items():
        np.testing.assert_allclose(
            np.asarray(b.result(rid)),
            np.asarray(dnn.dnn_forward(ws, bs, col[:, None], fused=True)[:, 0]),
            rtol=1e-5,
            atol=1e-5,
        )


def test_batcher_width_classes_validation():
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(31), 2, m)
    with pytest.raises(ValueError, match="width class"):
        ContinuousBatcher(
            SparseDNNEngine(ws, bs, batch_align=8),
            batch_size=32,
            width_classes=(8, 16),  # largest class < batch_size
        )
    with pytest.raises(ValueError, match="positive"):
        ContinuousBatcher(
            SparseDNNEngine(ws, bs, batch_align=8),
            batch_size=4,
            width_classes=(0, 8),
        )


def test_differentiable_engine_grad_flows_through_plan():
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(32), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=4, differentiable=True)
    y0 = jax.random.uniform(jax.random.PRNGKey(33), (m, 4))
    g = jax.grad(lambda y: jnp.sum(eng.infer(y)[0]))(y0)
    assert g.shape == y0.shape
    assert float(jnp.abs(g).max()) > 0.0
