"""Serving engine behaviour: shapes, greedy determinism, sampling,
and windowed-cache decode beyond the ring-buffer length."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.model import Model
from repro.serve.engine import Engine, cache_nbytes, sample_token


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3.2-1b").scaled_down()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_generate_shapes_and_determinism(setup):
    cfg, model, params = setup
    eng = Engine(model, params, batch_size=3, cache_len=64, temperature=0.0)
    prompts = jax.random.randint(jax.random.key(1), (3, 8), 0, cfg.vocab_size)
    out1, stats = eng.generate(prompts, 12)
    out2, _ = eng.generate(prompts, 12)
    assert out1.shape == (3, 12)
    assert bool((out1 == out2).all())  # greedy = deterministic
    assert stats["generated_tokens"] == 36
    assert stats["cache_bytes"] > 0


def test_sampling_temperature(setup):
    cfg, model, params = setup
    logits = jnp.zeros((4, cfg.vocab_size)).at[:, 7].set(10.0)
    greedy = sample_token(logits, jax.random.key(0), 0.0)
    assert bool((greedy == 7).all())
    hot = sample_token(jnp.zeros((64, cfg.vocab_size)), jax.random.key(0), 10.0)
    assert len(set(hot.tolist())) > 8  # high temperature → diverse


def test_generate_matches_forward_greedy(setup):
    """Engine's first generated token == argmax of the plain forward."""
    cfg, model, params = setup
    prompts = jax.random.randint(jax.random.key(2), (2, 10), 0, cfg.vocab_size)
    eng = Engine(model, params, batch_size=2, cache_len=32)
    out, _ = eng.generate(prompts, 1)
    full = model.forward(params, prompts)
    expect = jnp.argmax(full[:, -1], axis=-1)
    assert bool((out[:, 0] == expect).all())


def test_windowed_arch_long_decode():
    """gemma3's local layers use a ring buffer smaller than the stream —
    decoding past the window must stay finite and shape-correct."""
    cfg = get_config("gemma3-4b").scaled_down()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b, window = 2, 64
    cache = model.init_cache(b, 128)
    tok = jnp.zeros((b,), jnp.int32)
    for pos in range(0, 80, 8):  # decode past the 64-token local window
        logits, cache = model.decode_step(
            params, tok, cache, jnp.asarray(pos, jnp.int32)
        )
        assert bool(jnp.isfinite(logits).all())
    assert cache_nbytes(cache) > 0


def test_ssm_state_cache_is_constant_size():
    cfg = get_config("rwkv6-3b").scaled_down()
    model = Model(cfg)
    small = cache_nbytes(model.init_cache(2, 32))
    large = cache_nbytes(model.init_cache(2, 4096))
    assert small == large  # attention-free: O(1) state, not O(seq)
