"""Serving engine behaviour: shapes, greedy determinism, sampling,
windowed-cache decode beyond the ring-buffer length, and the
SparseDNNEngine step-level API (submit/step/drain) the continuous
batcher drives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import dnn
from repro.models.model import Model
from repro.serve.engine import (
    Engine,
    SparseDNNEngine,
    cache_nbytes,
    sample_token,
)
from repro.sparse.bsr import BlockSparseMatrix


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3.2-1b").scaled_down()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_generate_shapes_and_determinism(setup):
    cfg, model, params = setup
    eng = Engine(model, params, batch_size=3, cache_len=64, temperature=0.0)
    prompts = jax.random.randint(jax.random.key(1), (3, 8), 0, cfg.vocab_size)
    out1, stats = eng.generate(prompts, 12)
    out2, _ = eng.generate(prompts, 12)
    assert out1.shape == (3, 12)
    assert bool((out1 == out2).all())  # greedy = deterministic
    assert stats["generated_tokens"] == 36
    assert stats["cache_bytes"] > 0


def test_sampling_temperature(setup):
    cfg, model, params = setup
    logits = jnp.zeros((4, cfg.vocab_size)).at[:, 7].set(10.0)
    greedy = sample_token(logits, jax.random.key(0), 0.0)
    assert bool((greedy == 7).all())
    hot = sample_token(jnp.zeros((64, cfg.vocab_size)), jax.random.key(0), 10.0)
    assert len(set(hot.tolist())) > 8  # high temperature → diverse


def test_generate_matches_forward_greedy(setup):
    """Engine's first generated token == argmax of the plain forward."""
    cfg, model, params = setup
    prompts = jax.random.randint(jax.random.key(2), (2, 10), 0, cfg.vocab_size)
    eng = Engine(model, params, batch_size=2, cache_len=32)
    out, _ = eng.generate(prompts, 1)
    full = model.forward(params, prompts)
    expect = jnp.argmax(full[:, -1], axis=-1)
    assert bool((out[:, 0] == expect).all())


def test_windowed_arch_long_decode():
    """gemma3's local layers use a ring buffer smaller than the stream —
    decoding past the window must stay finite and shape-correct."""
    cfg = get_config("gemma3-4b").scaled_down()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b, window = 2, 64
    cache = model.init_cache(b, 128)
    tok = jnp.zeros((b,), jnp.int32)
    for pos in range(0, 80, 8):  # decode past the 64-token local window
        logits, cache = model.decode_step(
            params, tok, cache, jnp.asarray(pos, jnp.int32)
        )
        assert bool(jnp.isfinite(logits).all())
    assert cache_nbytes(cache) > 0


def test_ssm_state_cache_is_constant_size():
    cfg = get_config("rwkv6-3b").scaled_down()
    model = Model(cfg)
    small = cache_nbytes(model.init_cache(2, 32))
    large = cache_nbytes(model.init_cache(2, 4096))
    assert small == large  # attention-free: O(1) state, not O(seq)


# ---------------------------------------------------------------------
# SparseDNNEngine step-level API
# ---------------------------------------------------------------------


def _sparse_stack(key, L, m, bpr=2):
    ks = jax.random.split(key, L)
    ws = [
        BlockSparseMatrix.random(k, (m, m), (16, 16), blocks_per_row=bpr)
        for k in ks
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    return ws, bs


def test_sparse_engine_submit_step_drain():
    m = 32
    ws, bs = _sparse_stack(jax.random.key(30), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    cols = jax.random.uniform(jax.random.key(31), (m, 5))
    rids = eng.submit(cols)
    assert rids == [0, 1, 2, 3, 4] and eng.staged == 5
    out, stats = eng.step(limit=3)
    assert out.shape == (m, 3)
    assert stats["batch"] == 3
    assert stats["padded_batch"] == 8 and stats["pad_slots"] == 5
    assert stats["request_ids"] == [0, 1, 2]
    assert stats["grid_steps"] == dnn.dnn_grid_steps(ws, 8)
    assert eng.staged == 2
    rest = eng.drain(limit=1)
    assert [s["batch"] for _, s in rest] == [1, 1]
    assert eng.staged == 0
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(dnn.dnn_forward(ws, bs, cols[:, :3], fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_sparse_engine_infer_is_submit_step_wrapper():
    m = 32
    ws, bs = _sparse_stack(jax.random.key(32), 2, m)
    y0 = jax.random.uniform(jax.random.key(33), (m, 5))
    out_oneshot, s1 = SparseDNNEngine(ws, bs, batch_align=8).infer(y0)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    eng.submit(y0)
    out_stepped, s2 = eng.step()
    np.testing.assert_allclose(
        np.asarray(out_oneshot), np.asarray(out_stepped), rtol=1e-6
    )
    assert (s1["batch"], s1["padded_batch"]) == (s2["batch"], s2["padded_batch"])


def test_sparse_engine_step_rejects_nonpositive_limit():
    """limit=0 consumed nothing — drain(limit=0) used to spin forever."""
    m = 32
    ws, bs = _sparse_stack(jax.random.key(40), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    eng.submit(jax.random.uniform(jax.random.key(41), (m, 2)))
    with pytest.raises(ValueError):
        eng.step(limit=0)
    with pytest.raises(ValueError):
        eng.drain(limit=-1)
    assert eng.staged == 2  # nothing consumed by the rejected calls


def test_sparse_engine_step_splits_staged_chunk_at_limit():
    """A step boundary inside a submitted chunk splits it; ids and
    columns stay paired across the split."""
    m = 32
    ws, bs = _sparse_stack(jax.random.key(42), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=4)
    cols_a = jax.random.uniform(jax.random.key(43), (m, 3))
    cols_b = jax.random.uniform(jax.random.key(44), (m, 2))
    eng.submit(cols_a)
    eng.submit(cols_b)
    out, stats = eng.step(limit=4)  # 3 from chunk A + 1 from chunk B
    assert stats["request_ids"] == [0, 1, 2, 3]
    ref = dnn.dnn_forward(
        ws, bs, jnp.concatenate([cols_a, cols_b[:, :1]], axis=1), fused=True
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5
    )
    out2, stats2 = eng.step()
    assert stats2["request_ids"] == [4]
    np.testing.assert_allclose(
        np.asarray(out2),
        np.asarray(dnn.dnn_forward(ws, bs, cols_b[:, 1:], fused=True)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_sparse_engine_infer_refuses_to_jump_staged_queue():
    m = 32
    ws, bs = _sparse_stack(jax.random.key(34), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    eng.submit(jax.random.uniform(jax.random.key(35), (m, 2)))
    with pytest.raises(RuntimeError):
        eng.infer(jax.random.uniform(jax.random.key(36), (m, 1)))


def test_sparse_engine_step_grid_steps_track_padded_width():
    """The pad is billed: step cost is a function of the padded panel,
    and shrinking the alignment shrinks the bill once the pad crosses a
    kernel tile boundary (below one 128-wide tile the effective tile
    shrinks with the panel, so the step count is flat — slot-level waste
    there is what ``pad_slot_fraction`` reports)."""
    m = 64
    ws, bs = _sparse_stack(jax.random.key(37), 3, m)
    wide = SparseDNNEngine(ws, bs, batch_align=256)
    narrow = SparseDNNEngine(ws, bs, batch_align=8)
    col = jax.random.uniform(jax.random.key(38), (m, 1))
    _, s_wide = wide.infer(col)
    _, s_narrow = narrow.infer(col)
    assert s_wide["grid_steps"] == dnn.dnn_grid_steps(ws, 256)
    assert s_narrow["grid_steps"] == dnn.dnn_grid_steps(ws, 8)
    # 256-wide panel = two 128-wide tiles per layer vs one narrow tile
    assert s_narrow["grid_steps"] < s_wide["grid_steps"]
