"""Semiring algebra laws (paper §II-C/§II-D) — unit + property tests."""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import semiring as sr

ALL = list(sr.REGISTRY.values())
# Semirings whose ops are exact on float32 (max/min/add of small ints) —
# associativity/distributivity can be asserted exactly.
EXACT = [sr.MAX_PLUS, sr.MIN_PLUS, sr.MAX_MIN, sr.MIN_MAX]

small_ints = hnp.arrays(
    np.float32, (7,), elements=st.integers(-8, 8).map(float)
)


@pytest.mark.parametrize("s", ALL, ids=lambda s: s.name)
def test_additive_identity(s):
    a = jnp.array([-3.0, 0.0, 2.5, 7.0])
    if s.name in ("lor_land", "xor_and"):
        a = a != 0
    z = jnp.full_like(a, s.zero)
    np.testing.assert_array_equal(s.add(a, z), a)


@pytest.mark.parametrize("s", ALL, ids=lambda s: s.name)
def test_multiplicative_annihilator(s):
    a = jnp.array([-3.0, 0.0, 2.5, 7.0])
    if s.name in ("lor_land", "xor_and"):
        a = a != 0
    z = jnp.full_like(a, s.zero)
    out = s.mul(a, z)
    np.testing.assert_array_equal(out, z)


@hypothesis.given(a=small_ints, b=small_ints, c=small_ints)
@hypothesis.settings(deadline=None, max_examples=50)
def test_semiring_laws_property(a, b, c):
    for s in EXACT:
        aj, bj, cj = jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)
        # additive commutativity / associativity
        np.testing.assert_array_equal(s.add(aj, bj), s.add(bj, aj))
        np.testing.assert_array_equal(
            s.add(s.add(aj, bj), cj), s.add(aj, s.add(bj, cj))
        )
        # multiplicative associativity
        np.testing.assert_array_equal(
            s.mul(s.mul(aj, bj), cj), s.mul(aj, s.mul(bj, cj))
        )
        # distributivity
        np.testing.assert_array_equal(
            s.mul(aj, s.add(bj, cj)), s.add(s.mul(aj, bj), s.mul(aj, cj))
        )


@hypothesis.given(
    a=hnp.arrays(np.float32, (4, 5), elements=st.integers(-8, 8).map(float)),
    b=hnp.arrays(np.float32, (5, 3), elements=st.integers(-8, 8).map(float)),
    c=hnp.arrays(np.float32, (3, 2), elements=st.integers(-8, 8).map(float)),
)
@hypothesis.settings(deadline=None, max_examples=30)
def test_matmul_associativity_property(a, b, c):
    """(AB)C == A(BC) over exact semirings (paper §II-D)."""
    for s in EXACT:
        left = s.matmul(s.matmul(jnp.asarray(a), jnp.asarray(b)), jnp.asarray(c))
        right = s.matmul(jnp.asarray(a), s.matmul(jnp.asarray(b), jnp.asarray(c)))
        np.testing.assert_array_equal(left, right)


def test_plus_times_matches_matmul():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(6, 9)).astype(np.float32)
    b = rng.normal(size=(9, 4)).astype(np.float32)
    np.testing.assert_allclose(
        sr.PLUS_TIMES.matmul(jnp.asarray(a), jnp.asarray(b)),
        a @ b,
        rtol=1e-5,
    )


def test_max_plus_matmul_reference():
    a = jnp.array([[1.0, -2.0], [0.0, 3.0]])
    b = jnp.array([[0.5, 1.0], [2.0, -1.0]])
    out = sr.MAX_PLUS.matmul(a, b)
    ref = np.max(np.asarray(a)[:, :, None] + np.asarray(b)[None], axis=1)
    np.testing.assert_array_equal(out, ref)


def test_matvec_vecmat():
    a = jnp.arange(12.0).reshape(3, 4)
    v = jnp.arange(4.0)
    np.testing.assert_allclose(sr.PLUS_TIMES.matvec(a, v), a @ v, rtol=1e-6)
    w = jnp.arange(3.0)
    np.testing.assert_allclose(sr.PLUS_TIMES.vecmat(w, a), w @ a, rtol=1e-6)


def test_log_plus_is_smooth_max():
    a = jnp.array([[5.0, -50.0]])
    b = jnp.array([[1.0], [0.0]])
    out = sr.LOG_PLUS.matmul(a, b)
    assert abs(float(out[0, 0]) - 6.0) < 1e-3  # dominated by the max term


def test_registry_lookup():
    assert sr.get_semiring("max_plus") is sr.MAX_PLUS
    with pytest.raises(KeyError):
        sr.get_semiring("nope")


# --- full-registry semiring laws (property-based) ------------------------
#
# Every registry algebra — not just the four float-exact tropical ones —
# must satisfy the semiring axioms on its own operating domain: booleans
# for the lattice/GF(2) pairs, integer-valued floats elsewhere (exact in
# f32). ``log_plus`` ⊕ = logaddexp only associates/distributes to float
# roundoff, so it alone is compared with a tolerance.


def _domain(s, a):
    if s.name in ("lor_land", "xor_and"):
        return jnp.asarray(a) > 0
    return jnp.asarray(a)


def _law_assert(s, left, right):
    if s.name == "log_plus":
        np.testing.assert_allclose(
            np.asarray(left), np.asarray(right), rtol=1e-5, atol=1e-6
        )
    else:
        np.testing.assert_array_equal(np.asarray(left), np.asarray(right))


@hypothesis.given(a=small_ints, b=small_ints, c=small_ints)
@hypothesis.settings(deadline=None, max_examples=30)
def test_full_registry_add_monoid_laws(a, b, c):
    """⊕ commutative + associative for EVERY registry semiring."""
    for s in ALL:
        aj, bj, cj = _domain(s, a), _domain(s, b), _domain(s, c)
        _law_assert(s, s.add(aj, bj), s.add(bj, aj))
        _law_assert(s, s.add(s.add(aj, bj), cj), s.add(aj, s.add(bj, cj)))


@hypothesis.given(a=small_ints, b=small_ints, c=small_ints)
@hypothesis.settings(deadline=None, max_examples=30)
def test_full_registry_distributivity(a, b, c):
    """⊗ distributes over ⊕ (both sides) for EVERY registry semiring."""
    for s in ALL:
        aj, bj, cj = _domain(s, a), _domain(s, b), _domain(s, c)
        _law_assert(
            s, s.mul(aj, s.add(bj, cj)), s.add(s.mul(aj, bj), s.mul(aj, cj))
        )
        _law_assert(
            s, s.mul(s.add(bj, cj), aj), s.add(s.mul(bj, aj), s.mul(cj, aj))
        )


@hypothesis.given(a=small_ints)
@hypothesis.settings(deadline=None, max_examples=30)
def test_full_registry_annihilator_absorption(a):
    """a ⊗ 0̸ = 0̸ ⊗ a = 0̸ and a ⊕ 0̸ = a for EVERY registry semiring —
    the exact property that lets kernels skip missing/padded blocks."""
    for s in ALL:
        aj = _domain(s, a)
        zj = jnp.full_like(aj, bool(s.zero) if aj.dtype == bool else s.zero)
        _law_assert(s, s.mul(aj, zj), zj)
        _law_assert(s, s.mul(zj, aj), zj)
        _law_assert(s, s.add(aj, zj), aj)
