"""Block-CSR layout: round-trips, oracle, and the occupancy-exact kernel.

Kernel runs in ``interpret=True`` on CPU (identical kernel body to TPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dnn
from repro.core.semiring import get_semiring
from repro.kernels import bcsr_spmm as bcsr_kernel
from repro.kernels import ops, ref
from repro.sparse import BlockCSRMatrix, BlockSparseMatrix, ops as sops

ALL_SEMIRINGS = ["plus_times", "max_plus", "min_plus", "max_min", "min_max"]


def _skewed(seed=0, m=128, block=16, total=10, skew=0.9):
    return BlockCSRMatrix.random_skewed(
        seed, (m, m), (block, block), total_blocks=total, skew=skew
    )


# --- layout round-trips -----------------------------------------------------


def test_roundtrip_bsr_csr_dense():
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(0), (64, 96), (8, 8), blocks_per_row=4
    )
    c = BlockCSRMatrix.from_bsr(a)
    np.testing.assert_array_equal(c.to_dense(), a.to_dense())
    np.testing.assert_array_equal(c.to_bsr().to_dense(), a.to_dense())
    assert int(c.nnz_blocks) == int(a.nnz_blocks)
    assert c.total_blocks == int(a.nnz_blocks)  # no pad unless asked


def test_roundtrip_from_dense():
    rng = np.random.default_rng(1)
    dense = rng.normal(size=(48, 32)).astype(np.float32)
    dense[8:24, :] = 0.0  # two empty block-rows
    dense[:, 24:] = 0.0
    c = BlockCSRMatrix.from_dense(dense, (8, 8))
    np.testing.assert_array_equal(c.to_dense(), dense)
    counts = np.diff(np.asarray(c.row_ptr))
    assert counts[1] == 0 and counts[2] == 0


def test_csr_order_invariants():
    """row_id non-decreasing; col ascending within each row; row_ptr
    consistent with row_id."""
    c = _skewed(seed=3, total=17, skew=0.7)
    row_id = np.asarray(c.row_id)[np.asarray(c.valid)]
    cols = np.asarray(c.col_idx)[np.asarray(c.valid)]
    assert (np.diff(row_id) >= 0).all()
    for r in np.unique(row_id):
        rc = cols[row_id == r]
        assert (np.diff(rc) > 0).all()
    row_ptr = np.asarray(c.row_ptr)
    np.testing.assert_array_equal(
        np.bincount(row_id, minlength=c.n_row_blocks),
        row_ptr[1:] - row_ptr[:-1],
    )


def test_padded_tail_is_inert():
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(2), (32, 32), (8, 8), blocks_per_row=2
    )
    c = BlockCSRMatrix.from_bsr(a)
    cp = BlockCSRMatrix.from_bsr(a, pad_to=c.total_blocks + 6)
    assert cp.total_blocks == c.total_blocks + 6
    np.testing.assert_array_equal(cp.to_dense(), c.to_dense())
    b = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    np.testing.assert_allclose(
        ops.bcsr_spmm(cp, b), ops.bcsr_spmm(c, b), rtol=1e-6, atol=1e-6
    )


def test_from_bsr_rejects_too_small_pad():
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(4), (32, 32), (8, 8), blocks_per_row=2
    )
    with pytest.raises(ValueError):
        BlockCSRMatrix.from_bsr(a, pad_to=3)


def test_pytree_roundtrip_and_jit():
    c = _skewed(seed=5, total=8)
    leaves, treedef = jax.tree_util.tree_flatten(c)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(rebuilt.to_dense(), c.to_dense())

    b = jax.random.normal(jax.random.PRNGKey(6), (c.shape[1], 8))

    @jax.jit
    def f(a, b):
        return sops.bcsr_matmul(a, b)

    np.testing.assert_allclose(f(c, b), sops.bcsr_matmul(c, b), rtol=1e-6)


# --- oracle vs the ELL oracle ------------------------------------------------


@pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
def test_oracle_matches_ell_oracle(semiring):
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(7), (64, 96), (8, 8), blocks_per_row=4
    )
    c = BlockCSRMatrix.from_bsr(a)
    b = jax.random.normal(jax.random.PRNGKey(8), (96, 10))
    sr = get_semiring(semiring)
    np.testing.assert_allclose(
        sops.bcsr_matmul(c, b, sr),
        sops.bsr_matmul(a, b, sr),
        rtol=1e-5,
        atol=1e-5,
    )


# --- kernel vs oracle ---------------------------------------------------------

BCSR_CASES = [
    # (m, k, n, block, bpr)
    (64, 64, 32, (8, 8), 2),
    (128, 256, 48, (16, 16), 5),
    (256, 128, 100, (8, 16), 4),  # rectangular blocks + ragged n
]


@pytest.mark.parametrize("m,k,n,block,bpr", BCSR_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
def test_bcsr_spmm_plus_times(m, k, n, block, bpr, dtype):
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(m + k + n), (m, k), block, blocks_per_row=bpr
    ).astype(dtype)
    c = BlockCSRMatrix.from_bsr(a)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    tol = (
        dict(rtol=2e-2, atol=2e-2)
        if dtype == jnp.bfloat16
        else dict(rtol=2e-5, atol=2e-5)
    )
    np.testing.assert_allclose(
        np.asarray(ops.bcsr_spmm(c, b), np.float32),
        np.asarray(ref.bcsr_spmm_ref(c, b), np.float32),
        **tol,
    )


@pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
def test_bcsr_spmm_all_semirings(semiring):
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(9), (64, 64), (8, 8), blocks_per_row=3
    )
    c = BlockCSRMatrix.from_bsr(a)
    b = jax.random.normal(jax.random.PRNGKey(10), (64, 16))
    np.testing.assert_allclose(
        ops.bcsr_spmm(c, b, semiring_name=semiring),
        ref.bcsr_spmm_ref(c, b, semiring_name=semiring),
        rtol=2e-5,
        atol=2e-5,
    )


@pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
def test_bcsr_spmm_skewed_with_empty_rows(semiring):
    """Skewed occupancy incl. block-rows with zero stored blocks — the
    topology the ELL pad punishes worst and empty rows the CSR grid
    never visits (wrapper must fill them with the semiring zero)."""
    c = _skewed(seed=11, m=128, block=16, total=10, skew=0.9)
    counts = np.diff(np.asarray(c.row_ptr))
    assert (counts == 0).any(), "want at least one empty block-row"
    assert counts.max() >= 4 * max(int(np.median(counts)), 1), "want skew"
    b = jax.random.normal(jax.random.PRNGKey(12), (128, 8))
    np.testing.assert_allclose(
        ops.bcsr_spmm(c, b, semiring_name=semiring),
        ref.bcsr_spmm_ref(c, b, semiring_name=semiring),
        rtol=2e-5,
        atol=2e-5,
    )


@pytest.mark.parametrize("skewed", [False, True])
def test_bcsr_spmm_fused_epilogue(skewed):
    if skewed:
        c = _skewed(seed=13, m=128, block=16, total=9, skew=0.85)
        m, k = c.shape
    else:
        a = BlockSparseMatrix.random(
            jax.random.PRNGKey(14), (64, 64), (8, 8), blocks_per_row=3
        )
        c = BlockCSRMatrix.from_bsr(a)
        m, k = c.shape
    b = jax.random.normal(jax.random.PRNGKey(15), (k, 24))
    bias = jax.random.normal(jax.random.PRNGKey(16), (m,))
    out = ops.bcsr_spmm(c, b, bias, fuse_bias_relu=True)
    np.testing.assert_allclose(
        out,
        ref.bcsr_spmm_ref(c, b, bias=bias, fuse_bias_relu=True),
        rtol=2e-5,
        atol=2e-5,
    )
    assert float(out.min()) >= 0.0


def test_bcsr_matches_ell_kernel():
    """Cross-kernel: CSR grid result == ELL grid result on same matrix."""
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(17), (64, 64), (8, 8), blocks_per_row=3
    )
    c = BlockCSRMatrix.from_bsr(a)
    b = jax.random.normal(jax.random.PRNGKey(18), (64, 32))
    np.testing.assert_allclose(
        ops.bcsr_spmm(c, b), ops.bsr_spmm(a, b), rtol=2e-5, atol=2e-5
    )


def test_grid_steps_scale_with_true_nnz():
    """The tentpole claim: on a skewed topology at equal nnz, the CSR
    grid runs strictly fewer steps than the ELL grid."""
    c = _skewed(seed=19, m=256, block=16, total=20, skew=0.9)
    a = c.to_bsr()
    n = 128
    nrb, mbpr = a.col_idx.shape
    ell_steps = nrb * mbpr * (n // 128)
    csr_steps = bcsr_kernel.grid_steps(c, n, block_n=128)
    assert csr_steps == c.total_blocks * (n // 128)
    assert csr_steps < ell_steps, (csr_steps, ell_steps)
    # and the two kernels agree on the result
    b = jax.random.normal(jax.random.PRNGKey(20), (256, n))
    np.testing.assert_allclose(
        ops.bcsr_spmm(c, b), ops.bsr_spmm(a, b), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("semiring", ["log_plus", "lor_land", "xor_and"])
def test_oracle_exotic_semirings(semiring):
    """Layouts stay interchangeable on the generic-⊕ semirings too."""
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(40), (32, 32), (8, 8), blocks_per_row=2
    )
    c = BlockCSRMatrix.from_bsr(a)
    b = (jax.random.uniform(jax.random.PRNGKey(41), (32, 6)) > 0.5).astype(
        jnp.float32
    )
    sr = get_semiring(semiring)
    np.testing.assert_allclose(
        np.asarray(sops.bcsr_matmul(c, b, sr), np.float32),
        np.asarray(sops.bsr_matmul(a, b, sr), np.float32),
        rtol=1e-5,
        atol=1e-5,
    )


# --- transpose ----------------------------------------------------------------


def test_transpose_matches_dense():
    c = _skewed(seed=30, m=128, block=16, total=12, skew=0.8)
    t = c.transpose()
    np.testing.assert_array_equal(
        np.asarray(t.to_dense()), np.asarray(c.to_dense()).T
    )
    assert t.shape == (c.shape[1], c.shape[0])
    # canonical CSR order is preserved
    row_id = np.asarray(t.row_id)[np.asarray(t.valid)]
    assert (np.diff(row_id) >= 0).all()


def test_transpose_is_jittable_with_padding():
    a = BlockSparseMatrix.random(
        jax.random.PRNGKey(31), (64, 96), (8, 16), blocks_per_row=3
    )
    c = BlockCSRMatrix.from_bsr(a, pad_to=int(a.nnz_blocks) + 4)
    t = jax.jit(lambda x: x.transpose())(c)
    np.testing.assert_array_equal(
        np.asarray(t.to_dense()), np.asarray(c.to_dense()).T
    )
    # transposed matrix still works through the kernel wrapper
    b = jax.random.normal(jax.random.PRNGKey(32), (64, 8))
    np.testing.assert_allclose(
        ops.bcsr_spmm(t, b),
        np.asarray(c.to_dense()).T @ np.asarray(b),
        rtol=1e-5,
        atol=1e-5,
    )


def test_graphblas_vxm_and_transpose_accept_bcsr():
    from repro.core import graphblas as gb

    c = _skewed(seed=33, m=64, block=8, total=14, skew=0.5)
    v = jax.random.normal(jax.random.PRNGKey(34), (64,))
    np.testing.assert_allclose(
        gb.vxm(v, c),
        np.asarray(v) @ np.asarray(c.to_dense()),
        rtol=1e-5,
        atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(gb.transpose(c).to_dense()),
        np.asarray(c.to_dense()).T,
    )


# --- dispatch ----------------------------------------------------------------


def test_preferred_layout_dispatch():
    regular = BlockSparseMatrix.random(
        jax.random.PRNGKey(21), (64, 64), (8, 8), blocks_per_row=4
    )
    assert dnn.preferred_layout(regular) == "ell"
    assert isinstance(dnn.to_preferred_layout(regular), BlockSparseMatrix)

    skew_dense = np.zeros((64, 64), np.float32)
    skew_dense[:8, :] = 1.0  # one full row-block, rest nearly empty
    skew_dense[8:16, :8] = 1.0
    skewed = BlockSparseMatrix.from_dense(skew_dense, (8, 8))
    assert dnn.preferred_layout(skewed) == "bcsr"
    assert isinstance(dnn.to_preferred_layout(skewed), BlockCSRMatrix)


def test_dnn_layer_bcsr_matches_bsr():
    w = BlockSparseMatrix.random(
        jax.random.PRNGKey(22), (32, 32), (8, 8), blocks_per_row=2
    )
    wc = BlockCSRMatrix.from_bsr(w)
    y = jax.random.uniform(jax.random.PRNGKey(23), (32, 8))
    b = jax.random.uniform(jax.random.PRNGKey(24), (32,))
    for fused in (True, False):
        np.testing.assert_allclose(
            dnn.dnn_layer(wc, y, b, fused=fused),
            dnn.dnn_layer(w, y, b, fused=fused),
            rtol=1e-5,
            atol=1e-5,
        )
