"""Edge-case coverage for the fault-tolerance primitives themselves:
StragglerPolicy's rolling window, Supervisor's restore/replay path, and
checkpoint atomicity / retention (docs/robustness.md). System-level
wiring into sparse training is in tests/test_train_resilience.py."""

import json
import os
import tempfile

import jax.numpy as jnp
import pytest

from repro.train import checkpoint
from repro.train.fault_tolerance import StragglerPolicy, Supervisor


# ---------------------------------------------------------------------
# StragglerPolicy windows
# ---------------------------------------------------------------------


def test_straggler_warmup_never_fires():
    """< 4 observations = no median worth trusting: even an absurd
    outlier cannot fire during warmup."""
    p = StragglerPolicy(deadline_factor=2.0, evict_after=1)
    assert p.observe(1.0) is False
    assert p.observe(1000.0) is False
    assert p.observe(1000.0) is False


def test_straggler_consecutive_resets_on_fast_step():
    p = StragglerPolicy(deadline_factor=2.0, evict_after=2)
    for _ in range(6):
        p.observe(1.0)
    assert p.observe(10.0) is False  # 1st consecutive mark
    assert p.observe(1.0) is False  # fast step resets the streak
    assert p.observe(10.0) is False  # back to 1st mark
    assert p.observe(10.0) is True  # 2nd consecutive → fire


def test_straggler_window_eviction_shifts_median():
    """Old observations leave the rolling window: once the window is
    full of slow steps, a slow step is no longer an outlier."""
    p = StragglerPolicy(deadline_factor=2.0, evict_after=1, window=4)
    for _ in range(4):
        p.observe(1.0)
    assert p.observe(10.0) is True  # outlier vs the fast window
    for _ in range(4):  # window is now [10, 10, 10, 10]
        p.observe(10.0)
    assert p.observe(10.0) is False  # median caught up — not straggling


def test_straggler_evict_after_one_fires_immediately():
    p = StragglerPolicy(deadline_factor=3.0, evict_after=1)
    for _ in range(4):
        p.observe(1.0)
    assert p.observe(3.01) is True  # just past factor × median


# ---------------------------------------------------------------------
# Supervisor restart path
# ---------------------------------------------------------------------


def _counting_supervisor(d, fail_at, *, ckpt_interval=2, max_restarts=3):
    calls = []

    def step_fn(state, step):
        calls.append(step)
        if step == fail_at and calls.count(step) == 1:
            raise RuntimeError("boom")
        return {"x": state["x"] + 1.0}

    sup = Supervisor(
        step_fn=step_fn,
        save_state=lambda s: s,
        load_state=lambda s: s,
        ckpt_dir=d,
        ckpt_interval=ckpt_interval,
        max_restarts=max_restarts,
    )
    return sup, calls


def test_supervisor_replays_from_manifest_step():
    with tempfile.TemporaryDirectory() as d:
        state = {"x": jnp.zeros(())}
        checkpoint.save(d, 0, state)
        sup, calls = _counting_supervisor(d, fail_at=5)
        out = sup.run(state, 8)
        # fault at step 5 → restore ckpt at step 4 → replay 4, 5, ...;
        # the uncommitted step-5 update is discarded, the final value
        # counts exactly 8 committed steps.
        assert float(out["x"]) == 8.0
        assert calls == [0, 1, 2, 3, 4, 5, 4, 5, 6, 7]
        assert sup.history == [(5, "fault: RuntimeError")]


def test_supervisor_fault_with_no_checkpoint_propagates():
    with tempfile.TemporaryDirectory() as d:
        sup, _ = _counting_supervisor(d, fail_at=0)
        with pytest.raises(RuntimeError, match="boom"):
            sup.run({"x": jnp.zeros(())}, 4)


def test_supervisor_on_straggler_hook_fires():
    with tempfile.TemporaryDirectory() as d:
        import time as _time

        hits = []
        # baseline steps sleep a measurable amount so the rolling median
        # is dominated by the sleep, not by scheduler jitter
        sup = Supervisor(
            step_fn=lambda s, i: (_time.sleep(0.1 if i == 6 else 0.01), s)[1],
            save_state=lambda s: s,
            load_state=lambda s: s,
            ckpt_dir=d,
            ckpt_interval=100,
            straggler=StragglerPolicy(deadline_factor=3.0, evict_after=1),
            on_straggler=hits.append,
        )
        sup.run({"x": jnp.zeros(())}, 8)
        assert 6 in hits
        assert (6, "straggler") in sup.history


# ---------------------------------------------------------------------
# checkpoint atomicity + retention
# ---------------------------------------------------------------------


def test_save_cleans_stale_tmp_from_crashed_writer():
    """A crash mid-write leaves tmp.<step> behind; the next save of the
    same step must clear it and publish atomically."""
    with tempfile.TemporaryDirectory() as d:
        stale = os.path.join(d, "tmp.3")
        os.makedirs(stale)
        with open(os.path.join(stale, "garbage"), "w") as f:
            f.write("half-written")
        path = checkpoint.save(d, 3, {"w": jnp.ones((2, 2))})
        assert not os.path.exists(stale)  # tmp was consumed by replace
        assert os.path.isdir(path)
        assert not os.path.exists(os.path.join(path, "garbage"))
        restored, manifest = checkpoint.restore(
            d, {"w": jnp.zeros((2, 2))}, step=3
        )
        assert manifest["step"] == 3
        assert float(restored["w"].sum()) == 4.0


def test_save_replaces_existing_step_dir():
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, {"w": jnp.zeros((2,))})
        checkpoint.save(d, 1, {"w": jnp.ones((2,))})  # same step, new data
        restored, _ = checkpoint.restore(d, {"w": jnp.zeros((2,))})
        assert float(restored["w"].sum()) == 2.0
        # exactly one published dir, no tmp residue
        assert sorted(os.listdir(d)) == ["step_00000001"]


def test_retention_keep_every_protects_multiples():
    with tempfile.TemporaryDirectory() as d:
        for s in range(1, 11):
            checkpoint.save(d, s, {"w": jnp.zeros(())})
        checkpoint.retention(d, keep_last=2, keep_every=4)
        kept = sorted(
            int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_")
        )
        assert kept == [4, 8, 9, 10]  # multiples of 4 + newest 2
        assert checkpoint.latest_step(d) == 10


def test_retention_and_latest_on_missing_dir_are_noops():
    missing = os.path.join(tempfile.gettempdir(), "no-such-ckpt-dir-xyz")
    checkpoint.retention(missing, keep_last=1)  # must not raise
    assert checkpoint.latest_step(missing) is None


def test_manifest_carries_metadata_and_keys():
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(
            d, 2, {"a": jnp.zeros((2,)), "b": jnp.ones((3,))},
            metadata={"arch": "sparse-mlp"},
        )
        with open(os.path.join(d, "step_00000002", "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["step"] == 2
        assert manifest["arch"] == "sparse-mlp"
        assert manifest["num_leaves"] == 2
        assert manifest["keys"] == ["a", "b"]
