"""Trainer / optimizer / checkpoint / fault-tolerance system tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, graphblas_mlp
from repro.data import Prefetcher, SyntheticLM
from repro.models.model import Model
from repro.train import adamw, checkpoint, make_train_step, sgd
from repro.train.fault_tolerance import StragglerPolicy, Supervisor
from repro.train.optimizer import warmup_cosine
from repro.train.trainer import TrainState, init_train_state


@pytest.fixture(scope="module")
def small():
    cfg = get_config("llama3.2-1b").scaled_down()
    model = Model(cfg)
    opt = adamw(3e-3, weight_decay=0.0)
    state = init_train_state(model, opt, jax.random.key(0))
    return cfg, model, opt, state


def _batch(cfg, i, b=8, s=32):
    data = SyntheticLM(cfg.vocab_size, s, b, seed=1)
    return jax.tree.map(jnp.asarray, data.batch(i))


def test_loss_decreases(small):
    cfg, model, opt, state = small
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for i in range(25):
        state, m = step(state, _batch(cfg, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::6]


def test_microbatch_equivalence(small):
    """Gradient accumulation must match the full-batch step numerically.

    Compared under SGD (linear in the gradients): AdamW's m/√v is a sign
    function near zero, so bf16 rounding differences between the two
    batch slicings flip individual updates by ±2·lr — a property of the
    optimizer, not an accumulation bug.
    """
    cfg, model, _, state0 = small
    opt = sgd(1e-2, momentum=0.0)
    state = init_train_state(model, opt, jax.random.key(0))
    b = _batch(cfg, 0)
    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s4 = jax.jit(make_train_step(model, opt, microbatches=4))
    st1, m1 = s1(state, b)
    st4, m4 = s4(state, b)
    np.testing.assert_allclose(
        float(m1["loss"]), float(m4["loss"]), rtol=1e-4
    )
    for a, c in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st4.params)):
        if jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(c), rtol=2e-3, atol=2e-4
            )


def test_schedule():
    sched = warmup_cosine(1.0, 10, 110, final_frac=0.1)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sched(jnp.asarray(110))) == pytest.approx(0.1, abs=1e-3)
    assert float(sched(jnp.asarray(60))) < 1.0


def test_sgd_momentum_runs(small):
    cfg, model, _, _ = small
    opt = sgd(1e-2)
    state = init_train_state(model, opt, jax.random.key(1))
    step = jax.jit(make_train_step(model, opt))
    state, m = step(state, _batch(cfg, 0))
    assert bool(jnp.isfinite(m["loss"]))


def test_checkpoint_roundtrip_and_retention(small):
    cfg, model, opt, state = small
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 10):
            checkpoint.save(d, s, state, metadata={"arch": cfg.name})
        checkpoint.retention(d, keep_last=2, keep_every=10)
        steps = sorted(
            int(x.split("_")[1]) for x in os.listdir(d) if x.startswith("step_")
        )
        assert steps == [4, 10]
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state
        )
        restored, manifest = checkpoint.restore(d, like)
        assert manifest["step"] == 10
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(small):
    cfg, model, opt, state = small
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, {"w": jnp.zeros((4, 4))})
        with pytest.raises(ValueError):
            checkpoint.restore(d, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)})


def test_supervisor_restores_after_fault(small):
    cfg, model, opt, state = small
    step_jit = jax.jit(make_train_step(model, opt))
    calls = {"n": 0}

    def step(st, i):
        calls["n"] += 1
        if calls["n"] == 5:
            raise RuntimeError("injected failure")
        st2, _ = step_jit(st, _batch(cfg, i))
        return st2

    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(
            step_fn=step,
            save_state=lambda s: s,
            load_state=lambda t: TrainState(*t),
            ckpt_dir=d,
            ckpt_interval=2,
        )
        final = sup.run(state, 8)
        assert any("fault" in h[1] for h in sup.history)
        assert int(final.opt.step) == 8


def test_supervisor_gives_up_after_max_restarts(small):
    cfg, model, opt, state = small

    def bad_step(st, i):
        raise RuntimeError("always fails")

    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 0, state)
        sup = Supervisor(
            step_fn=bad_step,
            save_state=lambda s: s,
            load_state=lambda t: TrainState(*t),
            ckpt_dir=d,
            max_restarts=2,
        )
        with pytest.raises(RuntimeError, match="exceeded"):
            sup.run(state, 3)


def test_straggler_policy():
    p = StragglerPolicy(deadline_factor=2.0, evict_after=2)
    fired = []
    for t in [1.0, 1.0, 1.0, 1.0, 1.0, 5.0, 5.0, 1.0]:
        fired.append(p.observe(t))
    assert fired[6] and not any(fired[:6])  # fires on 2nd consecutive slow
    assert not fired[7]


def test_sparse_mlp_trainable():
    """The paper's sparse network retrains: grads flow into BSR blocks."""
    cfg = graphblas_mlp.make_config(m=64, num_layers=2, inverse_sparsity=2, block=16)
    model = Model(cfg)
    params = model.sparsify(model.init(jax.random.key(0)))
    opt = adamw(1e-2, weight_decay=0.0)
    state = TrainState(params, opt.init(params))
    step = jax.jit(make_train_step(model, opt))
    batch = {
        "inputs": jax.random.uniform(jax.random.key(1), (8, 64)),
        "labels": jax.random.randint(jax.random.key(2), (8, 1), 0, 64),
    }
    losses = []
    for _ in range(20):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2
    assert losses[-1] == losses[-1]  # finite


def test_prefetcher_deterministic_order():
    data = SyntheticLM(128, 8, 4, seed=3)
    pf = Prefetcher(data, depth=2)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    pf.close()
    assert (s0, s1) == (0, 1)
    np.testing.assert_array_equal(b0["inputs"], data.batch(0)["inputs"])
    np.testing.assert_array_equal(b1["inputs"], data.batch(1)["inputs"])
