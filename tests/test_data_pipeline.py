"""Data pipeline invariants (hypothesis property tests): determinism in
(seed, step), per-host shard disjointness-by-construction, learnability
structure, and the restart property the fault-tolerance design relies on."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import SyntheticLM


@given(
    seed=st.integers(0, 2**31 - 1),
    step=st.integers(0, 10_000),
    vocab=st.sampled_from([64, 1000, 32768]),
)
@settings(max_examples=25, deadline=None)
def test_deterministic_in_seed_and_step(seed, step, vocab):
    a = SyntheticLM(vocab, 16, 4, seed=seed).batch(step)
    b = SyntheticLM(vocab, 16, 4, seed=seed).batch(step)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


@given(step=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_steps_differ(step):
    d = SyntheticLM(1024, 16, 4, seed=0)
    assert not np.array_equal(d.batch(step)["inputs"], d.batch(step + 1)["inputs"])


@given(
    num_hosts=st.sampled_from([2, 4]),
    step=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_hosts_get_different_shards(num_hosts, step):
    batches = [
        SyntheticLM(
            1024, 16, 8, seed=0, host_id=h, num_hosts=num_hosts
        ).batch(step)
        for h in range(num_hosts)
    ]
    for i in range(num_hosts):
        assert batches[i]["inputs"].shape[0] == 8 // num_hosts
        for j in range(i + 1, num_hosts):
            assert not np.array_equal(
                batches[i]["inputs"], batches[j]["inputs"]
            )


def test_labels_are_shifted_inputs():
    b = SyntheticLM(512, 32, 4, seed=1).batch(0)
    # next-token structure: labels[t] continues inputs — the affine map
    # holds for non-noise positions
    a = 6364136223846793005 % 512 | 1
    c = 1442695040888963407 % 512
    pred = (a * b["inputs"].astype(np.int64) + c) % 512
    frac = (pred == b["labels"]).mean()
    assert frac > 0.85  # noise = 5%


def test_vocab_bounds():
    b = SyntheticLM(100, 16, 4, seed=2).batch(7)
    assert b["inputs"].min() >= 0 and b["inputs"].max() < 100
    assert b["labels"].min() >= 0 and b["labels"].max() < 100


def test_embeddings_mode():
    d = SyntheticLM(100, 8, 4, seed=0, input_mode="embeddings", d_model=32)
    b = d.batch(0)
    assert b["inputs"].shape == (4, 8, 32)
    assert b["inputs"].dtype == np.float32
