"""Continuous-batching scheduler: packing invariants, trace determinism,
ServeStats accounting against hand-computed values, starvation freedom,
and engine routing through the batcher."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dnn
from repro.serve import (
    ContinuousBatcher,
    RequestQueue,
    SparseDNNEngine,
    poissonish_trace,
    serve_trace_static,
)
from repro.sparse import BlockCSRMatrix, BlockSparseMatrix


def _stack(key, L, m, bpr=2, block=16):
    ks = jax.random.split(key, L)
    ws = [
        BlockSparseMatrix.random(k, (m, m), (block, block), blocks_per_row=bpr)
        for k in ks
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    return ws, bs


def _col(seed, m):
    return jax.random.uniform(jax.random.PRNGKey(seed), (m,), jnp.float32)


# ---------------------------------------------------------------------
# RequestQueue
# ---------------------------------------------------------------------


def test_queue_fifo_within_priority():
    q = RequestQueue()
    ids = [q.submit(_col(i, 8), now=0) for i in range(5)]
    got = [r.rid for r in q.pop_batch(3, now=0)]
    assert got == ids[:3]
    assert len(q) == 2


def test_queue_priority_and_deadline_order():
    q = RequestQueue()
    r_low = q.submit(_col(0, 8), now=0, priority=5)
    r_dead_late = q.submit(_col(1, 8), now=0, priority=1, deadline=90)
    r_dead_soon = q.submit(_col(2, 8), now=0, priority=1, deadline=10)
    r_urgent = q.submit(_col(3, 8), now=0, priority=0)
    got = [r.rid for r in q.pop_batch(4, now=0)]
    assert got == [r_urgent, r_dead_soon, r_dead_late, r_low]


def test_queue_aging_prevents_starvation():
    """A low-priority request overtakes a stream of fresh high-priority
    arrivals once it has aged enough — no request waits forever."""
    q = RequestQueue(age_every=4)
    old = q.submit(_col(0, 8), now=0, priority=3)
    # effective priority after waiting 12 ticks: 3 - 12//4 = 0, and the
    # older arrival breaks the tie against any fresh priority-0 request
    fresh = q.submit(_col(1, 8), now=12, priority=0)
    got = [r.rid for r in q.pop_batch(1, now=12)]
    assert got == [old] != [fresh]


# ---------------------------------------------------------------------
# packing invariants
# ---------------------------------------------------------------------


def test_batcher_packing_invariants():
    """slots ≤ batch_size; padded width is the smallest tile multiple
    covering the occupancy; every slot is tagged with its request id."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(0), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    b = ContinuousBatcher(eng, batch_size=4, min_fill=0.0, max_wait=0)
    rids = [b.submit(_col(10 + i, m)) for i in range(11)]
    while b.completed < 11:
        b.step(force=True)
    stats = b.stats()
    assert stats.requests == 11
    served = []
    for rec in stats.steps:
        assert 0 < rec.occupancy <= 4
        assert rec.padded_width == -(-rec.occupancy // 8) * 8
        assert rec.padded_width - rec.occupancy < 8
        assert len(rec.request_ids) == rec.occupancy
        served.extend(rec.request_ids)
    # every request served exactly once, in FIFO order for equal priority
    assert served == rids
    # capacity 4 over 11 requests → at least ceil(11/4) = 3 steps
    assert stats.engine_steps >= 3


def test_batcher_no_starvation_under_load():
    """A background-priority request completes despite a continuous
    stream of priority-0 arrivals saturating the batch each tick."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(1), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=4)
    b = ContinuousBatcher(eng, batch_size=2, min_fill=0.0, age_every=3)
    victim = b.submit(_col(0, m), priority=9)
    for t in range(40):
        b.submit(_col(100 + t, m), priority=0)
        b.submit(_col(200 + t, m), priority=0)
        b.step()
        if victim in b.stats().latencies:
            break
    assert victim in b.stats().latencies, "aged request never served"


def test_batcher_mid_flight_join_and_eviction():
    """Requests arriving between steps join the next panel; completed
    requests leave their slots (results retrievable, slots reused)."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(2), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=4)
    b = ContinuousBatcher(eng, batch_size=8, min_fill=0.0, max_wait=0)
    first = b.submit(_col(1, m))
    b.step()
    assert b.completed == 1  # evicted at the step boundary
    late = b.submit(_col(2, m))  # joins mid-stream, next panel
    rec = b.step()
    assert rec.request_ids == (late,)
    np.testing.assert_allclose(
        np.asarray(b.result(first)),
        np.asarray(
            dnn.dnn_forward(ws, bs, _col(1, m)[:, None], fused=True)[:, 0]
        ),
        rtol=1e-5,
        atol=1e-5,
    )


def test_min_fill_holds_then_max_wait_forces():
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(3), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=4)
    b = ContinuousBatcher(eng, batch_size=8, min_fill=0.5, max_wait=3)
    b.submit(_col(1, m))  # 1 < 0.5·8 → held
    assert b.step() is None
    assert b.step() is None
    assert b.step() is None
    rec = b.step()  # waited 3 ticks → forced out
    assert rec is not None and rec.occupancy == 1
    assert b.stats().latency_max == 4


# ---------------------------------------------------------------------
# trace determinism
# ---------------------------------------------------------------------


def test_poissonish_trace_deterministic():
    t1 = poissonish_trace(50, m=16, lam=2.5, burst_every=8, burst_size=6, seed=3)
    t2 = poissonish_trace(50, m=16, lam=2.5, burst_every=8, burst_size=6, seed=3)
    assert [len(a) for a in t1] == [len(a) for a in t2]
    assert sum(len(a) for a in t1) == 50
    for a1, a2 in zip(t1, t2):
        for c1, c2 in zip(a1, a2):
            np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    t3 = poissonish_trace(50, m=16, lam=2.5, burst_every=8, burst_size=6, seed=4)
    assert [len(a) for a in t1] != [len(a) for a in t3]


def test_trace_rejects_arrival_free_parameters():
    """lam=0 with no bursts can never terminate — must raise, not hang."""
    with pytest.raises(ValueError):
        poissonish_trace(10, m=8, lam=0.0)
    with pytest.raises(ValueError):
        poissonish_trace(10, m=8, lam=0.0, burst_every=0, burst_size=5)


def test_trace_bursts_land_on_schedule():
    trace = poissonish_trace(
        60, m=8, lam=0.0, burst_every=4, burst_size=5, seed=0
    )
    counts = [len(a) for a in trace]
    assert all(c == 0 for i, c in enumerate(counts) if i % 4 != 3)
    assert all(c == 5 for i, c in enumerate(counts) if i % 4 == 3)


# ---------------------------------------------------------------------
# ServeStats accounting vs hand-computed values
# ---------------------------------------------------------------------


def test_servestats_hand_computed():
    """3 + 1 requests, capacity 4, tile 8: one panel of width 8 holding
    4 rows → pad fraction 1 − 4/8, grid steps = L·nrb·mbpr·n_tiles."""
    m, L, bpr = 32, 2, 2
    ws, bs = _stack(jax.random.PRNGKey(4), L, m, bpr=bpr)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    b = ContinuousBatcher(eng, batch_size=4, min_fill=1.0, max_wait=10)
    for i in range(3):
        b.submit(_col(i, m))
    b.step()  # 3 < capacity 4 and wait < 10 → held
    b.submit(_col(9, m))
    rec = b.step()  # 4 = capacity → dispatched
    assert rec.occupancy == 4 and rec.padded_width == 8
    s = b.stats()
    assert s.rows_served == 4
    assert s.padded_slots == 8
    assert s.pad_slot_fraction == pytest.approx(0.5)
    # grid steps: padded width 8 → one 8-wide tile; per layer nrb·mbpr
    nrb = m // 16
    expect = L * nrb * bpr * 1
    assert rec.grid_steps == expect == s.grid_steps_total
    assert s.grid_steps_per_row == pytest.approx(expect / 4)
    # latencies: 3 early requests waited one held tick (2), late one 1
    assert sorted(s.latencies.values()) == [1, 2, 2, 2]
    assert s.latency_mean == pytest.approx(7 / 4)
    assert s.latency_max == 2
    assert s.idle_ticks == 1  # the held tick


def test_deadline_miss_accounting():
    """A request that cannot make its deadline is SHED at packing time —
    a deadline miss, never a (late) completion. docs/robustness.md."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(5), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=4)
    b = ContinuousBatcher(eng, batch_size=4, min_fill=1.0, max_wait=5)
    rid0 = b.submit(_col(0, m), deadline=1)  # admissible at tick 0, but
    b.submit(_col(1, m), deadline=50)  # min_fill holds the panel...
    for _ in range(6):
        b.step()
    s = b.stats()
    # ...so at tick 1 its earliest completion is tick 2 > deadline 1:
    # shed as inadmissible, never dispatched, counted as a miss.
    assert s.requests == 1
    assert s.deadline_misses == 1
    assert s.faults.shed_inadmissible == 1
    assert s.faults.shed_expired == 0
    assert s.goodput == pytest.approx(0.5)
    assert "shed" in b.failures[rid0]
    assert rid0 not in s.latencies


def test_deadline_enforcement_off_serves_late():
    """enforce_deadlines=False restores the legacy record-only miss."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(5), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=4)
    b = ContinuousBatcher(
        eng, batch_size=4, min_fill=1.0, max_wait=5,
        enforce_deadlines=False,
    )
    b.submit(_col(0, m), deadline=1)
    b.submit(_col(1, m), deadline=50)
    for _ in range(6):
        b.step()
    s = b.stats()
    assert s.requests == 2  # served anyway, just late
    assert s.deadline_misses == 1
    assert s.faults.shed == 0
    assert s.faults.completed_late == 1
    assert s.goodput == pytest.approx(0.5)


def test_static_baseline_accounting():
    """Static aligned batching: every tick pays a full aligned panel."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(6), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=16)
    trace = [
        [_col(1, m)],
        [],
        [_col(2, m), _col(3, m)],
    ]
    s = serve_trace_static(eng, trace)
    assert s.engine_steps == 2  # empty tick dispatches nothing
    assert s.rows_served == 3
    assert s.padded_slots == 32  # two 16-wide aligned panels
    assert s.pad_slot_fraction == pytest.approx(1 - 3 / 32)
    assert all(v == 1 for v in s.latencies.values())


def test_continuous_beats_static_on_bursty_trace():
    """The acceptance-criterion shape, small: same weights, same trace,
    strictly lower pad-slot fraction and grid steps for continuous."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(7), 2, m)
    trace = poissonish_trace(
        40, m=m, lam=2.0, burst_every=6, burst_size=8, seed=11
    )
    static = serve_trace_static(
        SparseDNNEngine(ws, bs, batch_align=32), trace
    )
    b = ContinuousBatcher(
        SparseDNNEngine(ws, bs, batch_align=8),
        batch_size=32,
        min_fill=0.25,
        max_wait=3,
    )
    cont = b.run_trace(trace)
    assert cont.requests == static.requests == 40
    assert cont.pad_slot_fraction < static.pad_slot_fraction
    assert cont.grid_steps_total < static.grid_steps_total


# ---------------------------------------------------------------------
# engine routing through the batcher
# ---------------------------------------------------------------------


def test_batcher_routes_resident_path_when_eligible():
    m = 64
    ws, bs = _stack(jax.random.PRNGKey(8), 3, m)
    assert dnn.resident_eligible(ws)
    eng = SparseDNNEngine(ws, bs, batch_align=8)
    b = ContinuousBatcher(eng, batch_size=8)
    b.submit(_col(0, m))
    rec = b.step(force=True)
    assert rec.resident is True
    assert rec.pallas_calls == 1  # the whole stack in one kernel call


def test_batcher_layered_path_on_mixed_layout():
    m = 64
    ws, bs = _stack(jax.random.PRNGKey(9), 2, m)
    mixed = [BlockCSRMatrix.from_bsr(ws[0]), ws[1]]
    eng = SparseDNNEngine(mixed, bs, batch_align=8)
    b = ContinuousBatcher(eng, batch_size=8)
    b.submit(_col(0, m))
    rec = b.step(force=True)
    assert rec.resident is False
    assert rec.pallas_calls == 2  # one kernel call per layer


def test_batcher_differentiable_engine():
    """differentiable=True engines route around the VJP-less resident
    kernel; the batcher serves them unchanged."""
    m = 64
    ws, bs = _stack(jax.random.PRNGKey(10), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=8, differentiable=True)
    b = ContinuousBatcher(eng, batch_size=8)
    rid = b.submit(_col(3, m))
    rec = b.step(force=True)
    assert rec.resident is False
    np.testing.assert_allclose(
        np.asarray(b.result(rid)),
        np.asarray(
            dnn.dnn_forward(ws, bs, _col(3, m)[:, None], fused=True)[:, 0]
        ),
        rtol=1e-5,
        atol=1e-5,
    )


def test_batcher_outputs_match_reference_across_panels():
    """Every request's column equals the one-shot forward regardless of
    which panel it was packed into."""
    m = 32
    ws, bs = _stack(jax.random.PRNGKey(11), 2, m)
    eng = SparseDNNEngine(ws, bs, batch_align=4)
    b = ContinuousBatcher(eng, batch_size=3, min_fill=0.0)
    cols = {b.submit(_col(40 + i, m)): _col(40 + i, m) for i in range(7)}
    b.drain()
    for rid, col in cols.items():
        np.testing.assert_allclose(
            np.asarray(b.result(rid)),
            np.asarray(dnn.dnn_forward(ws, bs, col[:, None], fused=True)[:, 0]),
            rtol=1e-5,
            atol=1e-5,
        )
