"""Mesh-sharded sparse stacks: the balanced block-CSR partitioner,
PartitionSpec resolution through the sharding rule table, plan-cache
keying on the mesh fingerprint, and the shard_map execution path.

Partitioner and cache-keying tests are device-free / single-device.
Multi-device numerics (the acceptance bar: sharded forward/backward ==
single-device plan path, serve parity, per-shard bills summing to the
unsharded bill) run twice: in a SUBPROCESS with 8 fake host devices so
the tier-1 suite covers them on any machine (dry-run contract — the
main process keeps its single-device view), and in-process when the
interpreter already has ≥ 8 devices (the CI multi-device job sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import plan as PL
from repro.core import dnn
from repro.distribution.sharding import (
    ShardingRules,
    mesh_shard_count,
    row_block_axes,
    sharded_csr_pspecs,
)
from repro.launch.mesh import make_row_blocks_mesh
from repro.serve import SparseDNNEngine
from repro.sparse import (
    BlockCSRMatrix,
    BlockSparseMatrix,
    partition_block_csr,
    stack_transpose_plans,
)


def _csr_stack(seed, L, m, bpr=4, block=16, scale=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), L)
    ws = []
    for k in ks:
        w = BlockSparseMatrix.random(
            k, (m, m), (block, block), blocks_per_row=bpr,
            minval=-0.5, maxval=0.5,
        )
        if scale:
            w = w.map_blocks(lambda x: x / (bpr * block) ** 0.5)
        ws.append(BlockCSRMatrix.from_bsr(w))
    bs = [jnp.full((m,), 0.01 * i, jnp.float32) for i in range(L)]
    return ws, bs


# ---------------------------------------------------------------------
# partitioner (host-side — no devices involved)
# ---------------------------------------------------------------------


def test_partition_balances_and_reassembles():
    a = BlockCSRMatrix.random_skewed(
        seed=3, shape=(128, 128), block_shape=(16, 16),
        total_blocks=40, skew=0.9,
    )
    sh = partition_block_csr(a, 8)
    assert sh.n_shards == 8
    assert sh.imbalance() <= 1.10  # the acceptance bar
    assert int(sh.nnz_per_shard().sum()) == int(a.nnz_blocks)
    # every stored block lands in exactly one shard → the sum of the
    # per-shard densifications reassembles the original matrix
    np.testing.assert_allclose(
        np.asarray(sh.to_dense()), np.asarray(a.to_dense())
    )


def test_partition_degenerate_zero_nnz_shards():
    """Regression (satellite): a shard receiving zero nnz blocks for a
    very sparse topology must become an empty sub-layout, not a crash."""
    m, block = 64, 16
    dense = jnp.zeros((m, m)).at[:block, :block].set(1.0)
    a = BlockCSRMatrix.from_dense(dense, (block, block))  # 1 stored block
    sh = partition_block_csr(a, 8)
    nnz = sh.nnz_per_shard()
    assert int(nnz.sum()) == 1 and (nnz == 0).sum() == 7
    # empty shards: all-invalid slots, all-zero row_ptr (every row reads
    # empty → the kernel wrapper fills the semiring zero, psum-neutral)
    for s in range(1, 8):
        local = sh.shard(s)
        assert not bool(np.asarray(local.valid).any())
        assert np.asarray(local.row_ptr).max() == 0
    np.testing.assert_allclose(
        np.asarray(sh.to_dense()), np.asarray(dense)
    )
    with pytest.raises(ValueError, match="n_shards"):
        partition_block_csr(a, 0)


def test_partition_rescatter_roundtrip_and_grad():
    ws, _ = _csr_stack(1, 1, 64)
    a = ws[0]
    sh = partition_block_csr(a, 4)
    # frozen-partition gather reproduces the partitioned values
    np.testing.assert_allclose(
        np.asarray(sh.rescatter_values(a.values)), np.asarray(sh.values)
    )
    # its VJP scatters back onto the unsharded layout (training route)
    g = jax.grad(lambda v: jnp.sum(sh.rescatter_values(v) ** 2))(a.values)
    np.testing.assert_allclose(
        np.asarray(g), np.asarray(2.0 * a.values), rtol=1e-6
    )


def test_stacked_transpose_plans_match_per_shard():
    ws, _ = _csr_stack(2, 1, 64)
    sh = partition_block_csr(ws[0], 4)
    stacked = stack_transpose_plans(sh)
    assert stacked.order.shape[0] == 4
    for s in range(4):
        ref = sh.shard(s).transpose()
        from repro.sparse import BcsrTransposePlan

        local = BcsrTransposePlan(
            stacked.order[s], stacked.row_ptr[s], stacked.row_id[s],
            stacked.col_idx[s], stacked.valid[s],
            stacked.shape, stacked.block_shape,
        )
        got = local.apply(sh.shard(s))
        np.testing.assert_allclose(
            np.asarray(got.to_dense()), np.asarray(ref.to_dense())
        )


# ---------------------------------------------------------------------
# rule-table resolution of the row_blocks axis
# ---------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape


def test_row_block_axes_prefers_dedicated_axis():
    assert row_block_axes(_FakeMesh({"row_blocks": 8})) == ("row_blocks",)
    assert mesh_shard_count(_FakeMesh({"row_blocks": 8})) == 8
    # compute meshes without the dedicated axis: every compute axis
    assert row_block_axes(_FakeMesh({"data": 4, "model": 2})) == (
        "data", "model",
    )
    assert mesh_shard_count(_FakeMesh({"data": 4, "model": 2})) == 8
    # nothing matches → unsharded (1 shard)
    assert row_block_axes(_FakeMesh({"pod": 2})) == ()
    assert mesh_shard_count(_FakeMesh({"pod": 2})) == 1
    # rules are honored: dropping the tp axis halves the shard count
    rules = ShardingRules(tp_axis=None)
    assert row_block_axes(_FakeMesh({"data": 4, "model": 2}), rules) == (
        "data",
    )


def test_sharded_csr_pspecs_resolve_leading_shard_dim():
    ws, _ = _csr_stack(4, 1, 64)
    sh = partition_block_csr(ws[0], 8)
    specs = sharded_csr_pspecs(sh, _FakeMesh({"row_blocks": 8}))
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(leaves) == 6  # one per ShardedBlockCSR leaf
    for spec in leaves:
        assert tuple(spec) == ("row_blocks",)  # dim0 sharded, rest local
    # divisibility fallback: a mesh whose axes cannot divide the shard
    # count replicates instead of mis-sharding
    specs = sharded_csr_pspecs(sh, _FakeMesh({"data": 3, "model": 1}))
    for spec in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    ):
        assert tuple(spec) in ((), (None,))


# ---------------------------------------------------------------------
# plan-cache keying (satellite): mesh fingerprint in PlanKey
# ---------------------------------------------------------------------


def test_plan_key_carries_mesh_fingerprint():
    mesh = make_row_blocks_mesh(1)  # 1 device is enough for keying
    fp = PL.mesh_fingerprint(mesh)
    assert fp.startswith("row_blocks[")
    ws, bs = _csr_stack(5, 2, 64)
    cache = PL.PlanCache(max_size=8)
    unsharded = cache.get(ws, bs, 8)
    sharded = cache.get(ws, bs, 8, mesh=mesh)
    # same topology, same width — the mesh fingerprint keeps the keys
    # (and hence the compiled executables) apart
    assert unsharded is not sharded
    assert unsharded.key.mesh is None and sharded.key.mesh == fp
    assert cache.stats()["builds"] == 2
    # and each key still hits on repeat
    assert cache.get(ws, bs, 8, mesh=mesh) is sharded
    assert cache.get(ws, bs, 8) is unsharded
    assert cache.stats()["hits"] == 2


def test_default_cache_reset_helper():
    PL.reset_default_cache()
    cache = PL.default_cache()
    ws, bs = _csr_stack(6, 1, 64)
    cache.get(ws, bs, 8)
    assert cache.stats()["builds"] == 1
    PL.reset_default_cache()
    fresh = PL.default_cache()
    assert fresh is not cache
    assert fresh.stats() == {
        "size": 0, "max_size": 4, "lookups": 0, "hits": 0, "misses": 0,
        "builds": 0, "evictions": 0, "hit_rate": 0.0,
    }


def test_sharded_plan_donor_shares_partition_across_widths():
    mesh = make_row_blocks_mesh(1)
    ws, bs = _csr_stack(7, 2, 64)
    cache = PL.PlanCache(max_size=8)
    p8 = cache.get(ws, bs, 8, mesh=mesh, differentiable=True)
    p16 = cache.get(ws, bs, 16, mesh=mesh, differentiable=True)
    assert p16.layers[0].sharded is p8.layers[0].sharded
    assert p16.layers[0].transpose is p8.layers[0].transpose
    assert p16.grid_steps == dnn.dnn_grid_steps(ws, 16)  # width-local


# ---------------------------------------------------------------------
# execution on whatever mesh this process can build (1 shard here;
# the 8-shard run happens in the subprocess / CI multi-device job)
# ---------------------------------------------------------------------


def test_sharded_plan_forward_matches_reference_one_shard():
    mesh = make_row_blocks_mesh(1)
    ws, bs = _csr_stack(8, 3, 64)
    plan = PL.build_sharded_plan(ws, bs, 8, mesh)
    assert plan.route == PL.ROUTE_SHARDED
    assert plan.grid_steps == dnn.dnn_grid_steps(ws, 8)
    assert sum(plan.grid_steps_per_shard) == plan.grid_steps
    y0 = jax.random.uniform(jax.random.PRNGKey(9), (64, 5))
    np.testing.assert_allclose(
        np.asarray(plan.forward(y0)),
        np.asarray(dnn.dnn_forward(ws, bs, y0, fused=True)),
        rtol=1e-5, atol=1e-5,
    )
    assert plan.compile_count == 1
    plan.forward(y0)
    assert plan.compile_count == 1  # same width class → same executable
    with pytest.raises(ValueError, match="width"):
        plan.forward(jnp.zeros((64, 9)))


def test_sharded_plan_rejects_resident_and_ell_differentiable():
    mesh = make_row_blocks_mesh(1)
    ws, bs = _csr_stack(10, 1, 64)
    with pytest.raises(ValueError, match="use_resident"):
        PL.build_sharded_plan(ws, bs, 8, mesh, use_resident=True)
    ell = [BlockSparseMatrix.random(
        jax.random.PRNGKey(0), (64, 64), (16, 16), blocks_per_row=2
    )]
    with pytest.raises(ValueError, match="block-CSR"):
        PL.build_sharded_plan(ell, bs, 8, mesh, differentiable=True)
    # inference plans re-lay ELL to CSR instead
    plan = PL.build_sharded_plan(ell, bs, 8, mesh)
    assert plan.layers[0].source_layout == "ell"
    assert plan.layers[0].kind == "bcsr"


def test_engine_mesh_rejects_resident_and_reports_shards():
    mesh = make_row_blocks_mesh(1)
    ws, bs = _csr_stack(11, 2, 64)
    with pytest.raises(ValueError, match="mesh"):
        SparseDNNEngine(ws, bs, use_resident=True, mesh=mesh)
    eng = SparseDNNEngine(ws, bs, batch_align=8, mesh=mesh)
    y0 = jax.random.uniform(jax.random.PRNGKey(12), (64, 5))
    out, stats = eng.infer(y0)
    assert stats["plan"]["route"] == PL.ROUTE_SHARDED
    assert stats["plan"]["shards"] == 1
    assert sum(stats["plan"]["grid_steps_per_shard"]) == stats["grid_steps"]
    ref = SparseDNNEngine(ws, bs, batch_align=8).infer(y0)[0]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------
# 8-shard numerics — the acceptance bar
# ---------------------------------------------------------------------

# Runs on an 8-host-device mesh: choose nnz_blocks divisible by 8 so
# the per-shard bills sum EXACTLY to the unsharded occupancy-exact bill
# (no Tp-padding remainder) — the accounting the serve stats expose.
_MULTIDEVICE_BODY = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    jax.config.update("jax_default_matmul_precision", "highest")

    from repro.core import dnn
    from repro.launch.mesh import make_row_blocks_mesh
    from repro.plan import build_sharded_plan
    from repro.serve import ContinuousBatcher, SparseDNNEngine
    from repro.sparse import BlockCSRMatrix, BlockSparseMatrix
    from repro.train.optimizer import sgd
    from repro.train.sparse import init_sparse_mlp_state, make_sparse_train_step

    assert len(jax.devices()) >= 8, jax.devices()
    mesh = make_row_blocks_mesh(8)
    m, L, block, bpr = 64, 3, 16, 4  # nnz = 16 blocks/layer → 8 | 16
    ws = []
    for i in range(L):
        w = BlockSparseMatrix.random(
            jax.random.PRNGKey(i), (m, m), (block, block), blocks_per_row=bpr,
            minval=-0.5, maxval=0.5,
        ).map_blocks(lambda x: x / (bpr * block) ** 0.5)
        ws.append(BlockCSRMatrix.from_bsr(w))
    bs = [jnp.full((m,), 0.01 * i, jnp.float32) for i in range(L)]
    y0 = jax.random.uniform(jax.random.PRNGKey(99), (m, 8), jnp.float32)

    # forward: sharded == single-device plan path == dense reference
    plan = build_sharded_plan(ws, bs, 8, mesh)
    assert plan.n_shards == 8
    assert plan.imbalance() <= 1.10, plan.imbalance()
    out = np.asarray(plan.forward(y0))
    np.testing.assert_allclose(
        out, np.asarray(dnn.dnn_forward(ws, bs, y0, fused=True)),
        rtol=1e-5, atol=1e-5,
    )
    dense_ref = y0
    for w, b in zip(ws, bs):
        dense_ref = jnp.maximum(w.to_dense() @ dense_ref + b[:, None], 0)
    np.testing.assert_allclose(out, np.asarray(dense_ref), rtol=1e-4, atol=1e-5)
    # per-shard bills sum to the unsharded occupancy-exact bill
    assert sum(plan.grid_steps_per_shard) == dnn.dnn_grid_steps(ws, 8), (
        plan.grid_steps_per_shard, dnn.dnn_grid_steps(ws, 8))
    assert plan.shard_pad_blocks() == 0
    print("forward8 OK")

    # backward: grads through the sharded plan match the legacy path
    targets = jnp.asarray(dense_ref) * 0.5
    dplan = build_sharded_plan(ws, bs, 8, mesh, differentiable=True)
    l1, (dw1, db1) = dnn.dnn_value_and_grad(ws, bs, y0, targets, plan=dplan)
    l2, (dw2, db2) = dnn.dnn_value_and_grad(ws, bs, y0, targets)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for a, b in zip(dw1, dw2):
        np.testing.assert_allclose(
            np.asarray(a.values), np.asarray(b.values), rtol=1e-4, atol=1e-7)
    for a, b in zip(db1, db2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-7)
    print("grads8 OK")

    # a degenerate-shard topology must execute, not just partition
    tiny = [BlockCSRMatrix.from_dense(
        jnp.zeros((m, m)).at[:16, :32].set(0.25), (16, 16))]
    tb = [jnp.zeros((m,), jnp.float32)]
    tp = build_sharded_plan(tiny, tb, 8, mesh)
    assert (tp.layers[0].sharded.nnz_per_shard() == 0).sum() == 6
    np.testing.assert_allclose(
        np.asarray(tp.forward(y0)),
        np.asarray(dnn.dnn_forward(tiny, tb, y0, fused=True)),
        rtol=1e-5, atol=1e-5,
    )
    print("degenerate8 OK")

    # serve: the sharded engine reproduces single-device outputs with
    # per-shard accounting summing to the unsharded bill
    e0 = SparseDNNEngine(ws, bs, batch_align=8)
    e1 = SparseDNNEngine(ws, bs, batch_align=8, mesh=mesh)
    for k in (3, 8, 5):
        y = jax.random.uniform(jax.random.PRNGKey(100 + k), (m, k))
        o0, s0 = e0.infer(y)
        o1, s1 = e1.infer(y)
        np.testing.assert_allclose(
            np.asarray(o0), np.asarray(o1), rtol=1e-5, atol=1e-5)
        assert s1["plan"]["shards"] == 8
        assert sum(s1["plan"]["grid_steps_per_shard"]) == s0["grid_steps"], (
            s1["plan"], s0["grid_steps"])
    b = ContinuousBatcher(e1, batch_size=16, min_fill=0.0, width_classes=(8, 16))
    cols = {}
    for i in range(5):
        for j in range(1 + i % 3):
            col = jax.random.uniform(jax.random.PRNGKey(200 + 10 * i + j), (m,))
            cols[b.submit(col)] = col
        b.step(force=True)
    b.drain()
    for rid, col in cols.items():
        np.testing.assert_allclose(
            np.asarray(b.result(rid)),
            np.asarray(dnn.dnn_forward(ws, bs, col[:, None], fused=True)[:, 0]),
            rtol=1e-5, atol=1e-5)
    print("serve8 OK")

    # train: the sharded step's losses track the legacy step exactly
    batch = {"y0": y0, "targets": targets}
    opt = sgd(0.5, momentum=0.0)
    step_s = jax.jit(make_sparse_train_step(opt, use_kernel=True, plan=dplan))
    step_l = jax.jit(make_sparse_train_step(opt, use_kernel=True))
    st_s = init_sparse_mlp_state(ws, bs, opt)
    st_l = init_sparse_mlp_state(ws, bs, opt)
    losses_s, losses_l = [], []
    for _ in range(4):
        st_s, ms = step_s(st_s, batch)
        st_l, ml = step_l(st_l, batch)
        losses_s.append(float(ms["loss"]))
        losses_l.append(float(ml["loss"]))
    assert np.allclose(losses_s, losses_l, rtol=1e-5), (losses_s, losses_l)
    assert losses_s[-1] < losses_s[0]
    print("train8 OK")
    """
)

_SUBPROC = (
    "import os\n"
    'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"\n'
    + _MULTIDEVICE_BODY
)

_MARKS = ("forward8", "grads8", "degenerate8", "serve8", "train8")


@pytest.mark.slow
def test_multidevice_sharding_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    for mark in _MARKS:
        assert f"{mark} OK" in r.stdout, r.stdout


@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(the CI multi-device job sets it)",
)
def test_multidevice_sharding_inprocess(capsys):
    exec(compile(_MULTIDEVICE_BODY, "<multidevice-sharding>", "exec"), {})
    out = capsys.readouterr().out
    for mark in _MARKS:
        assert f"{mark} OK" in out
