"""Kernel-layer benchmark: ELL grid vs occupancy-exact CSR grid vs
VMEM-resident fused multi-layer vs dense, across inverse sparsity and
row skew. ``python -m benchmarks.kernel_bench [--quick]``.

Two kinds of measurement, kept separate on purpose:

* **grid steps** — the architecture truth this PR is about. The ELL
  kernel executes ``nrb × max_blocks_per_row × n_tiles`` steps (the pad
  is paid on every row); the CSR kernel executes ``total_nnz_blocks ×
  n_tiles``. On TPU every step is one (MXU matmul + B-panel DMA) slot,
  so the step ratio IS the expected wall-clock/bandwidth ratio. Steps
  are exact and hardware-independent.
* **wall-clock** — measured on whatever backend is running. On this
  CPU-only container the Pallas kernels execute via ``interpret=True``
  (a correctness mode, ~10⁴× slower than compiled, timing meaningless),
  so wall-clock rows time the pure-jnp XLA paths (``sparse.ops``) that
  mirror each kernel's work scaling, plus the dense arm.

Writes ``BENCH_kernels.json`` at the repo root so subsequent PRs can
track the trajectory:
  steps:  per-topology {ell, csr} grid steps + the ratio
  fused:  pallas_call counts (L vs 1) + layered/fused XLA wall-clock
  sweep:  inverse-sparsity × skew wall-clock for the XLA arms
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import dnn
from repro.kernels import bcsr_spmm as bcsr_kernel
from repro.kernels import ops as kernel_ops
from repro.sparse import BlockCSRMatrix, BlockSparseMatrix
from repro.sparse import ops as sparse_ops

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_kernels.json")


def _grid_steps_ell(a: BlockSparseMatrix, n: int, block_n: int = 128) -> int:
    nrb, mbpr = a.col_idx.shape
    return nrb * mbpr * -(-n // block_n)


def topology_arms(m: int, block: int, total_blocks: int, skew: float, n: int):
    """Build one topology in both layouts and report steps + times."""
    c = BlockCSRMatrix.random_skewed(
        seed=int(1e3 * skew) + m, shape=(m, m), block_shape=(block, block),
        total_blocks=total_blocks, skew=skew,
    )
    a = c.to_bsr()
    counts = np.diff(np.asarray(c.row_ptr))

    ell_steps = _grid_steps_ell(a, n)
    csr_steps = bcsr_kernel.grid_steps(c, n)

    b = jax.random.uniform(jax.random.PRNGKey(0), (m, n), jnp.float32)
    bias = jnp.zeros((m,), jnp.float32)
    t_ell = timeit(
        jax.jit(lambda a_, b_: sparse_ops.bsr_matmul_fused_relu(a_, b_, bias)),
        a, b,
    )
    t_csr = timeit(
        jax.jit(lambda c_, b_: sparse_ops.bcsr_matmul_fused_relu(c_, b_, bias)),
        c, b,
    )
    w_dense = a.to_dense()
    t_dense = timeit(
        jax.jit(lambda w_, b_: sparse_ops.dense_matmul_fused_relu(w_, b_, bias)),
        w_dense, b,
    )
    return {
        "m": m,
        "block": block,
        "n": n,
        "nnz_blocks": int(total_blocks),
        "skew": skew,
        "max_blocks_per_row": int(counts.max()),
        "mean_blocks_per_row": float(counts.mean()),
        "grid_steps_ell": ell_steps,
        "grid_steps_csr": csr_steps,
        "step_ratio_ell_over_csr": ell_steps / csr_steps,
        "xla_time_s": {
            "ell": t_ell,
            "csr": t_csr,
            "dense": t_dense,
        },
    }


def fused_arm(m: int, L: int, bpr: int, n: int):
    """Layered vs single-call fused forward (counts + XLA wall-clock)."""
    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(i), (m, m), (16, 16), blocks_per_row=bpr
        )
        for i in range(L)
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    y0 = jax.random.uniform(jax.random.PRNGKey(99), (m, n), jnp.float32)

    stacked_w, stacked_b = dnn.stack_bsr(ws), jnp.stack(bs)
    jaxpr = jax.make_jaxpr(
        lambda w, b, y: kernel_ops.fused_mlp_forward(w, b, y)
    )(stacked_w, stacked_b, y0)
    fused_calls = str(jaxpr).count("pallas_call")

    t_layered = timeit(
        jax.jit(lambda ws_, bs_, y: dnn.dnn_forward(ws_, bs_, y, fused=True)),
        ws, bs, y0,
    )
    t_scan = timeit(
        jax.jit(dnn.dnn_forward_scan), stacked_w, stacked_b, y0
    )
    # correctness tie-in: fused kernel (interpret) == layered, one call
    out_fused = kernel_ops.fused_mlp_forward(stacked_w, stacked_b, y0)
    out_layered = dnn.dnn_forward(ws, bs, y0, fused=True)
    max_rel = float(
        jnp.max(
            jnp.abs(out_fused - out_layered)
            / jnp.maximum(jnp.abs(out_layered), 1.0)
        )
    )
    return {
        "m": m,
        "layers": L,
        "blocks_per_row": bpr,
        "n": n,
        "pallas_calls_fused": fused_calls,
        "pallas_calls_layered": L,
        "hbm_activation_roundtrips_eliminated": L - 1,
        "max_rel_err_vs_layered": max_rel,
        "xla_time_s": {"layered_loop": t_layered, "layered_scan": t_scan},
    }


def run(quick: bool = False):
    n = 64
    sizes = [256] if quick else [256, 512, 1024]
    skews = [0.0, 0.9] if quick else [0.0, 0.5, 0.9]
    inv_sparsities = [8, 32] if quick else [8, 32, 128]

    topologies = []
    for m in sizes:
        block = 16
        ncb = m // block
        for inv in inv_sparsities:
            total = max((m // block) * max(ncb // inv, 1), 1)
            for skew in skews:
                r = topology_arms(m, block, total, skew, n)
                topologies.append(r)
                print(
                    f"m={m:5d} inv={inv:4d} skew={skew:.1f}  "
                    f"steps ell={r['grid_steps_ell']:6d} "
                    f"csr={r['grid_steps_csr']:6d} "
                    f"(ratio {r['step_ratio_ell_over_csr']:.2f})  "
                    f"xla ell={r['xla_time_s']['ell']*1e3:7.2f}ms "
                    f"csr={r['xla_time_s']['csr']*1e3:7.2f}ms "
                    f"dense={r['xla_time_s']['dense']*1e3:7.2f}ms",
                    flush=True,
                )

    fused = fused_arm(m=256, L=4 if quick else 8, bpr=3, n=128)
    print(
        f"fused: L={fused['layers']} pallas_calls "
        f"{fused['pallas_calls_layered']}→{fused['pallas_calls_fused']}, "
        f"max rel err {fused['max_rel_err_vs_layered']:.2e}",
        flush=True,
    )

    # The tentpole invariants, asserted on every benchmark run:
    for r in topologies:
        if r["max_blocks_per_row"] > r["mean_blocks_per_row"]:
            assert r["grid_steps_csr"] < r["grid_steps_ell"], r
    assert fused["pallas_calls_fused"] == 1
    assert fused["max_rel_err_vs_layered"] <= 1e-5

    payload = {
        "backend": jax.default_backend(),
        "interpret_kernels": kernel_ops.auto_interpret(),
        "topologies": topologies,
        "fused": fused,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {OUT_PATH}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
