"""Kernel-layer benchmark: ELL grid vs occupancy-exact CSR grid vs
VMEM-resident fused multi-layer vs dense, across inverse sparsity and
row skew. ``python -m benchmarks.kernel_bench [--quick]``.

Two kinds of measurement, kept separate on purpose:

* **grid steps** — the architecture truth this PR is about. The ELL
  kernel executes ``nrb × max_blocks_per_row × n_tiles`` steps (the pad
  is paid on every row); the CSR kernel executes ``total_nnz_blocks ×
  n_tiles``. On TPU every step is one (MXU matmul + B-panel DMA) slot,
  so the step ratio IS the expected wall-clock/bandwidth ratio. Steps
  are exact and hardware-independent.
* **wall-clock** — measured on whatever backend is running. On this
  CPU-only container the Pallas kernels execute via ``interpret=True``
  (a correctness mode, ~10⁴× slower than compiled, timing meaningless),
  so wall-clock rows time the pure-jnp XLA paths (``sparse.ops``) that
  mirror each kernel's work scaling, plus the dense arm.

Writes ``BENCH_kernels.json`` at the repo root so subsequent PRs can
track the trajectory:
  steps:  per-topology {ell, csr} grid steps + the ratio
  fused:  pallas_call counts (L vs 1) + layered/fused XLA wall-clock
  sweep:  inverse-sparsity × skew wall-clock for the XLA arms
  train:  the TRAINING arm — a masked sparse MLP train step with the
          kernels (and their custom VJPs) in the hot path: pallas_call
          counts per step (forward kernels + the CSR backward-dX
          kernel), forward/backward grid-step accounting, and the loss
          trajectory proving the sparse stack actually learns.
  serve:  the SERVING arm — a deterministic bursty (Poisson-ish)
          100-request arrival trace served twice over the same weights:
          static aligned batching (one right-padded ``infer`` per tick)
          vs the continuous batcher (``repro.serve.scheduler``), with
          pad-slot fraction, exact grid-step totals, and latency for
          both. Identical in --quick and full runs so the CI gate
          (``tools/check_bench.py``) always compares like with like.
  plan:   the PLAN arm — compile-once execution plans (``repro.plan``,
          docs/architecture.md) measured on both halves of the claim:
          (serving) the same 100-request trace with width-class
          quantization, recording plan-cache hit rate and per-class
          recompile counts — a handful of compiled plans must absorb
          every panel (hit rate ≥ 0.9 asserted); (training) a masked
          sparse MLP train loop where the plan's cached block-CSR
          transpose makes the backward sort-free — the topology is
          sorted exactly ONCE (at plan build, asserted via the
          ``repro.sparse`` sort counter and a sort-free step jaxpr),
          with legacy-vs-planned per-step wall-clock recorded.
          Identical in --quick and full runs, like serve.
  sharded: the SHARDING arm — the balanced block-CSR partitioner
          (``repro.sparse.partition``) applied to a deterministic
          benchmark stack: per-shard nnz and grid-step bills vs the
          single-device occupancy-exact bill, the load-imbalance
          factor, and the critical-path step count (the parallel
          speedup bound). Pure host-side accounting — it needs no
          multi-device runtime, so CI's single-CPU bench job gates it
          exactly; the numerics are covered by tests/test_sharded.py
          on an 8-host-device mesh.
  challenge: the CHALLENGE arm — the GraphChallenge workload shape
          (RadiX-net topology, fan-in 32, weight 1/16, official bias)
          streamed through the serving engine on a stack past the VMEM
          budget (→ the multi-panel tiled fused route), reporting the
          official edges × inputs / sec metric plus a bit-level
          conformance check against the numpy ground-truth categories
          (tests/test_challenge.py is the full suite).
  gnn:    the GNN arm — graph inference over two semirings on one
          power-law block-sparse adjacency: a plus_times graph
          convolution (kernel route vs XLA oracle, pallas_call-counted)
          and a min_plus Bellman-Ford mxv relaxation iterated to a
          fixpoint that must match a pure-numpy reference bit-for-bit.
          Headline: the semiring-aware mxm plan re-lays the skewed ELL
          adjacency out to block-CSR and pays strictly fewer grid steps
          than the occupancy-equivalent XLA sparse path.
  fleet:  the FLEET arm — the async serving front-end
          (``repro.serve.frontend``) driving 1-replica vs N-replica
          fleets over the SAME bursty open-loop trace
          (``repro.serve.loadgen``) at a sweep of offered rates, all on
          a virtual clock with a deterministic grid-step service model:
          throughput-vs-p99 curves, deadline-miss rates, and the
          width-class-affinity router's fleet-wide plan-cache hit rate
          (≥ 0.9 asserted). The headline: the fleet sustains a strictly
          higher offered load than one engine at the same miss budget.
          Every curve number is a pure function of the config — gated
          exactly; also written standalone to
          ``BENCH_fleet_curves.json`` for the CI latency-curve
          artifact.

``--arms`` selects a comma-separated subset (e.g. ``--arms serve`` or
``--arms topologies,sharded``) so CI and local runs can execute a
single arm — the full suite is getting slow. Sections not run are
absent from the JSON; the CI gate compares full artifacts only.

See ``docs/benchmarks.md`` for the full field reference and how CI's
benchmark smoke job consumes this file; ``tools/check_bench.py`` fails
CI when grid-step counts drift from ``benchmarks/baselines/`` or the
serve arm's pad waste regresses.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import dnn
from repro.kernels import bcsr_spmm as bcsr_kernel
from repro.kernels import ops as kernel_ops
from repro.sparse import BlockCSRMatrix, BlockSparseMatrix
from repro.sparse import ops as sparse_ops

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_kernels.json")
FLEET_CURVES_PATH = os.path.join(REPO_ROOT, "BENCH_fleet_curves.json")
TUNING_TABLE_PATH = os.path.join(REPO_ROOT, "BENCH_tuning_table.json")


def _grid_steps_ell(a: BlockSparseMatrix, n: int, block_n: int = 128) -> int:
    nrb, mbpr = a.col_idx.shape
    return nrb * mbpr * -(-n // block_n)


def topology_arms(m: int, block: int, total_blocks: int, skew: float, n: int):
    """Build one topology in both layouts and report steps + times."""
    c = BlockCSRMatrix.random_skewed(
        seed=int(1e3 * skew) + m, shape=(m, m), block_shape=(block, block),
        total_blocks=total_blocks, skew=skew,
    )
    a = c.to_bsr()
    counts = np.diff(np.asarray(c.row_ptr))

    ell_steps = _grid_steps_ell(a, n)
    csr_steps = bcsr_kernel.grid_steps(c, n)

    b = jax.random.uniform(jax.random.PRNGKey(0), (m, n), jnp.float32)
    bias = jnp.zeros((m,), jnp.float32)
    t_ell = timeit(
        jax.jit(lambda a_, b_: sparse_ops.bsr_matmul_fused_relu(a_, b_, bias)),
        a, b,
    )
    t_csr = timeit(
        jax.jit(lambda c_, b_: sparse_ops.bcsr_matmul_fused_relu(c_, b_, bias)),
        c, b,
    )
    w_dense = a.to_dense()
    t_dense = timeit(
        jax.jit(lambda w_, b_: sparse_ops.dense_matmul_fused_relu(w_, b_, bias)),
        w_dense, b,
    )
    return {
        "m": m,
        "block": block,
        "n": n,
        "nnz_blocks": int(total_blocks),
        "skew": skew,
        "max_blocks_per_row": int(counts.max()),
        "mean_blocks_per_row": float(counts.mean()),
        "grid_steps_ell": ell_steps,
        "grid_steps_csr": csr_steps,
        "step_ratio_ell_over_csr": ell_steps / csr_steps,
        "xla_time_s": {
            "ell": t_ell,
            "csr": t_csr,
            "dense": t_dense,
        },
    }


def fused_arm(m: int, L: int, bpr: int, n: int):
    """Layered vs single-call fused forward (counts + XLA wall-clock)."""
    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(i), (m, m), (16, 16), blocks_per_row=bpr
        )
        for i in range(L)
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    y0 = jax.random.uniform(jax.random.PRNGKey(99), (m, n), jnp.float32)

    stacked_w, stacked_b = dnn.stack_bsr(ws), jnp.stack(bs)
    jaxpr = jax.make_jaxpr(
        lambda w, b, y: kernel_ops.fused_mlp_forward(w, b, y)
    )(stacked_w, stacked_b, y0)
    fused_calls = str(jaxpr).count("pallas_call")

    t_layered = timeit(
        jax.jit(lambda ws_, bs_, y: dnn.dnn_forward(ws_, bs_, y, fused=True)),
        ws, bs, y0,
    )
    t_scan = timeit(
        jax.jit(dnn.dnn_forward_scan), stacked_w, stacked_b, y0
    )
    # correctness tie-in: fused kernel (interpret) == layered, one call
    out_fused = kernel_ops.fused_mlp_forward(stacked_w, stacked_b, y0)
    out_layered = dnn.dnn_forward(ws, bs, y0, fused=True)
    max_rel = float(
        jnp.max(
            jnp.abs(out_fused - out_layered)
            / jnp.maximum(jnp.abs(out_layered), 1.0)
        )
    )
    return {
        "m": m,
        "layers": L,
        "blocks_per_row": bpr,
        "n": n,
        "pallas_calls_fused": fused_calls,
        "pallas_calls_layered": L,
        "hbm_activation_roundtrips_eliminated": L - 1,
        "max_rel_err_vs_layered": max_rel,
        "xla_time_s": {"layered_loop": t_layered, "layered_scan": t_scan},
    }


def train_arm(m: int, L: int, block: int, bpr: int, n: int, steps: int):
    """Train a masked sparse MLP with the kernels in BOTH passes.

    Layer layouts alternate ELL / block-CSR so both custom VJPs are
    exercised; the step function's jaxpr is inspected for pallas_call
    counts: every layer's forward is a kernel, and every CSR layer's
    backward dX = Wᵀ·dY is a SECOND kernel call (on the device-side
    transpose). ELL backward runs the occupancy-exact XLA scatter-⊕
    (same work scaling, no extra grid steps). Interpret mode off-TPU —
    keep the shapes small.
    """
    from repro.train.optimizer import sgd
    from repro.train.sparse import (
        grad_sparsity_preserved,
        init_sparse_mlp_state,
        make_sparse_train_step,
    )

    ws = []
    for i in range(L):
        w = BlockSparseMatrix.random(
            jax.random.PRNGKey(100 + i), (m, m), (block, block), blocks_per_row=bpr,
            minval=-0.5, maxval=0.5,
        )
        w = w.map_blocks(lambda x: x / (bpr * block) ** 0.5)
        ws.append(BlockCSRMatrix.from_bsr(w) if i % 2 else w)
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    layouts = ["bcsr" if isinstance(w, BlockCSRMatrix) else "ell" for w in ws]

    # Teacher with positive-mean weights (paper §V-B's U[-1, 3) values,
    # rescaled): its targets are O(1) while the small-init student
    # starts near zero — a non-trivial, realizable regression task.
    teacher = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(200 + i), (m, m), (block, block), blocks_per_row=bpr,
        ).map_blocks(lambda x: x / (bpr * block))
        for i in range(L)
    ]

    # Fixed full batch: deterministic, monotone loss in a handful of steps.
    y0 = jax.random.uniform(jax.random.PRNGKey(300), (m, n), jnp.float32)
    batch = {"y0": y0, "targets": dnn.dnn_forward(teacher, bs, y0, fused=True)}

    opt = sgd(3.0, momentum=0.0)
    state = init_sparse_mlp_state(ws, bs, opt)
    step = make_sparse_train_step(opt, use_kernel=True)

    jaxpr = jax.make_jaxpr(step)(state, batch)
    pallas_calls = str(jaxpr).count("pallas_call")

    # sparsity-preservation spot check on the raw cotangent
    _, (dws, _) = dnn.dnn_value_and_grad(
        state.weights, state.biases, batch["y0"], batch["targets"]
    )
    pattern_ok = grad_sparsity_preserved(state.weights, dws)

    step = jax.jit(step)
    losses = []
    for i in range(steps):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        print(f"train step {i} loss={losses[-1]:.6f}", flush=True)

    bn = min(128, n)
    fwd_steps = sum(
        bcsr_kernel.grid_steps(w, n, bn)
        if isinstance(w, BlockCSRMatrix)
        else _grid_steps_ell(w, n, bn)
        for w in ws
    )
    # backward kernel steps: one CSR kernel per CSR layer, on the
    # transpose (same total_blocks → same step count as its forward)
    bwd_steps = sum(
        bcsr_kernel.grid_steps(w, n, bn)
        for w in ws
        if isinstance(w, BlockCSRMatrix)
    )
    return {
        "m": m,
        "layers": L,
        "block": block,
        "blocks_per_row": bpr,
        "n": n,
        "layout_per_layer": layouts,
        "pallas_calls_per_step": pallas_calls,
        "pallas_calls_forward_only": L,
        "grid_steps_forward": fwd_steps,
        "grid_steps_backward_kernel": bwd_steps,
        "weight_cotangent_pattern_preserved": pattern_ok,
        "losses": losses,
        "loss_decreased": losses[-1] < losses[0],
    }


def serve_arm(
    m: int,
    L: int,
    bpr: int,
    n_requests: int,
    batch_size: int,
    tile_align: int,
    lam: float,
    burst_every: int,
    burst_size: int,
    seed: int,
    min_fill: float,
    max_wait: int,
):
    """Static aligned batching vs continuous batching on one trace.

    Same weights, same deterministic arrival stream; the only variable
    is the batching policy. Grid-step totals are exact (the pad rides
    through every layer's kernel grid), wall-clock is indicative only
    (interpret-mode kernels off-TPU). The comparison protocol itself
    lives in ``repro.serve.compare_static_continuous`` — this arm only
    parameterizes it and packages the JSON.
    """
    from repro.serve import (
        SparseDNNEngine,
        compare_static_continuous,
        poissonish_trace,
    )

    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(400 + i), (m, m), (16, 16), blocks_per_row=bpr
        )
        for i in range(L)
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    assert dnn.resident_eligible(ws), "serve arm expects the resident path"

    trace = poissonish_trace(
        n_requests,
        m=m,
        lam=lam,
        burst_every=burst_every,
        burst_size=burst_size,
        seed=seed,
    )
    cmp = compare_static_continuous(
        lambda align: SparseDNNEngine(ws, bs, batch_align=align),
        trace,
        batch_size=batch_size,
        tile_align=tile_align,
        min_fill=min_fill,
        max_wait=max_wait,
    )
    static, continuous = cmp["static"], cmp["continuous"]
    resident_used = all(s.resident for s in continuous.steps)
    return {
        "m": m,
        "layers": L,
        "blocks_per_row": bpr,
        "requests": n_requests,
        "batch_size": batch_size,
        "tile_align": tile_align,
        "min_fill": min_fill,
        "max_wait": max_wait,
        "trace": {
            "lam": lam,
            "burst_every": burst_every,
            "burst_size": burst_size,
            "seed": seed,
            "ticks": len(trace),
            "arrivals_per_tick": [len(a) for a in trace],
        },
        "resident_path_used": resident_used,
        "static": static.summary(),
        "continuous": continuous.summary(),
        "pad_fraction_ratio_continuous_over_static": cmp[
            "pad_fraction_ratio"
        ],
        "grid_steps_ratio_continuous_over_static": cmp["grid_steps_ratio"],
        "wall_time_s": cmp["wall_time_s"],
    }


def plan_arm(
    m: int,
    L: int,
    bpr: int,
    n_requests: int,
    batch_size: int,
    tile_align: int,
    lam: float,
    burst_every: int,
    burst_size: int,
    seed: int,
    width_classes: tuple,
    train_n: int,
    train_steps: int,
):
    """Compile-once plans, measured on serving AND training.

    Serving: the serve arm's deterministic trace, latency-optimal
    dispatch (``min_fill=0`` → one panel per non-empty tick, the
    worst case for per-width recompiles), panels quantized to
    ``width_classes`` — the engine's PlanCache must absorb the whole
    trace with one compiled plan per class.

    Training: the train arm's alternating ELL/CSR stack through
    ``make_sparse_train_step(plan=...)`` — the plan's cached transpose
    keeps the backward sort-free; legacy vs planned step jaxprs and
    wall-clocks are recorded side by side.
    """
    import time

    from repro.plan import build_plan
    from repro.serve import ContinuousBatcher, SparseDNNEngine, poissonish_trace
    from repro.sparse import (
        reset_transpose_sort_count,
        transpose_sort_count,
    )
    from repro.train.optimizer import sgd
    from repro.train.sparse import init_sparse_mlp_state, make_sparse_train_step

    # --- serving: plan-cache amortization over the request stream -----
    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(400 + i), (m, m), (16, 16), blocks_per_row=bpr
        )
        for i in range(L)
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    trace = poissonish_trace(
        n_requests,
        m=m,
        lam=lam,
        burst_every=burst_every,
        burst_size=burst_size,
        seed=seed,
    )
    eng = SparseDNNEngine(ws, bs, batch_align=tile_align)
    batcher = ContinuousBatcher(
        eng,
        batch_size=batch_size,
        min_fill=0.0,
        max_wait=0,
        width_classes=width_classes,
    )
    t0 = time.perf_counter()
    sstats = batcher.run_trace(trace)
    t_serve = time.perf_counter() - t0
    cache = eng.plan_cache.stats()

    # --- training: the cached transpose amortization ------------------
    tm, tL, tblock, tn = 64, 3, 16, train_n
    tws = []
    for i in range(tL):
        w = BlockSparseMatrix.random(
            jax.random.PRNGKey(100 + i), (tm, tm), (tblock, tblock),
            blocks_per_row=bpr, minval=-0.5, maxval=0.5,
        )
        w = w.map_blocks(lambda x: x / (bpr * tblock) ** 0.5)
        tws.append(BlockCSRMatrix.from_bsr(w) if i % 2 else w)
    tbs = [jnp.zeros((tm,), jnp.float32) for _ in range(tL)]
    layouts = ["bcsr" if isinstance(w, BlockCSRMatrix) else "ell" for w in tws]
    n_csr = sum(1 for l in layouts if l == "bcsr")
    y0 = jax.random.uniform(jax.random.PRNGKey(300), (tm, tn), jnp.float32)
    targets = dnn.dnn_forward(tws, tbs, y0, fused=True) * 0.5
    batch = {"y0": y0, "targets": targets}
    opt = sgd(1.0, momentum=0.0)

    def run_loop(step_fn):
        step_fn = jax.jit(step_fn)
        state = init_sparse_mlp_state(tws, tbs, opt)
        state, met = step_fn(state, batch)  # compile outside the timing
        jax.block_until_ready(met["loss"])
        losses = [float(met["loss"])]
        t0 = time.perf_counter()
        for _ in range(train_steps - 1):
            state, met = step_fn(state, batch)
            losses.append(float(met["loss"]))
        jax.block_until_ready(met["loss"])
        dt = (time.perf_counter() - t0) / max(train_steps - 1, 1)
        return losses, dt

    state0 = init_sparse_mlp_state(tws, tbs, opt)
    legacy_step = make_sparse_train_step(opt, use_kernel=True)
    legacy_has_sort = " sort" in str(jax.make_jaxpr(legacy_step)(state0, batch))
    losses_legacy, t_legacy = run_loop(legacy_step)

    # Plan build is the one and only topology sort; the whole planned
    # train loop after it (trace + compile + steps) adds ZERO sorts.
    reset_transpose_sort_count()
    plan = build_plan(tuple(tws), tuple(tbs), tn, differentiable=True)
    sorts_at_build = transpose_sort_count()
    planned_step = make_sparse_train_step(opt, use_kernel=True, plan=plan)
    planned_has_sort = " sort" in str(
        jax.make_jaxpr(planned_step)(state0, batch)
    )
    losses_planned, t_planned = run_loop(planned_step)
    sorts_total = transpose_sort_count()

    return {
        "m": m,
        "layers": L,
        "blocks_per_row": bpr,
        "requests": n_requests,
        "batch_size": batch_size,
        "tile_align": tile_align,
        "width_classes": list(width_classes),
        "trace": {
            "lam": lam,
            "burst_every": burst_every,
            "burst_size": burst_size,
            "seed": seed,
            "ticks": len(trace),
        },
        "train_params": {
            "m": tm, "layers": tL, "block": tblock,
            "blocks_per_row": bpr, "n": tn, "steps": train_steps,
        },
        "serve": {
            "engine_steps": sstats.engine_steps,
            "rows_served": sstats.rows_served,
            "padded_slots": sstats.padded_slots,
            "pad_slot_fraction": sstats.pad_slot_fraction,
            "grid_steps_total": sstats.grid_steps_total,
            "plan_lookups": cache["lookups"],
            "plan_builds": cache["builds"],
            "plan_evictions": cache["evictions"],
            "cache_hit_rate": cache["hit_rate"],
            "recompiles_by_class": sstats.summary()[
                "plan_recompiles_by_class"
            ],
            "wall_time_s": t_serve,
        },
        "train": {
            "layout_per_layer": layouts,
            "csr_layers": n_csr,
            "steps": train_steps,
            "sorts_at_plan_build": sorts_at_build,
            "sorts_total": sorts_total,
            "legacy_jaxpr_has_sort": legacy_has_sort,
            "planned_jaxpr_has_sort": planned_has_sort,
            "losses_planned": losses_planned,
            "loss_decreased": losses_planned[-1] < losses_planned[0],
            "losses_match_legacy": bool(
                np.allclose(losses_legacy, losses_planned, rtol=1e-5)
            ),
            "step_time_s": {"legacy": t_legacy, "planned": t_planned},
        },
    }


def sharded_arm(m: int, L: int, block: int, bpr: int, n: int, shards: int):
    """The balanced block-CSR partitioner's accounting, deterministic.

    Builds the benchmark stack (nnz divisible by ``shards`` so the
    common per-shard segment length carries zero padding), partitions
    every layer across ``shards`` row-block shards, and reports the
    per-shard grid-step bill vs the single-device occupancy-exact bill
    plus the load-imbalance factor. All host-side topology math — the
    single-CPU CI bench job gates these numbers exactly; the multi-
    device execution itself is validated by tests/test_sharded.py.
    """
    from repro.sparse import partition_block_csr

    ws = [
        BlockCSRMatrix.from_bsr(
            BlockSparseMatrix.random(
                jax.random.PRNGKey(500 + i), (m, m), (block, block),
                blocks_per_row=bpr,
            )
        )
        for i in range(L)
    ]
    from repro.plan import cost as plan_cost

    parts = [partition_block_csr(w, shards) for w in ws]
    # bill each shard through the SAME cost model ShardedStackPlan uses
    # (one source of truth — a kernel tile-width change moves both)
    per_shard = [
        sum(plan_cost.layer_grid_steps(p.shard(s), n) for p in parts)
        for s in range(shards)
    ]
    nnz_per_shard = [
        int(sum(p.nnz_per_shard()[s] for p in parts)) for s in range(shards)
    ]
    unsharded = dnn.dnn_grid_steps(ws, n)
    total = sum(per_shard)
    pad_blocks = sum(
        p.n_shards * p.local_total_blocks - int(p.nnz_per_shard().sum())
        for p in parts
    )
    nnz_total = sum(nnz_per_shard)
    imbalance = max(nnz_per_shard) * shards / nnz_total
    critical_path = max(per_shard)
    return {
        "m": m,
        "layers": L,
        "block": block,
        "blocks_per_row": bpr,
        "n": n,
        "shards": shards,
        "nnz_blocks_total": nnz_total,
        "nnz_per_shard": nnz_per_shard,
        "grid_steps_unsharded": unsharded,
        "grid_steps_per_shard": per_shard,
        "grid_steps_sharded_total": total,
        "shard_pad_blocks": pad_blocks,
        "bill_matches_unsharded": total == unsharded,
        "imbalance": imbalance,
        "critical_path_steps": critical_path,
        "parallel_speedup_bound": unsharded / critical_path,
    }


def faults_arm(
    m: int,
    L: int,
    bpr: int,
    n_requests: int,
    batch_size: int,
    tile_align: int,
    seed: int,
):
    """The ROBUSTNESS arm (docs/robustness.md), fully deterministic.

    Three sub-runs over the same benchmark stack:

    * ``serve`` — a 100-request deterministic trace served through a
      fault-injected engine + batcher: NaN-poisoned panels (quarantine),
      a transient step failure (retry), a cache-eviction storm, a
      straggler tick, impossible deadlines (shed at packing time) and a
      burst past the bounded queue (backpressure rejections). The run
      must complete without raising and keep goodput ≥ 0.8.
    * ``degrade`` — a mesh-sharded engine loses its mesh mid-stream and
      must serve the in-flight panel on the single-device plan with
      results identical to a healthy single-device engine.
    * ``train`` — resilient sparse training through one injected
      NaN-loss: restore-and-skip, final losses matching a clean run
      exactly.
    """
    import tempfile
    import time

    from repro.launch.mesh import make_row_blocks_mesh
    from repro.serve import ContinuousBatcher, SparseDNNEngine
    from repro.testing import faults as F
    from repro.train.optimizer import sgd
    from repro.train.resilience import run_resilient_training
    from repro.train.sparse import init_sparse_mlp_state

    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(600 + i), (m, m), (16, 16), blocks_per_row=bpr
        )
        for i in range(L)
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]

    # --- serve: faulted trace, goodput floor --------------------------
    rng = np.random.default_rng(seed)
    cols = [
        jnp.asarray(rng.uniform(0.0, 1.0, size=(m,)).astype(np.float32))
        for _ in range(n_requests)
    ]
    inj = F.FaultInjector(seed=seed)
    inj.schedule(F.SITE_PANEL_NANS, 3, count=1, mode="nan")
    inj.schedule(F.SITE_PANEL_NANS, 11, count=1, mode="nan")
    inj.schedule(F.SITE_STEP_TRANSIENT, 6, failures=1)  # retried, no loss
    inj.schedule(F.SITE_CACHE_EVICTION, 9)
    inj.schedule(F.SITE_STRAGGLER, 5, seconds=0.0)
    eng = SparseDNNEngine(
        ws, bs, batch_align=tile_align, fault_injector=inj,
        max_step_retries=2,
    )
    batcher = ContinuousBatcher(
        eng,
        batch_size=batch_size,
        min_fill=0.0,
        max_wait=0,
        max_pending=20,
        fault_injector=inj,
    )
    t0 = time.perf_counter()
    idx = 0
    for tick in range(20):
        arrivals = 24 if tick == 12 else 4  # burst past the queue bound
        for _ in range(arrivals):
            if idx >= n_requests:
                break
            deadline = None
            if idx % 10 == 9:
                deadline = batcher.tick  # impossible → shed at packing
            elif idx % 7 == 0:
                deadline = batcher.tick + 3  # feasible
            batcher.submit(cols[idx], deadline=deadline)
            idx += 1
        batcher.step()
    batcher.drain()
    t_serve = time.perf_counter() - t0
    sstats = batcher.stats()
    fa = sstats.faults
    serve = {
        "completed": sstats.requests,
        "engine_steps": sstats.engine_steps,
        "deadline_misses": sstats.deadline_misses,
        "goodput": sstats.goodput,
        "faults": fa.as_dict(),
        "shed_fraction": fa.shed / fa.offered if fa.offered else 0.0,
        "injector_fired": len(inj.fired),
        "injector_pending": inj.pending(),
        "wall_time_s": t_serve,
    }

    # --- degrade: shard failure → single-device fallback --------------
    cws = [BlockCSRMatrix.from_bsr(w) for w in ws]
    inj2 = F.FaultInjector(seed=seed)
    inj2.schedule(F.SITE_SHARD_FAILURE, 1, reason="injected node loss")
    meng = SparseDNNEngine(
        cws, bs, batch_align=tile_align,
        mesh=make_row_blocks_mesh(1), fault_injector=inj2,
    )
    seng = SparseDNNEngine(cws, bs, batch_align=tile_align)
    panels = [
        jnp.stack(cols[i * 8 : (i + 1) * 8], axis=1) for i in range(3)
    ]
    levels, failed_dispatches, match_after_failure = [], 0, True
    for i, p in enumerate(panels):
        out, st = meng.infer(p)
        if st["failed"]:
            failed_dispatches += 1
            continue
        levels.append(st["plan"]["level"])
        if i >= 1:  # dispatches at/after the injected failure
            ref, _ = seng.infer(p)
            match_after_failure &= bool(np.array_equal(out, ref))
    degrade = {
        "levels": levels,
        "recovery_steps": failed_dispatches,  # panels lost to the fault
        "matches_single_device_after_failure": match_after_failure,
        "ladder_events": len(meng.ladder.events),
        "degraded": meng.ladder.degraded,
    }

    # --- train: NaN-loss → restore-and-skip, clean-run parity ---------
    tm = 32

    def batch_fn(step):
        k = jax.random.PRNGKey(2000 + step)
        y0 = jax.random.uniform(k, (tm, 8), jnp.float32)
        return {"y0": y0, "targets": 0.3 * y0}

    def fresh_state():
        tws = [
            BlockCSRMatrix.from_bsr(
                BlockSparseMatrix.random(
                    jax.random.PRNGKey(700 + i), (tm, tm), (8, 8),
                    blocks_per_row=2, minval=-0.5, maxval=0.5,
                )
            )
            for i in range(2)
        ]
        tbs = [jnp.zeros((tm,), jnp.float32) for _ in tws]
        return init_sparse_mlp_state(tws, tbs, sgd(0.5, momentum=0.0))

    inj3 = F.FaultInjector(seed=seed)
    inj3.schedule(F.SITE_TRAIN_NAN_LOSS, 3)
    with tempfile.TemporaryDirectory() as d:
        _, faulted = run_resilient_training(
            fresh_state(), batch_fn, sgd(0.5, momentum=0.0), 6,
            os.path.join(d, "faulted"), ckpt_interval=2,
            use_kernel=False, fault_injector=inj3,
        )
        _, clean = run_resilient_training(
            fresh_state(), batch_fn, sgd(0.5, momentum=0.0), 6,
            os.path.join(d, "clean"), ckpt_interval=2, use_kernel=False,
        )
    train = {
        "steps": 6,
        "skipped_steps": faulted["skipped"],
        "restarts": len(faulted["restarts"]),
        "losses_match_clean": faulted["losses"] == clean["losses"],
        "loss_decreased": (
            faulted["losses"][5] < faulted["losses"][0]
        ),
    }

    return {
        "m": m,
        "layers": L,
        "blocks_per_row": bpr,
        "requests": n_requests,
        "batch_size": batch_size,
        "tile_align": tile_align,
        "seed": seed,
        "serve": serve,
        "degrade": degrade,
        "train": train,
    }


def challenge_arm(
    neurons: int,
    layers: int,
    n_inputs: int,
    panel_width: int,
    batch_align: int,
    density: float,
    seed: int,
):
    """The CHALLENGE arm — the GraphChallenge workload end to end.

    A RadiX-net topology (``repro.data.radixnet``: exact fan-in 32,
    weight 1/16, the official per-size bias) streamed through the
    serving engine in width-classed panels (``repro.serve.challenge``),
    reporting the challenge's official rate metric **edges × inputs /
    second**. The stack is sized past ``VMEM_SOFT_LIMIT_BYTES`` so the
    plan layer must route it through the multi-panel tiled fused kernel
    — the run doubles as a conformance check: the engine's answer set
    must equal the pure-numpy reference's ground-truth categories
    bit-for-bit. Deterministic topology + seeded inputs → all
    accounting fields are exact; only wall-clock (and the metric
    derived from it) varies by runner.
    """
    from repro.data import radixnet as rx
    from repro.serve import run_challenge

    spec = rx.RadixNetSpec(neurons, layers)
    y0 = rx.radixnet_input_panel(
        neurons, n_inputs, density=density, seed=seed
    )
    _, ref_cats = rx.radixnet_reference(spec, y0)
    res = run_challenge(
        spec,
        n_inputs=n_inputs,
        panel_width=panel_width,
        batch_align=batch_align,
        density=density,
        seed=seed,
    )
    return {
        "neurons": neurons,
        "layers": layers,
        "n_inputs": n_inputs,
        "panel_width": panel_width,
        "batch_align": batch_align,
        "density": density,
        "seed": seed,
        "bias": spec.bias,
        "fan_in": rx.FAN_IN,
        "edges": spec.edges,
        "routes": list(res.routes),
        "levels": list(res.levels),
        "width_classes": list(res.width_classes),
        "engine_steps": res.steps,
        "served": res.served,
        "grid_steps": res.grid_steps,
        "n_categories": int(len(res.categories)),
        "reference_match": bool(
            np.array_equal(res.categories, ref_cats)
        ),
        "wall_time_s": res.seconds,
        "edge_inputs_per_sec": res.edge_inputs_per_sec,
    }


def gnn_arm(
    m: int,
    block: int,
    total_blocks: int,
    skew: float,
    feat_dim: int,
    rounds: int,
    bf_iters_cap: int,
    seed: int,
):
    """The GNN arm — graph inference over two semirings, one adjacency.

    A power-law block-sparse adjacency (the degree-skewed topology real
    graphs have) drives two classic message-passing workloads through
    ``graphblas.mxm``/``mxv``:

    * **graph convolution** — ``rounds`` of ``relu(A ⊕.⊗ (H·W))`` over
      ``plus_times``, kernel route vs ``use_kernel=False`` XLA oracle;
    * **Bellman-Ford** — single-source shortest paths as a ``min_plus``
      ``mxv`` relaxation ``d ← min(d, A ⊕.⊗ d)`` iterated to fixpoint,
      checked bit-exactly against a pure-numpy reference (missing
      blocks are +∞, integer edge lengths keep f32 min/+ exact).

    The headline: the kernel route's plan re-lays the skewed ELL
    adjacency out to block-CSR and pays STRICTLY fewer grid steps than
    the occupancy-equivalent XLA sparse path, which einsums every
    ``nrb × max_blocks_per_row`` ELL slot, padding included.
    """
    from repro.core import graphblas as gb
    from repro.core.semiring import MIN_PLUS, PLUS_TIMES
    from repro.plan.cost import mxv_grid_steps
    from repro.plan.mxm import mxm_cache_stats, mxm_plan, reset_mxm_cache
    import time

    t0 = time.perf_counter()
    csr = BlockCSRMatrix.random_skewed(
        seed=seed, shape=(m, m), block_shape=(block, block),
        total_blocks=total_blocks, skew=skew,
    )
    adj = csr.to_bsr()  # the graph's "native" (badly padded) ELL layout

    reset_mxm_cache()
    plan = mxm_plan(adj, feat_dim)

    # --- graph convolution: rounds of relu(A @ (H W)), plus_times ----
    key = jax.random.PRNGKey(seed)
    k_h, k_w = jax.random.split(key)
    h = jax.random.uniform(k_h, (m, feat_dim), jnp.float32)
    ws = jax.random.uniform(
        k_w, (rounds, feat_dim, feat_dim), jnp.float32, -0.5, 0.5
    )
    h_kernel, h_oracle = h, h
    for r in range(rounds):
        msg_k = h_kernel @ ws[r]
        msg_o = h_oracle @ ws[r]
        h_kernel = jnp.maximum(gb.mxm(adj, msg_k, PLUS_TIMES), 0.0)
        h_oracle = jnp.maximum(
            gb.mxm(adj, msg_o, PLUS_TIMES, use_kernel=False), 0.0
        )
    # Scale-normalized error: plus_times sums in a different order than
    # the oracle einsum, so agreement is to f32 roundoff of the output
    # magnitude (raw relative error on post-relu near-zeros is noise).
    scale = max(float(np.abs(np.asarray(h_oracle)).max()), 1.0)
    conv_max_rel_err = float(
        np.abs(np.asarray(h_kernel) - np.asarray(h_oracle)).max() / scale
    )
    jaxpr_kernel = str(
        jax.make_jaxpr(lambda y: gb.mxm(adj, y, PLUS_TIMES))(msg_k)
    )
    jaxpr_oracle = str(
        jax.make_jaxpr(
            lambda y: gb.mxm(adj, y, PLUS_TIMES, use_kernel=False)
        )(msg_k)
    )
    conv_stats = mxm_cache_stats()

    # --- Bellman-Ford: min_plus mxv relaxation to fixpoint -----------
    # Integer edge lengths in [0, 6] on the SAME topology: f32 min/+ is
    # then order-independent exact, so kernel == numpy bit-for-bit.
    lengths = BlockCSRMatrix(
        jnp.round(jnp.abs(csr.values) * 2.0), csr.row_ptr, csr.row_id,
        csr.col_idx, csr.valid, csr.shape, csr.block_shape,
    )
    adj_len = lengths.to_bsr()
    ones = BlockCSRMatrix(
        jnp.ones_like(lengths.values), lengths.row_ptr, lengths.row_id,
        lengths.col_idx, lengths.valid, lengths.shape, lengths.block_shape,
    )
    present = np.asarray(ones.to_dense()) != 0  # stored entries = edges
    a_np = np.where(present, np.asarray(lengths.to_dense()), np.inf)

    d = jnp.full((m,), jnp.inf, jnp.float32).at[0].set(0.0)
    d_np = np.full((m,), np.inf, np.float32)
    d_np[0] = 0.0
    bf_iters, bf_converged = 0, False
    for _ in range(bf_iters_cap):
        d_next = jnp.minimum(d, gb.mxv(adj_len, d, MIN_PLUS))
        d_np = np.minimum(d_np, (a_np + d_np[None, :]).min(axis=1))
        bf_iters += 1
        if bool(jnp.array_equal(d_next, d)):
            bf_converged = True
            break
        d = d_next
    bf_stats = mxm_cache_stats()

    return {
        "m": m,
        "block": block,
        "total_blocks": total_blocks,
        "skew": skew,
        "feat_dim": feat_dim,
        "rounds": rounds,
        "bf_iters_cap": bf_iters_cap,
        "seed": seed,
        "source_layout": plan.source_layout,
        "exec_layout": plan.layout,
        "kernel_grid_steps": plan.grid_steps,
        "xla_sparse_grid_steps": plan.xla_equiv_grid_steps,
        "step_ratio_xla_over_kernel": (
            plan.xla_equiv_grid_steps / plan.grid_steps
        ),
        "mxv_grid_steps": mxv_grid_steps(plan.weight),
        "pallas_calls_conv": jaxpr_kernel.count("pallas_call"),
        "pallas_calls_oracle": jaxpr_oracle.count("pallas_call"),
        "conv_max_rel_err": conv_max_rel_err,
        "conv_matches_oracle": bool(conv_max_rel_err <= 1e-5),
        "conv_plan_builds": conv_stats["builds"],
        "conv_plan_hits": conv_stats["hits"],
        "bf_iters": bf_iters,
        "bf_converged": bf_converged,
        "bf_reachable": int(np.isfinite(np.asarray(d)).sum()),
        "bf_matches_numpy": bool(np.array_equal(np.asarray(d), d_np)),
        "bf_plan_hits": bf_stats["hits"] - conv_stats["hits"],
        "wall_time_s": time.perf_counter() - t0,
    }


def tune_arm(
    skewed_specs,
    skew: float,
    block: int,
    width: int,
    reps: int,
    neurons: int,
    layers: int,
    radix_width: int,
    density: float,
    seed: int,
):
    """The TUNE arm — the autotuner sweep (``repro.tune``) on two
    topologies where the default config is beatable, writing the winning
    table to ``BENCH_tuning_table.json`` for the CI artifact upload.

    **Skewed stack**: rectangular layers with per-row-skewed block
    counts — the default layout heuristic keeps ELL (waste stays under
    ``ELL_WASTE_THRESHOLD``), but forcing block-CSR drops the exact
    grid-step bill, so the sweep's cost-model scoring must pick
    ``layout=bcsr`` and the tuned plan must bill strictly fewer steps
    (and, recorded but not asserted: run faster).

    **RadiX-net stack**: sized so the f32 fused panel (16 MiB at
    ``neurons=8192``) busts ``VMEM_SOFT_LIMIT_BYTES`` while the bf16
    panel (8 MiB) fits — the tuned config moves the route from
    fused-tiled back to resident fused. The resident plan is *built*
    for the route assertion but never executed here: interpret-mode
    compilation of the resident kernel at this size takes minutes,
    and the tiled bf16 kernel computes the identical panels (same
    per-block f32-accumulate contraction), so accuracy and wall time
    are measured through the tiled route on the challenge-shaped
    {0, 1} input panel.
    """
    from repro import plan as plan_mod
    from repro import tune
    from repro.data import radixnet as rx
    from repro.kernels.fused_mlp import (
        VMEM_SOFT_LIMIT_BYTES,
        fused_mlp_vmem_bytes,
    )

    table = tune.TuningTable()

    # --- skewed stack: layout=bcsr must win the sweep ----------------
    ws = [
        BlockCSRMatrix.random_skewed(
            i, shape, (block, block), total, skew=skew
        ).to_bsr()
        for i, (shape, total) in enumerate(skewed_specs)
    ]
    bs = [jnp.zeros((w.shape[0],), jnp.float32) for w in ws]
    winner, records = tune.sweep_stack(ws, bs, width, reps=reps)
    winner_rec = next(r for r in records if r["selected"])
    default_rec = next(r for r in records if r["token"] == "default")
    tune.tune_stack(ws, bs, width, table=table, sweep=(winner, records))
    skewed = {
        "specs": [[list(shape), total] for shape, total in skewed_specs],
        "skew": skew,
        "block": block,
        "width": width,
        "winner": winner.token(),
        "route_tuned": winner_rec["route"],
        "route_default": default_rec["route"],
        "grid_steps_tuned": winner_rec["grid_steps"],
        "grid_steps_default": default_rec["grid_steps"],
        "block_work_tuned": winner_rec["block_work"],
        "block_work_default": default_rec["block_work"],
        "max_abs_err": winner_rec["max_abs_err"],
        "accuracy_ok": winner_rec["ok"],
        "wall_s_tuned": winner_rec["wall_s"],
        "wall_s_default": default_rec["wall_s"],
        "candidates": [
            {
                k: r[k]
                for k in (
                    "token", "route", "grid_steps", "block_work", "ok",
                    "selected", "error",
                )
                if k in r
            }
            for r in records
        ],
    }

    # --- RadiX-net stack: bf16 panels move the resident boundary -----
    spec = rx.RadixNetSpec(neurons, layers)
    rws, rbs = rx.radixnet_weights(spec, block_size=block)
    probe = jnp.asarray(
        rx.radixnet_input_panel(
            neurons, radix_width, density=density, seed=seed
        ),
        jnp.float32,
    )
    vmem_f32 = fused_mlp_vmem_bytes(neurons)
    vmem_bf16 = fused_mlp_vmem_bytes(neurons, panel_dtype="bfloat16")

    bf16_cfg = tune.TunedConfig(panel_dtype="bfloat16")
    default_plan = plan_mod.build_plan(rws, rbs, radix_width)
    bf16_plan = plan_mod.build_plan(
        rws, rbs, radix_width, tuned=bf16_cfg
    )  # built for the route assertion only — never forwarded here
    # Tiled twin of the bf16 resident plan: identical kernel math,
    # forced off the resident route by an under-cutting budget.
    bf16_tiled_plan = plan_mod.build_plan(
        rws,
        rbs,
        radix_width,
        tuned=tune.TunedConfig(
            panel_dtype="bfloat16", vmem_limit_bytes=vmem_bf16 - 1
        ),
    )
    ref = np.asarray(default_plan.forward(probe), np.float32)
    out = np.asarray(bf16_tiled_plan.forward(probe), np.float32)
    bf16_err = float(np.max(np.abs(out - ref)))
    wall_f32 = timeit(default_plan.forward, probe)
    wall_bf16 = timeit(bf16_tiled_plan.forward, probe)
    table.put(
        plan_mod.topology_fingerprint(rws),
        jax.default_backend(),
        "float32",
        bf16_cfg,
        {
            "width": radix_width,
            "route": bf16_plan.route,
            "default_route": default_plan.route,
            "grid_steps": int(bf16_plan.grid_steps),
            "default_grid_steps": int(default_plan.grid_steps),
            "vmem_bytes": int(vmem_bf16),
            "default_vmem_bytes": int(vmem_f32),
            "max_abs_err": bf16_err,
            "accuracy_via": "fused-tiled bf16 twin",
        },
    )
    radix = {
        "neurons": neurons,
        "layers": layers,
        "width": radix_width,
        "density": density,
        "seed": seed,
        "winner": bf16_cfg.token(),
        "route_default": default_plan.route,
        "route_tuned": bf16_plan.route,
        "grid_steps_default": int(default_plan.grid_steps),
        "grid_steps_tuned": int(bf16_plan.grid_steps),
        "vmem_bytes_f32": int(vmem_f32),
        "vmem_bytes_bf16": int(vmem_bf16),
        "vmem_soft_limit": int(VMEM_SOFT_LIMIT_BYTES),
        "bf16_max_abs_err": bf16_err,
        "wall_s_f32_tiled": wall_f32,
        "wall_s_bf16_tiled": wall_bf16,
    }

    table.save(TUNING_TABLE_PATH)
    return {
        # Flat generator-param record: tools/check_bench.py compares
        # this whole dict to decide baseline comparability.
        "params": {
            "skewed_specs": [
                [list(shape), total] for shape, total in skewed_specs
            ],
            "skew": skew,
            "block": block,
            "width": width,
            "reps": reps,
            "neurons": neurons,
            "layers": layers,
            "radix_width": radix_width,
            "density": density,
            "seed": seed,
        },
        "skewed": skewed,
        "radix": radix,
        "table_entries": len(table),
        "table_path": os.path.basename(TUNING_TABLE_PATH),
    }


def fleet_arm(
    m: int,
    L: int,
    bpr: int,
    duration_s: float,
    seed: int,
    replicas: int,
    rate_factors,
    miss_budget: float,
):
    """The FLEET arm — replicated serving under open-loop load.

    The same bursty trace shape (``LoadProfile.bursty``, Lewis–Shedler
    thinned Poisson arrivals, two panel width classes) is swept across
    ``rate_factors`` and served twice per rate: by a 1-replica fleet and
    by an N-replica fleet, both through the event-loop front-end on a
    :class:`VirtualClock` with a deterministic grid-step service model.
    Engine compute really runs (outputs are real); latency is the
    model's, so every curve point — p50/p99, deadline-miss rate,
    throughput, plan-cache hit rate — is a pure function of this
    config, bit-identical on any runner, and the CI gate compares it
    exactly.

    Headline metric: **sustained offered load** = the highest swept rate
    whose miss rate (deadline misses + admission rejections, over
    everything offered) stays within ``miss_budget``. The fleet must
    sustain strictly more than the single engine, and the width-class
    affinity router must keep the fleet-wide plan-cache hit rate ≥ 0.9
    (routing by load alone would recompile classes all over the fleet).
    """
    import time

    from repro.serve import (
        FleetFrontend,
        LoadProfile,
        ReplicaFleet,
        ServiceModel,
        SparseDNNEngine,
        VirtualClock,
        generate_jobs,
    )

    ws = [
        BlockSparseMatrix.random(
            jax.random.PRNGKey(900 + i), (m, m), (16, 16), blocks_per_row=bpr
        )
        for i in range(L)
    ]
    bs = [jnp.zeros((m,), jnp.float32) for _ in range(L)]
    profile = {
        "kind": "bursty",
        "base": 10.0,
        "burst_rate": 40.0,
        "burst_every": 2.0,
        "burst_len": 0.5,
    }
    width_classes = (8, 24)
    width_mix = ((4, 0.7), (24, 0.3))
    deadline_s = 0.05
    service = {"base_s": 2e-3, "per_grid_step_s": 1e-4}
    max_pending_cols = 2048
    base_profile = LoadProfile.bursty(
        profile["base"],
        profile["burst_rate"],
        profile["burst_every"],
        profile["burst_len"],
    )

    def run_point(n_replicas: int, factor: float) -> dict:
        jobs = generate_jobs(
            base_profile.scaled(factor),
            duration_s,
            m=m,
            seed=seed,
            width_mix=width_mix,
            deadline_s=deadline_s,
        )
        engines = [
            SparseDNNEngine(ws, bs, batch_align=8) for _ in range(n_replicas)
        ]
        fleet = ReplicaFleet(engines, width_classes=width_classes)
        fe = FleetFrontend(
            fleet,
            clock=VirtualClock(),
            service_model=ServiceModel(**service),
            max_pending_cols=max_pending_cols,
        )
        st = fe.run(jobs)
        f = st["fleet"]
        return {
            "replicas": n_replicas,
            "rate_factor": factor,
            "offered_jobs": st["offered_jobs"],
            "offered_jobs_per_s": st["offered_jobs"] / duration_s,
            "served_jobs": st["served_jobs"],
            "failed_jobs": st["failed_jobs"],
            "rejected_jobs": st["rejected_jobs"],
            "deadline_misses": st["deadline_misses"],
            "miss_rate": st["miss_rate"],
            "latency_p50_s": st["latency_p50_s"],
            "latency_p99_s": st["latency_p99_s"],
            "latency_max_s": st["latency_max_s"],
            "throughput_cols_per_s": st["throughput_cols_per_s"],
            "goodput_cols_per_s": st["goodput_cols_per_s"],
            "plan_hit_rate": f["plan_hit_rate"],
            "cross_replica_compiles": f["cross_replica_compiles"],
            "routing": f["routing"],
        }

    t0 = time.perf_counter()
    curves = {
        "single": [run_point(1, f) for f in rate_factors],
        "fleet": [run_point(replicas, f) for f in rate_factors],
    }

    def sustained(points) -> float:
        ok = [
            p["offered_jobs_per_s"]
            for p in points
            if p["miss_rate"] <= miss_budget
        ]
        return max(ok, default=0.0)

    return {
        "m": m,
        "layers": L,
        "blocks_per_row": bpr,
        "duration_s": duration_s,
        "seed": seed,
        "replicas": replicas,
        "rate_factors": list(rate_factors),
        "miss_budget": miss_budget,
        "profile": profile,
        "width_classes": list(width_classes),
        "width_mix": [list(p) for p in width_mix],
        "deadline_s": deadline_s,
        "service_model": service,
        "max_pending_cols": max_pending_cols,
        "curves": curves,
        "sustained_jobs_per_s": {
            "single": sustained(curves["single"]),
            "fleet": sustained(curves["fleet"]),
        },
        "fleet_plan_hit_rate_min": min(
            p["plan_hit_rate"] for p in curves["fleet"]
        ),
        "wall_time_s": time.perf_counter() - t0,
    }


ALL_ARMS = (
    "topologies", "fused", "train", "serve", "plan", "sharded", "faults",
    "challenge", "gnn", "tune", "fleet",
)


def run(quick: bool = False, arms=None):
    arms = set(ALL_ARMS) if arms is None else set(arms)
    unknown = arms - set(ALL_ARMS)
    if unknown:
        raise SystemExit(
            f"unknown arm(s) {sorted(unknown)}; choose from {ALL_ARMS}"
        )
    payload = {
        "backend": jax.default_backend(),
        "interpret_kernels": kernel_ops.auto_interpret(),
        "quick": quick,
    }

    n = 64
    sizes = [256] if quick else [256, 512, 1024]
    skews = [0.0, 0.9] if quick else [0.0, 0.5, 0.9]
    inv_sparsities = [8, 32] if quick else [8, 32, 128]

    if "topologies" in arms:
        topologies = []
        for m in sizes:
            block = 16
            ncb = m // block
            for inv in inv_sparsities:
                total = max((m // block) * max(ncb // inv, 1), 1)
                for skew in skews:
                    r = topology_arms(m, block, total, skew, n)
                    topologies.append(r)
                    print(
                        f"m={m:5d} inv={inv:4d} skew={skew:.1f}  "
                        f"steps ell={r['grid_steps_ell']:6d} "
                        f"csr={r['grid_steps_csr']:6d} "
                        f"(ratio {r['step_ratio_ell_over_csr']:.2f})  "
                        f"xla ell={r['xla_time_s']['ell']*1e3:7.2f}ms "
                        f"csr={r['xla_time_s']['csr']*1e3:7.2f}ms "
                        f"dense={r['xla_time_s']['dense']*1e3:7.2f}ms",
                        flush=True,
                    )
        # The tentpole invariant, asserted on every benchmark run:
        for r in topologies:
            if r["max_blocks_per_row"] > r["mean_blocks_per_row"]:
                assert r["grid_steps_csr"] < r["grid_steps_ell"], r
        payload["topologies"] = topologies

    if "fused" in arms:
        fused = fused_arm(m=256, L=4 if quick else 8, bpr=3, n=128)
        print(
            f"fused: L={fused['layers']} pallas_calls "
            f"{fused['pallas_calls_layered']}→{fused['pallas_calls_fused']}, "
            f"max rel err {fused['max_rel_err_vs_layered']:.2e}",
            flush=True,
        )
        assert fused["pallas_calls_fused"] == 1
        assert fused["max_rel_err_vs_layered"] <= 1e-5
        payload["fused"] = fused

    if "train" in arms:
        train = train_arm(
            m=64 if quick else 128,
            L=3,
            block=16,
            bpr=2,
            n=32,
            steps=3 if quick else 6,
        )
        print(
            f"train: L={train['layers']} layouts={train['layout_per_layer']} "
            f"pallas/step {train['pallas_calls_per_step']} "
            f"(fwd-only would be {train['pallas_calls_forward_only']}), "
            f"loss {train['losses'][0]:.4f}→{train['losses'][-1]:.4f}",
            flush=True,
        )
        # training arm: kernels in both passes, learning, sparsity kept
        assert train["loss_decreased"], train["losses"]
        assert train["weight_cotangent_pattern_preserved"]
        assert (
            train["pallas_calls_per_step"] > train["pallas_calls_forward_only"]
        )
        payload["train"] = train

    if "serve" in arms:
        # Serving arm: SAME trace + knobs in quick and full runs, so the
        # CI gate's baseline comparison is always like-for-like.
        serve = serve_arm(
            m=64,
            L=3,
            bpr=2,
            n_requests=100,
            batch_size=32,
            tile_align=8,
            lam=3.0,
            burst_every=8,
            burst_size=12,
            seed=7,
            min_fill=0.25,
            max_wait=3,
        )
        print(
            f"serve: {serve['requests']} reqs over {serve['trace']['ticks']} "
            f"ticks  pad-frac static={serve['static']['pad_slot_fraction']:.3f} "
            f"continuous={serve['continuous']['pad_slot_fraction']:.3f}  "
            f"grid steps {serve['static']['grid_steps_total']}"
            f"→{serve['continuous']['grid_steps_total']}  "
            f"latency p50/max "
            f"{serve['continuous']['latency_p50']:.0f}/"
            f"{serve['continuous']['latency_max']} ticks",
            flush=True,
        )
        # serving arm: every request served, the resident path engaged,
        # and continuous batching strictly beats static aligned batching
        # on pad waste AND total kernel grid steps for the same trace
        assert serve["static"]["requests"] == serve["requests"]
        assert serve["continuous"]["requests"] == serve["requests"]
        assert serve["resident_path_used"]
        assert (
            serve["continuous"]["pad_slot_fraction"]
            < serve["static"]["pad_slot_fraction"]
        ), serve
        assert (
            serve["continuous"]["grid_steps_total"]
            < serve["static"]["grid_steps_total"]
        ), serve
        payload["serve"] = serve

    if "plan" in arms:
        # Plan arm: same trace as serve, width-class quantized; plus the
        # cached-transpose train loop. Identical in quick and full runs.
        plan = plan_arm(
            m=64,
            L=3,
            bpr=2,
            n_requests=100,
            batch_size=32,
            tile_align=8,
            lam=3.0,
            burst_every=8,
            burst_size=12,
            seed=7,
            width_classes=(16, 32),
            train_n=32,
            train_steps=12,
        )
        print(
            f"plan: serve {plan['serve']['engine_steps']} steps, "
            f"{plan['serve']['plan_builds']} compiled plans, hit rate "
            f"{plan['serve']['cache_hit_rate']:.3f}  "
            f"train sorts {plan['train']['sorts_total']} "
            f"(csr layers {plan['train']['csr_layers']}), "
            f"step {plan['train']['step_time_s']['legacy']*1e3:.1f}ms"
            f"→{plan['train']['step_time_s']['planned']*1e3:.1f}ms",
            flush=True,
        )
        # plan arm: the PlanCache demonstrably amortizes — ≥ 90 % hit
        # rate on the 100-request trace with a handful of compiled width
        # classes, and the planned train loop sorts the frozen topology
        # exactly once (at plan build; the loop itself is sort-free).
        assert plan["serve"]["cache_hit_rate"] >= 0.9, plan["serve"]
        assert plan["serve"]["plan_builds"] <= len(plan["width_classes"]), plan
        assert plan["serve"]["rows_served"] == plan["requests"]
        assert (
            plan["train"]["sorts_total"]
            == plan["train"]["sorts_at_plan_build"]
            == plan["train"]["csr_layers"]
            == 1
        ), plan["train"]
        assert plan["train"]["legacy_jaxpr_has_sort"], plan["train"]
        assert not plan["train"]["planned_jaxpr_has_sort"], plan["train"]
        assert plan["train"]["loss_decreased"], plan["train"]
        assert plan["train"]["losses_match_legacy"], plan["train"]
        payload["plan"] = plan

    if "sharded" in arms:
        # Sharding arm: fixed stack in quick AND full runs (like serve),
        # nnz divisible by the shard count → exact bill equality.
        sharded = sharded_arm(m=128, L=3, block=16, bpr=4, n=64, shards=8)
        print(
            f"sharded: {sharded['shards']} shards over "
            f"{sharded['nnz_blocks_total']} nnz blocks  "
            f"bill {sharded['grid_steps_unsharded']}"
            f"→max/shard {sharded['critical_path_steps']} "
            f"(speedup bound {sharded['parallel_speedup_bound']:.2f}x)  "
            f"imbalance {sharded['imbalance']:.3f}",
            flush=True,
        )
        # sharding arm: per-shard bills sum EXACTLY to the unsharded
        # occupancy-exact bill, and the partitioner stays balanced
        assert sharded["bill_matches_unsharded"], sharded
        assert sharded["shard_pad_blocks"] == 0, sharded
        assert sharded["imbalance"] <= 1.10, sharded
        payload["sharded"] = sharded

    if "faults" in arms:
        # Robustness arm: identical faulted trace in quick and full
        # runs (like serve) so the gate compares like with like.
        faults = faults_arm(
            m=64,
            L=3,
            bpr=2,
            n_requests=100,
            batch_size=16,
            tile_align=8,
            seed=11,
        )
        fserve = faults["serve"]
        print(
            f"faults: {fserve['completed']}/{fserve['faults']['offered']} "
            f"served  goodput {fserve['goodput']:.3f}  "
            f"shed {fserve['faults']['shed']} "
            f"rejected {fserve['faults']['rejected']} "
            f"quarantined {fserve['faults']['quarantined']}  "
            f"degrade {'→'.join(faults['degrade']['levels'][:2])} "
            f"(match {faults['degrade']['matches_single_device_after_failure']})  "
            f"train restarts {faults['train']['restarts']} "
            f"skip {faults['train']['skipped_steps']}",
            flush=True,
        )
        # robustness arm: the faulted trace completes with goodput ≥
        # 0.8, every scheduled fault actually fired, shard failure
        # degrades to a single-device plan with identical results, and
        # the NaN-lossed train run replays a clean run exactly
        assert fserve["goodput"] >= 0.8, fserve
        assert fserve["injector_pending"] == 0, fserve
        assert fserve["faults"]["quarantined"] == 2, fserve
        assert fserve["faults"]["retried_steps"] == 1, fserve
        assert fserve["faults"]["rejected"] > 0, fserve
        assert fserve["faults"]["shed"] > 0, fserve
        assert faults["degrade"]["recovery_steps"] == 0, faults["degrade"]
        assert faults["degrade"]["matches_single_device_after_failure"], (
            faults["degrade"]
        )
        assert faults["degrade"]["degraded"], faults["degrade"]
        assert faults["train"]["losses_match_clean"], faults["train"]
        assert faults["train"]["skipped_steps"] == [3], faults["train"]
        payload["faults"] = faults

    if "challenge" in arms:
        # Challenge arm: fixed config in quick AND full runs (like
        # serve) — sized past the VMEM budget so the tiled fused route
        # is what gets measured.
        challenge = challenge_arm(
            neurons=16384,
            layers=6,
            n_inputs=48,
            panel_width=24,
            batch_align=8,
            density=0.4,
            seed=2,
        )
        print(
            f"challenge: {challenge['neurons']}x{challenge['layers']} "
            f"({challenge['edges']} edges, bias {challenge['bias']})  "
            f"route {'/'.join(challenge['routes'])}  "
            f"{challenge['n_categories']}/{challenge['n_inputs']} "
            f"categories (reference match "
            f"{challenge['reference_match']})  "
            f"{challenge['edge_inputs_per_sec']:.3g} edge-inputs/s",
            flush=True,
        )
        # challenge arm: the over-budget stack MUST take the tiled
        # fused route end to end, and the engine's answer set must
        # reproduce the numpy ground truth bit-for-bit
        assert challenge["routes"] == ["fused-tiled"], challenge
        assert challenge["levels"] == ["resident"], challenge
        assert challenge["reference_match"], challenge
        assert 0 < challenge["n_categories"] < challenge["n_inputs"]
        assert challenge["served"] == challenge["n_inputs"]
        payload["challenge"] = challenge

    if "gnn" in arms:
        # GNN arm: fixed config in quick AND full runs — every
        # accounting field is a pure function of the seeded topology.
        gnn = gnn_arm(
            m=256,
            block=16,
            total_blocks=56,
            skew=0.8,
            feat_dim=32,
            rounds=2,
            bf_iters_cap=32,
            seed=5,
        )
        print(
            f"gnn: {gnn['m']}x{gnn['m']} adjacency "
            f"({gnn['total_blocks']} blocks, skew {gnn['skew']})  "
            f"layout {gnn['source_layout']}→{gnn['exec_layout']}  "
            f"steps xla {gnn['xla_sparse_grid_steps']}"
            f"→kernel {gnn['kernel_grid_steps']} "
            f"({gnn['step_ratio_xla_over_kernel']:.2f}x)  "
            f"conv rel err {gnn['conv_max_rel_err']:.2e}  "
            f"BF fixpoint in {gnn['bf_iters']} iters "
            f"({gnn['bf_reachable']}/{gnn['m']} reachable, "
            f"numpy match {gnn['bf_matches_numpy']})",
            flush=True,
        )
        # gnn arm headline: graphblas.mxm on the sparse adjacency
        # demonstrably launches the Pallas kernel route (the oracle
        # route launches none), the plan's re-laid-out kernel bill
        # STRICTLY beats the occupancy-equivalent XLA sparse path, the
        # convolution matches the oracle, and the min_plus Bellman-Ford
        # relaxation reaches the numpy reference fixpoint bit-for-bit.
        assert gnn["pallas_calls_conv"] >= 1, gnn
        assert gnn["pallas_calls_oracle"] == 0, gnn
        assert gnn["kernel_grid_steps"] < gnn["xla_sparse_grid_steps"], gnn
        assert gnn["exec_layout"] == "bcsr", gnn
        assert gnn["conv_matches_oracle"], gnn
        assert gnn["bf_converged"], gnn
        assert gnn["bf_matches_numpy"], gnn
        assert gnn["conv_plan_hits"] >= 1, gnn  # rounds reuse the plan
        assert gnn["bf_plan_hits"] >= 1, gnn  # mxv iterations reuse too
        payload["gnn"] = gnn

    if "tune" in arms:
        # Tune arm: fixed config in quick AND full runs — the sweep is
        # cost-model-scored, so every accounting field is exact.
        tune = tune_arm(
            skewed_specs=(
                ((128, 256), 100),
                ((128, 128), 55),
                ((64, 128), 28),
            ),
            skew=0.3,
            block=16,
            width=64,
            reps=3,
            neurons=8192,
            layers=2,
            radix_width=32,
            density=0.3,
            seed=2,
        )
        sk, rad = tune["skewed"], tune["radix"]
        print(
            f"tune: skewed winner {sk['winner']}  steps "
            f"{sk['grid_steps_default']}→{sk['grid_steps_tuned']}  "
            f"wall {sk['wall_s_default']*1e3:.2f}ms"
            f"→{sk['wall_s_tuned']*1e3:.2f}ms  |  "
            f"radix {rad['neurons']}x{rad['layers']} "
            f"{rad['winner']}: route {rad['route_default']}"
            f"→{rad['route_tuned']} (panel "
            f"{rad['vmem_bytes_f32']>>20}MiB→{rad['vmem_bytes_bf16']>>20}MiB"
            f" vs {rad['vmem_soft_limit']>>20}MiB budget)  "
            f"bf16 err {rad['bf16_max_abs_err']:.4f}",
            flush=True,
        )
        # tune arm headline: the sweep's deterministic cost-model
        # scoring finds a config that STRICTLY beats the default — on
        # the skewed stack a forced block-CSR layout drops the exact
        # grid-step bill, and on the over-budget RadiX-net stack bf16
        # activation panels halve the resident footprint and move the
        # route from fused-tiled back to fused, with numerics inside
        # the gate on challenge-shaped inputs.
        assert sk["winner"] == "layout=bcsr", sk
        assert sk["grid_steps_tuned"] < sk["grid_steps_default"], sk
        assert sk["block_work_tuned"] < sk["block_work_default"], sk
        assert sk["accuracy_ok"], sk
        assert rad["route_default"] == "fused-tiled", rad
        assert rad["route_tuned"] == "fused", rad
        assert (
            rad["vmem_bytes_bf16"]
            <= rad["vmem_soft_limit"]
            < rad["vmem_bytes_f32"]
        ), rad
        assert rad["bf16_max_abs_err"] <= 0.05, rad
        assert tune["table_entries"] == 2, tune
        payload["tune"] = tune
        print(f"wrote {TUNING_TABLE_PATH}")

    if "fleet" in arms:
        # Fleet arm: identical config in quick and full runs (virtual
        # clock — the sweep costs engine compute, not waiting).
        fleet = fleet_arm(
            m=64,
            L=3,
            bpr=2,
            duration_s=8.0,
            seed=17,
            replicas=3,
            rate_factors=(2.0, 4.0, 6.0, 8.0),
            miss_budget=0.01,
        )
        sus = fleet["sustained_jobs_per_s"]
        print(
            f"fleet: sustained {sus['single']:.1f} jobs/s x1 → "
            f"{sus['fleet']:.1f} jobs/s x{fleet['replicas']} "
            f"(miss budget {fleet['miss_budget']})  "
            f"hit rate ≥ {fleet['fleet_plan_hit_rate_min']:.3f}  "
            f"p99 at top rate "
            f"{fleet['curves']['single'][-1]['latency_p99_s']*1e3:.1f}ms"
            f"→{fleet['curves']['fleet'][-1]['latency_p99_s']*1e3:.1f}ms",
            flush=True,
        )
        # fleet arm headline: N replicas behind the affinity router
        # sustain STRICTLY more offered load than one engine at the
        # same miss budget; the router keeps fleet-wide plan-cache hit
        # rate at single-engine levels; and nothing is ever dropped —
        # every offered job is served, failed-gracefully, or visibly
        # rejected at admission.
        assert sus["fleet"] > sus["single"], fleet["sustained_jobs_per_s"]
        assert fleet["fleet_plan_hit_rate_min"] >= 0.9, fleet
        for arm_name, points in fleet["curves"].items():
            for p in points:
                assert (
                    p["served_jobs"] + p["failed_jobs"] + p["rejected_jobs"]
                    == p["offered_jobs"]
                ), (arm_name, p)
                assert p["failed_jobs"] == 0, (arm_name, p)
        payload["fleet"] = fleet
        # Standalone latency-curve artifact for the CI bench job upload.
        with open(FLEET_CURVES_PATH, "w") as f:
            json.dump(
                {
                    "curves": fleet["curves"],
                    "sustained_jobs_per_s": fleet["sustained_jobs_per_s"],
                    "miss_budget": fleet["miss_budget"],
                    "replicas": fleet["replicas"],
                },
                f,
                indent=1,
            )
        print(f"wrote {FLEET_CURVES_PATH}")

    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {OUT_PATH}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--arms",
        default=None,
        help="comma-separated subset of arms to run "
        f"({','.join(ALL_ARMS)}; default: all). Partial artifacts are "
        "for local iteration — the CI gate compares full runs.",
    )
    args = ap.parse_args()
    arms = None if args.arms is None else args.arms.split(",")
    run(quick=args.quick, arms=arms)


if __name__ == "__main__":
    main()
