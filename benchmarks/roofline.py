"""§Roofline: aggregate the dry-run JSON artifacts into the roofline
table (per arch × shape × mesh: three terms, dominant bottleneck, MFU
bound, MODEL_FLOPS/HLO_FLOPs usefulness ratio).

``python -m benchmarks.roofline [--dir experiments/dryrun] [--markdown]``
"""

from __future__ import annotations

import argparse
import json
import os


def load(dir_: str) -> list[dict]:
    rows = []
    if not os.path.isdir(dir_):
        return rows
    for fn in sorted(os.listdir(dir_)):
        if fn.endswith(".json"):
            with open(os.path.join(dir_, fn)) as f:
                rows.append(json.load(f))
    return rows


def table(rows: list[dict], markdown: bool = False) -> str:
    out = []
    if markdown:
        out.append(
            "| arch | shape | mesh | t_compute | t_memory | t_collective |"
            " dominant | roofline frac | useful FLOPs | GiB/dev |"
        )
        out.append("|---|---|---|---|---|---|---|---|---|---|")
    else:
        out.append(
            f"{'arch':<22s} {'shape':<12s} {'mesh':<11s} {'t_comp':>9s}"
            f" {'t_mem':>9s} {'t_coll':>9s} {'dominant':<10s} {'frac':>6s}"
            f" {'useful':>7s} {'GiB':>6s}"
        )
    for r in rows:
        if r.get("status") == "skipped":
            msg = r.get("reason", "")[:48]
            if markdown:
                out.append(
                    f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                    f" skipped: {msg} |||||||"
                )
            else:
                out.append(
                    f"{r['arch']:<22s} {r['shape']:<12s} {r['mesh']:<11s}"
                    f" SKIP: {msg}"
                )
            continue
        if r.get("status") != "ok":
            out.append(
                f"{r['arch']:<22s} {r['shape']:<12s} {r['mesh']:<11s}"
                f" ERROR: {r.get('error', '?')[:60]}"
            )
            continue
        t = r["roofline"]
        gib = r["memory"]["peak_per_device_bytes"] / 2**30
        vals = (
            f"{t['t_compute_s']*1e3:8.1f}ms",
            f"{t['t_memory_s']*1e3:8.1f}ms",
            f"{t['t_collective_s']*1e3:8.1f}ms",
            t["dominant"].replace("t_", "").replace("_s", ""),
            f"{t['roofline_fraction']:.3f}",
            f"{r['useful_flops_fraction']:.2f}",
            f"{gib:.2f}",
        )
        if markdown:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                + " | ".join(vals)
                + " |"
            )
        else:
            out.append(
                f"{r['arch']:<22s} {r['shape']:<12s} {r['mesh']:<11s}"
                f" {vals[0]:>9s} {vals[1]:>9s} {vals[2]:>9s} {vals[3]:<10s}"
                f" {vals[4]:>6s} {vals[5]:>7s} {vals[6]:>6s}"
            )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    if not rows:
        print(f"[roofline] no artifacts under {args.dir}; run repro.launch.dryrun")
        return
    print(table(rows, markdown=args.markdown))
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["t_collective_s"])
        print(
            f"\n[roofline] worst fraction: {worst['arch']}/{worst['shape']}"
            f" ({worst['roofline']['roofline_fraction']:.3f});"
            f" most collective-bound: {coll['arch']}/{coll['shape']}"
            f" ({coll['roofline']['t_collective_s']*1e3:.0f}ms)"
        )


if __name__ == "__main__":
    main()
