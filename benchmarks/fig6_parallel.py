"""Paper Fig. 6 analogue: parallel scaling of the sparse vs dense DNN layer.

The paper measures 4/16-thread OpenMP speedup on a 24-core POWER8. This
container exposes ONE core, so wall-clock thread scaling cannot be
measured here. We reproduce the *structure* of the result instead: the
work per partition when the same layer is SPMD-partitioned over k
devices (the quantity whose decay sets the parallel-speedup ceiling),
measured from compiled per-device HLO FLOPs/bytes at k ∈ {1, 4, 16}.

The paper's qualitative finding — parallel efficiency drops as the
matrix gets sparser because per-partition work shrinks toward the fixed
row-processing overhead — appears here as the sparse arm's per-device
bytes flattening (index/padding overhead) while dense per-device FLOPs
keep dividing by k.

Run in a SUBPROCESS per k (jax fixes the device count at first init):
``python -m benchmarks.fig6_parallel`` orchestrates itself.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

M = 4096
BATCH = 64
BLOCK = 16
INVS = (1, 16, 256)


def worker(k: int) -> list[dict]:
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={k}"
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import hlo_analysis
    from repro.sparse import ops as sparse_ops
    from repro.sparse.bsr import BlockSparseMatrix

    mesh = jax.make_mesh((k,), ("model",))
    rows = []
    with mesh:
        for inv in INVS:
            ncb = M // BLOCK
            bpr = max(1, round(ncb / inv))
            w = BlockSparseMatrix.random(
                jax.random.key(0), (M, M), (BLOCK, BLOCK), bpr
            )
            y = jax.ShapeDtypeStruct((M, BATCH), jnp.float32)
            b = jax.ShapeDtypeStruct((M,), jnp.float32)
            w_specs = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), w
            )
            shard_row = NamedSharding(mesh, P("model"))
            rep = NamedSharding(mesh, P())

            def sparse_fn(w, y, b):
                return sparse_ops.bsr_matmul_fused_relu(w, y, b)

            in_sh = (
                jax.tree.map(lambda _: shard_row, w_specs),
                rep,
                shard_row,
            )
            c = (
                jax.jit(sparse_fn, in_shardings=in_sh)
                .lower(w_specs, y, b)
                .compile()
            )
            st = hlo_analysis.analyze(c.as_text())
            dense_fn = lambda w, y, b: jnp.maximum(w @ y + b[:, None], 0.0)
            wd = jax.ShapeDtypeStruct((M, M), jnp.float32)
            cd = (
                jax.jit(
                    dense_fn,
                    in_shardings=(
                        NamedSharding(mesh, P("model", None)),
                        rep,
                        shard_row,
                    ),
                )
                .lower(wd, y, b)
                .compile()
            )
            std = hlo_analysis.analyze(cd.as_text())
            rows.append(
                {
                    "k": k,
                    "inverse_sparsity": inv,
                    "sparse_flops_per_dev": st.flops,
                    "sparse_bytes_per_dev": st.bytes_accessed,
                    "dense_flops_per_dev": std.flops,
                    "dense_bytes_per_dev": std.bytes_accessed,
                }
            )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker-k", type=int, default=None)
    args = ap.parse_args()
    if args.worker_k:
        print(json.dumps(worker(args.worker_k)))
        return

    sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))
    all_rows = []
    for k in (1, 4, 16):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.fig6_parallel", "--worker-k", str(k)],
            capture_output=True,
            text=True,
            env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        if out.returncode != 0:
            print(out.stderr[-2000:])
            raise SystemExit(1)
        all_rows.extend(json.loads(out.stdout.strip().splitlines()[-1]))

    from benchmarks.common import save_results

    base = {
        (r["inverse_sparsity"],): r for r in all_rows if r["k"] == 1
    }
    print(f"{'k':>3s} {'inv':>5s} {'dense work/dev':>15s} {'sparse work/dev':>16s} {'dense eff':>10s} {'sparse eff':>10s}")
    for r in all_rows:
        b = base[(r["inverse_sparsity"],)]
        de = b["dense_flops_per_dev"] / (r["dense_flops_per_dev"] * r["k"]) if r["dense_flops_per_dev"] else 0
        # sparse work is bytes-dominated at high sparsity: use bytes
        se = b["sparse_bytes_per_dev"] / (r["sparse_bytes_per_dev"] * r["k"]) if r["sparse_bytes_per_dev"] else 0
        print(
            f"{r['k']:3d} {r['inverse_sparsity']:5d} "
            f"{r['dense_flops_per_dev']:15.3e} {r['sparse_bytes_per_dev']:16.3e} "
            f"{de:10.2f} {se:10.2f}"
        )
    save_results("fig6_parallel", all_rows)
    print("[fig6] parallel-efficiency ceilings recorded (1-core container: "
          "work-per-partition analogue of the paper's thread speedup)")


if __name__ == "__main__":
    main()
