"""Shared benchmark utilities: timing, matrix synthesis per paper §V-B."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds of fn(*args) (jit'd or not), blocked."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def paper_dense_weight(key, m: int) -> jax.Array:
    """U[-1, 3) dense weight (paper §V-B)."""
    return jax.random.uniform(key, (m, m), jnp.float32, -1.0, 3.0)


def paper_sparse_weight_np(
    seed: int, m: int, inverse_sparsity: int
) -> np.ndarray:
    """Bernoulli element sparsity at density 1/inverse_sparsity with
    U[-1,3) values (paper §V-B), as a host array."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(-1.0, 3.0, (m, m)).astype(np.float32)
    if inverse_sparsity > 1:
        mask = rng.random((m, m)) < (1.0 / inverse_sparsity)
        w = np.where(mask, w, 0.0).astype(np.float32)
    return w


def paper_input(key, m: int, n: int = 64) -> jax.Array:
    """U[0,1) layer input, batch 64 (paper §V-B)."""
    return jax.random.uniform(key, (m, n), jnp.float32)


def save_results(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load_results(name: str):
    path = os.path.join(RESULTS_DIR, name + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)
