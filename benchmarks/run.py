"""Benchmark orchestrator: ``python -m benchmarks.run [--full]``.

One benchmark per paper table/figure (DESIGN.md §9):
  kernel_bench  — ELL vs occupancy-exact CSR grid vs fused-multilayer vs
                  dense kernel arms (writes BENCH_kernels.json at repo root)
  fig5_sweep    — sparse vs dense forward time vs inverse sparsity (Fig. 5)
  fig7_scaling  — scaling parameters of those curves (Fig. 7)
  fig6_parallel — partitioned work-per-device analogue of thread scaling
                  (Fig. 6; this container has 1 core — see module doc)
  memory_table  — sparse vs dense storage (§V-C)
  roofline      — the (arch × shape × mesh) roofline table from the
                  dry-run artifacts, if present (deliverable g)

``--quick`` shrinks the fig5 grid (used by CI/tests); ``--full`` adds
m=32768 (several GiB of host RAM and minutes of runtime).
"""

from __future__ import annotations

import argparse
import subprocess
import sys
import time


def _run(mod: str, *args: str) -> None:
    t0 = time.monotonic()
    print(f"\n===== {mod} {' '.join(args)} =====", flush=True)
    r = subprocess.run([sys.executable, "-m", mod, *args])
    if r.returncode != 0:
        raise SystemExit(f"{mod} failed with {r.returncode}")
    print(f"===== {mod} done in {time.monotonic()-t0:.1f}s =====", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    fig5_args = ["--quick"] if args.quick else (["--full"] if args.full else [])
    kb_args = ["--quick"] if args.quick else []
    _run("benchmarks.kernel_bench", *kb_args)
    _run("benchmarks.fig5_sweep", *fig5_args)
    _run("benchmarks.fig7_scaling")
    _run("benchmarks.memory_table")
    _run("benchmarks.fig6_parallel")
    _run("benchmarks.paper_scale")
    _run("benchmarks.roofline")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
