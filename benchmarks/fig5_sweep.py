"""Paper Fig. 5: sparse (GraphBLAS) vs dense (BLAS) forward-layer time
as a function of inverse sparsity, for several matrix sizes m, batch 64.

Three arms on this container's CPU (real wall-clock, like the paper's
POWER8 measurements):

  BLAS   — dense jnp matmul + bias + ReLU (the paper's OpenBLAS arm;
           XLA CPU lowers to an optimized dense GEMM).
  GrB-el — element-granularity sparse (jax.experimental.sparse BCOO
           dot_general): the closest JAX analogue of the paper's CSR
           GraphBLAS arm with Bernoulli element sparsity.
  GrB-bl — our TPU-native arm: ELL-padded BSR (block-magnitude topology)
           through repro.sparse.ops — the arm that maps to the Pallas
           kernel on real hardware.

The paper's observations to reproduce: (1) BLAS flat in sparsity;
(2) GrB crossover near inverse sparsity ≈ 4–10; (3) GrB time saturates
at a floor once inverse sparsity ≫ n (fixed row-processing cost).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.experimental import sparse as jsparse

from benchmarks.common import (
    paper_input,
    paper_sparse_weight_np,
    save_results,
    timeit,
)
from repro.sparse import ops as sparse_ops
from repro.sparse.bsr import BlockSparseMatrix

DEFAULT_SIZES = (512, 2048, 8192)
FULL_SIZES = (512, 2048, 8192, 32768)
INV_SPARSITIES = (1, 4, 16, 64, 256, 1024, 4096, 16384, 65536, 262144)


@jax.jit
def _blas_layer(w, y, b):
    return jnp.maximum(w @ y + b[:, None], 0.0)


def _grb_el_layer(w_sp, y, b):
    z = jsparse.bcoo_dot_general(
        w_sp, y, dimension_numbers=(((1,), (0,)), ((), ()))
    )
    return jnp.maximum(z + b[:, None], 0.0)


def _grb_block_layer(w_bsr, y, b):
    return sparse_ops.bsr_matmul_fused_relu(w_bsr, y, b)


def run(sizes=DEFAULT_SIZES, inv_sparsities=INV_SPARSITIES, batch=64, block=16):
    key = jax.random.key(0)
    rows = []
    grb_el_jit = jax.jit(_grb_el_layer)
    grb_bl_jit = jax.jit(_grb_block_layer)
    for m in sizes:
        y = paper_input(key, m, batch)
        b = jnp.zeros((m,))
        w_dense_host = paper_sparse_weight_np(0, m, 1)
        t_blas = timeit(_blas_layer, jnp.asarray(w_dense_host), y, b)
        for inv in inv_sparsities:
            if inv > m * m:
                continue
            w_host = paper_sparse_weight_np(1, m, inv)
            nnz = int((w_host != 0).sum())
            # element arm (paper-faithful Bernoulli sparsity)
            w_sp = jsparse.BCOO.fromdense(jnp.asarray(w_host))
            t_el = timeit(grb_el_jit, w_sp, y, b)
            # block arm (TPU-native topology at matched nnz budget)
            ncb = m // block
            bpr = max(1, round(ncb / inv))
            w_bsr = BlockSparseMatrix.random(
                jax.random.key(2), (m, m), (block, block), bpr
            )
            t_bl = timeit(grb_bl_jit, w_bsr, y, b)
            rows.append(
                {
                    "m": m,
                    "inverse_sparsity": inv,
                    "nnz": nnz,
                    "t_blas_s": t_blas,
                    "t_grb_element_s": t_el,
                    "t_grb_block_s": t_bl,
                    "speedup_el_vs_blas": t_blas / t_el,
                    "speedup_bl_vs_blas": t_blas / t_bl,
                }
            )
            print(
                f"m={m:6d} inv={inv:7d} BLAS={t_blas*1e3:9.3f}ms "
                f"GrB-el={t_el*1e3:9.3f}ms GrB-bl={t_bl*1e3:9.3f}ms "
                f"el-speedup={t_blas/t_el:7.2f}x",
                flush=True,
            )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="include m=32768")
    ap.add_argument("--quick", action="store_true", help="tiny grid (CI)")
    args = ap.parse_args()
    if args.quick:
        rows = run(sizes=(512, 2048), inv_sparsities=(1, 4, 64, 1024, 65536))
    else:
        rows = run(sizes=FULL_SIZES if args.full else DEFAULT_SIZES)
    path = save_results("fig5_sweep", rows)
    # paper-claim checks
    crossovers = {}
    for m in {r["m"] for r in rows}:
        sub = sorted(
            (r for r in rows if r["m"] == m), key=lambda r: r["inverse_sparsity"]
        )
        cross = next(
            (r["inverse_sparsity"] for r in sub if r["speedup_el_vs_blas"] >= 1.0),
            None,
        )
        crossovers[m] = cross
        print(f"[fig5] m={m}: GrB-element beats BLAS from inverse sparsity {cross}")
    print(f"[fig5] wrote {path}")


if __name__ == "__main__":
    main()
