"""Paper §V-C memory claim: sparse storage ∝ nnz lets GraphBLAS hold
networks that cannot exist densely (a dense 32768² fp32 W is 4 GiB).

Reports measured bytes for dense vs element (BCOO) vs block (ELL-BSR)
representations across sizes and sparsities, plus the largest network
each representation fits into a 16 GiB v5e HBM (8 layers, fp32).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.experimental import sparse as jsparse

from benchmarks.common import paper_sparse_weight_np, save_results
from repro.sparse.bsr import BlockSparseMatrix

SIZES = (512, 2048, 8192, 32768)
INVS = (1, 16, 256, 4096)


def bcoo_nbytes(w: jsparse.BCOO) -> int:
    return sum(int(np.prod(b.shape)) * b.dtype.itemsize for b in (w.data, w.indices))


def main():
    rows = []
    print(f"{'m':>7s} {'inv':>6s} {'dense':>12s} {'BCOO':>12s} {'ELL-BSR':>12s}")
    for m in SIZES:
        for inv in INVS:
            dense_bytes = m * m * 4
            if m <= 8192:
                w_host = paper_sparse_weight_np(0, m, inv)
                sp = jsparse.BCOO.fromdense(jax.numpy.asarray(w_host))
                el_bytes = bcoo_nbytes(sp)
                del sp, w_host
            else:  # avoid allocating 4 GiB on the small container
                nnz = round(m * m / inv)
                el_bytes = nnz * (4 + 8)  # fp32 value + 2×int32 index
            block = 16
            ncb = m // block
            bpr = max(1, round(ncb / inv))
            bl = BlockSparseMatrix.random(
                jax.random.key(1), (m, m), (block, block), bpr
            )
            bl_bytes = bl.nbytes
            del bl
            rows.append(
                {
                    "m": m,
                    "inverse_sparsity": inv,
                    "dense_bytes": dense_bytes,
                    "bcoo_bytes": el_bytes,
                    "ell_bsr_bytes": bl_bytes,
                }
            )
            print(
                f"{m:7d} {inv:6d} {dense_bytes/2**20:10.1f}Mi "
                f"{el_bytes/2**20:10.1f}Mi {bl_bytes/2**20:10.1f}Mi"
            )
    hbm = 16 * 2**30
    layers = 8
    for inv in INVS:
        m_dense = int(np.sqrt(hbm / (4 * layers)))
        # bytes_sparse(m) = layers · (m²/inv)·12 → m = sqrt(hbm·inv/(12·layers))
        m_sparse = int(np.sqrt(hbm * inv / (12 * layers)))
        print(
            f"[memory] 16GiB HBM, {layers}L fp32: dense fits m≈{m_dense:,}; "
            f"element-sparse inv={inv} fits m≈{m_sparse:,}"
        )
    save_results("memory_table", rows)


if __name__ == "__main__":
    main()
