import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# ruff: noqa: E402
"""The paper's own workload at production scale (beyond-paper §Repro):
an L-layer m=32768 ReLU MLP, batch 64, lowered on the 16×16 mesh in both
arms — dense (BLAS) and ELL-BSR sparse (GraphBLAS) — and compared at the
roofline level. This is the claim of the paper's §V-C carried to TPU:
the sparse arm's memory term and per-device footprint scale with nnz
blocks while the dense arm pays the full m².

``python -m benchmarks.paper_scale [--m 32768] [--layers 8] [--inv 16]``
"""

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import save_results
from repro.core import dnn
from repro.distribution.sharding import activate, shardings_for
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.sparse.bsr import BlockSparseMatrix

P = jax.sharding.PartitionSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=32768)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--inv", type=int, default=16, help="inverse block sparsity")
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    m, L, n = args.m, args.layers, args.batch
    mesh = make_production_mesh()
    nrb = m // args.block
    bpr = max(1, round((m // args.block) / args.inv))

    # --- dense (BLAS) arm: stacked (L, m, m) weights, scanned -------------
    dense_w = jax.ShapeDtypeStruct((L, m, m), jnp.float32)
    biases = jax.ShapeDtypeStruct((L, m), jnp.float32)
    y0 = jax.ShapeDtypeStruct((m, n), jnp.float32)

    def dense_fwd(wb, y):
        w, b = wb
        return dnn.dnn_forward_scan(w, b, y, fused=True)

    dense_sh = (
        jax.tree.map(
            lambda s: s,
            shardings_for(
                None, mesh, (P(None, "data", "model"), P(None, "model"))
            ),
        ),
        shardings_for(None, mesh, P("model", None)),
    )
    with mesh:
        c_dense = (
            jax.jit(dense_fwd, in_shardings=dense_sh)
            .lower((dense_w, biases), y0)
            .compile()
        )
    st_d = hlo_analysis.analyze(c_dense.as_text(), default_trip_count=L)
    ma_d = c_dense.memory_analysis()

    # --- sparse (GraphBLAS/BSR) arm --------------------------------------
    bsr = BlockSparseMatrix(
        blocks=jax.ShapeDtypeStruct((L, nrb, bpr, args.block, args.block), jnp.float32),
        col_idx=jax.ShapeDtypeStruct((L, nrb, bpr), jnp.int32),
        block_mask=jax.ShapeDtypeStruct((L, nrb, bpr), jnp.bool_),
        shape=(m, m),
        block_shape=(args.block, args.block),
    )

    def sparse_fwd(wb, y):
        w, b = wb
        return dnn.dnn_forward_scan(w, b, y, fused=True)

    bsr_sh = BlockSparseMatrix(
        blocks=shardings_for(None, mesh, P(None, ("data", "model"), None, None, None)),
        col_idx=shardings_for(None, mesh, P(None, ("data", "model"), None)),
        block_mask=shardings_for(None, mesh, P(None, ("data", "model"), None)),
        shape=(m, m),
        block_shape=(args.block, args.block),
    )
    with mesh, activate(mesh):
        c_sparse = (
            jax.jit(
                sparse_fwd,
                in_shardings=(
                    (bsr_sh, shardings_for(None, mesh, P(None, "model"))),
                    shardings_for(None, mesh, P("model", None)),
                ),
            )
            .lower((bsr, biases), y0)
            .compile()
        )
    st_s = hlo_analysis.analyze(c_sparse.as_text(), default_trip_count=L)
    ma_s = c_sparse.memory_analysis()

    rows = []
    for tag, st, ma in (("dense", st_d, ma_d), (f"bsr-inv{args.inv}", st_s, ma_s)):
        t = hlo_analysis.roofline_terms(
            flops_per_device=st.flops,
            bytes_per_device=st.bytes_accessed,
            collective_bytes_per_device=st.collective_bytes,
        )
        arg_gib = ma.argument_size_in_bytes / 2**30
        rows.append(
            {
                "arm": tag,
                "m": m,
                "layers": L,
                "flops_per_device": st.flops,
                "bytes_per_device": st.bytes_accessed,
                "collective_bytes": st.collective_bytes,
                "t_memory_s": t["t_memory_s"],
                "t_compute_s": t["t_compute_s"],
                "weights_gib_per_device": arg_gib,
            }
        )
        print(
            f"[paper-scale] {tag:12s} t_comp={t['t_compute_s']*1e3:8.3f}ms "
            f"t_mem={t['t_memory_s']*1e3:8.3f}ms "
            f"args/dev={arg_gib:.3f}GiB"
        )
    d, s = rows
    print(
        f"[paper-scale] sparse arm: {d['bytes_per_device']/max(s['bytes_per_device'],1):.1f}x "
        f"less HBM traffic, {d['weights_gib_per_device']/max(s['weights_gib_per_device'],1e-9):.1f}x "
        f"less weight memory at inverse block sparsity {args.inv} "
        f"(paper §V-C at TPU scale)"
    )
    save_results("paper_scale", rows)


if __name__ == "__main__":
    main()
