"""Paper Fig. 7: scaling parameters of the execution-time curves,
derived from the Fig. 5 sweep results (normalized to m = 4096 — we use
the nearest measured size when 4096 itself is not in the grid).

Parameters reproduced (paper §V-D):
  * BLAS time per element           — ~invariant in m
  * GraphBLAS/BLAS dense-time ratio — ~3.2× in the paper, ~invariant in m
  * Slope of GraphBLAS time w.r.t. sparsity at S=1 (per-nnz cost)
  * Saturation value (almost-empty matrix) per row — ~invariant in m
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import load_results, save_results


def derive(rows):
    sizes = sorted({r["m"] for r in rows})
    out = []
    for m in sizes:
        sub = {r["inverse_sparsity"]: r for r in rows if r["m"] == m}
        dense = sub.get(1)
        if dense is None:
            continue
        t_blas = dense["t_blas_s"]
        t_grb1 = dense["t_grb_element_s"]
        # slope at S=1: (T(S=1) - T(S=1/4)) / (0.75·m²)  [paper formula]
        t_grb4 = sub.get(4, dense)["t_grb_element_s"]
        slope = (t_grb1 - t_grb4) / (0.75 * m * m)
        # saturation: the sparsest measured point, normalized per row
        sparsest = max(sub)
        t_sat = sub[sparsest]["t_grb_element_s"]
        out.append(
            {
                "m": m,
                "blas_per_element": t_blas / (m * m),
                "grb_blas_ratio_dense": t_grb1 / t_blas,
                "grb_slope_per_nnz": slope,
                "saturation_per_row": t_sat / m,
                "saturation_inv_sparsity": sparsest,
            }
        )
    # normalize to the reference size (nearest to 4096, as the paper does)
    ref = min(out, key=lambda r: abs(r["m"] - 4096))
    for r in out:
        r["norm_blas_per_element"] = r["blas_per_element"] / ref["blas_per_element"]
        r["norm_ratio"] = r["grb_blas_ratio_dense"] / ref["grb_blas_ratio_dense"]
        r["norm_slope"] = (
            r["grb_slope_per_nnz"] / ref["grb_slope_per_nnz"]
            if ref["grb_slope_per_nnz"]
            else float("nan")
        )
        r["norm_saturation"] = (
            r["saturation_per_row"] / ref["saturation_per_row"]
        )
    return out, ref["m"]


def main():
    rows = load_results("fig5_sweep")
    if rows is None:
        print("[fig7] run benchmarks.fig5_sweep first")
        return
    out, ref_m = derive(rows)
    print(f"[fig7] normalized to m={ref_m}")
    hdr = f"{'m':>7s} {'BLAS/elem':>10s} {'GrB/BLAS':>9s} {'slope':>8s} {'satur/row':>10s}"
    print(hdr)
    for r in out:
        print(
            f"{r['m']:7d} {r['norm_blas_per_element']:10.3f} "
            f"{r['norm_ratio']:9.3f} {r['norm_slope']:8.3f} "
            f"{r['norm_saturation']:10.3f}"
        )
    ratios = [r["grb_blas_ratio_dense"] for r in out]
    print(
        f"[fig7] dense GrB/BLAS ratio across sizes: "
        f"{np.min(ratios):.2f}–{np.max(ratios):.2f} (paper: ~3.2, invariant)"
    )
    save_results("fig7_scaling", out)


if __name__ == "__main__":
    main()
