import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  — the two lines above MUST precede any jax import
# (jax locks the device count at first init; see MULTI-POD DRY-RUN spec).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
emit the roofline inputs (memory analysis, per-device FLOPs/bytes,
per-device collective wire bytes) as JSON artifacts.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --summary   # table from saved artifacts
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPE_CELLS, get_config
from repro.distribution.sharding import ShardingRules, shardings_for
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_plan, model_flops, skip_reason

OUT_DEFAULT = "experiments/dryrun"


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool,
    rules: ShardingRules | None = None,
    microbatches: int | None = None,
    save_hlo: str | None = None,
) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    base = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "kind": cell.kind,
    }
    reason = skip_reason(cfg, cell)
    if reason:
        return {**base, "status": "skipped", "reason": reason}

    t0 = time.monotonic()
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(arch, shape, mesh, rules=rules, microbatches=microbatches)
    from repro.distribution.sharding import activate

    with mesh, activate(mesh, rules):
        jitted = jax.jit(
            plan.fn,
            in_shardings=shardings_for(plan.args, mesh, plan.in_shardings),
            out_shardings=shardings_for(None, mesh, plan.out_shardings),
            donate_argnums=plan.donate_argnums,
        )
        lowered = jitted.lower(*plan.args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    trip = max(cfg.n_periods, 1)
    stats = hlo_analysis.analyze(hlo, default_trip_count=trip)
    n_chips = mesh.devices.size
    flops_dev = stats.flops
    bytes_dev = stats.bytes_accessed
    terms = hlo_analysis.roofline_terms(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=stats.collective_bytes,
    )
    mf = model_flops(arch, shape)
    useful = mf["model_flops"] / max(flops_dev * n_chips, 1.0)
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "code_bytes": ma.generated_code_size_in_bytes,
        "peak_per_device_bytes": (
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        ),
    }
    return {
        **base,
        "status": "ok",
        "chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "cost_analysis_raw": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "XLA counts while bodies once; see hlo_analysis.py",
        },
        "collectives": {
            "per_kind_bytes": stats.per_kind_bytes,
            "per_kind_count": stats.per_kind_count,
            "total_bytes": stats.collective_bytes,
            "largest": stats.largest_collectives[:6],
        },
        "memory": mem,
        "roofline": terms,
        "model_flops": mf["model_flops"],
        "n_active_params": mf["n_active"],
        "useful_flops_fraction": useful,
        "static": plan.static,
    }


def cell_list(which: str):
    for arch in ARCHS:
        for shape in SHAPE_CELLS:
            if which == "all" or which == arch:
                yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default=OUT_DEFAULT)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence parallelism: shard the token dim over 'model'")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--summary", action="store_true")
    args = ap.parse_args()

    if args.summary:
        summarize(args.out)
        return

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        cells = list(cell_list("all"))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for multi_pod in meshes:
            mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
            tag = f"{arch}__{shape}__{mesh_name}"
            path = os.path.join(args.out, tag + ".json")
            rules = (
                ShardingRules(seq_axis="model") if args.seq_shard else None
            )
            try:
                rec = run_cell(
                    arch,
                    shape,
                    multi_pod=multi_pod,
                    rules=rules,
                    microbatches=args.microbatches,
                    save_hlo=args.save_hlo,
                )
            except Exception as e:  # noqa: BLE001
                rec = {
                    "arch": arch,
                    "shape": shape,
                    "mesh": mesh_name,
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                failures += 1
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (
                    f" dom={r['dominant']}"
                    f" frac={r['roofline_fraction']:.3f}"
                    f" mem={rec['memory']['peak_per_device_bytes']/2**30:.2f}GiB"
                    f" compile={rec['compile_s']:.0f}s"
                )
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


def summarize(out_dir: str):
    rows = []
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(out_dir, fn)) as f:
            rows.append(json.load(f))
    fmt = "{:<22s} {:<12s} {:<10s} {:<8s} {:>9s} {:>9s} {:>9s} {:<12s} {:>6s} {:>8s}"
    print(
        fmt.format(
            "arch", "shape", "mesh", "status",
            "t_comp", "t_mem", "t_coll", "dominant", "frac", "GiB/dev",
        )
    )
    for r in rows:
        if r["status"] != "ok":
            print(
                fmt.format(
                    r["arch"], r["shape"], r["mesh"], r["status"],
                    "-", "-", "-", r.get("reason", r.get("error", ""))[:12], "-", "-",
                )
            )
            continue
        t = r["roofline"]
        print(
            fmt.format(
                r["arch"], r["shape"], r["mesh"], r["status"],
                f"{t['t_compute_s']*1e3:.1f}ms",
                f"{t['t_memory_s']*1e3:.1f}ms",
                f"{t['t_collective_s']*1e3:.1f}ms",
                t["dominant"].replace("t_", "").replace("_s", ""),
                f"{t['roofline_fraction']:.2f}",
                f"{r['memory']['peak_per_device_bytes']/2**30:.2f}",
            )
        )


if __name__ == "__main__":
    main()
