"""Production meshes (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import, and everything else (tests, benches) must keep seeing 1 device.

Axis semantics:
  pod   — outer pure-DP axis; only the per-step gradient reduction
          crosses it (DCI traffic), never TP/EP collectives.
  data  — FSDP: batch + parameter/optimizer sharding (intra-pod ICI).
  model — TP/EP: heads, mlp, experts, vocab shards (intra-pod ICI).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(
    data: int | None = None, model: int = 1
) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_row_blocks_mesh(shards: int | None = None) -> jax.sharding.Mesh:
    """1-D mesh over the ``row_blocks`` axis — the sparse-stack shard
    axis (``repro.sparse.partition`` / ``repro.plan.ShardedStackPlan``).

    ``shards=None`` uses every visible device. On CPU hosts set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the
    first jax import to get N fake devices (the sharding tests and
    ``examples/serve_stream.py --shards N`` do exactly this).
    """
    n = len(jax.devices())
    shards = n if shards is None else shards
    return jax.make_mesh((shards,), ("row_blocks",))
