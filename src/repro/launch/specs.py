"""ShapeDtypeStruct stand-ins + step builders for every (arch × shape)
cell — the dry-run lowers these with no device allocation.

``input_specs`` mirrors what the data pipeline / serving frontend would
feed: int32 token ids for LM archs, precomputed bf16 patch/frame
embeddings for the VLM/audio stubs (their modality frontends are stubs
per the assignment), plus labels for train cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import SHAPE_CELLS, ShapeCell, get_config
from repro.configs.base import ModelConfig
from repro.distribution.sharding import (
    ShardingRules,
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
)
from repro.models.model import Model
from repro.serve.engine import make_serve_fns
from repro.train.optimizer import adamw, warmup_cosine
from repro.train.trainer import TrainState, make_train_step

Array = jax.Array
P = jax.sharding.PartitionSpec

# Microbatch counts for train cells (memory lever; global_batch=256).
# 8 is the divisibility ceiling: global_batch 256 / 8 micro = 32 = the
# multi-pod DP-shard count (pod×data); finer microbatching would leave
# per-micro batches unshardable and replicate activations.
DEFAULT_MICROBATCHES = 8
MICROBATCH_OVERRIDES: dict[str, int] = {}


def skip_reason(cfg: ModelConfig, cell: ShapeCell) -> str | None:
    """Assigned skip rules (recorded in the roofline table)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "pure full-attention arch: 500k decode has no sub-quadratic "
            "path (skip per assignment; see DESIGN.md)"
        )
    return None


def _token_spec(cfg: ModelConfig, batch: int, seq: int):
    if cfg.input_mode == "embeddings":
        return jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(arch: str, shape: str) -> dict[str, Any]:
    """The raw data-batch specs for one cell (train cells)."""
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    return {
        "inputs": _token_spec(cfg, cell.global_batch, cell.seq_len),
        "labels": jax.ShapeDtypeStruct(
            (cell.global_batch, cell.seq_len), jnp.int32
        ),
    }


@dataclasses.dataclass
class LoweringPlan:
    """Everything jit needs for one cell: fn, arg specs, shardings."""

    name: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    static: dict


def make_plan(
    arch: str,
    shape: str,
    mesh: jax.sharding.Mesh,
    *,
    rules: ShardingRules | None = None,
    microbatches: int | None = None,
) -> LoweringPlan:
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    reason = skip_reason(cfg, cell)
    if reason:
        raise ValueError(f"cell skipped: {reason}")
    rules = rules or ShardingRules()
    model = Model(cfg)

    if cell.kind == "train":
        mb = microbatches or MICROBATCH_OVERRIDES.get(arch, DEFAULT_MICROBATCHES)
        opt = adamw(
            warmup_cosine(3e-4, 2000, 100_000),
            state_dtype=jnp.bfloat16,  # sharded bf16 moments (DESIGN §5)
        )
        step_fn = make_train_step(model, opt, microbatches=mb)
        state_specs = jax.eval_shape(
            lambda: TrainState(
                (p := model.init(jax.random.key(0))), opt.init(p)
            )
        )
        batch_specs = {
            "inputs": _token_spec(cfg, cell.global_batch, cell.seq_len),
            "labels": jax.ShapeDtypeStruct(
                (cell.global_batch, cell.seq_len), jnp.int32
            ),
        }
        state_ps = param_pspecs(cfg, state_specs, mesh, rules)
        batch_ps = jax.tree.map(
            lambda _: batch_pspecs(mesh, rules)["inputs"], batch_specs
        )
        metrics_ps = {k: P() for k in ("ce", "moe_aux", "loss", "grad_norm")}
        return LoweringPlan(
            name=f"{arch}/{shape}",
            fn=step_fn,
            args=(state_specs, batch_specs),
            in_shardings=(state_ps, batch_ps),
            out_shardings=(state_ps, metrics_ps),
            donate_argnums=(0,),
            static={"microbatches": mb, "kind": "train"},
        )

    # serving cells
    prefill_fn, decode_fn = make_serve_fns(model)
    params_specs = jax.eval_shape(model.init, jax.random.key(0))
    params_ps = param_pspecs(cfg, params_specs, mesh, rules)
    cache_specs = jax.eval_shape(
        lambda: model.init_cache(cell.global_batch, cell.seq_len)
    )
    cache_ps = cache_pspecs(cfg, cache_specs, mesh, rules)
    dp = tuple(a for a in rules.batch_axes if a in mesh.shape)
    vocab_ax = (
        rules.tp_axis
        if rules.shard_vocab
        and rules.tp_axis in mesh.shape
        and cfg.vocab_size % mesh.shape[rules.tp_axis] == 0
        else None
    )
    bsz = cell.global_batch
    dp_ok = dp if bsz % max(
        1, _prod(mesh.shape[a] for a in dp)
    ) == 0 else ()

    if cell.kind == "prefill":
        tok_specs = _token_spec(cfg, bsz, cell.seq_len)
        logits_ps = P(dp_ok, None, vocab_ax)
        return LoweringPlan(
            name=f"{arch}/{shape}",
            fn=prefill_fn,
            args=(params_specs, tok_specs, cache_specs),
            in_shardings=(params_ps, P(dp_ok, None), cache_ps),
            out_shardings=(logits_ps, cache_ps),
            donate_argnums=(2,),
            static={"kind": "prefill"},
        )

    # decode: one new token against a full cache
    if cfg.input_mode == "embeddings":
        tok_specs = jax.ShapeDtypeStruct(
            (bsz, 1, cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )
        tok_ps = P(dp_ok, None, None)
    else:
        tok_specs = jax.ShapeDtypeStruct((bsz,), jnp.int32)
        tok_ps = P(dp_ok)
    pos_specs = jax.ShapeDtypeStruct((), jnp.int32)
    logits_ps = P(dp_ok, vocab_ax)
    return LoweringPlan(
        name=f"{arch}/{shape}",
        fn=decode_fn,
        args=(params_specs, tok_specs, cache_specs, pos_specs),
        in_shardings=(params_ps, tok_ps, cache_ps, P()),
        out_shardings=(logits_ps, cache_ps),
        donate_argnums=(2,),
        static={"kind": "decode"},
    )


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


def model_flops(arch: str, shape: str) -> dict[str, float]:
    """MODEL_FLOPS per §Roofline: 6·N·D train, 2·N·D forward-only, with
    N = active non-embedding params and D = tokens processed."""
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    model = Model(cfg)
    n_active = model.active_param_count()
    # exclude embedding + lm head from N (standard 6ND accounting)
    embed = cfg.vocab_size * cfg.d_model
    lm = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    if cfg.input_mode == "embeddings":
        embed = 0
    n = max(n_active - embed - lm, 0)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return {"model_flops": 6.0 * n * tokens, "n_active": float(n)}
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return {"model_flops": 2.0 * n * tokens, "n_active": float(n)}
    tokens = cell.global_batch  # one token per sequence
    return {"model_flops": 2.0 * n * tokens, "n_active": float(n)}
