"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Drives the batched engine (prefill + decode loop with sampling) over a
local mesh. The decode step compiled here is the same function the
dry-run lowers for the ``decode_32k`` / ``long_500k`` cells.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import Model
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale_down:
        cfg = cfg.scaled_down(max_seq_len=args.cache_len)
    model = Model(cfg)
    params = model.init(jax.random.key(args.seed))
    n_params = sum(l.size for l in jax.tree.leaves(params))
    print(f"[serve] arch={cfg.name} params={n_params/1e6:.1f}M")

    engine = Engine(
        model,
        params,
        batch_size=args.batch,
        cache_len=args.cache_len,
        temperature=args.temperature,
        seed=args.seed,
    )
    prompts = jax.random.randint(
        jax.random.key(args.seed + 1),
        (args.batch, args.prompt_len),
        0,
        cfg.vocab_size,
    ).astype(jnp.int32)

    t0 = time.monotonic()
    tokens, stats = engine.generate(prompts, args.max_new_tokens)
    dt = time.monotonic() - t0
    print(
        f"[serve] generated {stats['generated_tokens']} tokens in {dt:.2f}s"
        f" ({stats['generated_tokens']/dt:,.1f} tok/s)"
        f" cache={stats['cache_bytes']/2**20:.1f}MiB"
    )
    print("[serve] sample output ids:", tokens[0, :16].tolist())


if __name__ == "__main__":
    main()
