"""Loop-aware roofline accounting from post-SPMD optimized HLO (§Roofline).

Why hand-rolled: ``compiled.cost_analysis()`` counts every while-loop body
ONCE (verified experimentally — a 10-trip scanned matmul reports the same
FLOPs as a single matmul), and it has no collective-bytes entry at all.
Training steps are nested scans (microbatches × layer periods), so naive
cost analysis under-counts by orders of magnitude. This module parses the
optimized module text:

  1. split into computations; build a per-computation symbol table
     (%name → shape) so operand byte sizes resolve;
  2. build the call graph — while(body=…, condition=…) edges carry the
     loop's ``known_trip_count`` from backend_config, conditional branches
     carry 1 — and propagate an execution multiplier from ENTRY. Fusion /
     reduce sub-computations are *excluded* (their internals don't touch
     HBM; the fusion instruction itself is counted where it appears);
  3. per executed instruction, accumulate
       FLOPs:  dot = 2 · prod(result dims) · prod(lhs contracting dims)
               (+ convolution analog; elementwise flops are ignored —
               documented, matmul-dominated workloads)
       bytes:  result + Σ operands (XLA's own bytes-accessed model),
               skipping no-traffic opcodes (tuple/gte/bitcast/parameter)
       collectives: per-device wire bytes via ring formulas
                    all-reduce 2(g−1)/g·b, all-gather (g−1)/g·b,
                    reduce-scatter (g−1)·b(result), all-to-all (g−1)/g·b,
                    collective-permute b.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # control flow: the bodies' interior ops are counted (with loop
    # multipliers); the op itself only shuffles aliased buffers
    "while", "conditional", "call",
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([0-9,]*)\]")
# result is either a tuple "(shape, shape, ...)" (may contain /*index=N*/
# comments) or a single shape token; opcode follows, then "(" opens operands
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\("
)
_INSTR_START = re.compile(r"^\s+(?:ROOT\s+)?%[\w\.\-]+\s*=\s")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_TOKEN.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_TOKEN.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str  # result shape string (may be a tuple)
    opcode: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: list[_Instr]
    symbols: dict[str, str]  # %name -> shape string


def _logical_lines(hlo: str):
    """Join wrapped instruction lines (long tuple types spill across
    physical lines in XLA dumps) into one logical line each."""
    pending: str | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        starts_instr = bool(_INSTR_START.match(line))
        is_boundary = (
            starts_instr
            or stripped == "}"
            or stripped.endswith("{")
            or stripped.startswith("ENTRY")
            or not stripped
        )
        if is_boundary:
            if pending is not None:
                yield pending
            pending = line if starts_instr else None
            if not starts_instr:
                yield line
        elif pending is not None:
            pending += " " + stripped
        else:
            yield line
    if pending is not None:
        yield pending


def _parse_computations(hlo: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in _logical_lines(hlo):
        stripped = line.strip()
        if cur is None:
            # computation header: "%name (args) -> type {" or "ENTRY %name ..."
            if stripped.endswith("{") and (
                "->" in stripped or stripped.startswith("ENTRY")
            ):
                m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
                if m:
                    cur = _Computation(m.group(1), [], {})
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR.match(line)
        if im:
            name, shape, opcode = im.group(1), im.group(2), im.group(3)
            cur.symbols[name] = shape
            cur.instrs.append(_Instr(name, shape, opcode, stripped))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _call_edges(comp: _Computation) -> list[tuple[str, int, str]]:
    """(callee, multiplier, via) edges that represent *executed* control
    flow (while bodies/conditions, conditional branches, calls)."""
    edges = []
    for ins in comp.instrs:
        if ins.opcode == "while":
            trip = 1
            tm = _TRIP.search(ins.line)
            if tm:
                trip = int(tm.group(1))
            for role in ("body", "condition"):
                m = re.search(role + r"=%?([\w\.\-]+)", ins.line)
                if m:
                    edges.append((m.group(1), trip if role == "body" else trip + 1, role))
        elif ins.opcode == "conditional":
            for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)([^,}]+)", ins.line):
                for name in re.findall(r"%?([\w\.\-]+)", m.group(1)):
                    edges.append((name, 1, "branch"))
        elif ins.opcode == "call":
            m = re.search(r"to_apply=%?([\w\.\-]+)", ins.line)
            if m:
                edges.append((m.group(1), 1, "call"))
        elif ins.opcode.startswith("async"):
            m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
            if m:
                edges.append((m.group(1), 1, "async"))
    return edges


def _multipliers(
    comps: dict[str, _Computation], entry: str, default_trip: int
) -> dict[str, float]:
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # propagate through the (acyclic) call graph
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        if c not in comps:
            continue
        for callee, k, via in _call_edges(comps[c]):
            k_eff = k if k > 0 else (default_trip if via == "body" else default_trip + 1)
            mult[callee] += mult[c] * k_eff
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
    return dict(mult)


def _find_entry(hlo: str, comps: dict[str, _Computation]) -> str:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: a computation never called by others
    called = set()
    for c in comps.values():
        for callee, _, _ in _call_edges(c):
            called.add(callee)
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def _dot_flops(ins: _Instr, symbols: dict[str, str]) -> float:
    result = _shape_dims(ins.shape)
    n_out = 1
    for d in result:
        n_out *= d
    # contraction size from lhs operand shape + lhs_contracting_dims
    cm = _CONTRACT.search(ins.line)
    ops = _OPERAND.findall(ins.line.split("(", 1)[1])
    k = 1
    if cm and ops:
        lhs_shape = symbols.get(ops[0], "")
        dims = _shape_dims(lhs_shape)
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(dims):
                k *= dims[int(idx)]
    return 2.0 * n_out * k


def _conv_flops(ins: _Instr, symbols: dict[str, str]) -> float:
    # flops ≈ 2 · prod(result) · prod(kernel spatial dims) · in_channels/feature_group
    result = _shape_dims(ins.shape)
    n_out = 1
    for d in result:
        n_out *= d
    ops = _OPERAND.findall(ins.line.split("(", 1)[1])
    if len(ops) < 2:
        return 0.0
    kshape = _shape_dims(symbols.get(ops[1], ""))
    k = 1
    for d in kshape[:-1]:  # all but output-feature dim (approximation)
        k *= d
    return 2.0 * n_out * k


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * result_bytes
    if kind == "all-gather":
        return (g - 1) / g * result_bytes
    if kind == "reduce-scatter":
        return float((g - 1) * result_bytes)
    if kind == "all-to-all":
        return (g - 1) / g * result_bytes
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


def _operand_names(ins: _Instr) -> list[str]:
    # operands are in the paren group right after the opcode
    idx = ins.line.find(ins.opcode + "(")
    if idx < 0:
        return []
    args = ins.line[idx + len(ins.opcode) + 1 :].split(")", 1)[0]
    return _OPERAND.findall(args)


def _operand_bytes(ins: _Instr, symbols: dict[str, str]) -> int:
    return sum(_shape_bytes(symbols.get(n, "")) for n in _operand_names(ins))


def _instr_bytes(
    ins: _Instr,
    symbols: dict[str, str],
    comps: "dict[str, _Computation] | None" = None,
) -> float:
    """HBM bytes touched by one instruction. Slicing ops move only the
    slice, not the buffer they index into (XLA's model; counting the full
    operand would inflate scanned stacks by the stack length)."""
    op = ins.opcode
    rb = _shape_bytes(ins.shape)
    if op in ("dynamic-slice", "gather"):
        return 2.0 * rb  # read slice + write result
    if op in ("dynamic-update-slice", "scatter"):
        ops = _operand_names(ins)
        upd = _shape_bytes(symbols.get(ops[1], "")) if len(ops) > 1 else 0
        return 2.0 * upd  # read update + write region (buffer aliased)
    if op == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        callee = comps.get(m.group(1)) if m else None
        if callee is not None and callee.instrs:
            return _fusion_bytes(ins, symbols, callee)
    return float(rb + _operand_bytes(ins, symbols))


_TRIVIAL_UNARY = ("convert", "bitcast", "copy", "transpose", "reshape")


def _fusion_bytes(ins: _Instr, symbols: dict[str, str], callee: _Computation) -> float:
    """HBM traffic of one fusion call, looking inside the fused body:

    * operands that the body only ever dynamic-slices/gathers (possibly
      through convert/bitcast chains) contribute slice-sized reads —
      a scanned stack is NOT re-read whole on every loop iteration;
    * a dynamic-update-slice root (again allowing a trivial unary wrapper)
      writes only the updated region — the rest of the buffer is aliased.
    """
    rb = _shape_bytes(ins.shape)
    by_name = {ci.name: ci for ci in callee.instrs}

    # alias propagation: param → trivial-unary chains
    param_of: dict[str, int] = {}
    for ci in callee.instrs:
        pm = re.search(r"parameter\((\d+)\)", ci.line)
        if ci.opcode == "parameter" and pm:
            param_of[ci.name] = int(pm.group(1))
    alias: dict[str, int] = dict(param_of)
    changed = True
    while changed:
        changed = False
        for ci in callee.instrs:
            if ci.name in alias or ci.opcode not in _TRIVIAL_UNARY:
                continue
            ops = _operand_names(ci)
            if len(ops) == 1 and ops[0] in alias:
                alias[ci.name] = alias[ops[0]]
                changed = True

    # classify consumption of each param (via aliases)
    slice_bytes: dict[int, int] = {}
    dense_params: set[int] = set()
    for ci in callee.instrs:
        if ci.opcode in ("parameter",) or ci.opcode in _TRIVIAL_UNARY:
            continue
        names = _operand_names(ci)
        for pos, on in enumerate(names):
            if on not in alias:
                continue
            pid = alias[on]
            if ci.opcode in ("dynamic-slice", "gather") and pos == 0:
                slice_bytes[pid] = slice_bytes.get(pid, 0) + _shape_bytes(ci.shape)
            elif ci.opcode == "dynamic-update-slice" and pos == 0:
                pass  # aliased in-place destination
            else:
                dense_params.add(pid)
    for pid in dense_params:
        slice_bytes.pop(pid, None)

    # root: see through trivial unaries to detect in-place update writes
    root = next(
        (i for i in callee.instrs if "ROOT" in i.line),
        callee.instrs[-1],
    )
    seen = set()
    while root.opcode in _TRIVIAL_UNARY and root.name not in seen:
        seen.add(root.name)
        ops = _operand_names(root)
        if len(ops) == 1 and ops[0] in by_name:
            root = by_name[ops[0]]
        else:
            break
    write_bytes = float(rb)
    if root.opcode in ("dynamic-update-slice", "scatter"):
        ops = _operand_names(root)
        upd = _shape_bytes(callee.symbols.get(ops[1], "")) if len(ops) > 1 else 0
        write_bytes = 2.0 * upd  # read update + write region; buffer aliased
        dense_params.discard(alias.get(ops[0], -1))
        # the destination buffer param reads nothing extra
        dest_pid = alias.get(ops[0])
    else:
        dest_pid = None

    read_bytes = 0.0
    for pos, on in enumerate(_operand_names(ins)):
        pid = pos
        if pid == dest_pid:
            continue
        if pid in slice_bytes:
            read_bytes += slice_bytes[pid]
        else:
            read_bytes += _shape_bytes(symbols.get(on, ""))
    return write_bytes + read_bytes


@dataclasses.dataclass
class HLOStats:
    flops: float  # per-device, loop-scaled
    bytes_accessed: float  # per-device, loop-scaled
    collective_bytes: float  # per-device wire bytes, loop-scaled
    per_kind_bytes: dict[str, float]
    per_kind_count: dict[str, float]
    largest_collectives: list[dict]

    def to_json(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "per_kind_bytes": dict(self.per_kind_bytes),
            "per_kind_count": dict(self.per_kind_count),
            "largest_collectives": self.largest_collectives,
        }


def analyze(hlo: str, *, default_trip_count: int = 1) -> HLOStats:
    comps = _parse_computations(hlo)
    entry = _find_entry(hlo, comps)
    mult = _multipliers(comps, entry, default_trip_count)

    flops = 0.0
    bytes_accessed = 0.0
    per_kind_bytes: dict[str, float] = defaultdict(float)
    per_kind_count: dict[str, float] = defaultdict(float)
    coll_detail: list[dict] = []

    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None or m <= 0:
            continue
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, comp.symbols)
            elif ins.opcode == "convolution":
                flops += m * _conv_flops(ins, comp.symbols)
            if ins.opcode not in _NO_TRAFFIC:
                bytes_accessed += m * _instr_bytes(ins, comp.symbols, comps)
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                rb = _shape_bytes(ins.shape)
                if ins.opcode.endswith("-start"):
                    rb //= 2  # start ops carry (operand, result) tuples
                g = _group_size(ins.line)
                wb = _wire_bytes(base, rb, g) * m
                per_kind_bytes[base] += wb
                per_kind_count[base] += m
                coll_detail.append(
                    {
                        "kind": base,
                        "result_bytes": rb,
                        "group": g,
                        "mult": m,
                        "wire_bytes": wb,
                        "comp": cname,
                    }
                )

    coll_detail.sort(key=lambda d: -d["wire_bytes"])
    return HLOStats(
        flops=flops,
        bytes_accessed=bytes_accessed,
        collective_bytes=float(sum(per_kind_bytes.values())),
        per_kind_bytes=dict(per_kind_bytes),
        per_kind_count=dict(per_kind_count),
        largest_collectives=coll_detail[:12],
    )


# --------------------------- roofline terms ----------------------------------

# TPU v5e hardware constants (per chip), per the assignment.
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link


def roofline_terms(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
) -> dict[str, float]:
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = bytes_per_device / HBM_BW
    t_collective = collective_bytes_per_device / ICI_BW
    terms = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dominant
    terms["step_time_lower_bound_s"] = bound
    terms["roofline_fraction"] = t_compute / bound if bound > 0 else 0.0
    return terms
