"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs a real (CPU-scale) training loop with the full production stack:
sharded state over a local mesh, microbatched train step, deterministic
data pipeline with prefetch, atomic checkpointing, and the fault-
tolerance supervisor. On real hardware the same driver runs per-host with
``jax.distributed.initialize()`` and the production mesh; the scale knobs
(--scale-down) exist so the driver is runnable in this CPU container.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import Prefetcher, SyntheticLM
from repro.distribution.sharding import (
    ShardingRules,
    batch_pspecs,
    param_pspecs,
    shardings_for,
)
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.model import Model
from repro.train import adamw, checkpoint, make_train_step
from repro.train.fault_tolerance import StragglerPolicy, Supervisor
from repro.train.optimizer import warmup_cosine
from repro.train.trainer import TrainState, init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--scale-down", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale_down:
        cfg = cfg.scaled_down(max_seq_len=args.seq_len)
    model = Model(cfg)
    opt = adamw(warmup_cosine(args.lr, 10, args.steps), state_dtype=jnp.float32)
    mesh = (
        make_production_mesh()
        if args.production_mesh
        else make_local_mesh(model=1)
    )
    rules = ShardingRules()
    print(f"[train] arch={cfg.name} mesh={dict(mesh.shape)} params=", end="")

    with mesh:
        state = init_train_state(model, opt, jax.random.key(args.seed))
        n_params = sum(l.size for l in jax.tree.leaves(state.params))
        print(f"{n_params/1e6:.1f}M")
        state_ps = param_pspecs(cfg, state, mesh, rules)
        state = jax.device_put(state, shardings_for(None, mesh, state_ps))
        _batch_ps = batch_pspecs(mesh, rules)["inputs"]
        step_fn = jax.jit(
            make_train_step(model, opt, microbatches=args.microbatches),
            in_shardings=(
                shardings_for(None, mesh, state_ps),
                None,
            ),
            donate_argnums=(0,),
        )

        start_step = 0
        if args.resume and checkpoint.latest_step(args.ckpt_dir) is not None:
            like = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state
            )
            restored, manifest = checkpoint.restore(
                args.ckpt_dir,
                like,
                shardings=shardings_for(None, mesh, state_ps),
            )
            state = TrainState(*restored)
            start_step = manifest["step"]
            print(f"[train] resumed from step {start_step}")

        data = SyntheticLM(
            cfg.vocab_size,
            args.seq_len,
            args.global_batch,
            seed=args.seed,
            input_mode=cfg.input_mode,
            d_model=cfg.d_model,
        )
        prefetch = Prefetcher(data, start_step=start_step)
        metrics_box = {}

        def step(state, i):
            _, host_batch = prefetch.next()
            batch = jax.tree.map(jnp.asarray, host_batch)
            state, metrics = step_fn(state, batch)
            metrics_box.update(jax.tree.map(float, metrics))
            return state

        sup = Supervisor(
            step_fn=step,
            save_state=lambda s: s,
            load_state=lambda t: TrainState(*t),
            ckpt_dir=args.ckpt_dir,
            ckpt_interval=args.ckpt_interval,
            straggler=StragglerPolicy(),
            metadata={"arch": cfg.name},
        )
        t0 = time.monotonic()
        last_log = start_step
        # run in chunks so we can log without complicating the supervisor
        s = start_step
        while s < args.steps:
            chunk_end = min(s + 10, args.steps)
            state = sup.run(state, chunk_end, start_step=s)
            dt = time.monotonic() - t0
            tok_s = (chunk_end - last_log) * args.global_batch * args.seq_len / dt
            print(
                f"[train] step {chunk_end:5d} loss={metrics_box.get('loss', 0):.4f}"
                f" grad_norm={metrics_box.get('grad_norm', 0):.3f}"
                f" tok/s={tok_s:,.0f}"
            )
            t0, last_log = time.monotonic(), chunk_end
            s = chunk_end
        prefetch.close()
    print("[train] done")


if __name__ == "__main__":
    main()
