"""Deterministic kernel autotuner + cached tuning table (`docs/tuning.md`).

Per ``(topology fingerprint, backend, dtype)`` this package sweeps the
kernel knobs the plan layer exposes — column-tile width ``block_n``,
weight block size, forced layout (ELL vs block-CSR), bf16 activation
panels, and the resident↔tiled VMEM budget — scores candidates with the
exact grid-step cost model (``repro.plan.cost``), and persists the
winner in a versioned on-disk :class:`TuningTable` that
``repro.plan.PlanCache`` / ``build_plan`` consult before falling back
to defaults. Selection is cost-model-deterministic; wall-clock is
recorded as evidence, never used to pick (CI machines jitter, cost
models do not).
"""

from repro.tune.sweep import (  # noqa: F401
    default_candidates,
    sweep_stack,
    tune_stack,
)
from repro.tune.table import (  # noqa: F401
    SCHEMA_VERSION,
    TunedConfig,
    TuningTable,
    TuningTableError,
    entry_key,
)

__all__ = [
    "SCHEMA_VERSION",
    "TunedConfig",
    "TuningTable",
    "TuningTableError",
    "default_candidates",
    "entry_key",
    "sweep_stack",
    "tune_stack",
]
