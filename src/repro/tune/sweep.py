"""The deterministic kernel-config sweep behind the tuning table.

``sweep_stack`` builds one :class:`~repro.plan.StackPlan` per candidate
:class:`~repro.tune.table.TunedConfig`, scores each with the exact cost
model of ``repro.plan.cost``, gates numerics against the default plan's
output, and picks a winner **deterministically**:

1. ``stack_block_work`` — grid steps × stored-block area, summed over
   the plan's executed weights. Block-size-invariant (a re-blocked
   candidate cannot win by coarsening the grid) and layout-sensitive
   (forcing block-CSR on a skewed stack genuinely drops the bill).
2. route rank — ``fused`` < ``fused-tiled`` < ``layered`` < ``xla``:
   at equal ⊗-work, fewer pallas_calls and less HBM panel traffic win.
   This is where bf16 panels earn their keep: halving the panel bill
   moves a stack across the resident boundary without touching work.
3. fused-panel VMEM bytes — at equal work and route, the smaller
   resident footprint wins (bf16 beats f32 for resident stacks).
4. enumeration order — the default config is enumerated first, so a
   candidate must *strictly* improve something to displace it.

Wall-clock is measured (min over ``reps`` timed forwards, recorded in
the sweep evidence and the table entry) but **never used for
selection** — CI machines jitter, cost models do not, and a tuning
table that flips winners run-to-run is worse than no table.

Accuracy is a hard gate, not a score: every candidate's probe output
must stay within ``accuracy_rtol × max|default output|`` of the default
plan's output, so a bf16 (or re-blocked) config can only be selected if
its numerics hold on this topology.
"""

from __future__ import annotations

import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import DEFAULT_BLOCK_N
from repro.tune.table import TunedConfig, TuningTable

_ROUTE_RANK = {"fused": 0, "fused-tiled": 1, "layered": 2, "xla": 3}


def default_candidates(
    *,
    layouts: Sequence[str | None] = (None, "ell", "bcsr"),
    panel_dtypes: Sequence[str | None] = (None, "bfloat16"),
    block_ns: Sequence[int | None] = (None,),
    block_sizes: Sequence[int | None] = (None,),
    vmem_limits: Sequence[int | None] = (None,),
) -> list[TunedConfig]:
    """The sweep's candidate grid — the all-``None`` default config is
    always enumerated first (ties go to it)."""
    out: list[TunedConfig] = []
    seen: set[str] = set()
    for bn in block_ns:
        for pdt in panel_dtypes:
            for lay in layouts:
                for bs in block_sizes:
                    for vl in vmem_limits:
                        cfg = TunedConfig(
                            block_size=bs,
                            block_n=bn,
                            layout=lay,
                            panel_dtype=pdt,
                            vmem_limit_bytes=vl,
                        )
                        if cfg.token() in seen:
                            continue
                        seen.add(cfg.token())
                        out.append(cfg)
    out.sort(key=lambda c: not c.is_default)  # stable: default first
    return out


def _probe_panel(weights, width: int) -> jax.Array:
    k = weights[0].shape[1]
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.standard_normal((k, width)), jnp.float32)


def _timed_forward(plan, probe, reps: int) -> float:
    jax.block_until_ready(plan.forward(probe))  # compile outside the clock
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.forward(probe))
        best = min(best, time.perf_counter() - t0)
    return best


def sweep_stack(
    weights,
    biases,
    width: int,
    *,
    candidates: Sequence[TunedConfig] | None = None,
    reps: int = 2,
    accuracy_rtol: float = 0.02,
    time_forwards: bool = True,
    probe=None,
) -> tuple[TunedConfig, list[dict]]:
    """Sweep candidate configs over one stack; return (winner, records).

    One record per candidate: ``token``, ``config``, ``route``,
    ``grid_steps``, ``block_work``, ``vmem_bytes``, ``wall_s``,
    ``max_abs_err``, ``ok`` (accuracy gate), ``selected``. Candidates
    whose plan fails to build are recorded with ``error`` and skipped.
    ``time_forwards=False`` skips the timed reps (pure cost-model sweep
    — what the plan-layer tests use to stay fast). ``probe`` overrides
    the default seeded random-normal probe panel — pass workload-shaped
    inputs (e.g. the GraphChallenge {0,1} panels) so the accuracy gate
    judges the numerics that will actually be served.
    """
    from repro import plan as _plan
    from repro.kernels import fused_mlp as _fmlp

    if candidates is None:
        candidates = default_candidates()
    candidates = list(candidates)
    if not any(c.is_default for c in candidates):
        # The default config is the accuracy reference and the evidence
        # baseline — a custom candidate list always competes against it.
        candidates.insert(0, TunedConfig())
    weights = tuple(weights)
    biases = tuple(biases)
    if probe is None:
        probe = _probe_panel(weights, width)

    default_plan = _plan.build_plan(weights, biases, width)
    ref = np.asarray(default_plan.forward(probe), np.float32)
    err_bound = accuracy_rtol * max(float(np.max(np.abs(ref))), 1e-6)

    records: list[dict] = []
    best_idx: int | None = None
    best_score: tuple | None = None
    for idx, cand in enumerate(candidates):
        rec: dict = {"token": cand.token(), "config": cand.to_dict()}
        try:
            plan = (
                default_plan
                if cand.is_default
                else _plan.build_plan(weights, biases, width, tuned=cand)
            )
        except Exception as e:  # noqa: BLE001 — a bad knob combo skips
            rec.update(error=f"{type(e).__name__}: {e}", ok=False)
            records.append(rec)
            continue
        bn = cand.block_n or DEFAULT_BLOCK_N
        block_work = _plan.stack_block_work(plan.weights, width, block_n=bn)
        route_rank = _ROUTE_RANK.get(plan.route, len(_ROUTE_RANK))
        if plan.route in ("fused", "fused-tiled"):
            vmem = _fmlp.fused_mlp_vmem_bytes(
                plan.weights[0].shape[0], bn, cand.panel_dtype
            )
        else:
            vmem = 0
        out = np.asarray(plan.forward(probe), np.float32)
        err = float(np.max(np.abs(out - ref)))
        ok = err <= err_bound
        rec.update(
            route=plan.route,
            grid_steps=int(plan.grid_steps),
            block_work=int(block_work),
            vmem_bytes=int(vmem),
            max_abs_err=err,
            ok=ok,
        )
        if time_forwards:
            rec["wall_s"] = _timed_forward(plan, probe, reps)
        records.append(rec)
        if not ok:
            continue
        score = (block_work, route_rank, vmem, idx)
        if best_score is None or score < best_score:
            best_score = score
            best_idx = idx
    if best_idx is None:
        raise RuntimeError(
            "tuning sweep found no candidate passing the accuracy gate "
            "(the default config should always pass — bad probe?)"
        )
    for i, rec in enumerate(records):
        rec["selected"] = i == best_idx
    return candidates[best_idx], records


def tune_stack(
    weights,
    biases,
    width: int,
    *,
    table: TuningTable | None = None,
    backend: str | None = None,
    dtype: str | None = None,
    fingerprint: str | None = None,
    sweep: tuple[TunedConfig, list] | None = None,
    **sweep_kw,
) -> tuple[TunedConfig, TuningTable]:
    """Sweep one stack and record the winner in a tuning table.

    Returns ``(winner, table)``. The entry's evidence carries the tuned
    and default bills side by side so a committed table is auditable:
    the bench gate re-checks ``grid_steps <= default_grid_steps`` from
    the file alone. ``sweep`` reuses a prior :func:`sweep_stack` result
    (the bench sweeps once and both reports and records it).
    """
    from repro import plan as _plan

    if table is None:
        table = TuningTable()
    if backend is None:
        backend = jax.default_backend()
    if dtype is None:
        dtype = str(np.dtype(weights[0].dtype))
    if fingerprint is None:
        fingerprint = _plan.topology_fingerprint(weights)
    if sweep is None:
        winner, records = sweep_stack(weights, biases, width, **sweep_kw)
    else:
        winner, records = sweep
    default_rec = next(r for r in records if r["token"] == "default")
    winner_rec = next(r for r in records if r.get("selected"))
    evidence = {
        "width": width,
        "route": winner_rec["route"],
        "default_route": default_rec["route"],
        "grid_steps": winner_rec["grid_steps"],
        "default_grid_steps": default_rec["grid_steps"],
        "block_work": winner_rec["block_work"],
        "default_block_work": default_rec["block_work"],
        "max_abs_err": winner_rec["max_abs_err"],
        "candidates": len(records),
    }
    if "wall_s" in winner_rec:
        evidence["wall_s"] = winner_rec["wall_s"]
        evidence["default_wall_s"] = default_rec["wall_s"]
    table.put(fingerprint, backend, dtype, winner, evidence)
    return winner, table
