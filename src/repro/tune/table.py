"""The on-disk tuning table — versioned, fingerprint-keyed, schema-checked.

A :class:`TuningTable` maps ``(topology fingerprint, backend, dtype)``
to the :class:`TunedConfig` the sweep (``repro.tune.sweep``) selected
for that stack, so tuning happens once per topology — offline or in a
warmup pass — and every later plan build is a dictionary lookup.

Deliberately a leaf module: it imports nothing above ``repro.sparse`` /
``repro.kernels``, so ``repro.plan`` and ``repro.serve`` can consume
:class:`TunedConfig` objects without an import cycle. The plan layer
duck-types the config (it only reads the knob attributes and
``token()``), which keeps ``repro.plan`` free of any ``repro.tune``
import.

File format (JSON, human-diffable, committed next to benchmarks)::

    {
      "schema_version": 1,
      "entries": {
        "<fingerprint>:<backend>:<dtype>": {
          "config": {"block_n": 128, "panel_dtype": "bfloat16", ...},
          "grid_steps": 1234, "block_work": 315904, ...
        }
      }
    }

``load`` refuses anything it cannot trust: wrong/missing
``schema_version``, non-object entries, unknown config knobs — all
raise :class:`TuningTableError` rather than silently steering kernels
with garbage.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Mapping

import numpy as np

SCHEMA_VERSION = 1

# The only knobs a table entry may carry — anything else in a loaded
# config dict is a schema violation, not a forward-compat freebie.
_KNOBS = ("block_size", "block_n", "layout", "panel_dtype", "vmem_limit_bytes")
_LAYOUTS = ("ell", "bcsr")


class TuningTableError(ValueError):
    """A tuning-table file failed schema validation on load."""


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One tuned kernel configuration — every knob optional.

    ``None`` means "keep the default": a config of all-``None`` is
    byte-for-byte the untuned plan. The plan builder
    (``repro.plan.stack_plan.build_plan``) reads these attributes
    directly; ``token()`` is the stable string that lands in the
    :class:`~repro.plan.PlanKey` so tuned and untuned plans never share
    a cache slot.
    """

    block_size: int | None = None  # re-block sparse weights to (b, b)
    block_n: int | None = None  # column-tile width of the kernel grids
    layout: str | None = None  # force "ell" or "bcsr" (layered route)
    panel_dtype: str | None = None  # e.g. "bfloat16" activation panels
    vmem_limit_bytes: int | None = None  # resident↔tiled boundary budget

    def __post_init__(self):
        if self.layout is not None and self.layout not in _LAYOUTS:
            raise ValueError(
                f"layout must be one of {_LAYOUTS}, got {self.layout!r}"
            )
        if self.panel_dtype is not None:
            # Normalize eagerly so token() is canonical ("bfloat16", not
            # a dtype object repr) and bad names fail at build time.
            object.__setattr__(
                self, "panel_dtype", str(np.dtype(self.panel_dtype))
            )

    @property
    def is_default(self) -> bool:
        return all(getattr(self, k) is None for k in _KNOBS)

    def token(self) -> str:
        """Deterministic cache-key fragment for this config."""
        parts = [
            f"{k}={getattr(self, k)}"
            for k in _KNOBS
            if getattr(self, k) is not None
        ]
        return ",".join(parts) if parts else "default"

    def to_dict(self) -> dict:
        return {
            k: getattr(self, k)
            for k in _KNOBS
            if getattr(self, k) is not None
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TunedConfig":
        unknown = set(d) - set(_KNOBS)
        if unknown:
            raise TuningTableError(
                f"unknown tuning knobs {sorted(unknown)}; "
                f"known: {list(_KNOBS)}"
            )
        return cls(**dict(d))


def entry_key(fingerprint: str, backend: str, dtype: str) -> str:
    return f"{fingerprint}:{backend}:{dtype}"


class TuningTable:
    """In-memory view of one tuning-table file.

    ``entries`` maps :func:`entry_key` strings to records: each record
    holds the selected ``config`` plus the sweep's evidence (grid-step /
    block-work bills for tuned and default, measured wall-clock, probe
    width, route, bf16 max-abs error). The evidence rides along so a
    committed table is auditable — the bench gates re-derive the
    grid-step claims from it.
    """

    def __init__(self, entries: dict[str, dict] | None = None):
        self.entries: dict[str, dict] = dict(entries or {})

    def __len__(self) -> int:
        return len(self.entries)

    def put(
        self,
        fingerprint: str,
        backend: str,
        dtype: str,
        config: TunedConfig,
        evidence: Mapping[str, Any] | None = None,
    ) -> None:
        record = {"config": config.to_dict()}
        if evidence:
            record.update(evidence)
        self.entries[entry_key(fingerprint, backend, dtype)] = record

    def lookup(
        self,
        fingerprint: str,
        *,
        backend: str | None = None,
        dtype: str = "float32",
    ) -> TunedConfig | None:
        """The tuned config for this stack, or ``None`` on a miss.

        ``backend=None`` resolves to the running JAX backend, so a table
        tuned on one backend never silently steers another.
        """
        if backend is None:
            import jax

            backend = jax.default_backend()
        record = self.entries.get(entry_key(fingerprint, backend, dtype))
        if record is None:
            return None
        return TunedConfig.from_dict(record["config"])

    def record(
        self,
        fingerprint: str,
        *,
        backend: str | None = None,
        dtype: str = "float32",
    ) -> dict | None:
        """The full evidence record for this stack, or ``None``."""
        if backend is None:
            import jax

            backend = jax.default_backend()
        return self.entries.get(entry_key(fingerprint, backend, dtype))

    def to_json(self) -> dict:
        return {"schema_version": SCHEMA_VERSION, "entries": self.entries}

    def save(self, path: str | os.PathLike) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str | os.PathLike) -> "TuningTable":
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise TuningTableError(f"cannot read tuning table {path}: {e}")
        if not isinstance(raw, dict):
            raise TuningTableError("tuning table root must be an object")
        version = raw.get("schema_version")
        if version != SCHEMA_VERSION:
            raise TuningTableError(
                f"tuning table schema_version {version!r} != "
                f"{SCHEMA_VERSION}; re-run the tuner"
            )
        entries = raw.get("entries")
        if not isinstance(entries, dict):
            raise TuningTableError("tuning table 'entries' must be an object")
        for key, record in entries.items():
            if not isinstance(record, dict) or "config" not in record:
                raise TuningTableError(
                    f"tuning table entry {key!r} missing 'config'"
                )
            TunedConfig.from_dict(record["config"])  # validates knobs
        return cls(entries)
