"""Weight sparsification (Deep Compression style — the pipeline the paper
assumes, §I: "train with a full matrix, remove small weights, retrain").

Two granularities:

* ``magnitude_prune`` — element granularity, the paper/Han-et-al. scheme.
  Useful on CPU/CSR; on TPU it only helps memory if it survives at block
  granularity, so:
* ``block_prune`` — block granularity (MXU tile), scoring each block by a
  norm and keeping the top ``blocks_per_row`` per block-row (ELL-regular,
  matching :class:`BlockSparseMatrix`). This is the TPU-native analogue
  (DESIGN.md §2).

``PruneSchedule`` drives iterative prune→retrain.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array


def magnitude_prune(w: Array, density: float) -> Array:
    """Zero all but the top ``density`` fraction of |w| (global threshold)."""
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0, 1], got {density}")
    k = max(1, int(round(w.size * density)))
    thresh = jnp.sort(jnp.abs(w).ravel())[-k]
    return jnp.where(jnp.abs(w) >= thresh, w, 0.0)


def prune_mask(w: Array, density: float) -> Array:
    """Boolean keep-mask for ``magnitude_prune`` (for masked retraining)."""
    k = max(1, int(round(w.size * density)))
    thresh = jnp.sort(jnp.abs(w).ravel())[-k]
    return jnp.abs(w) >= thresh


def block_scores(
    w: Array, block_shape: tuple[int, int], *, norm: str = "l1"
) -> Array:
    m, n = w.shape
    bs_r, bs_c = block_shape
    tiles = w.reshape(m // bs_r, bs_r, n // bs_c, bs_c).transpose(0, 2, 1, 3)
    if norm == "l1":
        return jnp.sum(jnp.abs(tiles), axis=(2, 3))
    if norm == "l2":
        return jnp.sqrt(jnp.sum(tiles * tiles, axis=(2, 3)))
    if norm == "linf":
        return jnp.max(jnp.abs(tiles), axis=(2, 3))
    raise ValueError(f"unknown norm {norm!r}")


def block_prune_mask(
    w: Array,
    block_shape: tuple[int, int],
    blocks_per_row: int,
    *,
    norm: str = "l1",
) -> Array:
    """(n_row_blocks, n_col_blocks) bool mask keeping the top
    ``blocks_per_row`` blocks of each block-row by ``norm``."""
    scores = block_scores(w, block_shape, norm=norm)
    ncb = scores.shape[1]
    if blocks_per_row > ncb:
        raise ValueError(f"blocks_per_row {blocks_per_row} > {ncb}")
    order = jnp.argsort(-scores, axis=1)
    keep_cols = order[:, :blocks_per_row]
    mask = jnp.zeros_like(scores, dtype=bool)
    rows = jnp.broadcast_to(
        jnp.arange(scores.shape[0])[:, None], keep_cols.shape
    )
    return mask.at[rows, keep_cols].set(True)


def block_prune(
    w: Array,
    block_shape: tuple[int, int],
    blocks_per_row: int,
    *,
    norm: str = "l1",
) -> BlockSparseMatrix:
    """Prune ``w`` to an ELL-regular BSR matrix (host-side)."""
    import numpy as np

    mask = np.asarray(
        block_prune_mask(w, block_shape, blocks_per_row, norm=norm)
    )
    m, n = w.shape
    bs_r, bs_c = block_shape
    tiles = np.asarray(w).reshape(m // bs_r, bs_r, n // bs_c, bs_c)
    tiles = tiles.transpose(0, 2, 1, 3).copy()
    tiles[~mask] = 0.0
    dense = tiles.transpose(0, 2, 1, 3).reshape(m, n)
    return BlockSparseMatrix.from_dense(
        dense, block_shape, pad_to=blocks_per_row
    )


def apply_block_mask(w: Array, mask: Array, block_shape: tuple[int, int]) -> Array:
    """Zero out masked-off blocks of a dense ``w`` (masked retraining)."""
    m, n = w.shape
    bs_r, bs_c = block_shape
    full = jnp.repeat(jnp.repeat(mask, bs_r, axis=0), bs_c, axis=1)
    return jnp.where(full, w, 0.0)


@dataclasses.dataclass(frozen=True)
class PruneSchedule:
    """Iterative prune→retrain: at ``steps[i]`` reduce density to
    ``densities[i]`` (monotonically decreasing), then keep training with
    the mask frozen (gradient masking handled by the caller's train step).
    """

    steps: Sequence[int]
    densities: Sequence[float]

    def __post_init__(self):
        if len(self.steps) != len(self.densities):
            raise ValueError("steps and densities must align")
        if list(self.densities) != sorted(self.densities, reverse=True):
            raise ValueError("densities must be non-increasing")

    def density_at(self, step: int) -> float:
        d = 1.0
        for s, dens in zip(self.steps, self.densities):
            if step >= s:
                d = dens
        return d

    def is_prune_step(self, step: int) -> bool:
        return step in set(self.steps)
