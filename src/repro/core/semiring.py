"""Semiring algebra — the mathematical core of the GraphBLAS (paper §II).

A semiring bundles an additive monoid (⊕, 0̸) and a multiplicative
operation (⊗, 1̂) such that ⊕ is commutative/associative, ⊗ is
associative, ⊗ distributes over ⊕, 0̸ is the additive identity and the
multiplicative annihilator (a ⊗ 0̸ = 0̸). Those properties are exactly
what lets a GraphBLAS implementation skip stored zeros — the basis of the
paper's sparse-DNN argument.

Semirings here are *static* objects (hashable, usable as jit static
arguments). ``add``/``mul`` operate on jnp arrays elementwise;
``matmul(A, B)`` is the generalized product  C(i,j) = ⊕_k A(i,k) ⊗ B(k,j).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A (⊕, ⊗, 0̸, 1̂) semiring over jnp scalars/arrays.

    Attributes:
      name: stable identifier (used for kernel dispatch + caching).
      add: commutative associative binary op (the monoid ⊕).
      mul: binary op ⊗ distributing over ⊕.
      zero: additive identity / multiplicative annihilator 0̸.
      one: multiplicative identity 1̂ (None if the semiring has none).
      add_reduce: reduction form of ⊕ along an axis.
    """

    name: str
    add: Callable[[Array, Array], Array]
    mul: Callable[[Array, Array], Array]
    zero: float
    one: float | None
    add_reduce: Callable[..., Array]

    def __hash__(self) -> int:  # static-arg friendliness
        return hash(self.name)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Semiring) and other.name == self.name

    # --- generalized linear algebra -------------------------------------
    def matmul(self, a: Array, b: Array) -> Array:
        """C = A ⊕.⊗ B  (paper §II-D). Shapes: (..., m, l) × (..., l, n)."""
        if self.name == "plus_times":
            # Fast path: the arithmetic semiring IS jnp.matmul (MXU path).
            return jnp.matmul(a, b)
        # General path: broadcast ⊗ then ⊕-reduce the contraction axis.
        # a: (..., m, l) -> (..., m, l, 1); b: (..., l, n) -> (..., 1, l, n)
        prod = self.mul(a[..., :, :, None], b[..., None, :, :])
        return self.add_reduce(prod, axis=-2)

    def vecmat(self, v: Array, a: Array) -> Array:
        """vᵀ A over the semiring (GraphBLAS vxm)."""
        return self.matmul(v[None, :], a)[0]

    def matvec(self, a: Array, v: Array) -> Array:
        """A v over the semiring (GraphBLAS mxv)."""
        return self.matmul(a, v[:, None])[..., 0]


# --- The standard semirings used by the paper & the GraphBLAS spec -------

PLUS_TIMES = Semiring(
    name="plus_times",
    add=jnp.add,
    mul=jnp.multiply,
    zero=0.0,
    one=1.0,
    add_reduce=jnp.sum,
)
"""S1 = (ℝ, +, ×, 0, 1): standard arithmetic — correlation of inputs."""

MAX_PLUS = Semiring(
    name="max_plus",
    add=jnp.maximum,
    mul=jnp.add,
    zero=-jnp.inf,
    one=0.0,
    add_reduce=jnp.max,
)
"""S2 = ({-∞}∪ℝ, max, +, -∞, 0): optimal-path selection; carries ReLU."""

MIN_PLUS = Semiring(
    name="min_plus",
    add=jnp.minimum,
    mul=jnp.add,
    zero=jnp.inf,
    one=0.0,
    add_reduce=jnp.min,
)
"""Tropical shortest-path semiring."""

MAX_MIN = Semiring(
    name="max_min",
    add=jnp.maximum,
    mul=jnp.minimum,
    zero=-jnp.inf,
    one=jnp.inf,
    add_reduce=jnp.max,
)
"""Bottleneck-path semiring."""

MIN_MAX = Semiring(
    name="min_max",
    add=jnp.minimum,
    mul=jnp.maximum,
    zero=jnp.inf,
    one=-jnp.inf,
    add_reduce=jnp.min,
)

LOR_LAND = Semiring(
    name="lor_land",
    add=jnp.logical_or,
    mul=jnp.logical_and,
    zero=0.0,  # False
    one=1.0,  # True
    add_reduce=jnp.any,
)
"""Boolean reachability semiring."""

XOR_AND = Semiring(
    name="xor_and",
    add=jnp.logical_xor,
    mul=jnp.logical_and,
    zero=0.0,
    one=1.0,
    add_reduce=lambda x, axis=None, keepdims=False: jnp.sum(
        x.astype(jnp.int32), axis=axis, keepdims=keepdims
    )
    % 2
    == 1,
)
"""GF(2) — finite-field semiring from paper §II-C."""


def logsumexp_reduce(x: Array, axis=None, keepdims: bool = False) -> Array:
    return jax.nn.logsumexp(x, axis=axis, keepdims=keepdims)


LOG_PLUS = Semiring(
    name="log_plus",
    add=jnp.logaddexp,
    mul=jnp.add,
    zero=-jnp.inf,
    one=0.0,
    add_reduce=logsumexp_reduce,
)
"""Log-probability semiring (smooth max-plus) — useful for CRF/HMM layers."""


REGISTRY: dict[str, Semiring] = {
    s.name: s
    for s in (
        PLUS_TIMES,
        MAX_PLUS,
        MIN_PLUS,
        MAX_MIN,
        MIN_MAX,
        LOR_LAND,
        XOR_AND,
        LOG_PLUS,
    )
}


def get_semiring(name: str) -> Semiring:
    try:
        return REGISTRY[name]
    except KeyError as e:
        raise KeyError(
            f"unknown semiring {name!r}; available: {sorted(REGISTRY)}"
        ) from e
