"""The paper's ReLU DNN (§III, §IV Fig. 4) in JAX.

Execution modes:

* ``dnn_forward(..., fused=False)`` — **paper-faithful**: each layer is
  exactly the three GraphBLAS calls of Fig. 4:

    Y[k+1]  = GrB_mxm(FP32AddMul, W[k], Y[k])          # arithmetic semiring
    Y[k+1]  = GrB_eWiseMult(FP32MaxPlus, Y[k+1], B[k]) # ⊗=+  → bias add
    Y[k+1]  = GrB_eWiseAdd(FP32MaxPlus, Y[k+1], Zero)  # ⊕=max → ReLU

* ``fused=True`` — beyond-paper: one fused sparse-matmul + bias + max
  epilogue per layer (single activation stream; see DESIGN.md §2).

* ``dnn_forward_resident`` — beyond-paper, deepest fusion: ONE Pallas
  call for the whole homogeneous square stack, activations resident in
  VMEM across layers (``repro.kernels.fused_mlp``); falls back to the
  layered path when ineligible.

Weight layouts: dense arrays, ELL-padded :class:`BlockSparseMatrix`
(regular topologies) or occupancy-exact :class:`BlockCSRMatrix`
(skewed/pruned topologies — kernel grid ∝ true nnz blocks).
``preferred_layout``/``to_preferred_layout`` encode the choice; every
entry point dispatches on the weight type. ``dnn_forward_scan`` is the
stacked/scanned variant used inside jit for deep networks (one layer
traced once).

Dispatch itself now lives in ``repro.plan`` (layout heuristic, route
decision tree, grid-step cost model, compiled-plan cache — see
``docs/architecture.md``); this module keeps the paper-faithful math
plus backward-compatible wrappers that consult plans instead of
re-deriving dispatch per call.

Training: ``dnn_forward_trainable`` is the ``value_and_grad``-compatible
forward — every sparse layer goes through the custom-VJP Pallas kernel
wrappers (``repro.kernels.ops``), so the backward pass computes
dX = Wᵀ·dY and sparse-preserving weight cotangents with no densify
(``repro.kernels.autodiff``). ``dnn_value_and_grad`` packages the usual
loss → (loss, (dweights, dbiases)) step; the resident fused path is
forward-only and refuses differentiation.
"""

from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import graphblas as gb
from repro.core.semiring import MAX_PLUS, PLUS_TIMES
from repro.kernels import DEFAULT_BLOCK_N
from repro.sparse import ops as sparse_ops
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array
Weight = Union[Array, BlockSparseMatrix, BlockCSRMatrix]

# Backward-compatible wrappers — the layout heuristic, grid-step cost
# model, and route decision tree now live in ``repro.plan`` so plans,
# serving, and these legacy entry points all consult ONE implementation.
# ``repro.plan`` imports are deferred to call time: this module is
# imported during ``repro.core``/``repro.sparse`` package init, before
# the plan package can finish loading.


def __getattr__(name: str):
    if name == "ELL_WASTE_THRESHOLD":
        from repro.plan import layout as _plan_layout

        return _plan_layout.ELL_WASTE_THRESHOLD
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def preferred_layout(w: BlockSparseMatrix) -> str:
    """``"ell"`` or ``"bcsr"`` — alias of
    :func:`repro.plan.preferred_layout` (the ELL-pad waste heuristic)."""
    from repro.plan import layout as _plan_layout

    return _plan_layout.preferred_layout(w)


def to_preferred_layout(w: Weight) -> Weight:
    """Alias of :func:`repro.plan.to_preferred_layout`."""
    from repro.plan import layout as _plan_layout

    return _plan_layout.to_preferred_layout(w)


def layer_grid_steps(
    w: Weight, n: int, *, block_n: int = DEFAULT_BLOCK_N
) -> int:
    """Exact kernel grid steps one forward layer executes on an (·, n)
    activation panel (alias of :func:`repro.plan.layer_grid_steps` —
    the hardware-independent cost model, see `docs/serving.md`)."""
    from repro.plan import cost as _plan_cost

    return _plan_cost.layer_grid_steps(w, n, block_n=block_n)


def dnn_grid_steps(
    weights: Sequence[Weight], n: int, *, block_n: int = DEFAULT_BLOCK_N
) -> int:
    """Total forward grid steps of the L-layer stack on an (m, n) panel
    (alias of :func:`repro.plan.stack_grid_steps`; a compiled
    :class:`repro.plan.StackPlan` carries this as its precomputed
    ``grid_steps`` property)."""
    from repro.plan import cost as _plan_cost

    return _plan_cost.stack_grid_steps(weights, n, block_n=block_n)


def _sharded_plan_forward(
    weights: Sequence[Weight], biases: Sequence[Array], y0: Array, mesh
) -> Array:
    """The one mesh dispatch both forward wrappers share: fetch the
    mesh-sharded plan for this panel width from the shared default
    cache and run its shard_map executable."""
    from repro.plan import default_cache

    plan = default_cache().get(
        weights, biases, max(y0.shape[1], 1), mesh=mesh
    )
    return plan.forward(y0)


def dnn_layer(w: Weight, y: Array, b: Array, *, fused: bool = True) -> Array:
    """One forward layer: max(W·Y + b⊗1ᵀ, 0).  y: (m, n); b: (m,)."""
    if fused:
        if isinstance(w, BlockCSRMatrix):
            return sparse_ops.bcsr_matmul_fused_relu(w, y, b)
        if isinstance(w, BlockSparseMatrix):
            return sparse_ops.bsr_matmul_fused_relu(w, y, b)
        return sparse_ops.dense_matmul_fused_relu(w, y, b)
    # Paper-faithful three-call GraphBLAS sequence (Fig. 4 lines 30-32).
    bias = jnp.broadcast_to(b[:, None], y.shape)  # B[k] = b replicated
    zero = jnp.zeros_like(y)  # the Zero matrix (lines 24-26)
    z = gb.mxm(w, y, PLUS_TIMES)  # line 30
    z = gb.ewise_mult(z, bias, MAX_PLUS)  # line 31: ⊗ = +
    z = gb.ewise_add(z, zero, MAX_PLUS)  # line 32: ⊕ = max
    return z


def dnn_forward(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    y0: Array,
    *,
    fused: bool = True,
    mesh=None,
) -> Array:
    """Full L-layer forward pass (the paper's ``dnn()`` function).

    ``mesh``: run the stack mesh-sharded — every sparse layer's
    block-CSR segment is partitioned across the mesh's ``row_blocks``
    axes and executed under ``shard_map`` with a psum between layers
    (``repro.plan.ShardedStackPlan``, fetched through the shared
    :func:`repro.plan.default_cache`). Single-device semantics are
    unchanged when ``mesh`` is None (the default).
    """
    if mesh is not None:
        return _sharded_plan_forward(weights, biases, y0, mesh)
    y = y0
    for w, b in zip(weights, biases):
        y = dnn_layer(w, y, b, fused=fused)
    return y


def dnn_forward_all(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    y0: Array,
    *,
    fused: bool = True,
) -> list[Array]:
    """Forward pass returning every Y[k] (the paper's Y[0..L] array)."""
    ys = [y0]
    for w, b in zip(weights, biases):
        ys.append(dnn_layer(w, ys[-1], b, fused=fused))
    return ys


def resident_eligible(
    weights: Sequence[Weight], *, block_n: int = DEFAULT_BLOCK_N
) -> bool:
    """Can this stack run through the single-call VMEM-resident kernel?
    (Alias of :func:`repro.plan.resident_eligible` — the route decision
    tree lives in ``repro.plan.routes``.)"""
    from repro.plan import routes as _plan_routes

    return _plan_routes.resident_eligible(weights, block_n=block_n)


def _has_tracers(*trees) -> bool:
    return any(
        isinstance(leaf, jax.core.Tracer)
        for tree in trees
        for leaf in jax.tree.leaves(tree)
    )


def dnn_forward_resident(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    y0: Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
    panel_dtype=None,
    tuned=None,
    mesh=None,
) -> Array:
    """L-layer forward with the activation panel resident in VMEM.

    One ``pallas_call`` total (vs L for the layered path): eliminates
    L−1 HBM activation round-trips. Stacks whose panel exceeds the VMEM
    budget take the multi-panel tiled variant of the same single-call
    kernel (HBM ping-pong panel, m tiled over the row-block grid); falls
    back to ``dnn_forward(..., fused=True)`` when the stack is ineligible
    for both (heterogeneous, dense, CSR-layout, or non-square).

    A plan-backed wrapper: with default knobs the stack's route, layout
    choices, and executable come from the shared
    :class:`repro.plan.PlanCache` — repeated calls on the same topology
    and panel width reuse one compiled plan. A ``tuned`` config
    (``repro.tune.TunedConfig``) rides into the cache key, so tuned and
    untuned calls on the same topology each keep their own compiled
    plan. Explicit ``block_n``/``interpret``/``panel_dtype`` overrides
    take the direct path, as does any call under trace (a traced
    topology cannot be fingerprinted host-side, and a traced ``y0``
    means someone is differentiating or vmapping through this
    forward-only wrapper — the inline fallback keeps the legacy
    XLA-differentiable behaviour for ineligible stacks).

    ``mesh`` overrides residency entirely: the VMEM-resident kernel is
    single-device, so a mesh routes through the sharded layered plan
    (``repro.plan.ShardedStackPlan``) exactly like ``dnn_forward``.
    """
    if mesh is not None:
        return _sharded_plan_forward(weights, biases, y0, mesh)
    if (
        block_n == DEFAULT_BLOCK_N
        and interpret is None
        and panel_dtype is None
        and not _has_tracers(list(weights), list(biases), y0)
    ):
        from repro.plan import default_cache

        plan = default_cache().get(
            weights, biases, max(y0.shape[1], 1), tuned=tuned
        )
        return plan.forward(y0)
    from repro.plan import routes as _plan_routes

    route = _plan_routes.fused_route(
        weights, block_n=block_n, panel_dtype=panel_dtype
    )
    if route is None:
        return dnn_forward(weights, biases, y0, fused=True)
    from repro.kernels import ops as kernel_ops

    stacked_w = stack_bsr(list(weights))
    stacked_b = jnp.stack(list(biases))
    if route == _plan_routes.ROUTE_FUSED_TILED:
        return kernel_ops.fused_mlp_tiled_forward(
            stacked_w,
            stacked_b,
            y0,
            block_n=block_n,
            interpret=interpret,
            panel_dtype=panel_dtype,
        )
    return kernel_ops.fused_mlp_forward(
        stacked_w,
        stacked_b,
        y0,
        block_n=block_n,
        interpret=interpret,
        panel_dtype=panel_dtype,
    )


def dnn_layer_trainable(
    w: Weight,
    y: Array,
    b: Array,
    *,
    interpret: bool | None = None,
    transpose_plan=None,
) -> Array:
    """One differentiable layer max(W·Y + b⊗1ᵀ, 0) through the custom-VJP
    kernel wrappers (dense weights use the XLA fused path, which JAX
    differentiates natively). ``transpose_plan`` (for block-CSR weights)
    is the cached backward transpose from a ``repro.plan`` StackPlan —
    without it every backward pass re-sorts the frozen topology."""
    from repro.kernels import ops as kernel_ops

    if isinstance(w, BlockCSRMatrix):
        return kernel_ops.bcsr_spmm(
            w, y, b, transpose_plan, fuse_bias_relu=True, interpret=interpret
        )
    if isinstance(w, BlockSparseMatrix):
        return kernel_ops.bsr_spmm(
            w, y, b, fuse_bias_relu=True, interpret=interpret
        )
    return sparse_ops.dense_matmul_fused_relu(w, y, b)


def _layer_transpose_plans(weights: Sequence[Weight], plan):
    """Per-layer cached transposes from a ``repro.plan`` StackPlan (or
    None → no caching, the legacy re-sort-every-backward behaviour)."""
    if plan is None:
        return (None,) * len(weights)
    if not plan.differentiable:
        raise ValueError(
            "the supplied plan is not differentiable; build it with "
            "differentiable=True (PlanCache.get(..., differentiable=True))"
        )
    if plan.n_layers != len(weights):
        raise ValueError(
            f"plan has {plan.n_layers} layers but the stack has "
            f"{len(weights)}"
        )
    return plan.transpose_plans


def dnn_forward_trainable(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    y0: Array,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    plan=None,
) -> Array:
    """L-layer forward whose backward pass is kernel-resident.

    ``use_kernel=True`` routes every sparse layer through the Pallas
    kernels (custom VJPs: sparse-preserving dW, occupancy-exact dX);
    ``use_kernel=False`` uses the jnp oracle paths (same math, XLA
    autodiff — the pragmatic choice on CPU where kernels interpret).
    Both are ``jax.value_and_grad``-compatible; the resident fused
    forward is NOT (see ``dnn_forward_resident``).

    ``plan``: a differentiable :class:`repro.plan.StackPlan` built for
    this topology. Its cached block-CSR transposes make the backward
    sort-free — the frozen topology is sorted once at plan build, not
    once per backward pass. A :class:`repro.plan.ShardedStackPlan`
    routes the whole forward (and its backward) through the mesh-
    sharded shard_map executable instead — fresh values re-shard
    through the plan's frozen partition, cotangents keep the caller's
    unsharded layout.
    """
    if plan is not None and getattr(plan, "is_sharded", False):
        return plan.forward_trainable(
            weights, biases, y0, use_kernel=use_kernel, interpret=interpret
        )
    tps = _layer_transpose_plans(weights, plan)
    y = y0
    for w, b, tp in zip(weights, biases, tps):
        if use_kernel:
            y = dnn_layer_trainable(
                w, y, b, interpret=interpret, transpose_plan=tp
            )
        else:
            y = dnn_layer(w, y, b, fused=True)
    return y


def dnn_value_and_grad(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    y0: Array,
    targets: Array,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    plan=None,
):
    """The paper's DNN as a training step core: mean-squared loss of the
    forward pass against ``targets``, differentiated wrt weights AND
    biases. Returns ``(loss, (dweights, dbiases))`` where sparse weight
    cotangents keep the primal layout (stored blocks only; integer
    topology leaves carry float0 — optimizers skip them by dtype).
    ``plan`` as in :func:`dnn_forward_trainable`."""

    def loss_fn(ws, bs):
        out = dnn_forward_trainable(
            ws, bs, y0, use_kernel=use_kernel, interpret=interpret, plan=plan
        )
        return 0.5 * jnp.mean((out - targets) ** 2)

    return jax.value_and_grad(loss_fn, argnums=(0, 1), allow_int=True)(
        list(weights), list(biases)
    )


def dnn_forward_scan(
    stacked_weights: Weight,
    stacked_biases: Array,
    y0: Array,
    *,
    fused: bool = True,
) -> Array:
    """Scanned forward for homogeneous stacks.

    ``stacked_weights``: dense (L, m, m) array or a BlockSparseMatrix
    pytree whose leaves carry a leading L axis; ``stacked_biases``
    (L, m). One layer body in the HLO regardless of L.
    """

    def body(y, layer):
        w, b = layer
        return dnn_layer(w, y, b, fused=fused), None

    y, _ = jax.lax.scan(body, y0, (stacked_weights, stacked_biases))
    return y


def stack_bsr(mats: Sequence[BlockSparseMatrix]) -> BlockSparseMatrix:
    """Stack same-topology-shape BSR matrices along a new leading axis so
    they can be scanned over (weights of a deep sparse DNN)."""
    first = mats[0]
    for m in mats[1:]:
        if (
            m.shape != first.shape
            or m.block_shape != first.block_shape
            or m.max_blocks_per_row != first.max_blocks_per_row
        ):
            raise ValueError("stack_bsr requires homogeneous BSR structure")
    return BlockSparseMatrix(
        jnp.stack([m.blocks for m in mats]),
        jnp.stack([m.col_idx for m in mats]),
        jnp.stack([m.block_mask for m in mats]),
        first.shape,
        first.block_shape,
    )
