"""The paper's ReLU DNN (§III, §IV Fig. 4) in JAX.

Two execution modes:

* ``dnn_forward(..., fused=False)`` — **paper-faithful**: each layer is
  exactly the three GraphBLAS calls of Fig. 4:

    Y[k+1]  = GrB_mxm(FP32AddMul, W[k], Y[k])          # arithmetic semiring
    Y[k+1]  = GrB_eWiseMult(FP32MaxPlus, Y[k+1], B[k]) # ⊗=+  → bias add
    Y[k+1]  = GrB_eWiseAdd(FP32MaxPlus, Y[k+1], Zero)  # ⊕=max → ReLU

* ``fused=True`` — beyond-paper: one fused sparse-matmul + bias + max
  epilogue per layer (single activation stream; see DESIGN.md §2).

Weights may be dense arrays or :class:`BlockSparseMatrix` (homogeneous
list). ``dnn_forward_scan`` is the stacked/scanned variant used inside
jit for deep networks (one layer traced once).
"""

from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import graphblas as gb
from repro.core.semiring import MAX_PLUS, PLUS_TIMES
from repro.sparse import ops as sparse_ops
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array
Weight = Union[Array, BlockSparseMatrix]


def dnn_layer(w: Weight, y: Array, b: Array, *, fused: bool = True) -> Array:
    """One forward layer: max(W·Y + b⊗1ᵀ, 0).  y: (m, n); b: (m,)."""
    if fused:
        if isinstance(w, BlockSparseMatrix):
            return sparse_ops.bsr_matmul_fused_relu(w, y, b)
        return sparse_ops.dense_matmul_fused_relu(w, y, b)
    # Paper-faithful three-call GraphBLAS sequence (Fig. 4 lines 30-32).
    bias = jnp.broadcast_to(b[:, None], y.shape)  # B[k] = b replicated
    zero = jnp.zeros_like(y)  # the Zero matrix (lines 24-26)
    z = gb.mxm(w, y, PLUS_TIMES)  # line 30
    z = gb.ewise_mult(z, bias, MAX_PLUS)  # line 31: ⊗ = +
    z = gb.ewise_add(z, zero, MAX_PLUS)  # line 32: ⊕ = max
    return z


def dnn_forward(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    y0: Array,
    *,
    fused: bool = True,
) -> Array:
    """Full L-layer forward pass (the paper's ``dnn()`` function)."""
    y = y0
    for w, b in zip(weights, biases):
        y = dnn_layer(w, y, b, fused=fused)
    return y


def dnn_forward_all(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    y0: Array,
    *,
    fused: bool = True,
) -> list[Array]:
    """Forward pass returning every Y[k] (the paper's Y[0..L] array)."""
    ys = [y0]
    for w, b in zip(weights, biases):
        ys.append(dnn_layer(w, ys[-1], b, fused=fused))
    return ys


def dnn_forward_scan(
    stacked_weights: Weight,
    stacked_biases: Array,
    y0: Array,
    *,
    fused: bool = True,
) -> Array:
    """Scanned forward for homogeneous stacks.

    ``stacked_weights``: dense (L, m, m) array or a BlockSparseMatrix
    pytree whose leaves carry a leading L axis; ``stacked_biases``
    (L, m). One layer body in the HLO regardless of L.
    """

    def body(y, layer):
        w, b = layer
        return dnn_layer(w, y, b, fused=fused), None

    y, _ = jax.lax.scan(body, y0, (stacked_weights, stacked_biases))
    return y


def stack_bsr(mats: Sequence[BlockSparseMatrix]) -> BlockSparseMatrix:
    """Stack same-topology-shape BSR matrices along a new leading axis so
    they can be scanned over (weights of a deep sparse DNN)."""
    first = mats[0]
    for m in mats[1:]:
        if (
            m.shape != first.shape
            or m.block_shape != first.block_shape
            or m.max_blocks_per_row != first.max_blocks_per_row
        ):
            raise ValueError("stack_bsr requires homogeneous BSR structure")
    return BlockSparseMatrix(
        jnp.stack([m.blocks for m in mats]),
        jnp.stack([m.col_idx for m in mats]),
        jnp.stack([m.block_mask for m in mats]),
        first.shape,
        first.block_shape,
    )
