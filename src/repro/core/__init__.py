from repro.core.semiring import (
    LOG_PLUS,
    LOR_LAND,
    MAX_MIN,
    MAX_PLUS,
    MIN_MAX,
    MIN_PLUS,
    PLUS_TIMES,
    XOR_AND,
    Semiring,
    get_semiring,
)
from repro.core import dnn, graphblas, pruning

__all__ = [
    "Semiring",
    "get_semiring",
    "PLUS_TIMES",
    "MAX_PLUS",
    "MIN_PLUS",
    "MAX_MIN",
    "MIN_MAX",
    "LOR_LAND",
    "XOR_AND",
    "LOG_PLUS",
    "dnn",
    "graphblas",
    "pruning",
]
