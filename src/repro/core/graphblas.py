"""GraphBLAS primitive set in JAX (paper §II, §IV).

Implements the operations the paper's Fig. 4 C code uses — ``mxm``,
``eWiseMult``, ``eWiseAdd`` — plus the rest of the standard primitive set
(``mxv``/``vxm``, ``apply``, ``reduce``, ``select``, ``extract``,
``assign``, ``transpose``) with GraphBLAS-style masks and accumulators.

Dense arrays and :class:`repro.sparse.bsr.BlockSparseMatrix` operands are
both accepted where meaningful; sparse × dense products dispatch to the
BSR path (jnp oracle here; the Pallas kernel lives in
``repro.kernels.bsr_spmm`` and is selected by ``repro.kernels.ops``).
"""

from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.semiring import PLUS_TIMES, Semiring
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array
MatrixLike = Union[Array, BlockSparseMatrix, BlockCSRMatrix]


def _sparse_matmul_for(a: MatrixLike):
    """The layout's semiring matmul, or None for dense operands."""
    if isinstance(a, BlockCSRMatrix):
        from repro.sparse import ops as sparse_ops

        return sparse_ops.bcsr_matmul
    if isinstance(a, BlockSparseMatrix):
        from repro.sparse import ops as sparse_ops

        return sparse_ops.bsr_matmul
    return None


def _apply_mask_and_accum(
    out: Array,
    prev: Optional[Array],
    mask: Optional[Array],
    accum: Optional[Callable[[Array, Array], Array]],
) -> Array:
    """GraphBLAS output rule: out = mask ? accum(prev, out) : prev."""
    if accum is not None:
        if prev is None:
            raise ValueError("accum requires a previous output value")
        out = accum(prev, out)
    if mask is not None:
        base = prev if prev is not None else jnp.zeros_like(out)
        out = jnp.where(mask, out, base)
    return out


def mxm(
    a: MatrixLike,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """C = A ⊕.⊗ B  (GrB_mxm).

    ``a`` may be dense or BSR; ``b`` is dense (the paper keeps Y dense,
    §V-B: "we only consider dense Y matrices").
    """
    matmul = _sparse_matmul_for(a)
    if matmul is not None:
        out = matmul(a, b, semiring=semiring)
    else:
        out = semiring.matmul(a, b)
    return _apply_mask_and_accum(out, prev, mask, accum)


def mxv(
    a: MatrixLike,
    v: Array,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """w = A ⊕.⊗ v (GrB_mxv)."""
    out = mxm(a, v[:, None], semiring)[:, 0]
    return _apply_mask_and_accum(out, prev, mask, accum)


def vxm(
    v: Array,
    a: MatrixLike,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """wᵀ = vᵀ ⊕.⊗ A (GrB_vxm)."""
    matmul = _sparse_matmul_for(a)
    if matmul is not None:
        out = matmul(a.transpose(), v[:, None], semiring=semiring)[:, 0]
    else:
        out = semiring.vecmat(v, a)
    return _apply_mask_and_accum(out, prev, mask, accum)


def ewise_mult(
    a: Array,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """C(i,j) = A(i,j) ⊗ B(i,j) — intersection semantics (GrB_eWiseMult).

    In the paper's DNN (Fig. 4 line 31) this is the *max-plus* ⊗ = +,
    i.e. the bias add.
    """
    out = semiring.mul(a, b)
    return _apply_mask_and_accum(out, prev, mask, accum)


def ewise_add(
    a: Array,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """C(i,j) = A(i,j) ⊕ B(i,j) — union semantics (GrB_eWiseAdd).

    In the paper's DNN (Fig. 4 line 32) this is the *max-plus* ⊕ = max
    against the Zero matrix, i.e. the ReLU.
    """
    out = semiring.add(a, b)
    return _apply_mask_and_accum(out, prev, mask, accum)


def apply(
    a: Array,
    unary_op: Callable[[Array], Array],
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """C = f(A) elementwise (GrB_apply)."""
    out = unary_op(a)
    return _apply_mask_and_accum(out, prev, mask, accum)


def reduce_rows(
    a: Array, semiring: Semiring = PLUS_TIMES, *, axis: int = -1
) -> Array:
    """w(i) = ⊕_j A(i,j) (GrB_reduce to vector)."""
    return semiring.add_reduce(a, axis=axis)


def reduce_scalar(a: Array, semiring: Semiring = PLUS_TIMES) -> Array:
    """s = ⊕_{ij} A(i,j) (GrB_reduce to scalar)."""
    return semiring.add_reduce(a)


def select(a: Array, predicate: Callable[[Array], Array], fill=0.0) -> Array:
    """C = A where predicate(A), else the semiring zero (GrB_select)."""
    return jnp.where(predicate(a), a, jnp.asarray(fill, a.dtype))


def transpose(a: MatrixLike) -> MatrixLike:
    if isinstance(a, (BlockSparseMatrix, BlockCSRMatrix)):
        return a.transpose()
    return a.T


def extract(a: Array, rows: Array, cols: Array) -> Array:
    """C = A(rows, cols) (GrB_extract)."""
    return a[jnp.ix_(rows, cols)]


def assign(a: Array, rows: Array, cols: Array, value: Array) -> Array:
    """A(rows, cols) = value (GrB_assign); functional — returns new array."""
    return a.at[jnp.ix_(rows, cols)].set(value)
