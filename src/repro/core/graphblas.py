"""GraphBLAS primitive set in JAX (paper §II, §IV).

Implements the operations the paper's Fig. 4 C code uses — ``mxm``,
``eWiseMult``, ``eWiseAdd`` — plus the rest of the standard primitive set
(``mxv``/``vxm``, ``apply``, ``reduce``, ``select``, ``extract``,
``assign``, ``transpose``) with GraphBLAS-style masks and accumulators.

Dense arrays and :class:`repro.sparse.bsr.BlockSparseMatrix` /
:class:`repro.sparse.bcsr.BlockCSRMatrix` operands are both accepted
where meaningful. Sparse × dense products route through the Pallas
kernels (``repro.kernels.ops``) via a cached, semiring-aware
:class:`repro.plan.mxm.MxmPlan` — every registry semiring runs on the
fast occupancy-exact path, with the grid bill read off the plan's cost
model. ``use_kernel=False`` forces the pure-jnp XLA oracle
(``repro.sparse.ops``) for A/B comparison and for non-f32 exotica.
"""

from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.semiring import PLUS_TIMES, Semiring
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array
MatrixLike = Union[Array, BlockSparseMatrix, BlockCSRMatrix]


def _sparse_matmul_for(a: MatrixLike):
    """The layout's XLA-oracle semiring matmul, or None for dense."""
    if isinstance(a, BlockCSRMatrix):
        from repro.sparse import ops as sparse_ops

        return sparse_ops.bcsr_matmul
    if isinstance(a, BlockSparseMatrix):
        from repro.sparse import ops as sparse_ops

        return sparse_ops.bsr_matmul
    return None


def _sparse_product(
    a: MatrixLike, b: Array, semiring: Semiring, use_kernel: Optional[bool]
) -> Optional[Array]:
    """Sparse × dense over any registry semiring, or None for dense ``a``.

    Kernel route (default): a cached semiring-aware ``MxmPlan``
    dispatches the Pallas kernel on the occupancy-optimal layout —
    ``plus_times`` and ``min_plus`` plans live under different keys, so
    they never collide. ``use_kernel=False`` pins the XLA oracle. The
    boolean semirings come back in the kernels' {0, 1} f32 encoding
    either way (the oracle's bool output is cast to match).
    """
    if not isinstance(a, (BlockSparseMatrix, BlockCSRMatrix)):
        return None
    if use_kernel is None:
        # Plan building hashes the operand's concrete index arrays; under
        # a jit trace the operand's leaves are tracers, so auto-routing
        # falls back to the oracle (use_kernel=True still forces it).
        traced = any(
            isinstance(leaf, jax.core.Tracer)
            for leaf in jax.tree_util.tree_leaves(a)
        )
        use_kernel = (
            not traced and semiring.name in _kernel_semiring_names()
        )
    if use_kernel:
        from repro.plan.mxm import mxm_plan

        plan = mxm_plan(a, b.shape[1], semiring.name)
        return plan(b)
    out = _sparse_matmul_for(a)(a, b, semiring=semiring)
    if out.dtype == jnp.bool_:
        out = out.astype(jnp.float32)
    return out


def _kernel_semiring_names():
    from repro.kernels.semirings import supported

    return supported()


def _apply_mask_and_accum(
    out: Array,
    prev: Optional[Array],
    mask: Optional[Array],
    accum: Optional[Callable[[Array, Array], Array]],
) -> Array:
    """GraphBLAS output rule: out = mask ? accum(prev, out) : prev."""
    if accum is not None:
        if prev is None:
            raise ValueError("accum requires a previous output value")
        out = accum(prev, out)
    if mask is not None:
        base = prev if prev is not None else jnp.zeros_like(out)
        out = jnp.where(mask, out, base)
    return out


def mxm(
    a: MatrixLike,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
    use_kernel: Optional[bool] = None,
) -> Array:
    """C = A ⊕.⊗ B  (GrB_mxm).

    ``a`` may be dense, ELL-BSR, or block-CSR; ``b`` is dense (the paper
    keeps Y dense, §V-B: "we only consider dense Y matrices"). Sparse
    operands launch the Pallas kernel route by default (any registry
    semiring); ``use_kernel=False`` forces the XLA oracle.
    """
    out = _sparse_product(a, b, semiring, use_kernel)
    if out is None:
        out = semiring.matmul(a, b)
    return _apply_mask_and_accum(out, prev, mask, accum)


def mxv(
    a: MatrixLike,
    v: Array,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
    use_kernel: Optional[bool] = None,
) -> Array:
    """w = A ⊕.⊗ v (GrB_mxv).

    The vector rides as a width-1 panel; the kernel route's plan bills
    the narrow panel at the effective 8-wide tile
    (``repro.plan.cost.mxv_grid_steps``), not a full-width tile.
    """
    out = mxm(a, v[:, None], semiring, use_kernel=use_kernel)[:, 0]
    return _apply_mask_and_accum(out, prev, mask, accum)


def vxm(
    v: Array,
    a: MatrixLike,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
    use_kernel: Optional[bool] = None,
) -> Array:
    """wᵀ = vᵀ ⊕.⊗ A (GrB_vxm) — Aᵀ ⊕.⊗ v on the same narrow-panel
    kernel route as ``mxv`` for sparse operands."""
    if isinstance(a, (BlockSparseMatrix, BlockCSRMatrix)):
        out = _sparse_product(a.transpose(), v[:, None], semiring, use_kernel)
        out = out[:, 0]
    else:
        out = semiring.vecmat(v, a)
    return _apply_mask_and_accum(out, prev, mask, accum)


def ewise_mult(
    a: Array,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """C(i,j) = A(i,j) ⊗ B(i,j) — intersection semantics (GrB_eWiseMult).

    In the paper's DNN (Fig. 4 line 31) this is the *max-plus* ⊗ = +,
    i.e. the bias add.
    """
    out = semiring.mul(a, b)
    return _apply_mask_and_accum(out, prev, mask, accum)


def ewise_add(
    a: Array,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """C(i,j) = A(i,j) ⊕ B(i,j) — union semantics (GrB_eWiseAdd).

    In the paper's DNN (Fig. 4 line 32) this is the *max-plus* ⊕ = max
    against the Zero matrix, i.e. the ReLU.
    """
    out = semiring.add(a, b)
    return _apply_mask_and_accum(out, prev, mask, accum)


def apply(
    a: Array,
    unary_op: Callable[[Array], Array],
    *,
    mask: Optional[Array] = None,
    accum: Optional[Callable[[Array, Array], Array]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """C = f(A) elementwise (GrB_apply)."""
    out = unary_op(a)
    return _apply_mask_and_accum(out, prev, mask, accum)


def reduce_rows(
    a: Array, semiring: Semiring = PLUS_TIMES, *, axis: int = -1
) -> Array:
    """w(i) = ⊕_j A(i,j) (GrB_reduce to vector)."""
    return semiring.add_reduce(a, axis=axis)


def reduce_scalar(a: Array, semiring: Semiring = PLUS_TIMES) -> Array:
    """s = ⊕_{ij} A(i,j) (GrB_reduce to scalar)."""
    return semiring.add_reduce(a)


def select(a: Array, predicate: Callable[[Array], Array], fill=0.0) -> Array:
    """C = A where predicate(A), else the semiring zero (GrB_select)."""
    return jnp.where(predicate(a), a, jnp.asarray(fill, a.dtype))


def transpose(a: MatrixLike) -> MatrixLike:
    if isinstance(a, (BlockSparseMatrix, BlockCSRMatrix)):
        return a.transpose()
    return a.T


def extract(a: Array, rows: Array, cols: Array) -> Array:
    """C = A(rows, cols) (GrB_extract)."""
    return a[jnp.ix_(rows, cols)]


def assign(a: Array, rows: Array, cols: Array, value: Array) -> Array:
    """A(rows, cols) = value (GrB_assign); functional — returns new array."""
    return a.at[jnp.ix_(rows, cols)].set(value)
