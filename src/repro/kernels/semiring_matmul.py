"""Dense semiring matmul Pallas TPU kernel (paper §II-D / §III).

Computes ``C = A ⊕.⊗ B`` (+ optional fused max-plus bias/ReLU epilogue)
with explicit VMEM tiling:

* grid = (m/bm, n/bn, k/bk); the (i, j) output tile lives in a VMEM f32
  scratch accumulator across the k-steps (classic revisiting pattern).
* ``plus_times`` uses the MXU (``jnp.dot`` with f32 accumulation).
* every other registry semiring runs its tile product on the VPU; the
  (bm, bk, bn) broadcast is chunked along k (``semirings.K_CHUNK``) so
  the working set stays ≪ VMEM:  bm·bn·4  +  bm·chunk·bn·4 bytes.

Semiring dispatch (⊗/⊕ ops, accumulator init, annihilator fill) is
derived from ``core/semiring.py``'s registry by
``repro.kernels.semirings`` — the whole registry is supported, and
adding a semiring there is a one-place change.

TARGET is TPU; on CPU this file is exercised via ``interpret=True``
(see ``repro.kernels.ops``), checked against ``repro.kernels.ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import DEFAULT_BLOCK_N, _compat
from repro.kernels.semirings import accumulate_tile, kernel_semiring

Array = jax.Array


def _kernel(
    a_ref,
    b_ref,
    bias_ref,
    o_ref,
    acc_ref,
    *,
    semiring_name: str,
    k_steps: int,
    fuse_bias_relu: bool,
):
    spec = kernel_semiring(semiring_name)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        # ⊕-identity init (0 for plus_times, ±inf for the tropical
        # family, 0 for the boolean encodings, -inf for log_plus)
        acc_ref[...] = jnp.full_like(acc_ref, spec.init)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] = accumulate_tile(spec, a, b, acc_ref[...])

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...]
        if fuse_bias_relu:
            # max-plus pass of the paper fused in: max(acc + bias, 0).
            acc = jnp.maximum(acc + bias_ref[...].astype(jnp.float32), 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def semiring_matmul(
    a: Array,
    b: Array,
    *,
    semiring_name: str = "plus_times",
    bias: Array | None = None,
    fuse_bias_relu: bool = False,
    block_m: int = DEFAULT_BLOCK_N,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
    out_dtype=None,
) -> Array:
    """C = A ⊕.⊗ B with optional fused ``max(C + bias, 0)`` epilogue.

    a: (m, k); b: (k, n); bias: (m,) broadcast along n (paper's B[k]).
    m/k/n must divide the block sizes (wrappers in ``ops.py`` pad).
    Any registry semiring; unknown names raise ``KeyError`` at trace
    time via ``kernels.semirings``.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k),
        (block_m, block_n, block_k),
    )
    kernel_semiring(semiring_name)  # fail fast on unknown semirings
    if fuse_bias_relu and bias is None:
        raise ValueError("fuse_bias_relu requires bias")
    if bias is None:
        bias = jnp.zeros((m,), jnp.float32)
    bias2d = bias[:, None]  # (m, 1) so the tile is (block_m, 1)

    k_steps = k // block_k
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    kernel = functools.partial(
        _kernel,
        semiring_name=semiring_name,
        k_steps=k_steps,
        fuse_bias_relu=fuse_bias_relu,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b, bias2d)
