"""Jit'd public wrappers around the Pallas kernels.

One wrapper per kernel: ``semiring_matmul`` (dense), ``bsr_spmm``
(ELL grid), ``bcsr_spmm`` (occupancy-exact CSR grid — also fills the
empty block-rows the kernel grid never visits), ``fused_mlp_forward``
(VMEM-resident multi-layer, single pallas_call). See the package
docstring for when dispatch picks which.

On TPU the kernels run compiled; everywhere else (this container is
CPU-only) they run in ``interpret=True`` mode, which executes the kernel
body in Python/XLA-CPU for correctness validation. ``auto_interpret()``
makes that decision once.

Wrappers also handle shape padding to the kernel block grid, so callers
can pass arbitrary (m, k, n).

Differentiability: for the arithmetic (``plus_times``) semiring the
sparse wrappers route through the ``jax.custom_vjp`` rules of
``repro.kernels.autodiff`` — ``jax.grad`` through ``bsr_spmm`` /
``bcsr_spmm`` yields sparse-preserving weight cotangents (same layout as
the primal, no densify) and ``Aᵀ·dY`` operand gradients. Other semirings
keep the primal-only kernel path. ``fused_mlp_forward`` is NOT
differentiable and says so if asked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import DEFAULT_BLOCK_N
from repro.kernels import autodiff as _ad
from repro.kernels import bcsr_spmm as _bcsr
from repro.kernels import bsr_spmm as _bsr
from repro.kernels import semiring_matmul as _smm
from repro.kernels.semirings import kernel_zero
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array


@functools.cache
def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _semiring_zero(semiring_name: str) -> float:
    """The ⊕-identity used for k-padding and empty-row fills — the same
    registry-derived value the kernels init their accumulators with
    (``repro.kernels.semirings``), so fills and inits cannot drift."""
    return kernel_zero(semiring_name)


def _pad_to(x: Array, axis: int, mult: int, fill: float = 0.0) -> Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(
    jax.jit,
    static_argnames=(
        "semiring_name",
        "fuse_bias_relu",
        "block_m",
        "block_n",
        "block_k",
        "interpret",
    ),
)
def semiring_matmul(
    a: Array,
    b: Array,
    bias: Array | None = None,
    *,
    semiring_name: str = "plus_times",
    fuse_bias_relu: bool = False,
    block_m: int = DEFAULT_BLOCK_N,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
) -> Array:
    """Padded, jit'd ``C = A ⊕.⊗ B`` (+ optional fused bias/ReLU)."""
    interpret = auto_interpret() if interpret is None else interpret
    m, k = a.shape
    n = b.shape[1]
    block_m = min(block_m, _ceil_mult(m))
    block_n = effective_block_n(n, block_n)
    block_k = min(block_k, _ceil_mult(k))
    sr_zero = _semiring_zero(semiring_name)
    ap = _pad_to(_pad_to(a, 0, block_m), 1, block_k, fill=sr_zero)
    bp = _pad_to(_pad_to(b, 0, block_k, fill=sr_zero), 1, block_n)
    # NOTE: for plus_times zero-padding is exact. For max/min semirings the
    # ⊗ over padded k-entries uses the ⊕-identity so it cannot win the
    # reduction either.
    bias_p = None
    if bias is not None:
        bias_p = _pad_to(bias, 0, block_m)
    out = _smm.semiring_matmul(
        ap,
        bp,
        semiring_name=semiring_name,
        bias=bias_p,
        fuse_bias_relu=fuse_bias_relu,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:m, :n]


def _ceil_mult(size: int, base: int = 8) -> int:
    """Largest power-of-two block ≤ DEFAULT_BLOCK_N that keeps padding small."""
    b = DEFAULT_BLOCK_N
    while b > base and size < b:
        b //= 2
    return b


def effective_block_n(n: int, block_n: int = DEFAULT_BLOCK_N) -> int:
    """The column-tile width a wrapper actually runs for an (·, n) panel:
    the requested tile shrunk to the largest power-of-two that keeps the
    pad small — EXACTLY the clamp every wrapper below applies, exposed so
    the cost model (``repro.plan.cost``) and the autotuner
    (``repro.tune``) bill the same grid the kernels execute."""
    return min(block_n, _ceil_mult(n))


def _panel_dtype_name(panel_dtype) -> str | None:
    """Canonical (hashable) panel-dtype name for the static jit config."""
    return None if panel_dtype is None else str(np.dtype(panel_dtype))


@functools.partial(
    jax.jit,
    static_argnames=("semiring_name", "fuse_bias_relu", "block_n", "interpret"),
)
def bsr_spmm(
    a: BlockSparseMatrix,
    b: Array,
    bias: Array | None = None,
    *,
    semiring_name: str = "plus_times",
    fuse_bias_relu: bool = False,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
) -> Array:
    """Padded, jit'd block-sparse ``C = A ⊕.⊗ B`` (+ fused epilogue).

    Differentiable for ``plus_times`` (custom VJP: sparse-preserving
    weight cotangent, occupancy-exact dX — see ``kernels.autodiff``).
    """
    interpret = auto_interpret() if interpret is None else interpret
    n = b.shape[1]
    block_n = effective_block_n(n, block_n)
    bp = _pad_to(b, 1, block_n)
    if fuse_bias_relu and bias is None:
        raise ValueError("fuse_bias_relu requires bias")
    if semiring_name == "plus_times":
        bias_arr = bias if bias is not None else jnp.zeros((a.shape[0],), jnp.float32)
        cfg = _ad.SpmmConfig(fuse_bias_relu, block_n, interpret)
        out = _ad.bsr_spmm_diff(cfg, a, bp, bias_arr)
    else:
        out = _bsr.bsr_spmm(
            a,
            bp,
            semiring_name=semiring_name,
            bias=bias,
            fuse_bias_relu=fuse_bias_relu,
            block_n=block_n,
            interpret=interpret,
        )
    return out[:, :n]


@functools.partial(
    jax.jit,
    static_argnames=("semiring_name", "fuse_bias_relu", "block_n", "interpret"),
)
def bcsr_spmm(
    a: BlockCSRMatrix,
    b: Array,
    bias: Array | None = None,
    transpose_plan=None,
    *,
    semiring_name: str = "plus_times",
    fuse_bias_relu: bool = False,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
) -> Array:
    """Padded, jit'd occupancy-exact block-CSR ``C = A ⊕.⊗ B``.

    Grid steps ∝ stored nnz blocks (vs ``nrb × max_blocks_per_row`` for
    the ELL kernel). Block-rows with no stored blocks are filled with the
    epilogue of the semiring zero here (the kernel never visits them).

    Differentiable for ``plus_times``: the custom VJP runs the backward
    dX = Aᵀ·dY through this same Pallas kernel on the (jittable) block-
    CSR transpose, and the weight cotangent lands only on stored blocks.
    ``transpose_plan`` (``BcsrTransposePlan`` from ``a.transpose_plan()``
    or a ``repro.plan`` StackPlan) removes the backward's per-call
    topology re-sort — the frozen pattern is sorted once, at plan build.
    """
    interpret = auto_interpret() if interpret is None else interpret
    n = b.shape[1]
    block_n = effective_block_n(n, block_n)
    bp = _pad_to(b, 1, block_n)
    if fuse_bias_relu and bias is None:
        raise ValueError("fuse_bias_relu requires bias")
    if semiring_name == "plus_times":
        bias_arr = bias if bias is not None else jnp.zeros((a.shape[0],), jnp.float32)
        cfg = _ad.SpmmConfig(fuse_bias_relu, block_n, interpret)
        out = _ad.bcsr_spmm_diff(cfg, a, bp, bias_arr, transpose_plan)[:, :n]
    else:
        out = _bcsr.bcsr_spmm(
            a,
            bp,
            semiring_name=semiring_name,
            bias=bias,
            fuse_bias_relu=fuse_bias_relu,
            block_n=block_n,
            interpret=interpret,
        )[:, :n]
    # Empty block-rows: kernel grid never maps them — splice in the
    # epilogue of the accumulator init (semiring zero).
    fill = jnp.full((a.shape[0],), _semiring_zero(semiring_name), out.dtype)
    if fuse_bias_relu:
        fill = jnp.maximum(fill + bias.astype(out.dtype), 0).astype(out.dtype)
    counts = a.row_ptr[1:] - a.row_ptr[:-1]
    row_empty = jnp.repeat(
        counts == 0, a.block_shape[0], total_repeat_length=a.shape[0]
    )
    return jnp.where(row_empty[:, None], fill[:, None], out)


@functools.partial(
    jax.jit, static_argnames=("block_n", "interpret", "panel_dtype")
)
def fused_mlp_forward(
    stacked_w: BlockSparseMatrix,
    stacked_b: Array,
    y0: Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
    panel_dtype=None,
) -> Array:
    """Padded, jit'd VMEM-resident L-layer forward — ONE pallas_call.

    ``stacked_w``: BlockSparseMatrix whose leaves carry a leading L axis
    (see ``repro.core.dnn.stack_bsr``); square layers only. The
    activation panel never round-trips to HBM between layers.
    ``panel_dtype=jnp.bfloat16`` halves the resident panel's VMEM bill
    (f32 accumulate, result cast back — see ``kernels.fused_mlp``).

    NOT differentiable (per-layer activations never leave VMEM, so there
    is nothing to checkpoint): ``jax.grad`` through this raises with a
    pointer to the layered path (``dnn_forward_trainable``).
    """
    interpret = auto_interpret() if interpret is None else interpret
    n = y0.shape[1]
    block_n = effective_block_n(n, block_n)
    yp = _pad_to(y0, 1, block_n)
    cfg = _ad.FusedMlpConfig(block_n, interpret, _panel_dtype_name(panel_dtype))
    out = _ad.fused_mlp_forward_nondiff(cfg, stacked_w, stacked_b, yp)
    return out[:, :n]


@functools.partial(
    jax.jit, static_argnames=("block_n", "interpret", "panel_dtype")
)
def fused_mlp_tiled_forward(
    stacked_w: BlockSparseMatrix,
    stacked_b: Array,
    y0: Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
    panel_dtype=None,
) -> Array:
    """Padded, jit'd multi-panel tiled L-layer forward — ONE pallas_call.

    The route for homogeneous square stacks whose activation panel
    exceeds ``VMEM_SOFT_LIMIT_BYTES``: the ping-pong panel lives in HBM
    scratch and the m dimension is tiled over the row-block grid
    (``repro.kernels.fused_mlp.fused_mlp_tiled_forward``). Same
    forward-only contract as ``fused_mlp_forward``, including bf16
    activation panels via ``panel_dtype``.
    """
    interpret = auto_interpret() if interpret is None else interpret
    n = y0.shape[1]
    block_n = effective_block_n(n, block_n)
    yp = _pad_to(y0, 1, block_n)
    cfg = _ad.FusedMlpConfig(block_n, interpret, _panel_dtype_name(panel_dtype))
    out = _ad.fused_mlp_tiled_forward_nondiff(cfg, stacked_w, stacked_b, yp)
    return out[:, :n].astype(jnp.result_type(stacked_w.dtype, y0.dtype))
