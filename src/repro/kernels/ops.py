"""Jit'd public wrappers around the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this container is
CPU-only) they run in ``interpret=True`` mode, which executes the kernel
body in Python/XLA-CPU for correctness validation. ``auto_interpret()``
makes that decision once.

Wrappers also handle shape padding to the kernel block grid, so callers
can pass arbitrary (m, k, n).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bsr_spmm as _bsr
from repro.kernels import semiring_matmul as _smm
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array


@functools.cache
def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: Array, axis: int, mult: int, fill: float = 0.0) -> Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(
    jax.jit,
    static_argnames=(
        "semiring_name",
        "fuse_bias_relu",
        "block_m",
        "block_n",
        "block_k",
        "interpret",
    ),
)
def semiring_matmul(
    a: Array,
    b: Array,
    bias: Array | None = None,
    *,
    semiring_name: str = "plus_times",
    fuse_bias_relu: bool = False,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> Array:
    """Padded, jit'd ``C = A ⊕.⊗ B`` (+ optional fused bias/ReLU)."""
    interpret = auto_interpret() if interpret is None else interpret
    m, k = a.shape
    n = b.shape[1]
    block_m = min(block_m, _ceil_mult(m))
    block_n = min(block_n, _ceil_mult(n))
    block_k = min(block_k, _ceil_mult(k))
    sr_zero = 0.0 if semiring_name == "plus_times" else (
        _smm._VPU_SEMIRINGS[semiring_name][2]
    )
    ap = _pad_to(_pad_to(a, 0, block_m), 1, block_k, fill=sr_zero)
    bp = _pad_to(_pad_to(b, 0, block_k, fill=sr_zero), 1, block_n)
    # NOTE: for plus_times zero-padding is exact. For max/min semirings the
    # ⊗ over padded k-entries uses the ⊕-identity so it cannot win the
    # reduction either.
    bias_p = None
    if bias is not None:
        bias_p = _pad_to(bias, 0, block_m)
    out = _smm.semiring_matmul(
        ap,
        bp,
        semiring_name=semiring_name,
        bias=bias_p,
        fuse_bias_relu=fuse_bias_relu,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:m, :n]


def _ceil_mult(size: int, base: int = 8) -> int:
    """Largest power-of-two block ≤ 128 that keeps padding small."""
    b = 128
    while b > base and size < b:
        b //= 2
    return b


@functools.partial(
    jax.jit,
    static_argnames=("semiring_name", "fuse_bias_relu", "block_n", "interpret"),
)
def bsr_spmm(
    a: BlockSparseMatrix,
    b: Array,
    bias: Array | None = None,
    *,
    semiring_name: str = "plus_times",
    fuse_bias_relu: bool = False,
    block_n: int = 128,
    interpret: bool | None = None,
) -> Array:
    """Padded, jit'd block-sparse ``C = A ⊕.⊗ B`` (+ fused epilogue)."""
    interpret = auto_interpret() if interpret is None else interpret
    n = b.shape[1]
    block_n = min(block_n, _ceil_mult(n))
    bp = _pad_to(b, 1, block_n)
    out = _bsr.bsr_spmm(
        a,
        bp,
        semiring_name=semiring_name,
        bias=bias,
        fuse_bias_relu=fuse_bias_relu,
        block_n=block_n,
        interpret=interpret,
    )
    return out[:, :n]
