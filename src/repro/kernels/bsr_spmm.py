"""Block-sparse (ELL-padded BSR) × dense Pallas TPU kernel.

The TPU-native port of the paper's CSR SpMM (DESIGN.md §2): each stored
nonzero *block* becomes one dense MXU matmul; the block-column index table
is scalar-prefetched into SMEM and drives the B-panel gather via the
BlockSpec ``index_map`` (so the HBM→VMEM DMA only ever touches B panels
that are actually needed — compute AND bandwidth scale with nnz blocks).

grid = (row_blocks, n_tiles, max_blocks_per_row):
  t-axis walks the stored blocks of row-block i; the (i, j) output tile
  accumulates in VMEM scratch; invalid (padding) slots are skipped via
  ``block_mask`` + ``pl.when``. The final t-step applies the optional
  fused max-plus epilogue  max(acc + bias, 0)  — the paper's eWiseMult +
  eWiseAdd collapsed into the matmul's last store.

Semirings: the full ``core/semiring.py`` registry — ``plus_times`` on
the MXU, everything else chunked on the VPU via the registry-derived
dispatch in ``repro.kernels.semirings`` (⊕-identity accumulator init at
``t == 0``; masked pad slots are skipped before they can touch the
accumulator, so padding contributes exactly the ⊕-identity).

Autodiff: this module is the primal only. The ``plus_times`` form is
made differentiable by the ``jax.custom_vjp`` rule in
``repro.kernels.autodiff`` (attached at the ``repro.kernels.ops``
wrapper): dX = Wᵀ·dY via the occupancy-exact scatter-⊕ and a weight
cotangent computed only at stored (mask-true) block slots — same ELL
layout as the primal, padded slots exactly zero. See docs/kernels.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import DEFAULT_BLOCK_N, _compat

from repro.kernels.semirings import accumulate_tile, kernel_semiring
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array


def grid_steps(a: BlockSparseMatrix, n: int, block_n: int = DEFAULT_BLOCK_N) -> int:
    """Grid steps this kernel executes — the ELL pad is billed in full
    (``nrb × max_blocks_per_row`` per column tile), read from the
    weight's own layout."""
    nrb, mbpr = a.col_idx.shape
    return nrb * mbpr * (-(-n // block_n))


def _kernel(
    col_idx_ref,  # scalar-prefetch (nrb, mbpr) int32
    mask_ref,  # scalar-prefetch (nrb, mbpr) int32
    blocks_ref,  # (1, 1, bs_r, bs_c)
    b_ref,  # (bs_c, bn)
    bias_ref,  # (bs_r, 1)
    o_ref,  # (bs_r, bn)
    acc_ref,  # VMEM scratch (bs_r, bn) f32
    *,
    semiring_name: str,
    t_steps: int,
    fuse_bias_relu: bool,
):
    spec = kernel_semiring(semiring_name)
    i = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, spec.init)

    @pl.when(mask_ref[i, t] != 0)
    def _accumulate():
        # masked ELL pad slots never reach the accumulator: skipped work
        # contributes exactly the ⊕-identity (annihilator-aware padding)
        a = blocks_ref[0, 0].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        acc_ref[...] = accumulate_tile(spec, a, b, acc_ref[...])

    @pl.when(t == t_steps - 1)
    def _epilogue():
        acc = acc_ref[...]
        if fuse_bias_relu:
            acc = jnp.maximum(acc + bias_ref[...].astype(jnp.float32), 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def bsr_spmm(
    a: BlockSparseMatrix,
    b: Array,
    *,
    semiring_name: str = "plus_times",
    bias: Array | None = None,
    fuse_bias_relu: bool = False,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
    out_dtype=None,
) -> Array:
    """C (m, n) = A ⊕.⊗ B for ELL-padded BSR A (m, k), dense B (k, n)."""
    m, k = a.shape
    assert b.shape[0] == k, (a.shape, b.shape)
    n = b.shape[1]
    bs_r, bs_c = a.block_shape
    nrb, mbpr = a.col_idx.shape
    assert n % block_n == 0, (n, block_n)
    if fuse_bias_relu and bias is None:
        raise ValueError("fuse_bias_relu requires bias")
    kernel_semiring(semiring_name)  # fail fast on unknown semirings
    if bias is None:
        bias = jnp.zeros((m,), jnp.float32)
    bias2d = bias[:, None]
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)

    kernel = functools.partial(
        _kernel,
        semiring_name=semiring_name,
        t_steps=mbpr,
        fuse_bias_relu=fuse_bias_relu,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nrb, n // block_n, mbpr),
        in_specs=[
            # stored block (i, t)
            pl.BlockSpec(
                (1, 1, bs_r, bs_c), lambda i, j, t, ci, mk: (i, t, 0, 0)
            ),
            # B panel selected by the scalar-prefetched block-column index
            pl.BlockSpec((bs_c, block_n), lambda i, j, t, ci, mk: (ci[i, t], j)),
            # bias row-tile
            pl.BlockSpec((bs_r, 1), lambda i, j, t, ci, mk: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bs_r, block_n), lambda i, j, t, ci, mk: (i, j)
        ),
        scratch_shapes=[pltpu.VMEM((bs_r, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(
        a.col_idx,
        a.block_mask.astype(jnp.int32),
        a.blocks,
        b,
        bias2d,
    )
