"""VMEM-resident multi-layer fused forward Pallas TPU kernel.

``dnn_forward`` re-streams the (m, n) activation panel through HBM once
per layer: L layers → L−1 needless round-trips. The GraphChallenge
winners (arXiv:2004.01181, arXiv:1909.05631) fuse the whole layer stack;
this kernel does the TPU equivalent for the paper's square deep MLP
(homogeneous ``stack_bsr`` weight stacks):

  ONE ``pallas_call``, grid = (n_tiles, L, nrb, max_blocks_per_row).

Per output column stripe j, the full (m, block_n) activation panel lives
in a double-buffered VMEM scratch: layer l reads panel ``l % 2`` and
writes ``(l+1) % 2`` row-block by row-block, applying the per-layer
``max(W·Y + b, 0)`` epilogue in-register. Only y0 is read from HBM and
only Y[L] is written back.

VMEM budget: 2·m·block_n f32 panels + the streamed-in y0/out blocks +
one (bs_r, block_n) accumulator — callers check
:func:`fused_mlp_vmem_bytes` before dispatching (``repro.core.dnn``
falls back to the layered path when the panel would not fit).

Weights use the ELL layout (the stack shares one static
``max_blocks_per_row``); the occupancy-exact CSR grid and the resident
panel are complementary optimisations — CSR wins on skewed single
layers, residency wins on deep stacks — and dispatch picks per workload.

plus_times only: the per-layer ReLU epilogue is the paper's max-plus
step already fused in; other semirings take the layered path.

Forward-only: per-layer activations never exist outside VMEM, so there
is nothing to checkpoint for a backward pass — ``jax.grad`` through the
``repro.kernels.ops`` wrapper raises ``NotImplementedError`` (rule in
``repro.kernels.autodiff``) pointing at the layered differentiable path
(``core.dnn.dnn_forward_trainable``); ``serve.SparseDNNEngine(
differentiable=True)`` routes around this kernel automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import DEFAULT_BLOCK_N, _compat

from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array

# Stay well inside the ~16 MiB/core VMEM so the streamed blocks and
# double-buffering slack fit alongside the resident panels.
VMEM_SOFT_LIMIT_BYTES = 12 * 1024 * 1024


def _panel_np_dtype(panel_dtype) -> np.dtype:
    """Canonical activation-panel dtype: f32 unless the caller opts into
    a reduced-precision panel (name, np/jnp dtype — all accepted)."""
    return np.dtype(panel_dtype if panel_dtype is not None else np.float32)


def fused_mlp_vmem_bytes(
    m: int, block_n: int = DEFAULT_BLOCK_N, panel_dtype=None
) -> int:
    """Scratch bytes the resident panel needs (2 panels + in/out tiles).

    All four (m, block_n) stripes — the ping-pong ybuf pair, the y0
    stripe and the out stripe — are held in ``panel_dtype``, so bf16
    panels halve this bill and move the resident↔tiled boundary
    (accumulation stays f32 in a block-sized register tile)."""
    panel = m * block_n * _panel_np_dtype(panel_dtype).itemsize
    return 4 * panel  # ybuf×2 + y0 stripe + out stripe


def fused_mlp_eligible(
    w: BlockSparseMatrix,
    block_n: int = DEFAULT_BLOCK_N,
    *,
    panel_dtype=None,
    vmem_limit: int | None = None,
) -> bool:
    """Square stack small enough for the panel to live in VMEM."""
    m, k = w.shape
    limit = VMEM_SOFT_LIMIT_BYTES if vmem_limit is None else vmem_limit
    return m == k and fused_mlp_vmem_bytes(m, block_n, panel_dtype) <= limit


def fused_mlp_tiled_eligible(
    w: BlockSparseMatrix, block_n: int = DEFAULT_BLOCK_N
) -> bool:
    """Square stack of ANY height — the tiled variant keeps the panel in
    HBM scratch and holds only per-block tiles in VMEM, so there is no
    panel-size ceiling. (Dispatch still prefers the fully resident kernel
    whenever :func:`fused_mlp_eligible` says the panel fits.)"""
    m, k = w.shape
    return m == k


def _kernel(
    col_idx_ref,  # scalar-prefetch (L, nrb, mbpr) int32
    mask_ref,  # scalar-prefetch (L, nrb, mbpr) int32
    blocks_ref,  # (1, 1, 1, bs_r, bs_c)
    y0_ref,  # (m, bn) — this j-stripe of the input panel
    bias_ref,  # (1, bs_r, 1)
    o_ref,  # (m, bn) — this j-stripe of Y[L]
    ybuf_ref,  # VMEM scratch (2, m, bn) panel_dtype double-buffered panel
    acc_ref,  # VMEM scratch (bs_r, bn) f32
    *,
    n_layers: int,
    t_steps: int,
    bs_r: int,
    bs_c: int,
    panel_dtype,
):
    l = pl.program_id(1)
    i = pl.program_id(2)
    t = pl.program_id(3)

    @pl.when((l == 0) & (i == 0) & (t == 0))
    def _load_input_panel():
        ybuf_ref[0] = y0_ref[...].astype(panel_dtype)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[l, i, t] != 0)
    def _accumulate():
        w = blocks_ref[0, 0, 0].astype(jnp.float32)
        c = col_idx_ref[l, i, t]
        y = ybuf_ref[l % 2, pl.ds(c * bs_c, bs_c), :]
        acc_ref[...] += jnp.dot(w, y, preferred_element_type=jnp.float32)

    @pl.when(t == t_steps - 1)
    def _close_row_block():
        # The paper's eWiseMult(+bias) / eWiseAdd(max 0) pair, in-register.
        val = jnp.maximum(acc_ref[...] + bias_ref[0].astype(jnp.float32), 0.0)
        ybuf_ref[(l + 1) % 2, pl.ds(i * bs_r, bs_r), :] = val.astype(panel_dtype)

        @pl.when(l == n_layers - 1)
        def _store_output():
            o_ref[pl.ds(i * bs_r, bs_r), :] = val.astype(o_ref.dtype)


def fused_mlp_forward(
    stacked_w: BlockSparseMatrix,
    stacked_b: Array,
    y0: Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
    out_dtype=None,
    panel_dtype=None,
) -> Array:
    """Y[L] (m, n) = relu-MLP(y0) through all L layers in one kernel.

    ``stacked_w.blocks``: (L, nrb, mbpr, bs_r, bs_c) — a ``stack_bsr``
    result; ``stacked_b``: (L, m). Requires square layers (m == k) and
    ``n % block_n == 0``. ``panel_dtype=jnp.bfloat16`` keeps every
    activation stripe (ybuf pair, y0, out) in bf16 — halving
    :func:`fused_mlp_vmem_bytes` — while the per-block accumulate and the
    bias/ReLU epilogue stay f32; the result is cast back to
    ``out_dtype``.
    """
    m, k = stacked_w.shape
    if m != k:
        raise ValueError(f"fused MLP needs square layers, got {stacked_w.shape}")
    if stacked_w.blocks.ndim != 5:
        raise ValueError("stacked_w must carry a leading L axis (stack_bsr)")
    n_layers, nrb, mbpr = stacked_w.col_idx.shape
    bs_r, bs_c = stacked_w.block_shape
    n = y0.shape[1]
    assert y0.shape[0] == k, (stacked_w.shape, y0.shape)
    assert n % block_n == 0, (n, block_n)
    assert stacked_b.shape == (n_layers, m), stacked_b.shape
    out_dtype = out_dtype or jnp.result_type(stacked_w.dtype, y0.dtype)
    pdt = _panel_np_dtype(panel_dtype)
    default_panels = pdt == np.dtype(np.float32)

    kernel = functools.partial(
        _kernel,
        n_layers=n_layers,
        t_steps=mbpr,
        bs_r=bs_r,
        bs_c=bs_c,
        panel_dtype=pdt,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n // block_n, n_layers, nrb, mbpr),
        in_specs=[
            # stored block (l, i, t)
            pl.BlockSpec(
                (1, 1, 1, bs_r, bs_c),
                lambda j, l, i, t, ci, mk: (l, i, t, 0, 0),
            ),
            # the full input column stripe for this j
            pl.BlockSpec((m, block_n), lambda j, l, i, t, ci, mk: (0, j)),
            # bias row-tile of layer l, row-block i
            pl.BlockSpec(
                (1, bs_r, 1), lambda j, l, i, t, ci, mk: (l, i, 0)
            ),
        ],
        # the full output column stripe — written once per j, on layer L-1
        out_specs=pl.BlockSpec((m, block_n), lambda j, l, i, t, ci, mk: (0, j)),
        scratch_shapes=[
            pltpu.VMEM((2, m, block_n), pdt),
            pltpu.VMEM((bs_r, block_n), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        # bf16 panels: the streamed y0/out stripes are bf16 too (that is
        # what makes the VMEM bill exactly 4 panels × itemsize); the
        # wrapper casts back to out_dtype below.
        out_shape=jax.ShapeDtypeStruct(
            (m, n), out_dtype if default_panels else pdt
        ),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(
        stacked_w.col_idx,
        stacked_w.block_mask.astype(jnp.int32),
        stacked_w.blocks,
        y0 if default_panels else y0.astype(pdt),
        stacked_b[:, :, None],
    )
    return out if default_panels else out.astype(out_dtype)


# --------------------------------------------------------------------------
# Multi-panel tiled variant: m beyond the VMEM budget, panel in HBM
# --------------------------------------------------------------------------


def _tiled_kernel(
    col_idx_ref,  # scalar-prefetch (L, nrb, mbpr) int32
    mask_ref,  # scalar-prefetch (L, nrb, mbpr) int32
    blocks_ref,  # (1, 1, mbpr, bs_r, bs_c) — row-block i's stored blocks
    y0_ref,  # full (m, n) panel_dtype, HBM (never pulled into VMEM whole)
    bias_ref,  # (1, bs_r, 1)
    o_ref,  # full (m, n) panel_dtype, HBM
    panel_ref,  # HBM scratch (2, m, bn) panel_dtype ping-pong activation panel
    ybuf_ref,  # VMEM scratch (2, bs_c, bn) panel_dtype double-buffered gather
    acc_ref,  # VMEM scratch (bs_r, bn) f32
    vout_ref,  # VMEM scratch (bs_r, bn) panel_dtype outgoing row-block stage
    stage_sem,  # DMA semaphore: y0 stripe → panel[0]
    gather_sems,  # DMA semaphores (2,): panel → ybuf slots
    out_sem,  # DMA semaphore: vout → panel/output
    *,
    n_layers: int,
    t_steps: int,
    bs_r: int,
    bs_c: int,
    block_n: int,
):
    j = pl.program_id(0)
    l = pl.program_id(1)
    i = pl.program_id(2)
    src = l % 2  # panel slot layer l reads; (l+1)%2 == 1-src is written

    @pl.when((l == 0) & (i == 0))
    def _stage_input_stripe():
        # HBM→HBM: this j-stripe of y0 becomes layer 0's input panel.
        cp = pltpu.make_async_copy(
            y0_ref.at[:, pl.ds(j * block_n, block_n)],
            panel_ref.at[0],
            stage_sem,
        )
        cp.start()
        cp.wait()

    def gather(t, slot):
        c = col_idx_ref[l, i, t]
        return pltpu.make_async_copy(
            panel_ref.at[src, pl.ds(c * bs_c, bs_c), :],
            ybuf_ref.at[slot],
            gather_sems.at[slot],
        )

    gather(0, 0).start()
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(t, carry):
        slot = jax.lax.rem(t, 2)

        @pl.when(t + 1 < t_steps)
        def _prefetch_next():
            gather(t + 1, jax.lax.rem(t + 1, 2)).start()

        gather(t, slot).wait()

        @pl.when(mask_ref[l, i, t] != 0)
        def _accumulate():
            w = blocks_ref[0, 0, t].astype(jnp.float32)
            acc_ref[...] += jnp.dot(
                w, ybuf_ref[slot], preferred_element_type=jnp.float32
            )

        return carry

    jax.lax.fori_loop(0, t_steps, body, 0)

    # Same in-register epilogue as the resident kernel, then one DMA to
    # the next layer's panel slot (waited: layer l+1 may read ANY block).
    vout_ref[...] = jnp.maximum(
        acc_ref[...] + bias_ref[0].astype(jnp.float32), 0.0
    ).astype(vout_ref.dtype)
    cp = pltpu.make_async_copy(
        vout_ref,
        panel_ref.at[1 - src, pl.ds(i * bs_r, bs_r), :],
        out_sem,
    )
    cp.start()
    cp.wait()

    @pl.when(l == n_layers - 1)
    def _store_output():
        cp2 = pltpu.make_async_copy(
            vout_ref,
            o_ref.at[pl.ds(i * bs_r, bs_r), pl.ds(j * block_n, block_n)],
            out_sem,
        )
        cp2.start()
        cp2.wait()


def fused_mlp_tiled_forward(
    stacked_w: BlockSparseMatrix,
    stacked_b: Array,
    y0: Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
    out_dtype=None,
    panel_dtype=None,
) -> Array:
    """Y[L] = relu-MLP(y0), ONE ``pallas_call``, panel tiled over m.

    The resident kernel's (2, m, block_n) VMEM scratch caps m at
    ``VMEM_SOFT_LIMIT_BYTES``; past it this variant keeps the ping-pong
    activation panel in **HBM scratch** and tiles the m dimension over
    the row-block grid: grid = (n_tiles, L, nrb) — each step DMAs the
    row's ≤ ``max_blocks_per_row`` input blocks into a double-buffered
    (bs_c, block_n) VMEM window (overlapping the gather of block t+1
    with the MXU product of block t), closes the row with the fused
    ``max(W·Y+b, 0)`` epilogue, and DMAs the (bs_r, block_n) result to
    the next layer's panel slot. VMEM use is O(mbpr·bs² + bs·block_n) —
    independent of m — while the stack still runs as a single kernel
    with no per-layer XLA round-trips (the GraphChallenge 16k/64k-neuron
    configs land here).

    Same contract as :func:`fused_mlp_forward` otherwise: homogeneous
    square ``stack_bsr`` stacks, ``n % block_n == 0``, forward-only.
    """
    m, k = stacked_w.shape
    if m != k:
        raise ValueError(f"fused MLP needs square layers, got {stacked_w.shape}")
    if stacked_w.blocks.ndim != 5:
        raise ValueError("stacked_w must carry a leading L axis (stack_bsr)")
    n_layers, nrb, mbpr = stacked_w.col_idx.shape
    bs_r, bs_c = stacked_w.block_shape
    n = y0.shape[1]
    assert y0.shape[0] == k, (stacked_w.shape, y0.shape)
    assert n % block_n == 0, (n, block_n)
    assert stacked_b.shape == (n_layers, m), stacked_b.shape
    out_dtype = out_dtype or jnp.result_type(stacked_w.dtype, y0.dtype)
    pdt = _panel_np_dtype(panel_dtype)

    kernel = functools.partial(
        _tiled_kernel,
        n_layers=n_layers,
        t_steps=mbpr,
        bs_r=bs_r,
        bs_c=bs_c,
        block_n=block_n,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n // block_n, n_layers, nrb),
        in_specs=[
            # all stored blocks of (layer l, row-block i)
            pl.BlockSpec(
                (1, 1, mbpr, bs_r, bs_c),
                lambda j, l, i, ci, mk: (l, i, 0, 0, 0),
            ),
            # the input panel stays in HBM; the kernel DMAs slices
            pl.BlockSpec(memory_space=pltpu.ANY),
            # bias row-tile of layer l, row-block i
            pl.BlockSpec((1, bs_r, 1), lambda j, l, i, ci, mk: (l, i, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.ANY((2, m, block_n), pdt),
            pltpu.VMEM((2, bs_c, block_n), pdt),
            pltpu.VMEM((bs_r, block_n), jnp.float32),
            pltpu.VMEM((bs_r, block_n), pdt),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), pdt),
        compiler_params=_compat.CompilerParams(
            # The HBM panel scratch is shared across ALL grid steps —
            # even the j stripes must run sequentially on one core.
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(
        stacked_w.col_idx,
        stacked_w.block_mask.astype(jnp.int32),
        stacked_w.blocks,
        y0.astype(pdt),
        stacked_b[:, :, None],
    )
    return out.astype(out_dtype)
