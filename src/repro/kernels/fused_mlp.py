"""VMEM-resident multi-layer fused forward Pallas TPU kernel.

``dnn_forward`` re-streams the (m, n) activation panel through HBM once
per layer: L layers → L−1 needless round-trips. The GraphChallenge
winners (arXiv:2004.01181, arXiv:1909.05631) fuse the whole layer stack;
this kernel does the TPU equivalent for the paper's square deep MLP
(homogeneous ``stack_bsr`` weight stacks):

  ONE ``pallas_call``, grid = (n_tiles, L, nrb, max_blocks_per_row).

Per output column stripe j, the full (m, block_n) activation panel lives
in a double-buffered VMEM scratch: layer l reads panel ``l % 2`` and
writes ``(l+1) % 2`` row-block by row-block, applying the per-layer
``max(W·Y + b, 0)`` epilogue in-register. Only y0 is read from HBM and
only Y[L] is written back.

VMEM budget: 2·m·block_n f32 panels + the streamed-in y0/out blocks +
one (bs_r, block_n) accumulator — callers check
:func:`fused_mlp_vmem_bytes` before dispatching (``repro.core.dnn``
falls back to the layered path when the panel would not fit).

Weights use the ELL layout (the stack shares one static
``max_blocks_per_row``); the occupancy-exact CSR grid and the resident
panel are complementary optimisations — CSR wins on skewed single
layers, residency wins on deep stacks — and dispatch picks per workload.

plus_times only: the per-layer ReLU epilogue is the paper's max-plus
step already fused in; other semirings take the layered path.

Forward-only: per-layer activations never exist outside VMEM, so there
is nothing to checkpoint for a backward pass — ``jax.grad`` through the
``repro.kernels.ops`` wrapper raises ``NotImplementedError`` (rule in
``repro.kernels.autodiff``) pointing at the layered differentiable path
(``core.dnn.dnn_forward_trainable``); ``serve.SparseDNNEngine(
differentiable=True)`` routes around this kernel automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array

# Stay well inside the ~16 MiB/core VMEM so the streamed blocks and
# double-buffering slack fit alongside the resident panels.
VMEM_SOFT_LIMIT_BYTES = 12 * 1024 * 1024


def fused_mlp_vmem_bytes(m: int, block_n: int = 128) -> int:
    """Scratch bytes the resident panel needs (2 panels + in/out tiles)."""
    panel = m * block_n * 4
    return 4 * panel  # ybuf×2 + y0 stripe + out stripe


def fused_mlp_eligible(w: BlockSparseMatrix, block_n: int = 128) -> bool:
    """Square stack small enough for the panel to live in VMEM."""
    m, k = w.shape
    return m == k and fused_mlp_vmem_bytes(m, block_n) <= VMEM_SOFT_LIMIT_BYTES


def _kernel(
    col_idx_ref,  # scalar-prefetch (L, nrb, mbpr) int32
    mask_ref,  # scalar-prefetch (L, nrb, mbpr) int32
    blocks_ref,  # (1, 1, 1, bs_r, bs_c)
    y0_ref,  # (m, bn) — this j-stripe of the input panel
    bias_ref,  # (1, bs_r, 1)
    o_ref,  # (m, bn) — this j-stripe of Y[L]
    ybuf_ref,  # VMEM scratch (2, m, bn) f32 double-buffered panel
    acc_ref,  # VMEM scratch (bs_r, bn) f32
    *,
    n_layers: int,
    t_steps: int,
    bs_r: int,
    bs_c: int,
):
    l = pl.program_id(1)
    i = pl.program_id(2)
    t = pl.program_id(3)

    @pl.when((l == 0) & (i == 0) & (t == 0))
    def _load_input_panel():
        ybuf_ref[0] = y0_ref[...].astype(jnp.float32)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[l, i, t] != 0)
    def _accumulate():
        w = blocks_ref[0, 0, 0].astype(jnp.float32)
        c = col_idx_ref[l, i, t]
        y = ybuf_ref[l % 2, pl.ds(c * bs_c, bs_c), :]
        acc_ref[...] += jnp.dot(w, y, preferred_element_type=jnp.float32)

    @pl.when(t == t_steps - 1)
    def _close_row_block():
        # The paper's eWiseMult(+bias) / eWiseAdd(max 0) pair, in-register.
        val = jnp.maximum(acc_ref[...] + bias_ref[0].astype(jnp.float32), 0.0)
        ybuf_ref[(l + 1) % 2, pl.ds(i * bs_r, bs_r), :] = val

        @pl.when(l == n_layers - 1)
        def _store_output():
            o_ref[pl.ds(i * bs_r, bs_r), :] = val.astype(o_ref.dtype)


def fused_mlp_forward(
    stacked_w: BlockSparseMatrix,
    stacked_b: Array,
    y0: Array,
    *,
    block_n: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> Array:
    """Y[L] (m, n) = relu-MLP(y0) through all L layers in one kernel.

    ``stacked_w.blocks``: (L, nrb, mbpr, bs_r, bs_c) — a ``stack_bsr``
    result; ``stacked_b``: (L, m). Requires square layers (m == k) and
    ``n % block_n == 0``.
    """
    m, k = stacked_w.shape
    if m != k:
        raise ValueError(f"fused MLP needs square layers, got {stacked_w.shape}")
    if stacked_w.blocks.ndim != 5:
        raise ValueError("stacked_w must carry a leading L axis (stack_bsr)")
    n_layers, nrb, mbpr = stacked_w.col_idx.shape
    bs_r, bs_c = stacked_w.block_shape
    n = y0.shape[1]
    assert y0.shape[0] == k, (stacked_w.shape, y0.shape)
    assert n % block_n == 0, (n, block_n)
    assert stacked_b.shape == (n_layers, m), stacked_b.shape
    out_dtype = out_dtype or jnp.result_type(stacked_w.dtype, y0.dtype)

    kernel = functools.partial(
        _kernel,
        n_layers=n_layers,
        t_steps=mbpr,
        bs_r=bs_r,
        bs_c=bs_c,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n // block_n, n_layers, nrb, mbpr),
        in_specs=[
            # stored block (l, i, t)
            pl.BlockSpec(
                (1, 1, 1, bs_r, bs_c),
                lambda j, l, i, t, ci, mk: (l, i, t, 0, 0),
            ),
            # the full input column stripe for this j
            pl.BlockSpec((m, block_n), lambda j, l, i, t, ci, mk: (0, j)),
            # bias row-tile of layer l, row-block i
            pl.BlockSpec(
                (1, bs_r, 1), lambda j, l, i, t, ci, mk: (l, i, 0)
            ),
        ],
        # the full output column stripe — written once per j, on layer L-1
        out_specs=pl.BlockSpec((m, block_n), lambda j, l, i, t, ci, mk: (0, j)),
        scratch_shapes=[
            pltpu.VMEM((2, m, block_n), jnp.float32),
            pltpu.VMEM((bs_r, block_n), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(
        stacked_w.col_idx,
        stacked_w.block_mask.astype(jnp.int32),
        stacked_w.blocks,
        y0,
        stacked_b[:, :, None],
    )
