"""Occupancy-exact block-CSR × dense Pallas TPU kernel.

The ELL kernel (``bsr_spmm``) runs a ``(nrb, n_tiles, max_blocks_per_row)``
grid: wall-clock scales with the *worst-case* row occupancy because
padded slots still cost a grid step and the B-panel HBM→VMEM DMA even
though ``pl.when`` skips their compute. This kernel's grid is

    (n_tiles, total_nnz_blocks)

— one step per *stored* block, so compute AND DMA traffic scale with
true nnz (the paper's §V claim carried into the grid). The CSR row map
(``row_id``) is scalar-prefetched into SMEM and drives both the output
BlockSpec ``index_map`` and the accumulator lifecycle:

  * a step whose ``row_id`` differs from the previous step's opens a new
    output row-block → re-init the VMEM accumulator;
  * a step whose ``row_id`` differs from the *next* step's closes the
    row → apply the (optional) fused ``max(acc + bias, 0)`` epilogue and
    store; Pallas' revisiting machinery flushes the tile to HBM when the
    mapped output block changes.

Block-rows with no stored blocks are never visited; the host wrapper
(``repro.kernels.ops.bcsr_spmm``) fills them with the epilogue of the
semiring zero, matching the oracle's masked semantics.

Semirings: the full ``core/semiring.py`` registry — ``plus_times`` on
the MXU, everything else on the VPU via the registry-derived dispatch
in ``repro.kernels.semirings`` (⊕-identity accumulator init on every
row *open*, so the flush-on-row-change protocol is correct for
non-additive monoids; invalid slots are skipped before they can touch
the accumulator, which is what annihilator-aware padding means here).

Autodiff: this module is the primal only. The ``plus_times`` form is
made differentiable by the ``jax.custom_vjp`` rule in
``repro.kernels.autodiff`` (attached at the ``repro.kernels.ops``
wrapper); notably its backward dX = Wᵀ·dY re-enters THIS kernel on the
device-side ``BlockCSRMatrix.transpose()`` (fully jittable — static
``total_blocks``), so the backward pass also runs on the
occupancy-exact grid. The weight cotangent lands only on stored blocks
(invalid tail slots exactly zero). See docs/kernels.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import DEFAULT_BLOCK_N, _compat

from repro.kernels.semirings import accumulate_tile, kernel_semiring
from repro.sparse.bcsr import BlockCSRMatrix

Array = jax.Array


def grid_steps(a: BlockCSRMatrix, n: int, block_n: int = DEFAULT_BLOCK_N) -> int:
    """Grid steps this kernel executes — ∝ stored blocks, not the ELL pad."""
    return a.total_blocks * -(-n // block_n)


def _kernel(
    row_id_ref,  # scalar-prefetch (T,) int32
    col_idx_ref,  # scalar-prefetch (T,) int32 (drives the B BlockSpec)
    valid_ref,  # scalar-prefetch (T,) int32
    values_ref,  # (1, bs_r, bs_c)
    b_ref,  # (bs_c, bn)
    bias_ref,  # (bs_r, 1)
    o_ref,  # (bs_r, bn)
    acc_ref,  # VMEM scratch (bs_r, bn) f32
    *,
    semiring_name: str,
    t_steps: int,
    fuse_bias_relu: bool,
):
    spec = kernel_semiring(semiring_name)
    t = pl.program_id(1)
    row = row_id_ref[t]
    prev_row = row_id_ref[jnp.maximum(t - 1, 0)]
    next_row = row_id_ref[jnp.minimum(t + 1, t_steps - 1)]
    row_opens = (t == 0) | (row != prev_row)
    row_closes = (t == t_steps - 1) | (row != next_row)

    @pl.when(row_opens)
    def _init():
        # ⊕-identity init on every row OPEN — the flush-on-row-change
        # lifecycle stays correct for non-additive monoids because a
        # fresh row never sees another row's partial.
        acc_ref[...] = jnp.full_like(acc_ref, spec.init)

    @pl.when(valid_ref[t] != 0)
    def _accumulate():
        # invalid tail slots never reach the accumulator: skipped work
        # contributes exactly the ⊕-identity (annihilator-aware padding)
        a = values_ref[0].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        acc_ref[...] = accumulate_tile(spec, a, b, acc_ref[...])

    @pl.when(row_closes)
    def _epilogue():
        acc = acc_ref[...]
        if fuse_bias_relu:
            acc = jnp.maximum(acc + bias_ref[...].astype(jnp.float32), 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def bcsr_spmm(
    a: BlockCSRMatrix,
    b: Array,
    *,
    semiring_name: str = "plus_times",
    bias: Array | None = None,
    fuse_bias_relu: bool = False,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
    out_dtype=None,
) -> Array:
    """C (m, n) = A ⊕.⊗ B for block-CSR A (m, k), dense B (k, n).

    Block-rows of A with zero stored blocks are left UNWRITTEN in the
    output — callers must mask them (``repro.kernels.ops.bcsr_spmm``
    does). n must divide ``block_n``.
    """
    m, k = a.shape
    assert b.shape[0] == k, (a.shape, b.shape)
    n = b.shape[1]
    bs_r, bs_c = a.block_shape
    t_steps = a.total_blocks
    assert n % block_n == 0, (n, block_n)
    if fuse_bias_relu and bias is None:
        raise ValueError("fuse_bias_relu requires bias")
    kernel_semiring(semiring_name)  # fail fast on unknown semirings
    if bias is None:
        bias = jnp.zeros((m,), jnp.float32)
    bias2d = bias[:, None]
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)

    kernel = functools.partial(
        _kernel,
        semiring_name=semiring_name,
        t_steps=t_steps,
        fuse_bias_relu=fuse_bias_relu,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        # j outer / t inner: each output column stripe walks the stored
        # blocks once, in CSR order, flushing on row change.
        grid=(n // block_n, t_steps),
        in_specs=[
            # stored block t
            pl.BlockSpec((1, bs_r, bs_c), lambda j, t, ri, ci, vd: (t, 0, 0)),
            # B panel selected by the prefetched block-column index
            pl.BlockSpec((bs_c, block_n), lambda j, t, ri, ci, vd: (ci[t], j)),
            # bias row-tile of the block's row
            pl.BlockSpec((bs_r, 1), lambda j, t, ri, ci, vd: (ri[t], 0)),
        ],
        out_specs=pl.BlockSpec(
            (bs_r, block_n), lambda j, t, ri, ci, vd: (ri[t], j)
        ),
        scratch_shapes=[pltpu.VMEM((bs_r, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(
        a.row_id,
        a.col_idx,
        a.valid.astype(jnp.int32),
        a.values,
        b,
        bias2d,
    )
