"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.semiring import get_semiring
from repro.sparse import ops as sparse_ops
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array


def semiring_matmul_ref(
    a: Array,
    b: Array,
    *,
    semiring_name: str = "plus_times",
    bias: Array | None = None,
    fuse_bias_relu: bool = False,
) -> Array:
    sr = get_semiring(semiring_name)
    out = sr.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    if fuse_bias_relu:
        out = jnp.maximum(out + bias.astype(jnp.float32)[:, None], 0.0)
    return out


def bsr_spmm_ref(
    a: BlockSparseMatrix,
    b: Array,
    *,
    semiring_name: str = "plus_times",
    bias: Array | None = None,
    fuse_bias_relu: bool = False,
) -> Array:
    sr = get_semiring(semiring_name)
    out = sparse_ops.bsr_matmul(a.astype(jnp.float32), b.astype(jnp.float32), sr)
    if fuse_bias_relu:
        out = jnp.maximum(out + bias.astype(jnp.float32)[:, None], 0.0)
    return out


def bcsr_spmm_ref(
    a: BlockCSRMatrix,
    b: Array,
    *,
    semiring_name: str = "plus_times",
    bias: Array | None = None,
    fuse_bias_relu: bool = False,
) -> Array:
    sr = get_semiring(semiring_name)
    out = sparse_ops.bcsr_matmul(
        a.astype(jnp.float32), b.astype(jnp.float32), sr
    )
    if fuse_bias_relu:
        out = jnp.maximum(out + bias.astype(jnp.float32)[:, None], 0.0)
    return out


def fused_mlp_forward_ref(
    stacked_w: BlockSparseMatrix,
    stacked_b: Array,
    y0: Array,
) -> Array:
    """Layer-by-layer reference for the VMEM-resident fused forward."""
    n_layers = stacked_b.shape[0]
    y = y0.astype(jnp.float32)
    for l in range(n_layers):
        w_l = BlockSparseMatrix(
            stacked_w.blocks[l].astype(jnp.float32),
            stacked_w.col_idx[l],
            stacked_w.block_mask[l],
            stacked_w.shape,
            stacked_w.block_shape,
        )
        y = sparse_ops.bsr_matmul_fused_relu(
            w_l, y, stacked_b[l].astype(jnp.float32)
        )
    return y
