"""Version compatibility for the Pallas TPU API surface we use.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer jax releases; resolve whichever this installation provides once so
every kernel file stays version-agnostic.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
