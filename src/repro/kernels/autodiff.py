"""Custom VJP rules that make the sparse Pallas kernels trainable.

The paper's equations (y = h(Wᵀx + b)) cover training as well as
inference, and the Graph Challenge studies (arXiv:1909.05631,
arXiv:2004.01181) show sparse-times-dense products dominate BOTH passes.
This module closes the loop: ``jax.custom_vjp`` rules for the two SpMM
kernels so ``jax.grad`` / ``jax.value_and_grad`` flow through them with
**no densification anywhere**:

  primal      Z = A ⊕.⊗ B (+ fused ``max(Z + b·1ᵀ, 0)`` epilogue)
  dB  (dense) = Aᵀ · dZ          — occupancy-exact transpose product
  dA  (sparse) at stored block positions ONLY:
                dA[blk] = dZ_row(blk) · Bᵀ_col(blk)
                (the sampled/SDDMM-style product; same ELL or CSR layout
                 as the primal, padded/invalid slots exactly zero)
  db  (bias)  = Σₙ dZ  (masked by the ReLU when the epilogue is fused)

Backward-pass routing:

  * ``bcsr`` — dB runs through the **Pallas CSR kernel itself** on the
    block-CSR transpose (fully jittable because ``total_blocks`` is
    static), so the backward hot path is kernel-resident like the
    forward. dA uses the jnp sampled product
    (``sparse.ops.bcsr_weight_cotangent``). The transpose's argsort is
    the only per-call analysis left: pass a cached
    :class:`~repro.sparse.bcsr.BcsrTransposePlan` (built once per
    topology by ``repro.plan`` / ``BlockCSRMatrix.transpose_plan``) and
    the backward re-sorts NOTHING — it gathers fresh values through the
    cached permutation instead.
  * ``bsr/ELL`` — the ELL transpose needs a static output pad width that
    a traced weight cannot provide, so dB uses the occupancy-exact
    scatter-⊕ (``sparse.ops.bsr_transpose_matmul``) and dA the sampled
    product; both scale with stored blocks, neither densifies.
  * ``fused_mlp`` — the VMEM-resident multi-layer kernel has NO VJP (its
    per-layer activations never exist outside VMEM, so nothing can be
    checkpointed); its rule raises with a pointer to the layered path.
    ``serve.SparseDNNEngine(differentiable=True)`` routes around it.

Only the arithmetic (``plus_times``) semiring is differentiable — ReLU
is the fused max-plus step and its subgradient is handled here; the
exotic semirings keep the primal-only kernel path
(``repro.kernels.ops`` dispatches).

Cotangent structure: the sparse weight's cotangent is a
:class:`BlockSparseMatrix` / :class:`BlockCSRMatrix` whose float leaves
carry the gradient and whose integer/bool topology leaves carry the
``float0`` zeros JAX expects for non-differentiable leaves — optimizers
that guard on param dtype (``repro.train.optimizer``) consume it as-is.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes as jax_dtypes

from repro.kernels import DEFAULT_BLOCK_N
from repro.kernels import bcsr_spmm as _bcsr
from repro.kernels import bsr_spmm as _bsr
from repro.kernels import fused_mlp as _fmlp
from repro.sparse import ops as sparse_ops
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array


class SpmmConfig(NamedTuple):
    """Static (hashable) kernel-call configuration threaded through the
    custom_vjp as a nondiff argument."""

    fuse_bias_relu: bool
    block_n: int = DEFAULT_BLOCK_N
    interpret: bool = False


def _float0_zeros(x) -> np.ndarray:
    """The cotangent JAX expects for integer/bool primal leaves."""
    return np.zeros(np.shape(x), jax_dtypes.float0)


def _relu_mask_and_bias_grad(cfg: SpmmConfig, out: Array, g: Array, bias):
    """Shared epilogue backward: push g through the fused max(·+b, 0)."""
    g = g.astype(jnp.float32)
    if cfg.fuse_bias_relu:
        dz = jnp.where(out > 0, g, 0.0)
        dbias = jnp.sum(dz, axis=1).astype(bias.dtype)
    else:
        dz = g
        dbias = jnp.zeros_like(bias)
    return dz, dbias


# --------------------------------------------------------------------------
# ELL-padded BSR kernel
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def bsr_spmm_diff(cfg: SpmmConfig, a: BlockSparseMatrix, b: Array, bias: Array):
    """Differentiable ``bsr_spmm`` (plus_times). ``b.shape[1]`` must be a
    multiple of ``cfg.block_n`` (the jit wrapper in ``kernels.ops`` pads)."""
    return _bsr.bsr_spmm(
        a,
        b,
        semiring_name="plus_times",
        bias=bias,
        fuse_bias_relu=cfg.fuse_bias_relu,
        block_n=cfg.block_n,
        interpret=cfg.interpret,
    )


def _bsr_fwd(cfg, a, b, bias):
    out = bsr_spmm_diff(cfg, a, b, bias)
    return out, (a, b, bias, out)


def _bsr_bwd(cfg, res, g):
    a, b, bias, out = res
    dz, dbias = _relu_mask_and_bias_grad(cfg, out, g, bias)
    # dB = Aᵀ·dZ — occupancy-exact scatter-⊕ (the ELL transpose's pad
    # width is data-dependent, so the jnp path is the jittable one here).
    db = sparse_ops.bsr_transpose_matmul(a, dz).astype(b.dtype)
    # dA only at stored positions — primal's sparsity pattern preserved.
    dblocks = sparse_ops.bsr_weight_cotangent(a, dz, b).astype(a.blocks.dtype)
    da = BlockSparseMatrix(
        dblocks,
        _float0_zeros(a.col_idx),
        _float0_zeros(a.block_mask),
        a.shape,
        a.block_shape,
    )
    return da, db, dbias


bsr_spmm_diff.defvjp(_bsr_fwd, _bsr_bwd)


# --------------------------------------------------------------------------
# Occupancy-exact block-CSR kernel
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def bcsr_spmm_diff(
    cfg: SpmmConfig,
    a: BlockCSRMatrix,
    b: Array,
    bias: Array,
    transpose_plan=None,
):
    """Differentiable ``bcsr_spmm`` (plus_times). Same raw-kernel caveat
    as the primal: empty block-rows are left unwritten — the ``kernels.
    ops`` wrapper splices the fill in OUTSIDE this rule (so upstream
    cotangents for empty rows arrive here already zeroed by the
    ``where``'s own VJP, and the garbage rows can never leak).

    ``transpose_plan`` (a :class:`~repro.sparse.bcsr.BcsrTransposePlan`
    or None) only feeds the backward pass: with it, dB's transpose is a
    gather through the cached permutation; without it, every backward
    re-sorts the (frozen) topology."""
    del transpose_plan  # primal never needs it
    return _bcsr.bcsr_spmm(
        a,
        b,
        semiring_name="plus_times",
        bias=bias,
        fuse_bias_relu=cfg.fuse_bias_relu,
        block_n=cfg.block_n,
        interpret=cfg.interpret,
    )


def _bcsr_fwd(cfg, a, b, bias, transpose_plan):
    out = bcsr_spmm_diff(cfg, a, b, bias, transpose_plan)
    return out, (a, b, bias, out, transpose_plan)


def _bcsr_bwd(cfg, res, g):
    a, b, bias, out, tp = res
    dz, dbias = _relu_mask_and_bias_grad(cfg, out, g, bias)
    # dB = Aᵀ·dZ through the Pallas kernel itself: the block-CSR
    # transpose is fully jittable (static total_blocks), so the backward
    # pass stays on the occupancy-exact kernel grid (∝ true nnz). With a
    # cached plan the per-call argsort disappears entirely — the frozen
    # topology was sorted once, at plan-build time.
    at = a.transpose() if tp is None else tp.apply(a)
    db_raw = _bcsr.bcsr_spmm(
        at,
        dz,
        semiring_name="plus_times",
        block_n=cfg.block_n,
        interpret=cfg.interpret,
    )
    # Rows of Aᵀ with no stored blocks (= empty columns of A) are never
    # visited by the kernel grid → their dB rows are identically zero.
    empty_t = (at.row_ptr[1:] == at.row_ptr[:-1])
    row_empty = jnp.repeat(
        empty_t, at.block_shape[0], total_repeat_length=at.shape[0]
    )
    db = jnp.where(row_empty[:, None], 0.0, db_raw).astype(b.dtype)
    # dA: sampled products at the stored blocks, CSR order preserved.
    dvalues = sparse_ops.bcsr_weight_cotangent(a, dz, b).astype(a.values.dtype)
    da = BlockCSRMatrix(
        dvalues,
        _float0_zeros(a.row_ptr),
        _float0_zeros(a.row_id),
        _float0_zeros(a.col_idx),
        _float0_zeros(a.valid),
        a.shape,
        a.block_shape,
    )
    # The plan is pure frozen topology (int/bool leaves) — its cotangent
    # is the float0 pytree JAX expects for non-differentiable leaves.
    dtp = None
    if tp is not None:
        from repro.sparse.bcsr import BcsrTransposePlan

        dtp = BcsrTransposePlan(
            _float0_zeros(tp.order),
            _float0_zeros(tp.row_ptr),
            _float0_zeros(tp.row_id),
            _float0_zeros(tp.col_idx),
            _float0_zeros(tp.valid),
            tp.shape,
            tp.block_shape,
        )
    return da, db, dbias, dtp


bcsr_spmm_diff.defvjp(_bcsr_fwd, _bcsr_bwd)


# --------------------------------------------------------------------------
# VMEM-resident fused multi-layer forward: explicitly NOT differentiable
# --------------------------------------------------------------------------


class FusedMlpConfig(NamedTuple):
    block_n: int = DEFAULT_BLOCK_N
    interpret: bool = False
    # activation-panel dtype name ("bfloat16" halves the resident VMEM
    # footprint; accumulation stays f32) — None keeps float32 panels
    panel_dtype: str | None = None


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_mlp_forward_nondiff(
    cfg: FusedMlpConfig, stacked_w: BlockSparseMatrix, stacked_b: Array, y0: Array
):
    """The fused kernel with a VJP rule that fails loudly (instead of the
    opaque pallas_call transpose error) and says what to use instead."""
    return _fmlp.fused_mlp_forward(
        stacked_w,
        stacked_b,
        y0,
        block_n=cfg.block_n,
        interpret=cfg.interpret,
        panel_dtype=cfg.panel_dtype,
    )


def _fused_fwd(cfg, stacked_w, stacked_b, y0):
    return fused_mlp_forward_nondiff(cfg, stacked_w, stacked_b, y0), None


def _fused_bwd(cfg, res, g):
    raise NotImplementedError(
        "fused_mlp_forward has no VJP: the VMEM-resident kernel never "
        "materializes per-layer activations, so there is nothing to "
        "checkpoint for the backward pass. Differentiate the layered "
        "kernel path instead (repro.core.dnn.dnn_forward_trainable, or "
        "serve.SparseDNNEngine(differentiable=True) which routes around "
        "the fused path automatically)."
    )


fused_mlp_forward_nondiff.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_mlp_tiled_forward_nondiff(
    cfg: FusedMlpConfig, stacked_w: BlockSparseMatrix, stacked_b: Array, y0: Array
):
    """The tiled fused kernel (HBM ping-pong panel) with the same
    fails-loudly VJP story as the resident kernel: per-layer activations
    only ever exist in the kernel's scratch buffers."""
    return _fmlp.fused_mlp_tiled_forward(
        stacked_w,
        stacked_b,
        y0,
        block_n=cfg.block_n,
        interpret=cfg.interpret,
        panel_dtype=cfg.panel_dtype,
    )


def _fused_tiled_fwd(cfg, stacked_w, stacked_b, y0):
    return fused_mlp_tiled_forward_nondiff(cfg, stacked_w, stacked_b, y0), None


def _fused_tiled_bwd(cfg, res, g):
    raise NotImplementedError(
        "fused_mlp_tiled_forward has no VJP: per-layer activations only "
        "exist in the kernel's HBM/VMEM scratch, so there is nothing to "
        "checkpoint for the backward pass. Differentiate the layered "
        "kernel path instead (repro.core.dnn.dnn_forward_trainable, or "
        "serve.SparseDNNEngine(differentiable=True) which routes around "
        "the fused paths automatically)."
    )


fused_mlp_tiled_forward_nondiff.defvjp(_fused_tiled_fwd, _fused_tiled_bwd)
