"""Registry-derived semiring dispatch for the Pallas kernels.

The kernels compute in f32 VMEM tiles, so every registry semiring
(``repro.core.semiring.REGISTRY``) is lowered here to an f32-space
:class:`KernelSemiring`: the per-block ⊗-product, the binary ⊕ that
merges chunk/block partials into the accumulator, the axis form of ⊕
for the chunked VPU broadcast, and the accumulator init — which is the
⊕-identity AND the ⊗-annihilator (one value, by the semiring axioms),
so it doubles as the fill for k-padding and empty-row splices.

This module is the ONE place kernel semantics are derived from the
`Semiring` objects: adding a semiring to ``core/semiring.py`` whose
⊕/⊗ are drawn from the op translation tables below makes it available
to the dense, ELL, and block-CSR kernels with no kernel edits
(previously each kernel carried its own ``_VPU_SEMIRINGS`` copy of
⊕/⊗/identity).

Boolean semirings (lor_land, xor_and) run in the {0.0, 1.0} ⊂ f32
encoding: ⊗ canonicalises both operands through ``!= 0`` so arbitrary
float inputs behave like their truth values, ⊕ stays exact on {0, 1}
(max for ∨, sum-mod-2 for ⊻). The kernel output is the f32 encoding of
the boolean result — compare against ``Semiring.matmul`` after an
``astype(float32)`` of its bool output.

``plus_times`` is the only MXU semiring (``jnp.dot``); everything else
takes the chunked VPU broadcast (``vpu_tile_product``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import semiring as _core

Array = jax.Array

# k-slab for the chunked VPU tile product: the (bm, chunk, bn) broadcast
# working set stays ≪ VMEM at 8 sublanes.
K_CHUNK = 8


@dataclasses.dataclass(frozen=True)
class KernelSemiring:
    """One registry semiring lowered to f32 kernel ops.

    ``init`` is the ⊕-identity == ⊗-annihilator in the f32 encoding:
    the accumulator init value, the k-padding fill, and the empty-row
    splice value, all at once.
    """

    name: str
    mul: Callable[[Array, Array], Array]  # elementwise ⊗, f32-space
    add: Callable[[Array, Array], Array]  # binary ⊕ (accumulator merge)
    add_reduce: Callable[[Array, int], Array]  # ⊕ along one axis
    init: float  # ⊕-identity / ⊗-annihilator
    mxu: bool  # True only for plus_times (jnp.dot path)

    def __hash__(self) -> int:
        return hash(self.name)


# --- f32 encodings of the boolean ops ------------------------------------


def _f32_and(a: Array, b: Array) -> Array:
    return jnp.logical_and(a != 0, b != 0).astype(jnp.float32)


def _f32_or(a: Array, b: Array) -> Array:
    # exact ∨ on the {0, 1} encoding (⊗ above canonicalises inputs)
    return jnp.maximum(a, b)


def _f32_xor(a: Array, b: Array) -> Array:
    return jnp.logical_xor(a != 0, b != 0).astype(jnp.float32)


def _f32_xor_reduce(x: Array, axis: int) -> Array:
    # parity: sums of {0, 1} f32 are exact far beyond any tile width
    return jnp.mod(jnp.sum(x, axis=axis), 2.0)


def _logaddexp_reduce(x: Array, axis: int) -> Array:
    return jax.nn.logsumexp(x, axis=axis)


# --- op translation: core-registry callables → f32 kernel ops ------------
# Keyed by the IDENTITY of the ops the `Semiring` objects carry, so the
# lowering reads ⊕/⊗/zero straight off the registry entry.

_MUL_F32: dict[Callable, Callable[[Array, Array], Array]] = {
    jnp.multiply: jnp.multiply,
    jnp.add: jnp.add,
    jnp.minimum: jnp.minimum,
    jnp.maximum: jnp.maximum,
    jnp.logical_and: _f32_and,
}

# ⊕ → (binary merge, axis reduce — called as fn(x, axis))
_ADD_F32: dict[Callable, tuple[Callable, Callable]] = {
    jnp.add: (jnp.add, jnp.sum),
    jnp.maximum: (jnp.maximum, jnp.max),
    jnp.minimum: (jnp.minimum, jnp.min),
    jnp.logical_or: (_f32_or, jnp.max),
    jnp.logical_xor: (_f32_xor, _f32_xor_reduce),
    jnp.logaddexp: (jnp.logaddexp, _logaddexp_reduce),
}


def _lower(sr: _core.Semiring) -> KernelSemiring:
    try:
        mul = _MUL_F32[sr.mul]
        add, add_reduce = _ADD_F32[sr.add]
    except KeyError as e:
        raise NotImplementedError(
            f"semiring {sr.name!r} uses ops with no f32 kernel lowering; "
            f"register them in repro.kernels.semirings"
        ) from e
    return KernelSemiring(
        name=sr.name,
        mul=mul,
        add=add,
        add_reduce=add_reduce,
        init=float(sr.zero),
        mxu=(sr.name == "plus_times"),
    )


@functools.cache
def kernel_semiring(name: str) -> KernelSemiring:
    """The f32 kernel lowering of registry semiring ``name``.

    Raises ``KeyError`` for names not in the core registry — the kernels
    support exactly what ``core/semiring.py`` defines, by construction.
    """
    return _lower(_core.get_semiring(name))


def kernel_zero(name: str) -> float:
    """⊕-identity / ⊗-annihilator fill for ``name`` (f32 encoding)."""
    return kernel_semiring(name).init


def supported() -> tuple[str, ...]:
    """Every semiring the kernels speak — the whole core registry."""
    return tuple(sorted(_core.REGISTRY))


def vpu_tile_product(
    spec: KernelSemiring, a: Array, b: Array, acc: Array
) -> Array:
    """acc ⊕= A_tile ⊗-contract B_tile on the VPU, k chunked by K_CHUNK.

    a: (bm, bk); b: (bk, bn); acc: (bm, bn) — bk must divide K_CHUNK.
    Each chunk broadcasts to (bm, chunk, bn), ⊕-reduces its own k slab,
    then ⊕-merges into the accumulator; both steps use the semiring's
    exact f32 ops, so any k association gives the same result for the
    order-independent monoids (max/min/or/xor).
    """
    bk = a.shape[1]
    n_chunks = bk // K_CHUNK

    def body(c, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, c * K_CHUNK, K_CHUNK, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, c * K_CHUNK, K_CHUNK, axis=0)
        prod = spec.mul(a_c[:, :, None], b_c[None, :, :])  # (bm, chunk, bn)
        return spec.add(acc, spec.add_reduce(prod, 1))

    return jax.lax.fori_loop(0, n_chunks, body, acc)


def accumulate_tile(
    spec: KernelSemiring, a: Array, b: Array, acc: Array
) -> Array:
    """One kernel accumulation step: MXU dot for plus_times, chunked VPU
    broadcast for everything else. The shared inner reduce of all three
    kernels (dense / ELL / block-CSR)."""
    if spec.mxu:
        return acc + jnp.dot(a, b, preferred_element_type=jnp.float32)
    return vpu_tile_product(spec, a, b, acc)
