"""Pallas TPU kernels + jit'd wrappers (``ops``) and jnp oracles (``ref``).

Kernel menu and when dispatch picks which (see ``repro.core.dnn``):

  semiring_matmul — dense ⊕.⊗ with fused bias/ReLU epilogue; the BLAS
      arm and the fallback for weights with no sparse structure.
  bsr_spmm        — ELL-padded BSR × dense. Grid ``(nrb, n_tiles,
      max_blocks_per_row)``: best for *regular* topologies where every
      block-row stores ≈ the same number of blocks.
  bcsr_spmm       — occupancy-exact block-CSR × dense. Grid ``(n_tiles,
      total_nnz_blocks)``: compute and DMA scale with true nnz, the
      right arm for skewed or magnitude-pruned topologies.
  fused_mlp       — VMEM-resident multi-layer forward for square
      ``stack_bsr`` stacks: one ``pallas_call`` for all L layers, no
      inter-layer HBM activation traffic. Forward-only (no VJP).

``autodiff`` holds the ``jax.custom_vjp`` rules that make the two SpMM
wrappers trainable (sparse-preserving weight cotangents, kernel-
resident backward for the CSR layout); ``ops`` attaches them for the
``plus_times`` semiring. See docs/kernels.md for the full contract.
"""

# The hand-picked column-tile width every kernel defaults to. ONE
# definition so the autotuner (``repro.tune``) overrides it in a single
# place; defined BEFORE the submodule imports so they can pull it from
# the (partially initialised) package during their own import.
DEFAULT_BLOCK_N = 128

from repro.kernels import autodiff, ops, ref  # noqa: E402

__all__ = ["DEFAULT_BLOCK_N", "autodiff", "ops", "ref"]
