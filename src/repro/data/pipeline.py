"""Deterministic synthetic data pipeline with per-host sharding and
double-buffered prefetch (DESIGN.md §6).

The stream is a pure function of (seed, step, host slice): restart-safe
with no loader checkpoint, and any host can recompute any shard — the
property the fault-tolerance and elastic-scaling stories rely on.

The synthetic LM task is *learnable* (tokens follow a noisy modular-affine
recurrence x_{t+1} = (a·x_t + b + ε) mod V), so example training runs show
a real loss drop rather than flat noise — the end-to-end driver uses it.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05
    input_mode: str = "tokens"  # "tokens" | "embeddings" | "features"
    d_model: int = 0  # for embeddings/features modes
    # per-host sharding
    host_id: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def _rng(self, step: int) -> np.random.Generator:
        # Philox keyed on (seed, step, host): deterministic, splittable.
        return np.random.Generator(
            np.random.Philox(key=self.seed, counter=[0, 0, step, self.host_id])
        )

    def batch(self, step: int) -> dict[str, Any]:
        rng = self._rng(step)
        b, s, v = self.host_batch, self.seq_len, self.vocab_size
        if self.input_mode == "embeddings":
            x = rng.standard_normal((b, s, self.d_model), dtype=np.float32)
            labels = rng.integers(0, v, (b, s), dtype=np.int64)
            return {"inputs": x, "labels": labels.astype(np.int32)}
        if self.input_mode == "features":
            x = rng.random((b, self.d_model), dtype=np.float32)
            labels = rng.integers(0, v, (b,), dtype=np.int64)
            return {"inputs": x, "labels": labels.astype(np.int32)}
        a = 6364136223846793005 % v | 1
        c = 1442695040888963407 % v
        toks = np.empty((b, s + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, v, b)
        noise_mask = rng.random((b, s)) < self.noise
        noise_tok = rng.integers(0, v, (b, s))
        for t in range(s):
            nxt = (a * toks[:, t] + c) % v
            toks[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {
            "inputs": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict[str, Any]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Double-buffered background prefetch: overlaps host-side batch
    synthesis (or, in deployment, storage reads) with device compute."""

    def __init__(self, source: SyntheticLM, *, depth: int = 2, start_step: int = 0):
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict[str, Any]]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
