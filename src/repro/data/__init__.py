from repro.data.pipeline import SyntheticLM, Prefetcher  # noqa: F401
from repro.data.radixnet import (  # noqa: F401
    CHALLENGE_BIAS,
    FAN_IN,
    WEIGHT_VALUE,
    RadixNetSpec,
    challenge_bias,
    conn_to_bsr,
    radixnet_connectivity,
    radixnet_input_panel,
    radixnet_reference,
    radixnet_weights,
    reference_categories,
    reference_forward,
)
