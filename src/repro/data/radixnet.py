"""Deterministic RadiX-net-style GraphChallenge topology generator.

The MIT/IEEE Sparse DNN GraphChallenge (arXiv 2004.01181) benchmarks
inference over synthetic deep ReLU nets whose layers are RadiX-net
mixed-radix Kronecker topologies (arXiv 1905.00416): every neuron has
EXACTLY ``fan_in = 32`` inbound edges, all weights are 1/16, and each
network size carries a fixed bias constant. This module reproduces that
workload shape deterministically — no downloads, no RNG in the topology
— so the conformance suite (`tests/test_challenge.py`) can pin
ground-truth categories.

Topology. For ``n = 32**k * q`` neurons (``q`` a power of two < 32) the
generator cycles layers through ``k`` radix-32 butterfly phases plus, when
``q > 1``, one mixed radix-``q`` ⊗ radix-``32/q`` phase:

* phase ``t < k`` connects row ``r`` to the 32 columns that differ from
  ``r`` only in base-32 digit ``t`` (stride ``32**t`` butterfly);
* the mixed phase replaces the top radix-``q`` digit (stride ``32**k``)
  AND the low ``32/q`` remainder jointly — ``q · 32/q = 32`` edges.

Layer ``l`` uses phase ``l mod num_phases``, so any window of
``num_phases`` consecutive layers composes to a full Kronecker mixing of
all ``n`` coordinates — the RadiX-net "all inputs reach all outputs"
property.

Reference semantics. ``reference_forward`` is the pure-numpy oracle:
``Y ← max(Wᵀ-gather(Y)·(1/16) + bias, 0)`` per layer, computed by index
gather (never densified). Because 1/16 is a power of two and the seeded
input panel is {0, 1}-valued, the first layer is EXACT in float32 under
any summation order; deeper layers differ between execution paths only
at ulp order, which the fixed-seed conformance configs keep away from
the category threshold. NOTE the official challenge additionally clamps
activations at ``YMAX = 32``; this repo's engine semantics are plain
ReLU throughout, so the generator deliberately omits the clamp (see
``docs/benchmarks.md``) — categories here are defined against the same
un-clamped reference every execution path implements.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

FAN_IN = 32
WEIGHT_VALUE = 1.0 / 16.0  # exact in binary floating point

# The GraphChallenge per-size bias constants (arXiv 2004.01181 table 1).
CHALLENGE_BIAS = {
    1024: -0.3,
    4096: -0.35,
    16384: -0.4,
    65536: -0.45,
}


def challenge_bias(neurons: int) -> float:
    """The official bias for a challenge size, else the nearest smaller
    size's constant (small test configs reuse the 1024-neuron bias)."""
    if neurons in CHALLENGE_BIAS:
        return CHALLENGE_BIAS[neurons]
    smaller = [n for n in sorted(CHALLENGE_BIAS) if n <= neurons]
    return CHALLENGE_BIAS[smaller[-1]] if smaller else CHALLENGE_BIAS[1024]


def _factor(neurons: int) -> tuple[int, int]:
    """``neurons = 32**k * q`` with q a power of two in [1, 32)."""
    if neurons < FAN_IN or neurons & (neurons - 1):
        raise ValueError(
            f"RadiX-net sizes must be powers of two >= {FAN_IN}; got "
            f"{neurons}"
        )
    k, rest = 0, neurons
    while rest % FAN_IN == 0:
        k += 1
        rest //= FAN_IN
    return k, rest


def num_phases(neurons: int) -> int:
    k, q = _factor(neurons)
    return k + (1 if q > 1 else 0)


@dataclasses.dataclass(frozen=True)
class RadixNetSpec:
    """One challenge configuration: ``neurons × layers`` at the official
    bias, fan-in 32, weight 1/16."""

    neurons: int
    layers: int
    bias: float = None  # type: ignore[assignment]  # None → official constant

    def __post_init__(self):
        _factor(self.neurons)  # validate
        if self.layers < 1:
            raise ValueError("layers must be >= 1")
        if self.bias is None:
            object.__setattr__(self, "bias", challenge_bias(self.neurons))

    @property
    def edges(self) -> int:
        """Stored nonzeros of the whole net — the challenge's work unit."""
        return self.layers * self.neurons * FAN_IN

    def connectivity(self, layer: int) -> np.ndarray:
        return radixnet_connectivity(self.neurons, layer)


def radixnet_connectivity(neurons: int, layer: int) -> np.ndarray:
    """The (neurons, 32) int32 column indices of layer ``layer``.

    Row ``r`` of the layer's weight matrix has exactly these 32 nonzero
    columns (all valued 1/16). Deterministic — a pure function of
    (neurons, layer).
    """
    k, q = _factor(neurons)
    phase = layer % num_phases(neurons)
    r = np.arange(neurons, dtype=np.int64)[:, None]
    if phase < k:
        # radix-32 butterfly on base-32 digit `phase` (stride 32**phase)
        stride = FAN_IN**phase
        digit = (r // stride) % FAN_IN
        base = r - digit * stride
        cols = base + np.arange(FAN_IN, dtype=np.int64)[None, :] * stride
    else:
        # mixed phase: top radix-q digit (stride 32**k) ⊗ low 32/q bits
        stride = FAN_IN**k
        g = FAN_IN // q
        digit = (r // stride) % q
        base = r - digit * stride - r % g
        hi = np.arange(q, dtype=np.int64)[:, None] * stride  # (q, 1)
        lo = np.arange(g, dtype=np.int64)[None, :]  # (1, 32/q)
        cols = base + (hi + lo).reshape(1, FAN_IN)
    return cols.astype(np.int32)


def radixnet_input_panel(
    neurons: int, n_inputs: int, *, density: float = 0.3, seed: int = 0
) -> np.ndarray:
    """Seeded sparse {0, 1} float32 input panel, shape (neurons, n_inputs).

    Columns are inputs (the challenge's 60 000 MNIST-derived rows live
    here transposed — this repo's activation panels are column-major
    batches). Philox-keyed: a pure function of (neurons, n_inputs,
    density, seed).
    """
    rng = np.random.Generator(
        np.random.Philox(key=seed, counter=[0, 0, neurons, n_inputs])
    )
    panel = rng.random((neurons, n_inputs), dtype=np.float32) < density
    return panel.astype(np.float32)


# ---------------------------------------------------------------------
# Pure-numpy reference inference (the conformance ground truth)
# ---------------------------------------------------------------------


def reference_forward(
    conns: Sequence[np.ndarray],
    biases: Sequence[float],
    y0: np.ndarray,
) -> np.ndarray:
    """Gather-based reference: per layer
    ``Y ← max((1/16)·Σ_{c∈conn[r]} Y[c] + bias, 0)``.

    Never densifies a weight matrix — ``y[conn]`` is an
    (neurons, 32, n_inputs) gather, summed over the fan-in axis. float32
    throughout to match the kernels' accumulate dtype.
    """
    y = np.asarray(y0, dtype=np.float32)
    w = np.float32(WEIGHT_VALUE)
    for conn, b in zip(conns, biases):
        z = (y[conn] * w).sum(axis=1, dtype=np.float32) + np.float32(b)
        y = np.maximum(z, np.float32(0.0))
    return y


def reference_categories(y_final: np.ndarray) -> np.ndarray:
    """The challenge's answer set: indices of inputs (panel columns) with
    any positive neuron in the final activation."""
    return np.flatnonzero(np.asarray(y_final).max(axis=0) > 0).astype(
        np.int64
    )


# ---------------------------------------------------------------------
# Connectivity → block-sparse weights (the engine-side representation)
# ---------------------------------------------------------------------


def conn_to_bsr(
    conn: np.ndarray,
    *,
    block_size: int = 16,
    pad_blocks_per_row: int | None = None,
    dtype=None,
):
    """Lower a (n, 32) connectivity to an ELL :class:`BlockSparseMatrix`.

    Every block-row's occupied column blocks become stored
    ``block_size²`` tiles holding 1/16 at the exact (row, col) positions
    of ``conn`` and 0 elsewhere. ``pad_blocks_per_row`` right-pads the
    ELL slot axis with masked-off blocks so layers of different phases
    can stack homogeneously (``stack_bsr`` and the fused kernels require
    one ``max_blocks_per_row`` across the stack).
    """
    import jax.numpy as jnp

    from repro.sparse.bsr import BlockSparseMatrix

    n = conn.shape[0]
    bs = block_size
    if n % bs:
        raise ValueError(f"neurons ({n}) must divide block_size ({bs})")
    nrb = n // bs
    block_cols = np.asarray(conn, dtype=np.int64) // bs  # (n, 32)
    per_row_blocks = block_cols.reshape(nrb, bs * FAN_IN)
    col_idx_rows = []
    for rb in range(nrb):
        col_idx_rows.append(np.unique(per_row_blocks[rb]))
    mbpr = max(len(c) for c in col_idx_rows)
    if pad_blocks_per_row is not None:
        if pad_blocks_per_row < mbpr:
            raise ValueError(
                f"pad_blocks_per_row={pad_blocks_per_row} < required "
                f"{mbpr}"
            )
        mbpr = pad_blocks_per_row
    col_idx = np.zeros((nrb, mbpr), dtype=np.int32)
    block_mask = np.zeros((nrb, mbpr), dtype=np.int32)
    blocks = np.zeros((nrb, mbpr, bs, bs), dtype=np.float32)
    rows = np.repeat(np.arange(n, dtype=np.int64), FAN_IN)
    cols = np.asarray(conn, dtype=np.int64).reshape(-1)
    for rb in range(nrb):
        occupied = col_idx_rows[rb]
        col_idx[rb, : len(occupied)] = occupied
        block_mask[rb, : len(occupied)] = 1
        # ELL slot of each stored entry in this block-row
        slot_of = {int(c): s for s, c in enumerate(occupied)}
        lo, hi = rb * bs * FAN_IN, (rb + 1) * bs * FAN_IN
        r_local = rows[lo:hi] - rb * bs
        c_global = cols[lo:hi]
        slots = np.fromiter(
            (slot_of[int(c // bs)] for c in c_global),
            dtype=np.int64,
            count=bs * FAN_IN,
        )
        blocks[rb, slots, r_local, c_global % bs] = WEIGHT_VALUE
    mat = BlockSparseMatrix(
        jnp.asarray(blocks, dtype=dtype or jnp.float32),
        jnp.asarray(col_idx),
        jnp.asarray(block_mask),
        (n, n),
        (bs, bs),
    )
    return mat


def radixnet_weights(
    spec: RadixNetSpec, *, block_size: int = 16, dtype=None
):
    """The spec's full homogeneous BSR stack + bias vectors.

    All layers share one ``max_blocks_per_row`` (the max over the spec's
    phases — butterfly phases past stride ``block_size`` store 32
    diagonal blocks, the stride-1 phase stores ``32/block_size`` dense
    ones), so the stack is eligible for the fused single-``pallas_call``
    routes.
    """
    import jax.numpy as jnp

    phases = num_phases(spec.neurons)
    phase_conns = [
        radixnet_connectivity(spec.neurons, p) for p in range(phases)
    ]
    phase_mats = {}
    mbpr = 0
    for p, conn in enumerate(phase_conns):
        m = conn_to_bsr(conn, block_size=block_size, dtype=dtype)
        phase_mats[p] = m
        mbpr = max(mbpr, m.max_blocks_per_row)
    for p, conn in enumerate(phase_conns):
        if phase_mats[p].max_blocks_per_row != mbpr:
            phase_mats[p] = conn_to_bsr(
                conn,
                block_size=block_size,
                pad_blocks_per_row=mbpr,
                dtype=dtype,
            )
    weights = [phase_mats[l % phases] for l in range(spec.layers)]
    bias = jnp.full((spec.neurons,), spec.bias, dtype=dtype or jnp.float32)
    biases = [bias] * spec.layers
    return weights, biases


def radixnet_reference(
    spec: RadixNetSpec, y0: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """(final activations, ground-truth categories) of the numpy oracle."""
    phases = num_phases(spec.neurons)
    phase_conns = [
        radixnet_connectivity(spec.neurons, p) for p in range(phases)
    ]
    conns = [phase_conns[l % phases] for l in range(spec.layers)]
    y = reference_forward(conns, [spec.bias] * spec.layers, y0)
    return y, reference_categories(y)
