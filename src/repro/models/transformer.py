"""Layer assembly + period-scanned stacking for every assigned arch.

A stack is ``head`` (unique leading layers) + ``n_periods`` repeats of the
``period`` pattern (executed under ``jax.lax.scan`` with per-position
stacked params) + ``tail``. One period traces once regardless of depth —
this keeps the HLO compact for 60-80 layer models and gives XLA a single
loop body whose weight all-gathers (FSDP) overlap with the previous
iteration's compute.

Three execution paths per layer, all cache-structure compatible:
  * ``apply_layer``   — training / no-cache forward; returns (x, aux_loss)
  * ``prefill_layer`` — forward that also fills the decode cache
  * ``decode_layer``  — single-token step against the cache

The paper's technique enters through ``ffn`` weights: any FFN projection
may be a :class:`BlockSparseMatrix` (see ``layers.linear`` dispatch and
``sparsify_stack``), and the ``relu_mlp`` layer kind *is* the paper's
Fig. 4 network (fused max-plus epilogue; the unfused paper-faithful
sequence lives in ``repro.core.dnn``).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.ad_checkpoint import checkpoint_name
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.distribution.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    apply_ffn,
    dense_init,
    init_ffn,
    init_rms_norm,
    linear,
    rms_norm,
    sparsify_ffn,
)

Array = jax.Array
Params = dict[str, Any]


# =============================== single layer ================================


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype=jnp.float32) -> Params:
    km, kf = jax.random.split(key)
    p: Params = {}
    d = cfg.d_model
    if spec.mixer == "attn":
        p["mixer_norm"] = init_rms_norm(d)
        p["mixer"] = attn.INIT[cfg.attention.kind](km, cfg.attention, d, dtype)
        if cfg.post_norms:
            p["mixer_post_norm"] = init_rms_norm(d)
    elif spec.mixer == "mamba":
        p["mixer_norm"] = init_rms_norm(d)
        p["mixer"] = ssm.init_mamba(km, d, cfg.mamba, dtype)
    elif spec.mixer == "rwkv":
        p["mixer_norm"] = init_rms_norm(d)
        p["mixer"] = ssm.init_rwkv_time_mix(km, d, cfg.rwkv, dtype)
    elif spec.mixer != "none":
        raise ValueError(f"unknown mixer {spec.mixer!r}")

    if spec.ffn == "dense":
        p["ffn_norm"] = init_rms_norm(d)
        p["ffn"] = init_ffn(kf, d, cfg.d_ff, cfg.glu, dtype)
        if cfg.post_norms:
            p["ffn_post_norm"] = init_rms_norm(d)
    elif spec.ffn == "moe":
        p["ffn_norm"] = init_rms_norm(d)
        p["ffn"] = moe_mod.init_moe(kf, d, cfg.moe, cfg.glu, dtype)
    elif spec.ffn == "rwkv_channel_mix":
        p["ffn_norm"] = init_rms_norm(d)
        p["ffn"] = ssm.init_rwkv_channel_mix(kf, d, cfg.d_ff, dtype)
    elif spec.ffn == "relu_mlp":
        # The paper's layer: square weight + bias, no norm, no residual.
        p["ffn"] = {
            "w": dense_init(kf, d, d, dtype),
            "b": jnp.zeros((d,), dtype),
        }
    elif spec.ffn != "none":
        raise ValueError(f"unknown ffn {spec.ffn!r}")
    return p


def _apply_mixer(p: Params, cfg: ModelConfig, spec: LayerSpec, x: Array) -> Array:
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    h = checkpoint_name(h, "norm_out")
    if spec.mixer == "attn":
        out = attn.APPLY[cfg.attention.kind](
            p["mixer"],
            cfg.attention,
            h,
            window=spec.window,
            rope_theta=spec.rope_theta,
        )
    elif spec.mixer == "mamba":
        out, _ = ssm.apply_mamba(p["mixer"], cfg.mamba, h)
    else:  # rwkv
        out, _ = ssm.apply_rwkv_time_mix(p["mixer"], cfg.rwkv, h)
    if cfg.post_norms:
        out = rms_norm(out, p["mixer_post_norm"], cfg.norm_eps)
    return x + out


def _apply_ffn_block(
    p: Params, cfg: ModelConfig, spec: LayerSpec, x: Array
) -> tuple[Array, Array]:
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "relu_mlp":
        # Paper layer (Fig. 4), fused: no norm/residual, max-plus epilogue.
        f = p["ffn"]
        return jnp.maximum(linear(f["w"], x) + f["b"], 0.0), aux
    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    h = checkpoint_name(h, "norm_out")
    if spec.ffn == "dense":
        out = apply_ffn(p["ffn"], h, cfg.act, cfg.glu)
    elif spec.ffn == "moe":
        out, aux = moe_mod.apply_moe(p["ffn"], cfg.moe, h, cfg.act, cfg.glu)
    else:  # rwkv_channel_mix
        out, _ = ssm.apply_rwkv_channel_mix(p["ffn"], h)
    if cfg.post_norms:
        out = rms_norm(out, p["ffn_post_norm"], cfg.norm_eps)
    return x + out, aux


def apply_layer(
    p: Params, cfg: ModelConfig, spec: LayerSpec, x: Array
) -> tuple[Array, Array]:
    """Full-sequence forward. Returns (x, moe_aux_loss)."""
    # pin the residual stream: batch over DP axes (+ optional sequence
    # parallelism via rules.seq_axis) — keeps GSPMD from drifting into
    # replicated activations across scan/remat boundaries.
    x = constrain(x, ("batch", "seq", None))
    if spec.mixer != "none":
        x = _apply_mixer(p, cfg, spec, x)
    if spec.ffn != "none":
        x, aux = _apply_ffn_block(p, cfg, spec, x)
    else:
        aux = jnp.zeros((), jnp.float32)
    return x, aux


# ------------------------------- caches --------------------------------------


def init_layer_cache(
    cfg: ModelConfig,
    spec: LayerSpec,
    batch: int,
    cache_len: int,
    dtype,
) -> Params:
    c: Params = {}
    d = cfg.d_model
    if spec.mixer == "attn":
        c["attn"] = attn.INIT_CACHE[cfg.attention.kind](
            cfg.attention, batch, cache_len, spec.window, dtype
        )
    elif spec.mixer == "mamba":
        di = cfg.mamba.expand * d
        c["mamba"] = {
            "conv": jnp.zeros((batch, cfg.mamba.d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, cfg.mamba.d_state), jnp.float32),
        }
    elif spec.mixer == "rwkv":
        hd = cfg.rwkv.head_dim
        c["rwkv"] = {
            "shift": jnp.zeros((batch, d), dtype),
            "wkv": jnp.zeros((batch, d // hd, hd, hd), jnp.float32),
        }
    if spec.ffn == "rwkv_channel_mix":
        c["cmix"] = {"shift": jnp.zeros((batch, d), dtype)}
    return c


def _mixer_with_cache(
    p: Params,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: Array,
    cache: Params,
    pos: Array | None,
    *,
    decode: bool,
) -> tuple[Array, Params]:
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    new = dict(cache)
    if spec.mixer == "attn":
        fn = attn.DECODE if decode else attn.PREFILL
        if decode:
            out, new["attn"] = fn[cfg.attention.kind](
                p["mixer"],
                cfg.attention,
                h,
                cache["attn"],
                pos,
                window=spec.window,
                rope_theta=spec.rope_theta,
            )
        else:
            out, new["attn"] = fn[cfg.attention.kind](
                p["mixer"],
                cfg.attention,
                h,
                cache["attn"],
                window=spec.window,
                rope_theta=spec.rope_theta,
            )
    elif spec.mixer == "mamba":
        state = cache["mamba"] if decode else None
        out, new["mamba"] = ssm.apply_mamba(p["mixer"], cfg.mamba, h, state)
    else:  # rwkv
        state = cache["rwkv"] if decode else None
        out, new["rwkv"] = ssm.apply_rwkv_time_mix(p["mixer"], cfg.rwkv, h, state)
    if cfg.post_norms:
        out = rms_norm(out, p["mixer_post_norm"], cfg.norm_eps)
    return x + out, new


def _ffn_with_cache(
    p: Params,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: Array,
    cache: Params,
    *,
    decode: bool,
) -> tuple[Array, Params]:
    new = dict(cache)
    if spec.ffn == "relu_mlp":
        f = p["ffn"]
        return jnp.maximum(linear(f["w"], x) + f["b"], 0.0), new
    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if spec.ffn == "dense":
        out = apply_ffn(p["ffn"], h, cfg.act, cfg.glu)
    elif spec.ffn == "moe":
        out, _ = moe_mod.apply_moe(p["ffn"], cfg.moe, h, cfg.act, cfg.glu)
    else:  # rwkv_channel_mix (stateful token shift)
        state = cache["cmix"] if decode else None
        out, new["cmix"] = ssm.apply_rwkv_channel_mix(p["ffn"], h, state)
    if cfg.post_norms:
        out = rms_norm(out, p["ffn_post_norm"], cfg.norm_eps)
    return x + out, new


def prefill_layer(
    p: Params, cfg: ModelConfig, spec: LayerSpec, x: Array, cache: Params
) -> tuple[Array, Params]:
    new = cache
    x = constrain(x, ("batch", "seq", None))
    if spec.mixer != "none":
        x, new = _mixer_with_cache(p, cfg, spec, x, new, None, decode=False)
    if spec.ffn != "none":
        x, new = _ffn_with_cache(p, cfg, spec, x, new, decode=False)
    return x, new


def decode_layer(
    p: Params,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: Array,
    cache: Params,
    pos: Array,
) -> tuple[Array, Params]:
    new = cache
    x = constrain(x, ("batch", None, None))
    if spec.mixer != "none":
        x, new = _mixer_with_cache(p, cfg, spec, x, new, pos, decode=True)
    if spec.ffn != "none":
        x, new = _ffn_with_cache(p, cfg, spec, x, new, decode=True)
    return x, new


# ============================ stacked execution ==============================


def init_stack(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    """Parameters for head + stacked period + tail."""
    kh, kp, kt = jax.random.split(key, 3)
    head = [
        init_layer(k, cfg, s, dtype)
        for k, s in zip(jax.random.split(kh, max(len(cfg.head), 1)), cfg.head)
    ]
    tail = [
        init_layer(k, cfg, s, dtype)
        for k, s in zip(jax.random.split(kt, max(len(cfg.tail), 1)), cfg.tail)
    ]
    period = []
    pos_keys = jax.random.split(kp, len(cfg.period))
    for pos, spec in enumerate(cfg.period):
        per_rep = jax.random.split(pos_keys[pos], cfg.n_periods)
        stacked = jax.vmap(lambda k: init_layer(k, cfg, spec, dtype))(per_rep)
        period.append(stacked)
    return {"head": head, "period": period, "tail": tail}


def init_stack_cache(
    cfg: ModelConfig, batch: int, cache_len: int, dtype
) -> Params:
    def one(spec):
        return init_layer_cache(cfg, spec, batch, cache_len, dtype)

    period = []
    for spec in cfg.period:
        c = one(spec)
        period.append(
            jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (cfg.n_periods,) + a.shape
                ).copy(),
                c,
            )
        )
    return {
        "head": [one(s) for s in cfg.head],
        "period": period,
        "tail": [one(s) for s in cfg.tail],
    }


def apply_stack(p: Params, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    """Full-sequence forward through the whole stack → (x, aux_sum)."""
    aux = jnp.zeros((), jnp.float32)
    for lp, spec in zip(p["head"], cfg.head):
        x, a = apply_layer(lp, cfg, spec, x)
        aux = aux + a

    def body(carry, xs):
        x, aux = carry
        for pos, spec in enumerate(cfg.period):
            x, a = apply_layer(xs[pos], cfg, spec, x)
            aux = aux + a
        return (x, aux), None

    if cfg.n_periods > 0:
        # full remat (save only the layer-boundary carry). §Perf L3 tried
        # policy=save_only_these_names("norm_out"): REFUTED — the saved
        # stacks' dynamic-update-slice traffic (+1.9 GiB live state)
        # exceeded the recompute it avoided (t_mem 3.91 s → 4.47 s).
        body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), tuple(p["period"]))

    for lp, spec in zip(p["tail"], cfg.tail):
        x, a = apply_layer(lp, cfg, spec, x)
        aux = aux + a
    return x, aux


def prefill_stack(
    p: Params, cfg: ModelConfig, x: Array, cache: Params
) -> tuple[Array, Params]:
    new_head = []
    for lp, spec, c in zip(p["head"], cfg.head, cache["head"]):
        x, nc = prefill_layer(lp, cfg, spec, x, c)
        new_head.append(nc)

    def body(x, xs):
        params_slice, cache_slice = xs
        new = []
        for pos, spec in enumerate(cfg.period):
            x, nc = prefill_layer(params_slice[pos], cfg, spec, x, cache_slice[pos])
            new.append(nc)
        return x, tuple(new)

    new_period = cache["period"]
    if cfg.n_periods > 0:
        x, new_period = jax.lax.scan(
            body, x, (tuple(p["period"]), tuple(cache["period"]))
        )
        new_period = list(new_period)

    new_tail = []
    for lp, spec, c in zip(p["tail"], cfg.tail, cache["tail"]):
        x, nc = prefill_layer(lp, cfg, spec, x, c)
        new_tail.append(nc)
    return x, {"head": new_head, "period": new_period, "tail": new_tail}


def decode_stack(
    p: Params, cfg: ModelConfig, x: Array, cache: Params, pos: Array
) -> tuple[Array, Params]:
    new_head = []
    for lp, spec, c in zip(p["head"], cfg.head, cache["head"]):
        x, nc = decode_layer(lp, cfg, spec, x, c, pos)
        new_head.append(nc)

    def body(x, xs):
        params_slice, cache_slice = xs
        new = []
        for i, spec in enumerate(cfg.period):
            x, nc = decode_layer(params_slice[i], cfg, spec, x, cache_slice[i], pos)
            new.append(nc)
        return x, tuple(new)

    new_period = cache["period"]
    if cfg.n_periods > 0:
        x, new_period = jax.lax.scan(
            body, x, (tuple(p["period"]), tuple(cache["period"]))
        )
        new_period = list(new_period)

    new_tail = []
    for lp, spec, c in zip(p["tail"], cfg.tail, cache["tail"]):
        x, nc = decode_layer(lp, cfg, spec, x, c, pos)
        new_tail.append(nc)
    return x, {"head": new_head, "period": new_period, "tail": new_tail}


# ------------------------- the paper's technique -----------------------------


def sparsify_stack(p: Params, cfg: ModelConfig) -> Params:
    """Convert targeted FFN weights to BSR by block-magnitude pruning
    (host-side; concrete values required). The deployment path of the
    paper's sparse-weight technique for every assigned arch."""
    sp = cfg.sparsity
    if sp is None or sp.blocks_per_row <= 0:
        return p

    def convert(layer: Params) -> Params:
        out = dict(layer)
        if "ffn" in layer and "ffn" in sp.targets:
            out["ffn"] = sparsify_ffn(
                layer["ffn"], sp.block_shape, sp.blocks_per_row
            )
        return out

    def convert_stacked(layer: Params) -> Params:
        # stacked leaves (n_periods, ...): unstack, convert, restack
        n = cfg.n_periods
        slices = [jax.tree.map(lambda a: a[i], layer) for i in range(n)]
        converted = [convert(s) for s in slices]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *converted)

    return {
        "head": [convert(l) for l in p["head"]],
        "period": [convert_stacked(l) for l in p["period"]],
        "tail": [convert(l) for l in p["tail"]],
    }
