"""Shared model building blocks (pure-JAX, dict-pytree params).

Every projection goes through :func:`linear`, which dispatches on weight
type — a dense ``jnp`` array or a :class:`BlockSparseMatrix` — so the
paper's sparse-weight technique is a first-class option for any layer
(DESIGN.md §4). Initializers are trace-friendly (usable under
``jax.eval_shape`` for the dry-run).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.sparse import ops as sparse_ops
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array
Params = dict[str, Any]


# --- init helpers ---------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> Array:
    scale = 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Array:
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


# --- primitive ops --------------------------------------------------------


def linear(
    w,
    x: Array,
    bias: Array | None = None,
    *,
    use_kernel: bool | None = None,
    transpose_plan=None,
) -> Array:
    """y = x @ W (+ b). ``w`` is dense (d_in, d_out) or sparse
    (d_out, d_in) — ELL-padded BSR for regular topologies, block-CSR for
    skewed/pruned ones (see ``repro.plan.preferred_layout``).

    Sparse weights store the *output-major* layout (as the paper's W
    matrices are applied ``W @ Y``), so they compute ``(W @ x^T)^T``
    through the block-sparse path.

    ``use_kernel`` selects the Pallas kernel wrappers (custom-VJP
    differentiable — ``repro.kernels.autodiff``) over the jnp oracle
    paths; ``None`` auto-picks the kernels on TPU and the XLA paths
    elsewhere (interpret-mode kernels are correctness-only). Both paths
    are ``jax.grad``-compatible and sparse-preserving.

    ``transpose_plan``: for a block-CSR ``w`` on the kernel path, the
    cached backward transpose (``w.transpose_plan()`` or a LayerPlan's,
    see ``repro.plan``) so ``jax.grad`` through this projection never
    re-sorts the frozen topology.
    """
    if isinstance(w, (BlockSparseMatrix, BlockCSRMatrix)):
        lead = x.shape[:-1]
        xt = x.reshape(-1, x.shape[-1]).T  # (d_in, tokens)
        is_csr = isinstance(w, BlockCSRMatrix)
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        if use_kernel:
            from repro.kernels import ops as kernel_ops

            if is_csr:
                out = kernel_ops.bcsr_spmm(w, xt, None, transpose_plan)
            else:
                out = kernel_ops.bsr_spmm(w, xt)
        else:
            matmul = sparse_ops.bcsr_matmul if is_csr else sparse_ops.bsr_matmul
            out = matmul(w, xt)  # (d_out, tokens)
        y = out.T.reshape(*lead, w.shape[0])
    else:
        y = jnp.einsum("...i,io->...o", x, w)
    if bias is not None:
        y = y + bias
    return y


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int) -> Array:
    return jnp.zeros((d,), jnp.float32)  # (1 + scale) convention


def activation(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


# --- FFN -------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, glu: bool, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype),
    }
    if glu:
        p["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def apply_ffn(p: Params, x: Array, act: str, glu: bool) -> Array:
    h = linear(p["w_in"], x)
    if glu:
        h = activation(linear(p["w_gate"], x), act) * h
    else:
        h = activation(h, act)
    return linear(p["w_out"], h)


def sparsify_ffn(
    p: Params, block_shape: tuple[int, int], blocks_per_row: int
) -> Params:
    """Convert an FFN's weights to BSR via block-magnitude pruning
    (host-side; the paper's deployment path for sparse weights)."""
    from repro.core import pruning

    out = {}
    for name, w in p.items():
        if isinstance(w, (BlockSparseMatrix, BlockCSRMatrix)) or w.ndim != 2:
            out[name] = w
            continue
        # prune in output-major orientation (W @ x convention of the paper)
        out[name] = pruning.block_prune(
            w.T, block_shape, blocks_per_row=blocks_per_row
        )
    return out


# --- losses ----------------------------------------------------------------


def cross_entropy_loss(
    logits: Array, labels: Array, *, z_loss: float = 0.0
) -> Array:
    """Mean next-token CE in f32; labels < 0 are masked out.

    The gold-logit extraction uses an iota==label mask + reduction rather
    than ``take_along_axis``: a gather over a vocab-sharded logits tensor
    forces GSPMD to replicate the operand (GiBs per device at 128k-262k
    vocab), while the mask-reduce stays elementwise over the shard and
    reduces with one tiny all-reduce.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    hit = iota == jnp.maximum(labels, 0)[..., None]
    gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
