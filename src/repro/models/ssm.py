"""Attention-free mixers: RWKV-6 (Finch) time/channel-mix and Mamba-1
selective SSM (for Jamba), with chunked-parallel training scans.

Both recurrences are diagonal-decay linear systems
``h_t = exp(w_t) ⊙ h_{t-1} + k_t ⊗ v_t`` — the chunked form turns them
into dense (MXU-friendly) matmuls per chunk with an inter-chunk carried
state, instead of a length-T sequential loop. Numerical discipline: all
per-step log-decays are clamped to ``≥ _LOG_DECAY_MIN`` at op entry (in
BOTH chunked and recurrent paths, so the clamp is part of the op's
semantics — mirroring the fp32 clamps in the official CUDA kernels) and
the chunk is 16 so the within-chunk ``exp(±cumsum)`` rescaling stays
inside f32 range (e^{5·16} ≈ 5.5e34 < f32 max).

Decode paths carry O(1) state: (wkv state, token-shift) for RWKV;
(conv tap, ssm state) for Mamba.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, RWKVConfig
from repro.models.layers import dense_init, init_rms_norm, rms_norm

Array = jax.Array
Params = dict[str, Any]

_LOG_DECAY_MIN = -5.0
_CHUNK = 16


def _clamp_logw(logw: Array) -> Array:
    return jnp.clip(logw, _LOG_DECAY_MIN, 0.0)


# =============================== RWKV-6 ======================================


def init_rwkv_time_mix(
    key, d_model: int, cfg: RWKVConfig, dtype=jnp.float32
) -> Params:
    ks = jax.random.split(key, 10)
    h = d_model // cfg.head_dim
    return {
        # data-dependent token-shift interpolation (5 targets: w,k,v,r,g)
        "mu_x": jnp.zeros((d_model,), dtype),
        "mu": jnp.zeros((5, d_model), dtype),
        "mix_w1": dense_init(ks[0], d_model, 5 * cfg.mix_lora, dtype),
        "mix_w2": 0.01
        * jax.random.normal(ks[1], (5, cfg.mix_lora, d_model), dtype),
        "w_r": dense_init(ks[2], d_model, d_model, dtype),
        "w_k": dense_init(ks[3], d_model, d_model, dtype),
        "w_v": dense_init(ks[4], d_model, d_model, dtype),
        "w_g": dense_init(ks[5], d_model, d_model, dtype),
        "w_o": dense_init(ks[6], d_model, d_model, dtype),
        # data-dependent decay: logw = -exp(w0 + tanh(x@dw1)@dw2)
        "w0": jnp.full((d_model,), -1.0, dtype),
        "decay_w1": dense_init(ks[7], d_model, cfg.decay_lora, dtype),
        "decay_w2": 0.01
        * jax.random.normal(ks[8], (cfg.decay_lora, d_model), dtype),
        "bonus_u": 0.5 * jax.random.normal(ks[9], (h, cfg.head_dim), dtype),
        "ln_x": init_rms_norm(cfg.head_dim),  # per-head group norm
    }


def _token_shift(x: Array, prev: Array | None) -> Array:
    """The x_{t-1} stream; ``prev`` is the carried last token (decode)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(p: Params, x: Array, xprev: Array) -> list[Array]:
    """RWKV-6 data-dependent lerp producing the 5 mixed streams."""
    b, s, _ = x.shape
    diff = xprev - x
    xx = x + diff * p["mu_x"]
    inner = jnp.tanh(xx @ p["mix_w1"]).reshape(b, s, 5, -1)
    dyn = jnp.einsum("bsnl,nld->nbsd", inner, p["mix_w2"])  # (5,B,S,D)
    return [x + diff * (p["mu"][i] + dyn[i]) for i in range(5)]


def wkv_chunked(
    r: Array,  # (B, H, T, K)
    k: Array,  # (B, H, T, K)
    v: Array,  # (B, H, T, V)
    logw: Array,  # (B, H, T, K), ≤ 0 after clamp
    u: Array,  # (H, K) current-token bonus
    h0: Array,  # (B, H, K, V)
    *,
    chunk: int = _CHUNK,
) -> tuple[Array, Array]:
    """out_t = r_t·(h_{t-1} + u⊙k_t⊗v_t);  h_t = e^{w_t}⊙h_{t-1} + k_t⊗v_t."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    logw = _clamp_logw(logw.astype(jnp.float32))
    pad = (-t) % chunk
    if pad:
        r, k, v, logw = (
            jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
            for a in (r, k, v, logw)
        )
    nc = (t + pad) // chunk

    def chunks(a):
        return (
            a.astype(jnp.float32)
            .reshape(b, h, nc, chunk, a.shape[-1])
            .transpose(2, 0, 1, 3, 4)
        )

    tri_strict = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
    uf = u.astype(jnp.float32)

    def body(hs, xs):
        rc, kc, vc, wc = xs  # each (B, H, C, ·)
        cum = jnp.cumsum(wc, axis=2)
        r_t = rc * jnp.exp(cum - wc)  # decay up to t-1 (exclusive)
        k_t = kc * jnp.exp(-cum)
        scores = jnp.einsum("bhtk,bhsk->bhts", r_t, k_t)
        scores = jnp.where(tri_strict, scores, 0.0)
        y = jnp.einsum("bhts,bhsv->bhtv", scores, vc)
        y += jnp.einsum("bhtk,bhkv->bhtv", r_t, hs)
        diag = jnp.einsum("bhtk,hk->bht", rc * kc, uf)
        y += diag[..., None] * vc
        decay_end = jnp.exp(cum[:, :, -1:, :] - cum)
        h_new = hs * jnp.exp(cum[:, :, -1, :])[..., None] + jnp.einsum(
            "bhtk,bhtv->bhkv", kc * decay_end, vc
        )
        return h_new, y

    # checkpointed chunk body (§Perf J1): the backward recomputes the
    # within-chunk decay matrices instead of saving ~10 per-chunk stacks
    h_fin, ys = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        h0.astype(jnp.float32),
        (chunks(r), chunks(k), chunks(v), chunks(logw)),
    )
    out = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, nc * chunk, dv)[:, :, :t]
    return out, h_fin


def wkv_step(
    r: Array,  # (B, H, K)
    k: Array,
    v: Array,  # (B, H, V)
    logw: Array,  # (B, H, K)
    u: Array,  # (H, K)
    h: Array,  # (B, H, K, V)
) -> tuple[Array, Array]:
    r, k, v, h = (a.astype(jnp.float32) for a in (r, k, v, h))
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum(
        "bhk,bhkv->bhv", r, h + u.astype(jnp.float32)[None, :, :, None] * kv
    )
    h_new = jnp.exp(_clamp_logw(logw.astype(jnp.float32)))[..., None] * h + kv
    return out, h_new


def apply_rwkv_time_mix(
    p: Params,
    cfg: RWKVConfig,
    x: Array,
    state: Params | None = None,
) -> tuple[Array, Params]:
    """state (decode): {"shift": (B,D), "wkv": (B,H,K,V)}; None → zeros."""
    b, s, d = x.shape
    hd = cfg.head_dim
    h = d // hd
    prev = state["shift"] if state is not None else None
    xprev = _token_shift(x, prev)
    xw, xk, xv, xr, xg = _ddlerp(p, x, xprev)

    def heads(a):
        return a.reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    r = heads(xr @ p["w_r"])
    k = heads(xk @ p["w_k"])
    v = heads(xv @ p["w_v"])
    g = jax.nn.silu(xg @ p["w_g"])
    logw = -jnp.exp(
        p["w0"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    )  # (B,S,D)
    logw = heads(logw)

    h0 = (
        state["wkv"]
        if state is not None
        else jnp.zeros((b, h, hd, hd), jnp.float32)
    )
    if s == 1 and state is not None:
        out, h_fin = wkv_step(
            r[:, :, 0], k[:, :, 0], v[:, :, 0], logw[:, :, 0], p["bonus_u"], h0
        )
        out = out[:, None]  # (B,1,H,V) after transpose below
        out = out.transpose(0, 1, 2, 3).reshape(b, 1, h, hd)
    else:
        out, h_fin = wkv_chunked(r, k, v, logw, p["bonus_u"], h0)
        out = out.transpose(0, 2, 1, 3)  # (B,S,H,V)
    out = rms_norm(out, p["ln_x"])  # per-head group norm
    out = out.reshape(b, s, d).astype(x.dtype) * g
    out = out @ p["w_o"]
    return out, {"shift": x[:, -1], "wkv": h_fin}


def init_rwkv_channel_mix(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d_model,), dtype),
        "mu_r": jnp.zeros((d_model,), dtype),
        "w_k": dense_init(k1, d_model, d_ff, dtype),
        "w_v": dense_init(k2, d_ff, d_model, dtype),
        "w_r": dense_init(k3, d_model, d_model, dtype),
    }


def apply_rwkv_channel_mix(
    p: Params, x: Array, state: Params | None = None
) -> tuple[Array, Params]:
    prev = state["shift"] if state is not None else None
    xprev = _token_shift(x, prev)
    xk = x + (xprev - x) * p["mu_k"]
    xr = x + (xprev - x) * p["mu_r"]
    k = jax.nn.relu(xk @ p["w_k"])
    k = k * k  # squared ReLU
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"])
    return out, {"shift": x[:, -1]}


# =============================== Mamba-1 =====================================


def init_mamba(key, d_model: int, cfg: MambaConfig, dtype=jnp.float32) -> Params:
    di = cfg.expand * d_model
    dt_rank = cfg.dt_rank or math.ceil(d_model / 16)
    ks = jax.random.split(key, 5)
    a_init = jnp.broadcast_to(
        jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (di, cfg.d_state)
    )
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.d_conv, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * cfg.d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus ≈ 0.01
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, d_model, dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array, tap: Array | None) -> Array:
    """Depthwise causal conv: y_t = Σ_i w[i]·x[t-(K-1)+i] + b.
    ``tap``: (B, K-1, di) carried context (decode/prefill continuation)."""
    kk = w.shape[0]
    if tap is None:
        xp = jnp.pad(x, ((0, 0), (kk - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tap, x], axis=1)
    y = sum(w[i] * xp[:, i : i + x.shape[1]] for i in range(kk))
    return y + b


def mamba_scan_chunked(
    u: Array,  # (B, T, di) conv+silu output
    delta: Array,  # (B, T, di)
    a: Array,  # (di, N) negative
    bm: Array,  # (B, T, N)
    cm: Array,  # (B, T, N)
    h0: Array,  # (B, di, N)
    *,
    chunk: int = _CHUNK,
) -> tuple[Array, Array]:
    """h_t = e^{Δ_t A}⊙h_{t-1} + (Δ_t u_t)⊗B_t ;  y_t = C_t·h_t."""
    b, t, di = u.shape
    n = a.shape[1]
    pad = (-t) % chunk
    if pad:
        u, delta = (jnp.pad(x, ((0, 0), (0, pad), (0, 0))) for x in (u, delta))
        bm, cm = (jnp.pad(x, ((0, 0), (0, pad), (0, 0))) for x in (bm, cm))
    nch = (t + pad) // chunk

    def chunks(x):
        return (
            x.astype(jnp.float32)
            .reshape(b, nch, chunk, x.shape[-1])
            .transpose(1, 0, 2, 3)
        )

    tri_incl = jnp.tril(jnp.ones((chunk, chunk), bool))
    af = a.astype(jnp.float32)

    def body(hs, xs):
        uc, dc, bc, cc = xs  # (B, C, di) / (B, C, N)
        da = _clamp_logw(dc[..., None] * af)  # (B, C, di, N)
        cum = jnp.cumsum(da, axis=1)
        q = cc[:, :, None, :] * jnp.exp(cum)
        kt = bc[:, :, None, :] * jnp.exp(-cum)
        scores = jnp.einsum("btcn,bscn->btsc", q, kt)
        scores = jnp.where(tri_incl[None, :, :, None], scores, 0.0)
        dx = dc * uc  # (B, C, di)
        y = jnp.einsum("btsc,bsc->btc", scores, dx)
        y += jnp.einsum("btcn,bcn->btc", q, hs)
        k_end = bc[:, :, None, :] * jnp.exp(cum[:, -1:] - cum)
        h_new = hs * jnp.exp(cum[:, -1]) + jnp.einsum(
            "bscn,bsc->bcn", k_end, dx
        )
        return h_new, y

    h_fin, ys = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),  # §Perf J1
        h0.astype(jnp.float32),
        (chunks(u), chunks(delta), chunks(bm), chunks(cm)),
    )
    y = ys.transpose(1, 0, 2, 3).reshape(b, nch * chunk, di)[:, :t]
    return y, h_fin


def mamba_step(
    u_t: Array,  # (B, di)
    delta_t: Array,
    a: Array,
    b_t: Array,  # (B, N)
    c_t: Array,
    h: Array,  # (B, di, N)
) -> tuple[Array, Array]:
    da = jnp.exp(_clamp_logw(delta_t[..., None] * a.astype(jnp.float32)))
    h_new = da * h + (delta_t * u_t)[..., None] * b_t[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h_new, c_t)
    return y, h_new


def apply_mamba(
    p: Params,
    cfg: MambaConfig,
    x: Array,
    state: Params | None = None,
) -> tuple[Array, Params]:
    """state (decode): {"conv": (B, d_conv-1, di), "ssm": (B, di, N)}."""
    b, s, d = x.shape
    di = cfg.expand * d
    xz = x @ p["in_proj"]
    x_in, z = xz[..., :di], xz[..., di:]
    tap = state["conv"] if state is not None else None
    x_c = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"], tap))
    proj = x_c @ p["x_proj"]
    dt_rank = p["dt_proj"].shape[0]
    n = cfg.d_state
    dt_raw, bm, cm = (
        proj[..., :dt_rank],
        proj[..., dt_rank : dt_rank + n],
        proj[..., dt_rank + n :],
    )
    delta = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    h0 = (
        state["ssm"]
        if state is not None
        else jnp.zeros((b, di, n), jnp.float32)
    )
    if s == 1 and state is not None:
        y, h_fin = mamba_step(
            x_c[:, 0].astype(jnp.float32),
            delta[:, 0].astype(jnp.float32),
            a,
            bm[:, 0].astype(jnp.float32),
            cm[:, 0].astype(jnp.float32),
            h0,
        )
        y = y[:, None]
    else:
        y, h_fin = mamba_scan_chunked(x_c, delta, a, bm, cm, h0)
    y = y.astype(x.dtype) + p["D"] * x_c
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_tap = (
        jnp.concatenate([tap, x_in], axis=1)[:, -(cfg.d_conv - 1) :]
        if tap is not None
        else x_in[:, -(cfg.d_conv - 1) :]
        if s >= cfg.d_conv - 1
        else jnp.pad(x_in, ((0, 0), (cfg.d_conv - 1 - s, 0), (0, 0)))
    )
    return out, {"conv": new_tap, "ssm": h_fin}
