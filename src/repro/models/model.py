"""Top-level model API: init / forward / loss / prefill / decode per config.

One class serves every assigned architecture (``--arch <id>``); the
config's layer pattern decides what gets built. Entry points:

  * ``init(key)``                     → params (dense; ``sparsify`` opt-in)
  * ``forward(params, inputs)``       → logits  (B, S, V)
  * ``loss(params, batch)``           → (scalar, metrics)  [train_step core]
  * ``init_cache(batch, cache_len)``  → decode cache pytree
  * ``prefill(params, inputs, cache)``→ (logits, cache)
  * ``decode_step(params, tok, cache, pos)`` → (logits, cache)

Input modes: ``tokens`` (int32 ids → embedding table), ``embeddings``
(float (B,S,D) — the VLM/audio frontend stub per the assignment) and
``features`` (the paper's MLP: float (B, m) feature vectors, no
embedding, logits = output features).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution import sharding
from repro.models import transformer as tfm
from repro.models.layers import cross_entropy_loss, embed_init, init_rms_norm, rms_norm

Array = jax.Array
Params = dict[str, Any]


def cast_floating(tree, dtype):
    """Cast floating leaves to the compute dtype (master params stay fp32
    in the optimizer; compute uses bf16 copies — the all-gather under FSDP
    then moves half the bytes)."""
    return jax.tree.map(
        lambda a: a.astype(dtype)
        if jnp.issubdtype(a.dtype, jnp.floating)
        else a,
        tree,
    )


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------ init ------------------------------------
    def init(self, key: Array) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        ke, ks, kl = jax.random.split(key, 3)
        p: Params = {"stack": tfm.init_stack(ks, cfg, dtype)}
        if cfg.input_mode == "tokens":
            p["embed"] = embed_init(ke, cfg.vocab_size, cfg.d_model, dtype)
        if cfg.input_mode != "features":
            p["final_norm"] = init_rms_norm(cfg.d_model)
            if not cfg.tie_embeddings:
                p["lm_head"] = (
                    jax.random.normal(kl, (cfg.d_model, cfg.vocab_size), dtype)
                    * 0.02
                )
        return p

    def sparsify(self, params: Params) -> Params:
        """Apply the paper's technique: block-prune targeted weights → BSR."""
        out = dict(params)
        out["stack"] = tfm.sparsify_stack(params["stack"], self.cfg)
        return out

    # ----------------------------- forward ----------------------------------
    def _embed(self, params: Params, inputs: Array) -> Array:
        cfg = self.cfg
        if cfg.input_mode == "tokens":
            # distributed vocab-parallel lookup (plain table[ids] on CPU)
            x = sharding.embed_lookup(params["embed"], inputs)
            x = sharding.constrain(x, ("batch", "seq", None))
        elif cfg.input_mode == "embeddings":
            x = inputs  # (B, S, D) float stub frontend
        else:  # features — the paper's MLP operates on (B, m)
            x = inputs[:, None, :] if inputs.ndim == 2 else inputs
        return x.astype(jnp.dtype(cfg.compute_dtype))

    def _head(self, params: Params, x: Array) -> Array:
        cfg = self.cfg
        if cfg.input_mode == "features":
            return x  # output features ARE the logits (vocab = m)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            w = params["embed"].astype(x.dtype)
            # the table is stored d-sharded (gather-friendly); reshard it
            # vocab-over-tp for the logits matmul so logits come out
            # vocab-sharded instead of partial-summed (see sharding.py)
            w = sharding.constrain(w, ("tp", None))
            return jnp.einsum("bsd,vd->bsv", x, w)
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))

    def forward(self, params: Params, inputs: Array) -> Array:
        return self.forward_with_aux(params, inputs)[0]

    def forward_with_aux(self, params: Params, inputs: Array) -> tuple[Array, Array]:
        params = cast_floating(params, jnp.dtype(self.cfg.compute_dtype))
        x = self._embed(params, inputs)
        x, aux = tfm.apply_stack(params["stack"], self.cfg, x)
        return self._head(params, x), aux

    # ------------------------------- loss -----------------------------------
    def loss(self, params: Params, batch: dict[str, Array]) -> tuple[Array, dict]:
        """batch: {"inputs": tokens/embeddings, "labels": (B, S) int32}."""
        logits, aux = self.forward_with_aux(params, batch["inputs"])
        ce = cross_entropy_loss(logits, batch["labels"], z_loss=1e-4)
        aux_w = self.cfg.moe.aux_loss_weight if self.cfg.moe else 0.0
        total = ce + aux_w * aux
        return total, {"ce": ce, "moe_aux": aux}

    # ------------------------------ serving ---------------------------------
    def init_cache(self, batch: int, cache_len: int, dtype=None) -> Params:
        dtype = dtype or jnp.dtype(self.cfg.compute_dtype)
        return tfm.init_stack_cache(self.cfg, batch, cache_len, dtype)

    def prefill(
        self, params: Params, inputs: Array, cache: Params
    ) -> tuple[Array, Params]:
        """Process the prompt, fill the cache; logits for the LAST position."""
        params = cast_floating(params, jnp.dtype(self.cfg.compute_dtype))
        x = self._embed(params, inputs)
        x, cache = tfm.prefill_stack(params["stack"], self.cfg, x, cache)
        logits = self._head(params, x[:, -1:])
        return logits, cache

    def decode_step(
        self, params: Params, token: Array, cache: Params, pos: Array
    ) -> tuple[Array, Params]:
        """One new token (B,) int32 (or (B,1,D) embeddings) at position pos."""
        params = cast_floating(params, jnp.dtype(self.cfg.compute_dtype))
        if token.ndim == 1:
            token = token[:, None]
        x = self._embed(params, token)
        x, cache = tfm.decode_stack(params["stack"], self.cfg, x, cache, pos)
        logits = self._head(params, x)
        return logits[:, 0], cache

    # ----------------------------- accounting -------------------------------
    def param_count(self) -> int:
        import math

        shapes = jax.eval_shape(self.init, jax.random.key(0))
        return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        cfg = self.cfg
        total = self.param_count()
        if cfg.moe is None:
            return total
        specs = cfg.layer_specs()
        n_moe = sum(1 for s in specs if s.ffn == "moe")
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        per_expert = cfg.d_model * cfg.moe.d_expert * (3 if cfg.glu else 2)
        inactive = n_moe * (e - k) * per_expert
        return total - inactive


def build(name_or_cfg) -> Model:
    if isinstance(name_or_cfg, ModelConfig):
        return Model(name_or_cfg)
    from repro.configs import get_config

    return Model(get_config(name_or_cfg))
