"""Mixture-of-Experts FFN: top-k routing with GShard-style dense
dispatch/combine (capacity-bounded), shared experts, Switch aux loss.

The dispatch is expressed as einsums over a (tokens, experts, capacity)
one-hot tensor so GSPMD can partition experts over the "model" mesh axis
(expert parallelism): under pjit the dispatch einsum lowers to an
all-to-all between the token (data) and expert (model) shardings — the
collective pattern this layer is designed around. Tokens over capacity
are dropped (residual passes them through), standard GShard semantics.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import activation, dense_init

Array = jax.Array
Params = dict[str, Any]


def init_moe(key, d_model: int, cfg: MoEConfig, glu: bool, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    e, f = cfg.num_experts, cfg.d_expert

    def expert_bank(k, d_in, d_out):
        w = jax.random.normal(k, (e, d_in, d_out), dtype)
        return w * (1.0 / jnp.sqrt(d_in))

    p = {
        "router": dense_init(ks[0], d_model, e, dtype),
        "w_in": expert_bank(ks[1], d_model, f),
        "w_out": expert_bank(ks[2], f, d_model),
    }
    if glu:
        p["w_gate"] = expert_bank(ks[3], d_model, f)
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * cfg.d_expert
        p["shared"] = {
            "w_in": dense_init(ks[4], d_model, fs, dtype),
            "w_gate": dense_init(
                jax.random.fold_in(ks[4], 1), d_model, fs, dtype
            ),
            "w_out": dense_init(
                jax.random.fold_in(ks[4], 2), fs, d_model, dtype
            ),
        }
    return p


def _top_k_dispatch(
    probs: Array,  # (G, S, E) router probabilities
    top_k: int,
    capacity: int,
) -> tuple[Array, Array]:
    """Returns combine (G,S,E,C) f32 and dispatch (G,S,E,C) bool."""
    g, s, e = probs.shape
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9
    )
    combine = jnp.zeros((g, s, e), probs.dtype)
    dispatch_cnt = jnp.zeros((g, s, e), jnp.int32)
    for i in range(top_k):
        onehot = jax.nn.one_hot(gate_idx[..., i], e, dtype=probs.dtype)
        combine += onehot * gate_vals[..., i : i + 1]
        dispatch_cnt += onehot.astype(jnp.int32)
    # position of each token within its expert's queue (priority = seq order)
    pos_in_expert = jnp.cumsum(dispatch_cnt, axis=1) - dispatch_cnt  # (G,S,E)
    keep = (dispatch_cnt > 0) & (pos_in_expert < capacity)
    cap_onehot = jax.nn.one_hot(
        jnp.where(keep, pos_in_expert, capacity), capacity, dtype=probs.dtype
    )  # overflow maps to a dropped row
    dispatch = cap_onehot * keep[..., None]  # (G,S,E,C)
    combine4 = combine[..., None] * dispatch
    return combine4, dispatch


def _group_size(total_tokens: int, target: int = 256) -> int:
    """Largest power-of-two ≤ target dividing total_tokens (GShard groups
    are small so the (G, S_g, E, C) dispatch tensor stays ~O(tokens·k·cf)
    and per-group capacity stays O(10))."""
    g = 1
    while g < target and total_tokens % (g * 2) == 0:
        g *= 2
    return g


def apply_moe(
    p: Params,
    cfg: MoEConfig,
    x: Array,  # (B, S, D) — flattened into (G, S_g, D) token groups
    act: str,
    glu: bool,
) -> tuple[Array, Array]:
    """Returns (output, aux_loss)."""
    b, s0, d = x.shape
    tokens = b * s0
    s = _group_size(tokens)
    x = x.reshape(tokens // s, s, d)
    e = cfg.num_experts
    capacity = max(
        1, -(-int(cfg.capacity_factor * s * cfg.top_k) // e)
    )
    from repro.distribution.sharding import constrain

    x = constrain(x, ("batch", None, None))  # token groups over DP axes
    logits = (x.astype(jnp.float32)) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G,S,E)
    combine, dispatch = _top_k_dispatch(probs, cfg.top_k, capacity)

    # Switch/GShard load-balance loss: E · Σ_e f_e · P_e
    density = jnp.mean(
        (dispatch.sum(-1) > 0).astype(jnp.float32), axis=1
    )  # (G,E) fraction routed
    mean_prob = jnp.mean(probs, axis=1)
    aux = e * jnp.mean(jnp.sum(density * mean_prob, axis=-1))

    # EP layout (§Perf D1): expert-major tensors are sharded e→model AND
    # g→data. The dispatch einsum is then fully local (each device
    # contracts its token groups against its experts' one-hot slice), the
    # expert matmuls gather only the f-shard of their own experts' weights
    # over data (FSDP semantics, ~0.44 GB/layer for deepseek), and the
    # only activation collective is the combine's y all-reduce over model.
    # The earlier g-replicated layout paid a ~1.26 GB f32 all-gather AND a
    # 3.8 GB all-reduce per layer-microbatch instead (measured: 152 s →
    # see EXPERIMENTS.md §Perf).
    ep = ("tp", "batch", None, None)
    xe = jnp.einsum(
        "gsd,gsec->egcd", x, dispatch.astype(x.dtype)
    )  # token → expert redistribution boundary
    xe = constrain(xe, ep)
    h = jnp.einsum("egcd,edf->egcf", xe, p["w_in"])
    if glu:
        gate = jnp.einsum("egcd,edf->egcf", xe, p["w_gate"])
        h = activation(gate, act) * h
    else:
        h = activation(h, act)
    h = constrain(h, ep)
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_out"])
    ye = constrain(ye, ep)
    y = jnp.einsum(
        "egcd,gsec->gsd", ye, combine.astype(x.dtype)
    )  # experts → tokens
    y = constrain(y, ("batch", None, None))

    if "shared" in p:
        sp = p["shared"]
        hs = jnp.einsum("gsd,df->gsf", x, sp["w_in"])
        hs = activation(jnp.einsum("gsd,df->gsf", x, sp["w_gate"]), act) * hs
        y = y + jnp.einsum("gsf,fd->gsd", hs, sp["w_out"])
    return y.reshape(b, s0, d), aux
