"""Attention mixers: GQA (full / sliding-window, QK-norm, bias) and MLA
(DeepSeek-V2 latent attention) — train, prefill and decode paths.

Decode semantics: the KV cache is a fixed-size buffer (ring buffer for
windowed layers) with an explicit ``positions`` track; batch entries
decode at a shared position (the serving engine aligns them). MLA decode
uses the *absorbed* formulation — only the (kv_lora + rope) latents are
cached and the up-projections are folded into the query/output sides,
which is the memory trick that makes 32k×128-head decode feasible.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.layers import dense_init, init_rms_norm, rms_norm

Array = jax.Array
Params = dict[str, Any]

_NEG_INF = -1e30


# --- RoPE -------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- shared attention core ---------------------------------------------------


def _attend(
    q: Array,  # (B, T, H, hd)
    k: Array,  # (B, S, Hkv, hd)
    v: Array,  # (B, S, Hkv, dv)
    mask: Array,  # (B, T, S) or (T, S) boolean (True = attend)
    *,
    scale: float,
    q_chunk: int = 1024,
) -> Array:
    """Grouped scaled-dot-product attention, f32 softmax, query-chunked so
    the score matrix never exceeds (chunk × S) per head.

    SPMD posture: KV heads are *repeated* up to the full query-head count
    (Megatron-style KV replication within the TP group) so every einsum
    carries one full `h` dim that shards cleanly over the model axis —
    the grouped (hkv, g) formulation leaves GSPMD unable to shard either
    sub-dim when hkv < |model| and silently replicates the whole score
    tensor (16× the FLOPs at mesh 16). ``constrain`` pins the layout;
    it is a no-op outside an ``activate(mesh)`` scope.
    """
    from repro.distribution.sharding import constrain

    b, t, h, _ = q.shape
    hkv = k.shape[2]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    if mask.ndim == 2:
        mask = jnp.broadcast_to(mask[None], (b, t, mask.shape[-1]))
    head_spec = ("batch", None, "tp", None)
    q = constrain(q, head_spec)
    k_cast = constrain(k, head_spec)
    v_cast = constrain(v, head_spec)
    # working dtype = the compute dtype (bf16 in production). Scores and
    # probabilities are STORED at working precision — the f32-everywhere
    # variant doubles attention HBM traffic and the TP collective payloads
    # (§Perf iteration L1). Softmax normalization still happens in f32.
    wdt = q.dtype

    def block(args):
        qb, mb = args  # (B, tc, H, hd), (B, tc, S)
        scores = jnp.einsum(
            "bthd,bshd->bhts", qb, k_cast,
            preferred_element_type=wdt,
        ) * jnp.asarray(scale, wdt)
        scores = jnp.where(mb[:, None], scores, jnp.asarray(_NEG_INF, wdt))
        scores = constrain(scores, ("batch", "tp", None, None))
        m = jax.lax.stop_gradient(
            jnp.max(scores, axis=-1, keepdims=True)
        ).astype(jnp.float32)
        e = jnp.exp(scores.astype(jnp.float32) - m)
        w = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(wdt)
        ob = jnp.einsum("bhts,bshd->bthd", w, v_cast,
                        preferred_element_type=wdt)
        return constrain(ob, head_spec)

    if t <= q_chunk:
        out = block((q, mask))
    else:
        n = t // q_chunk
        rem = t % q_chunk
        qs = q[:, : n * q_chunk].reshape(b, n, q_chunk, h, -1)
        ms = mask[:, : n * q_chunk].reshape(b, n, q_chunk, -1)
        outs = jax.lax.map(
            block, (qs.transpose(1, 0, 2, 3, 4), ms.transpose(1, 0, 2, 3))
        )
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n * q_chunk, h, -1)
        if rem:
            tail = block((q[:, n * q_chunk :], mask[:, n * q_chunk :]))
            out = jnp.concatenate([out, tail], axis=1)
    return out.astype(q.dtype)


def _attend_streaming(
    q: Array,  # (B, T, H, hd) — heads already repeated to full count
    k: Array,  # (B, S, H, hd)
    v: Array,  # (B, S, H, dv)
    *,
    scale: float,
    causal_offset: int = 0,  # absolute position of q[0] minus k[0]
    window: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> Array:
    """Flash-attention-2 style streaming attention in pure JAX (§Perf L2).

    Online-softmax over k-tiles inside a checkpointed scan: full (T, S)
    score matrices never materialize in HBM — per-tile (q_chunk, k_chunk)
    blocks live only inside the scan body (recomputed in the backward).
    Tiles that are statically dead under the causal/window mask are never
    launched: the k-scan for query chunk i covers only
    [max(0, hi−window+1) … hi], halving causal compute and making
    sliding-window layers O(T·window) instead of O(T·S).
    """
    from repro.distribution.sharding import constrain

    b, t, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    s = k.shape[1]
    dv = v.shape[-1]
    head_spec = ("batch", None, "tp", None)
    q = constrain(q, head_spec)
    k = constrain(k, head_spec)
    v = constrain(v, head_spec)
    nq = -(-t // q_chunk)
    nk_total = -(-s // k_chunk)

    def q_block(i: int, qb: Array) -> Array:
        # static causal/window bounds for this query chunk
        q_lo = i * q_chunk
        q_hi = min(t, q_lo + q_chunk) - 1
        hi_abs = q_hi + causal_offset  # last key visible to this chunk
        k_hi_tile = min(nk_total, hi_abs // k_chunk + 1)
        k_lo_tile = 0
        if window:
            k_lo_tile = max(0, (q_lo + causal_offset - window + 1) // k_chunk)
        tiles = jnp.arange(k_lo_tile, k_hi_tile)
        tc = qb.shape[1]

        def body(carry, kt):
            acc, m_run, l_run = carry
            kb = jax.lax.dynamic_slice_in_dim(k, kt * k_chunk, k_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, kt * k_chunk, k_chunk, 1)
            sc = (
                jnp.einsum(
                    "bthd,bshd->bhts",
                    qb.astype(jnp.float32),
                    kb.astype(jnp.float32),
                )
                * scale
            )  # (B, H, tc, k_chunk)
            qpos = causal_offset + q_lo + jnp.arange(tc)[:, None]
            kpos = kt * k_chunk + jnp.arange(k_chunk)[None, :]
            ok = kpos <= qpos
            if window:
                ok &= (qpos - kpos) < window
            sc = jnp.where(ok, sc, _NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhts,bshd->bhtd", p, vb.astype(jnp.float32)
            )
            return (acc, m_new, l_new), None

        init = (
            jnp.zeros((b, h, tc, dv), jnp.float32),
            jnp.full((b, h, tc), -jnp.inf, jnp.float32),
            jnp.zeros((b, h, tc), jnp.float32),
        )
        (acc, m_run, l_run), _ = jax.lax.scan(
            jax.checkpoint(body, prevent_cse=False), init, tiles
        )
        out = acc / jnp.maximum(l_run[..., None], 1e-30)
        return constrain(
            out.transpose(0, 2, 1, 3).astype(q.dtype), head_spec
        )  # (B, tc, H, dv)

    outs = []
    for i in range(nq):
        qb = q[:, i * q_chunk : min(t, (i + 1) * q_chunk)]
        outs.append(q_block(i, qb))
    return outs[0] if nq == 1 else jnp.concatenate(outs, axis=1)


def attend_causal(
    q: Array,
    k: Array,
    v: Array,
    *,
    scale: float,
    window: int = 0,
    q_chunk: int = 1024,
) -> Array:
    """Causal self-attention dispatch: streaming (flash-style) for long
    sequences, single-block path otherwise."""
    t = q.shape[1]
    if t > q_chunk:
        return _attend_streaming(
            q, k, v, scale=scale, window=window, q_chunk=q_chunk
        )
    return _attend(q, k, v, causal_mask(t, window), scale=scale, q_chunk=q_chunk)


def causal_mask(t: int, window: int = 0) -> Array:
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    m = j <= i
    if window:
        m &= (i - j) < window
    return m


def cache_mask(positions_in_cache: Array, pos: Array, window: int = 0) -> Array:
    """(S_cache,) absolute positions (−1 = empty) vs current position."""
    m = (positions_in_cache >= 0) & (positions_in_cache <= pos)
    if window:
        m &= (pos - positions_in_cache) < window
    return m


# --- GQA ---------------------------------------------------------------------


def init_gqa(key, cfg: AttentionConfig, d_model: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "w_q": dense_init(ks[0], d_model, h * hd, dtype).reshape(d_model, h, hd),
        "w_k": dense_init(ks[1], d_model, hkv * hd, dtype).reshape(
            d_model, hkv, hd
        ),
        "w_v": dense_init(ks[2], d_model, hkv * hd, dtype).reshape(
            d_model, hkv, hd
        ),
        "w_o": dense_init(ks[3], h * hd, d_model, dtype).reshape(h, hd, d_model),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h, hd), dtype)
        p["b_k"] = jnp.zeros((hkv, hd), dtype)
        p["b_v"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _gqa_qkv(p: Params, cfg: AttentionConfig, x: Array, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"])
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def apply_gqa(
    p: Params,
    cfg: AttentionConfig,
    x: Array,
    *,
    window: int = 0,
    rope_theta: float = 0.0,
    q_chunk: int = 1024,
) -> Array:
    b, s, _ = x.shape
    theta = rope_theta or cfg.rope_theta
    positions = jnp.arange(s)[None, :]
    q, k, v = _gqa_qkv(p, cfg, x, positions, theta)
    out = attend_causal(
        q,
        k,
        v,
        window=window,
        scale=1.0 / math.sqrt(cfg.head_dim),
        q_chunk=q_chunk,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"])


def init_gqa_cache(
    cfg: AttentionConfig, batch: int, cache_len: int, window: int, dtype
) -> Params:
    size = min(cache_len, window) if window else cache_len
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, hkv, hd), dtype),
        "v": jnp.zeros((batch, size, hkv, hd), dtype),
        "positions": jnp.full((size,), -1, jnp.int32),
    }


def prefill_gqa(
    p: Params,
    cfg: AttentionConfig,
    x: Array,
    cache: Params,
    *,
    window: int = 0,
    rope_theta: float = 0.0,
    q_chunk: int = 1024,
) -> tuple[Array, Params]:
    b, s, _ = x.shape
    theta = rope_theta or cfg.rope_theta
    positions = jnp.arange(s)[None, :]
    q, k, v = _gqa_qkv(p, cfg, x, positions, theta)
    out = attend_causal(
        q,
        k,
        v,
        window=window,
        scale=1.0 / math.sqrt(cfg.head_dim),
        q_chunk=q_chunk,
    )
    size = cache["k"].shape[1]
    if size >= s:
        new_cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
            ),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
            ),
            "positions": jax.lax.dynamic_update_slice(
                cache["positions"], jnp.arange(s, dtype=jnp.int32), (0,)
            ),
        }
    else:  # ring buffer smaller than the prompt: keep the last `size`
        new_cache = {
            "k": _ring_fill(cache["k"], k, s),
            "v": _ring_fill(cache["v"], v, s),
            "positions": _ring_positions(size, s),
        }
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"]), new_cache


def _ring_fill(buf: Array, seq: Array, s: int) -> Array:
    size = buf.shape[1]
    last = seq[:, s - size :]
    slots = jnp.arange(s - size, s, dtype=jnp.int32) % size
    return buf.at[:, slots].set(last.astype(buf.dtype))


def _ring_positions(size: int, s: int) -> Array:
    pos = jnp.arange(s - size, s, dtype=jnp.int32)
    slots = pos % size
    return jnp.zeros((size,), jnp.int32).at[slots].set(pos)


def decode_gqa(
    p: Params,
    cfg: AttentionConfig,
    x: Array,  # (B, 1, D)
    cache: Params,
    pos: Array,  # scalar int32 — current position
    *,
    window: int = 0,
    rope_theta: float = 0.0,
) -> tuple[Array, Params]:
    theta = rope_theta or cfg.rope_theta
    positions = jnp.full((1, 1), pos, jnp.int32)
    q, k, v = _gqa_qkv(p, cfg, x, positions, theta)
    size = cache["k"].shape[1]
    slot = (pos % size) if window else pos
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)
    )
    positions_c = jax.lax.dynamic_update_slice(
        cache["positions"], pos[None].astype(jnp.int32), (slot,)
    )
    mask = cache_mask(positions_c, pos, window)[None, None, :]  # (1,1,S)
    out = _attend(
        q,
        k_cache,
        v_cache,
        mask,
        scale=1.0 / math.sqrt(cfg.head_dim),
    )
    out = jnp.einsum("bshk,hkd->bsd", out, p["w_o"])
    return out, {"k": k_cache, "v": v_cache, "positions": positions_c}


# --- MLA (DeepSeek-V2) -------------------------------------------------------


def init_mla(key, cfg: AttentionConfig, d_model: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "w_dq": dense_init(ks[0], d_model, ql, dtype),
        "q_norm": init_rms_norm(ql),
        "w_uq": dense_init(ks[1], ql, h * (dn + dr), dtype).reshape(
            ql, h, dn + dr
        ),
        "w_dkv": dense_init(ks[2], d_model, kl + dr, dtype),
        "kv_norm": init_rms_norm(kl),
        "w_uk": dense_init(ks[3], kl, h * dn, dtype).reshape(kl, h, dn),
        "w_uv": dense_init(ks[4], kl, h * dv, dtype).reshape(kl, h, dv),
        "w_o": dense_init(ks[5], h * dv, d_model, dtype).reshape(h, dv, d_model),
    }


def _mla_q(p, cfg, x, positions, theta):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rms_norm(linear_(p["w_dq"], x), p["q_norm"])
    q = jnp.einsum("bsl,lhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, theta)
    return q_nope, q_rope


def _mla_latents(p, cfg, x, positions, theta):
    kl, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckv_full = linear_(p["w_dkv"], x)
    c_kv = rms_norm(ckv_full[..., :kl], p["kv_norm"])
    k_rope = apply_rope(ckv_full[..., kl:][:, :, None, :], positions, theta)[
        :, :, 0
    ]
    return c_kv, k_rope


def linear_(w, x):
    return jnp.einsum("...i,io->...o", x, w)


def apply_mla(
    p: Params,
    cfg: AttentionConfig,
    x: Array,
    *,
    q_chunk: int = 1024,
    window: int = 0,
    rope_theta: float = 0.0,
) -> Array:
    del window  # MLA archs here are full-attention
    b, s, _ = x.shape
    theta = rope_theta or cfg.rope_theta
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions, theta)
    c_kv, k_rope = _mla_latents(p, cfg, x, positions, theta)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, cfg.num_heads, dr))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attend_causal(
        q,
        k,
        v,
        scale=1.0 / math.sqrt(dn + dr),
        q_chunk=q_chunk,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"])


def init_mla_cache(
    cfg: AttentionConfig, batch: int, cache_len: int, window: int, dtype
) -> Params:
    del window
    return {
        "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_head_dim), dtype),
        "positions": jnp.full((cache_len,), -1, jnp.int32),
    }


def prefill_mla(
    p: Params,
    cfg: AttentionConfig,
    x: Array,
    cache: Params,
    *,
    q_chunk: int = 1024,
    window: int = 0,
    rope_theta: float = 0.0,
) -> tuple[Array, Params]:
    b, s, _ = x.shape
    theta = rope_theta or cfg.rope_theta
    out = apply_mla(
        p, cfg, x, q_chunk=q_chunk, window=window, rope_theta=rope_theta
    )
    positions = jnp.arange(s)[None, :]
    c_kv, k_rope = _mla_latents(p, cfg, x, positions, theta)
    new_cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)
        ),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0)
        ),
        "positions": jax.lax.dynamic_update_slice(
            cache["positions"], jnp.arange(s, dtype=jnp.int32), (0,)
        ),
    }
    return out, new_cache


def decode_mla(
    p: Params,
    cfg: AttentionConfig,
    x: Array,  # (B, 1, D)
    cache: Params,
    pos: Array,
    *,
    window: int = 0,
    rope_theta: float = 0.0,
) -> tuple[Array, Params]:
    """Absorbed-matrix MLA decode: scores/outputs computed against the
    cached latents; W_uk folds into q, W_uv folds into the output."""
    del window
    theta = rope_theta or cfg.rope_theta
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    positions = jnp.full((1, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions, theta)  # (B,1,H,·)
    c_kv_t, k_rope_t = _mla_latents(p, cfg, x, positions, theta)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_t.astype(cache["c_kv"].dtype), (0, pos, 0)
    )
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_t.astype(cache["k_rope"].dtype), (0, pos, 0)
    )
    positions_c = jax.lax.dynamic_update_slice(
        cache["positions"], pos[None].astype(jnp.int32), (pos,)
    )
    # absorb W_uk into the query:  q_abs (B,1,H,kl)
    q_abs = jnp.einsum("bthk,lhk->bthl", q_nope.astype(jnp.float32), p["w_uk"])
    scores = jnp.einsum(
        "bthl,bsl->bhts", q_abs, c_kv.astype(jnp.float32)
    ) + jnp.einsum(
        "bthk,bsk->bhts", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
    )
    scores = scores / math.sqrt(dn + dr)
    mask = cache_mask(positions_c, pos)[None, None, None, :]
    scores = jnp.where(mask, scores, _NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out_latent = jnp.einsum("bhts,bsl->bthl", w, c_kv.astype(jnp.float32))
    out = jnp.einsum("bthl,lhk->bthk", out_latent, p["w_uv"])  # (B,1,H,dv)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["w_o"])
    return out, {"c_kv": c_kv, "k_rope": k_rope, "positions": positions_c}


# --- dispatch ----------------------------------------------------------------

INIT = {"gqa": init_gqa, "mla": init_mla}
APPLY = {"gqa": apply_gqa, "mla": apply_mla}
INIT_CACHE = {"gqa": init_gqa_cache, "mla": init_mla_cache}
PREFILL = {"gqa": prefill_gqa, "mla": prefill_mla}
DECODE = {"gqa": decode_gqa, "mla": decode_mla}
