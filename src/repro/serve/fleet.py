"""A replicated :class:`~repro.serve.engine.SparseDNNEngine` fleet.

One engine serves one panel at a time; GraphChallenge-scale offered load
(``repro.serve.loadgen``) needs N of them. This module is the *routing*
half of the fleet serving layer: :class:`ReplicaFleet` owns N
data-parallel replicas — same frozen stack, but each with its **own**
:class:`repro.plan.PlanCache` and :class:`repro.plan.DegradationLadder`
(enforced at construction), so a compile storm or a health mark on one
replica never bleeds into another. The event loop that drives dispatch
against a clock lives above, in ``repro.serve.frontend``.

Routing policy — width-class affinity, then load:

1. A job's *width class* is ``quantize_width(k, width_classes)`` — the
   padded panel width it will dispatch at, hence the
   :class:`repro.plan.PlanKey` it will look up.
2. The first time a class is seen, the least-loaded replica (preferring
   replicas that own fewest classes) **claims** it and compiles its one
   plan. Every later job of that class prefers the owner
   (``"affinity"``) — a guaranteed plan-cache hit.
3. Affinity yields to load only when the owner is backed up by more
   than ``affinity_slack`` columns relative to the least-loaded replica
   (``"spill"``), and to liveness always: a dead owner's classes are
   re-claimed on next sight (``"claim"``), and its queued/in-flight
   jobs are re-routed (``"failover"``), never dropped.

Spreading classes across replicas costs one compile per class per
*owning* replica — the same total compile count as a single engine —
while spill/failover compiles are visible as ``cross_replica_compiles``
in :meth:`ReplicaFleet.stats`. With affinity on, a trace's fleet-wide
plan-cache hit rate matches single-engine levels (≥ 0.9 on the bench
trace; gated in CI); routing purely by load would recompile every class
on every replica it lands on.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

from repro.plan import quantize_width
from repro.serve.engine import SparseDNNEngine
from repro.serve.loadgen import ArrivalJob

REASON_CLAIM = "claim"  # first sight of a class: claim + compile
REASON_AFFINITY = "affinity"  # owner alive and not overloaded
REASON_SPILL = "spill"  # owner too backed up; least-loaded wins
REASON_FAILOVER = "failover"  # owner/replica dead; re-routed


@dataclasses.dataclass(frozen=True)
class RoutingDecision:
    """One routing verdict — the fleet's audit log entry."""

    rid: int
    width_class: int
    replica: int
    reason: str


@dataclasses.dataclass
class Replica:
    """One engine plus the fleet's per-replica serving state."""

    index: int
    engine: SparseDNNEngine
    alive: bool = True
    queue: "deque[ArrivalJob]" = dataclasses.field(default_factory=deque)
    inflight: ArrivalJob | None = None
    # Counters accumulated from engine step stats by the frontend.
    dispatches: int = 0
    served_jobs: int = 0
    served_cols: int = 0
    plan_lookups: int = 0
    plan_hits: int = 0
    compiles: int = 0
    compiled_classes: set = dataclasses.field(default_factory=set)
    busy_s: float = 0.0

    @property
    def depth(self) -> int:
        """Backlog in feature columns (queued + in-flight) — the load
        signal the router balances on."""
        cols = sum(j.cols for j in self.queue)
        if self.inflight is not None:
            cols += self.inflight.cols
        return cols

    def observe_step(self, stats: dict) -> None:
        """Fold one engine ``step`` stats dict into the counters."""
        self.dispatches += 1
        plan = stats.get("plan")
        if plan is not None:
            self.plan_lookups += 1
            if plan["cache_hit"]:
                self.plan_hits += 1
            else:
                self.compiles += 1
                self.compiled_classes.add(plan["width_class"])
        if not stats.get("failed"):
            self.served_jobs += 1
            self.served_cols += stats["batch"]


class ReplicaFleet:
    """N isolated engine replicas behind a width-class-affinity router.

    ``engines`` must not share plan caches or ladders — replica
    isolation is the point (a compile or health event on one replica
    must not serialize the others), so sharing raises at construction.
    ``width_classes`` is the same quantization set every engine
    dispatches at (``step(pad_to=...)``); it defines the affinity key.
    """

    def __init__(
        self,
        engines: Sequence[SparseDNNEngine],
        *,
        width_classes: Sequence[int],
        affinity_slack: int | None = None,
    ):
        if not engines:
            raise ValueError("a fleet needs at least one engine replica")
        if not width_classes or min(width_classes) < 1:
            raise ValueError("width_classes must be positive ints")
        if affinity_slack is None:
            # Tolerate one largest-class panel of backlog imbalance
            # before spilling off the owner: a spill saves some queueing
            # but costs a fresh plan compile on the target, so small
            # imbalances should ride out on affinity.
            affinity_slack = max(width_classes)
        if affinity_slack < 0:
            raise ValueError(f"affinity_slack must be >= 0, got {affinity_slack}")
        caches = [e.plan_cache for e in engines]
        ladders = [e.ladder for e in engines]
        for name, objs in (("plan_cache", caches), ("ladder", ladders)):
            if len({id(o) for o in objs}) != len(objs):
                raise ValueError(
                    f"fleet replicas must not share a {name}: replica "
                    "isolation requires per-engine plan caches and "
                    "degradation ladders"
                )
        fps = {e._fingerprint for e in engines}
        if len(fps) != 1:
            raise ValueError(
                "fleet replicas serve different topologies "
                f"({len(fps)} distinct fingerprints); data-parallel "
                "replicas must share one stack"
            )
        self.fingerprint = next(iter(fps))
        self.width_classes = tuple(sorted(int(c) for c in width_classes))
        self.affinity_slack = int(affinity_slack)
        self.replicas = [Replica(i, e) for i, e in enumerate(engines)]
        self._owner: dict[int, int] = {}  # width class -> replica index
        self.decisions: list[RoutingDecision] = []
        self.events: list[dict] = []  # replica loss etc., for stats

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    def width_class(self, k: int) -> int:
        return quantize_width(int(k), self.width_classes)

    def _alive(self) -> list[Replica]:
        alive = [r for r in self.replicas if r.alive]
        if not alive:
            raise RuntimeError("no live replicas in the fleet")
        return alive

    def _least_loaded(self, among: Sequence[Replica]) -> Replica:
        # Deterministic tie-break: lowest index.
        return min(among, key=lambda r: (r.depth, r.index))

    def route(self, job: ArrivalJob, *, reason: str | None = None) -> Replica:
        """Pick a replica for ``job``, enqueue it there, log why.

        ``reason`` overrides the logged reason (the frontend passes
        ``"failover"`` when re-routing off a dead replica).
        """
        alive = self._alive()
        cls = self.width_class(job.cols)
        owner_idx = self._owner.get(cls)
        owner = (
            self.replicas[owner_idx]
            if owner_idx is not None and self.replicas[owner_idx].alive
            else None
        )
        if owner is None:
            # Claim: spread ownership — among least-owning replicas,
            # take the least-loaded one.
            owned = {r.index: 0 for r in alive}
            for i in self._owner.values():
                if i in owned:
                    owned[i] += 1
            min_owned = min(owned.values())
            cands = [r for r in alive if owned[r.index] == min_owned]
            chosen = self._least_loaded(cands)
            self._owner[cls] = chosen.index
            why = REASON_CLAIM
        else:
            lightest = self._least_loaded(alive)
            if owner.depth - lightest.depth > self.affinity_slack:
                chosen, why = lightest, REASON_SPILL
            else:
                chosen, why = owner, REASON_AFFINITY
        self.decisions.append(
            RoutingDecision(job.rid, cls, chosen.index, reason or why)
        )
        chosen.queue.append(job)
        return chosen

    def fail_replica(self, index: int, *, at: float, reason: str) -> list[ArrivalJob]:
        """Kill replica ``index``; return its orphaned jobs (queued,
        FIFO order, plus any in-flight job LAST — the frontend re-routes
        every one of them, so a replica loss costs latency, never a
        dropped request). Its class ownerships lapse (re-claimed on next
        sight). Idempotent-safe: failing a dead replica returns []."""
        r = self.replicas[index]
        if not r.alive:
            return []
        r.alive = False
        orphans = list(r.queue)
        r.queue.clear()
        if r.inflight is not None:
            orphans.append(r.inflight)
            r.inflight = None
        self._owner = {c: i for c, i in self._owner.items() if i != index}
        self.events.append(
            {
                "event": "replica-loss",
                "replica": index,
                "at": float(at),
                "reason": reason,
                "requeued_jobs": len(orphans),
            }
        )
        return orphans

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    @property
    def owners(self) -> dict[int, int]:
        """width class -> owning replica index (live view, copied)."""
        return dict(self._owner)

    def cross_replica_compiles(self) -> int:
        """Compiles beyond one-per-class fleet-wide: how many times a
        class was compiled on a replica that was not its first compiler.
        0 under pure affinity; each spill/failover to a cold replica
        adds one."""
        per_class: dict[int, int] = {}
        for r in self.replicas:
            for cls in r.compiled_classes:
                per_class[cls] = per_class.get(cls, 0) + 1
        return sum(n - 1 for n in per_class.values() if n > 1)

    def plan_hit_rate(self) -> float:
        """Fleet-wide plan-cache hit rate over every dispatched panel."""
        lookups = sum(r.plan_lookups for r in self.replicas)
        hits = sum(r.plan_hits for r in self.replicas)
        return hits / lookups if lookups else 0.0

    def stats(self) -> dict:
        routing: dict[str, int] = {}
        for d in self.decisions:
            routing[d.reason] = routing.get(d.reason, 0) + 1
        return {
            "replicas": len(self.replicas),
            "alive": sum(r.alive for r in self.replicas),
            "width_classes": list(self.width_classes),
            "owners": {str(c): i for c, i in sorted(self._owner.items())},
            "routing": routing,
            "plan_lookups": sum(r.plan_lookups for r in self.replicas),
            "plan_hits": sum(r.plan_hits for r in self.replicas),
            "plan_hit_rate": self.plan_hit_rate(),
            "cross_replica_compiles": self.cross_replica_compiles(),
            "events": list(self.events),
            "per_replica": [
                {
                    "replica": r.index,
                    "alive": r.alive,
                    "dispatches": r.dispatches,
                    "served_jobs": r.served_jobs,
                    "served_cols": r.served_cols,
                    "compiles": r.compiles,
                    "compiled_classes": sorted(r.compiled_classes),
                    "plan_hits": r.plan_hits,
                    "busy_s": r.busy_s,
                }
                for r in self.replicas
            ],
        }


__all__ = [
    "Replica",
    "ReplicaFleet",
    "RoutingDecision",
    "REASON_CLAIM",
    "REASON_AFFINITY",
    "REASON_SPILL",
    "REASON_FAILOVER",
]
