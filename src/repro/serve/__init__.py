from repro.serve.engine import Engine, cache_nbytes  # noqa: F401
