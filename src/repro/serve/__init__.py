from repro.serve.challenge import (  # noqa: F401
    ChallengeResult,
    run_challenge,
)
from repro.serve.clock import (  # noqa: F401
    WALL_CLOCK,
    Clock,
    VirtualClock,
    WallClock,
)
from repro.serve.engine import (  # noqa: F401
    Engine,
    SparseDNNEngine,
    cache_nbytes,
)
from repro.serve.fleet import (  # noqa: F401
    Replica,
    ReplicaFleet,
    RoutingDecision,
)
from repro.serve.frontend import (  # noqa: F401
    CompletedJob,
    FleetFrontend,
    ServiceModel,
)
from repro.serve.loadgen import (  # noqa: F401
    ArrivalJob,
    LoadProfile,
    generate_jobs,
)
from repro.serve.scheduler import (  # noqa: F401
    ContinuousBatcher,
    FaultCounters,
    QueueFull,
    Request,
    RequestQueue,
    ServeStats,
    StepRecord,
    compare_static_continuous,
    poissonish_trace,
    serve_trace_static,
)
