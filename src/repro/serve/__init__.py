from repro.serve.engine import (  # noqa: F401
    Engine,
    SparseDNNEngine,
    cache_nbytes,
)
