from repro.serve.challenge import (  # noqa: F401
    ChallengeResult,
    run_challenge,
)
from repro.serve.engine import (  # noqa: F401
    Engine,
    SparseDNNEngine,
    cache_nbytes,
)
from repro.serve.scheduler import (  # noqa: F401
    ContinuousBatcher,
    FaultCounters,
    QueueFull,
    Request,
    RequestQueue,
    ServeStats,
    StepRecord,
    compare_static_continuous,
    poissonish_trace,
    serve_trace_static,
)
