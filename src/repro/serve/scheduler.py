"""Continuous batching above :class:`repro.serve.SparseDNNEngine`.

The paper's economics — inference cost ∝ stored nonzeros — only survive
contact with real traffic if the *batching* layer keeps kernel panels
full. The one-shot ``SparseDNNEngine.infer`` serves one aligned,
right-padded batch per call, so arrival skew (a trickle of requests per
tick, bursts above capacity) turns directly into pad waste: idle padded
columns ride through every layer's kernel grid. GraphChallenge
(arXiv:2004.01181, arXiv:1909.05631) scores this workload as sustained
rate over request *streams*, which is what this module serves:

* :class:`RequestQueue` — admission, priorities, deadlines, and an aging
  rule that makes starvation impossible;
* :class:`ContinuousBatcher` — each scheduling tick, packs pending
  requests into ONE tile-aligned panel (late arrivals join mid-stream up
  to ``batch_size``; completed requests leave their slots at the step
  boundary), dispatches it through the engine's step API, and books
  per-request latency plus exact grid-step cost;
* :class:`ServeStats` — the GraphChallenge-style accounting: pad-slot
  fraction, kernel grid steps per served row, latency distribution,
  deadline misses;
* :func:`poissonish_trace` / :func:`serve_trace_static` — a
  deterministic bursty arrival trace and the static-aligned-batching
  baseline the benchmark's ``serve`` arm compares against.

Scheduling model: discrete ticks. Every engine step serves a full
L-layer forward for its panel (the resident path does the whole stack in
one ``pallas_call``; splitting a request across ticks would re-stream
its activations through HBM for no kernel saving — see
``docs/serving.md``). "Continuous" therefore means continuous over the
*stream*: slots turn over every step, a request arriving while a panel
is in flight is packed into the very next panel instead of waiting for a
fixed-width batch to fill, and panels are padded only to the kernel tile
(``engine.batch_align``), not to a fixed service width.

Everything here is deterministic: same trace + same knobs → the same
packings, the same grid-step bill, the same ServeStats. The benchmark
gate (``tools/check_bench.py``) relies on that.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Sequence

import jax
import numpy as np

from repro.serve.clock import WALL_CLOCK
from repro.serve.engine import SparseDNNEngine
from repro.testing import faults as _faults

Array = jax.Array


class QueueFull(RuntimeError):
    """Bounded-queue backpressure: admission rejected, caller should
    shed load upstream (or retry later)."""


@dataclasses.dataclass(frozen=True)
class Request:
    """One unit of work: a feature column through the full sparse stack.

    ``priority``: smaller = more urgent (0 is the default class).
    ``deadline``: absolute tick by which the request should complete, or
    None. Deadlines order dispatch *within* a priority class and are
    reported as misses in :class:`ServeStats`; they are not drop-causes.
    """

    rid: int
    features: Array  # (m,) feature column
    arrival: int  # tick the request was admitted
    priority: int = 0
    deadline: int | None = None


class RequestQueue:
    """Pending-request pool with priority + deadline + aging order.

    Dispatch order is by ``(effective_priority, deadline, arrival, rid)``
    where ``effective_priority = priority - waited // age_every``. The
    aging term is the starvation guarantee: every ``age_every`` ticks a
    waiting request climbs one priority class, so any request overtakes
    any finite-priority stream after a bounded wait — there is no
    arrival pattern under which a request waits forever.

    ``max_pending`` bounds the pool: admission past the bound raises
    :class:`QueueFull` (backpressure — an unbounded queue converts
    overload into unbounded latency AND unbounded memory; a bounded one
    converts it into explicit, countable rejections). ``None`` keeps
    the legacy unbounded behaviour.
    """

    def __init__(self, age_every: int = 8, max_pending: int | None = None):
        if age_every < 1:
            raise ValueError("age_every must be >= 1")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        self.age_every = age_every
        self.max_pending = max_pending
        self._pending: list[Request] = []
        self._next_rid = 0

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> tuple[Request, ...]:
        return tuple(self._pending)

    def submit(
        self,
        features: Array,
        *,
        now: int,
        priority: int = 0,
        deadline: int | None = None,
    ) -> int:
        """Admit one request; returns its id. Raises :class:`QueueFull`
        when a ``max_pending`` bound is set and reached."""
        if features.ndim != 1:
            raise ValueError(
                f"features must be one (m,) column, got {features.shape}"
            )
        if (
            self.max_pending is not None
            and len(self._pending) >= self.max_pending
        ):
            raise QueueFull(
                f"request queue at max_pending={self.max_pending}; "
                "shed load upstream"
            )
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(
            Request(rid, features, now, priority, deadline)
        )
        return rid

    def effective_priority(self, req: Request, now: int) -> int:
        return req.priority - (now - req.arrival) // self.age_every

    def oldest_wait(self, now: int) -> int:
        if not self._pending:
            return 0
        return now - min(r.arrival for r in self._pending)

    def pop_batch(self, k: int, now: int) -> list[Request]:
        """Remove and return the ≤ k most urgent pending requests."""
        if k <= 0 or not self._pending:
            return []
        take = self._dispatch_order(now)[:k]
        taken = {r.rid for r in take}
        self._pending = [r for r in self._pending if r.rid not in taken]
        return take

    def _dispatch_order(self, now: int) -> list[Request]:
        inf = float("inf")
        return sorted(
            self._pending,
            key=lambda r: (
                self.effective_priority(r, now),
                r.deadline if r.deadline is not None else inf,
                r.arrival,
                r.rid,
            ),
        )

    def shed_hopeless(
        self, now: int, batch_size: int
    ) -> tuple[list[Request], list[Request]]:
        """Drop deadlined requests that cannot complete in time; returns
        ``(expired, inadmissible)``.

        A panel dispatched at tick t completes at t+1, so a request at
        dispatch position ``p`` (in the queue's own order) finishes no
        earlier than ``now + 1 + p // batch_size``. ``expired`` requests
        are already past deadline at packing time (``deadline < now``);
        ``inadmissible`` ones are not yet expired but their earliest
        completion overshoots. Both classes would burn kernel grid steps
        to produce an answer nobody is waiting for — shedding them at
        packing time is what keeps *goodput* (useful completions per
        offered request) from collapsing under overload. Positions are
        recomputed as hopeless requests are removed, so a request is
        only shed if it cannot make it even AFTER the queue ahead of it
        is thinned.
        """
        if not self._pending:
            return [], []
        expired: list[Request] = []
        inadmissible: list[Request] = []
        keep: list[Request] = []
        pos = 0
        for r in self._dispatch_order(now):
            if r.deadline is None:
                keep.append(r)
                pos += 1
                continue
            earliest_done = now + 1 + pos // batch_size
            if earliest_done > r.deadline:
                (expired if r.deadline < now else inadmissible).append(r)
            else:
                keep.append(r)
                pos += 1
        if expired or inadmissible:
            kept = {r.rid for r in keep}
            self._pending = [r for r in self._pending if r.rid in kept]
        return expired, inadmissible


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """One engine dispatch as the scheduler saw it."""

    tick: int
    request_ids: tuple[int, ...]
    occupancy: int  # real request columns in the panel
    padded_width: int  # panel width after tile alignment
    grid_steps: int  # exact kernel grid steps billed for the panel
    pallas_calls: int
    resident: bool
    width_class: int | None = None  # plan width the panel compiled at
    plan_cache_hit: bool | None = None  # compiled-plan reuse vs build
    retries: int = 0  # transient-failure retries before success
    quarantined: int = 0  # non-finite output columns failed per-request
    plan_level: str | None = None  # degradation level the panel ran at
    degraded: bool = False  # level below the engine's preferred one


@dataclasses.dataclass
class FaultCounters:
    """Serving fault accounting (docs/robustness.md).

    ``offered`` counts every admission attempt, accepted or not — it is
    the goodput denominator. The loss buckets are disjoint: a request
    ends up in exactly one of rejected / shed / quarantined / failed /
    completed (late or on time).
    """

    offered: int = 0  # submit() attempts (accepted + rejected)
    rejected: int = 0  # bounded-queue backpressure rejections
    shed_expired: int = 0  # already past deadline at packing time
    shed_inadmissible: int = 0  # could not finish before deadline
    quarantined: int = 0  # non-finite output, failed individually
    failed: int = 0  # lost to exhausted step retries
    retried_steps: int = 0  # transient-failure retries (step-level)
    failed_steps: int = 0  # panels lost after retry exhaustion
    straggler_ticks: int = 0  # injected/observed slow ticks
    completed_late: int = 0  # served, but past deadline

    @property
    def shed(self) -> int:
        return self.shed_expired + self.shed_inadmissible

    def goodput(self, completed: int) -> float:
        """Useful completions / offered requests. Late completions are
        not useful; a fault-free run scores 1.0 by construction."""
        offered = self.offered if self.offered else completed
        if offered <= 0:
            return 1.0
        return (completed - self.completed_late) / offered

    def as_dict(self) -> dict:
        return {
            "offered": self.offered,
            "rejected": self.rejected,
            "shed_expired": self.shed_expired,
            "shed_inadmissible": self.shed_inadmissible,
            "shed": self.shed,
            "quarantined": self.quarantined,
            "failed": self.failed,
            "retried_steps": self.retried_steps,
            "failed_steps": self.failed_steps,
            "straggler_ticks": self.straggler_ticks,
            "completed_late": self.completed_late,
        }


@dataclasses.dataclass
class ServeStats:
    """Aggregate serving report — the fields the benchmark's ``serve``
    arm records and ``tools/check_bench.py`` gates on.

    ``pad_slot_fraction`` = 1 − rows/padded-slots: the fraction of every
    kernel panel that was alignment padding (idle grid work).
    ``grid_steps_per_row`` is the kernel-step cost of one served request
    — the nnz-proportional rate metric, GraphChallenge-style.
    """

    requests: int
    engine_steps: int
    idle_ticks: int
    rows_served: int
    padded_slots: int
    pad_slot_fraction: float
    grid_steps_total: int
    grid_steps_per_row: float
    latency_mean: float
    latency_p50: float
    latency_max: int
    deadline_misses: int
    latencies: dict[int, int]  # rid → ticks from arrival to completion
    steps: list[StepRecord]
    # Compiled-plan accounting (repro.plan): how many engine steps
    # rebuilt/recompiled a plan, per width class — with width-class
    # quantization a handful of classes should absorb every panel.
    plan_recompiles_by_class: dict[int, int] = dataclasses.field(
        default_factory=dict
    )
    plan_cache_hit_rate: float = 0.0
    # Fault accounting (docs/robustness.md): loss buckets + goodput =
    # on-time completions / offered requests. Fault-free legacy callers
    # get empty counters and goodput 1.0.
    faults: FaultCounters = dataclasses.field(default_factory=FaultCounters)
    goodput: float = 1.0

    @classmethod
    def from_steps(
        cls,
        steps: Sequence[StepRecord],
        latencies: dict[int, int],
        deadline_misses: int,
        idle_ticks: int,
        faults: FaultCounters | None = None,
    ) -> "ServeStats":
        faults = faults if faults is not None else FaultCounters()
        rows = sum(s.occupancy for s in steps)
        padded = sum(s.padded_width for s in steps)
        lat = sorted(latencies.values())
        recompiles: dict[int, int] = {}
        plan_lookups = plan_hits = 0
        for s in steps:
            if s.plan_cache_hit is None:
                continue
            plan_lookups += 1
            if s.plan_cache_hit:
                plan_hits += 1
            else:
                cls_w = (
                    s.width_class if s.width_class is not None
                    else s.padded_width
                )
                recompiles[cls_w] = recompiles.get(cls_w, 0) + 1
        return cls(
            requests=len(latencies),
            engine_steps=len(steps),
            idle_ticks=idle_ticks,
            rows_served=rows,
            padded_slots=padded,
            pad_slot_fraction=1.0 - rows / padded if padded else 0.0,
            grid_steps_total=sum(s.grid_steps for s in steps),
            grid_steps_per_row=(
                sum(s.grid_steps for s in steps) / rows if rows else 0.0
            ),
            latency_mean=float(np.mean(lat)) if lat else 0.0,
            latency_p50=float(np.median(lat)) if lat else 0.0,
            latency_max=max(lat) if lat else 0,
            deadline_misses=deadline_misses,
            latencies=dict(latencies),
            steps=list(steps),
            plan_recompiles_by_class=recompiles,
            plan_cache_hit_rate=(
                plan_hits / plan_lookups if plan_lookups else 0.0
            ),
            faults=faults,
            goodput=faults.goodput(len(latencies)),
        )

    def summary(self) -> dict:
        """JSON-ready scalars (drops the per-request / per-step detail)."""
        return {
            "requests": self.requests,
            "engine_steps": self.engine_steps,
            "idle_ticks": self.idle_ticks,
            "rows_served": self.rows_served,
            "padded_slots": self.padded_slots,
            "pad_slot_fraction": self.pad_slot_fraction,
            "grid_steps_total": self.grid_steps_total,
            "grid_steps_per_row": self.grid_steps_per_row,
            "latency_mean": self.latency_mean,
            "latency_p50": self.latency_p50,
            "latency_max": self.latency_max,
            "deadline_misses": self.deadline_misses,
            "plan_recompiles_by_class": {
                str(k): v
                for k, v in sorted(self.plan_recompiles_by_class.items())
            },
            "plan_cache_hit_rate": self.plan_cache_hit_rate,
            "goodput": self.goodput,
            "faults": self.faults.as_dict(),
        }


class ContinuousBatcher:
    """Packs the request stream into tile-aligned engine panels.

    Knobs:

    * ``batch_size`` — slot capacity of one panel (requests beyond it
      wait; arrivals join mid-stream as slots free up each step);
    * ``min_fill`` / ``max_wait`` — dispatch holds off while the panel
      would be emptier than ``min_fill · batch_size`` AND no pending
      request has waited ``max_wait`` ticks yet. ``min_fill=0`` serves
      every tick (latency-optimal); raising it trades bounded latency
      (≤ ``max_wait`` + 1 ticks) for fuller, less-padded panels.
    * ``width_classes`` — quantize each panel's width UP to the smallest
      listed class before dispatch (``repro.plan.quantize_width``). A
      few classes absorb every occupancy the trace produces, so the
      engine's :class:`repro.plan.PlanCache` compiles a handful of
      plans once and reuses them — instead of recompiling on every new
      panel width. The extra pad slots are billed honestly
      (``pad_slot_fraction`` sees them); ``None`` disables quantization
      (pad to the kernel tile only). Per-class recompile counts land in
      :class:`ServeStats`.

    * ``max_pending`` — bounds the request queue; admission past it is
      REJECTED (``submit`` returns None, counted in the fault stats) —
      backpressure instead of unbounded latency. ``None`` = unbounded.
    * ``enforce_deadlines`` — shed deadlined requests that cannot
      complete in time at packing time (``RequestQueue.shed_hopeless``)
      instead of serving them late: shed requests count as deadline
      misses, never as completions. ``False`` restores the record-only
      legacy behaviour.
    * ``fault_injector`` — a ``repro.testing.faults.FaultInjector``
      polled at the tick-keyed sites (straggler); pass the same
      injector to the engine for the dispatch-keyed sites.

    The batcher owns the clock: one ``step()`` = one tick. Completed
    requests' outputs are available via :meth:`result`; requests lost
    to quarantine / shedding / rejection / step failure are in
    :attr:`failures` with a reason string. :meth:`stats` rolls all of
    it into :class:`ServeStats` (fault counters + goodput).
    """

    def __init__(
        self,
        engine: SparseDNNEngine,
        *,
        batch_size: int = 64,
        min_fill: float = 0.0,
        max_wait: int = 4,
        age_every: int = 8,
        width_classes: Sequence[int] | None = None,
        max_pending: int | None = None,
        enforce_deadlines: bool = True,
        fault_injector=None,
        clock=None,
    ):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if not 0.0 <= min_fill <= 1.0:
            raise ValueError("min_fill must be in [0, 1]")
        if engine.staged:
            raise ValueError("engine already has staged columns")
        if width_classes is not None:
            width_classes = tuple(sorted(int(c) for c in width_classes))
            if not width_classes or min(width_classes) < 1:
                raise ValueError("width_classes must be positive ints")
            if max(width_classes) < batch_size:
                raise ValueError(
                    f"largest width class {max(width_classes)} is below "
                    f"batch_size {batch_size}; full panels would spill "
                    "past every class"
                )
        self.engine = engine
        self.batch_size = batch_size
        self.min_fill = min_fill
        self.max_wait = max_wait
        self.width_classes = width_classes
        self.enforce_deadlines = enforce_deadlines
        self.fault_injector = fault_injector
        # Straggler stalls (and any future wall-clock wait) go through
        # the injectable clock (repro.serve.clock) so tests can run
        # faulted traces without real sleeps.
        self.clock = clock if clock is not None else WALL_CLOCK
        self.queue = RequestQueue(age_every=age_every, max_pending=max_pending)
        self._tick = 0
        self._idle_ticks = 0
        self._results: dict[int, Array] = {}
        self._latencies: dict[int, int] = {}
        self._deadline_misses = 0
        self._steps: list[StepRecord] = []
        self._faults = FaultCounters()
        self._failures: dict[int, str] = {}  # rid → failure reason

    @property
    def tick(self) -> int:
        return self._tick

    @property
    def completed(self) -> int:
        return len(self._latencies)

    @property
    def failures(self) -> dict[int, str]:
        """rid → reason, for every admitted request lost to a fault path
        (shed / quarantined / failed step). Rejected submissions never
        got an rid; they are only counted in ``stats().faults``."""
        return dict(self._failures)

    def submit(
        self,
        features: Array,
        *,
        priority: int = 0,
        deadline: int | None = None,
    ) -> int | None:
        """Admit one request at the current tick; returns its id — or
        ``None`` when the bounded queue rejects it (backpressure; the
        rejection is counted in the fault stats)."""
        self._faults.offered += 1
        try:
            return self.queue.submit(
                features, now=self._tick, priority=priority,
                deadline=deadline,
            )
        except QueueFull:
            self._faults.rejected += 1
            return None

    def result(self, rid: int) -> Array:
        """The (m,) output column of a completed request."""
        return self._results[rid]

    def _should_dispatch(self) -> bool:
        pending = len(self.queue)
        if pending == 0:
            return False
        if pending >= self.batch_size:
            return True
        if pending >= self.min_fill * self.batch_size:
            return True
        return self.queue.oldest_wait(self._tick) >= self.max_wait

    def step(self, *, force: bool = False) -> StepRecord | None:
        """Advance one tick; dispatch one panel if the policy says so.

        Packing invariants (tested in ``tests/test_scheduler.py``):
        occupancy ≤ ``batch_size``; the panel is padded only to the
        engine's tile (``batch_align``); every slot is tagged with its
        request id; completed requests leave at the step boundary, so a
        request arriving between steps joins the next panel whenever a
        slot is free — never behind a fixed-width batch quota.

        Fault paths (docs/robustness.md): deadlined requests that cannot
        complete in time are shed BEFORE packing; a panel whose retries
        are exhausted fails its member requests individually instead of
        raising; non-finite output columns are quarantined per-request.
        The stream keeps ticking through all three.
        """
        inj = self.fault_injector
        if inj is not None:
            spec = inj.fires(_faults.SITE_STRAGGLER, self._tick)
            if spec is not None:
                self._faults.straggler_ticks += 1
                self.clock.sleep(float(spec.get("seconds", 0.0)))
        if self.enforce_deadlines:
            expired, inadmissible = self.queue.shed_hopeless(
                self._tick, self.batch_size
            )
            self._faults.shed_expired += len(expired)
            self._faults.shed_inadmissible += len(inadmissible)
            for req in expired:
                self._deadline_misses += 1
                self._failures[req.rid] = (
                    f"shed: already past deadline {req.deadline} "
                    f"at tick {self._tick}"
                )
            for req in inadmissible:
                self._deadline_misses += 1
                self._failures[req.rid] = (
                    f"shed: cannot complete by deadline {req.deadline} "
                    f"from tick {self._tick}"
                )
        record = None
        if self._should_dispatch() or (force and len(self.queue)):
            batch = self.queue.pop_batch(self.batch_size, self._tick)
            cols = jax.numpy.stack([r.features for r in batch], axis=1)
            self.engine.submit(cols, request_ids=[r.rid for r in batch])
            pad_to = None
            if self.width_classes is not None:
                from repro.plan import quantize_width

                pad_to = quantize_width(len(batch), self.width_classes)
            out, estats = self.engine.step(pad_to=pad_to)
            self._faults.retried_steps += int(estats.get("retries", 0))
            if out is None or estats.get("failed"):
                # Panel lost after retry exhaustion: fail its requests
                # individually and keep serving — a dead step must not
                # take the stream down with it.
                self._faults.failed_steps += 1
                self._faults.failed += len(batch)
                reason = (
                    f"step failed: {estats.get('error') or 'unknown error'}"
                )
                for req in batch:
                    self._failures[req.rid] = reason
                self._tick += 1
                return None
            quarantined = set(estats.get("quarantined_request_ids") or ())
            done_tick = self._tick + 1  # service completes at tick end
            for j, req in enumerate(batch):
                if req.rid in quarantined:
                    self._faults.quarantined += 1
                    self._failures[req.rid] = (
                        "quarantined: non-finite output column"
                    )
                    continue
                self._results[req.rid] = out[:, j]
                self._latencies[req.rid] = done_tick - req.arrival
                if req.deadline is not None and done_tick > req.deadline:
                    self._deadline_misses += 1
                    self._faults.completed_late += 1
            plan_stats = estats.get("plan") or {}
            record = StepRecord(
                tick=self._tick,
                request_ids=tuple(r.rid for r in batch),
                occupancy=estats["batch"],
                padded_width=estats["padded_batch"],
                grid_steps=estats["grid_steps"],
                pallas_calls=estats["pallas_calls"],
                resident=estats["resident"],
                width_class=plan_stats.get("width_class"),
                plan_cache_hit=plan_stats.get("cache_hit"),
                retries=int(estats.get("retries", 0)),
                quarantined=len(quarantined),
                plan_level=plan_stats.get("level"),
                degraded=bool(plan_stats.get("degraded", False)),
            )
            self._steps.append(record)
        else:
            self._idle_ticks += 1
        self._tick += 1
        return record

    def drain(self) -> list[StepRecord]:
        """Step (forced) until no request is pending."""
        records = []
        while len(self.queue):
            rec = self.step(force=True)
            if rec is not None:
                records.append(rec)
        return records

    def run_trace(self, trace: Sequence[Sequence[Array]]) -> ServeStats:
        """Serve an arrival trace: ``trace[t]`` = feature columns arriving
        at tick t. One scheduler step per tick, then a forced drain."""
        for arrivals in trace:
            for features in arrivals:
                self.submit(features)
            self.step()
        self.drain()
        return self.stats()

    def stats(self) -> ServeStats:
        return ServeStats.from_steps(
            self._steps, self._latencies, self._deadline_misses,
            self._idle_ticks, faults=self._faults,
        )


def poissonish_trace(
    n_requests: int,
    *,
    m: int,
    lam: float = 4.0,
    burst_every: int = 16,
    burst_size: int = 0,
    seed: int = 0,
) -> list[list[Array]]:
    """Deterministic bursty arrival trace: ``trace[t]`` is the list of
    (m,) feature columns arriving at tick t.

    Per-tick counts are Poisson(``lam``) draws from a seeded NumPy
    generator, with an extra ``burst_size`` arrivals every
    ``burst_every`` ticks (the skew that makes static batching pad).
    Same arguments → bit-identical trace, including feature values —
    the determinism the benchmark baseline and tests rely on.
    """
    if lam <= 0 and not (burst_size and burst_every):
        raise ValueError(
            "lam <= 0 with no bursts can never produce an arrival; "
            "the trace would grow forever"
        )
    rng = np.random.default_rng(seed)
    trace: list[list[Array]] = []
    total = 0
    t = 0
    while total < n_requests:
        count = int(rng.poisson(lam))
        if burst_size and burst_every and t % burst_every == burst_every - 1:
            count += burst_size
        count = min(count, n_requests - total)
        cols = [
            jax.numpy.asarray(
                rng.uniform(0.0, 1.0, size=(m,)).astype(np.float32)
            )
            for _ in range(count)
        ]
        trace.append(cols)
        total += count
        t += 1
    return trace


def serve_trace_static(
    engine: SparseDNNEngine, trace: Iterable[Sequence[Array]]
) -> ServeStats:
    """The pre-scheduler baseline: static aligned batching.

    Every tick's arrivals are served immediately through the one-shot
    ``infer`` API — one aligned, right-padded batch per call at the
    engine's ``batch_align`` (construct the engine with ``batch_align =
    batch_size`` for the classic fixed-service-width setup). No
    cross-tick packing: a 3-request tick pays for a full aligned panel,
    which is exactly the pad waste the continuous batcher removes.
    """
    steps: list[StepRecord] = []
    latencies: dict[int, int] = {}
    rid = 0
    for t, arrivals in enumerate(trace):
        if not arrivals:
            continue
        cols = jax.numpy.stack(list(arrivals), axis=1)
        out, estats = engine.infer(cols)
        ids = tuple(range(rid, rid + len(arrivals)))
        rid += len(arrivals)
        for r in ids:
            latencies[r] = 1  # served the tick it arrived
        plan_stats = estats.get("plan") or {}
        steps.append(
            StepRecord(
                tick=t,
                request_ids=ids,
                occupancy=estats["batch"],
                padded_width=estats["padded_batch"],
                grid_steps=estats["grid_steps"],
                pallas_calls=estats["pallas_calls"],
                resident=estats["resident"],
                width_class=plan_stats.get("width_class"),
                plan_cache_hit=plan_stats.get("cache_hit"),
            )
        )
    return ServeStats.from_steps(steps, latencies, 0, idle_ticks=0)


def compare_static_continuous(
    make_engine,
    trace: Sequence[Sequence[Array]],
    *,
    batch_size: int = 64,
    tile_align: int = 8,
    min_fill: float = 0.0,
    max_wait: int = 4,
) -> dict:
    """Run the same trace through static aligned batching and the
    continuous batcher; return both :class:`ServeStats` plus the
    head-to-head ratios and per-arm wall-clock the benchmark records
    (wall-clock is indicative only — interpret-mode kernels off-TPU).

    ``make_engine(batch_align)`` must build a fresh engine over the same
    weights (fresh, so served/step counters don't leak across arms).
    """
    t0 = time.perf_counter()
    static = serve_trace_static(make_engine(batch_size), trace)
    t_static = time.perf_counter() - t0
    batcher = ContinuousBatcher(
        make_engine(tile_align),
        batch_size=batch_size,
        min_fill=min_fill,
        max_wait=max_wait,
    )
    t0 = time.perf_counter()
    continuous = batcher.run_trace(trace)
    t_continuous = time.perf_counter() - t0
    assert continuous.requests == static.requests, (
        continuous.requests,
        static.requests,
    )
    return {
        "static": static,
        "continuous": continuous,
        "batcher": batcher,
        "pad_fraction_ratio": (
            continuous.pad_slot_fraction / static.pad_slot_fraction
            if static.pad_slot_fraction
            else float("inf")
        ),
        "grid_steps_ratio": (
            continuous.grid_steps_total / static.grid_steps_total
            if static.grid_steps_total
            else float("inf")
        ),
        "wall_time_s": {"static": t_static, "continuous": t_continuous},
    }


__all__ = [
    "Request",
    "RequestQueue",
    "QueueFull",
    "StepRecord",
    "FaultCounters",
    "ServeStats",
    "ContinuousBatcher",
    "poissonish_trace",
    "serve_trace_static",
    "compare_static_continuous",
]
