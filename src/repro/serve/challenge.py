"""GraphChallenge-shaped streaming inference driver.

Pushes a seeded sparse input set through :class:`SparseDNNEngine` in
width-classed panels — the serving shape of the Sparse DNN GraphChallenge
(arXiv 2004.01181): a ``neurons × layers`` RadiX-net topology
(`repro.data.radixnet`), a {0, 1} input panel with the challenge's 60 000
inputs as columns, and the official rate metric

    edges × inputs / second,   edges = layers · neurons · 32

reported per run. Every panel goes through the engine's normal
submit/step path, so runs exercise exactly what production serving
exercises: plan-cache width classes, the degradation ladder, fused /
fused-tiled / layered / sharded routing — a mesh makes this the
"sharded engine" leg of the conformance suite.

The driver never materialises the full output set: each step's panel is
reduced to its per-column activity mask on the spot, and the run's
answer is the challenge category set (indices of inputs with any
positive final activation), bit-comparable against
``repro.data.radixnet.radixnet_reference``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import radixnet as rx
from repro.serve.engine import SparseDNNEngine


@dataclasses.dataclass(frozen=True)
class ChallengeResult:
    """One challenge run's scorecard."""

    spec: rx.RadixNetSpec
    n_inputs: int
    categories: np.ndarray  # ground-truth-comparable answer set
    seconds: float  # timed serving loop (post-warmup)
    edge_inputs_per_sec: float  # the official challenge metric
    steps: int  # engine steps dispatched
    served: int  # input columns served (== n_inputs)
    routes: tuple[str, ...]  # distinct plan routes seen, in order
    levels: tuple[str, ...]  # distinct ladder levels seen, in order
    width_classes: tuple[int, ...]  # distinct padded widths seen
    grid_steps: int  # summed kernel grid-step bill

    @property
    def edges(self) -> int:
        return self.spec.edges


def _ordered_unique(values) -> tuple:
    seen: dict[Any, None] = {}
    for v in values:
        seen.setdefault(v)
    return tuple(seen)


def run_challenge(
    spec: rx.RadixNetSpec,
    *,
    n_inputs: int = 60000,
    panel_width: int = 512,
    batch_align: int = 32,
    density: float = 0.3,
    seed: int = 0,
    mesh: Any = None,
    use_resident: bool | None = None,
    engine: SparseDNNEngine | None = None,
    warmup: bool = True,
    block_size: int = 16,
    tuning_table: Any = None,
    panel_dtype: Any = None,
) -> ChallengeResult:
    """Stream ``n_inputs`` seeded inputs through the engine, panelwise.

    ``engine``: pass a prebuilt engine (e.g. with a fault injector or a
    shared plan cache) — it must serve the spec's topology; by default
    the driver builds one from :func:`repro.data.radixnet
    .radixnet_weights` with the given ``mesh``/``use_resident``.
    ``warmup`` runs one untimed panel of the same width class first so
    the metric bills steady-state serving, not plan compilation.
    ``tuning_table``/``panel_dtype`` thread straight into the default
    engine (``repro.tune``): a table hit on this spec's fingerprint —
    or an explicit bf16-panel override — retunes every panel's plan.
    """
    if engine is None:
        weights, biases = rx.radixnet_weights(spec, block_size=block_size)
        engine = SparseDNNEngine(
            weights,
            biases,
            batch_align=batch_align,
            mesh=mesh,
            use_resident=use_resident,
            tuning_table=tuning_table,
            panel_dtype=panel_dtype,
        )
    panel = jnp.asarray(
        rx.radixnet_input_panel(
            spec.neurons, n_inputs, density=density, seed=seed
        )
    )
    if warmup:
        engine.submit(panel[:, : min(panel_width, n_inputs)])
        out, _ = engine.step(pad_to=panel_width)
        if out is not None:
            jax.block_until_ready(out)

    active = np.zeros((n_inputs,), dtype=bool)
    step_stats: list[dict] = []
    steps = served = grid_steps = 0
    t0 = time.perf_counter()
    for start in range(0, n_inputs, panel_width):
        chunk = panel[:, start : start + panel_width]
        engine.submit(chunk)
        out, stats = engine.step(pad_to=panel_width)
        if out is None or stats["failed"]:
            raise RuntimeError(
                f"challenge panel at column {start} failed: "
                f"{stats.get('error', 'no output')}"
            )
        width = chunk.shape[1]
        active[start : start + width] = np.asarray(
            (out[:, :width] > 0).any(axis=0)
        )
        steps += 1
        served += stats["batch"]
        grid_steps += stats["grid_steps"]
        step_stats.append(stats)
    jax.block_until_ready(out)
    seconds = time.perf_counter() - t0

    return ChallengeResult(
        spec=spec,
        n_inputs=n_inputs,
        categories=np.flatnonzero(active).astype(np.int64),
        seconds=seconds,
        edge_inputs_per_sec=spec.edges * n_inputs / max(seconds, 1e-9),
        steps=steps,
        served=served,
        routes=_ordered_unique(
            s["plan"]["route"] for s in step_stats if s["plan"]
        ),
        levels=_ordered_unique(
            s["plan"]["level"] for s in step_stats if s["plan"]
        ),
        width_classes=_ordered_unique(
            s["padded_batch"] for s in step_stats
        ),
        grid_steps=grid_steps,
    )
