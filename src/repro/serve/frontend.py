"""Wall-clock serving front-end for a replicated engine fleet.

This is the layer that turns the tick-based serving stack into a
*timed* one: :class:`FleetFrontend` runs a discrete-event loop over an
injectable :class:`repro.serve.clock.Clock` — real arrival timestamps
(``repro.serve.loadgen`` traces), absolute deadlines, bounded-queue
backpressure — and drives a :class:`repro.serve.fleet.ReplicaFleet`
through its width-class-affinity router.

Two times, one code path:

* **event time** comes from the clock. Under :class:`WallClock` the
  loop sleeps until each event really happens; under
  :class:`VirtualClock` the same loop advances simulated time instantly,
  so a minutes-long bursty trace with deadlines, replica loss and slow
  nodes runs in milliseconds of CI time and is bit-identical run to run.
* **service time** is a deterministic :class:`ServiceModel` over the
  engine's exact grid-step bill (``base + grid_steps × per_step``).
  Engine compute really runs at dispatch (outputs are real); the
  *latency* a dispatch is charged is the model's, so throughput-vs-p99
  curves are a pure function of (trace, fleet, model) — gateable in CI
  byte-for-byte — while staying proportional to the kernel work the
  paper's nnz-scaling argument is about.

Backpressure: admitted-but-unfinished work is bounded by
``max_pending_cols``; an arrival that would exceed it is REJECTED at
admission (counted, never queued) — the open-loop generator does not
slow down, so overload shows up honestly as rejections + deadline
misses rather than as an unbounded queue.

Fault sites (``repro.testing.faults``), keyed by fleet dispatch
ordinal: ``SITE_REPLICA_LOSS`` (payload ``replica=k``) kills replica k
right before the Nth dispatch — its queued AND in-flight jobs re-route
to the survivors (reason ``"failover"``), so the loss costs latency,
never a dropped request; ``SITE_REPLICA_SLOW`` (payload ``factor=x``)
multiplies the Nth dispatch's service time (a degraded node).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Sequence

from repro.serve.clock import Clock, WALL_CLOCK
from repro.serve.fleet import REASON_FAILOVER, Replica, ReplicaFleet
from repro.serve.loadgen import ArrivalJob
from repro.testing import faults as _faults


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Deterministic service time for one dispatched panel.

    ``base_s`` is the per-dispatch overhead (launch + pad + readback);
    ``per_grid_step_s`` prices each kernel grid step, so service time
    scales with the *actual* sparse work of the padded panel — wider
    classes and deeper stacks cost proportionally more, exactly the
    hardware-independent accounting the step stats already carry.
    """

    base_s: float = 1e-3
    per_grid_step_s: float = 1e-5

    def service_s(self, stats: dict) -> float:
        return self.base_s + self.per_grid_step_s * float(stats["grid_steps"])


@dataclasses.dataclass(frozen=True)
class CompletedJob:
    """One finished (or gracefully failed) job, with its timings."""

    rid: int
    replica: int
    width_class: int
    cols: int
    arrival: float
    completed: float
    latency: float
    deadline: float | None
    deadline_miss: bool
    failed: bool
    quarantined_cols: int


@dataclasses.dataclass(frozen=True)
class _InFlight:
    job: ArrivalJob
    replica: int
    out: Any
    stats: dict
    dispatched: float
    service: float


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not sorted_vals:
        return 0.0
    rank = max(1, math.ceil(q * len(sorted_vals)))
    return float(sorted_vals[min(rank, len(sorted_vals)) - 1])


class FleetFrontend:
    """Discrete-event front-end: arrivals → admission → router → fleet.

    One frontend instance runs one trace (``run``); construct fresh for
    the next. ``results`` maps job rid → output panel (m, k) for every
    completed job — reference tests compare these against a
    single-engine pass over the same features.
    """

    def __init__(
        self,
        fleet: ReplicaFleet,
        *,
        clock: Clock | None = None,
        service_model: ServiceModel | None = None,
        max_pending_cols: int | None = None,
        fault_injector: Any = None,
    ):
        if max_pending_cols is not None and max_pending_cols < 1:
            raise ValueError(
                f"max_pending_cols must be >= 1, got {max_pending_cols}"
            )
        self.fleet = fleet
        self.clock = clock if clock is not None else WALL_CLOCK
        self.service_model = (
            service_model if service_model is not None else ServiceModel()
        )
        self.max_pending_cols = max_pending_cols
        self.fault_injector = fault_injector
        self.completed: list[CompletedJob] = []
        self.rejected: list[int] = []  # rids bounced at admission
        self.requeues: dict[int, int] = {}  # rid -> failover count
        self.results: dict[int, Any] = {}
        self._events: list[tuple] = []  # (t, seq, kind, payload) heap
        self._seq = 0
        self._pending_cols = 0
        self._dispatches = 0  # fleet dispatch ordinal (fault-site key)
        self._next_token = 0
        self._inflight: dict[int, _InFlight] = {}
        self._replica_token: dict[int, int] = {}
        self._ran = False
        # Trace timestamps are relative to trace time 0; the clock's
        # epoch is arbitrary (time.monotonic). ``run`` anchors trace
        # time 0 to the clock reading at loop start, so the same trace
        # replays identically under WallClock and VirtualClock(start=0).
        self._base = 0.0

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------

    def _push(self, t: float, kind: str, payload: Any) -> None:
        heapq.heappush(self._events, (float(t), self._seq, kind, payload))
        self._seq += 1

    def run(self, jobs: Sequence[ArrivalJob]) -> dict:
        """Serve one open-loop trace to completion; return the stats."""
        if self._ran:
            raise RuntimeError(
                "a FleetFrontend runs one trace; construct a fresh one"
            )
        self._ran = True
        jobs = sorted(jobs, key=lambda j: (j.t, j.rid))
        if not jobs:
            return self.stats()
        self._base = self.clock.now()
        for job in jobs:
            self._push(self._base + job.t, "arrive", job)
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            dt = t - self.clock.now()
            if dt > 0:
                self.clock.sleep(dt)
            if kind == "arrive":
                self._on_arrival(payload)
            else:
                self._on_complete(payload)
            self._pump_all()
        return self.stats(span=max(self.clock.now() - self._base, 1e-9))

    def _on_arrival(self, job: ArrivalJob) -> None:
        if (
            self.max_pending_cols is not None
            and self._pending_cols + job.cols > self.max_pending_cols
        ):
            self.rejected.append(job.rid)
            return
        self._pending_cols += job.cols
        self.fleet.route(job)

    def _on_complete(self, token: int) -> None:
        rec = self._inflight.pop(token, None)
        if rec is None:
            return  # cancelled: the replica died mid-flight, job re-routed
        replica = self.fleet.replicas[rec.replica]
        replica.inflight = None
        self._replica_token.pop(rec.replica, None)
        replica.busy_s += rec.service
        self._finish(rec.job, replica, out=rec.out, stats=rec.stats)

    def _pump_all(self) -> None:
        """Dispatch until no live replica has a free slot and a queue.
        A dispatch can kill a replica and re-route its jobs, so iterate
        to a fixpoint (replica order is deterministic)."""
        progress = True
        while progress:
            progress = False
            for replica in self.fleet.replicas:
                if replica.alive and replica.inflight is None and replica.queue:
                    self._dispatch(replica)
                    progress = True

    def _dispatch(self, replica: Replica) -> None:
        inj = self.fault_injector
        ordinal = self._dispatches
        if inj is not None:
            spec = inj.fires(_faults.SITE_REPLICA_LOSS, ordinal)
            if spec is not None:
                # Fires BEFORE dispatch N; the dispatch itself retries
                # on whoever survives (same ordinal).
                self._handle_loss(int(spec["replica"]), spec)
                return
        job = replica.queue.popleft()
        self._dispatches += 1
        factor = 1.0
        if inj is not None:
            slow = inj.fires(_faults.SITE_REPLICA_SLOW, ordinal)
            if slow is not None:
                factor = float(slow.get("factor", 2.0))
                if factor < 1.0:
                    raise ValueError(
                        f"replica-slow factor must be >= 1, got {factor}"
                    )
        cls = self.fleet.width_class(job.cols)
        replica.engine.submit(job.features)
        out, stats = replica.engine.step(pad_to=cls)
        replica.observe_step(stats)
        if stats.get("failed"):
            # Graceful engine failure: the job is finished-as-failed at
            # dispatch time; the replica slot frees immediately.
            self._finish(job, replica, out=None, stats=stats)
            return
        now = self.clock.now()
        service = self.service_model.service_s(stats) * factor
        token = self._next_token
        self._next_token += 1
        self._inflight[token] = _InFlight(job, replica.index, out, stats, now, service)
        replica.inflight = job
        self._replica_token[replica.index] = token
        self._push(now + service, "complete", token)

    def _handle_loss(self, index: int, spec: dict) -> None:
        token = self._replica_token.pop(index, None)
        if token is not None:
            # Invalidate the in-flight completion; fail_replica hands
            # the job back below and it re-routes like the queued ones.
            self._inflight.pop(token, None)
        orphans = self.fleet.fail_replica(
            index,
            at=self.clock.now() - self._base,
            reason=spec.get("reason", "injected replica loss"),
        )
        for job in orphans:
            self.fleet.route(job, reason=REASON_FAILOVER)
            self.requeues[job.rid] = self.requeues.get(job.rid, 0) + 1

    def _finish(
        self, job: ArrivalJob, replica: Replica, *, out: Any, stats: dict
    ) -> None:
        # Times in the record are trace-relative (subtract the base) so
        # reports read the same under WallClock and VirtualClock.
        now = self.clock.now() - self._base
        failed = bool(stats.get("failed"))
        miss = job.deadline is not None and now > job.deadline
        self._pending_cols -= job.cols
        if not failed:
            self.results[job.rid] = out
        self.completed.append(
            CompletedJob(
                rid=job.rid,
                replica=replica.index,
                width_class=self.fleet.width_class(job.cols),
                cols=job.cols,
                arrival=job.t,
                completed=now,
                latency=now - job.t,
                deadline=job.deadline,
                deadline_miss=miss or failed,
                failed=failed,
                quarantined_cols=len(stats.get("quarantined_request_ids") or ()),
            )
        )

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self, span: float | None = None) -> dict:
        """Trace-level serving report: latency percentiles, goodput per
        replica, routing + fault accounting. ``span`` is the wall (or
        virtual) seconds from first arrival to loop drain; rates are 0
        when it is unknown (empty trace)."""
        served = [c for c in self.completed if not c.failed]
        lat = sorted(c.latency for c in served)
        on_time = [c for c in served if not c.deadline_miss]
        offered = len(self.completed) + len(self.rejected)
        misses = sum(c.deadline_miss for c in self.completed)
        per_replica_cols: dict[int, int] = {}
        for c in on_time:
            per_replica_cols[c.replica] = (
                per_replica_cols.get(c.replica, 0) + c.cols
            )
        fleet = self.fleet.stats()
        for entry in fleet["per_replica"]:
            cols = per_replica_cols.get(entry["replica"], 0)
            entry["on_time_cols"] = cols
            entry["goodput_cols_per_s"] = cols / span if span else 0.0
        return {
            "offered_jobs": offered,
            "admitted_jobs": len(self.completed),
            "rejected_jobs": len(self.rejected),
            "served_jobs": len(served),
            "failed_jobs": len(self.completed) - len(served),
            "served_cols": sum(c.cols for c in served),
            "quarantined_cols": sum(c.quarantined_cols for c in served),
            "deadline_misses": int(misses),
            # Misses, failures and rejections all break the SLO; the
            # open-loop denominator is everything that arrived.
            "miss_rate": (
                (misses + len(self.rejected)) / offered if offered else 0.0
            ),
            "requeued_jobs": len(self.requeues),
            "latency_p50_s": _percentile(lat, 0.50),
            "latency_p99_s": _percentile(lat, 0.99),
            "latency_max_s": lat[-1] if lat else 0.0,
            "span_s": span if span is not None else 0.0,
            "throughput_cols_per_s": (
                sum(c.cols for c in served) / span if span else 0.0
            ),
            "goodput_cols_per_s": (
                sum(c.cols for c in on_time) / span if span else 0.0
            ),
            "fleet": fleet,
        }


__all__ = ["CompletedJob", "FleetFrontend", "ServiceModel"]
