"""Batched serving engines.

``Engine`` — LLM prefill + decode loop with sampling. Owns the decode
cache (GQA KV / MLA latent / SSM state — built by ``Model.init_cache``
per the arch's mixer kinds) and drives jit'd ``prefill`` /
``decode_step`` functions. Requests are served in aligned batches
(continuous batching is a scheduler concern above this layer; the
dry-run cells ``decode_32k``/``long_500k`` lower exactly the
``decode_step`` this engine calls in its loop).

``SparseDNNEngine`` — the paper's workload as a service: batched forward
passes through a deep sparse ReLU MLP (GraphChallenge-style inference).
Requests are feature columns; the engine right-pads each batch to the
kernel tile, dispatches the VMEM-resident single-``pallas_call`` forward
when the stack qualifies (square, homogeneous, panel fits VMEM) and the
layered fused path otherwise, and reports per-batch kernel-step
accounting so operators can see the nnz-proportional scaling live.

Two call conventions on ``SparseDNNEngine``:

* **one-shot** — ``infer(y0)``: one aligned right-padded batch per call
  (the original API, now a thin wrapper over the step API);
* **step-level** — ``submit(cols)`` stages feature columns,
  ``step(limit=...)`` dispatches one padded panel over what is staged,
  ``drain()`` steps until the stage is empty. This is the surface
  ``repro.serve.scheduler.ContinuousBatcher`` drives: it decides *what*
  to stage each scheduling tick (admission, priorities, deadlines,
  mid-flight joins) while the engine stays the only component that
  touches kernels. Step stats carry exact grid-step accounting
  (``repro.core.dnn.dnn_grid_steps``) so pad waste is visible as
  hardware-independent kernel steps, not just wall-clock.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import dnn
from repro.models.model import Model

Array = jax.Array


def cache_nbytes(cache: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def sample_token(logits: Array, key: Array, temperature: float = 0.0) -> Array:
    """Greedy (T=0) or temperature sampling over (B, V) logits."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


@dataclasses.dataclass
class Engine:
    model: Model
    params: Any
    batch_size: int
    cache_len: int
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        self._key = jax.random.key(self.seed)

    def generate(
        self, prompts: Array, max_new_tokens: int
    ) -> tuple[Array, dict]:
        """prompts: (B, S_prompt) int32 (right-aligned, no padding support
        needed for the aligned-batch benchmark path). Returns (B, new)."""
        b, s = prompts.shape
        assert b == self.batch_size
        cache = self.model.init_cache(b, self.cache_len)
        logits, cache = self._prefill(self.params, prompts, cache)
        self._key, k = jax.random.split(self._key)
        tok = sample_token(logits[:, -1], k, self.temperature)
        out = [tok]
        for i in range(max_new_tokens - 1):
            pos = jnp.asarray(s + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            self._key, k = jax.random.split(self._key)
            tok = sample_token(logits, k, self.temperature)
            out.append(tok)
        tokens = jnp.stack(out, axis=1)
        stats = {
            "prompt_tokens": b * s,
            "generated_tokens": b * max_new_tokens,
            "cache_bytes": cache_nbytes(cache),
        }
        return tokens, stats


@dataclasses.dataclass
class SparseDNNEngine:
    """Serve batched inference through the paper's deep sparse MLP.

    ``weights``/``biases``: the L-layer stack (dense, BSR, or block-CSR
    per layer — ``repro.core.dnn`` dispatch rules apply). ``infer``
    accepts (m, batch) activation panels of any batch size; batches are
    padded to ``batch_align`` so the jit cache stays warm across request
    sizes. ``differentiable=True`` guarantees the served forward is
    ``jax.grad``-compatible (layered custom-VJP kernels only; the
    VJP-less fused resident path is rejected/bypassed).
    """

    weights: Sequence[dnn.Weight]
    biases: Sequence[Array]
    batch_align: int = 64
    use_resident: bool | None = None  # None = auto-detect eligibility
    # Differentiable serving (gradient-based attribution, fine-tuning
    # against served traffic): the VMEM-resident fused kernel has NO VJP
    # (activations never leave VMEM — nothing to checkpoint), so this
    # flag forces the layered custom-VJP kernel path and REJECTS an
    # explicit use_resident=True.
    differentiable: bool = False

    def __post_init__(self):
        self.n_layers = len(self.weights)
        if len(self.biases) != self.n_layers:
            raise ValueError("weights/biases length mismatch")
        if self.differentiable and self.use_resident:
            raise ValueError(
                "use_resident=True is incompatible with differentiable="
                "True: the fused VMEM-resident kernel has no VJP. Use "
                "use_resident=None/False to route through the layered "
                "kernel path, whose custom VJPs support jax.grad."
            )
        resident_ok = (
            not self.differentiable and dnn.resident_eligible(self.weights)
        )
        if self.use_resident and not resident_ok:
            raise ValueError(
                "use_resident=True but the stack is not eligible for the "
                "VMEM-resident kernel (needs a homogeneous square BSR "
                "stack whose activation panel fits VMEM); pass "
                "use_resident=None to auto-detect"
            )
        self._resident = (
            resident_ok if self.use_resident is None else self.use_resident
        )
        if self._resident:
            # Stack once — weights are immutable across requests; the
            # hot path must not rebuild the L-layer stack per infer().
            self._stacked_w = dnn.stack_bsr(list(self.weights))
            self._stacked_b = jnp.stack(list(self.biases))
        self._served = 0
        self._steps = 0
        self._next_rid = 0
        # Staged work is kept as contiguous (request_ids, panel) chunks —
        # a chunk is only split when a step's limit lands inside it, so
        # the one-shot infer path stays a single pad on the caller's
        # array with no per-column slicing.
        self._staged: list[tuple[list, Array]] = []
        self._staged_count = 0

    def _layered_kernel_forward(self, y: Array) -> Array:
        """Fallback: one fused kernel call per layer, dispatched on the
        layer's weight layout (the real kernel path, not the jnp oracle).

        Sparse layers delegate to ``dnn.dnn_layer_trainable`` (the same
        custom-VJP kernel wrappers). Dense layers split: the dense Pallas
        kernel has no VJP, so differentiable=True takes the XLA fused
        form instead — keeping the jax.grad-compatibility guarantee."""
        from repro.kernels import ops as kernel_ops
        from repro.sparse.bcsr import BlockCSRMatrix
        from repro.sparse.bsr import BlockSparseMatrix

        for w, b in zip(self.weights, self.biases):
            is_dense = not isinstance(w, (BlockCSRMatrix, BlockSparseMatrix))
            if is_dense and not self.differentiable:
                y = kernel_ops.semiring_matmul(w, y, b, fuse_bias_relu=True)
            else:
                y = dnn.dnn_layer_trainable(w, y, b)
        return y

    # ------------------------------------------------------------------
    # step-level API (driven by serve.scheduler.ContinuousBatcher)
    # ------------------------------------------------------------------

    @property
    def staged(self) -> int:
        """Feature columns submitted but not yet dispatched."""
        return self._staged_count

    @property
    def staged_request_ids(self) -> list:
        return [rid for rids, _ in self._staged for rid in rids]

    def submit(
        self, cols: Array, request_ids: Sequence[Any] | None = None
    ) -> list:
        """Stage (m, k) feature columns for the next ``step``.

        Returns the request ids assigned to the k columns (monotonic
        ints unless the caller names them). Staging is pure bookkeeping
        — no kernel work happens until ``step``.
        """
        m, k = cols.shape
        if request_ids is None:
            request_ids = list(range(self._next_rid, self._next_rid + k))
            self._next_rid += k
        elif len(request_ids) != k:
            raise ValueError(
                f"{len(request_ids)} request ids for {k} columns"
            )
        if k:
            self._staged.append((list(request_ids), cols))
            self._staged_count += k
        return list(request_ids)

    def _idle_stats(self) -> dict:
        return {
            "batch": 0,
            "padded_batch": 0,
            "pad_slots": 0,
            "grid_steps": 0,
            "request_ids": [],
            "resident": self._resident,
            "differentiable": self.differentiable,
            "pallas_calls": 0,
            "served_total": self._served,
            "engine_steps": self._steps,
        }

    def step(self, limit: int | None = None) -> tuple[Array | None, dict]:
        """Dispatch ONE padded forward pass over up to ``limit`` staged
        columns (FIFO). Returns ``(Y[L] (m, batch), stats)``; stats carry
        the exact grid-step bill for the padded panel, so idle pad slots
        are visible as kernel steps. ``(None, stats)`` when nothing is
        staged.
        """
        if limit is not None and limit < 1:
            raise ValueError(f"step limit must be >= 1, got {limit}")
        batch = (
            self._staged_count
            if limit is None
            else min(limit, self._staged_count)
        )
        pallas_calls = 1 if self._resident else self.n_layers
        if batch == 0:
            return None, self._idle_stats()
        need = batch
        take: list[tuple[list, Array]] = []
        while need:
            rids, arr = self._staged[0]
            k = arr.shape[1]
            if k <= need:
                take.append(self._staged.pop(0))
                need -= k
            else:  # split the chunk at the step boundary
                take.append((rids[:need], arr[:, :need]))
                self._staged[0] = (rids[need:], arr[:, need:])
                need = 0
        self._staged_count -= batch
        ids = [rid for rids, _ in take for rid in rids]
        pad = (-batch) % self.batch_align
        yp = (
            take[0][1]
            if len(take) == 1
            else jnp.concatenate([arr for _, arr in take], axis=1)
        )
        if pad:
            yp = jnp.pad(yp, ((0, 0), (0, pad)))
        if self._resident:
            from repro.kernels import ops as kernel_ops

            out = kernel_ops.fused_mlp_forward(
                self._stacked_w, self._stacked_b, yp
            )
        else:
            out = self._layered_kernel_forward(yp)
        self._served += batch
        self._steps += 1
        stats = {
            "batch": batch,
            "padded_batch": batch + pad,
            "pad_slots": pad,
            "grid_steps": dnn.dnn_grid_steps(self.weights, batch + pad),
            "request_ids": ids,
            "resident": self._resident,
            "differentiable": self.differentiable,
            "pallas_calls": pallas_calls,
            "served_total": self._served,
            "engine_steps": self._steps,
        }
        return out[:, :batch], stats

    def drain(self, limit: int | None = None) -> list[tuple[Array, dict]]:
        """Step until the stage is empty (≤ ``limit`` columns per step)."""
        results = []
        while self._staged:
            results.append(self.step(limit))
        return results

    def infer(self, y0: Array) -> tuple[Array, dict]:
        """One-shot API: y0 (m, batch) feature columns → (Y[L], stats).

        A thin wrapper over ``submit`` + ``step`` — one aligned,
        right-padded batch per call, exactly the pre-scheduler contract.
        """
        m, batch = y0.shape
        if batch == 0:
            return y0, self._idle_stats()
        if self._staged:
            raise RuntimeError(
                "infer() on an engine with staged columns would reorder "
                "them past the step API's FIFO; call drain() first"
            )
        self.submit(y0)
        out, stats = self.step()
        return out, stats


def make_serve_fns(model: Model):
    """(prefill_fn, decode_fn) suitable for jit/lower — the functions the
    dry-run compiles for the decode-shape cells."""

    def prefill_fn(params, tokens, cache):
        return model.prefill(params, tokens, cache)

    def decode_fn(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return prefill_fn, decode_fn
