"""Batched serving engines.

``Engine`` — LLM prefill + decode loop with sampling. Owns the decode
cache (GQA KV / MLA latent / SSM state — built by ``Model.init_cache``
per the arch's mixer kinds) and drives jit'd ``prefill`` /
``decode_step`` functions. Requests are served in aligned batches
(continuous batching is a scheduler concern above this layer; the
dry-run cells ``decode_32k``/``long_500k`` lower exactly the
``decode_step`` this engine calls in its loop).

``SparseDNNEngine`` — the paper's workload as a service: batched forward
passes through a deep sparse ReLU MLP (GraphChallenge-style inference).
Requests are feature columns; the engine right-pads each batch to the
kernel tile, dispatches the VMEM-resident single-``pallas_call`` forward
when the stack qualifies (square, homogeneous, panel fits VMEM) and the
layered fused path otherwise, and reports per-batch kernel-step
accounting so operators can see the nnz-proportional scaling live.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import dnn
from repro.models.model import Model

Array = jax.Array


def cache_nbytes(cache: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def sample_token(logits: Array, key: Array, temperature: float = 0.0) -> Array:
    """Greedy (T=0) or temperature sampling over (B, V) logits."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


@dataclasses.dataclass
class Engine:
    model: Model
    params: Any
    batch_size: int
    cache_len: int
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        self._key = jax.random.key(self.seed)

    def generate(
        self, prompts: Array, max_new_tokens: int
    ) -> tuple[Array, dict]:
        """prompts: (B, S_prompt) int32 (right-aligned, no padding support
        needed for the aligned-batch benchmark path). Returns (B, new)."""
        b, s = prompts.shape
        assert b == self.batch_size
        cache = self.model.init_cache(b, self.cache_len)
        logits, cache = self._prefill(self.params, prompts, cache)
        self._key, k = jax.random.split(self._key)
        tok = sample_token(logits[:, -1], k, self.temperature)
        out = [tok]
        for i in range(max_new_tokens - 1):
            pos = jnp.asarray(s + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            self._key, k = jax.random.split(self._key)
            tok = sample_token(logits, k, self.temperature)
            out.append(tok)
        tokens = jnp.stack(out, axis=1)
        stats = {
            "prompt_tokens": b * s,
            "generated_tokens": b * max_new_tokens,
            "cache_bytes": cache_nbytes(cache),
        }
        return tokens, stats


@dataclasses.dataclass
class SparseDNNEngine:
    """Serve batched inference through the paper's deep sparse MLP.

    ``weights``/``biases``: the L-layer stack (dense, BSR, or block-CSR
    per layer — ``repro.core.dnn`` dispatch rules apply). ``infer``
    accepts (m, batch) activation panels of any batch size; batches are
    padded to ``batch_align`` so the jit cache stays warm across request
    sizes. ``differentiable=True`` guarantees the served forward is
    ``jax.grad``-compatible (layered custom-VJP kernels only; the
    VJP-less fused resident path is rejected/bypassed).
    """

    weights: Sequence[dnn.Weight]
    biases: Sequence[Array]
    batch_align: int = 64
    use_resident: bool | None = None  # None = auto-detect eligibility
    # Differentiable serving (gradient-based attribution, fine-tuning
    # against served traffic): the VMEM-resident fused kernel has NO VJP
    # (activations never leave VMEM — nothing to checkpoint), so this
    # flag forces the layered custom-VJP kernel path and REJECTS an
    # explicit use_resident=True.
    differentiable: bool = False

    def __post_init__(self):
        self.n_layers = len(self.weights)
        if len(self.biases) != self.n_layers:
            raise ValueError("weights/biases length mismatch")
        if self.differentiable and self.use_resident:
            raise ValueError(
                "use_resident=True is incompatible with differentiable="
                "True: the fused VMEM-resident kernel has no VJP. Use "
                "use_resident=None/False to route through the layered "
                "kernel path, whose custom VJPs support jax.grad."
            )
        resident_ok = (
            not self.differentiable and dnn.resident_eligible(self.weights)
        )
        if self.use_resident and not resident_ok:
            raise ValueError(
                "use_resident=True but the stack is not eligible for the "
                "VMEM-resident kernel (needs a homogeneous square BSR "
                "stack whose activation panel fits VMEM); pass "
                "use_resident=None to auto-detect"
            )
        self._resident = (
            resident_ok if self.use_resident is None else self.use_resident
        )
        if self._resident:
            # Stack once — weights are immutable across requests; the
            # hot path must not rebuild the L-layer stack per infer().
            self._stacked_w = dnn.stack_bsr(list(self.weights))
            self._stacked_b = jnp.stack(list(self.biases))
        self._served = 0

    def _layered_kernel_forward(self, y: Array) -> Array:
        """Fallback: one fused kernel call per layer, dispatched on the
        layer's weight layout (the real kernel path, not the jnp oracle).

        Sparse layers delegate to ``dnn.dnn_layer_trainable`` (the same
        custom-VJP kernel wrappers). Dense layers split: the dense Pallas
        kernel has no VJP, so differentiable=True takes the XLA fused
        form instead — keeping the jax.grad-compatibility guarantee."""
        from repro.kernels import ops as kernel_ops
        from repro.sparse.bcsr import BlockCSRMatrix
        from repro.sparse.bsr import BlockSparseMatrix

        for w, b in zip(self.weights, self.biases):
            is_dense = not isinstance(w, (BlockCSRMatrix, BlockSparseMatrix))
            if is_dense and not self.differentiable:
                y = kernel_ops.semiring_matmul(w, y, b, fuse_bias_relu=True)
            else:
                y = dnn.dnn_layer_trainable(w, y, b)
        return y

    def infer(self, y0: Array) -> tuple[Array, dict]:
        """y0: (m, batch) feature columns → (Y[L], stats)."""
        m, batch = y0.shape
        pallas_calls = 1 if self._resident else self.n_layers
        if batch == 0:
            return y0, {
                "batch": 0,
                "padded_batch": 0,
                "resident": self._resident,
                "differentiable": self.differentiable,
                "pallas_calls": 0,
                "served_total": self._served,
            }
        pad = (-batch) % self.batch_align
        yp = jnp.pad(y0, ((0, 0), (0, pad))) if pad else y0
        if self._resident:
            from repro.kernels import ops as kernel_ops

            out = kernel_ops.fused_mlp_forward(
                self._stacked_w, self._stacked_b, yp
            )
        else:
            out = self._layered_kernel_forward(yp)
        self._served += batch
        stats = {
            "batch": batch,
            "padded_batch": batch + pad,
            "resident": self._resident,
            "differentiable": self.differentiable,
            "pallas_calls": pallas_calls,
            "served_total": self._served,
        }
        return out[:, :batch], stats


def make_serve_fns(model: Model):
    """(prefill_fn, decode_fn) suitable for jit/lower — the functions the
    dry-run compiles for the decode-shape cells."""

    def prefill_fn(params, tokens, cache):
        return model.prefill(params, tokens, cache)

    def decode_fn(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return prefill_fn, decode_fn
