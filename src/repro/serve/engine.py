"""Batched serving engines.

``Engine`` — LLM prefill + decode loop with sampling. Owns the decode
cache (GQA KV / MLA latent / SSM state — built by ``Model.init_cache``
per the arch's mixer kinds) and drives jit'd ``prefill`` /
``decode_step`` functions. Requests are served in aligned batches
(continuous batching is a scheduler concern above this layer; the
dry-run cells ``decode_32k``/``long_500k`` lower exactly the
``decode_step`` this engine calls in its loop).

``SparseDNNEngine`` — the paper's workload as a service: batched forward
passes through a deep sparse ReLU MLP (GraphChallenge-style inference).
Requests are feature columns; the engine right-pads each batch to the
kernel tile, dispatches the VMEM-resident single-``pallas_call`` forward
when the stack qualifies (square, homogeneous, panel fits VMEM) and the
layered fused path otherwise, and reports per-batch kernel-step
accounting so operators can see the nnz-proportional scaling live.

Two call conventions on ``SparseDNNEngine``:

* **one-shot** — ``infer(y0)``: one aligned right-padded batch per call
  (the original API, now a thin wrapper over the step API);
* **step-level** — ``submit(cols)`` stages feature columns,
  ``step(limit=...)`` dispatches one padded panel over what is staged,
  ``drain()`` steps until the stage is empty. This is the surface
  ``repro.serve.scheduler.ContinuousBatcher`` drives: it decides *what*
  to stage each scheduling tick (admission, priorities, deadlines,
  mid-flight joins) while the engine stays the only component that
  touches kernels. Step stats carry exact grid-step accounting
  (``repro.core.dnn.dnn_grid_steps``) so pad waste is visible as
  hardware-independent kernel steps, not just wall-clock.

Execution is plan-backed (``repro.plan``, `docs/architecture.md`): the
engine fingerprints its (frozen) topology once, and every ``step``
fetches a compiled :class:`repro.plan.StackPlan` from its
:class:`repro.plan.PlanCache` keyed by the padded panel width — route,
layouts, grid-step bill, and the jitted executable are all amortized
across requests. ``step(pad_to=...)`` lets a scheduler quantize panel
widths to a small set of classes so a handful of compiled plans serve
every panel (``ContinuousBatcher(width_classes=...)``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dnn
from repro.models.model import Model
from repro.plan import DegradationLadder, PlanCache, topology_fingerprint
from repro.serve.clock import WALL_CLOCK
from repro.testing import faults as _faults

Array = jax.Array


def cache_nbytes(cache: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def sample_token(logits: Array, key: Array, temperature: float = 0.0) -> Array:
    """Greedy (T=0) or temperature sampling over (B, V) logits."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


@dataclasses.dataclass
class Engine:
    model: Model
    params: Any
    batch_size: int
    cache_len: int
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        self._key = jax.random.key(self.seed)

    def generate(
        self, prompts: Array, max_new_tokens: int
    ) -> tuple[Array, dict]:
        """prompts: (B, S_prompt) int32 (right-aligned, no padding support
        needed for the aligned-batch benchmark path). Returns (B, new)."""
        b, s = prompts.shape
        assert b == self.batch_size
        cache = self.model.init_cache(b, self.cache_len)
        logits, cache = self._prefill(self.params, prompts, cache)
        self._key, k = jax.random.split(self._key)
        tok = sample_token(logits[:, -1], k, self.temperature)
        out = [tok]
        for i in range(max_new_tokens - 1):
            pos = jnp.asarray(s + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            self._key, k = jax.random.split(self._key)
            tok = sample_token(logits, k, self.temperature)
            out.append(tok)
        tokens = jnp.stack(out, axis=1)
        stats = {
            "prompt_tokens": b * s,
            "generated_tokens": b * max_new_tokens,
            "cache_bytes": cache_nbytes(cache),
        }
        return tokens, stats


@dataclasses.dataclass
class SparseDNNEngine:
    """Serve batched inference through the paper's deep sparse MLP.

    ``weights``/``biases``: the L-layer stack (dense, BSR, or block-CSR
    per layer — ``repro.core.dnn`` dispatch rules apply). ``infer``
    accepts (m, batch) activation panels of any batch size; batches are
    padded to ``batch_align`` so the jit cache stays warm across request
    sizes. ``differentiable=True`` guarantees the served forward is
    ``jax.grad``-compatible (layered custom-VJP kernels only; the
    VJP-less fused resident path is rejected/bypassed). ``mesh=``
    serves the stack mesh-sharded (``repro.plan.ShardedStackPlan``):
    same outputs, per-shard grid-step accounting in the step stats.
    """

    weights: Sequence[dnn.Weight]
    biases: Sequence[Array]
    batch_align: int = 64
    use_resident: bool | None = None  # None = auto-detect eligibility
    # Differentiable serving (gradient-based attribution, fine-tuning
    # against served traffic): the VMEM-resident fused kernel has NO VJP
    # (activations never leave VMEM — nothing to checkpoint), so this
    # flag forces the layered custom-VJP kernel path and REJECTS an
    # explicit use_resident=True.
    differentiable: bool = False
    # Compiled-plan cache (one per engine unless shared explicitly):
    # holds one StackPlan per padded panel width seen; size it to the
    # number of width classes the scheduler quantizes to.
    plan_cache: PlanCache | None = None
    # Mesh-sharded serving: partition every sparse layer's block-CSR
    # segment across the mesh's row_blocks axes and serve through
    # repro.plan.ShardedStackPlan (shard-local kernels + psum between
    # layers). Outputs match the single-device engine; step stats grow
    # per-shard grid-step accounting. Incompatible with
    # use_resident=True (the fused kernel is single-device VMEM).
    mesh: Any = None
    # Fault handling (docs/robustness.md). ``fault_injector``: a
    # repro.testing.faults.FaultInjector polled at this engine's named
    # sites, keyed by the dispatch ordinal (None in production).
    # Transient step failures are retried up to ``max_step_retries``
    # with exponential backoff (base ``retry_backoff_s``, 0 = no sleep);
    # an exhausted panel FAILS GRACEFULLY: step returns (None, stats)
    # naming the lost request ids instead of raising.
    fault_injector: Any = None
    max_step_retries: int = 2
    retry_backoff_s: float = 0.0
    # Per-request NaN quarantine: after each step, non-finite output
    # columns fail only their own request ids (stats carry them as
    # ``quarantined_request_ids``); the rest of the panel is served.
    quarantine_nonfinite: bool = True
    # Validate sparse layout invariants at construction (sorted
    # in-bounds indices, finite values — see BlockCSRMatrix.validate).
    # Trust boundary only; the per-step hot path never re-checks.
    validate: bool = True
    # Time source for retry backoff (repro.serve.clock): None = real
    # wall clock. Tests and the bench inject a VirtualClock so a
    # backoff-heavy faulted trace neither stalls CI nor depends on
    # runner load.
    clock: Any = None
    # Kernel autotuning (docs/tuning.md). ``tuning_table``: a
    # repro.tune.TuningTable consulted ONCE at construction by this
    # stack's topology fingerprint — a hit threads the tuned config
    # (block_n, forced layout, bf16 panels, VMEM budget) through every
    # plan this engine builds; a miss serves defaults, silently.
    # ``panel_dtype``: explicit bf16-panel override (e.g. "bfloat16"),
    # applied on top of any table hit. The sharded level always serves
    # untuned (the sharded builder takes no tuning knobs).
    tuning_table: Any = None
    panel_dtype: Any = None

    def __post_init__(self):
        self.n_layers = len(self.weights)
        if len(self.biases) != self.n_layers:
            raise ValueError("weights/biases length mismatch")
        if self.differentiable and self.use_resident:
            raise ValueError(
                "use_resident=True is incompatible with differentiable="
                "True: the fused VMEM-resident kernel has no VJP. Use "
                "use_resident=None/False to route through the layered "
                "kernel path, whose custom VJPs support jax.grad."
            )
        if self.mesh is not None and self.use_resident:
            raise ValueError(
                "use_resident=True is incompatible with mesh=: the "
                "VMEM-resident fused kernel runs a single device's "
                "VMEM; sharded serving always takes the per-shard "
                "layered route. Pass use_resident=None/False."
            )
        from repro.plan import routes as _routes

        # Fingerprint once — weights are immutable across requests; the
        # hot path must not re-hash the topology per step. Computed
        # before residency so the tuning-table lookup (keyed by this
        # fingerprint) can shift the resident boundary below.
        self._fingerprint = topology_fingerprint(tuple(self.weights))
        self._tuned = None
        if self.tuning_table is not None:
            dtype = str(
                np.dtype(getattr(self.weights[0], "dtype", np.float32))
            )
            self._tuned = self.tuning_table.lookup(
                self._fingerprint, dtype=dtype
            )
        if self.panel_dtype is not None:
            from repro.tune.table import TunedConfig

            pdt = str(np.dtype(self.panel_dtype))
            if self._tuned is None:
                self._tuned = TunedConfig(panel_dtype=pdt)
            else:
                self._tuned = dataclasses.replace(
                    self._tuned, panel_dtype=pdt
                )
        # Fused-family eligibility covers both the VMEM-resident kernel
        # and the multi-panel tiled variant (panel past the VMEM budget)
        # — either way the plan layer serves ONE pallas_call per step.
        # Tuned knobs move the boundary: bf16 panels halve the VMEM
        # bill, so a stack that tiles under f32 can serve resident.
        fused_kw: dict = {}
        if self._tuned is not None:
            if self._tuned.block_n is not None:
                fused_kw["block_n"] = self._tuned.block_n
            fused_kw["panel_dtype"] = self._tuned.panel_dtype
            fused_kw["vmem_limit"] = self._tuned.vmem_limit_bytes
        resident_ok = (
            not self.differentiable
            and self.mesh is None
            and _routes.fused_route(self.weights, **fused_kw) is not None
        )
        if self.use_resident and not resident_ok:
            raise ValueError(
                "use_resident=True but the stack is not eligible for the "
                "fused whole-stack kernels (needs a homogeneous square "
                "BSR stack); pass use_resident=None to auto-detect"
            )
        self._resident = (
            resident_ok if self.use_resident is None else self.use_resident
        )
        if self.validate:
            for i, w in enumerate(self.weights):
                if hasattr(w, "validate"):
                    w.validate(name=f"SparseDNNEngine layer {i} weight")
        if self.plan_cache is None:
            self.plan_cache = PlanCache(max_size=16)
        # The degradation ladder owns execution-level health: sharded →
        # single-device → layered fallback for the same fingerprint.
        self._ladder = DegradationLadder(
            self.plan_cache,
            mesh=self.mesh,
            use_resident=self._resident,
            tuned=self._tuned,
        )
        self._served = 0
        self._steps = 0
        self._dispatches = 0  # fault sites key on this ordinal
        self._next_rid = 0
        # Staged work is kept as contiguous (request_ids, panel) chunks —
        # a chunk is only split when a step's limit lands inside it, so
        # the one-shot infer path stays a single pad on the caller's
        # array with no per-column slicing.
        self._staged: list[tuple[list, Array]] = []
        self._staged_count = 0

    @property
    def ladder(self) -> DegradationLadder:
        """The engine's degradation ladder (health marks, events)."""
        return self._ladder

    @property
    def tuned(self):
        """The resolved tuned config this engine serves with (None =
        defaults; see ``repro.tune``)."""
        return self._tuned

    def _plan_for_width(self, width: int, *, step: int = -1, compile_hook=None):
        """(plan, level, cache_hit) serving a ``width``-wide panel at
        the best healthy degradation level. Route rules are the plan
        layer's (fused when eligible and not differentiable; layered
        per-layout kernels otherwise; dense layers keep jax.grad
        compatibility under ``differentiable=True`` via the XLA form);
        the ladder only decides WHICH level of them to serve at when the
        mesh or the resident path is marked unhealthy."""
        return self._ladder.get_plan(
            tuple(self.weights),
            tuple(self.biases),
            width,
            differentiable=self.differentiable,
            fingerprint=self._fingerprint,
            step=step,
            compile_hook=compile_hook,
        )

    # ------------------------------------------------------------------
    # step-level API (driven by serve.scheduler.ContinuousBatcher)
    # ------------------------------------------------------------------

    @property
    def staged(self) -> int:
        """Feature columns submitted but not yet dispatched."""
        return self._staged_count

    @property
    def staged_request_ids(self) -> list:
        return [rid for rids, _ in self._staged for rid in rids]

    def submit(
        self, cols: Array, request_ids: Sequence[Any] | None = None
    ) -> list:
        """Stage (m, k) feature columns for the next ``step``.

        Returns the request ids assigned to the k columns (monotonic
        ints unless the caller names them). Staging is pure bookkeeping
        — no kernel work happens until ``step``.
        """
        m, k = cols.shape
        if request_ids is None:
            request_ids = list(range(self._next_rid, self._next_rid + k))
            self._next_rid += k
        elif len(request_ids) != k:
            raise ValueError(
                f"{len(request_ids)} request ids for {k} columns"
            )
        if k:
            self._staged.append((list(request_ids), cols))
            self._staged_count += k
        return list(request_ids)

    def _idle_stats(self) -> dict:
        return {
            "batch": 0,
            "padded_batch": 0,
            "pad_slots": 0,
            "grid_steps": 0,
            "request_ids": [],
            "resident": self._resident,
            "differentiable": self.differentiable,
            "pallas_calls": 0,
            "served_total": self._served,
            "engine_steps": self._steps,
            "plan": None,
            "failed": False,
            "retries": 0,
            "quarantined_request_ids": [],
        }

    def step(
        self, limit: int | None = None, *, pad_to: int | None = None
    ) -> tuple[Array | None, dict]:
        """Dispatch ONE padded forward pass over up to ``limit`` staged
        columns (FIFO). Returns ``(Y[L] (m, batch), stats)``; stats carry
        the exact grid-step bill for the padded panel, so idle pad slots
        are visible as kernel steps. ``(None, stats)`` when nothing is
        staged.

        ``pad_to`` pads the panel further, up to that width (itself
        aligned to ``batch_align``) — the scheduler's width-class
        quantization hook: panels padded to a shared class width reuse
        one compiled plan instead of compiling per distinct width.
        """
        if limit is not None and limit < 1:
            raise ValueError(f"step limit must be >= 1, got {limit}")
        if pad_to is not None and pad_to < 1:
            raise ValueError(f"pad_to must be >= 1, got {pad_to}")
        batch = (
            self._staged_count
            if limit is None
            else min(limit, self._staged_count)
        )
        if batch == 0:
            return None, self._idle_stats()
        need = batch
        take: list[tuple[list, Array]] = []
        while need:
            rids, arr = self._staged[0]
            k = arr.shape[1]
            if k <= need:
                take.append(self._staged.pop(0))
                need -= k
            else:  # split the chunk at the step boundary
                take.append((rids[:need], arr[:, :need]))
                self._staged[0] = (rids[need:], arr[:, need:])
                need = 0
        self._staged_count -= batch
        ids = [rid for rids, _ in take for rid in rids]
        width = batch + (-batch) % self.batch_align
        if pad_to is not None:
            width = max(width, pad_to + (-pad_to) % self.batch_align)
        yp = (
            take[0][1]
            if len(take) == 1
            else jnp.concatenate([arr for _, arr in take], axis=1)
        )
        # ---- fault sites (docs/robustness.md), keyed by dispatch ordinal
        ordinal = self._dispatches
        self._dispatches += 1
        inj = self.fault_injector
        compile_spec = transient_spec = None
        if inj is not None:
            if inj.fires(_faults.SITE_CACHE_EVICTION, ordinal) is not None:
                self.plan_cache.clear()  # eviction storm: every width recompiles
            spec = inj.fires(_faults.SITE_SHARD_FAILURE, ordinal)
            if spec is not None and self.mesh is not None:
                self._ladder.mark_unhealthy(
                    "sharded",
                    reason=spec.get("reason", "injected shard failure"),
                    step=ordinal,
                )
            spec = inj.fires(_faults.SITE_PANEL_NANS, ordinal)
            if spec is not None:
                # poison only real request columns — pad stays clean
                yp, _ = _faults.poison_panel(
                    yp, limit=batch, rng=inj.rng, **spec
                )
            compile_spec = inj.fires(_faults.SITE_PLAN_COMPILE, ordinal)
            transient_spec = inj.fires(_faults.SITE_STEP_TRANSIENT, ordinal)
        failures_left = (
            int(transient_spec.get("failures", 1)) if transient_spec else 0
        )

        def compile_hook(level: str) -> None:
            nonlocal compile_spec
            if compile_spec is not None:
                compile_spec = None  # fires once, at the preferred level
                raise _faults.InjectedFault(
                    f"injected plan-compile failure at level {level!r}"
                )

        out = None
        retries = 0
        last_err: Exception | None = None
        plan = level = cache_hit = None
        for attempt in range(self.max_step_retries + 1):
            try:
                plan, level, cache_hit = self._plan_for_width(
                    width, step=ordinal, compile_hook=compile_hook
                )
                if failures_left > 0:
                    failures_left -= 1
                    raise _faults.TransientFault(
                        "injected transient step failure"
                    )
                out = plan.forward(yp)
                break
            except _faults.TransientFault as e:
                last_err = e
                if attempt >= self.max_step_retries:
                    break
                retries += 1
                if self.retry_backoff_s:
                    (self.clock or WALL_CLOCK).sleep(
                        self.retry_backoff_s * 2**attempt
                    )
            except Exception as e:  # noqa: BLE001 — not retryable
                last_err = e
                break
        if out is None:
            # Graceful panel failure: the batch's requests are lost, the
            # engine (and the requests behind it) live on.
            stats = self._idle_stats()
            stats.update(
                batch=batch,
                request_ids=ids,
                failed=True,
                retries=retries,
                error=f"{type(last_err).__name__}: {last_err}",
            )
            return None, stats
        self._served += batch
        self._steps += 1
        res = out[:, :batch]
        quarantined: list = []
        if self.quarantine_nonfinite and not bool(jnp.isfinite(res).all()):
            col_ok = np.asarray(jnp.isfinite(res).all(axis=0))
            quarantined = [ids[j] for j in range(batch) if not col_ok[j]]
        plan_stats = {
            "width_class": width,
            "cache_hit": cache_hit,
            "route": plan.route,
            "compiles": plan.compile_count,
            "level": level,
            "degraded": level != self._ladder.preferred_level,
            "tuned": plan.key.tuned,
        }
        if getattr(plan, "is_sharded", False):
            # Per-shard accounting: each shard's bill is its local
            # segment length × column tiles; they sum to plan.grid_steps
            # (= the unsharded occupancy-exact bill when shard counts
            # divide the stored blocks evenly).
            plan_stats["shards"] = plan.n_shards
            plan_stats["grid_steps_per_shard"] = list(
                plan.grid_steps_per_shard
            )
        stats = {
            "batch": batch,
            "padded_batch": width,
            "pad_slots": width - batch,
            "grid_steps": plan.grid_steps,
            "request_ids": ids,
            "resident": self._resident,
            "differentiable": self.differentiable,
            "pallas_calls": plan.pallas_calls,
            "served_total": self._served,
            "engine_steps": self._steps,
            "plan": plan_stats,
            "failed": False,
            "retries": retries,
            "quarantined_request_ids": quarantined,
        }
        return res, stats

    def drain(self, limit: int | None = None) -> list[tuple[Array, dict]]:
        """Step until the stage is empty (≤ ``limit`` columns per step)."""
        results = []
        while self._staged:
            results.append(self.step(limit))
        return results

    def infer(self, y0: Array) -> tuple[Array, dict]:
        """One-shot API: y0 (m, batch) feature columns → (Y[L], stats).

        A thin wrapper over ``submit`` + ``step`` — one aligned,
        right-padded batch per call, exactly the pre-scheduler contract.
        """
        m, batch = y0.shape
        if batch == 0:
            return y0, self._idle_stats()
        if self._staged:
            raise RuntimeError(
                "infer() on an engine with staged columns would reorder "
                "them past the step API's FIFO; call drain() first"
            )
        self.submit(y0)
        out, stats = self.step()
        return out, stats


def make_serve_fns(model: Model):
    """(prefill_fn, decode_fn) suitable for jit/lower — the functions the
    dry-run compiles for the decode-shape cells."""

    def prefill_fn(params, tokens, cache):
        return model.prefill(params, tokens, cache)

    def decode_fn(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return prefill_fn, decode_fn
