"""Batched serving engine: prefill + decode loop with sampling.

The engine owns the decode cache (GQA KV / MLA latent / SSM state — built
by ``Model.init_cache`` per the arch's mixer kinds) and drives jit'd
``prefill`` / ``decode_step`` functions. Requests are served in aligned
batches (continuous batching is a scheduler concern above this layer; the
dry-run cells ``decode_32k``/``long_500k`` lower exactly the
``decode_step`` this engine calls in its loop).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model

Array = jax.Array


def cache_nbytes(cache: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def sample_token(logits: Array, key: Array, temperature: float = 0.0) -> Array:
    """Greedy (T=0) or temperature sampling over (B, V) logits."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


@dataclasses.dataclass
class Engine:
    model: Model
    params: Any
    batch_size: int
    cache_len: int
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        self._key = jax.random.key(self.seed)

    def generate(
        self, prompts: Array, max_new_tokens: int
    ) -> tuple[Array, dict]:
        """prompts: (B, S_prompt) int32 (right-aligned, no padding support
        needed for the aligned-batch benchmark path). Returns (B, new)."""
        b, s = prompts.shape
        assert b == self.batch_size
        cache = self.model.init_cache(b, self.cache_len)
        logits, cache = self._prefill(self.params, prompts, cache)
        self._key, k = jax.random.split(self._key)
        tok = sample_token(logits[:, -1], k, self.temperature)
        out = [tok]
        for i in range(max_new_tokens - 1):
            pos = jnp.asarray(s + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            self._key, k = jax.random.split(self._key)
            tok = sample_token(logits, k, self.temperature)
            out.append(tok)
        tokens = jnp.stack(out, axis=1)
        stats = {
            "prompt_tokens": b * s,
            "generated_tokens": b * max_new_tokens,
            "cache_bytes": cache_nbytes(cache),
        }
        return tokens, stats


def make_serve_fns(model: Model):
    """(prefill_fn, decode_fn) suitable for jit/lower — the functions the
    dry-run compiles for the decode-shape cells."""

    def prefill_fn(params, tokens, cache):
        return model.prefill(params, tokens, cache)

    def decode_fn(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return prefill_fn, decode_fn
