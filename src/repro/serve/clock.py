"""Injectable clocks for the serving stack.

Every latency number the serving layer reports — arrival timestamps,
deadlines, retry backoff, straggler stalls, the front-end's event loop —
flows through a :class:`Clock` so the SAME code path runs in two modes:

* :class:`WallClock` — production: ``time.monotonic`` timestamps and
  real ``time.sleep`` waits;
* :class:`VirtualClock` — tests, benchmarks, CI: time is a number the
  event loop advances. ``sleep`` moves the clock forward instantly and
  records the request, so a whole bursty serving trace with deadlines,
  backoff and straggler stalls runs in milliseconds of real time and is
  bit-identical run to run — including on a loaded CI runner.

Nothing in ``repro.serve`` may call ``time.time``/``time.monotonic``/
``time.sleep`` directly for latency accounting; the CI ``fleet`` job
runs the serving tests with a guard that fails on any real sleep.
(``time.perf_counter`` spans around whole benchmark arms measure *real*
elapsed wall-clock of the run itself and are gated only tolerantly —
those are measurements of the host, not of request latency.)
"""

from __future__ import annotations

import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """What the serving stack needs from a time source."""

    def now(self) -> float:
        """Current time in seconds (monotonic; epoch is arbitrary)."""
        ...

    def sleep(self, seconds: float) -> None:
        """Block (or advance virtual time) for ``seconds``."""
        ...


class WallClock:
    """Real time: ``time.monotonic`` + ``time.sleep``."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class VirtualClock:
    """Deterministic simulated time.

    ``now()`` returns the simulated timestamp; ``sleep(dt)`` advances it
    by ``dt`` instantly and logs the request in :attr:`sleeps` (tests
    assert on it — e.g. that retry backoff *would* have waited without
    actually stalling CI). ``advance_to(t)`` is the event-loop primitive:
    jump to an absolute timestamp, never backwards.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self.sleeps: list[float] = []

    def now(self) -> float:
        return self._now

    def sleep(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError(f"cannot sleep {seconds} s")
        self.sleeps.append(float(seconds))
        self._now += float(seconds)

    def advance_to(self, t: float) -> None:
        if t < self._now:
            raise ValueError(
                f"cannot move a monotonic clock backwards: {t} < {self._now}"
            )
        self._now = float(t)

    @property
    def slept_total(self) -> float:
        return sum(self.sleeps)


# Module-level default used when callers don't inject one. A singleton,
# so `clock or WALL_CLOCK` never allocates on the hot path.
WALL_CLOCK = WallClock()


__all__ = ["Clock", "WallClock", "VirtualClock", "WALL_CLOCK"]
