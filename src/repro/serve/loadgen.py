"""Open-loop load generation for the fleet serving front-end.

GraphChallenge (arXiv:2004.01181) scores sparse inference as *sustained
streaming rate under load* — which only means something against a
defined arrival process. This module generates those processes as
deterministic, timestamped job traces:

* :class:`LoadProfile` — a rate function λ(t) (jobs/second):
  ``constant``, ``diurnal`` (sinusoidal day-curve), ``bursty``
  (baseline + periodic burst windows — the overload shape the
  backpressure path exists for);
* :func:`generate_jobs` — an inhomogeneous Poisson draw against the
  profile via Lewis–Shedler thinning, from one seeded generator: same
  arguments → the same jobs, timestamps, panels, and deadlines, bit for
  bit. CI gates benchmark curves on that determinism.

**Open-loop** means arrivals never wait for the system: the trace is a
fixed function of (profile, seed), so an overloaded fleet sees the same
offered load as a healthy one — the honest way to measure saturation
(closed-loop generators self-throttle and hide it).

A *job* is an ``(m, k)`` panel of k feature columns served together —
the unit a client submits (k = 1 is a single request). ``k`` is drawn
from ``width_mix``, so a trace can carry several panel width classes;
the fleet router's affinity policy (``repro.serve.fleet``) keys on
exactly those classes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ArrivalJob:
    """One timestamped unit of offered load."""

    rid: int
    t: float  # arrival timestamp, seconds from trace start
    features: Array  # (m, k) panel; k columns served together
    deadline: float | None = None  # absolute seconds, or None

    @property
    def cols(self) -> int:
        return int(self.features.shape[1])


@dataclasses.dataclass(frozen=True)
class LoadProfile:
    """λ(t) in jobs/second, with the peak rate thinning needs.

    Build with the constructors (:meth:`constant` / :meth:`diurnal` /
    :meth:`bursty`) — they set a coherent ``peak``.
    """

    rate: Callable[[float], float]
    peak: float
    name: str = "custom"

    @staticmethod
    def constant(rate: float) -> "LoadProfile":
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        return LoadProfile(lambda t: rate, rate, "constant")

    @staticmethod
    def diurnal(
        base: float, amplitude: float, period: float
    ) -> "LoadProfile":
        """λ(t) = base + amplitude · (1 + sin(2πt/period)) / 2 — a
        smooth trough-to-peak day curve (trough = base, peak = base +
        amplitude)."""
        if base <= 0 or amplitude < 0 or period <= 0:
            raise ValueError(
                f"need base > 0, amplitude >= 0, period > 0; got "
                f"({base}, {amplitude}, {period})"
            )

        def lam(t: float) -> float:
            return base + amplitude * (
                1.0 + math.sin(2.0 * math.pi * t / period)
            ) / 2.0

        return LoadProfile(lam, base + amplitude, "diurnal")

    @staticmethod
    def bursty(
        base: float,
        burst_rate: float,
        burst_every: float,
        burst_len: float,
    ) -> "LoadProfile":
        """λ(t) = base, except ``burst_rate`` during the first
        ``burst_len`` seconds of every ``burst_every``-second window —
        the flash-crowd shape that exercises queueing + backpressure."""
        if base <= 0 or burst_rate < base:
            raise ValueError(
                f"need burst_rate >= base > 0, got ({base}, {burst_rate})"
            )
        if not 0 < burst_len <= burst_every:
            raise ValueError(
                f"need 0 < burst_len <= burst_every, got "
                f"({burst_len}, {burst_every})"
            )

        def lam(t: float) -> float:
            return burst_rate if (t % burst_every) < burst_len else base

        return LoadProfile(lam, burst_rate, "bursty")

    def scaled(self, factor: float) -> "LoadProfile":
        """The same shape at ``factor``× the rate — how the benchmark
        sweeps offered load along one curve."""
        if factor <= 0:
            raise ValueError(f"factor must be > 0, got {factor}")
        return LoadProfile(
            lambda t: self.rate(t) * factor,
            self.peak * factor,
            f"{self.name}x{factor:g}",
        )


def generate_jobs(
    profile: LoadProfile,
    duration: float,
    *,
    m: int,
    seed: int,
    width_mix: Sequence[tuple[int, float]] = ((1, 1.0),),
    deadline_s: float | None = None,
) -> list[ArrivalJob]:
    """Draw a deterministic open-loop job trace from ``profile``.

    Lewis–Shedler thinning: candidate arrivals are a homogeneous
    Poisson process at ``profile.peak``; a candidate at time t survives
    with probability λ(t)/peak. ``width_mix`` is a sequence of
    ``(k, weight)`` panel widths; weights are normalized. Every random
    choice (inter-arrival gaps, thinning, widths, feature values) comes
    from one ``np.random.default_rng(seed)`` stream, so the trace is a
    pure function of the arguments.
    """
    if duration <= 0:
        raise ValueError(f"duration must be > 0, got {duration}")
    if not width_mix or any(k < 1 or w <= 0 for k, w in width_mix):
        raise ValueError(
            f"width_mix needs positive (k, weight) pairs, got {width_mix}"
        )
    rng = np.random.default_rng(seed)
    widths = np.array([k for k, _ in width_mix], dtype=np.int64)
    weights = np.array([w for _, w in width_mix], dtype=np.float64)
    weights = weights / weights.sum()

    jobs: list[ArrivalJob] = []
    t = 0.0
    rid = 0
    while True:
        t += float(rng.exponential(1.0 / profile.peak))
        if t >= duration:
            break
        if rng.uniform() > profile.rate(t) / profile.peak:
            continue  # thinned away: λ(t) < peak here
        k = int(widths[rng.choice(len(widths), p=weights)])
        features = jax.numpy.asarray(
            rng.uniform(0.0, 1.0, size=(m, k)).astype(np.float32)
        )
        jobs.append(
            ArrivalJob(
                rid=rid,
                t=t,
                features=features,
                deadline=None if deadline_s is None else t + deadline_s,
            )
        )
        rid += 1
    return jobs


__all__ = ["ArrivalJob", "LoadProfile", "generate_jobs"]
