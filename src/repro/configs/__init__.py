"""Config registry: ``get_config(name)`` / ``--arch <id>``."""

from __future__ import annotations

from repro.configs import graphblas_mlp
from repro.configs.base import (
    SHAPE_CELLS,
    AttentionConfig,
    LayerSpec,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    ShapeCell,
    SparsityConfig,
)

from repro.configs.deepseek_v2_236b import CONFIG as _deepseek
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.qwen1_5_4b import CONFIG as _qwen15
from repro.configs.gemma3_4b import CONFIG as _gemma3
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.llama3_2_1b import CONFIG as _llama32
from repro.configs.internvl2_76b import CONFIG as _internvl2
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _deepseek,
        _moonshot,
        _qwen15,
        _gemma3,
        _qwen2,
        _llama32,
        _internvl2,
        _rwkv6,
        _musicgen,
        _jamba,
    )
}

ASSIGNED_ARCHS = tuple(ARCHS)  # the 10 assigned architectures


def get_config(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name.startswith("graphblas-mlp"):
        return graphblas_mlp.CONFIG
    raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")


__all__ = [
    "ARCHS",
    "ASSIGNED_ARCHS",
    "SHAPE_CELLS",
    "ShapeCell",
    "get_config",
    "ModelConfig",
    "AttentionConfig",
    "MoEConfig",
    "MambaConfig",
    "RWKVConfig",
    "SparsityConfig",
    "LayerSpec",
    "graphblas_mlp",
]
