"""InternVL2-Llama3-76B LM backbone [arXiv:2404.16821]: the language
tower is Hermes-2-Theta-Llama-3-70B — 80L, d_model 8192, 64 heads GQA
(kv=8, head_dim 128), d_ff 28672, vocab 128256. The InternViT-6B vision
frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed patch/text embeddings (B, S, d_model)."""

from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    d_ff=28672,
    vocab_size=128_256,
    attention=AttentionConfig(
        kind="gqa",
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=500_000.0,
    ),
    period=(LayerSpec(mixer="attn", ffn="dense"),),
    input_mode="embeddings",
    max_seq_len=32_768,
    citation="arXiv:2404.16821",
)
