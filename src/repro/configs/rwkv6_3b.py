"""RWKV-6 (Finch) 3B [arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b]: 32L,
d_model 2560, attention-free time-mix with data-dependent decay (40
heads of 64), channel-mix d_ff 8960 (3.5×), vocab 65536."""

from repro.configs.base import LayerSpec, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65_536,
    attention=None,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    period=(LayerSpec(mixer="rwkv", ffn="rwkv_channel_mix"),),
    act="relu",  # channel-mix uses squared ReLU
    glu=False,
    max_seq_len=1_048_576,  # state-based: unbounded context
    citation="arXiv:2404.05892",
)
