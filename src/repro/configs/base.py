"""Model configuration dataclasses + the assigned input-shape cells.

Every assigned architecture is described by a :class:`ModelConfig` built
from published dimensions (citations in each config file). Layer stacking
is expressed as ``head`` (unique leading layers, e.g. DeepSeek's dense
layer 0), a repeating ``period`` pattern (scanned), and a ``tail``
(remainder layers, e.g. Gemma-3's 34 = 5·6 + 4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    kind: str  # "gqa" | "mla"
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # sliding window (0 = full/causal); per-layer override via LayerSpec
    window: int = 0
    # MLA (DeepSeek-V2) dims
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """The paper's technique applied to model weights (DESIGN.md §4)."""

    block_shape: Tuple[int, int] = (128, 128)
    blocks_per_row: int = 0  # 0 = dense; else ELL budget per block-row
    targets: Tuple[str, ...] = ("ffn",)  # which weight families go BSR


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer's composition within the stack pattern."""

    mixer: str = "attn"  # "attn" | "mamba" | "rwkv"
    ffn: str = "dense"  # "dense" | "moe" | "rwkv_channel_mix"
    window: int = 0  # per-layer attention window (gemma3 locals)
    rope_theta: float = 0.0  # per-layer theta override (0 = global)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | mlp
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    sparsity: Optional[SparsityConfig] = None
    head: Tuple[LayerSpec, ...] = ()
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)
    tail: Tuple[LayerSpec, ...] = ()
    act: str = "silu"  # silu | gelu | relu
    glu: bool = True
    norm_eps: float = 1e-6
    post_norms: bool = False  # gemma3 sandwich norms
    tie_embeddings: bool = False
    input_mode: str = "tokens"  # tokens | embeddings (vlm/audio stubs)
    max_seq_len: int = 131_072
    remat: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    citation: str = ""

    def __post_init__(self):
        n_pattern = self.num_layers - len(self.head) - len(self.tail)
        if n_pattern < 0 or (
            len(self.period) and n_pattern % len(self.period) != 0
        ):
            raise ValueError(
                f"{self.name}: head({len(self.head)}) + k·period"
                f"({len(self.period)}) + tail({len(self.tail)}) cannot reach"
                f" {self.num_layers} layers"
            )

    @property
    def n_periods(self) -> int:
        return (self.num_layers - len(self.head) - len(self.tail)) // len(
            self.period
        )

    def layer_specs(self) -> list[LayerSpec]:
        return (
            list(self.head)
            + list(self.period) * self.n_periods
            + list(self.tail)
        )

    @property
    def sub_quadratic(self) -> bool:
        """True if every layer's mixer is O(seq) at decode with bounded
        state/KV (SSM, linear-attn, or bounded-window attention)."""
        full_attn_layers = [
            s
            for s in self.layer_specs()
            if s.mixer == "attn" and s.window == 0
        ]
        # hybrid archs with a small fraction of full-attn layers still
        # qualify per the assignment (jamba, gemma3's 1-in-6 globals).
        return len(full_attn_layers) <= self.num_layers // 4

    def scaled_down(
        self,
        *,
        num_layers: int | None = None,
        d_model: int = 64,
        vocab_size: int = 512,
        max_seq_len: int = 256,
    ) -> "ModelConfig":
        """Structure-preserving reduced config for CPU smoke tests."""
        period = self.period
        head, tail = self.head, self.tail
        if num_layers is None:
            num_layers = len(head) + len(period) + len(tail)
        scale = d_model / self.d_model
        attn = None
        if self.attention is not None:
            a = self.attention
            heads = max(2, int(a.num_heads * scale)) if a.num_heads else 0
            kv = max(1, min(heads, int(a.num_kv_heads * scale)) or 1)
            heads = (heads // kv) * kv or kv
            attn = dataclasses.replace(
                a,
                num_heads=heads,
                num_kv_heads=kv,
                head_dim=16,
                q_lora_rank=32 if a.q_lora_rank else 0,
                kv_lora_rank=16 if a.kv_lora_rank else 0,
                qk_nope_head_dim=16 if a.qk_nope_head_dim else 0,
                qk_rope_head_dim=8 if a.qk_rope_head_dim else 0,
                v_head_dim=16 if a.v_head_dim else 0,
                window=min(a.window, 64) if a.window else 0,
            )
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_expert=32,
                # capacity ≥ group size at test scale: GShard token dropping
                # depends on grouping, which would make prefill/forward
                # outputs diverge spuriously in consistency tests
                capacity_factor=float(4 // min(self.moe.top_k, 2)),
            )
        period = tuple(
            dataclasses.replace(s, window=min(s.window, 64) if s.window else 0)
            for s in period
        )
        head = tuple(head)
        tail = tuple(tail)
        n_pattern = num_layers - len(head) - len(tail)
        if n_pattern < len(period) or n_pattern % len(period):
            # keep exactly head + 1 period + tail
            num_layers = len(head) + len(period) + len(tail)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            d_ff=d_model * 2,
            vocab_size=vocab_size,
            attention=attn,
            moe=moe,
            mamba=dataclasses.replace(self.mamba, d_state=4, d_conv=2)
            if self.mamba
            else None,
            rwkv=dataclasses.replace(
                self.rwkv, head_dim=16, decay_lora=8, mix_lora=8
            )
            if self.rwkv
            else None,
            head=head,
            period=period,
            tail=tail,
            max_seq_len=max_seq_len,
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch × input-shape) evaluation cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
