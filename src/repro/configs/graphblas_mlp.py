"""The paper's own architecture (Kepner et al. 2017, §IV-§V): an L-layer
square ReLU MLP, weights m×m (dense or sparse), bias per layer, batch
n=64. ``make_config(m, inverse_sparsity)`` reproduces the experimental
grid of Fig. 5 (m ∈ {512, 2048, 8192, 32768}; inverse sparsity 1 →
262144). The DNN is evaluated through ``repro.core.dnn`` over the
(S1, S2) semiring pair."""

from repro.configs.base import LayerSpec, ModelConfig, SparsityConfig


def make_config(
    m: int = 8192,
    num_layers: int = 8,
    inverse_sparsity: int = 1,
    block: int = 128,
) -> ModelConfig:
    if inverse_sparsity <= 1:
        sparsity = None
    else:
        ncb = m // block
        bpr = max(1, round(ncb / inverse_sparsity))
        sparsity = SparsityConfig(
            block_shape=(block, block), blocks_per_row=bpr, targets=("ffn",)
        )
    return ModelConfig(
        name=f"graphblas-mlp-m{m}-is{inverse_sparsity}",
        family="mlp",
        num_layers=num_layers,
        d_model=m,
        d_ff=m,
        vocab_size=m,  # features in = features out = m
        attention=None,
        sparsity=sparsity,
        period=(LayerSpec(mixer="none", ffn="relu_mlp"),),
        act="relu",
        glu=False,
        input_mode="features",
        max_seq_len=1,
        compute_dtype="float32",  # the paper's experiments are FP32 (§V-B)
        citation="Kepner et al. 2017 (this paper)",
    )


CONFIG = make_config()
