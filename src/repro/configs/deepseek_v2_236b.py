"""DeepSeek-V2 236B (MLA + fine-grained MoE). [arXiv:2405.04434; hf
deepseek-ai/DeepSeek-V2]: 60L, d_model 5120, 128 heads MLA
(q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128),
160 routed experts top-6 (d_expert 1536) + 2 shared, layer 0 dense FFN
(intermediate 12288), vocab 102400."""

from repro.configs.base import (
    AttentionConfig,
    LayerSpec,
    ModelConfig,
    MoEConfig,
)

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    d_ff=12288,  # dense-FFN intermediate (layer 0)
    vocab_size=102_400,
    attention=AttentionConfig(
        kind="mla",
        num_heads=128,
        num_kv_heads=128,
        head_dim=192,  # qk_nope + qk_rope
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        rope_theta=10_000.0,
    ),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_expert=1536,
        num_shared_experts=2,
        capacity_factor=1.25,
    ),
    head=(LayerSpec(mixer="attn", ffn="dense"),),
    period=(LayerSpec(mixer="attn", ffn="moe"),),
    max_seq_len=131_072,
    citation="arXiv:2405.04434",
)
