"""Qwen2-72B [arXiv:2407.10671; hf:Qwen/Qwen2-72B]: 80L, d_model 8192,
64 heads GQA (kv=8, head_dim 128), d_ff 29568, vocab 152064, QKV bias."""

from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152_064,
    attention=AttentionConfig(
        kind="gqa",
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    period=(LayerSpec(mixer="attn", ffn="dense"),),
    max_seq_len=131_072,
    citation="arXiv:2407.10671",
)
