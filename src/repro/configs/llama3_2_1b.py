"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B]: 16L, d_model 2048,
32 heads GQA (kv=8, head_dim 64), d_ff 8192, vocab 128256, tied
embeddings."""

from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab_size=128_256,
    attention=AttentionConfig(
        kind="gqa",
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        rope_theta=500_000.0,
    ),
    period=(LayerSpec(mixer="attn", ffn="dense"),),
    tie_embeddings=True,
    max_seq_len=131_072,
    citation="hf:meta-llama/Llama-3.2-1B",
)
