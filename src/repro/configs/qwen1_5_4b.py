"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B; arXiv:2309.16609 family]: 40L,
d_model 2560, 20 heads MHA (kv=20, head_dim 128), d_ff 6912, vocab
151936, QKV bias (Qwen signature)."""

from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    d_ff=6912,
    vocab_size=151_936,
    attention=AttentionConfig(
        kind="gqa",
        num_heads=20,
        num_kv_heads=20,
        head_dim=128,
        qkv_bias=True,
        rope_theta=5_000_000.0,
    ),
    period=(LayerSpec(mixer="attn", ffn="dense"),),
    max_seq_len=32_768,
    citation="hf:Qwen/Qwen1.5-4B",
)
