"""Jamba-v0.1 52B [arXiv:2403.19887; hf:ai21labs/Jamba-v0.1]: 32L hybrid,
d_model 4096, Mamba:attention 7:1 (attn at period offset 4), MoE every
other layer (16 experts top-2, d_expert 14336), attn 32 heads GQA kv=8
(head_dim 128), Mamba d_state 16 / d_conv 4 / expand 2, vocab 65536.
Period of 8: [M, M(moe), M, M(moe), A, M(moe), M, M(moe)] × 4."""

from repro.configs.base import (
    AttentionConfig,
    LayerSpec,
    MambaConfig,
    ModelConfig,
    MoEConfig,
)

_M = LayerSpec(mixer="mamba", ffn="dense")
_Mmoe = LayerSpec(mixer="mamba", ffn="moe")
_A = LayerSpec(mixer="attn", ffn="dense")
_Amoe = LayerSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65_536,
    attention=AttentionConfig(
        kind="gqa",
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=10_000.0,
    ),
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_expert=14336,
        num_shared_experts=0,
        capacity_factor=1.25,
    ),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    period=(_M, _Mmoe, _M, _Mmoe, _A, _Mmoe, _M, _Mmoe),
    max_seq_len=262_144,
    citation="arXiv:2403.19887",
)
