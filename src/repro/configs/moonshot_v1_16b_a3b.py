"""Moonlight-16B-A3B (Kimi/Moonshot, DeepSeek-V3-style MoE).
[hf:moonshotai/Moonlight-16B-A3B]: 48L(+embed norm), d_model 2048,
16 heads (MHA kv=16, head_dim 128), 64 routed experts top-6
(moe_intermediate 1408) + 2 shared, first layer dense (intermediate
11264), vocab 163840."""

from repro.configs.base import (
    AttentionConfig,
    LayerSpec,
    ModelConfig,
    MoEConfig,
)

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    d_ff=11264,
    vocab_size=163_840,
    attention=AttentionConfig(
        kind="gqa",
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        rope_theta=50_000.0,
    ),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_expert=1408,
        num_shared_experts=2,
        capacity_factor=1.25,
    ),
    head=(LayerSpec(mixer="attn", ffn="dense"),),
    period=(LayerSpec(mixer="attn", ffn="moe"),),
    max_seq_len=8192,
    citation="hf:moonshotai/Moonlight-16B-A3B",
)
