"""MusicGen-large decoder [arXiv:2306.05284; hf:facebook/musicgen-large]:
48L, d_model 2048, 32 heads MHA (kv=32, head_dim 64), d_ff 8192 (GELU,
non-gated), vocab 2048 (EnCodec codebook). The EnCodec tokenizer +
codebook-interleaving frontend is a STUB per the assignment:
``input_specs`` supplies precomputed frame embeddings (sum of the 4
codebook embeddings)."""

from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    d_ff=8192,
    vocab_size=2048,
    attention=AttentionConfig(
        kind="gqa",
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        rope_theta=10_000.0,
    ),
    period=(LayerSpec(mixer="attn", ffn="dense"),),
    act="gelu",
    glu=False,
    input_mode="embeddings",
    max_seq_len=32_768,
    citation="arXiv:2306.05284",
)
