"""Gemma-3-4B [hf:google/gemma-3-4b-pt; Gemma-3 report]: 34L, d_model
2560, 8 heads GQA (kv=4, head_dim 256), d_ff 10240 (GeGLU), vocab
262144, 5:1 local:global interleave (window 1024), qk-norm, sandwich
(post) norms, rope theta 1M global / 10k local. 34 = 5·(5L+1G) + 4L."""

from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="attn", ffn="dense", window=1024, rope_theta=10_000.0)
_GLOBAL = LayerSpec(mixer="attn", ffn="dense", window=0, rope_theta=1_000_000.0)

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    d_ff=10240,
    vocab_size=262_144,
    attention=AttentionConfig(
        kind="gqa",
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        qk_norm=True,
        rope_theta=1_000_000.0,
    ),
    period=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    tail=(_LOCAL, _LOCAL, _LOCAL, _LOCAL),
    act="gelu",
    post_norms=True,
    tie_embeddings=True,
    max_seq_len=131_072,
    citation="hf:google/gemma-3-4b-pt",
)
