"""Compile-once execution plans (`docs/architecture.md`).

The paper's economics — inference cost ∝ stored nonzeros — assume the
per-topology analysis is free. It is, but only if it happens once: this
package compiles a sparse stack's layout choices, route (fused /
layered / XLA), exact grid-step bill, cached block-CSR backward
transpose, and a per-width-class jitted executable into a
:class:`StackPlan`, cached in a :class:`PlanCache` keyed by
``(topology fingerprint, width class, differentiable?)``. Every
execution path — ``repro.core.dnn``, ``repro.serve``, ``repro.train``
— consults plans instead of re-deriving dispatch per call.
"""

from repro.plan.cache import (  # noqa: F401
    PlanCache,
    default_cache,
    reset_default_cache,
)
from repro.plan.cost import (  # noqa: F401
    layer_block_area,
    layer_grid_steps,
    mxv_grid_steps,
    stack_block_work,
    stack_grid_steps,
)
from repro.plan.degrade import (  # noqa: F401
    LEVEL_LAYERED,
    LEVEL_RESIDENT,
    LEVEL_SHARDED,
    DegradationLadder,
    DegradeEvent,
)
from repro.plan.layout import (  # noqa: F401
    ELL_WASTE_THRESHOLD,
    layer_layout,
    preferred_layout,
    to_preferred_layout,
)
from repro.plan.mxm import (  # noqa: F401
    MxmPlan,
    mxm_cache_stats,
    mxm_plan,
    reset_mxm_cache,
)
from repro.plan.routes import (  # noqa: F401
    ROUTE_FUSED,
    ROUTE_FUSED_TILED,
    ROUTE_LAYERED,
    ROUTE_SHARDED,
    ROUTE_XLA,
    fused_route,
    layer_path,
    resident_eligible,
)
from repro.plan.sharded import (  # noqa: F401
    ShardedLayerPlan,
    ShardedStackPlan,
    build_sharded_plan,
    mesh_fingerprint,
)
from repro.plan.stack_plan import (  # noqa: F401
    DEFAULT_WIDTH_CLASSES,
    LayerPlan,
    PlanKey,
    StackPlan,
    build_plan,
    quantize_width,
    topology_fingerprint,
)

__all__ = [
    "ELL_WASTE_THRESHOLD",
    "DEFAULT_WIDTH_CLASSES",
    "ROUTE_FUSED",
    "ROUTE_FUSED_TILED",
    "ROUTE_LAYERED",
    "ROUTE_SHARDED",
    "ROUTE_XLA",
    "LEVEL_LAYERED",
    "LEVEL_RESIDENT",
    "LEVEL_SHARDED",
    "DegradationLadder",
    "DegradeEvent",
    "LayerPlan",
    "PlanCache",
    "PlanKey",
    "ShardedLayerPlan",
    "ShardedStackPlan",
    "StackPlan",
    "build_plan",
    "build_sharded_plan",
    "default_cache",
    "fused_route",
    "layer_block_area",
    "layer_grid_steps",
    "layer_layout",
    "stack_block_work",
    "layer_path",
    "mesh_fingerprint",
    "preferred_layout",
    "quantize_width",
    "reset_default_cache",
    "resident_eligible",
    "stack_grid_steps",
    "to_preferred_layout",
    "topology_fingerprint",
]
