"""The route decision tree — how a sparse stack executes.

One decision, made once per (topology, width-class, differentiable?)
key at plan-build time and never re-derived per call:

    homogeneous square BSR stack AND not differentiable AND fused
    allowed?
      └─ panel fits VMEM → **fused**: ONE VMEM-resident ``pallas_call``
               for the whole stack (``repro.kernels.fused_mlp``)
      └─ panel past ``VMEM_SOFT_LIMIT_BYTES`` → **fused-tiled**: still
               ONE ``pallas_call``, but the ping-pong activation panel
               lives in HBM scratch and the m dimension is tiled over
               the row-block grid
               (``repro.kernels.fused_mlp.fused_mlp_tiled_forward``)
      └─ no  → per-layer dispatch, by execution layout:
               block-CSR → **kernel-bcsr** (occupancy-exact grid; the
                           differentiable backward reuses the plan's
                           cached transpose)
               ELL-BSR   → **kernel-ell**
               dense     → **kernel-dense** (Pallas tiled matmul), or
                           **xla-dense** when the plan must stay
                           ``jax.grad``-compatible (the dense Pallas
                           kernel has no VJP)
    all layers xla-dense → the stack route reads **xla** (pure-XLA
    fallback); otherwise **layered**.

See ``docs/architecture.md`` for the prose version of this tree.
"""

from __future__ import annotations

from typing import Sequence

from repro.kernels import DEFAULT_BLOCK_N
from repro.plan.layout import Weight, layer_layout
from repro.sparse.bsr import BlockSparseMatrix

ROUTE_FUSED = "fused"
ROUTE_FUSED_TILED = "fused-tiled"
ROUTE_LAYERED = "layered"
ROUTE_XLA = "xla"
# Mesh-sharded layered route (repro.plan.sharded): per-shard block-CSR
# kernels under shard_map with a psum between layers. Chosen explicitly
# by passing a mesh, never by the single-device decision tree above.
ROUTE_SHARDED = "sharded"


def _homogeneous_bsr_stack(weights: Sequence[Weight]) -> bool:
    """≥1 layer, all BSR with identical shape / block shape / pad width
    — the structural precondition both fused kernels share.
    (BlockCSRMatrix stacks take the layered path — per-layer
    ``total_blocks`` varies, so there is no static stacked layout.)"""
    if not weights:
        return False
    first = weights[0]
    if not isinstance(first, BlockSparseMatrix):
        return False
    return all(
        isinstance(w, BlockSparseMatrix)
        and w.shape == first.shape
        and w.block_shape == first.block_shape
        and w.max_blocks_per_row == first.max_blocks_per_row
        for w in weights
    )


def resident_eligible(
    weights: Sequence[Weight],
    *,
    block_n: int = DEFAULT_BLOCK_N,
    panel_dtype=None,
    vmem_limit: int | None = None,
) -> bool:
    """Can this stack run through the single-call VMEM-resident kernel?

    Requires: a homogeneous square BSR stack whose activation panel (at
    this ``block_n`` and ``panel_dtype``) fits the VMEM budget. Stacks
    past the budget are NOT resident-eligible but may still be
    ``fused-tiled``-eligible — :func:`fused_route` makes the three-way
    call. bf16 panels halve the panel bill, so the same stack can be
    resident under ``panel_dtype="bfloat16"`` and tiled under f32.
    """
    from repro.kernels import fused_mlp as _fmlp

    if not _homogeneous_bsr_stack(weights):
        return False
    return _fmlp.fused_mlp_eligible(
        weights[0], block_n, panel_dtype=panel_dtype, vmem_limit=vmem_limit
    )


def fused_route(
    weights: Sequence[Weight],
    *,
    block_n: int = DEFAULT_BLOCK_N,
    panel_dtype=None,
    vmem_limit: int | None = None,
) -> str | None:
    """Which single-``pallas_call`` fused route (if any) fits this stack.

    ``ROUTE_FUSED`` when the activation panel fits VMEM
    (:func:`resident_eligible`), ``ROUTE_FUSED_TILED`` for a homogeneous
    square BSR stack past the VMEM budget (panel ping-pongs through HBM
    scratch, m tiled over the row-block grid), ``None`` when only the
    per-layer routes apply. The boundary is exact:
    ``fused_mlp_vmem_bytes(m, block_n, panel_dtype) == vmem_limit``
    (default ``VMEM_SOFT_LIMIT_BYTES``) is the last resident m; one
    block-row more tips into fused-tiled. The autotuner moves this
    boundary through ``panel_dtype`` (bf16 halves the bill) and
    ``vmem_limit`` (silicon-calibrated budget).
    """
    from repro.kernels import fused_mlp as _fmlp

    if not _homogeneous_bsr_stack(weights):
        return None
    first = weights[0]
    if not _fmlp.fused_mlp_tiled_eligible(first, block_n):  # square check
        return None
    if _fmlp.fused_mlp_eligible(
        first, block_n, panel_dtype=panel_dtype, vmem_limit=vmem_limit
    ):
        return ROUTE_FUSED
    return ROUTE_FUSED_TILED


def layer_path(w: Weight, *, differentiable: bool) -> str:
    """The per-layer execution path for the layered route."""
    layout = layer_layout(w)
    if layout == "bcsr":
        return "kernel-bcsr"
    if layout == "ell":
        return "kernel-ell"
    return "xla-dense" if differentiable else "kernel-dense"
