"""The route decision tree — how a sparse stack executes.

One decision, made once per (topology, width-class, differentiable?)
key at plan-build time and never re-derived per call:

    resident-eligible AND not differentiable AND fused allowed?
      └─ yes → **fused**: ONE VMEM-resident ``pallas_call`` for the
               whole stack (``repro.kernels.fused_mlp``)
      └─ no  → per-layer dispatch, by execution layout:
               block-CSR → **kernel-bcsr** (occupancy-exact grid; the
                           differentiable backward reuses the plan's
                           cached transpose)
               ELL-BSR   → **kernel-ell**
               dense     → **kernel-dense** (Pallas tiled matmul), or
                           **xla-dense** when the plan must stay
                           ``jax.grad``-compatible (the dense Pallas
                           kernel has no VJP)
    all layers xla-dense → the stack route reads **xla** (pure-XLA
    fallback); otherwise **layered**.

See ``docs/architecture.md`` for the prose version of this tree.
"""

from __future__ import annotations

from typing import Sequence

from repro.plan.layout import Weight, layer_layout
from repro.sparse.bsr import BlockSparseMatrix

ROUTE_FUSED = "fused"
ROUTE_LAYERED = "layered"
ROUTE_XLA = "xla"
# Mesh-sharded layered route (repro.plan.sharded): per-shard block-CSR
# kernels under shard_map with a psum between layers. Chosen explicitly
# by passing a mesh, never by the single-device decision tree above.
ROUTE_SHARDED = "sharded"


def resident_eligible(
    weights: Sequence[Weight], *, block_n: int = 128
) -> bool:
    """Can this stack run through the single-call VMEM-resident kernel?

    Requires: ≥1 layer, all layers BSR with identical square shape /
    block shape / pad width, and the activation panel (at this
    ``block_n``) within the VMEM budget. (BlockCSRMatrix stacks take the
    layered path — per-layer ``total_blocks`` varies, so there is no
    static stacked layout.)
    """
    from repro.kernels import fused_mlp as _fmlp

    if not weights:
        return False
    first = weights[0]
    if not isinstance(first, BlockSparseMatrix):
        return False
    if not all(
        isinstance(w, BlockSparseMatrix)
        and w.shape == first.shape
        and w.block_shape == first.block_shape
        and w.max_blocks_per_row == first.max_blocks_per_row
        for w in weights
    ):
        return False
    return _fmlp.fused_mlp_eligible(first, block_n)


def layer_path(w: Weight, *, differentiable: bool) -> str:
    """The per-layer execution path for the layered route."""
    layout = layer_layout(w)
    if layout == "bcsr":
        return "kernel-bcsr"
    if layout == "ell":
        return "kernel-ell"
    return "xla-dense" if differentiable else "kernel-dense"
