"""Compile-once plans for GraphBLAS ``mxm``/``mxv`` on sparse layouts.

``repro.core.graphblas`` routes every sparse × dense product through
here instead of the pure-jnp XLA oracles (``repro.sparse.ops``): the
plan binds the occupancy-optimal execution layout (the same ELL-waste
heuristic DNN stack plans apply — a skewed ELL operand is re-laid out
to block-CSR once, at plan build), the exact grid-step bill from the
cost model (narrow ``mxv`` panels billed at the effective 8-wide tile,
not a full ``DEFAULT_BLOCK_N`` tile), and the Pallas kernel wrapper for
the plan's semiring.

Plans are cached under the same :class:`~repro.plan.stack_plan.PlanKey`
the stack-plan cache uses, with the key's ``semiring`` field carrying
the ⊕.⊗ algebra — a ``plus_times`` and a ``min_plus`` plan over the
same adjacency can never collide. Value staleness follows the
``PlanCache`` convention: a plan is only reused for the *same* operand
object (identity check), because the fingerprint hashes topology, not
stored values.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Union

import jax

from repro.kernels import DEFAULT_BLOCK_N
from repro.kernels import ops as kernel_ops
from repro.plan.cost import layer_grid_steps
from repro.plan.layout import layer_layout, to_preferred_layout
from repro.plan.stack_plan import PlanKey, topology_fingerprint
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array
SparseMatrix = Union[BlockSparseMatrix, BlockCSRMatrix]

_MAX_PLANS = 32


@dataclasses.dataclass
class MxmPlan:
    """One sparse operand × one panel width × one semiring, compiled.

    ``grid_steps`` is the exact Pallas bill of the kernel route;
    ``xla_equiv_grid_steps`` is the occupancy-equivalent bill of the
    *source* layout — what the pre-plan XLA sparse path pays (the ELL
    einsum computes every ``nrb × max_blocks_per_row`` slot, padding
    included), which is the number the GNN bench arm beats.
    """

    key: PlanKey
    source_layout: str  # caller's layout ("ell" / "bcsr")
    layout: str  # execution layout after the waste heuristic
    width: int  # the exact panel width n this plan bills for
    grid_steps: int  # kernel-route bill at this width (cost model)
    xla_equiv_grid_steps: int  # source-layout bill (XLA sparse path)
    weight: SparseMatrix  # execution operand (possibly re-laid-out)
    source: SparseMatrix  # the operand the plan was built from
    _fn: Callable[[SparseMatrix, Array], Array]

    def __call__(self, b: Array) -> Array:
        return self._fn(self.weight, b)


_cache: OrderedDict[PlanKey, MxmPlan] = OrderedDict()
_stats = {"lookups": 0, "hits": 0, "builds": 0, "evictions": 0}


def mxm_cache_stats() -> dict:
    return dict(_stats)


def reset_mxm_cache() -> None:
    _cache.clear()
    for k in _stats:
        _stats[k] = 0


def _executable(layout: str, semiring_name: str):
    if layout == "bcsr":
        return lambda w, b: kernel_ops.bcsr_spmm(
            w, b, semiring_name=semiring_name
        )
    return lambda w, b: kernel_ops.bsr_spmm(w, b, semiring_name=semiring_name)


def _build(
    a: SparseMatrix, n: int, semiring_name: str, key: PlanKey, block_n: int
) -> MxmPlan:
    exec_w = to_preferred_layout(a)  # ELL→CSR once the pad waste crosses
    return MxmPlan(
        key=key,
        source_layout=layer_layout(a),
        layout=layer_layout(exec_w),
        width=n,
        grid_steps=layer_grid_steps(exec_w, n, block_n=block_n),
        xla_equiv_grid_steps=layer_grid_steps(a, n, block_n=block_n),
        weight=exec_w,
        source=a,
        _fn=_executable(layer_layout(exec_w), semiring_name),
    )


def mxm_plan(
    a: SparseMatrix,
    n: int,
    semiring_name: str = "plus_times",
    *,
    block_n: int = DEFAULT_BLOCK_N,
) -> MxmPlan:
    """The cached plan for ``a ⊕.⊗ B`` with an ``(·, n)`` dense panel.

    Keyed by (topology fingerprint, exact width n, semiring) — the width
    is NOT quantized, so narrow ``mxv`` panels (n = 1) are billed at the
    8-wide effective tile the kernels actually run. A key hit whose
    cached plan was built from a *different* operand object rebuilds
    (values may differ under the same topology fingerprint).
    """
    key = PlanKey(
        fingerprint=topology_fingerprint([a]),
        width=n,
        differentiable=False,
        resident=False,
        semiring=semiring_name,
    )
    _stats["lookups"] += 1
    plan = _cache.get(key)
    if plan is not None and plan.source is a:
        _stats["hits"] += 1
        _cache.move_to_end(key)
        return plan
    plan = _build(a, n, semiring_name, key, block_n)
    _stats["builds"] += 1
    _cache[key] = plan
    _cache.move_to_end(key)
    while len(_cache) > _MAX_PLANS:
        _cache.popitem(last=False)
        _stats["evictions"] += 1
    return plan
