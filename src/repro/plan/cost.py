"""Exact grid-step accounting — the hardware-independent cost model.

One forward layer on an (·, n) activation panel executes a knowable
number of kernel grid steps; a :class:`~repro.plan.StackPlan` carries
the stack's total as a precomputed property so serving can bill pad
waste without re-deriving the sum per panel. Lifted out of
``repro.core.dnn`` (which keeps ``layer_grid_steps``/``dnn_grid_steps``
as aliases).

Every sparse branch delegates to the kernel module's own ``grid_steps``
formula and reads the block geometry from the weight's layout (NOT the
seed constants), so the model stays exact for autotuner-chosen block
sizes and ``block_n`` — ``tests/test_cost_model.py`` pins it against
the grid the Pallas calls actually launch.
"""

from __future__ import annotations

from typing import Sequence

from repro.kernels import DEFAULT_BLOCK_N
from repro.plan.layout import Weight
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix


def layer_grid_steps(
    w: Weight, n: int, *, block_n: int = DEFAULT_BLOCK_N
) -> int:
    """Exact kernel grid steps one forward layer executes on an (·, n)
    activation panel.

    ELL: ``nrb × max_blocks_per_row × n_tiles`` (the pad is paid on every
    block-row); block-CSR: ``total_nnz_blocks × n_tiles`` (occupancy-
    exact); dense: the full ``(m/bm) × (n/bn) × (k/bk)`` tile grid.
    Mirrors the effective-block-size shrink of ``repro.kernels.ops`` so
    narrow panels are accounted at the tile width they actually run at,
    and reads block geometry from the weight's own layout so tuner-chosen
    block sizes are billed exactly.
    """
    from repro.kernels import bcsr_spmm as _bcsr_kernel
    from repro.kernels import bsr_spmm as _bsr_kernel
    from repro.kernels.ops import _ceil_mult, effective_block_n

    bn = effective_block_n(n, block_n)
    if isinstance(w, BlockCSRMatrix):
        return _bcsr_kernel.grid_steps(w, n, bn)
    if isinstance(w, BlockSparseMatrix):
        return _bsr_kernel.grid_steps(w, n, bn)
    m, k = w.shape
    bm = min(DEFAULT_BLOCK_N, _ceil_mult(m))
    bk = min(DEFAULT_BLOCK_N, _ceil_mult(k))
    return -(-m // bm) * (-(-n // bn)) * -(-k // bk)


def mxv_grid_steps(w: Weight, *, block_n: int = DEFAULT_BLOCK_N) -> int:
    """Exact bill for a GraphBLAS ``mxv``/``vxm`` narrow panel (n = 1).

    The vector rides through the kernels as a ``[:, None]`` panel; the
    effective-tile shrink bottoms out at an 8-wide column tile, so the
    bill is one 8-wide stripe of the weight's grid — NOT a full
    ``DEFAULT_BLOCK_N``-wide tile. Same formula ``plan.mxm`` uses when
    it builds a width-1 plan."""
    return layer_grid_steps(w, 1, block_n=block_n)


def stack_grid_steps(
    weights: Sequence[Weight], n: int, *, block_n: int = DEFAULT_BLOCK_N
) -> int:
    """Total forward grid steps of the L-layer stack on an (m, n) panel.

    The VMEM-resident fused kernel's grid is ``(n_tiles, L, nrb, mbpr)``
    — exactly the Σ of its layers' ELL grids — so this sum is the step
    count for BOTH the layered and the resident dispatch; residency
    changes pallas_call count and HBM traffic, not grid steps.
    """
    return sum(layer_grid_steps(w, n, block_n=block_n) for w in weights)


def layer_block_area(w: Weight) -> int:
    """⊗-work units one grid step of this layer performs — the stored
    block's area (``bs_r × bs_c``), or the dense tile's. Grid-step counts
    at DIFFERENT block sizes are not comparable raw (a 32×32 step does 4×
    the MACs of a 16×16 step); the autotuner normalizes by this so
    re-blocked candidates cannot win the cost race by coarsening."""
    from repro.kernels.ops import _ceil_mult

    if isinstance(w, (BlockCSRMatrix, BlockSparseMatrix)):
        bs_r, bs_c = w.block_shape
        return bs_r * bs_c
    m, k = w.shape
    bm = min(DEFAULT_BLOCK_N, _ceil_mult(m))
    bk = min(DEFAULT_BLOCK_N, _ceil_mult(k))
    return bm * bk


def stack_block_work(
    weights: Sequence[Weight], n: int, *, block_n: int = DEFAULT_BLOCK_N
) -> int:
    """Σ layer grid steps × block area — the block-size-invariant cost
    the autotuner ranks candidates by (equal to ``stack_grid_steps × bs²``
    for homogeneous stacks)."""
    return sum(
        layer_grid_steps(w, n, block_n=block_n) * layer_block_area(w)
        for w in weights
    )
