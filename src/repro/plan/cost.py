"""Exact grid-step accounting — the hardware-independent cost model.

One forward layer on an (·, n) activation panel executes a knowable
number of kernel grid steps; a :class:`~repro.plan.StackPlan` carries
the stack's total as a precomputed property so serving can bill pad
waste without re-deriving the sum per panel. Lifted out of
``repro.core.dnn`` (which keeps ``layer_grid_steps``/``dnn_grid_steps``
as aliases).
"""

from __future__ import annotations

from typing import Sequence

from repro.plan.layout import Weight
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix


def layer_grid_steps(w: Weight, n: int, *, block_n: int = 128) -> int:
    """Exact kernel grid steps one forward layer executes on an (·, n)
    activation panel.

    ELL: ``nrb × max_blocks_per_row × n_tiles`` (the pad is paid on every
    block-row); block-CSR: ``total_nnz_blocks × n_tiles`` (occupancy-
    exact); dense: the full ``(m/bm) × (n/bn) × (k/bk)`` tile grid.
    Mirrors the effective-block-size shrink of ``repro.kernels.ops`` so
    narrow panels are accounted at the tile width they actually run at.
    """
    from repro.kernels import bcsr_spmm as _bcsr_kernel
    from repro.kernels.ops import _ceil_mult

    bn = min(block_n, _ceil_mult(n))
    n_tiles = -(-n // bn)
    if isinstance(w, BlockCSRMatrix):
        return _bcsr_kernel.grid_steps(w, n, bn)
    if isinstance(w, BlockSparseMatrix):
        nrb, mbpr = w.col_idx.shape
        return nrb * mbpr * n_tiles
    m, k = w.shape
    bm = min(128, _ceil_mult(m))
    bk = min(128, _ceil_mult(k))
    return -(-m // bm) * n_tiles * -(-k // bk)


def stack_grid_steps(
    weights: Sequence[Weight], n: int, *, block_n: int = 128
) -> int:
    """Total forward grid steps of the L-layer stack on an (m, n) panel.

    The VMEM-resident fused kernel's grid is ``(n_tiles, L, nrb, mbpr)``
    — exactly the Σ of its layers' ELL grids — so this sum is the step
    count for BOTH the layered and the resident dispatch; residency
    changes pallas_call count and HBM traffic, not grid steps.
    """
    return sum(layer_grid_steps(w, n, block_n=block_n) for w in weights)
