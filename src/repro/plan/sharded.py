"""Mesh-sharded execution plans — the multi-device sparse stack.

A :class:`~repro.plan.StackPlan` compiles one topology's dispatch for
one device; this module is the same amortization applied across a mesh,
the step the GraphChallenge scaling papers (arXiv:2004.01181,
arXiv:1909.05631) take past single-node memory. A
:class:`ShardedStackPlan`:

* partitions every sparse layer's block-CSR segment across the
  ``row_blocks`` mesh axes with near-equal nnz per shard
  (``repro.sparse.partition`` — built once per topology, like all plan
  analysis);
* compiles ONE shard-local SPMD executable per width class under
  ``jax.shard_map``: each shard runs the occupancy-exact ``bcsr_spmm``
  Pallas kernel over its own sub-segment (partial row products — the
  arithmetic semiring's ⊕ is +, so cuts may straddle rows), a ``psum``
  over the shard axes assembles the full activation panel between
  layers, and the bias + ReLU epilogue runs post-collective;
* bills grid steps **per shard**: each shard's bill is its local
  segment length × column tiles, so the per-shard bills sum to the
  unsharded occupancy-exact bill (plus any Tp-padding remainder when
  ``n_shards`` does not divide nnz — exposed, never hidden);
* stays differentiable: the custom VJPs of ``repro.kernels.autodiff``
  run inside the shard_map body with **per-shard cached transpose
  plans** (each shard's sub-topology is sorted once, at plan build),
  and fresh training values re-shard through a frozen gather
  (``ShardedBlockCSR.rescatter_values``) whose VJP scatters weight
  cotangents back onto the caller's unsharded layout.

Sharded plans live in the same :class:`repro.plan.PlanCache` as
single-device plans; :class:`repro.plan.PlanKey` carries the mesh
fingerprint so the two can never collide. Entry points:
``repro.core.dnn.dnn_forward(..., mesh=...)``,
``serve.SparseDNNEngine(mesh=...)`` (and the ``ContinuousBatcher``
above it), ``train.make_sparse_train_step(plan=sharded_plan)``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.plan import cost as _cost
from repro.plan import layout as _layout
from repro.plan import routes as _routes
from repro.plan.layout import Weight
from repro.plan.stack_plan import PlanKey, topology_fingerprint
from repro.sparse.bcsr import BcsrTransposePlan, BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix
from repro.sparse.partition import (
    ShardedBlockCSR,
    partition_block_csr,
    stack_transpose_plans,
)

Array = jax.Array


def mesh_fingerprint(mesh: Mesh, rules=None) -> str:
    """Stable cache-key component for a mesh's row-block sharding: the
    resolved shard axes, their sizes, AND the device ids. Two meshes
    with the same fingerprint partition a stack identically and run on
    the same devices — a shape-alike mesh over different devices must
    miss, because a plan's shard_map executable is bound to the mesh it
    was built with. ``None`` (no mesh) is the single-device key, so
    sharded and unsharded plans never collide."""
    from repro.distribution.sharding import row_block_axes

    axes = row_block_axes(mesh, rules)
    inner = ",".join(f"{a}={mesh.shape[a]}" for a in axes)
    devs = ",".join(str(d.id) for d in mesh.devices.flat)
    return f"row_blocks[{inner or 'replicated'}]@devices[{devs}]"


@dataclasses.dataclass(frozen=True)
class ShardedLayerPlan:
    """One layer's frozen partition artifacts."""

    index: int
    source_layout: str  # caller's layout ("dense"/"ell"/"bcsr")
    kind: str  # "bcsr" (partitioned) or "dense" (replicated)
    sharded: ShardedBlockCSR | None
    transpose: BcsrTransposePlan | None  # stacked per-shard plans
    grid_steps_per_shard: tuple[int, ...]  # at the plan's width


@dataclasses.dataclass
class ShardedStackPlan:
    """A compiled multi-device execution plan for one sparse stack at
    one width class. Duck-compatible with :class:`repro.plan.StackPlan`
    where serving needs it (``forward``/``grid_steps``/``route``/
    ``pallas_calls``/``compile_count``); extra sharding observability
    rides on top (``grid_steps_per_shard``, ``nnz_per_shard``,
    ``imbalance``)."""

    key: PlanKey
    mesh: Mesh
    axes: tuple[str, ...]  # mesh axes the shard dim spans
    n_shards: int
    layers: tuple[ShardedLayerPlan, ...]
    width: int
    differentiable: bool
    weights: tuple  # per-layer ShardedBlockCSR / replicated dense array
    biases: tuple
    source_weights: tuple  # caller's objects — cache identity check
    source_biases: tuple
    _body: Callable | None = None  # un-jitted shard_map'd forward
    _fn: Callable | None = None  # jitted serving executable
    _compiles: int = 0
    calls: int = 0

    # StackPlan-compatible surface ------------------------------------
    route: str = _routes.ROUTE_SHARDED
    is_sharded: bool = True

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def grid_steps_per_shard(self) -> tuple[int, ...]:
        """Per-shard forward bill (summed over layers) for one panel of
        this plan's width — the accounting `serve` surfaces per step."""
        return tuple(
            sum(lp.grid_steps_per_shard[s] for lp in self.layers)
            for s in range(self.n_shards)
        )

    @property
    def grid_steps(self) -> int:
        """Total kernel grid steps across all shards (Σ of the per-shard
        bills): equals the unsharded occupancy-exact bill whenever
        ``n_shards`` divides each layer's nnz (no Tp-padding remainder);
        ``shard_pad_blocks`` exposes the remainder otherwise."""
        return sum(self.grid_steps_per_shard)

    @property
    def pallas_calls(self) -> int:
        """Kernel launches per shard per forward (one per sparse layer)."""
        return sum(1 for lp in self.layers if lp.kind == "bcsr")

    @property
    def compile_count(self) -> int:
        return self._compiles

    @property
    def transpose_plans(self) -> tuple[BcsrTransposePlan | None, ...]:
        return tuple(lp.transpose for lp in self.layers)

    def nnz_per_shard(self) -> tuple[int, ...]:
        """Stored blocks per shard, summed over the sparse layers."""
        totals = [0] * self.n_shards
        for lp in self.layers:
            if lp.sharded is not None:
                for s, n in enumerate(lp.sharded.nnz_per_shard()):
                    totals[s] += int(n)
        return tuple(totals)

    def imbalance(self) -> float:
        """max-shard-nnz / mean-shard-nnz across the whole stack."""
        nnz = self.nnz_per_shard()
        total = sum(nnz)
        if total == 0:
            return 1.0
        return max(nnz) * self.n_shards / total

    def shard_pad_blocks(self) -> int:
        """Inert padding slots the common per-shard segment length adds
        over true nnz (nonzero only when n_shards ∤ a layer's nnz) —
        each one burns a grid step per column tile, billed honestly in
        ``grid_steps_per_shard``."""
        pad = 0
        for lp in self.layers:
            if lp.sharded is not None:
                nnz = int(lp.sharded.nnz_per_shard().sum())
                pad += lp.sharded.n_shards * lp.sharded.local_total_blocks - nnz
        return pad

    def describe(self) -> dict:
        return {
            "fingerprint": self.key.fingerprint[:12],
            "mesh": self.key.mesh,
            "shards": self.n_shards,
            "width": self.width,
            "differentiable": self.differentiable,
            "route": self.route,
            "layouts": [lp.kind for lp in self.layers],
            "grid_steps": self.grid_steps,
            "grid_steps_per_shard": list(self.grid_steps_per_shard),
            "nnz_per_shard": list(self.nnz_per_shard()),
            "imbalance": self.imbalance(),
            "shard_pad_blocks": self.shard_pad_blocks(),
            "pallas_calls": self.pallas_calls,
            "compiles": self.compile_count,
            "calls": self.calls,
        }

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def forward(self, y0: Array) -> Array:
        """One forward pass over an (m, k) panel, k ≤ the width class —
        same contract as ``StackPlan.forward``, executed SPMD over the
        mesh: every panel of this width class reuses ONE compiled
        shard_map executable."""
        m, k = y0.shape
        if k > self.width:
            raise ValueError(
                f"panel width {k} exceeds this plan's width class "
                f"{self.width}; fetch a plan for the wider class"
            )
        if k < self.width:
            y0 = jnp.pad(y0, ((0, 0), (0, self.width - k)))
        self.calls += 1
        out = self._fn(
            self.weights, self.transpose_plans, self.biases, y0
        )
        return out[:, :k]

    def forward_trainable(
        self,
        weights: Sequence[Weight],
        biases: Sequence[Array],
        y0: Array,
        *,
        use_kernel: bool = True,
        interpret: bool | None = None,
    ) -> Array:
        """Differentiable sharded forward with CALLER-supplied (fresh)
        values. The frozen partition re-shards each layer's values with
        one gather (VJP: scatter-add back onto the caller's layout), so
        weight cotangents keep the unsharded primal structure and the
        backward kernels run shard-local on the cached per-shard
        transposes. ``use_kernel=False`` falls back to the replicated
        jnp oracle (same math, XLA autodiff — CPU-bound runs)."""
        del interpret  # the shard_map body decides per-backend, like jit
        if not self.differentiable:
            raise ValueError(
                "forward_trainable needs a differentiable plan; rebuild "
                "with differentiable=True"
            )
        if len(weights) != self.n_layers:
            raise ValueError(
                f"plan has {self.n_layers} layers but the stack has "
                f"{len(weights)}"
            )
        if not use_kernel:
            from repro.core import dnn as _dnn

            y = y0
            for w, b in zip(weights, biases):
                y = _dnn.dnn_layer(w, y, b, fused=True)
            return y
        objs = []
        for lp, w in zip(self.layers, weights):
            if lp.kind == "bcsr":
                if not isinstance(w, BlockCSRMatrix):
                    raise ValueError(
                        "sharded differentiable plans require block-CSR "
                        f"weights; layer {lp.index} is "
                        f"{_layout.layer_layout(w)} (convert with "
                        "BlockCSRMatrix.from_bsr)"
                    )
                objs.append(
                    lp.sharded.with_values(
                        lp.sharded.rescatter_values(w.values)
                    )
                )
            else:
                objs.append(w)
        return self._body(
            tuple(objs), self.transpose_plans, tuple(biases), y0
        )


def _make_sharded_body(plan: ShardedStackPlan) -> Callable:
    """The shard_map'd SPMD forward. Per layer: shard-local
    occupancy-exact SpMM on the sub-segment → psum of the partial row
    products over the shard axes → bias + ReLU post-collective. Weights
    ride as pytree arguments (training substitutes fresh values); the
    in_specs come from the ``repro.distribution.sharding`` rule table."""
    from jax.experimental.shard_map import shard_map

    from repro.distribution.sharding import sharded_csr_pspecs
    from repro.kernels import ops as kernel_ops
    from repro.sparse import ops as sparse_ops

    mesh, axes = plan.mesh, plan.axes
    kinds = tuple(lp.kind for lp in plan.layers)

    def local_forward(layer_objs, tps, biases, y):
        for kind, obj, tp, b in zip(kinds, layer_objs, tps, biases):
            if kind == "bcsr":
                local = BlockCSRMatrix(
                    obj.values[0],
                    obj.row_ptr[0],
                    obj.row_id[0],
                    obj.col_idx[0],
                    obj.valid[0],
                    obj.shape,
                    obj.block_shape,
                )
                ltp = None
                if tp is not None:
                    ltp = BcsrTransposePlan(
                        tp.order[0],
                        tp.row_ptr[0],
                        tp.row_id[0],
                        tp.col_idx[0],
                        tp.valid[0],
                        tp.shape,
                        tp.block_shape,
                    )
                # Partial products only: bias/ReLU must wait for the
                # cross-shard sum (non-owned and empty rows read as the
                # semiring zero, so the psum is exact).
                z = kernel_ops.bcsr_spmm(
                    local, y, None, ltp, fuse_bias_relu=False
                )
                if axes:
                    z = jax.lax.psum(z, axes)
                y = jnp.maximum(z + b[:, None], 0.0)
            else:  # dense layer: replicated compute, no collective
                y = sparse_ops.dense_matmul_fused_relu(obj, y, b)
        return y

    w_specs = []
    tp_specs = []
    shard_spec = P(axes) if axes else P()
    for lp, w in zip(plan.layers, plan.weights):
        if lp.kind == "bcsr":
            w_specs.append(sharded_csr_pspecs(w, mesh))
            tp_specs.append(
                None
                if lp.transpose is None
                else jax.tree.map(lambda _: shard_spec, lp.transpose)
            )
        else:
            w_specs.append(P())
            tp_specs.append(None)

    return shard_map(
        local_forward,
        mesh=mesh,
        in_specs=(
            tuple(w_specs),
            tuple(tp_specs),
            jax.tree.map(lambda _: P(), tuple(plan.biases)),
            P(),
        ),
        out_specs=P(),
        check_rep=False,
    )


def build_sharded_plan(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    width: int,
    mesh: Mesh,
    *,
    differentiable: bool = False,
    use_resident: bool | None = None,
    fingerprint: str | None = None,
    donor: "ShardedStackPlan | None" = None,
) -> ShardedStackPlan:
    """Compile one :class:`ShardedStackPlan` (all per-topology,
    per-mesh analysis: partition, per-shard transposes, bills, SPMD
    executable).

    Layout rules: block-CSR layers are partitioned as-is; ELL layers are
    re-laid to block-CSR at build time for inference plans (the segment
    layout is what partitions) and **rejected** for differentiable plans
    (cotangents must mirror the caller's layout — convert the stack to
    block-CSR first); dense layers run replicated. ``use_resident=True``
    is refused — the VMEM-resident fused kernel is single-device.

    ``donor``: an existing sharded plan for the same (stack, mesh,
    differentiability) at another width class; partition artifacts and
    per-shard transposes are shared by reference, only the bills and the
    executable are per-width (``PlanCache.get`` supplies this).
    """
    from repro.distribution.sharding import mesh_shard_count, row_block_axes

    weights = tuple(weights)
    biases = tuple(biases)
    if len(weights) != len(biases):
        raise ValueError("weights/biases length mismatch")
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    if use_resident:
        raise ValueError(
            "use_resident=True is incompatible with mesh sharding: the "
            "VMEM-resident fused kernel runs a single device's VMEM; "
            "sharded plans always take the layered per-shard route"
        )
    if fingerprint is None:
        fingerprint = topology_fingerprint(weights)
    axes = row_block_axes(mesh)
    n_shards = mesh_shard_count(mesh)
    mesh_fp = mesh_fingerprint(mesh)
    key = PlanKey(fingerprint, width, differentiable, use_resident, mesh_fp)

    if donor is not None and (
        donor.key.fingerprint != fingerprint
        or donor.differentiable != differentiable
        or donor.key.mesh != mesh_fp
        or donor.n_layers != len(weights)
    ):
        raise ValueError(
            "donor plan does not match this stack's plan key "
            "(fingerprint / differentiable / mesh / layers)"
        )

    layer_plans = []
    exec_weights = []
    for i, w in enumerate(weights):
        src_layout = _layout.layer_layout(w)
        if isinstance(w, BlockSparseMatrix) and differentiable:
            raise ValueError(
                "sharded differentiable plans require block-CSR "
                f"weights; layer {i} is ELL (convert with "
                "BlockCSRMatrix.from_bsr so weight cotangents keep "
                "the caller's layout)"
            )
        if isinstance(w, (BlockSparseMatrix, BlockCSRMatrix)):
            if donor is not None:
                # width-independent artifacts (partition, transposes —
                # including any ELL→CSR relayout baked into them) are
                # shared by reference; only bills are per-width
                dlp = donor.layers[i]
                sharded, tp = dlp.sharded, dlp.transpose
            else:
                ew = (
                    BlockCSRMatrix.from_bsr(w)
                    if isinstance(w, BlockSparseMatrix)
                    else w
                )
                sharded = partition_block_csr(ew, n_shards)
                tp = (
                    stack_transpose_plans(sharded)
                    if differentiable
                    else None
                )
            bills = tuple(
                _cost.layer_grid_steps(sharded.shard(s), width)
                for s in range(n_shards)
            )
            layer_plans.append(
                ShardedLayerPlan(i, src_layout, "bcsr", sharded, tp, bills)
            )
            exec_weights.append(sharded)
        else:  # dense: replicated — every shard pays the full tile grid
            bill = _cost.layer_grid_steps(w, width)
            layer_plans.append(
                ShardedLayerPlan(
                    i, src_layout, "dense", None, None, (bill,) * n_shards
                )
            )
            exec_weights.append(w)

    plan = ShardedStackPlan(
        key=key,
        mesh=mesh,
        axes=axes,
        n_shards=n_shards,
        layers=tuple(layer_plans),
        width=width,
        differentiable=differentiable,
        weights=tuple(exec_weights),
        biases=biases,
        source_weights=weights,
        source_biases=biases,
    )
    body = _make_sharded_body(plan)
    plan._body = body

    def run(layer_objs, tps, bs, y):
        plan._compiles += 1
        return body(layer_objs, tps, bs, y)

    plan._fn = jax.jit(run)
    return plan
