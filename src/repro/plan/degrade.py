"""Graceful plan degradation: sharded → single-device → layered.

A :class:`repro.plan.PlanCache` answers "the plan for this (stack,
width)"; this module answers "the plan for this (stack, width) *given
the world is partly broken*". The ladder orders the execution levels a
serving engine can run a fingerprinted stack at:

1. ``sharded``  — mesh-sharded :class:`~repro.plan.ShardedStackPlan`
   (only when the engine was built with a mesh and it is healthy);
2. ``resident`` — single-device VMEM-resident fused plan (only when the
   engine resolved residency and no compile failure demoted it);
3. ``layered``  — single-device per-layer kernel plan, the floor: it
   needs nothing but one device and always exists.

``get_plan`` walks the ladder top-down and returns the first level that
produces a plan. A level that fails to build — a plan-compile failure,
a VMEM-guard rejection, an injected fault — is marked unhealthy and the
walk continues downward, so **in-flight requests are never dropped**: a
shard failure mid-stream re-plans the same fingerprint on a single
device and the panel that triggered the fallback is still served (the
plan cache already holds or builds the lower-level plan for the same
``PlanKey`` fingerprint). Health marks are sticky until ``restore``
(operator re-slots the node), and every transition is recorded in
:attr:`DegradationLadder.events` for the serve-stats surface.

The ladder deliberately knows nothing about *why* a level failed —
fault injection lives in ``repro.testing.faults`` and reaches this
layer only through the engine's ``compile_hook`` callback, keeping
``repro.plan`` dependency-free of the testing harness.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

LEVEL_SHARDED = "sharded"
LEVEL_RESIDENT = "resident"
LEVEL_LAYERED = "layered"


@dataclasses.dataclass(frozen=True)
class DegradeEvent:
    """One ladder transition (demotion or restore)."""

    step: int  # engine dispatch ordinal when the transition happened
    level: str  # the level whose health changed
    healthy: bool  # False = demoted, True = restored
    reason: str


class DegradationLadder:
    """Health-aware plan lookup over a :class:`~repro.plan.PlanCache`.

    ``mesh``/``use_resident`` describe the engine's *preferred* level;
    the ladder serves the highest healthy level at or below it. The
    floor level (``layered``) cannot be demoted — a failure there
    propagates, because there is nothing left to degrade to.
    """

    def __init__(
        self, cache, *, mesh=None, use_resident: bool = False, tuned=None
    ):
        self.cache = cache
        self.mesh = mesh
        self.use_resident = bool(use_resident)
        # Tuned kernel config (repro.tune.TunedConfig) applied at the
        # single-device levels; the sharded builder takes no tuning
        # knobs, so a sharded lookup always passes tuned=None.
        self.tuned = tuned
        self._healthy = {LEVEL_SHARDED: True, LEVEL_RESIDENT: True}
        self.events: list[DegradeEvent] = []

    @property
    def preferred_level(self) -> str:
        if self.mesh is not None:
            return LEVEL_SHARDED
        if self.use_resident:
            return LEVEL_RESIDENT
        return LEVEL_LAYERED

    def levels(self) -> list[str]:
        """Currently serviceable levels, most preferred first."""
        out = []
        if self.mesh is not None and self._healthy[LEVEL_SHARDED]:
            out.append(LEVEL_SHARDED)
        if self.use_resident and self._healthy[LEVEL_RESIDENT]:
            out.append(LEVEL_RESIDENT)
        out.append(LEVEL_LAYERED)
        return out

    def is_healthy(self, level: str) -> bool:
        return self._healthy.get(level, True)

    @property
    def degraded(self) -> bool:
        return self.levels()[0] != self.preferred_level

    def mark_unhealthy(self, level: str, *, reason: str, step: int = -1) -> None:
        """Demote a level (e.g. the mesh lost a shard). Idempotent."""
        if level not in self._healthy:
            raise ValueError(
                f"level {level!r} cannot be demoted (floor or unknown)"
            )
        if self._healthy[level]:
            self._healthy[level] = False
            self.events.append(DegradeEvent(step, level, False, reason))

    def restore(self, level: str, *, reason: str = "restored", step: int = -1):
        """Re-admit a demoted level (operator re-slotted the node)."""
        if level not in self._healthy:
            raise ValueError(f"level {level!r} has no health state")
        if not self._healthy[level]:
            self._healthy[level] = True
            self.events.append(DegradeEvent(step, level, True, reason))

    def get_plan(
        self,
        weights,
        biases,
        width: int,
        *,
        differentiable: bool = False,
        fingerprint: str | None = None,
        step: int = -1,
        compile_hook: Callable[[str], None] | None = None,
    ):
        """(plan, level, cache_hit) at the best healthy level.

        ``compile_hook(level)`` runs before each level's cache lookup;
        raising from it (fault injection, VMEM guards) demotes that
        level and falls through. Only the floor's failure propagates.
        """
        last_err: Exception | None = None
        for level in self.levels():
            try:
                if compile_hook is not None:
                    compile_hook(level)
                before = self.cache.hits
                plan = self.cache.get(
                    weights,
                    biases,
                    width,
                    differentiable=differentiable,
                    use_resident=level == LEVEL_RESIDENT,
                    fingerprint=fingerprint,
                    mesh=self.mesh if level == LEVEL_SHARDED else None,
                    tuned=self.tuned if level != LEVEL_SHARDED else None,
                )
                return plan, level, self.cache.hits > before
            except Exception as e:  # noqa: BLE001 — any build/compile fault
                last_err = e
                if level == LEVEL_LAYERED:
                    raise
                self.mark_unhealthy(
                    level,
                    reason=f"{type(e).__name__}: {e}",
                    step=step,
                )
        raise last_err if last_err else RuntimeError("no serviceable level")

    def describe(self) -> dict:
        return {
            "preferred": self.preferred_level,
            "current": self.levels()[0],
            "degraded": self.degraded,
            "health": dict(self._healthy),
            "events": [dataclasses.asdict(e) for e in self.events],
        }
