"""LRU cache of compiled :class:`~repro.plan.StackPlan` objects.

The cache is what turns per-call analysis into per-topology analysis:
serving looks a plan up per dispatched panel, and after the first panel
of each width class every lookup is a hit — zero layout decisions, zero
grid-step sums, zero topology sorts, zero recompiles on the hot path.

Keying: ``(topology fingerprint, width class, differentiable?,
requested residency)`` — see :class:`repro.plan.PlanKey`. Because plans
bind weight/bias VALUES (serving weights are frozen), a hit additionally
requires the cached plan's bound arrays to be the same objects the
caller passed; a same-topology stack with different value arrays
rebuilds instead of silently serving stale numbers.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Sequence

from repro.plan import sharded as _sharded
from repro.plan.layout import Weight
from repro.plan.stack_plan import (
    PlanKey,
    StackPlan,
    build_plan,
    topology_fingerprint,
)


class PlanCache:
    """Bounded LRU plan cache with observable hit/miss/eviction stats."""

    def __init__(self, max_size: int = 16):
        if max_size < 1:
            raise ValueError("max_size must be >= 1")
        self.max_size = max_size
        self._entries: "OrderedDict[PlanKey, StackPlan]" = OrderedDict()
        self.lookups = 0
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._entries

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def stats(self) -> dict:
        return {
            "size": len(self._entries),
            "max_size": self.max_size,
            "lookups": self.lookups,
            "hits": self.hits,
            "misses": self.misses,
            "builds": self.builds,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }

    def get(
        self,
        weights: Sequence[Weight],
        biases,
        width: int,
        *,
        differentiable: bool = False,
        use_resident: bool | None = None,
        relayout: bool | None = None,
        fingerprint: str | None = None,
        mesh=None,
        tuned=None,
    ) -> StackPlan:
        """The plan for this (stack, width, differentiable?, mesh) —
        cached.

        ``fingerprint`` skips the host-side topology hash when the
        caller already knows it (the engine computes it once at
        construction). ``mesh`` routes to a mesh-sharded
        :class:`repro.plan.ShardedStackPlan`; its fingerprint lands in
        the :class:`PlanKey`, so a sharded and an unsharded plan for the
        same topology never collide. ``tuned`` (a
        ``repro.tune.TunedConfig``) keys the entry by its ``token()``,
        so a tuned and an untuned plan for the same topology never
        collide either; the sharded builder takes no tuning knobs, so
        mesh + tuned together is an error.
        """
        weights = tuple(weights)
        biases = tuple(biases)
        if mesh is not None and tuned is not None:
            raise ValueError(
                "tuned configs apply to single-device plans only; "
                "pass tuned=None with a mesh"
            )
        if fingerprint is None:
            fingerprint = topology_fingerprint(weights)
        mesh_fp = None if mesh is None else _sharded.mesh_fingerprint(mesh)
        tuned_token = None if tuned is None else tuned.token()
        key = PlanKey(
            fingerprint, width, differentiable, use_resident, mesh_fp,
            tuned=tuned_token,
        )
        self.lookups += 1
        plan = self._entries.get(key)
        if (
            plan is not None
            and len(plan.source_weights) == len(weights)
            and all(a is b for a, b in zip(plan.source_weights, weights))
            and all(a is b for a, b in zip(plan.source_biases, biases))
        ):
            self.hits += 1
            self._entries.move_to_end(key)
            return plan
        self.misses += 1
        # A resident plan for the same stack at ANOTHER width class can
        # donate its width-independent artifacts (relayouted weights,
        # cached transposes, fused stack; for sharded plans: partition
        # layouts and per-shard transposes) — only the executable and
        # the grid-step bill are per-width.
        donor = None
        for cand in reversed(self._entries.values()):
            if (
                cand.key.fingerprint == fingerprint
                and cand.differentiable == differentiable
                and cand.key.resident == use_resident
                and cand.key.mesh == mesh_fp
                and cand.key.tuned == tuned_token
                and len(cand.source_weights) == len(weights)
                and all(
                    a is b for a, b in zip(cand.source_weights, weights)
                )
                and all(a is b for a, b in zip(cand.source_biases, biases))
            ):
                donor = cand
                break
        if mesh is not None:
            plan = _sharded.build_sharded_plan(
                weights,
                biases,
                width,
                mesh,
                differentiable=differentiable,
                use_resident=use_resident,
                fingerprint=fingerprint,
                donor=donor,
            )
        else:
            plan = build_plan(
                weights,
                biases,
                width,
                differentiable=differentiable,
                use_resident=use_resident,
                relayout=relayout,
                fingerprint=fingerprint,
                donor=donor,
                tuned=tuned,
            )
        self.builds += 1
        self._entries[key] = plan
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_size:
            self._entries.popitem(last=False)
            self.evictions += 1
        return plan

    def compiled_widths(self, fingerprint: str) -> set[int]:
        """Width classes this cache already holds a plan for, for one
        topology fingerprint. The fleet router's affinity signal
        (``repro.serve.fleet``): a replica whose cache lists a request's
        width class serves it without a fresh compile, so routing by
        this set keeps the fleet-wide hit rate at single-engine levels.
        Cheap (walks the ≤ max_size entries; no building, no LRU
        touch)."""
        return {
            key.width
            for key in self._entries
            if key.fingerprint == fingerprint
        }

    def clear(self) -> None:
        self._entries.clear()


# Shared cache behind the module-level convenience wrappers
# (repro.core.dnn.dnn_forward_resident and friends). Engines own their
# own caches; this one serves ad-hoc functional callers. Plans hold
# strong references to the weight stacks they bind, so this cache is
# kept SMALL — loops over many transient models retain at most
# ``max_size`` stacks; call ``default_cache().clear()`` to drop them
# eagerly.
_DEFAULT_CACHE: PlanCache | None = None


def default_cache() -> PlanCache:
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None:
        _DEFAULT_CACHE = PlanCache(max_size=4)
    return _DEFAULT_CACHE


def reset_default_cache() -> None:
    """Drop the shared default cache (entries AND stats) — test
    isolation: a test asserting hit/miss/build counts must not inherit
    plans another test parked in the process-wide cache."""
    global _DEFAULT_CACHE
    _DEFAULT_CACHE = None
