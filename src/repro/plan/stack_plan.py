"""Compile-once execution plans for sparse DNN stacks.

Every entry point used to re-derive *how* to run a stack on every call:
layout choice, fused-residency eligibility, grid-step billing, and —
worst — the block-CSR backward re-sorted the frozen topology every
single backward pass. A :class:`StackPlan` does all of that analysis
ONCE per ``(topology-fingerprint, panel-width class, differentiable?)``
key (the GraphChallenge amortization pattern: the topology is fixed,
the per-topology analysis should be too) and carries:

* the chosen layout per layer (the ELL-pad waste heuristic of
  ``repro.plan.layout``, applied at build time instead of per call);
* the route — fused / layered / XLA fallback (``repro.plan.routes``);
* the exact grid-step bill for the plan's panel width
  (``repro.plan.cost``);
* the **cached block-CSR transpose** (sorted layout + permutation,
  ``BcsrTransposePlan``) so differentiable paths never re-sort;
* a **jitted executable** per plan — serving quantizes panel widths to
  a small set of classes (:func:`quantize_width`) and reuses compiled
  plans instead of recompiling on every new panel width.

Plans are built through :class:`repro.plan.PlanCache`; the legacy entry
points (``repro.core.dnn``, ``repro.serve``) stay as thin wrappers.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import DEFAULT_BLOCK_N
from repro.plan import cost as _cost
from repro.plan import layout as _layout
from repro.plan import routes as _routes
from repro.plan.layout import Weight
from repro.sparse.bcsr import BcsrTransposePlan, BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array

# Panel-width classes serving quantizes to by default: one compiled
# executable per class instead of one per distinct request-batch width.
DEFAULT_WIDTH_CLASSES = (8, 16, 32, 64, 128, 256, 512)


def quantize_width(n: int, classes: Sequence[int] | None = None) -> int:
    """Smallest width class covering an ``n``-column panel.

    ``classes=None`` → identity (no quantization). Widths beyond the
    largest class round up to a multiple of it.
    """
    if not classes:
        return n
    for c in sorted(classes):
        if n <= c:
            return c
    top = max(classes)
    return -(-n // top) * top


def topology_fingerprint(weights: Sequence[Weight]) -> str:
    """Hash of the stack's *topology*: per-layer layout class, shapes,
    and index/mask arrays — NOT the stored values. Two stacks share a
    fingerprint iff every plan-relevant decision (layouts, routes, grid
    bills, transposes) is identical for both. Host-side (one device_get
    per topology; callers cache the result)."""
    h = hashlib.sha1()
    for w in weights:
        if isinstance(w, BlockCSRMatrix):
            h.update(b"bcsr")
            h.update(repr((w.shape, w.block_shape, w.total_blocks)).encode())
            for arr in (w.row_ptr, w.row_id, w.col_idx, w.valid):
                h.update(np.asarray(jax.device_get(arr)).tobytes())
        elif isinstance(w, BlockSparseMatrix):
            h.update(b"ell")
            h.update(
                repr((w.shape, w.block_shape, w.max_blocks_per_row)).encode()
            )
            for arr in (w.col_idx, w.block_mask):
                h.update(np.asarray(jax.device_get(arr)).tobytes())
        else:
            h.update(b"dense")
            h.update(repr(tuple(w.shape)).encode())
    return h.hexdigest()


class PlanKey(NamedTuple):
    """What a compiled plan is keyed on. Same topology + same width
    class + same differentiability (+ same residency request, + same
    mesh) → the same plan, hence a cache hit and zero recompiles.

    ``mesh`` is the mesh/shard fingerprint
    (:func:`repro.plan.sharded.mesh_fingerprint`) for sharded plans and
    ``None`` for single-device plans — a sharded and an unsharded plan
    for the same topology can NEVER collide in a cache.

    ``tuned`` is the :meth:`repro.tune.TunedConfig.token` of the tuning
    entry the plan was built under, or ``None`` for plans built on the
    hand-picked defaults — so a tuned and an untuned plan for the same
    topology can never collide either.

    ``semiring`` is the ⊕.⊗ algebra the plan's executable computes
    (``repro.core.semiring`` registry name). DNN stack plans are always
    ``plus_times``; the GraphBLAS ``mxm``/``mxv`` plans
    (:mod:`repro.plan.mxm`) key their algebra here so a ``plus_times``
    and a ``min_plus`` plan over the same topology can never collide."""

    fingerprint: str
    width: int
    differentiable: bool
    resident: bool | None  # the use_resident tri-state the caller asked
    mesh: str | None = None  # mesh/shard fingerprint, None = unsharded
    tuned: str | None = None  # TunedConfig token, None = default constants
    semiring: str = "plus_times"  # the plan's ⊕.⊗ algebra


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's precomputed execution decisions."""

    index: int
    source_layout: str  # layout of the caller's weight ("dense"/"ell"/"bcsr")
    layout: str  # execution layout after the waste heuristic
    path: str  # routes.layer_path value, or "fused"/"fused-tiled"
    grid_steps: int  # exact bill at the plan's width
    transpose_plan: BcsrTransposePlan | None  # cached backward transpose


@dataclasses.dataclass
class StackPlan:
    """A compiled execution plan for one sparse stack at one width class.

    Built by :func:`build_plan` (usually via ``PlanCache.get``). The
    plan binds the weights/biases it was built from — serving weights
    are frozen, so ``forward(y0)`` reuses the same jitted executable for
    every panel of this width class. Training passes fresh values
    through :meth:`forward_trainable`, which only consumes the plan's
    topology artifacts (layouts + cached transposes).
    """

    key: PlanKey
    route: str  # routes.ROUTE_FUSED / ROUTE_FUSED_TILED / ROUTE_LAYERED / ROUTE_XLA
    layers: tuple[LayerPlan, ...]
    width: int
    differentiable: bool
    grid_steps: int  # exact forward bill for one width-wide panel
    weights: tuple  # execution weights (post-relayout, bound values)
    biases: tuple
    source_weights: tuple  # caller's objects — cache identity check
    source_biases: tuple
    tuned: object | None = None  # the TunedConfig the plan was built under
    _stacked: tuple | None = None  # (stacked_w, stacked_b) for fused
    _fn: Callable | None = None
    _compiles: int = 0
    calls: int = 0

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def is_fused_route(self) -> bool:
        """Single-``pallas_call`` whole-stack route (resident or tiled)."""
        return self.route in (
            _routes.ROUTE_FUSED,
            _routes.ROUTE_FUSED_TILED,
        )

    @property
    def pallas_calls(self) -> int:
        """Kernel launches one forward of this plan performs."""
        if self.is_fused_route:
            return 1
        return sum(1 for lp in self.layers if lp.path != "xla-dense")

    @property
    def compile_count(self) -> int:
        """Times the executable was traced (→ compiled) so far."""
        return self._compiles

    @property
    def layouts(self) -> tuple[str, ...]:
        return tuple(lp.layout for lp in self.layers)

    @property
    def transpose_plans(self) -> tuple[BcsrTransposePlan | None, ...]:
        return tuple(lp.transpose_plan for lp in self.layers)

    def describe(self) -> dict:
        """JSON-ready summary (docs/architecture.md shows one)."""
        return {
            "fingerprint": self.key.fingerprint[:12],
            "width": self.width,
            "differentiable": self.differentiable,
            "route": self.route,
            "layouts": list(self.layouts),
            "paths": [lp.path for lp in self.layers],
            "grid_steps": self.grid_steps,
            "pallas_calls": self.pallas_calls,
            "cached_transposes": sum(
                1 for lp in self.layers if lp.transpose_plan is not None
            ),
            "compiles": self.compile_count,
            "calls": self.calls,
            "tuned": self.key.tuned,
        }

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def forward(self, y0: Array) -> Array:
        """One forward pass of the bound stack over an (m, k) panel,
        k ≤ the plan's width. The panel is padded to the width class so
        every call of this plan reuses ONE compiled executable."""
        m, k = y0.shape
        if k > self.width:
            raise ValueError(
                f"panel width {k} exceeds this plan's width class "
                f"{self.width}; fetch a plan for the wider class"
            )
        if k < self.width:
            y0 = jnp.pad(y0, ((0, 0), (0, self.width - k)))
        self.calls += 1
        if self.is_fused_route:
            out = self._fn(self._stacked[0], self._stacked[1], y0)
        else:
            out = self._fn(self.weights, self.biases, y0)
        return out[:, :k]

    def forward_trainable(
        self,
        weights: Sequence[Weight],
        biases: Sequence[Array],
        y0: Array,
        *,
        use_kernel: bool = True,
        interpret: bool | None = None,
    ) -> Array:
        """Differentiable forward with CALLER-supplied (fresh) values —
        the plan contributes only its frozen-topology artifacts, most
        importantly the cached block-CSR transposes, so a train step
        built on this never re-sorts the topology."""
        if not self.differentiable:
            raise ValueError(
                "forward_trainable needs a differentiable plan; rebuild "
                "with differentiable=True"
            )
        from repro.core import dnn as _dnn

        y = y0
        for lp, w, b in zip(self.layers, weights, biases):
            if use_kernel:
                y = _dnn.dnn_layer_trainable(
                    w, y, b, interpret=interpret,
                    transpose_plan=lp.transpose_plan,
                )
            else:
                y = _dnn.dnn_layer(w, y, b, fused=True)
        return y


def _make_executable(plan: StackPlan) -> Callable:
    """The plan's jitted forward. Weights ride as pytree arguments (not
    closure constants) so value updates never retrace; the trace counter
    increments exactly once per compilation, which is how serving counts
    recompiles per width class."""
    from repro.kernels import ops as kernel_ops
    from repro.sparse import ops as sparse_ops

    # Tuned plans thread their overrides into every kernel call; untuned
    # plans pass nothing so the wrappers run on the hand-picked defaults.
    block_n = _tuned_attr(plan.tuned, "block_n") or DEFAULT_BLOCK_N
    panel_dtype = _tuned_attr(plan.tuned, "panel_dtype")
    fused_kw = {"block_n": block_n, "panel_dtype": panel_dtype}

    if plan.route == _routes.ROUTE_FUSED:

        def run_fused(stacked_w, stacked_b, y):
            plan._compiles += 1
            return kernel_ops.fused_mlp_forward(stacked_w, stacked_b, y, **fused_kw)

        return jax.jit(run_fused)

    if plan.route == _routes.ROUTE_FUSED_TILED:

        def run_fused_tiled(stacked_w, stacked_b, y):
            plan._compiles += 1
            return kernel_ops.fused_mlp_tiled_forward(
                stacked_w, stacked_b, y, **fused_kw
            )

        return jax.jit(run_fused_tiled)

    paths = tuple(lp.path for lp in plan.layers)
    tps = plan.transpose_plans

    def run_layered(weights, biases, y):
        plan._compiles += 1
        for path, tp, w, b in zip(paths, tps, weights, biases):
            if path == "kernel-bcsr":
                y = kernel_ops.bcsr_spmm(
                    w, y, b, tp, fuse_bias_relu=True, block_n=block_n
                )
            elif path == "kernel-ell":
                y = kernel_ops.bsr_spmm(
                    w, y, b, fuse_bias_relu=True, block_n=block_n
                )
            elif path == "kernel-dense":
                y = kernel_ops.semiring_matmul(
                    w, y, b, fuse_bias_relu=True, block_n=block_n
                )
            else:  # xla-dense: grad-compatible fused XLA form
                y = sparse_ops.dense_matmul_fused_relu(w, y, b)
        return y

    return jax.jit(run_layered)


def _tuned_attr(tuned, name: str):
    """Read one knob off a TunedConfig-shaped object (duck-typed so the
    plan layer never imports ``repro.tune``); None when untuned."""
    return None if tuned is None else getattr(tuned, name, None)


def _reblock(w: Weight, block_size: int) -> Weight:
    """Re-block a sparse execution weight through its dense form (host-
    side, plan-build-time only). Keeps the execution layout family."""
    if isinstance(w, BlockCSRMatrix):
        return BlockCSRMatrix.from_dense(
            np.asarray(jax.device_get(w.to_dense())), (block_size, block_size)
        )
    if isinstance(w, BlockSparseMatrix):
        return BlockSparseMatrix.from_dense(
            np.asarray(jax.device_get(w.to_dense())), (block_size, block_size)
        )
    return w


def _force_layout(w: Weight, layout: str) -> Weight:
    """Tuner override of the ELL-waste heuristic: force the execution
    layout of a sparse weight (identity for dense weights)."""
    if layout == "bcsr" and isinstance(w, BlockSparseMatrix):
        return BlockCSRMatrix.from_bsr(w)
    if layout == "ell" and isinstance(w, BlockCSRMatrix):
        return w.to_bsr()
    return w


def build_plan(
    weights: Sequence[Weight],
    biases: Sequence[Array],
    width: int,
    *,
    differentiable: bool = False,
    use_resident: bool | None = None,
    relayout: bool | None = None,
    fingerprint: str | None = None,
    donor: "StackPlan | None" = None,
    tuned=None,
) -> StackPlan:
    """Compile one :class:`StackPlan` (all the per-topology analysis).

    ``use_resident``: None auto-detects fused eligibility, True demands
    it (ValueError when ineligible), False forces the layered route —
    the ``SparseDNNEngine`` tri-state, verbatim. ``relayout`` applies
    the ELL→CSR waste heuristic to the bound execution weights; default
    on for inference plans, always off for differentiable plans (their
    cotangents must mirror the caller's layout).

    ``donor``: an existing plan for the SAME stack (same fingerprint,
    differentiability, and residency request) at a different width
    class. Only the width-dependent pieces (grid-step bill, executable)
    are rebuilt; the width-independent topology artifacts — relayouted
    execution weights, cached transposes (so the topology is still
    sorted exactly once no matter how many width classes serve it), and
    the fused weight stack — are shared by reference.
    ``PlanCache.get`` supplies this automatically.

    ``tuned``: a :class:`repro.tune.TunedConfig` (duck-typed — the plan
    layer only reads its fields) consulted BEFORE the hand-picked
    defaults: ``block_n`` feeds every kernel call and the grid bill,
    ``panel_dtype``/``vmem_limit_bytes`` move the resident↔tiled
    boundary, ``layout`` overrides the ELL-waste heuristic, and
    ``block_size`` re-blocks layered execution weights. The config's
    token lands in :attr:`PlanKey.tuned` so tuned and untuned plans
    never collide in a :class:`~repro.plan.PlanCache`.
    """
    weights = tuple(weights)
    biases = tuple(biases)
    if len(weights) != len(biases):
        raise ValueError("weights/biases length mismatch")
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    if fingerprint is None:
        fingerprint = topology_fingerprint(weights)

    tuned_token = None if tuned is None else tuned.token()
    t_block_n = _tuned_attr(tuned, "block_n") or DEFAULT_BLOCK_N
    t_panel = _tuned_attr(tuned, "panel_dtype")
    t_vmem = _tuned_attr(tuned, "vmem_limit_bytes")
    t_layout = _tuned_attr(tuned, "layout")
    t_block_size = _tuned_attr(tuned, "block_size")

    # fused_ok: which single-pallas_call route structurally fits —
    # ROUTE_FUSED (panel resident in VMEM), ROUTE_FUSED_TILED (panel
    # past the VMEM budget, ping-ponged through HBM scratch), or None.
    fused_ok = (
        None
        if differentiable
        else _routes.fused_route(
            weights,
            block_n=t_block_n,
            panel_dtype=t_panel,
            vmem_limit=t_vmem,
        )
    )
    if use_resident and fused_ok is None:
        raise ValueError(
            "use_resident=True but the stack is not eligible for the "
            "fused whole-stack kernels"
            + (
                " (differentiable plans route around their missing VJP)"
                if differentiable
                else " (needs a homogeneous square BSR stack)"
            )
        )
    if use_resident is None or use_resident:
        route = fused_ok or _routes.ROUTE_LAYERED
    else:
        route = _routes.ROUTE_LAYERED

    if relayout is None:
        relayout = not differentiable
    if differentiable and relayout:
        raise ValueError(
            "relayout converts bound weights; a differentiable plan "
            "must keep the caller's layouts so cotangents line up"
        )

    if donor is not None:
        if (
            donor.key.fingerprint != fingerprint
            or donor.differentiable != differentiable
            or donor.key.resident != use_resident
            or donor.key.tuned != tuned_token
            or donor.n_layers != len(weights)
        ):
            raise ValueError(
                "donor plan does not match this stack's plan key "
                "(fingerprint / differentiable / residency / tuned / layers)"
            )
        route = donor.route
        exec_weights = list(donor.weights)
        layer_plans = [
            dataclasses.replace(
                lp,
                grid_steps=_cost.layer_grid_steps(
                    ew, width, block_n=t_block_n
                ),
            )
            for lp, ew in zip(donor.layers, exec_weights)
        ]
    else:
        fused_family = route in (
            _routes.ROUTE_FUSED,
            _routes.ROUTE_FUSED_TILED,
        )
        exec_weights = []
        layer_plans = []
        for i, w in enumerate(weights):
            src_layout = _layout.layer_layout(w)
            ew = w
            if not fused_family and relayout:
                if t_layout is not None:
                    ew = _force_layout(w, t_layout)
                else:
                    ew = _layout.to_preferred_layout(w)
                if t_block_size is not None:
                    bs = getattr(ew, "block_shape", (t_block_size,))[0]
                    if bs != t_block_size:
                        ew = _reblock(ew, t_block_size)
            exec_layout = _layout.layer_layout(ew)
            path = (
                route
                if fused_family
                else _routes.layer_path(ew, differentiable=differentiable)
            )
            tp = None
            if differentiable and isinstance(ew, BlockCSRMatrix):
                # The one and only topology sort for this layer: every
                # backward of every step — at every width class, via
                # donor sharing — reuses this plan's permutation.
                tp = ew.transpose_plan()
            exec_weights.append(ew)
            layer_plans.append(
                LayerPlan(
                    index=i,
                    source_layout=src_layout,
                    layout=exec_layout,
                    path=path,
                    grid_steps=_cost.layer_grid_steps(
                        ew, width, block_n=t_block_n
                    ),
                    transpose_plan=tp,
                )
            )
        if route == _routes.ROUTE_LAYERED and all(
            lp.path == "xla-dense" for lp in layer_plans
        ):
            route = _routes.ROUTE_XLA

    plan = StackPlan(
        key=PlanKey(
            fingerprint, width, differentiable, use_resident, tuned=tuned_token
        ),
        route=route,
        layers=tuple(layer_plans),
        width=width,
        differentiable=differentiable,
        grid_steps=sum(lp.grid_steps for lp in layer_plans),
        weights=tuple(exec_weights),
        biases=biases,
        source_weights=weights,
        source_biases=biases,
        tuned=tuned,
    )
    if plan.is_fused_route:
        if donor is not None:
            plan._stacked = donor._stacked  # one device copy per topology
        else:
            from repro.core import dnn as _dnn

            plan._stacked = (
                _dnn.stack_bsr(list(exec_weights)),
                jnp.stack(list(biases)),
            )
    plan._fn = _make_executable(plan)
    return plan
