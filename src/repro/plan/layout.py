"""Per-layer layout choice — the ELL-pad waste heuristic.

The ELL grid runs ``nrb × max_blocks_per_row`` steps per column tile
(the pad is paid on every block-row); the occupancy-exact CSR grid runs
``total_nnz_blocks``. This module owns the choice rule — lifted out of
``repro.core.dnn`` so every execution path (plans, serving, training,
the legacy wrappers) consults ONE heuristic instead of re-deriving it
per call. ``repro.core.dnn.preferred_layout`` remains as a
backward-compatible alias.
"""

from __future__ import annotations

from typing import Union

import jax

from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Weight = Union[jax.Array, BlockSparseMatrix, BlockCSRMatrix]

# A block-row whose ELL pad wastes more than this fraction of its slots
# (1 - nnz / (nrb·mbpr)) is better served by the occupancy-exact grid.
ELL_WASTE_THRESHOLD = 0.25


def layer_layout(w: Weight) -> str:
    """The storage layout of a weight: ``"dense"``, ``"ell"``, ``"bcsr"``."""
    if isinstance(w, BlockCSRMatrix):
        return "bcsr"
    if isinstance(w, BlockSparseMatrix):
        return "ell"
    return "dense"


def preferred_layout(w: BlockSparseMatrix) -> str:
    """``"ell"`` or ``"bcsr"`` — which kernel grid wastes less work.

    Choose CSR once the pad's wasted fraction crosses
    :data:`ELL_WASTE_THRESHOLD` (host-side: reads the mask).
    """
    nrb, mbpr = w.col_idx.shape
    # numpy, not w.nnz_blocks: a jnp reduction would turn into a tracer
    # inside a trace context even on a concrete (closed-over) mask,
    # and plan builds may happen while tracing (graphblas.mxm routing).
    import numpy as np

    nnz = int(np.asarray(jax.device_get(w.block_mask)).sum())
    waste = 1.0 - nnz / float(nrb * mbpr)
    return "bcsr" if waste > ELL_WASTE_THRESHOLD else "ell"


def to_preferred_layout(w: Weight) -> Weight:
    """Re-layout an ELL weight to block-CSR when its occupancy is skewed
    enough for the occupancy-exact grid to win (host-side; identity for
    dense and already-CSR weights)."""
    if isinstance(w, BlockSparseMatrix) and preferred_layout(w) == "bcsr":
        return BlockCSRMatrix.from_bsr(w)
    return w
