"""Test/robustness harnesses that ship with the library.

``repro.testing.faults`` is the deterministic fault-injection layer
(docs/robustness.md): the serving engine, the continuous batcher, and
the resilient train loop each poll it at named sites, so tests and the
benchmark's ``faults`` arm can script exact failure sequences.
"""

from repro.testing.faults import (  # noqa: F401
    ALL_SITES,
    SITE_CACHE_EVICTION,
    SITE_PANEL_NANS,
    SITE_PLAN_COMPILE,
    SITE_SHARD_FAILURE,
    SITE_STEP_TRANSIENT,
    SITE_STRAGGLER,
    SITE_TRAIN_NAN_LOSS,
    FaultEvent,
    FaultInjector,
    InjectedFault,
    TransientFault,
    poison_panel,
)

__all__ = [
    "ALL_SITES",
    "SITE_CACHE_EVICTION",
    "SITE_PANEL_NANS",
    "SITE_PLAN_COMPILE",
    "SITE_SHARD_FAILURE",
    "SITE_STEP_TRANSIENT",
    "SITE_STRAGGLER",
    "SITE_TRAIN_NAN_LOSS",
    "FaultEvent",
    "FaultInjector",
    "InjectedFault",
    "TransientFault",
    "poison_panel",
]
