"""Deterministic fault injection for the serving/training stack.

Production-scale sparse-DNN serving (the ROADMAP's north star) fails in
a handful of recurring ways: a request column goes non-finite and
poisons its packed panel, a mesh shard dies mid-stream, a plan compile
blows the VMEM guard, a cache eviction storm forces recompiles, a node
straggles. This module makes every one of those *scriptable*: faults
are **scheduled**, never sampled — a :class:`FaultInjector` holds a map
``(site, when) → payload`` armed by tests/benchmarks, and each
subsystem polls :meth:`FaultInjector.fires` at its named injection site
with its own monotonic counter:

=========================  ============================================
site                       ``when`` counter (owner)
=========================  ============================================
``SITE_PANEL_NANS``        engine dispatch ordinal (``SparseDNNEngine``)
``SITE_STEP_TRANSIENT``    engine dispatch ordinal
``SITE_PLAN_COMPILE``      engine dispatch ordinal
``SITE_CACHE_EVICTION``    engine dispatch ordinal
``SITE_SHARD_FAILURE``     engine dispatch ordinal
``SITE_STRAGGLER``         scheduler tick (``ContinuousBatcher``)
``SITE_TRAIN_NAN_LOSS``    train step (``train.resilience``)
``SITE_REPLICA_LOSS``      fleet dispatch ordinal (``serve.frontend``)
``SITE_REPLICA_SLOW``      fleet dispatch ordinal (``serve.frontend``)
=========================  ============================================

The two fleet sites cover the replicated serving layer: REPLICA_LOSS
(payload ``replica=k``) kills replica k right before the Nth fleet
dispatch — its queued and in-flight requests must be re-routed, never
dropped; REPLICA_SLOW (payload ``factor=x``) inflates the service time
of the Nth dispatch (a degraded node), which must show up as latency,
not as a stuck event loop.

A fired fault is consumed (popped) and logged in :attr:`FaultInjector.
fired`, so one ``schedule`` call produces exactly one fault — same
schedule + same trace → the same faulted run, bit for bit. Randomness
(e.g. which panel columns to poison) comes from the injector's own
seeded generator, never global state. See docs/robustness.md for the
full fault model and how each subsystem degrades.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

SITE_PANEL_NANS = "panel-nans"
SITE_STEP_TRANSIENT = "step-transient"
SITE_PLAN_COMPILE = "plan-compile"
SITE_CACHE_EVICTION = "cache-eviction"
SITE_SHARD_FAILURE = "shard-failure"
SITE_STRAGGLER = "straggler"
SITE_TRAIN_NAN_LOSS = "train-nan-loss"
SITE_REPLICA_LOSS = "replica-loss"
SITE_REPLICA_SLOW = "replica-slow"

ALL_SITES = (
    SITE_PANEL_NANS,
    SITE_STEP_TRANSIENT,
    SITE_PLAN_COMPILE,
    SITE_CACHE_EVICTION,
    SITE_SHARD_FAILURE,
    SITE_STRAGGLER,
    SITE_TRAIN_NAN_LOSS,
    SITE_REPLICA_LOSS,
    SITE_REPLICA_SLOW,
)


class InjectedFault(RuntimeError):
    """A scripted failure fired by the injector (not retryable)."""


class TransientFault(InjectedFault):
    """A scripted failure the engine is allowed to retry."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One consumed fault — the injector's audit log entry."""

    site: str
    when: int
    payload: dict


class FaultInjector:
    """Seeded, scheduled fault source shared across subsystems.

    ``schedule(site, when, **payload)`` arms one fault; the owning
    subsystem's ``fires(site, when)`` pops and returns the payload (or
    None). Multiple faults may be armed at the same (site, when); they
    pop in schedule order, one per ``fires`` call.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._scheduled: dict[tuple[str, int], list[dict]] = {}
        self.fired: list[FaultEvent] = []

    def schedule(self, site: str, when: int, **payload) -> None:
        if site not in ALL_SITES:
            raise ValueError(f"unknown fault site {site!r}; one of {ALL_SITES}")
        if when < 0:
            raise ValueError(f"when must be >= 0, got {when}")
        self._scheduled.setdefault((site, int(when)), []).append(dict(payload))

    def fires(self, site: str, when: int) -> dict | None:
        """Pop-and-log the next fault armed at (site, when), if any."""
        queue = self._scheduled.get((site, int(when)))
        if not queue:
            return None
        payload = queue.pop(0)
        if not queue:
            del self._scheduled[(site, int(when))]
        self.fired.append(FaultEvent(site, int(when), dict(payload)))
        return payload

    def pending(self, site: str | None = None) -> int:
        """Armed-but-unfired fault count (optionally one site's)."""
        return sum(
            len(q)
            for (s, _), q in self._scheduled.items()
            if site is None or s == site
        )

    def fired_at(self, site: str) -> list[FaultEvent]:
        return [e for e in self.fired if e.site == site]


def poison_panel(
    panel,
    *,
    columns=None,
    count: int = 1,
    mode: str = "nan",
    limit: int | None = None,
    rng=None,
):
    """Inject non-finite values into whole columns of an (m, k) panel.

    Returns ``(poisoned_panel, columns)``. Columns are poisoned whole
    because the serving panel is column-batched (one request per
    column) — a poisoned request corrupts exactly its own column, which
    is what the engine's per-request quarantine relies on. ``limit``
    restricts the choice to the first ``limit`` columns (the real,
    non-pad requests). ``mode``: ``"nan"`` (propagates unconditionally
    through the ReLU stack) or ``"inf"``.
    """
    if mode not in ("nan", "inf"):
        raise ValueError(f"mode must be 'nan' or 'inf', got {mode!r}")
    k = panel.shape[1]
    hi = k if limit is None else min(int(limit), k)
    if columns is None:
        if hi < 1:
            return panel, ()
        rng = np.random.default_rng(0) if rng is None else rng
        count = min(int(count), hi)
        columns = sorted(int(c) for c in rng.choice(hi, size=count, replace=False))
    else:
        columns = sorted(int(c) for c in columns)
        bad = [c for c in columns if not 0 <= c < hi]
        if bad:
            raise ValueError(f"columns {bad} out of range [0, {hi})")
    if not columns:
        return panel, ()
    value = float("nan") if mode == "nan" else float("inf")
    panel = jnp.asarray(panel).at[:, jnp.asarray(columns)].set(value)
    return panel, tuple(columns)
