"""Distributed-optimization collectives (DESIGN.md §5/§6).

``compressed_psum_mean`` — int8 error-feedback gradient all-reduce for the
cross-pod DP axis: each participant transmits an int8 quantized gradient
plus one fp32 scale; quantization error is carried locally and re-added
next step (error feedback keeps SGD/Adam convergence — 1-bit Adam /
PowerSGD lineage). On real hardware this moves 4× fewer bytes over the
pod-to-pod DCI; here the semantics are emulated inside shard_map with an
int32 ``psum`` of the int8 payload (noted in EXPERIMENTS.md — the traffic
claim is structural, the *numerics* are exact to the deployed scheme).

``bucketed`` — flatten a gradient pytree into fixed-size buckets so the
all-reduce launches overlap with the backward pass instead of waiting for
the full gradient (the classic DDP bucketing trick; under XLA this also
keeps each collective's payload in the latency-optimal range).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


# ----------------------- int8 error-feedback psum ----------------------------


def quantize_int8(x: Array, axis_name: str) -> tuple[Array, Array]:
    """Symmetric int8 quantization with a *shared* (pmax'd) scale so the
    reduced sum can be reconstructed without exchanging per-peer scales."""
    amax = jnp.max(jnp.abs(x))
    amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(
    x: Array, axis_name: str, err: Array
) -> tuple[Array, Array]:
    """Mean-reduce ``x`` over ``axis_name`` transmitting int8 payloads.

    Returns (mean, new_error). Call inside ``shard_map``.
    """
    # jax.lax.axis_size only exists in newer jax; psum of 1 is the
    # portable spelling (constant-folded by the partitioner, no wire cost).
    n = jax.lax.psum(1, axis_name)
    xe = x + err
    q, scale = quantize_int8(xe, axis_name)
    dequant_local = q.astype(x.dtype) * scale
    new_err = xe - dequant_local
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = total.astype(x.dtype) * (scale / n)
    return mean, new_err


def compressed_psum_mean_tree(
    grads: Any, axis_name: str, err_tree: Any
) -> tuple[Any, Any]:
    """Tree version; error state mirrors the gradient pytree."""
    flat, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err_tree)
    out, new_errs = [], []
    for g, e in zip(flat, errs):
        m, ne = compressed_psum_mean(g, axis_name, e)
        out.append(m)
        new_errs.append(ne)
    return treedef.unflatten(out), treedef.unflatten(new_errs)


def init_error_state(grads_shape: Any) -> Any:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype)
        if hasattr(s, "shape")
        else jnp.zeros_like(s),
        grads_shape,
    )


# ------------------------------ bucketing ------------------------------------


def bucketed(tree: Any, bucket_bytes: int = 32 * 1024 * 1024) -> list[list]:
    """Group pytree leaves into ≤bucket_bytes groups (reduction launch
    granularity). Returns a list of lists of (path, leaf)."""
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    buckets, cur, cur_bytes = [], [], 0
    for path, leaf in leaves:
        nbytes = leaf.size * leaf.dtype.itemsize
        if cur and cur_bytes + nbytes > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append((path, leaf))
        cur_bytes += nbytes
    if cur:
        buckets.append(cur)
    return buckets
