"""Sharding rules: param/activation/cache PartitionSpecs over the
production mesh (DESIGN.md §5).

Strategy (baseline): FSDP over the "data" axis × Megatron-style TP/EP over
the "model" axis; "pod" is an outer pure-DP axis (batch + gradient
reduction only — ICI-heavy collectives never cross it). Every rule is a
*preference*: the resolver drops any axis whose size does not divide the
corresponding dim (e.g. 8 KV heads on a 16-way model axis), so one rule
table covers all ten architectures.

The rule table is keyed by (context, leaf-name) where context is the
nearest enclosing component ("mixer" / "ffn" / "shared" / top-level) —
that disambiguates e.g. GQA's 3-D ``w_k`` from RWKV channel-mix's 2-D
``w_k``. Logical axes are then mapped onto mesh axes through
:class:`ShardingRules`, the hillclimbing surface for §Perf.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Params = dict[str, Any]

# ---------------------- activation-constraint context ------------------------
# Model code calls ``constrain(x, logical_axes)`` at a few key points
# (tied-head weight, MoE dispatch, sequence sharding). Outside an
# ``activate(mesh, rules)`` scope it is a no-op, so plain CPU tests and
# examples never touch mesh machinery.

_ACTIVE: list[tuple[Mesh, "ShardingRules"]] = []


@contextlib.contextmanager
def activate(mesh: Mesh, rules: "ShardingRules | None" = None):
    _ACTIVE.append((mesh, rules or ShardingRules()))
    try:
        yield
    finally:
        _ACTIVE.pop()


def constrain(x, logical: tuple):
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = _resolve_spec(tuple(logical), x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def active_rules() -> "ShardingRules | None":
    return _ACTIVE[-1][1] if _ACTIVE else None


def embed_lookup(table, ids):
    """Distributed embedding lookup: masked local take + psum.

    GSPMD's gather partitioning hits an XLA verifier bug for several of
    the assigned archs (dynamic-slice of the sharded table's full dim —
    see EXPERIMENTS.md §Dry-run), and its backward materializes a
    full-size dW scatter buffer. This shard_map formulation is the
    standard Megatron vocab-parallel embedding: each vocab shard looks up
    the ids it owns, zeros the rest, and one small psum over the vocab
    axis assembles the row. Backward is a local scatter-add (dW stays
    sharded). Outside activate() (CPU tests), falls back to table[ids].
    """
    if not _ACTIVE:
        return table[ids]
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map

    mesh, rules = _ACTIVE[-1]
    tbl_spec = tuple(_resolve_spec(_TOP["embed"], table.shape, mesh, rules))
    tbl_spec += (None,) * (2 - len(tbl_spec))
    ids_spec = tuple(_resolve_spec(("batch", "seq"), ids.shape, mesh, rules))
    ids_spec += (None,) * (ids.ndim - len(ids_spec))
    vocab_axes, d_axes = tbl_spec
    # ids must be REPLICATED over the vocab axes (the psum below sums
    # vocab shards of the SAME id set — a batch axis shared with the
    # vocab axis would sum different batch shards' rows), and must not
    # collide with the output's d sharding either.
    v_ax = set(
        vocab_axes if isinstance(vocab_axes, tuple) else (vocab_axes,)
    ) - {None}
    forbidden = v_ax | ({d_axes} - {None})
    def _strip(s):
        if s is None:
            return None
        parts = tuple(a for a in (s if isinstance(s, tuple) else (s,))
                      if a not in forbidden)
        return parts if len(parts) > 1 else (parts[0] if parts else None)
    ids_spec = tuple(_strip(s) for s in ids_spec)
    if vocab_axes is None:
        # table not vocab-sharded → plain gather partitions fine
        out = table[ids]
        return jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, P(*(ids_spec + (d_axes,))))
        )

    axes = vocab_axes if isinstance(vocab_axes, tuple) else (vocab_axes,)

    def local(tbl, ids_local):
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        vloc = tbl.shape[0]
        lo = idx * vloc
        loc = ids_local - lo
        ok = (loc >= 0) & (loc < vloc)
        rows = jnp.take(tbl, jnp.clip(loc, 0, vloc - 1), axis=0)
        rows = jnp.where(ok[..., None], rows, jnp.zeros((), rows.dtype))
        return jax.lax.psum(rows, axes)

    out = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(*( (vocab_axes, tbl_spec[1]) )), P(*ids_spec)),
        out_specs=P(*(ids_spec + (tbl_spec[1],))),
        check_rep=False,
    )(table, ids)
    # re-shard the rows onto the batch axes for the downstream layers
    final = tuple(_resolve_spec(("batch", "seq"), ids.shape, mesh, rules))
    final += (None,) * (ids.ndim - len(final))
    final = tuple(_strip(s) if s and (set(
        s if isinstance(s, tuple) else (s,)) & ({d_axes} - {None})) else s
        for s in final)
    return jax.lax.with_sharding_constraint(
        out, NamedSharding(mesh, P(*(final + (d_axes,))))
    )


def constrain_like_params(tree):
    """Pin a param-shaped pytree (e.g. gradients) to the parameter
    sharding rules. Without this, GSPMD materializes full-size f32
    gradient accumulators for scatter-producing backward ops (embedding
    tables: ~2 GiB each at 102k×5120) before sharding them; with it, the
    dW reduce-scatter happens at production. No-op outside activate()."""
    if not _ACTIVE:
        return tree
    mesh, rules = _ACTIVE[-1]
    specs = param_pspecs(None, tree, mesh, rules)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
        if jax.numpy.issubdtype(x.dtype, jax.numpy.inexact)
        else x,
        tree,
        specs,
    )

# ------------------------------ rules ----------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical→mesh axis assignment. Fields are hillclimb levers."""

    batch_axes: tuple[str, ...] = ("pod", "data")  # batch dim of activations
    fsdp_axis: str | None = "data"  # weight "replicated-ish" dims
    tp_axis: str | None = "model"  # heads / mlp / experts / vocab
    shard_vocab: bool = True  # embed+lm_head over tp_axis
    cache_seq_axis: str | None = "model"  # decode KV/latent cache seq dim
    seq_axis: str | None = None  # sequence parallelism (prefill/train)

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch_axes
        if logical == "fsdp":
            return self.fsdp_axis
        if logical == "tp":
            return self.tp_axis
        if logical == "vocab":
            return self.tp_axis if self.shard_vocab else None
        if logical == "cache_seq":
            return self.cache_seq_axis
        if logical == "seq":
            return self.seq_axis
        if logical == "row_blocks":
            # Sparse-weight shard dim (ShardedBlockCSR leading axis / BSR
            # row-block dim): a dedicated "row_blocks" mesh axis when the
            # mesh has one (launch.mesh.make_row_blocks_mesh), else fully
            # sharded over every compute axis. The resolver drops names
            # absent from the mesh, so one rule covers both mesh styles.
            axes = ("row_blocks",) + tuple(
                a for a in (self.fsdp_axis, self.tp_axis) if a
            )
            return axes or None
        raise ValueError(f"unknown logical axis {logical!r}")


# (context, name) -> tuple of logical axes per dim. "fsdp" ~ d_model-like
# dims (sharded for FSDP storage), "tp" ~ heads/mlp/expert dims.
_MIXER = {
    # GQA
    "w_q": ("fsdp", "tp", None),
    "w_k": ("fsdp", "tp", None),
    "w_v": ("fsdp", "tp", None),
    "w_o": ("tp", None, "fsdp"),
    "b_q": ("tp", None),
    "b_k": ("tp", None),
    "b_v": ("tp", None),
    # MLA
    "w_dq": ("fsdp", None),
    "w_uq": (None, "tp", None),
    "w_dkv": ("fsdp", None),
    "w_uk": (None, "tp", None),
    "w_uv": (None, "tp", None),
    # Mamba (di = expand·d_model is the "tp" dim)
    "in_proj": ("fsdp", "tp"),
    "conv_w": (None, "tp"),
    "conv_b": ("tp",),
    "x_proj": ("tp", None),
    "dt_proj": (None, "tp"),
    "dt_bias": ("tp",),
    "A_log": ("tp", None),
    "D": ("tp",),
    "out_proj": ("tp", "fsdp"),
    # RWKV-6 time mix (square d→d projections: column-parallel in, row-
    # parallel out; small LoRA/mix tensors stay replicated)
    "w_r": ("fsdp", "tp"),
    "w_g": ("fsdp", "tp"),
    "mix_w1": ("fsdp", None),
    "mix_w2": (None, None, "fsdp"),
    "decay_w1": ("fsdp", None),
    "decay_w2": (None, "fsdp"),
    "mu_x": (None,),
    "mu": (None, None),
    "w0": (None,),
    "bonus_u": (None, None),
}
_FFN = {
    # dense FFN / GLU
    "w_in": ("fsdp", "tp"),
    "w_gate": ("fsdp", "tp"),
    "w_out": ("tp", "fsdp"),
    # MoE expert banks (leading experts dim = EP over tp_axis)
    "router": ("fsdp", None),
    # RWKV channel mix
    "w_k": ("fsdp", "tp"),
    "w_v": ("tp", "fsdp"),
    "w_r": ("fsdp", None),
    "mu_k": (None,),
    "mu_r": (None,),
    # the paper's MLP layer (square m×m weight, x @ W input-major)
    "w": ("fsdp", "tp"),
    "b": ("tp",),
}
_MOE_BANK = {  # 3-D expert banks, disambiguated by ndim
    # EP over the model axis (e) × Megatron column/row split of the
    # expert FF dim over the data axis. FSDP-style d_model sharding of
    # expert banks is deliberately avoided: it turns every expert matmul
    # into a partial-sum all-reduce of (tokens×d_ff) activations, which
    # dwarfs the f-shard weight halves (measured: §Perf deepseek cell).
    "w_in": ("tp", None, "fsdp"),
    "w_gate": ("tp", None, "fsdp"),
    "w_out": ("tp", "fsdp", None),
}
_TOP = {
    # embed is 2-D sharded for storage (vocab over data, d over tp); the
    # lookup gathers from the d-shard (vocab side resolved by GSPMD via
    # masked local lookup + reduce). The tied-head matmul reshards it on
    # the fly — see Model._head + constrain().
    "embed": ("fsdp", "tp"),
    "lm_head": ("fsdp", "vocab"),
}
# BSR weight leaves (output-major: row blocks = output dim → tp)
_BSR = {
    "blocks": ("tp", None, None, None),
    "col_idx": ("tp", None),
    "block_mask": ("tp", None),
}
# ShardedBlockCSR leaves (repro.sparse.partition): every leaf carries a
# leading shard axis, sharded over the "row_blocks" logical axis; all
# trailing dims stay local to the shard. Order mirrors
# repro.sparse.partition.SHARDED_CSR_LEAVES.
_SHARDED_CSR = {
    "values": ("row_blocks", None, None, None),
    "row_ptr": ("row_blocks", None),
    "row_id": ("row_blocks", None),
    "col_idx": ("row_blocks", None),
    "valid": ("row_blocks", None),
    "gather_index": ("row_blocks", None),
}


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            out.append(k.name)
        else:
            out.append(str(k))
    return out


def _logical_axes(names: list[str], ndim: int) -> tuple:
    leaf = names[-1]
    in_period = "period" in names
    eff_ndim = ndim - 1 if in_period else ndim  # stacked leading layer dim

    if leaf in _BSR:
        spec = _BSR[leaf]
    elif "mixer" in names and leaf in _MIXER:
        spec = _MIXER[leaf]
    elif ("ffn" in names or "shared" in names) and leaf in _FFN:
        spec = _MOE_BANK[leaf] if (leaf in _MOE_BANK and eff_ndim == 3) else _FFN[leaf]
    elif leaf in _TOP:
        spec = _TOP[leaf]
    else:
        spec = (None,) * eff_ndim  # norms, scalars, unknowns → replicated
    if len(spec) != eff_ndim:
        spec = (None,) * eff_ndim  # rank mismatch (e.g. biases) → replicate
    if in_period:
        spec = (None,) + tuple(spec)
    return tuple(spec)


def _resolve_spec(
    logical: tuple, shape: tuple[int, ...], mesh: Mesh, rules: ShardingRules
) -> P:
    axes = []
    used: set[str] = set()
    for dim, lg in enumerate(logical):
        assignment = rules.resolve(lg)
        if assignment is None:
            axes.append(None)
            continue
        names = assignment if isinstance(assignment, tuple) else (assignment,)
        names = tuple(
            a for a in names if a in mesh.shape and a not in used
        )
        size = 1
        for a in names:
            size *= mesh.shape[a]
        if not names or shape[dim] % size != 0:
            axes.append(None)  # divisibility fallback → replicate this dim
            continue
        used.update(names)
        axes.append(names if len(names) > 1 else names[0])
    while axes and axes[-1] is None:
        axes.pop()
    return P(*axes)


# ------------------------------ public API -----------------------------------


def param_pspecs(
    cfg: ModelConfig, params: Params, mesh: Mesh, rules: ShardingRules | None = None
) -> Params:
    """PartitionSpec tree matching ``params`` (arrays or ShapeDtypeStructs)."""
    del cfg
    rules = rules or ShardingRules()

    def one(path, leaf):
        names = _path_names(path)
        return _resolve_spec(_logical_axes(names, leaf.ndim), leaf.shape, mesh, rules)

    return jax.tree_util.tree_map_with_path(one, params)


# cache leaf table: name -> logical axes (dims after the leading batch dim)
_CACHE = {
    "k": ("batch", "cache_seq", None, None),
    "v": ("batch", "cache_seq", None, None),
    "c_kv": ("batch", "cache_seq", None),
    "k_rope": ("batch", "cache_seq", None),
    "positions": (None,),
    "conv": ("batch", None, "tp"),
    "ssm": ("batch", "tp", None),
    "wkv": ("batch", "tp", None, None),
    "shift": ("batch", None),
}


def cache_pspecs(
    cfg: ModelConfig, cache: Params, mesh: Mesh, rules: ShardingRules | None = None
) -> Params:
    del cfg
    rules = rules or ShardingRules()

    def one(path, leaf):
        names = _path_names(path)
        in_period = "period" in names
        leaf_name = names[-1]
        logical = _CACHE.get(leaf_name, ("batch",) + (None,) * (leaf.ndim - 1))
        eff = leaf.ndim - 1 if in_period else leaf.ndim
        if len(logical) != eff:
            logical = (None,) * eff
        if in_period:
            logical = (None,) + tuple(logical)
        return _resolve_spec(tuple(logical), leaf.shape, mesh, rules)

    return jax.tree_util.tree_map_with_path(one, cache)


def batch_pspecs(
    mesh: Mesh, rules: ShardingRules | None = None
) -> dict[str, P]:
    """Specs for a train/serve data batch: batch dim over DP axes, optional
    sequence sharding of the token dim."""
    rules = rules or ShardingRules()
    b = tuple(a for a in rules.batch_axes if a in mesh.shape)
    s = rules.resolve("seq")
    s = s if (s is None or s in mesh.shape) else None
    return {
        "inputs": P(b, s),
        "labels": P(b, s),
    }


def shardings_for(tree, mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ----------------------- sharded sparse weights ------------------------------


def row_block_axes(
    mesh: Mesh, rules: "ShardingRules | None" = None
) -> tuple[str, ...]:
    """Mesh axes the ``row_blocks`` logical axis lands on, in order —
    ``("row_blocks",)`` for a dedicated shard mesh, ``("data", "model")``
    style for compute meshes, ``()`` when nothing matches (unsharded)."""
    rules = rules or ShardingRules()
    assignment = rules.resolve("row_blocks") or ()
    names = assignment if isinstance(assignment, tuple) else (assignment,)
    return tuple(a for a in names if a in mesh.shape)


def mesh_shard_count(mesh: Mesh, rules: "ShardingRules | None" = None) -> int:
    """How many row-block shards this mesh carries (Π of the resolved
    ``row_blocks`` axes' sizes) — the ``n_shards`` the partitioner and
    the sharded plans must agree on."""
    n = 1
    for a in row_block_axes(mesh, rules):
        n *= mesh.shape[a]
    return n


def sharded_csr_pspecs(sharded, mesh: Mesh, rules: "ShardingRules | None" = None):
    """PartitionSpec pytree for one :class:`repro.sparse.partition.
    ShardedBlockCSR`, resolved through the same rule table as every
    other leaf (divisibility fallback included): the leading shard dim
    lands on the ``row_blocks`` axes, everything else is replicated.
    Used directly as ``shard_map`` in_specs by ``repro.plan.sharded``.
    """
    from repro.sparse.partition import SHARDED_CSR_LEAVES

    rules = rules or ShardingRules()
    leaves, treedef = jax.tree_util.tree_flatten(sharded)
    specs = [
        _resolve_spec(_SHARDED_CSR[name], leaf.shape, mesh, rules)
        for name, leaf in zip(SHARDED_CSR_LEAVES, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)
