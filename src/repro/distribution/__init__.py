from repro.distribution.sharding import (  # noqa: F401
    ShardingRules,
    batch_pspecs,
    cache_pspecs,
    mesh_shard_count,
    param_pspecs,
    row_block_axes,
    sharded_csr_pspecs,
)
