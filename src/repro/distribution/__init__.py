from repro.distribution.sharding import (  # noqa: F401
    ShardingRules,
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
)
