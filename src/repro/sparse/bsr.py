"""Block-compressed sparse row (BSR) weight matrices, ELL-padded for TPU.

This is the TPU-native adaptation of the paper's CSR weight storage (see
DESIGN.md §2): instead of (col, value) scalar pairs consumed by scalar
FMAs, we store MXU-tile-sized dense blocks addressed by a per-row-block
column-index table. The table is padded to a static ``max_blocks_per_row``
(ELL format) so every shape is static — a hard requirement for jit /
pjit / shard_map and for the Pallas kernel's BlockSpec grid.

Padding discipline: padded slots carry ``col_idx = 0``, ``block = 0`` and
``block_mask = False``. Under the arithmetic semiring the zero block is
self-neutralising; for general semirings consumers must honour
``block_mask`` (``repro.sparse.ops`` does).

The ELL pad prices every block-row at the WORST row's occupancy — fine
for regular topologies, wasteful for skewed/pruned ones. For those, use
the occupancy-exact :mod:`repro.sparse.bcsr` layout; the choice rule
lives in ``repro.core.dnn.preferred_layout``.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockSparseMatrix:
    """ELL-padded BSR matrix of logical shape ``shape``.

    Attributes:
      blocks:     (n_row_blocks, max_blocks_per_row, bs_r, bs_c) values.
      col_idx:    (n_row_blocks, max_blocks_per_row) int32 block-column ids.
      block_mask: (n_row_blocks, max_blocks_per_row) bool validity.
      shape:      logical (m, n) — static.
      block_shape: (bs_r, bs_c) — static.
    """

    blocks: Array
    col_idx: Array
    block_mask: Array
    shape: Tuple[int, int]
    block_shape: Tuple[int, int]

    # --- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        return (self.blocks, self.col_idx, self.block_mask), (
            self.shape,
            self.block_shape,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        blocks, col_idx, block_mask = children
        shape, block_shape = aux
        return cls(blocks, col_idx, block_mask, shape, block_shape)

    # --- derived structure ----------------------------------------------
    @property
    def n_row_blocks(self) -> int:
        return self.shape[0] // self.block_shape[0]

    @property
    def n_col_blocks(self) -> int:
        return self.shape[1] // self.block_shape[1]

    @property
    def max_blocks_per_row(self) -> int:
        return self.col_idx.shape[1]

    @property
    def dtype(self):
        return self.blocks.dtype

    @property
    def nnz_blocks(self) -> Array:
        return jnp.sum(self.block_mask)

    @property
    def block_density(self) -> Array:
        return self.nnz_blocks / (self.n_row_blocks * self.n_col_blocks)

    @property
    def nbytes(self) -> int:
        """Storage actually consumed (values + index + mask)."""
        return int(
            self.blocks.size * self.blocks.dtype.itemsize
            + self.col_idx.size * self.col_idx.dtype.itemsize
            + self.block_mask.size  # bool = 1 byte
        )

    @property
    def dense_nbytes(self) -> int:
        m, n = self.shape
        return int(m * n * self.blocks.dtype.itemsize)

    def astype(self, dtype) -> "BlockSparseMatrix":
        return BlockSparseMatrix(
            self.blocks.astype(dtype),
            self.col_idx,
            self.block_mask,
            self.shape,
            self.block_shape,
        )

    def map_blocks(self, fn) -> "BlockSparseMatrix":
        """Elementwise transform of stored values (keeps topology)."""
        blocks = jnp.where(
            self.block_mask[:, :, None, None], fn(self.blocks), self.blocks
        )
        return BlockSparseMatrix(
            blocks, self.col_idx, self.block_mask, self.shape, self.block_shape
        )

    # --- integrity --------------------------------------------------------
    def validate(self, *, name: str = "") -> "BlockSparseMatrix":
        """Check the ELL layout invariants; raise ValueError with a
        precise message on the first violation, return ``self`` clean.

        Host-side (syncs the index arrays once) — call at trust
        boundaries (checkpoint restore, engine construction), not per
        step. Checked: shape/block divisibility, array-shape agreement,
        per-row masks a contiguous prefix, in-bounds and strictly
        ascending masked column indices, and finite masked values.
        """
        label = name or f"BlockSparseMatrix{self.shape}"
        m, n = self.shape
        bs_r, bs_c = self.block_shape
        if m % bs_r or n % bs_c:
            raise ValueError(
                f"{label}: shape {self.shape} not divisible by block "
                f"{self.block_shape}"
            )
        nrb, ncb = self.n_row_blocks, self.n_col_blocks
        blocks = np.asarray(jax.device_get(self.blocks))
        col_idx = np.asarray(jax.device_get(self.col_idx))
        mask = np.asarray(jax.device_get(self.block_mask)).astype(bool)
        mbpr = col_idx.shape[1] if col_idx.ndim == 2 else -1
        if col_idx.shape != (nrb, mbpr) or mask.shape != (nrb, mbpr):
            raise ValueError(
                f"{label}: col_idx {col_idx.shape} / block_mask "
                f"{mask.shape} must both be ({nrb}, max_blocks_per_row)"
            )
        if blocks.shape != (nrb, mbpr, bs_r, bs_c):
            raise ValueError(
                f"{label}: blocks shape {blocks.shape} != "
                f"({nrb}, {mbpr}, {bs_r}, {bs_c})"
            )
        if mbpr > 1 and np.any(mask[:, 1:] & ~mask[:, :-1]):
            row = int(np.argmax((mask[:, 1:] & ~mask[:, :-1]).any(axis=1)))
            raise ValueError(
                f"{label}: block_mask of block-row {row} is not a "
                "contiguous prefix (a valid slot follows padding)"
            )
        oob = mask & ((col_idx < 0) | (col_idx >= ncb))
        if np.any(oob):
            row = int(np.argmax(oob.any(axis=1)))
            slot = int(np.argmax(oob[row]))
            raise ValueError(
                f"{label}: col_idx[{row}, {slot}] = "
                f"{int(col_idx[row, slot])} out of [0, {ncb})"
            )
        if mbpr > 1:
            # prefix masks ⇒ mask[:, 1:] implies mask[:, :-1]
            unsorted = mask[:, 1:] & (col_idx[:, 1:] <= col_idx[:, :-1])
            if np.any(unsorted):
                row = int(np.argmax(unsorted.any(axis=1)))
                slot = int(np.argmax(unsorted[row]))
                raise ValueError(
                    f"{label}: col_idx not strictly ascending within "
                    f"block-row {row} (slot {slot}: "
                    f"{int(col_idx[row, slot])} -> "
                    f"{int(col_idx[row, slot + 1])})"
                )
        bad = mask & ~np.isfinite(blocks).all(axis=(2, 3))
        if np.any(bad):
            row = int(np.argmax(bad.any(axis=1)))
            slot = int(np.argmax(bad[row]))
            raise ValueError(
                f"{label}: non-finite value in stored block at "
                f"block-row {row}, slot {slot} "
                f"(block-col {int(col_idx[row, slot])})"
            )
        return self

    # --- conversions ------------------------------------------------------
    @classmethod
    def from_dense(
        cls,
        dense: Array,
        block_shape: Tuple[int, int],
        *,
        pad_to: int | None = None,
    ) -> "BlockSparseMatrix":
        """Build from a dense matrix, keeping blocks with any nonzero.

        Host-side (non-jittable): topology discovery needs concrete values.
        ``pad_to`` forces ``max_blocks_per_row`` (for shape-stable sweeps).
        """
        dense = np.asarray(dense)
        m, n = dense.shape
        bs_r, bs_c = block_shape
        if m % bs_r or n % bs_c:
            raise ValueError(
                f"shape {dense.shape} not divisible by block {block_shape}"
            )
        nrb, ncb = m // bs_r, n // bs_c
        tiles = dense.reshape(nrb, bs_r, ncb, bs_c).transpose(0, 2, 1, 3)
        nz = np.any(tiles != 0, axis=(2, 3))  # (nrb, ncb)
        counts = nz.sum(axis=1)
        mbpr = int(pad_to if pad_to is not None else max(int(counts.max()), 1))
        if counts.max() > mbpr:
            raise ValueError(f"pad_to={pad_to} < max row occupancy {counts.max()}")
        blocks = np.zeros((nrb, mbpr, bs_r, bs_c), dense.dtype)
        col_idx = np.zeros((nrb, mbpr), np.int32)
        mask = np.zeros((nrb, mbpr), bool)
        for i in range(nrb):
            cols = np.nonzero(nz[i])[0]
            blocks[i, : len(cols)] = tiles[i, cols]
            col_idx[i, : len(cols)] = cols
            mask[i, : len(cols)] = True
        return cls(
            jnp.asarray(blocks),
            jnp.asarray(col_idx),
            jnp.asarray(mask),
            (m, n),
            (bs_r, bs_c),
        )

    @classmethod
    def random(
        cls,
        key: Array,
        shape: Tuple[int, int],
        block_shape: Tuple[int, int],
        blocks_per_row: int,
        *,
        dtype=jnp.float32,
        minval: float = -1.0,
        maxval: float = 3.0,
    ) -> "BlockSparseMatrix":
        """Random topology + U[minval, maxval) values (paper §V-B uses
        U[-1,3)). Exactly ``blocks_per_row`` nonzero blocks per block-row —
        the ELL-regular analogue of the paper's Bernoulli sampling.
        """
        m, n = shape
        bs_r, bs_c = block_shape
        nrb, ncb = m // bs_r, n // bs_c
        if blocks_per_row > ncb:
            raise ValueError(f"blocks_per_row {blocks_per_row} > col blocks {ncb}")
        k_idx, k_val = jax.random.split(key)
        # Per-row random choice without replacement via argsort of uniforms.
        u = jax.random.uniform(k_idx, (nrb, ncb))
        col_idx = jnp.argsort(u, axis=1)[:, :blocks_per_row].astype(jnp.int32)
        col_idx = jnp.sort(col_idx, axis=1)
        blocks = jax.random.uniform(
            k_val, (nrb, blocks_per_row, bs_r, bs_c), dtype, minval, maxval
        )
        mask = jnp.ones((nrb, blocks_per_row), bool)
        return cls(blocks, col_idx, mask, shape, block_shape)

    def to_dense(self) -> Array:
        nrb, mbpr = self.col_idx.shape
        bs_r, bs_c = self.block_shape
        ncb = self.n_col_blocks
        safe_blocks = jnp.where(
            self.block_mask[:, :, None, None], self.blocks, 0
        )
        tiles = jnp.zeros((nrb, ncb, bs_r, bs_c), self.dtype)
        rows = jnp.broadcast_to(jnp.arange(nrb)[:, None], (nrb, mbpr))
        # scatter-add: duplicate (row, col) slots would double-count, but
        # construction never aliases a (row, col) twice.
        tiles = tiles.at[rows, self.col_idx].add(safe_blocks)
        return tiles.transpose(0, 2, 1, 3).reshape(self.shape)

    def transpose(self, *, pad_to: int | None = None) -> "BlockSparseMatrix":
        """Device-side transpose: regroup stored blocks by column, no
        densification (the old path materialised the full (m, n) dense
        matrix — O(m·n) memory — and was host-only).

        Stored topology is preserved exactly (including explicit zero
        blocks). Jittable when ``pad_to`` (the transposed
        ``max_blocks_per_row``, i.e. the max *column* occupancy of
        ``self``) is given; with ``pad_to=None`` the width is read off
        the mask, which syncs one small scalar to host. ``pad_to``
        smaller than the true max column occupancy raises outside jit
        and silently drops blocks inside jit — pass a safe bound (e.g.
        ``n_row_blocks``) when unsure.
        """
        nrb, mbpr = self.col_idx.shape
        ncb = self.n_col_blocks
        bs_r, bs_c = self.block_shape
        flat = nrb * mbpr

        flat_cols = self.col_idx.reshape(flat)
        flat_valid = self.block_mask.reshape(flat)
        flat_rows = jnp.repeat(
            jnp.arange(nrb, dtype=jnp.int32), mbpr, total_repeat_length=flat
        )
        valid_i32 = flat_valid.astype(jnp.int32)
        counts = (
            jnp.zeros((ncb,), jnp.int32).at[flat_cols].add(valid_i32)
        )
        if pad_to is None:
            out_mbpr = max(int(jax.device_get(counts.max())), 1)
        else:
            out_mbpr = int(pad_to)
            if not isinstance(counts, jax.core.Tracer):
                max_occ = int(jax.device_get(counts.max()))
                if max_occ > out_mbpr:
                    raise ValueError(
                        f"pad_to={pad_to} < max column occupancy {max_occ}"
                    )

        # Stable sort by (valid first, column): valid blocks land grouped
        # by output row-block, original row-major order (→ ascending new
        # col_idx) preserved inside each group.
        order = jnp.argsort(
            jnp.where(flat_valid, flat_cols, ncb), stable=True
        )
        s_cols = flat_cols[order]
        s_valid = flat_valid[order]
        s_rows = flat_rows[order]
        group_start = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)]
        )
        pos = (
            jnp.arange(flat, dtype=jnp.int32)
            - group_start[jnp.where(s_valid, s_cols, 0)]
        )
        # invalid slots (and pad_to overflow under jit) scatter out of
        # range and are dropped
        pos = jnp.where(s_valid, pos, out_mbpr)
        s_blocks = jnp.swapaxes(
            self.blocks.reshape(flat, bs_r, bs_c)[order], -1, -2
        )

        dest_col = jnp.where(s_valid, s_cols, 0)
        blocks_t = (
            jnp.zeros((ncb, out_mbpr, bs_c, bs_r), self.dtype)
            .at[dest_col, pos]
            .set(s_blocks, mode="drop")
        )
        col_idx_t = (
            jnp.zeros((ncb, out_mbpr), jnp.int32)
            .at[dest_col, pos]
            .set(s_rows, mode="drop")
        )
        mask_t = (
            jnp.zeros((ncb, out_mbpr), bool)
            .at[dest_col, pos]
            .set(True, mode="drop")
        )
        return BlockSparseMatrix(
            blocks_t,
            col_idx_t,
            mask_t,
            (self.shape[1], self.shape[0]),
            (bs_c, bs_r),
        )
