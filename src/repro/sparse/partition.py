"""Balanced row-block partitioning of block-CSR matrices across a mesh.

The GraphChallenge follow-ups (arXiv:2004.01181, arXiv:1909.05631)
scale the paper's sparse stacks past one processor by partitioning the
weight matrices; this module is that split for the occupancy-exact
:class:`~repro.sparse.bcsr.BlockCSRMatrix` layout. The flattened
nnz-block segment (already sorted row-major by construction) is cut
into ``n_shards`` contiguous runs of near-equal nnz — the CSR analogue
of a balanced row-block partition. Because the arithmetic semiring's
``⊕`` is ``+``, a block-row whose blocks straddle a cut is *still
correct*: each shard computes a partial row product and the cross-shard
``psum`` (``repro.plan.sharded``) completes the sum, so balance never
fights row granularity.

:class:`ShardedBlockCSR` stacks the per-shard sub-layouts into single
arrays with a leading shard axis, which is what ``jax.shard_map`` wants:
each leaf is sharded over the ``row_blocks`` mesh axes (PartitionSpecs
resolved through the ``repro.distribution.sharding`` rule table) and a
shard's local slice reconstructs an ordinary :class:`BlockCSRMatrix`
with **global** shape and row indexing — the existing Pallas kernel
runs unchanged on the sub-segment, writing (partial) rows at their
global positions.

Degenerate shards are first-class: a very sparse or skewed topology can
hand a shard zero nnz blocks. Such a shard gets an empty sub-layout
(one invalid padding slot, all-zero ``row_ptr``) instead of a crash —
its kernel output is identically zero and the psum ignores it.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.bcsr import BcsrTransposePlan, BlockCSRMatrix

Array = jax.Array

# Leaf order of ShardedBlockCSR.tree_flatten — kept in sync with the
# PartitionSpec resolution table in repro.distribution.sharding
# (_SHARDED_CSR) and with stack_transpose_plans below.
SHARDED_CSR_LEAVES = (
    "values",
    "row_ptr",
    "row_id",
    "col_idx",
    "valid",
    "gather_index",
)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedBlockCSR:
    """A block-CSR matrix split into per-shard sub-segments.

    Every leaf carries a leading ``n_shards`` axis; shard ``s``'s slice
    is a valid :class:`BlockCSRMatrix` of the SAME logical ``shape``
    holding only its blocks (global ``row_id``/``col_idx``, per-shard
    ``row_ptr`` counting local blocks per global block-row — block-rows
    with no local blocks read as empty, which the kernel wrapper fills
    with the semiring zero so the cross-shard psum sees exact zeros).

    ``gather_index`` maps each local slot back to its source slot in the
    unsharded ``values`` array: re-sharding *fresh* values (training —
    the topology is frozen, the values are not) is one gather, fully
    differentiable, no re-partition.
    """

    values: Array  # (S, Tp, bs_r, bs_c)
    row_ptr: Array  # (S, nrb + 1) int32 — local counts per global row
    row_id: Array  # (S, Tp) int32 — GLOBAL block-row ids
    col_idx: Array  # (S, Tp) int32
    valid: Array  # (S, Tp) bool
    gather_index: Array  # (S, Tp) int32 into the unsharded segment
    shape: Tuple[int, int]
    block_shape: Tuple[int, int]

    def tree_flatten(self):
        return (
            tuple(getattr(self, name) for name in SHARDED_CSR_LEAVES),
            (self.shape, self.block_shape),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, block_shape = aux
        return cls(*children, shape, block_shape)

    # --- structure --------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return self.values.shape[0]

    @property
    def local_total_blocks(self) -> int:
        """Per-shard segment length (= each shard's kernel grid extent)."""
        return self.values.shape[1]

    @property
    def n_row_blocks(self) -> int:
        return self.shape[0] // self.block_shape[0]

    @property
    def dtype(self):
        return self.values.dtype

    def nnz_per_shard(self) -> np.ndarray:
        """(S,) valid-block counts — the balance the partitioner targets."""
        return np.asarray(jax.device_get(self.valid)).sum(axis=1)

    def imbalance(self) -> float:
        """max-shard-nnz / mean-shard-nnz (1.0 = perfectly balanced).

        The acceptance bar for the partitioner is ≤ 1.10 on realistic
        topologies; a contiguous equal-count segment split keeps it at
        ``1 + O(S / nnz)``.
        """
        nnz = self.nnz_per_shard()
        total = int(nnz.sum())
        if total == 0:
            return 1.0
        return float(nnz.max() * self.n_shards / total)

    def shard(self, s: int) -> BlockCSRMatrix:
        """Shard ``s``'s sub-layout as an ordinary BlockCSRMatrix
        (global shape and indexing — host-side convenience view)."""
        return BlockCSRMatrix(
            self.values[s],
            self.row_ptr[s],
            self.row_id[s],
            self.col_idx[s],
            self.valid[s],
            self.shape,
            self.block_shape,
        )

    def rescatter_values(self, flat_values: Array) -> Array:
        """Fresh unsharded values → the stacked (S, Tp, bs_r, bs_c)
        layout, through the frozen partition. Differentiable (the VJP is
        a scatter-add back onto the unsharded segment) — this is how
        training re-shards each step without re-partitioning."""
        gathered = flat_values[self.gather_index]
        return jnp.where(self.valid[:, :, None, None], gathered, 0)

    def with_values(self, stacked_values: Array) -> "ShardedBlockCSR":
        return dataclasses.replace(self, values=stacked_values)

    def to_dense(self) -> Array:
        """Σ over shards of the per-shard densifications — the exactness
        check tests rely on (every stored block lands in exactly one
        shard, so the sum reassembles the original)."""
        out = self.shard(0).to_dense()
        for s in range(1, self.n_shards):
            out = out + self.shard(s).to_dense()
        return out


def partition_block_csr(
    a: BlockCSRMatrix, n_shards: int
) -> ShardedBlockCSR:
    """Split ``a``'s stored-block segment into ``n_shards`` contiguous,
    nnz-balanced sub-segments (host-side, like all topology work).

    Valid slots are dealt to shards in CSR order via an equal-count
    split (sizes differ by at most one), so nnz imbalance is
    ``≤ 1 + n_shards/nnz``. Tail padding of the source matrix is
    dropped; each shard is re-padded to the common per-shard length
    ``Tp = max(1, ceil(nnz / n_shards))`` with inert invalid slots
    (``row_id`` pinned to the shard's last valid block so the kernel's
    flush logic never fires on padding). Shards beyond the available
    blocks — possible for very sparse topologies — become empty
    sub-layouts rather than errors.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    row_id = np.asarray(jax.device_get(a.row_id))
    col_idx = np.asarray(jax.device_get(a.col_idx))
    valid = np.asarray(jax.device_get(a.valid))
    values = np.asarray(jax.device_get(a.values))
    bs_r, bs_c = a.block_shape
    nrb = a.n_row_blocks

    slots = np.nonzero(valid)[0]  # CSR order by construction
    splits = np.array_split(slots, n_shards)
    tp = max(1, max((len(s) for s in splits), default=1))

    S = n_shards
    out_values = np.zeros((S, tp, bs_r, bs_c), values.dtype)
    out_row_id = np.zeros((S, tp), np.int32)
    out_col = np.zeros((S, tp), np.int32)
    out_valid = np.zeros((S, tp), bool)
    out_gidx = np.zeros((S, tp), np.int32)
    out_rptr = np.zeros((S, nrb + 1), np.int32)
    for s, idx in enumerate(splits):
        k = len(idx)
        if k == 0:
            continue  # degenerate shard: empty sub-layout stays inert
        out_values[s, :k] = values[idx]
        out_row_id[s, :k] = row_id[idx]
        out_row_id[s, k:] = row_id[idx][-1]  # pin padding to last row
        out_col[s, :k] = col_idx[idx]
        out_valid[s, :k] = True
        out_gidx[s, :k] = idx
        counts = np.bincount(row_id[idx], minlength=nrb).astype(np.int64)
        np.cumsum(counts, out=out_rptr[s, 1:])
    return ShardedBlockCSR(
        jnp.asarray(out_values),
        jnp.asarray(out_rptr),
        jnp.asarray(out_row_id),
        jnp.asarray(out_col),
        jnp.asarray(out_valid),
        jnp.asarray(out_gidx),
        a.shape,
        a.block_shape,
    )


def stack_transpose_plans(sharded: ShardedBlockCSR) -> BcsrTransposePlan:
    """Per-shard backward-transpose plans, stacked for ``shard_map``.

    Each shard's sub-layout is sorted into transposed CSR order once
    (``BlockCSRMatrix.transpose_plan`` — this is the sharded analogue of
    the plan layer's one-sort-per-topology rule: S sorts per topology,
    one per shard, ever). The per-shard plans share static aux data, so
    they stack into ONE :class:`BcsrTransposePlan` pytree whose leaves
    carry a leading shard axis; a shard's local slice is its own valid
    plan, consumed by the custom-VJP backward inside the shard_map body.
    """
    plans = [sharded.shard(s).transpose_plan() for s in range(sharded.n_shards)]
    first = plans[0]
    return BcsrTransposePlan(
        jnp.stack([p.order for p in plans]),
        jnp.stack([p.row_ptr for p in plans]),
        jnp.stack([p.row_id for p in plans]),
        jnp.stack([p.col_idx for p in plans]),
        jnp.stack([p.valid for p in plans]),
        first.shape,
        first.block_shape,
    )
