"""Block-CSR weight matrices — the occupancy-exact sparse layout.

The ELL-padded :class:`~repro.sparse.bsr.BlockSparseMatrix` pays the
*worst-case* row occupancy on every row: its kernel grid is
``nrb × max_blocks_per_row`` and padded slots still burn grid steps and
HBM→VMEM DMAs (their compute is skipped, their latency is not). This
module stores the same topology in flattened CSR order so work scales
with the *true* number of stored blocks — the paper's core claim
(arXiv:1708.02937 §V: inference time ∝ nnz) carried through to the
kernel grid.

Layout (all leading dimensions = ``total_blocks``):

  values:  (total_blocks, bs_r, bs_c)  stored blocks, row-major by
           block-row, columns ascending within a row.
  row_id:  (total_blocks,) int32       block-row of each stored block —
           the kernel's scalar-prefetched flush map.
  col_idx: (total_blocks,) int32       block-column of each stored block.
  valid:   (total_blocks,) bool        False only for the optional
           tail padding (shape-stable sweeps); padded slots carry
           ``row_id`` of the last real block so they never trigger a
           spurious row-change flush.
  row_ptr: (n_row_blocks + 1,) int32   classic CSR offsets over *valid*
           blocks (used for empty-row detection and analysis).

When to use which layout (see also ``repro.kernels``):
  * ELL/BSR — regular topologies (uniform blocks/row, e.g. the paper's
    fixed-degree synthetic networks). Simplest grid, no flush logic.
  * block-CSR — skewed or pruned topologies where max row occupancy ≫
    mean: the ELL pad multiplies the whole grid by the worst row while
    the CSR grid pays exactly ``total_nnz_blocks`` steps.
``repro.core.dnn.preferred_layout`` encodes this choice.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array

# How many times a block-CSR topology has been *sorted* (the O(T log T)
# argsort behind ``transpose``/``transpose_plan``) since the last reset.
# The plan layer (``repro.plan``) amortizes this to once per topology:
# tests and the benchmark's ``plan`` arm assert a multi-step train loop
# increments it exactly once (at plan build), never per backward pass.
_transpose_sort_count = 0


def transpose_sort_count() -> int:
    """Process-wide count of topology sorts (trace-time invocations)."""
    return _transpose_sort_count


def reset_transpose_sort_count() -> None:
    global _transpose_sort_count
    _transpose_sort_count = 0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BcsrTransposePlan:
    """The sorted layout + permutation of a block-CSR transpose.

    Everything here is **topology-only** (int/bool leaves — no values),
    so the plan stays valid across training steps that update the stored
    block values but keep the pattern frozen. :meth:`apply` rebuilds the
    transposed matrix from fresh values with a single gather — no
    re-sort. Built once per topology by
    :meth:`BlockCSRMatrix.transpose_plan`; consumed by the backward rule
    in ``repro.kernels.autodiff`` and carried by ``repro.plan``.
    """

    order: Array  # (T,) int32 — permutation into transposed CSR order
    row_ptr: Array  # (ncb + 1,) int32 over valid transposed blocks
    row_id: Array  # (T,) int32 — transposed block-row per slot
    col_idx: Array  # (T,) int32 — transposed block-col per slot
    valid: Array  # (T,) bool
    shape: Tuple[int, int]  # shape of the TRANSPOSED matrix
    block_shape: Tuple[int, int]

    def tree_flatten(self):
        return (
            (self.order, self.row_ptr, self.row_id, self.col_idx, self.valid),
            (self.shape, self.block_shape),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        order, row_ptr, row_id, col_idx, valid = children
        shape, block_shape = aux
        return cls(order, row_ptr, row_id, col_idx, valid, shape, block_shape)

    def apply(self, a: "BlockCSRMatrix") -> "BlockCSRMatrix":
        """Transpose ``a`` through the cached permutation (gather only).

        ``a`` must share the topology the plan was built from; only its
        ``values`` are read — fully jittable, no sort anywhere.
        """
        values_t = jnp.swapaxes(a.values[self.order], -1, -2)
        return BlockCSRMatrix(
            jnp.where(self.valid[:, None, None], values_t, 0),
            self.row_ptr,
            self.row_id,
            self.col_idx,
            self.valid,
            self.shape,
            self.block_shape,
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockCSRMatrix:
    """Flattened block-CSR matrix of logical shape ``shape``.

    Construction is host-side (topology discovery needs concrete
    values), like ``BlockSparseMatrix.from_dense``; the result is a
    pytree usable under jit.
    """

    values: Array  # (T, bs_r, bs_c)
    row_ptr: Array  # (nrb + 1,) int32 over valid blocks
    row_id: Array  # (T,) int32
    col_idx: Array  # (T,) int32
    valid: Array  # (T,) bool
    shape: Tuple[int, int]
    block_shape: Tuple[int, int]

    # --- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        return (
            (self.values, self.row_ptr, self.row_id, self.col_idx, self.valid),
            (self.shape, self.block_shape),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, row_ptr, row_id, col_idx, valid = children
        shape, block_shape = aux
        return cls(values, row_ptr, row_id, col_idx, valid, shape, block_shape)

    # --- derived structure ----------------------------------------------
    @property
    def n_row_blocks(self) -> int:
        return self.shape[0] // self.block_shape[0]

    @property
    def n_col_blocks(self) -> int:
        return self.shape[1] // self.block_shape[1]

    @property
    def total_blocks(self) -> int:
        """Stored blocks including tail padding — the kernel's grid extent."""
        return self.values.shape[0]

    @property
    def nnz_blocks(self) -> Array:
        return jnp.sum(self.valid)

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def nbytes(self) -> int:
        return int(
            self.values.size * self.values.dtype.itemsize
            + self.row_ptr.size * self.row_ptr.dtype.itemsize
            + self.row_id.size * self.row_id.dtype.itemsize
            + self.col_idx.size * self.col_idx.dtype.itemsize
            + self.valid.size  # bool = 1 byte
        )

    def astype(self, dtype) -> "BlockCSRMatrix":
        return BlockCSRMatrix(
            self.values.astype(dtype),
            self.row_ptr,
            self.row_id,
            self.col_idx,
            self.valid,
            self.shape,
            self.block_shape,
        )

    # --- integrity --------------------------------------------------------
    def validate(self, *, name: str = "") -> "BlockCSRMatrix":
        """Check the layout invariants; raise ValueError with a precise
        message on the first violation, return ``self`` when clean.

        Host-side (syncs the index arrays once) — call it at trust
        boundaries: checkpoint restore, engine construction — not per
        step. Checked: shape/block divisibility, index-array shapes,
        ``row_ptr`` monotone from 0 to nnz, validity a contiguous
        prefix, in-bounds ``row_id``/``col_idx``, blocks stored
        row-major with strictly ascending columns within a block-row,
        ``row_ptr`` consistent with per-row block counts, and finite
        stored values.
        """
        label = name or f"BlockCSRMatrix{self.shape}"
        m, n = self.shape
        bs_r, bs_c = self.block_shape
        if m % bs_r or n % bs_c:
            raise ValueError(
                f"{label}: shape {self.shape} not divisible by block "
                f"{self.block_shape}"
            )
        nrb, ncb = self.n_row_blocks, self.n_col_blocks
        values = np.asarray(jax.device_get(self.values))
        row_ptr = np.asarray(jax.device_get(self.row_ptr))
        row_id = np.asarray(jax.device_get(self.row_id))
        col_idx = np.asarray(jax.device_get(self.col_idx))
        valid = np.asarray(jax.device_get(self.valid)).astype(bool)
        total = values.shape[0]
        if values.shape != (total, bs_r, bs_c):
            raise ValueError(
                f"{label}: values shape {values.shape} != "
                f"({total}, {bs_r}, {bs_c})"
            )
        for arr_name, arr in (("row_id", row_id), ("col_idx", col_idx),
                              ("valid", valid)):
            if arr.shape != (total,):
                raise ValueError(
                    f"{label}: {arr_name} shape {arr.shape} != ({total},)"
                )
        if row_ptr.shape != (nrb + 1,):
            raise ValueError(
                f"{label}: row_ptr shape {row_ptr.shape} != ({nrb + 1},)"
            )
        if row_ptr[0] != 0:
            raise ValueError(f"{label}: row_ptr[0] = {row_ptr[0]}, expected 0")
        if np.any(np.diff(row_ptr) < 0):
            i = int(np.argmax(np.diff(row_ptr) < 0))
            raise ValueError(
                f"{label}: row_ptr not monotone at block-row {i} "
                f"({row_ptr[i]} -> {row_ptr[i + 1]})"
            )
        nnz = int(valid.sum())
        if int(row_ptr[-1]) != nnz:
            raise ValueError(
                f"{label}: row_ptr[-1] = {int(row_ptr[-1])} != valid block "
                f"count {nnz}"
            )
        if np.any(valid[1:] & ~valid[:-1]):
            raise ValueError(
                f"{label}: valid mask is not a contiguous prefix (a valid "
                "block follows an invalid slot)"
            )
        if np.any((row_id < 0) | (row_id >= nrb)):
            bad = int(np.argmax((row_id < 0) | (row_id >= nrb)))
            raise ValueError(
                f"{label}: row_id[{bad}] = {int(row_id[bad])} out of "
                f"[0, {nrb})"
            )
        rows, cols = row_id[:nnz], col_idx[:nnz]
        if nnz and np.any((cols < 0) | (cols >= ncb)):
            bad = int(np.argmax((cols < 0) | (cols >= ncb)))
            raise ValueError(
                f"{label}: col_idx[{bad}] = {int(cols[bad])} out of "
                f"[0, {ncb})"
            )
        if nnz > 1:
            if np.any(rows[1:] < rows[:-1]):
                bad = int(np.argmax(rows[1:] < rows[:-1]))
                raise ValueError(
                    f"{label}: blocks not stored row-major (row_id drops "
                    f"{int(rows[bad])} -> {int(rows[bad + 1])} at slot "
                    f"{bad + 1})"
                )
            same_row = rows[1:] == rows[:-1]
            if np.any(same_row & (cols[1:] <= cols[:-1])):
                bad = int(np.argmax(same_row & (cols[1:] <= cols[:-1])))
                raise ValueError(
                    f"{label}: col_idx not strictly ascending within "
                    f"block-row {int(rows[bad])} (slot {bad}: "
                    f"{int(cols[bad])} -> {int(cols[bad + 1])})"
                )
        counts = np.bincount(rows, minlength=nrb) if nnz else np.zeros(nrb, int)
        if not np.array_equal(np.cumsum(counts), row_ptr[1:]):
            bad = int(np.argmax(np.cumsum(counts) != row_ptr[1:]))
            raise ValueError(
                f"{label}: row_ptr inconsistent with row_id counts at "
                f"block-row {bad}"
            )
        if nnz and not np.isfinite(values[:nnz]).all():
            flat = np.isfinite(values[:nnz]).all(axis=(1, 2))
            bad = int(np.argmax(~flat))
            raise ValueError(
                f"{label}: non-finite value in stored block {bad} "
                f"(block-row {int(rows[bad])}, block-col {int(cols[bad])})"
            )
        return self

    # --- conversions ------------------------------------------------------
    @classmethod
    def from_bsr(
        cls, a: BlockSparseMatrix, *, pad_to: int | None = None
    ) -> "BlockCSRMatrix":
        """Flatten an ELL-padded BSR matrix to CSR order (host-side).

        ``pad_to`` forces ``total_blocks`` (shape-stable sweeps); padded
        tail slots are invalid zero blocks.
        """
        mask = np.asarray(a.block_mask)
        col_idx = np.asarray(a.col_idx)
        blocks = np.asarray(a.blocks)
        nrb, mbpr = mask.shape
        bs_r, bs_c = a.block_shape

        rows, slots = np.nonzero(mask)  # row-major → CSR order; cols
        # ascending within a row because construction stores them sorted.
        nnz = len(rows)
        total = int(pad_to) if pad_to is not None else max(nnz, 1)
        if nnz > total:
            raise ValueError(f"pad_to={pad_to} < nnz blocks {nnz}")

        values = np.zeros((total, bs_r, bs_c), blocks.dtype)
        row_id = np.zeros((total,), np.int32)
        cols = np.zeros((total,), np.int32)
        valid = np.zeros((total,), bool)
        values[:nnz] = blocks[rows, slots]
        row_id[:nnz] = rows
        cols[:nnz] = col_idx[rows, slots]
        valid[:nnz] = True
        # Tail padding rides on the last real row so the kernel's
        # row-change flush logic never fires on an invalid slot.
        row_id[nnz:] = rows[-1] if nnz else 0

        counts = mask.sum(axis=1).astype(np.int64)
        row_ptr = np.zeros((nrb + 1,), np.int32)
        np.cumsum(counts, out=row_ptr[1:])
        return cls(
            jnp.asarray(values),
            jnp.asarray(row_ptr),
            jnp.asarray(row_id),
            jnp.asarray(cols),
            jnp.asarray(valid),
            a.shape,
            a.block_shape,
        )

    @classmethod
    def from_dense(
        cls,
        dense: Array,
        block_shape: Tuple[int, int],
        *,
        pad_to: int | None = None,
    ) -> "BlockCSRMatrix":
        return cls.from_bsr(
            BlockSparseMatrix.from_dense(dense, block_shape), pad_to=pad_to
        )

    @classmethod
    def random_skewed(
        cls,
        seed: int,
        shape: Tuple[int, int],
        block_shape: Tuple[int, int],
        total_blocks: int,
        *,
        skew: float = 0.0,
        dtype=np.float32,
    ) -> "BlockCSRMatrix":
        """Random topology with ``total_blocks`` stored blocks distributed
        over rows with controllable skew (host-side; benchmark helper).

        ``skew`` ∈ [0, 1): 0 spreads blocks uniformly; approaching 1
        concentrates them on the first rows (Zipf-like) — the regime
        where the ELL pad is maximally wasteful. Values ~ U[-1, 3)
        (paper §V-B).
        """
        m, n = shape
        bs_r, bs_c = block_shape
        nrb, ncb = m // bs_r, n // bs_c
        if total_blocks > nrb * ncb:
            raise ValueError("total_blocks exceeds capacity")
        rng = np.random.default_rng(seed)
        # Zipf-ish row weights: w_i ∝ (i+1)^(-s) with s mapped from skew.
        s = 4.0 * skew
        w = (np.arange(nrb) + 1.0) ** (-s)
        w /= w.sum()
        counts = rng.multinomial(total_blocks, w)
        counts = np.minimum(counts, ncb)
        # Redistribute overflow to rows with spare capacity.
        deficit = total_blocks - counts.sum()
        while deficit > 0:
            spare = np.nonzero(counts < ncb)[0]
            take = spare[: int(deficit)]
            counts[take] += 1
            deficit = total_blocks - counts.sum()

        dense = np.zeros((m, n), dtype)
        for i in range(nrb):
            cols = rng.choice(ncb, size=int(counts[i]), replace=False)
            for c in np.sort(cols):
                blk = rng.uniform(-1.0, 3.0, (bs_r, bs_c)).astype(dtype)
                # keep the block nonzero so from_dense keeps it
                blk[0, 0] = blk[0, 0] if blk[0, 0] != 0 else 1.0
                dense[i * bs_r : (i + 1) * bs_r, c * bs_c : (c + 1) * bs_c] = blk
        return cls.from_dense(jnp.asarray(dense), block_shape, pad_to=total_blocks)

    def to_bsr(self, *, pad_to: int | None = None) -> BlockSparseMatrix:
        """Re-widen to the ELL layout (host-side)."""
        row_ptr = np.asarray(self.row_ptr)
        counts = row_ptr[1:] - row_ptr[:-1]
        nrb = self.n_row_blocks
        bs_r, bs_c = self.block_shape
        mbpr = int(pad_to if pad_to is not None else max(int(counts.max()), 1))
        if counts.max() > mbpr:
            raise ValueError(f"pad_to={pad_to} < max row occupancy")
        blocks = np.zeros((nrb, mbpr, bs_r, bs_c), np.asarray(self.values).dtype)
        col_idx = np.zeros((nrb, mbpr), np.int32)
        mask = np.zeros((nrb, mbpr), bool)
        vals = np.asarray(self.values)
        cols = np.asarray(self.col_idx)
        for i in range(nrb):
            lo, hi = int(row_ptr[i]), int(row_ptr[i + 1])
            blocks[i, : hi - lo] = vals[lo:hi]
            col_idx[i, : hi - lo] = cols[lo:hi]
            mask[i, : hi - lo] = True
        return BlockSparseMatrix(
            jnp.asarray(blocks),
            jnp.asarray(col_idx),
            jnp.asarray(mask),
            self.shape,
            self.block_shape,
        )

    def transpose_plan(self) -> BcsrTransposePlan:
        """Sort the topology into transposed CSR order ONCE and return
        the reusable :class:`BcsrTransposePlan` (permutation + transposed
        index arrays, no values). This is the only place the transpose's
        argsort runs — ``transpose_sort_count`` tracks invocations so the
        amortization is testable.

        Invalid tail slots sort to the end (they keep their inert role);
        their ``row_id`` is pinned to the last valid block's row so the
        kernels' flush logic stays sound.
        """
        global _transpose_sort_count
        _transpose_sort_count += 1
        ncb = self.n_col_blocks
        # Stable sort by (valid first, new row = old col); stability keeps
        # old rows (= new cols) ascending within each new row.
        order = jnp.argsort(
            jnp.where(self.valid, self.col_idx, ncb), stable=True
        )
        new_row = self.col_idx[order]
        new_col = self.row_id[order]
        new_valid = self.valid[order]

        counts = (
            jnp.zeros((ncb,), jnp.int32)
            .at[self.col_idx]
            .add(self.valid.astype(jnp.int32))
        )
        row_ptr = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)]
        )
        # Pin padding row_id to the last valid block's new row (see class
        # docstring); nnz is dynamic, so gather it from row_ptr's tail.
        nnz = row_ptr[-1]
        last_row = new_row[jnp.maximum(nnz - 1, 0)]
        new_row = jnp.where(new_valid, new_row, last_row)
        new_col = jnp.where(new_valid, new_col, 0)
        return BcsrTransposePlan(
            order,
            row_ptr,
            new_row,
            new_col,
            new_valid,
            (self.shape[1], self.shape[0]),
            (self.block_shape[1], self.block_shape[0]),
        )

    def transpose(self) -> "BlockCSRMatrix":
        """Device-side, fully jittable transpose: re-sort the stored
        blocks into the transposed CSR order (``total_blocks`` is static,
        so — unlike the ELL layout — no output pad width is needed).

        Sorts on every call; when the topology is frozen across calls
        (training loops), build :meth:`transpose_plan` once and
        ``plan.apply(self)`` instead — same result, gather only.
        """
        return self.transpose_plan().apply(self)

    def to_dense(self) -> Array:
        m, n = self.shape
        bs_r, bs_c = self.block_shape
        nrb, ncb = self.n_row_blocks, self.n_col_blocks
        safe = jnp.where(self.valid[:, None, None], self.values, 0)
        tiles = jnp.zeros((nrb, ncb, bs_r, bs_c), self.dtype)
        # invalid slots scatter to their (row_id, col_idx) with zero data —
        # harmless (construction never aliases a real (row, col) twice).
        tiles = tiles.at[self.row_id, self.col_idx].add(safe)
        return tiles.transpose(0, 2, 1, 3).reshape(m, n)
