"""Pure-jnp sparse operations — the executable oracles + CPU paths.

Two layouts, two oracles:

* ``bsr_matmul`` — generalized ``C = A ⊕.⊗ B`` for an ELL-padded BSR
  ``A`` (regular topologies) and dense ``B`` over any
  :class:`~repro.core.semiring.Semiring`. Checks
  ``repro.kernels.bsr_spmm``.
* ``bcsr_matmul`` — the same contraction for the occupancy-exact
  :class:`~repro.sparse.bcsr.BlockCSRMatrix` layout (skewed/pruned
  topologies): per-stored-block products followed by a segment-⊕ over
  the CSR row map, so host compute also scales with true nnz. Checks
  ``repro.kernels.bcsr_spmm``.

On CPU these *are* the production paths (XLA fuses the gather + einsum
well enough to show the paper's sparsity crossover — see benchmarks).

The module also hosts the occupancy-exact building blocks of the custom
VJPs (``repro.kernels.autodiff``): ``*_transpose_matmul`` computes
``Aᵀ·Y`` by scatter-⊕ over the stored blocks (no transposed matrix, no
densify) and ``*_weight_cotangent`` computes the sampled block products
``dW[blk] = dZ_row(blk) · Bᵀ_col(blk)`` at stored positions only, so the
weight gradient comes back in the primal's exact sparsity pattern.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.semiring import PLUS_TIMES, Semiring
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array


def bsr_matmul(
    a: BlockSparseMatrix,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
) -> Array:
    """C (m, k) = A (m, n) ⊕.⊗ B (n, k).

    Gathers the needed B row-panels per stored block and contracts with
    dense per-block products; padded slots are neutralised with the
    semiring zero before the ⊕ reduction so non-arithmetic semirings stay
    correct.
    """
    m, n = a.shape
    if b.shape[0] != n:
        raise ValueError(f"shape mismatch: A {a.shape} @ B {b.shape}")
    k = b.shape[1]
    bs_r, bs_c = a.block_shape
    nrb, mbpr = a.col_idx.shape

    from repro.distribution.sharding import constrain

    b_panels = b.reshape(n // bs_c, bs_c, k)
    gathered = b_panels[a.col_idx]  # (nrb, mbpr, bs_c, k)
    # keep the panel gather row-block sharded (GSPMD otherwise replicates
    # gather outputs over the model axis — no-op outside activate())
    gathered = constrain(gathered, ("row_blocks", None, None, None))

    if semiring.name == "plus_times":
        safe_blocks = jnp.where(a.block_mask[:, :, None, None], a.blocks, 0)
        safe_blocks = constrain(safe_blocks, ("row_blocks", None, None, None))
        out = jnp.einsum(
            "rmbc,rmck->rbk",
            safe_blocks,
            gathered,
            preferred_element_type=jnp.promote_types(a.dtype, b.dtype),
        )
        out = constrain(out, ("row_blocks", None, None))
        return out.reshape(m, k).astype(jnp.result_type(a.dtype, b.dtype))

    # General semiring: per-block generalized product, ⊕ across blocks.
    # prod[r, mb, i, j] = ⊕_c blocks[r, mb, i, c] ⊗ gathered[r, mb, c, j]
    prod = semiring.mul(
        a.blocks[:, :, :, :, None], gathered[:, :, None, :, :]
    )  # (nrb, mbpr, bs_r, bs_c, k)
    prod = semiring.add_reduce(prod, axis=3)  # (nrb, mbpr, bs_r, k)
    zero = jnp.asarray(semiring.zero, prod.dtype)
    prod = jnp.where(a.block_mask[:, :, None, None], prod, zero)
    out = semiring.add_reduce(prod, axis=1)  # (nrb, bs_r, k)
    return out.reshape(m, k)


def _segment_add_reduce(
    semiring: Semiring, x: Array, segment_ids: Array, num_segments: int
) -> Array:
    """⊕-reduce ``x`` over leading-axis segments (sorted CSR row ids)."""
    kwargs = dict(
        num_segments=num_segments, indices_are_sorted=True
    )
    if semiring.add is jnp.add:
        return jax.ops.segment_sum(x, segment_ids, **kwargs)
    if semiring.add is jnp.maximum:
        return jax.ops.segment_max(x, segment_ids, **kwargs)
    if semiring.add is jnp.minimum:
        return jax.ops.segment_min(x, segment_ids, **kwargs)
    # Generic ⊕ (log_plus, lor_land, xor_and, …): mask-broadcast reduce.
    # O(num_segments × T) memory — fine for the oracle/CPU role these
    # exotic semirings play; the hot semirings take the paths above.
    hit = segment_ids[None, :] == jnp.arange(num_segments)[:, None]  # (R, T)
    zero = jnp.asarray(semiring.zero, x.dtype)
    expanded = jnp.where(hit[:, :, None, None], x[None], zero)
    return semiring.add_reduce(expanded, axis=1)


def bcsr_matmul(
    a: BlockCSRMatrix,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
) -> Array:
    """C (m, k) = A (m, n) ⊕.⊗ B (n, k) for the flattened CSR layout.

    One generalized block product per *stored* block, then a segment-⊕
    keyed by ``row_id``. Rows with no stored blocks come out as the
    segment identity — the semiring zero, matching ``bsr_matmul``'s
    masked semantics.
    """
    m, n = a.shape
    if b.shape[0] != n:
        raise ValueError(f"shape mismatch: A {a.shape} @ B {b.shape}")
    k = b.shape[1]
    bs_r, bs_c = a.block_shape
    nrb = a.n_row_blocks

    b_panels = b.reshape(n // bs_c, bs_c, k)
    gathered = b_panels[a.col_idx]  # (T, bs_c, k)

    if semiring.name == "plus_times":
        safe = jnp.where(a.valid[:, None, None], a.values, 0)
        prod = jnp.einsum(
            "tbc,tck->tbk",
            safe,
            gathered,
            preferred_element_type=jnp.promote_types(a.dtype, b.dtype),
        )  # (T, bs_r, k)
        out = jax.ops.segment_sum(
            prod, a.row_id, num_segments=nrb, indices_are_sorted=True
        )
        return out.reshape(m, k).astype(jnp.result_type(a.dtype, b.dtype))

    # General semiring: ⊗ then ⊕ over the block's contraction axis, then
    # neutralise invalid slots and segment-⊕ over the row map.
    prod = semiring.mul(
        a.values[:, :, :, None], gathered[:, None, :, :]
    )  # (T, bs_r, bs_c, k)
    prod = semiring.add_reduce(prod, axis=2)  # (T, bs_r, k)
    zero = jnp.asarray(semiring.zero, prod.dtype)
    prod = jnp.where(a.valid[:, None, None], prod, zero)
    out = _segment_add_reduce(semiring, prod, a.row_id, nrb)
    # segment_max/min use their own identity for empty segments; for the
    # tropical semirings those identities coincide with semiring.zero
    # (±inf), but clamp anyway in case a segment implementation differs.
    empty = (a.row_ptr[1:] == a.row_ptr[:-1])[:, None, None]
    out = jnp.where(empty, zero, out)
    return out.reshape(m, k)


def bsr_transpose_matmul(a: BlockSparseMatrix, y: Array) -> Array:
    """``Aᵀ (k, m) @ Y (m, n)`` without materializing the transpose.

    Each stored block (r, c, W) contributes ``Wᵀ @ Y_row(r)`` to output
    row-block c: per-block products followed by a segment-sum keyed by
    ``col_idx``. Work ∝ stored blocks — the backward-pass analogue of
    ``bsr_matmul`` (used by the kernels' custom VJPs for dX = Wᵀ·dY).
    """
    m, k = a.shape
    if y.shape[0] != m:
        raise ValueError(f"shape mismatch: Aᵀ {(k, m)} @ Y {y.shape}")
    n = y.shape[1]
    bs_r, bs_c = a.block_shape
    nrb, mbpr = a.col_idx.shape
    ncb = a.n_col_blocks

    y_panels = y.reshape(nrb, bs_r, n)
    safe_blocks = jnp.where(a.block_mask[:, :, None, None], a.blocks, 0)
    # prod[r, s] = W[r, s]ᵀ @ Y_row(r)   (bs_c, n) per stored block
    prod = jnp.einsum(
        "rsbc,rbn->rscn",
        safe_blocks,
        y_panels,
        preferred_element_type=jnp.promote_types(a.dtype, y.dtype),
    )
    out = jax.ops.segment_sum(
        prod.reshape(nrb * mbpr, bs_c, n),
        a.col_idx.reshape(-1),
        num_segments=ncb,
    )
    return out.reshape(k, n).astype(jnp.result_type(a.dtype, y.dtype))


def bsr_weight_cotangent(a: BlockSparseMatrix, dz: Array, b: Array) -> Array:
    """Cotangent of ``a.blocks`` for ``Z = A @ B``: the sampled products
    ``dW[r, s] = dZ_row(r) @ B_col(col_idx[r, s])ᵀ`` — computed ONLY at
    the stored (mask-true) slots; padded slots come back exactly zero so
    the gradient lives in the primal's sparsity pattern."""
    nrb, mbpr = a.col_idx.shape
    bs_r, bs_c = a.block_shape
    n = dz.shape[1]
    dz_panels = dz.reshape(nrb, bs_r, n)
    b_panels = b.reshape(a.n_col_blocks, bs_c, n)[a.col_idx]  # (nrb, mbpr, bs_c, n)
    d = jnp.einsum(
        "rbn,rscn->rsbc",
        dz_panels,
        b_panels,
        preferred_element_type=jnp.float32,
    )
    return jnp.where(a.block_mask[:, :, None, None], d, 0.0)


def bcsr_transpose_matmul(c: BlockCSRMatrix, y: Array) -> Array:
    """``Aᵀ (k, m) @ Y (m, n)`` for the flattened CSR layout — per-stored-
    block ``Wᵀ @ Y_row`` products scatter-summed by ``col_idx`` (unsorted
    segment ids; work ∝ true nnz). jnp mirror of running ``bcsr_spmm`` on
    ``c.transpose()`` — the oracle for the CSR kernel's backward pass."""
    m, k = c.shape
    if y.shape[0] != m:
        raise ValueError(f"shape mismatch: Aᵀ {(k, m)} @ Y {y.shape}")
    n = y.shape[1]
    bs_r, bs_c = c.block_shape
    y_gathered = y.reshape(c.n_row_blocks, bs_r, n)[c.row_id]  # (T, bs_r, n)
    safe = jnp.where(c.valid[:, None, None], c.values, 0)
    prod = jnp.einsum(
        "tbc,tbn->tcn",
        safe,
        y_gathered,
        preferred_element_type=jnp.promote_types(c.dtype, y.dtype),
    )
    out = jax.ops.segment_sum(prod, c.col_idx, num_segments=c.n_col_blocks)
    return out.reshape(k, n).astype(jnp.result_type(c.dtype, y.dtype))


def bcsr_weight_cotangent(c: BlockCSRMatrix, dz: Array, b: Array) -> Array:
    """Cotangent of ``c.values`` for ``Z = A @ B``: sampled products
    ``dW[t] = dZ_row(row_id[t]) @ B_col(col_idx[t])ᵀ`` at stored blocks
    only; invalid tail slots come back exactly zero."""
    bs_r, bs_c = c.block_shape
    n = dz.shape[1]
    dz_gathered = dz.reshape(c.n_row_blocks, bs_r, n)[c.row_id]  # (T, bs_r, n)
    b_gathered = b.reshape(c.n_col_blocks, bs_c, n)[c.col_idx]  # (T, bs_c, n)
    d = jnp.einsum(
        "tbn,tcn->tbc",
        dz_gathered,
        b_gathered,
        preferred_element_type=jnp.float32,
    )
    return jnp.where(c.valid[:, None, None], d, 0.0)


def bcsr_matmul_fused_relu(
    a: BlockCSRMatrix,
    b: Array,
    bias: Array,
) -> Array:
    """Fused max(A·B + bias, 0) for the CSR layout (cf. the ELL version)."""
    out = bcsr_matmul(a, b, PLUS_TIMES)
    return jnp.maximum(out + bias[:, None], 0.0)


def bsr_matmul_fused_relu(
    a: BlockSparseMatrix,
    b: Array,
    bias: Array,
) -> Array:
    """Beyond-paper fused op: max(A·B + bias, 0) in one pass.

    The paper executes this as three GraphBLAS calls (mxm, eWiseMult,
    eWiseAdd), each re-streaming the (m, k) activations; the fused form
    streams them once. Matches ``kernels/bsr_spmm`` with fused epilogue.
    """
    out = bsr_matmul(a, b, PLUS_TIMES)
    return jnp.maximum(out + bias[:, None], 0.0)


def dense_matmul_fused_relu(w: Array, y: Array, bias: Array) -> Array:
    """Dense (BLAS-arm) fused baseline: max(W·Y + b, 0)."""
    return jnp.maximum(jnp.matmul(w, y) + bias[:, None], 0.0)
