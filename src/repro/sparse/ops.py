"""Pure-jnp BSR operations — the executable oracle + CPU path.

``bsr_matmul`` is the generalized ``C = A ⊕.⊗ B`` for an ELL-padded BSR
``A`` and dense ``B`` over any :class:`~repro.core.semiring.Semiring`.
The Pallas TPU kernel (``repro.kernels.bsr_spmm``) is checked against this
implementation; on CPU this *is* the production path (XLA fuses the
gather + einsum well enough to show the paper's sparsity crossover — see
benchmarks).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.semiring import PLUS_TIMES, Semiring
from repro.sparse.bsr import BlockSparseMatrix

Array = jax.Array


def bsr_matmul(
    a: BlockSparseMatrix,
    b: Array,
    semiring: Semiring = PLUS_TIMES,
) -> Array:
    """C (m, k) = A (m, n) ⊕.⊗ B (n, k).

    Gathers the needed B row-panels per stored block and contracts with
    dense per-block products; padded slots are neutralised with the
    semiring zero before the ⊕ reduction so non-arithmetic semirings stay
    correct.
    """
    m, n = a.shape
    if b.shape[0] != n:
        raise ValueError(f"shape mismatch: A {a.shape} @ B {b.shape}")
    k = b.shape[1]
    bs_r, bs_c = a.block_shape
    nrb, mbpr = a.col_idx.shape

    from repro.distribution.sharding import constrain

    b_panels = b.reshape(n // bs_c, bs_c, k)
    gathered = b_panels[a.col_idx]  # (nrb, mbpr, bs_c, k)
    # keep the panel gather row-block sharded (GSPMD otherwise replicates
    # gather outputs over the model axis — no-op outside activate())
    gathered = constrain(gathered, ("row_blocks", None, None, None))

    if semiring.name == "plus_times":
        safe_blocks = jnp.where(a.block_mask[:, :, None, None], a.blocks, 0)
        safe_blocks = constrain(safe_blocks, ("row_blocks", None, None, None))
        out = jnp.einsum(
            "rmbc,rmck->rbk",
            safe_blocks,
            gathered,
            preferred_element_type=jnp.promote_types(a.dtype, b.dtype),
        )
        out = constrain(out, ("row_blocks", None, None))
        return out.reshape(m, k).astype(jnp.result_type(a.dtype, b.dtype))

    # General semiring: per-block generalized product, ⊕ across blocks.
    # prod[r, mb, i, j] = ⊕_c blocks[r, mb, i, c] ⊗ gathered[r, mb, c, j]
    prod = semiring.mul(
        a.blocks[:, :, :, :, None], gathered[:, :, None, :, :]
    )  # (nrb, mbpr, bs_r, bs_c, k)
    prod = semiring.add_reduce(prod, axis=3)  # (nrb, mbpr, bs_r, k)
    zero = jnp.asarray(semiring.zero, prod.dtype)
    prod = jnp.where(a.block_mask[:, :, None, None], prod, zero)
    out = semiring.add_reduce(prod, axis=1)  # (nrb, bs_r, k)
    return out.reshape(m, k)


def bsr_matmul_fused_relu(
    a: BlockSparseMatrix,
    b: Array,
    bias: Array,
) -> Array:
    """Beyond-paper fused op: max(A·B + bias, 0) in one pass.

    The paper executes this as three GraphBLAS calls (mxm, eWiseMult,
    eWiseAdd), each re-streaming the (m, k) activations; the fused form
    streams them once. Matches ``kernels/bsr_spmm`` with fused epilogue.
    """
    out = bsr_matmul(a, b, PLUS_TIMES)
    return jnp.maximum(out + bias[:, None], 0.0)


def dense_matmul_fused_relu(w: Array, y: Array, bias: Array) -> Array:
    """Dense (BLAS-arm) fused baseline: max(W·Y + b, 0)."""
    return jnp.maximum(jnp.matmul(w, y) + bias[:, None], 0.0)
