from repro.sparse.bsr import BlockSparseMatrix
from repro.sparse import ops

__all__ = ["BlockSparseMatrix", "ops"]
