from repro.sparse.bsr import BlockSparseMatrix
from repro.sparse.bcsr import (
    BcsrTransposePlan,
    BlockCSRMatrix,
    reset_transpose_sort_count,
    transpose_sort_count,
)
from repro.sparse.partition import (
    ShardedBlockCSR,
    partition_block_csr,
    stack_transpose_plans,
)
from repro.sparse import ops

__all__ = [
    "BlockSparseMatrix",
    "BlockCSRMatrix",
    "BcsrTransposePlan",
    "ShardedBlockCSR",
    "partition_block_csr",
    "stack_transpose_plans",
    "transpose_sort_count",
    "reset_transpose_sort_count",
    "ops",
]
