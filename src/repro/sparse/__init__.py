from repro.sparse.bsr import BlockSparseMatrix
from repro.sparse.bcsr import (
    BcsrTransposePlan,
    BlockCSRMatrix,
    reset_transpose_sort_count,
    transpose_sort_count,
)
from repro.sparse import ops

__all__ = [
    "BlockSparseMatrix",
    "BlockCSRMatrix",
    "BcsrTransposePlan",
    "transpose_sort_count",
    "reset_transpose_sort_count",
    "ops",
]
