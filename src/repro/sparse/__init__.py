from repro.sparse.bsr import BlockSparseMatrix
from repro.sparse.bcsr import BlockCSRMatrix
from repro.sparse import ops

__all__ = ["BlockSparseMatrix", "BlockCSRMatrix", "ops"]
