"""Fault-tolerant run supervisor + straggler mitigation (DESIGN.md §6).

``Supervisor`` wraps a step function with checkpoint/restart semantics:
on any step failure it restores the last good checkpoint and continues,
up to ``max_restarts``. Because the data pipeline is stateless-
deterministic in (seed, step), a restart replays the exact batch stream
with no loader state to recover — the property that also makes *elastic*
DP scaling safe (any host can serve any shard).

``StragglerPolicy`` implements the step-deadline rule used at scale: a
step slower than ``deadline_factor`` × the rolling median marks the step
as straggled; after ``evict_after`` consecutive marks the supervisor's
``on_straggler`` hook fires (in a real deployment: evict + re-slot the
node and resume from the last checkpoint — exactly the restore path
exercised here).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.train import checkpoint


@dataclasses.dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0
    evict_after: int = 2
    window: int = 16

    def __post_init__(self):
        self._times: list[float] = []
        self._consecutive = 0

    def observe(self, step_time: float) -> bool:
        """Returns True if the straggler action should fire."""
        self._times.append(step_time)
        self._times = self._times[-self.window :]
        if len(self._times) < 4:
            return False
        median = sorted(self._times)[len(self._times) // 2]
        if step_time > self.deadline_factor * median:
            self._consecutive += 1
        else:
            self._consecutive = 0
        return self._consecutive >= self.evict_after


@dataclasses.dataclass
class Supervisor:
    step_fn: Callable[[Any, int], Any]  # (state, step) -> state
    save_state: Callable[[Any], Any]  # state -> checkpointable pytree
    load_state: Callable[[Any], Any]  # pytree -> state
    ckpt_dir: str
    ckpt_interval: int = 50
    max_restarts: int = 3
    straggler: StragglerPolicy | None = None
    on_straggler: Callable[[int], None] | None = None
    metadata: dict | None = None

    def run(self, state: Any, num_steps: int, *, start_step: int = 0) -> Any:
        step = start_step
        restarts = 0
        self._history: list[tuple[int, str]] = []
        while step < num_steps:
            try:
                t0 = time.monotonic()
                state = self.step_fn(state, step)
                dt = time.monotonic() - t0
                if self.straggler and self.straggler.observe(dt):
                    self._history.append((step, "straggler"))
                    if self.on_straggler:
                        self.on_straggler(step)
                step += 1
                if step % self.ckpt_interval == 0 or step == num_steps:
                    checkpoint.save(
                        self.ckpt_dir,
                        step,
                        self.save_state(state),
                        metadata={**(self.metadata or {}), "supervised": True},
                    )
                    checkpoint.retention(self.ckpt_dir, keep_last=3)
            except Exception as e:  # noqa: BLE001 — any step fault
                restarts += 1
                self._history.append((step, f"fault: {type(e).__name__}"))
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts at step {step}"
                    ) from e
                last = checkpoint.latest_step(self.ckpt_dir)
                if last is None:
                    raise  # nothing to restore from
                template = self.save_state(state)
                restored, manifest = checkpoint.restore(self.ckpt_dir, template)
                state = self.load_state(restored)
                step = manifest["step"]
        return state

    @property
    def history(self) -> list[tuple[int, str]]:
        return list(getattr(self, "_history", []))
