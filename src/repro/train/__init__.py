from repro.train.optimizer import adamw, sgd  # noqa: F401
from repro.train.trainer import TrainState, make_train_step  # noqa: F401
