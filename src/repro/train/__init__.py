from repro.train.optimizer import adamw, sgd  # noqa: F401
from repro.train.resilience import (  # noqa: F401
    NonFiniteLossError,
    run_resilient_training,
    validate_sparse_state,
)
from repro.train.sparse import (  # noqa: F401
    SparseMLPState,
    init_sparse_mlp_state,
    make_sparse_train_step,
)
from repro.train.trainer import TrainState, make_train_step  # noqa: F401
