"""Fault-tolerant sparse training: Supervisor + checkpoints wired into
the sparse-MLP loop (docs/robustness.md).

``run_resilient_training`` drives :func:`repro.train.sparse.
make_sparse_train_step` under :class:`repro.train.fault_tolerance.
Supervisor` with :mod:`repro.train.checkpoint` as the restore source:

* sparse layouts (block-CSR / ELL-BSR pytrees) checkpoint and restore
  **exactly** — float32 values round-trip bit-identically through the
  npz payload and integer topology leaves keep their dtypes, so a
  resumed run replays the same losses to the last bit;
* a non-finite loss raises :class:`NonFiniteLossError` BEFORE the
  poisoned update is committed; the Supervisor restores the last good
  checkpoint and replays — because the batch pipeline is deterministic
  in ``step`` (and an injected fault fires only once), the replay is
  clean: restore-and-skip, with the discarded attempts reported;
* every restore re-validates the restored layouts
  (:func:`validate_sparse_state`) so a corrupt checkpoint fails loudly
  at the restore boundary, not as silent garbage ten steps later.

Kill-and-resume: call again with ``resume=True`` (the default) on a
directory holding checkpoints and training continues from the latest
manifest step — the bit-identical-replay property tested in
``tests/test_train_resilience.py``.
"""

from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.testing import faults as _faults
from repro.train import checkpoint
from repro.train.fault_tolerance import StragglerPolicy, Supervisor
from repro.train.optimizer import Optimizer
from repro.train.sparse import SparseMLPState, make_sparse_train_step


class NonFiniteLossError(RuntimeError):
    """Loss went NaN/Inf: the in-flight update must not be committed."""


def validate_sparse_state(
    state: SparseMLPState, *, name: str = "SparseMLPState"
) -> SparseMLPState:
    """Validate every layer of a sparse training state: structural
    layout invariants for sparse weights (``validate()``), finiteness
    for dense weights and biases. Returns ``state``; raises ValueError
    naming the offending layer. Called on every checkpoint restore."""
    for i, w in enumerate(state.weights):
        if hasattr(w, "validate"):
            w.validate(name=f"{name} layer {i} weight")
        elif not bool(jnp.isfinite(w).all()):
            raise ValueError(
                f"{name} layer {i} weight has non-finite entries"
            )
    for i, b in enumerate(state.biases):
        if not bool(jnp.isfinite(b).all()):
            raise ValueError(f"{name} layer {i} bias has non-finite entries")
    return state


def run_resilient_training(
    state: SparseMLPState,
    batch_fn: Callable[[int], dict],
    optimizer: Optimizer,
    num_steps: int,
    ckpt_dir: str,
    *,
    ckpt_interval: int = 10,
    max_restarts: int = 3,
    use_kernel: bool = True,
    interpret: bool | None = None,
    plan: Any = None,
    fault_injector: Any = None,
    straggler: StragglerPolicy | None = None,
    resume: bool = True,
    metadata: dict | None = None,
) -> tuple[SparseMLPState, dict]:
    """Train the sparse stack for ``num_steps`` with checkpoint/restart.

    ``batch_fn(step) -> {"y0": ..., "targets": ...}`` MUST be
    deterministic in ``step`` — that determinism is the whole recovery
    story (DESIGN.md §6): a restart replays the exact batch stream, so
    restored runs are bit-identical to never-failed ones.

    ``fault_injector`` is polled at ``SITE_TRAIN_NAN_LOSS`` per step; a
    fire poisons that step's batch, which surfaces as a non-finite loss
    → restore-and-skip. Returns ``(final_state, report)`` where report
    has ``losses`` (step → float loss, replayed steps overwritten with
    identical values), ``skipped`` (steps whose poisoned attempt was
    discarded), ``restarts`` (Supervisor fault history), and
    ``start_step`` (where this call actually began).
    """
    if num_steps < 1:
        raise ValueError("num_steps must be >= 1")
    validate_sparse_state(state)
    train_step = jax.jit(
        make_sparse_train_step(
            optimizer, use_kernel=use_kernel, interpret=interpret, plan=plan
        )
    )

    losses: dict[int, float] = {}
    poisoned: set[int] = set()

    def step_fn(st: SparseMLPState, step: int) -> SparseMLPState:
        batch = batch_fn(step)
        if fault_injector is not None:
            spec = fault_injector.fires(_faults.SITE_TRAIN_NAN_LOSS, step)
            if spec is not None:
                poisoned.add(step)
                batch = dict(batch)
                batch["y0"] = batch["y0"].at[0, 0].set(float("nan"))
        new_st, metrics = train_step(st, batch)
        loss = float(metrics["loss"])
        if not math.isfinite(loss):
            # Raise BEFORE the Supervisor commits new_st: the poisoned
            # update dies here and the restore path takes over.
            raise NonFiniteLossError(f"loss={loss} at step {step}")
        losses[step] = loss
        return new_st

    def load_state(tree: SparseMLPState) -> SparseMLPState:
        return validate_sparse_state(tree, name="restored SparseMLPState")

    start_step = 0
    last = checkpoint.latest_step(ckpt_dir)
    if resume and last is not None:
        restored, manifest = checkpoint.restore(ckpt_dir, state)
        state = load_state(restored)
        start_step = int(manifest["step"])
    elif last is None:
        # Seed the restore path: a fault on the very first steps needs
        # a step-0 checkpoint to fall back to.
        checkpoint.save(
            ckpt_dir, 0, state,
            metadata={**(metadata or {}), "initial": True},
        )

    sup = Supervisor(
        step_fn=step_fn,
        save_state=lambda st: st,
        load_state=load_state,
        ckpt_dir=ckpt_dir,
        ckpt_interval=ckpt_interval,
        max_restarts=max_restarts,
        straggler=straggler,
        metadata=metadata,
    )
    final = sup.run(state, num_steps, start_step=start_step)
    report = {
        "losses": dict(sorted(losses.items())),
        "skipped": sorted(poisoned),
        "restarts": [h for h in sup.history if h[1].startswith("fault")],
        "start_step": start_step,
        "final_step": num_steps,
    }
    return final, report


__all__ = [
    "NonFiniteLossError",
    "run_resilient_training",
    "validate_sparse_state",
]
