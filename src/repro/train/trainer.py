"""Train-step builder: value_and_grad + microbatch gradient accumulation
+ optimizer update, as a single jit-able function.

Microbatching is the memory lever for the 4k×256 train cells: the global
batch is split into ``microbatches`` chunks scanned sequentially, so live
activation memory is 1/microbatches of the full-batch footprint while
arithmetic is unchanged. Gradients accumulate in fp32.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import constrain_like_params
from repro.models.model import Model
from repro.train.optimizer import Optimizer, OptState, global_norm

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(model: Model, optimizer: Optimizer, key) -> TrainState:
    params = model.init(key)
    return TrainState(params, optimizer.init(params))


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves have leading dim = global_batch; with microbatching the
    leading dim must divide evenly into ``microbatches`` chunks.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    # allow_int: sparse (BSR) weights carry int32 col_idx / bool mask
    # leaves — their cotangents come back as float0 and are dropped below.
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True, allow_int=True)

    def _float(x) -> bool:
        return jnp.issubdtype(x.dtype, jnp.inexact)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, constrain_like_params(grads)

    def accumulated(params, batch):
        def split(x):
            return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

        mb = jax.tree.map(split, batch)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32)
            if jnp.issubdtype(p.dtype, jnp.floating)
            else jnp.zeros(p.shape, p.dtype),
            params,
        )

        def body(carry, mbatch):
            loss_sum, metrics_sum, grads = carry
            (loss, metrics), g = grad_fn(params, mbatch)
            g = constrain_like_params(g)
            grads = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype) if _float(a) else a,
                grads,
                g,
            )
            grads = constrain_like_params(grads)
            metrics_sum = jax.tree.map(lambda a, b: a + b, metrics_sum, metrics)
            return (loss_sum + loss, metrics_sum, grads), None

        init_metrics = {"ce": jnp.zeros(()), "moe_aux": jnp.zeros(())}
        (loss_sum, metrics_sum, grads), _ = jax.lax.scan(
            body, (jnp.zeros(()), init_metrics, zero_grads), mb
        )
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv if _float(g) else g, grads)
        metrics = jax.tree.map(lambda a: a * inv, metrics_sum)
        return loss_sum * inv, metrics, grads

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if microbatches > 1:
            loss, metrics, grads = accumulated(state.params, batch)
        else:
            loss, metrics, grads = single(state.params, batch)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = global_norm(grads)
        return TrainState(new_params, new_opt), metrics

    return train_step
