"""Training loop for the paper's masked sparse MLP with the Pallas
kernels in the hot path of BOTH passes.

``repro.train.trainer`` drives the generic ``Model`` abstraction; this
module is the dnn-stack-level loop the paper actually describes — an
L-layer list of (W, b) with W dense, ELL-BSR, or block-CSR — wired
through ``repro.core.dnn.dnn_forward_trainable`` so the forward runs the
SpMM kernels and the backward runs their custom VJPs
(``repro.kernels.autodiff``): dX = Wᵀ·dY (the CSR layout's dX is itself
a Pallas kernel call on the device-side transpose) and weight cotangents
only at stored block positions. Topology is frozen by construction: the
cotangent cannot touch a block the primal does not store, so "masked
retraining" needs no separate mask application.

Gradient pytrees mirror the param pytrees with float0 leaves for the
integer/bool topology arrays; ``repro.train.optimizer`` updates skip
non-float params by dtype, so AdamW/SGD consume sparse stacks as-is.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import dnn
from repro.train.optimizer import Optimizer, OptState, global_norm

Array = jax.Array


class SparseMLPState(NamedTuple):
    weights: tuple  # per-layer dense / BlockSparseMatrix / BlockCSRMatrix
    biases: tuple
    opt: OptState


def init_sparse_mlp_state(
    weights: Sequence[dnn.Weight],
    biases: Sequence[Array],
    optimizer: Optimizer,
) -> SparseMLPState:
    params = (tuple(weights), tuple(biases))
    return SparseMLPState(params[0], params[1], optimizer.init(params))


def make_sparse_train_step(
    optimizer: Optimizer,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    plan=None,
):
    """step(state, batch) -> (state, metrics) for the sparse-MLP stack.

    batch: {"y0": (m, n) activation panel, "targets": (m, n)} — the
    paper's column-batched convention (features down, batch across).
    ``use_kernel=True`` puts the Pallas kernels (and their custom VJPs)
    in the hot path; ``False`` uses the jnp oracle forms (same math,
    XLA autodiff) for CPU-bound runs. jit-able either way.

    ``plan``: a differentiable :class:`repro.plan.StackPlan` for the
    state's topology (``repro.plan.build_plan(weights, biases, n,
    differentiable=True)``). Its cached block-CSR transposes make every
    backward pass sort-free: the frozen topology is sorted exactly once,
    at plan build, instead of once per step — the GraphChallenge
    amortization applied to training. A mesh-sharded
    :class:`repro.plan.ShardedStackPlan` (``repro.plan.
    build_sharded_plan(..., differentiable=True)``) instead runs BOTH
    passes shard-local under shard_map: fresh values re-shard through
    the plan's frozen partition each step and weight cotangents come
    back on the caller's unsharded block-CSR layout, so the optimizer
    update is unchanged.
    """

    def loss_fn(params, batch):
        weights, biases = params
        out = dnn.dnn_forward_trainable(
            weights, biases, batch["y0"], use_kernel=use_kernel,
            interpret=interpret, plan=plan,
        )
        return 0.5 * jnp.mean((out - batch["targets"]) ** 2)

    # allow_int: sparse layouts carry int32/bool topology leaves whose
    # cotangents come back as float0 and are skipped by the optimizer.
    grad_fn = jax.value_and_grad(loss_fn, allow_int=True)

    def step(state: SparseMLPState, batch) -> tuple[SparseMLPState, dict]:
        params = (state.weights, state.biases)
        loss, grads = grad_fn(params, batch)
        new_params, new_opt = optimizer.update(grads, state.opt, params)
        metrics = {"loss": loss, "grad_norm": global_norm(grads)}
        return SparseMLPState(new_params[0], new_params[1], new_opt), metrics

    return step


def grad_sparsity_preserved(weights: Sequence[Any], grads: Sequence[Any]) -> bool:
    """True iff every sparse weight cotangent is zero outside the
    primal's stored pattern (the custom-VJP invariant; cheap host check
    for tests and training-loop asserts)."""
    from repro.sparse.bcsr import BlockCSRMatrix
    from repro.sparse.bsr import BlockSparseMatrix

    for w, g in zip(weights, grads):
        if isinstance(w, BlockSparseMatrix):
            off = jnp.where(w.block_mask[:, :, None, None], 0.0, g.blocks)
        elif isinstance(w, BlockCSRMatrix):
            off = jnp.where(w.valid[:, None, None], 0.0, g.values)
        else:
            continue
        if float(jnp.max(jnp.abs(off))) != 0.0:
            return False
    return True
