"""Optimizers built from scratch (no optax in the target environment).

AdamW with optionally bf16 first/second moments (halves optimizer HBM —
at 512 chips the m/v states of a 236B model drop from 1.9 GB to 0.9 GB
per device), decoupled weight decay, and a linear-warmup cosine schedule.
State pytrees mirror the param tree, so the FSDP param PartitionSpecs
apply verbatim to optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class OptState(NamedTuple):
    step: Array  # int32 scalar
    mu: Any  # first moment (param-tree)
    nu: Any  # second moment (param-tree)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]
    # update(grads, state, params) -> (new_params, new_state)


def _tree_cast(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype)
        if jnp.issubdtype(a.dtype, jnp.floating)
        else a,
        tree,
    )


def adamw(
    lr: float | Callable[[Array], Array],
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    state_dtype=jnp.float32,
    grad_clip_norm: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params) -> OptState:
        zeros = jax.tree.map(
            lambda a: jnp.zeros(a.shape, state_dtype)
            if jnp.issubdtype(a.dtype, jnp.floating)
            else jnp.zeros(a.shape, a.dtype),
            params,
        )
        return OptState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.copy, zeros))

    def update(grads, state: OptState, params):
        step = state.step + 1
        grads = _tree_cast(grads, jnp.float32)
        if grad_clip_norm:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(
                lambda g: g * scale
                if jnp.issubdtype(g.dtype, jnp.inexact)
                else g,
                grads,
            )
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            if not jnp.issubdtype(p.dtype, jnp.floating):
                return p, m, v
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m32 = b1 * m32 + (1.0 - b1) * g
            v32 = b2 * v32 + (1.0 - b2) * g * g
            mhat, vhat = m32 / c1, v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
                jnp.float32
            )
            newp = p.astype(jnp.float32) - lr_t * delta
            return (
                newp.astype(p.dtype),
                m32.astype(state_dtype),
                v32.astype(state_dtype),
            )

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        newp = treedef.unflatten([o[0] for o in out])
        newm = treedef.unflatten([o[1] for o in out])
        newv = treedef.unflatten([o[2] for o in out])
        return newp, OptState(step, newm, newv)

    return Optimizer(init, update)


def sgd(lr: float | Callable, *, momentum: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params) -> OptState:
        zeros = jax.tree.map(lambda a: jnp.zeros_like(a), params)
        return OptState(jnp.zeros((), jnp.int32), zeros, jnp.zeros(()))

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            # non-float params (sparse-layout topology leaves) are frozen;
            # their cotangents are float0 and must not be cast or applied.
            if not jnp.issubdtype(p.dtype, jnp.floating):
                return p, m
            m = momentum * m + g.astype(m.dtype)
            return (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), m

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (
            treedef.unflatten([o[0] for o in out]),
            OptState(step, treedef.unflatten([o[1] for o in out]), state.nu),
        )

    return Optimizer(init, update)


def global_norm(tree) -> Array:
    sq = sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)))
        for l in jax.tree.leaves(tree)
        if jnp.issubdtype(l.dtype, jnp.floating)
    )
    return jnp.sqrt(sq)


def warmup_cosine(
    peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
) -> Callable[[Array], Array]:
    def schedule(step: Array) -> Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (
            final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        )
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
